//! End-to-end serving driver (the repo's E2E validation run).
//!
//!   make artifacts && cargo run --release --offline --example shared_prefix_serving
//!
//! Loads the AOT-compiled tiny MLA transformer (real weights, real
//! numerics) into the PJRT CPU runtime, serves batched requests over a
//! shared system prompt through the full stack — continuous-batching
//! coordinator, paged KV-cache with prefix sharing, TyphoonMLA kernel
//! policy — and reports latency/throughput per kernel variant, plus a
//! token-level equivalence check between them.  Results are recorded in
//! EXPERIMENTS.md §E2E.

// Real-runtime E2E driver: wall clocks are the measurement, not a
// determinism hazard (outside rust/src, so detlint does not scan it).
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use typhoon_mla::config::model::tiny;
use typhoon_mla::config::{KernelKind, ServingConfig};
use typhoon_mla::coordinator::{Coordinator, KernelPolicy};
use typhoon_mla::kvcache::KvCacheManager;
use typhoon_mla::runtime::{default_artifacts_dir, TinyModelEngine};
use typhoon_mla::util::rng::Rng;
use typhoon_mla::workload::Request;

const N_REQUESTS: u64 = 24;
const GEN_TOKENS: usize = 16;

fn run(kernel: KernelKind, b_theta: usize) -> anyhow::Result<(Vec<(u64, Vec<i32>)>, String, f64)> {
    let dir = default_artifacts_dir();
    let engine = TinyModelEngine::new(&dir, kernel)?;
    let cfg = ServingConfig {
        block_size: 16,
        max_batch: 8,
        max_seq_len: 128,
        total_blocks: 2048,
        kernel,
        ..Default::default()
    };
    let policy = KernelPolicy::with_threshold(kernel, b_theta);
    let kv = KvCacheManager::new(tiny(), cfg.total_blocks, cfg.block_size);
    let mut c = Coordinator::new(cfg, policy, kv, engine)?;

    // A 200-token synthetic "system prompt" (byte-level vocabulary).
    let mut rng = Rng::new(1234);
    let prompt: Vec<u32> = (0..200).map(|_| rng.gen_range(1, 256) as u32).collect();
    let t0 = Instant::now();
    c.set_shared_prefix(&prompt)?;

    for i in 0..N_REQUESTS {
        c.submit(&Request {
            id: i,
            prompt_tokens: 6 + (i as usize * 5) % 40,
            max_new_tokens: GEN_TOKENS,
        })?;
    }
    c.run_to_completion()?;
    let wall = t0.elapsed().as_secs_f64();
    let compile_s = c.engine.compile_seconds();

    let m = &c.metrics;
    let report = format!(
        "tokens={} requests={} iters={} wall={:.2}s engine_time={:.2}s \
         throughput={:.1} tok/s p50_lat={:.2}s kernels(t/a/n)={}/{}/{} compile={:.1}s",
        m.tokens_generated,
        m.requests_completed,
        m.decode_iterations,
        wall,
        m.elapsed(),
        m.tokens_generated as f64 / m.elapsed(),
        {
            let mut lat = m.request_latency.clone();
            lat.median()
        },
        m.typhoon_iters,
        m.absorb_iters,
        m.naive_iters,
        compile_s,
    );
    let mut gen: Vec<(u64, Vec<i32>)> =
        c.engine.generated.iter().map(|(k, v)| (*k, v.clone())).collect();
    gen.sort();
    Ok((gen, report, m.tokens_generated as f64 / m.elapsed()))
}

fn main() -> anyhow::Result<()> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        anyhow::bail!("artifacts not built — run `make artifacts` first");
    }
    println!("== end-to-end serving: tiny MLA transformer on PJRT CPU ==");
    println!("   {} requests x {} tokens, batch 8, shared 200-token prompt\n", N_REQUESTS, GEN_TOKENS);

    let mut outputs = Vec::new();
    for (kernel, b_theta, label) in [
        (KernelKind::Typhoon, 2, "typhoon"),
        (KernelKind::Absorb, 2, "absorb "),
        (KernelKind::Naive, 2, "naive  "),
        (KernelKind::Typhoon, 1000, "typhoon-fallback"),
    ] {
        let (gen, report, _) = run(kernel, b_theta)?;
        println!("[{label}] {report}");
        outputs.push((label, gen));
    }

    // Mathematical-equivalence check at system level: every variant must
    // generate the exact same token streams.
    let reference = &outputs[0].1;
    for (label, gen) in &outputs[1..] {
        assert_eq!(
            gen, reference,
            "{label} diverged from typhoon — equivalence violated"
        );
    }
    println!("\nEquivalence check: all variants produced identical tokens for all {} requests. OK", N_REQUESTS);

    // Show a sample generation (byte tokens).
    let (id, tokens) = &reference[0];
    println!("sample: request {id} -> {:?}", tokens);
    Ok(())
}
