//! Tree-of-Thought decoding scenario (paper §2.2): many reasoning
//! branches share a long common prefix.  Demonstrates
//!  * radix-tree prefix reuse in the KV-cache manager (no duplicate
//!    pages across branches, ~3% expansion overhead), and
//!  * the throughput advantage TyphoonMLA extracts from branch-level
//!    data reuse, via the cost-model simulator.
//!
//!   cargo run --release --offline --example tree_decode [--branches 64]

use typhoon_mla::config::hardware::ascend_npu;
use typhoon_mla::config::model::deepseek_v3;
use typhoon_mla::config::KernelKind;
use typhoon_mla::costmodel::exec_time::attention_time;
use typhoon_mla::costmodel::flops::AttentionWorkload;
use typhoon_mla::kvcache::KvCacheManager;
use typhoon_mla::util::cli::Args;
use typhoon_mla::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&[])?;
    let branches = args.get_usize("branches", 64)?;
    let prefix_len = args.get_usize("prefix", 4096)?;
    let branch_len = args.get_usize("branch-len", 256)?;

    // ---- KV-cache view -----------------------------------------------------
    let model = deepseek_v3();
    let mut kv = KvCacheManager::new(model.clone(), 65536, 128);
    let mut rng = Rng::new(7);
    let prompt: Vec<u32> = (0..prefix_len).map(|_| rng.gen_range(0, 50000) as u32).collect();

    let pid = kv.register_shared_prefix(&prompt)?;
    let pages_after_prefix = kv.used_blocks();
    kv.expand_shared_prefix(pid)?;
    for b in 0..branches as u64 {
        kv.add_sequence(b, pid, branch_len)?;
    }
    let pages_per_branch =
        (kv.used_blocks() - pages_after_prefix) as f64 / branches as f64;
    println!("== KV-cache: {branches} branches over a {prefix_len}-token prefix ==");
    println!(
        "  prefix pages: {pages_after_prefix} (shared once), per-branch pages: {pages_per_branch:.1}"
    );
    println!(
        "  naive duplication would need {} pages; radix sharing uses {}",
        pages_after_prefix * branches + (pages_per_branch as usize) * branches,
        kv.used_blocks()
    );
    println!(
        "  typhoon uncompressed copy: {:.1}x the currently-live latent bytes \
         (amortizes to ~3% at production batch/seq scale — see `figures fig5`)",
        kv.expansion_overhead()
    );

    // ---- throughput view ----------------------------------------------------
    let hw = ascend_npu();
    println!("\n== per-iteration attention time (DeepSeek-v3, Ascend) ==");
    println!(
        "{:>9} {:>12} {:>12} {:>12} {:>9}",
        "branches", "naive ms", "absorb ms", "typhoon ms", "speedup"
    );
    for b in [1usize, 8, 32, 64, 128, 256, 512] {
        let wl = AttentionWorkload::decode(b as u64, prefix_len as u64, branch_len as u64);
        let n = attention_time(&model, KernelKind::Naive, &wl, &hw) * 1e3;
        let a = attention_time(&model, KernelKind::Absorb, &wl, &hw) * 1e3;
        let t = attention_time(&model, KernelKind::Typhoon, &wl, &hw) * 1e3;
        // The policy would fall back below B_theta=61.
        let t_eff = if b < 61 { a } else { t };
        println!(
            "{:>9} {:>12.3} {:>12.3} {:>12.3} {:>8.2}x",
            b,
            n,
            a,
            t_eff,
            n.min(a) / t_eff
        );
    }
    println!("\nBranch counts past B_theta=61 unlock the naive stage's data reuse;\nspeculative decoding (S_q>1 per branch) lowers the threshold further.");
    Ok(())
}
