//! Quickstart: the TyphoonMLA public API in five minutes.
//!
//!   cargo run --release --offline --example quickstart
//!
//! Walks through (1) the Table-1 cost model, (2) the Eq. 1 fall-back
//! threshold, (3) the kernel-selection policy, and (4) a small
//! simulated serving run — no artifacts required.

use typhoon_mla::config::hardware::ascend_npu;
use typhoon_mla::config::model::deepseek_v3;
use typhoon_mla::config::KernelKind;
use typhoon_mla::coordinator::KernelPolicy;
use typhoon_mla::costmodel::exec_time::attention_time;
use typhoon_mla::costmodel::flops::{attention_cost, AttentionWorkload};
use typhoon_mla::costmodel::threshold::batch_threshold;
use typhoon_mla::costmodel::ParallelismConfig;
use typhoon_mla::simulator::{run_experiment, SimParams};
use typhoon_mla::workload::datasets::mmlu;
use typhoon_mla::workload::prompts::PROMPT_A;

fn main() -> anyhow::Result<()> {
    let model = deepseek_v3();
    let hw = ascend_npu();

    // 1. Table-1 cost model: one decode iteration, batch 256, 26k-token
    //    shared prompt, 512-token suffixes.
    let wl = AttentionWorkload::decode(256, PROMPT_A.tokens as u64, 512);
    println!("== operation counts (DeepSeek-v3, B=256, Ls=26472, Ln=512) ==");
    for kind in KernelKind::all() {
        let c = attention_cost(&model, kind, &wl).attention_only();
        let t = attention_time(&model, kind, &wl, &hw);
        println!(
            "  {:<8} {:>8.1} GMAC {:>9.1} MWords -> {:>7.3} ms/layer",
            kind.as_str(),
            c.macs as f64 / 1e9,
            c.hbm_words as f64 / 1e6,
            t * 1e3
        );
    }

    // 2. Eq. 1: when does the naive stage pay off?
    let b_theta = batch_threshold(&model, &hw, 1);
    println!("\n== fall-back threshold ==\n  B_theta = {b_theta} (paper: 61)");

    // 3. The policy in action (single device; a TP/SP-sharded stack
    //    would pass its own `ParallelismConfig` for the per-rank Eq. 1).
    let policy = KernelPolicy::from_parallelism(
        KernelKind::Typhoon,
        &model,
        &hw,
        1,
        &ParallelismConfig::single(),
    );
    for b in [16usize, 61, 256] {
        println!(
            "  batch {b:>4} -> {}",
            policy.select(b, PROMPT_A.tokens).as_str()
        );
    }

    // 4. A small simulated serving run (MMLU questions over Prompt A).
    println!("\n== simulated serving run (256 requests, batch 128) ==");
    for kind in KernelKind::all() {
        let mut p = SimParams::new(model.clone(), hw.clone(), kind, 128);
        p.max_requests = Some(256);
        let r = run_experiment(&p, &mmlu(), &PROMPT_A)?;
        println!(
            "  {:<8} {:>9.0} tok/s/layer ({} tokens, {} iterations)",
            kind.as_str(),
            r.throughput,
            r.tokens,
            r.iterations
        );
    }
    println!("\nNext: `cargo run --release --bin figures -- all` regenerates every\npaper table/figure; `--example shared_prefix_serving` runs the real\nPJRT-backed tiny model end to end.");
    Ok(())
}
