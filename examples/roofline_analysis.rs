//! Roofline + threshold exploration (paper Appendix A.1 / Eq. 1)
//! across models and hardware — the capacity-planning view a deployer
//! would use to decide where TyphoonMLA pays off.
//!
//!   cargo run --release --offline --example roofline_analysis [--ls 4096]

use typhoon_mla::config::hardware::{ascend_npu, gpu_h800, roofline_npu};
use typhoon_mla::config::model::{deepseek_v3, kimi_k2};
use typhoon_mla::config::KernelKind;
use typhoon_mla::costmodel::roofline::{ridge_batch, roofline_point};
use typhoon_mla::costmodel::threshold::{batch_threshold, batch_threshold_exact};
use typhoon_mla::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&[])?;
    let l_ctx = args.get_usize("ls", 4096)? as u64;

    println!("== roofline: query-token throughput vs batch (L={l_ctx}) ==");
    let hw = roofline_npu();
    for model in [deepseek_v3(), kimi_k2()] {
        println!("\n-- {} on {} --", model.name, hw.name);
        println!(
            "{:>6} {:>16} {:>16} {:>8}",
            "batch", "naive tok/s", "absorb tok/s", "ratio"
        );
        for b in [1u64, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024] {
            let n = roofline_point(&model, KernelKind::Naive, &hw, b, l_ctx);
            let a = roofline_point(&model, KernelKind::Absorb, &hw, b, l_ctx);
            println!(
                "{:>6} {:>13.0} ({}) {:>13.0} ({}) {:>7.2}x",
                b,
                n.throughput,
                if n.compute_bound { 'C' } else { 'M' },
                a.throughput,
                if a.compute_bound { 'C' } else { 'M' },
                n.throughput / a.throughput
            );
        }
        println!(
            "ridge batches: naive {:.1}, absorb {:.2}",
            ridge_batch(&model, KernelKind::Naive, &hw),
            ridge_batch(&model, KernelKind::Absorb, &hw)
        );
    }

    println!("\n== Eq. 1 fall-back thresholds across deployments ==");
    println!(
        "{:<14} {:>12} {:>12} {:>12} {:>12}",
        "model", "hardware", "T (TOPS)", "M (TB/s)", "B_theta"
    );
    for model in [deepseek_v3(), kimi_k2()] {
        for hw in [ascend_npu(), gpu_h800()] {
            println!(
                "{:<14} {:>12} {:>12.0} {:>12.1} {:>7} ({:.1})",
                model.name,
                hw.name,
                hw.peak_ops / 1e12,
                hw.hbm_bw / 1e12,
                batch_threshold(&model, &hw, 1),
                batch_threshold_exact(&model, &hw, 1),
            );
        }
    }
    println!("\nSpeculative decode (S_q > 1) divides the threshold:");
    let model = deepseek_v3();
    let hw = ascend_npu();
    for sq in [1u64, 2, 4, 8] {
        println!(
            "  S_q = {sq}: B_theta = {}",
            batch_threshold(&model, &hw, sq)
        );
    }
    Ok(())
}
