//! Loom models of the repo's two audited lock protocols.
//!
//! The models live in `tests/` (`pool_handoff.rs`,
//! `price_surface.rs`) and re-state the protocols of
//! `rust/src/util/pool.rs` and `rust/src/costmodel/surface.rs` in
//! loom's checked primitives, small enough for exhaustive
//! interleaving exploration:
//!
//! * **Pool handoff** — a job published under the state mutex as
//!   `(epoch+1, active=participants)` with a condvar wakeup; workers
//!   drain a shared `fetch_add` cursor and check out by decrementing
//!   `active`; the caller blocks until `active == 0`.  Properties:
//!   every index executes exactly once, no worker touches the job
//!   after the caller's wait returns (the lifetime-erasure soundness
//!   claim), and of concurrent failure payloads exactly the first
//!   stash wins.
//! * **PriceSurface insert race** — hits take a read lock; a miss
//!   computes outside any lock and inserts under the write lock.  Two
//!   threads missing the same key both compute the same pure value,
//!   so whichever insert wins the stored value is identical and
//!   `hits + misses` equals the call count.
//!
//! Run with `cargo test --release` in this directory (release: loom
//! explores thousands of executions per model).
