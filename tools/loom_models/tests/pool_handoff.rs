//! Loom model of the `WorkerPool` handoff protocol
//! (rust/src/util/pool.rs, DESIGN.md §17).
//!
//! The real pool parks immortal workers on a condvar; loom needs every
//! thread to terminate, so the model gives the epoch counter one extra
//! value meaning "shut down" (`job == None`), published exactly like a
//! job.  Everything else is the production protocol verbatim: publish
//! `(epoch+1, active=participants)` under the state mutex, notify the
//! work condvar, workers drain a shared `fetch_add` cursor, check out
//! by decrementing `active`, and the caller blocks on the done condvar
//! until `active == 0`.

use loom::sync::atomic::{AtomicUsize, Ordering};
use loom::sync::{Arc, Condvar, Mutex};
use loom::thread;

const WORKERS: usize = 2;
const ITEMS: usize = 3;

struct State {
    epoch: u64,
    /// `Some(items)` publishes a job; `None` at a new epoch shuts down.
    job: Option<usize>,
    active: usize,
}

struct Pool {
    state: Mutex<State>,
    work: Condvar,
    done: Condvar,
    cursor: AtomicUsize,
    counts: [AtomicUsize; ITEMS],
    /// First failure payload wins (models the `panicked` stash; the
    /// payload is the worker id instead of a panic payload).
    panicked: Mutex<Option<usize>>,
}

fn worker(pool: &Pool, worker_id: usize, fail: bool) {
    let mut seen_epoch = 0u64;
    loop {
        let job = {
            let mut st = pool.state.lock().unwrap();
            while st.epoch == seen_epoch {
                st = pool.work.wait(st).unwrap();
            }
            seen_epoch = st.epoch;
            st.job
        };
        let Some(items) = job else { return };
        loop {
            let i = pool.cursor.fetch_add(1, Ordering::Relaxed);
            if i >= items {
                break;
            }
            pool.counts[i].fetch_add(1, Ordering::Relaxed);
        }
        if fail {
            // The production worker stashes the first caught panic
            // payload OUTSIDE the state lock — same order here.
            let mut slot = pool.panicked.lock().unwrap();
            if slot.is_none() {
                *slot = Some(worker_id);
            }
        }
        let mut st = pool.state.lock().unwrap();
        st.active -= 1;
        if st.active == 0 {
            pool.done.notify_all();
        }
    }
}

fn run_model(fail: bool) {
    loom::model(move || {
        let pool = Arc::new(Pool {
            state: Mutex::new(State { epoch: 0, job: None, active: 0 }),
            work: Condvar::new(),
            done: Condvar::new(),
            cursor: AtomicUsize::new(0),
            counts: [AtomicUsize::new(0), AtomicUsize::new(0), AtomicUsize::new(0)],
            panicked: Mutex::new(None),
        });
        let handles: Vec<_> = (0..WORKERS)
            .map(|id| {
                let p = Arc::clone(&pool);
                thread::spawn(move || worker(&p, id, fail))
            })
            .collect();

        // Publish the job exactly as WorkerPool::run does.
        {
            let mut st = pool.state.lock().unwrap();
            st.job = Some(ITEMS);
            st.epoch += 1;
            st.active = WORKERS;
            pool.work.notify_all();
        }
        // Completion wait: by the time this returns, no worker holds
        // the job — the lifetime-erasure soundness claim.
        {
            let mut st = pool.state.lock().unwrap();
            while st.active != 0 {
                st = pool.done.wait(st).unwrap();
            }
            st.job = None;
        }
        // Exactly-once execution, observed at the instant the caller's
        // wait returns (not after join).
        for (i, c) in pool.counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 1, "index {i} ran once");
        }
        let payload = pool.panicked.lock().unwrap().take();
        if fail {
            let id = payload.expect("a failing job re-raises exactly one payload");
            assert!(id < WORKERS, "payload is the first failing worker's");
        } else {
            assert!(payload.is_none(), "clean jobs re-raise nothing");
        }

        // Shutdown epoch (model-only): wake workers with job == None.
        {
            let mut st = pool.state.lock().unwrap();
            st.epoch += 1;
            st.job = None;
            pool.work.notify_all();
        }
        for h in handles {
            h.join().unwrap();
        }
    });
}

#[test]
fn handoff_runs_each_index_exactly_once() {
    run_model(false);
}

#[test]
fn first_failure_payload_wins_and_reaches_the_caller() {
    run_model(true);
}
