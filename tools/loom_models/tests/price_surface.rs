//! Loom model of the `PriceSurface` memo protocol
//! (rust/src/costmodel/surface.rs, DESIGN.md §17).
//!
//! Production protocol: a hit takes the read lock only; a miss
//! computes OUTSIDE any lock, then takes the write lock to insert.
//! Two threads missing the same key both compute — the priced
//! function is pure, so the stored value is bit-identical whichever
//! insert wins.  The model checks the protocol's published claims:
//! every caller returns the pure value, the memo ends up holding it,
//! and `hits + misses` equals the call count (only the split is
//! schedule-dependent).

use loom::sync::atomic::{AtomicU64, Ordering};
use loom::sync::{Arc, RwLock};
use loom::thread;

/// The pure pricing function both threads evaluate on a miss.
const PURE_VALUE: u64 = 42;

struct Surface {
    /// One-key stand-in for `DenseMemo`.
    memo: RwLock<Option<u64>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

/// `PriceSurface::cost` / `kernel_seconds`, shrunk to one key.
fn price(s: &Surface) -> u64 {
    if let Some(v) = *s.memo.read().unwrap() {
        s.hits.fetch_add(1, Ordering::Relaxed);
        return v;
    }
    s.misses.fetch_add(1, Ordering::Relaxed);
    let v = PURE_VALUE; // computed outside any lock
    let mut memo = s.memo.write().unwrap();
    *memo = Some(v);
    v
}

#[test]
fn concurrent_misses_agree_and_the_split_accounts_for_every_call() {
    loom::model(|| {
        let s = Arc::new(Surface {
            memo: RwLock::new(None),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        });
        let a = {
            let s = Arc::clone(&s);
            thread::spawn(move || price(&s))
        };
        let got_main = price(&s);
        let got_a = a.join().unwrap();

        // Values are deterministic regardless of which insert won.
        assert_eq!(got_main, PURE_VALUE);
        assert_eq!(got_a, PURE_VALUE);
        assert_eq!(*s.memo.read().unwrap(), Some(PURE_VALUE));
        // Only the hit/miss split varies; the total never does.
        let (h, m) = (s.hits.load(Ordering::Relaxed), s.misses.load(Ordering::Relaxed));
        assert_eq!(h + m, 2, "hits {h} + misses {m} must cover both calls");
        assert!(m >= 1, "a cold memo always records at least one miss");
    });
}
