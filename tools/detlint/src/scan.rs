//! Source scanning: comment/string stripping and `detlint:` annotation
//! extraction.
//!
//! The rules in `rules.rs` operate on *code lines* — the input text with
//! every comment and every string/char-literal body blanked to spaces,
//! line structure preserved — so a `HashMap` mentioned in a doc comment
//! or an error message can never fire a rule.  Annotations
//! (`// detlint: allow(rule, reason)` and `// detlint: lock-protocol`)
//! are parsed from the *raw* lines, because they live inside comments by
//! design.

/// One `allow(rule, reason)` annotation as written in the source.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Allow {
    pub rule: String,
    pub reason: String,
}

/// A scanned source file ready for rule evaluation.
pub struct Scanned {
    /// Repo-relative path with forward slashes (e.g.
    /// `rust/src/simulator/cluster.rs`).
    pub path: String,
    /// Code lines: comments and literal bodies blanked, 1:1 with the
    /// raw lines.
    pub code: Vec<String>,
    /// Every annotation as parsed, with its 0-based source line (for
    /// hygiene checks: unknown rule names, empty reasons).
    pub all_allows: Vec<(usize, Allow)>,
    /// Effective suppressions per 0-based code line: a trailing
    /// annotation applies to its own line; an annotation on a
    /// comment-only line applies to the next line carrying code.
    pub line_allows: Vec<Vec<Allow>>,
    /// The file declared `// detlint: lock-protocol` — opt in to the
    /// lock-discipline rule regardless of path.
    pub lock_marker: bool,
}

impl Scanned {
    pub fn new(path: &str, text: &str) -> Scanned {
        let stripped = strip(text);
        let code: Vec<String> = stripped.lines().map(str::to_string).collect();
        let raw: Vec<&str> = text.lines().collect();
        let n = raw.len().max(code.len());

        let mut all_allows: Vec<(usize, Allow)> = Vec::new();
        let mut own: Vec<Vec<Allow>> = vec![Vec::new(); n];
        let mut lock_marker = false;
        for (i, line) in raw.iter().enumerate() {
            if let Some(cpos) = line.find("//") {
                let comment = &line[cpos..];
                if comment.contains("detlint: lock-protocol") {
                    lock_marker = true;
                }
                for a in parse_allows(comment) {
                    all_allows.push((i, a.clone()));
                    own[i].push(a);
                }
            }
        }

        // Attach: annotations on comment-only lines carry forward to the
        // next line that has code; trailing annotations stay put.
        let mut line_allows: Vec<Vec<Allow>> = vec![Vec::new(); n];
        let mut pending: Vec<Allow> = Vec::new();
        for i in 0..n {
            let code_blank = code.get(i).is_none_or(|l| l.trim().is_empty());
            if code_blank {
                pending.append(&mut own[i]);
            } else {
                line_allows[i].append(&mut pending);
                line_allows[i].append(&mut own[i]);
            }
        }

        Scanned { path: path.to_string(), code, all_allows, line_allows, lock_marker }
    }

    /// Is `rule` suppressed at 0-based line `i`?  Only well-formed
    /// annotations (known rule handled by the caller, non-empty reason)
    /// suppress.
    pub fn allowed(&self, i: usize, rule: &str) -> bool {
        self.line_allows
            .get(i)
            .is_some_and(|v| v.iter().any(|a| a.rule == rule && !a.reason.trim().is_empty()))
    }
}

/// Parse every `detlint: allow(rule, reason)` in a comment fragment.
/// The reason may itself contain balanced parentheses.
pub fn parse_allows(comment: &str) -> Vec<Allow> {
    let mut out = Vec::new();
    let mut rest = comment;
    while let Some(p) = rest.find("detlint:") {
        rest = &rest[p + "detlint:".len()..];
        let after = rest.trim_start();
        if let Some(body) = after.strip_prefix("allow(") {
            let mut depth = 1usize;
            let mut end = None;
            for (bi, c) in body.char_indices() {
                match c {
                    '(' => depth += 1,
                    ')' => {
                        depth -= 1;
                        if depth == 0 {
                            end = Some(bi);
                            break;
                        }
                    }
                    _ => {}
                }
            }
            let inner = match end {
                Some(e) => &body[..e],
                // Unclosed annotation: take the rest of the line so the
                // hygiene check can still flag the rule name.
                None => body,
            };
            let (rule, reason) = match inner.find(',') {
                Some(cp) => (inner[..cp].trim(), inner[cp + 1..].trim()),
                None => (inner.trim(), ""),
            };
            out.push(Allow { rule: rule.to_string(), reason: reason.to_string() });
        }
    }
    out
}

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Code,
    LineComment,
    Block(u32),
    Str,
    RawStr(usize),
    Char,
}

/// Blank comments and string/char-literal bodies to spaces, preserving
/// newlines exactly (line numbers in the output match the input).
/// Handles nested block comments, raw strings (`r#"…"#`), byte strings,
/// escapes, and the char-literal vs lifetime ambiguity.
pub fn strip(text: &str) -> String {
    let chars: Vec<char> = text.chars().collect();
    let mut out = String::with_capacity(text.len());
    let mut mode = Mode::Code;
    let mut prev_ident = false;
    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        let next = chars.get(i + 1).copied();
        match mode {
            Mode::Code => {
                if c == '/' && next == Some('/') {
                    mode = Mode::LineComment;
                    out.push_str("  ");
                    prev_ident = false;
                    i += 2;
                    continue;
                }
                if c == '/' && next == Some('*') {
                    mode = Mode::Block(1);
                    out.push_str("  ");
                    prev_ident = false;
                    i += 2;
                    continue;
                }
                if !prev_ident && (c == 'r' || c == 'b') {
                    // Candidate prefixed string literal: r"…", r#"…"#,
                    // b"…", br"…", b'…'.
                    let mut k = i + 1;
                    let mut raw = c == 'r';
                    if c == 'b' && chars.get(k) == Some(&'r') {
                        raw = true;
                        k += 1;
                    }
                    let mut hashes = 0usize;
                    if raw {
                        while chars.get(k) == Some(&'#') {
                            hashes += 1;
                            k += 1;
                        }
                    }
                    if chars.get(k) == Some(&'"') && (raw || c == 'b') {
                        for _ in i..=k {
                            out.push(' ');
                        }
                        mode = if raw { Mode::RawStr(hashes) } else { Mode::Str };
                        prev_ident = false;
                        i = k + 1;
                        continue;
                    }
                    if c == 'b' && next == Some('\'') {
                        // Byte char literal: blank the prefix, let the
                        // quote branch consume the body.
                        out.push(' ');
                        prev_ident = false;
                        i += 1;
                        continue;
                    }
                    // Plain identifier character; fall through.
                }
                if c == '"' {
                    mode = Mode::Str;
                    out.push(' ');
                    prev_ident = false;
                    i += 1;
                    continue;
                }
                if c == '\'' {
                    let is_char = match next {
                        Some('\\') => true,
                        Some(x) if x != '\'' => chars.get(i + 2) == Some(&'\''),
                        _ => false,
                    };
                    if is_char {
                        mode = Mode::Char;
                        out.push(' ');
                    } else {
                        // Lifetime tick: keep it, it is code.
                        out.push('\'');
                    }
                    prev_ident = false;
                    i += 1;
                    continue;
                }
                out.push(c);
                prev_ident = c.is_alphanumeric() || c == '_';
                i += 1;
            }
            Mode::LineComment => {
                if c == '\n' {
                    mode = Mode::Code;
                    out.push('\n');
                } else {
                    out.push(' ');
                }
                i += 1;
            }
            Mode::Block(d) => {
                if c == '/' && next == Some('*') {
                    mode = Mode::Block(d + 1);
                    out.push_str("  ");
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    mode = if d == 1 { Mode::Code } else { Mode::Block(d - 1) };
                    out.push_str("  ");
                    i += 2;
                } else {
                    out.push(if c == '\n' { '\n' } else { ' ' });
                    i += 1;
                }
            }
            Mode::Str | Mode::Char => {
                let terminator = if mode == Mode::Str { '"' } else { '\'' };
                if c == '\\' {
                    out.push(' ');
                    if let Some(n) = next {
                        out.push(if n == '\n' { '\n' } else { ' ' });
                        i += 2;
                    } else {
                        i += 1;
                    }
                } else if c == terminator {
                    mode = Mode::Code;
                    out.push(' ');
                    i += 1;
                } else {
                    out.push(if c == '\n' { '\n' } else { ' ' });
                    i += 1;
                }
            }
            Mode::RawStr(h) => {
                if c == '"' {
                    let mut cnt = 0usize;
                    while cnt < h && chars.get(i + 1 + cnt) == Some(&'#') {
                        cnt += 1;
                    }
                    if cnt == h {
                        for _ in 0..=h {
                            out.push(' ');
                        }
                        mode = Mode::Code;
                        i += 1 + h;
                        continue;
                    }
                }
                out.push(if c == '\n' { '\n' } else { ' ' });
                i += 1;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_line_and_block_comments() {
        let s = strip("let a = 1; // HashMap here\nlet /* HashMap */ b = 2;\n");
        assert!(!s.contains("HashMap"));
        assert!(s.contains("let a = 1;"));
        assert!(s.contains("b = 2;"));
        assert_eq!(s.lines().count(), 2);
    }

    #[test]
    fn strips_nested_block_comments() {
        let s = strip("a /* outer /* inner */ still comment */ b");
        assert!(s.contains('a'));
        assert!(s.contains('b'));
        assert!(!s.contains("still"));
    }

    #[test]
    fn strips_string_bodies_but_keeps_line_structure() {
        let s = strip("let m = \"HashMap::new()\\n more\";\nnext();\n");
        assert!(!s.contains("HashMap"));
        assert_eq!(s.lines().nth(1), Some("next();"));
    }

    #[test]
    fn strips_raw_and_byte_strings() {
        let s = strip("let r = r#\"Instant::now() \"quoted\" \"#; let b = b\"SystemTime\";");
        assert!(!s.contains("Instant"));
        assert!(!s.contains("SystemTime"));
        assert!(s.contains("let r ="));
        assert!(s.contains("let b ="));
    }

    #[test]
    fn lifetimes_survive_char_literals_do_not() {
        let s = strip("fn f<'a>(x: &'a str) { let c = 'y'; let n = '\\n'; }");
        assert!(s.contains("<'a>"));
        assert!(s.contains("&'a str"));
        assert!(!s.contains('y'), "char literal body must be blanked: {s}");
    }

    #[test]
    fn multiline_strings_keep_numbering() {
        let text = "let s = \"line one\nline two\";\nafter();\n";
        let s = strip(text);
        assert_eq!(s.lines().count(), 3);
        assert_eq!(s.lines().nth(2), Some("after();"));
        assert!(!s.contains("line two"));
    }

    #[test]
    fn parse_allow_with_reason() {
        let a = parse_allows("// detlint: allow(unordered-iter, builds a keyed map (order-free))");
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].rule, "unordered-iter");
        assert_eq!(a[0].reason, "builds a keyed map (order-free)");
    }

    #[test]
    fn parse_allow_without_reason_is_captured_empty() {
        let a = parse_allows("// detlint: allow(wall-clock)");
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].rule, "wall-clock");
        assert_eq!(a[0].reason, "");
    }

    #[test]
    fn standalone_annotation_attaches_to_next_code_line() {
        let sc = Scanned::new(
            "rust/src/simulator/x.rs",
            "// detlint: allow(wall-clock, harness timing)\nlet t = now();\n",
        );
        assert!(sc.allowed(1, "wall-clock"));
        assert!(!sc.allowed(0, "wall-clock"));
    }

    #[test]
    fn trailing_annotation_attaches_to_its_own_line() {
        let sc = Scanned::new(
            "rust/src/simulator/x.rs",
            "let t = now(); // detlint: allow(wall-clock, harness timing)\n",
        );
        assert!(sc.allowed(0, "wall-clock"));
    }

    #[test]
    fn empty_reason_never_suppresses() {
        let sc = Scanned::new(
            "rust/src/simulator/x.rs",
            "let t = now(); // detlint: allow(wall-clock)\n",
        );
        assert!(!sc.allowed(0, "wall-clock"));
    }

    #[test]
    fn lock_marker_detected() {
        let sc = Scanned::new("rust/src/other.rs", "//! detlint: lock-protocol\nfn f() {}\n");
        assert!(sc.lock_marker);
    }
}
