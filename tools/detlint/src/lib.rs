//! `detlint` — the determinism & concurrency static-analysis gate
//! (DESIGN.md §18).
//!
//! Five rules, each pinning an invariant the TyphoonMLA tree already
//! relies on:
//!
//! 1. `unordered-iter` — no `HashMap`/`HashSet` iteration in
//!    determinism-critical modules unless routed through
//!    `util::det::sorted_*` or annotated with a reason.
//! 2. `wall-clock` — no `Instant::now`/`SystemTime::now`/ambient
//!    randomness outside `bin/bench_sweep.rs`; simulations run on
//!    modeled time.
//! 3. `float-reduce` — no float reductions fed by an unordered
//!    iterator; accumulation order is part of the bit-identity
//!    contract.
//! 4. `oracle-coverage` — every retained reference-path flag
//!    (`use_linear_reference`, `use_hash_reference`,
//!    `use_spawn_reference`) stays exercised under `rust/tests/`.
//! 5. `lock-discipline` — no second lock acquisition while holding a
//!    guard in `costmodel/surface.rs` / `util/pool.rs` (or any file
//!    opting in via `// detlint: lock-protocol`).
//!
//! The frontend is a purpose-built comment/string-stripping scanner
//! (`scan`), not a full parser: the authoring containers have no crate
//! registry, so the crate is dependency-free by design, and the five
//! rules only need line-level syntax.  Escape hatch:
//! `// detlint: allow(<rule>, <reason>)` — a *non-empty* reason is
//! required; empty or unknown annotations are themselves violations.

pub mod rules;
pub mod scan;

use std::fs;
use std::path::Path;

/// One input file: repo-relative path (forward slashes) plus contents.
pub struct SourceFile {
    pub path: String,
    pub text: String,
}

/// A single rule violation at a 1-based line (0 = tree-level finding).
#[derive(Debug)]
pub struct Violation {
    pub path: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

/// The result of a full analysis pass.
pub struct Analysis {
    /// Violations sorted by (path, line, rule) — output is stable
    /// regardless of filesystem enumeration order.
    pub violations: Vec<Violation>,
    pub files_scanned: usize,
    /// Well-formed allow annotations that suppressed a firing rule.
    pub allows_used: usize,
}

/// Run every rule over `src` (rule 4 additionally reads `tests`).
pub fn analyze(src: &[SourceFile], tests: &[SourceFile]) -> Analysis {
    let mut violations = Vec::new();
    let mut suppressed = 0usize;
    for f in src {
        let sc = scan::Scanned::new(&f.path, &f.text);
        violations.extend(rules::rule_unordered_iter(&sc, &mut suppressed));
        violations.extend(rules::rule_wall_clock(&sc, &mut suppressed));
        violations.extend(rules::rule_float_reduce(&sc, &mut suppressed));
        violations.extend(rules::rule_lock_discipline(&sc, &mut suppressed));
        violations.extend(rules::rule_allow_syntax(&sc));
    }
    violations.extend(rules::rule_oracle_coverage(src, tests));
    violations.sort_by(|a, b| {
        a.path.cmp(&b.path).then(a.line.cmp(&b.line)).then(a.rule.cmp(b.rule))
    });
    Analysis { violations, files_scanned: src.len() + tests.len(), allows_used: suppressed }
}

/// Analyze the repository rooted at `root`: scans `rust/src/**` as rule
/// input and reads `rust/tests/**` for the oracle-coverage check.
pub fn analyze_tree(root: &Path) -> std::io::Result<Analysis> {
    let src = read_tree(root, "rust/src")?;
    let tests = read_tree(root, "rust/tests")?;
    Ok(analyze(&src, &tests))
}

/// Read every `.rs` file under `root/rel`, sorted by repo-relative
/// path so the scan is machine-independent.
fn read_tree(root: &Path, rel: &str) -> std::io::Result<Vec<SourceFile>> {
    let mut out = Vec::new();
    let dir = root.join(rel);
    if !dir.is_dir() {
        return Ok(out);
    }
    let mut stack = vec![dir];
    while let Some(d) = stack.pop() {
        for entry in fs::read_dir(&d)? {
            let p = entry?.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|x| x == "rs") {
                let relpath = p
                    .strip_prefix(root)
                    .unwrap_or(&p)
                    .components()
                    .map(|c| c.as_os_str().to_string_lossy())
                    .collect::<Vec<_>>()
                    .join("/");
                out.push(SourceFile { path: relpath, text: fs::read_to_string(&p)? });
            }
        }
    }
    out.sort_by(|a, b| a.path.cmp(&b.path));
    Ok(out)
}
