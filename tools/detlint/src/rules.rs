//! The five determinism/concurrency rules (DESIGN.md §18), evaluated
//! over scanned code lines.
//!
//! Every rule is syntactic and intentionally conservative: it flags the
//! patterns this repo's invariants forbid and accepts an explicit,
//! reason-carrying `// detlint: allow(rule, reason)` where a human has
//! argued the site is safe.  What syntax cannot see — lock temporaries
//! living past a statement, cross-file field types, real interleavings
//! — is covered by the dynamic legs (loom models, TSan, Miri; see
//! `.github/workflows/verify.yml`).

use crate::scan::Scanned;
use crate::{SourceFile, Violation};

/// Rule names, as written inside `allow(...)` annotations.
pub const RULE_UNORDERED_ITER: &str = "unordered-iter";
pub const RULE_WALL_CLOCK: &str = "wall-clock";
pub const RULE_FLOAT_REDUCE: &str = "float-reduce";
pub const RULE_ORACLE_COVERAGE: &str = "oracle-coverage";
pub const RULE_LOCK_DISCIPLINE: &str = "lock-discipline";
/// Hygiene pseudo-rule for malformed annotations (cannot be allowed).
pub const RULE_ALLOW_SYNTAX: &str = "allow-syntax";

pub const KNOWN_RULES: [&str; 5] = [
    RULE_UNORDERED_ITER,
    RULE_WALL_CLOCK,
    RULE_FLOAT_REDUCE,
    RULE_ORACLE_COVERAGE,
    RULE_LOCK_DISCIPLINE,
];

/// Modules whose iteration order is part of the bit-identity contract.
pub const CRITICAL_MODULES: [&str; 7] =
    ["simulator", "coordinator", "costmodel", "kvcache", "policy", "metrics", "analysis"];

/// The one sanctioned wall-clock reader.
pub const WALL_CLOCK_EXEMPT: &str = "rust/src/bin/bench_sweep.rs";

/// Types whose iteration order is unspecified.
const UNORDERED_TYPES: [&str; 2] = ["HashMap", "HashSet"];

/// Iteration methods that expose unordered traversal.
const ITER_METHODS: [&str; 9] = [
    ".iter()",
    ".iter_mut()",
    ".keys()",
    ".values()",
    ".values_mut()",
    ".drain()",
    ".into_iter()",
    ".into_keys()",
    ".into_values()",
];

/// Routing an iteration through these `util::det` helpers yields a
/// key-sorted sequence, which satisfies the rule by construction.
const SORTED_ROUTES: [&str; 5] =
    ["sorted_pairs(", "sorted_keys(", "sorted_values(", "sorted_members(", "drain_sorted("];

/// Wall-clock / ambient-randomness readers (modeled time only outside
/// the bench bin).
const WALL_TOKENS: [&str; 5] =
    ["Instant::now", "SystemTime::now", "thread_rng", "rand::random", "from_entropy"];

/// Float accumulators whose result depends on summation order.
const FLOAT_REDUCERS: [&str; 7] = [
    ".sum::<f64>",
    ".sum::<f32>",
    ".product::<f64>",
    ".product::<f32>",
    ".fold(0.0",
    ".fold(0f64",
    ".fold(0f32",
];

/// Every fast-path oracle flag that must stay exercised under
/// `rust/tests/` (rule 4): the retained reference implementations of
/// the event core (§15), the dense pricing memo (§17), and the worker
/// pool dispatch (§17).
pub const ORACLE_FLAGS: [&str; 3] =
    ["use_linear_reference", "use_hash_reference", "use_spawn_reference"];

/// Files under the lock-discipline rule (plus any file carrying a
/// `// detlint: lock-protocol` marker).
pub const LOCK_FILES: [&str; 2] = ["rust/src/costmodel/surface.rs", "rust/src/util/pool.rs"];

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// The identifier ending exactly at byte offset `end` (exclusive).
fn ident_ending_at(line: &str, end: usize) -> Option<&str> {
    let head = &line[..end];
    let start = head
        .char_indices()
        .rev()
        .take_while(|&(_, c)| is_ident_char(c))
        .last()
        .map(|(i, _)| i)?;
    let id = &head[start..];
    if id.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        None
    } else {
        Some(id)
    }
}

/// The identifier at the very end of `line` (after trailing trim).
fn ident_at_end(line: &str) -> Option<&str> {
    let t = line.trim_end();
    ident_ending_at(t, t.len())
}

/// The first identifier starting at byte offset `start`.
fn ident_starting_at(line: &str, start: usize) -> Option<&str> {
    let tail = &line[start..];
    let end = tail.find(|c: char| !is_ident_char(c)).unwrap_or(tail.len());
    if end == 0 {
        None
    } else {
        Some(&tail[..end])
    }
}

/// Does `path` (repo-relative, forward slashes) live in a
/// determinism-critical module?
pub fn is_critical(path: &str) -> bool {
    let Some(rel) = path.strip_prefix("rust/src/") else {
        return false;
    };
    let module = rel.split('/').next().unwrap_or(rel);
    let module = module.strip_suffix(".rs").unwrap_or(module);
    CRITICAL_MODULES.contains(&module)
}

/// Collect identifiers bound to `HashMap`/`HashSet` in this file: typed
/// bindings, fields, and fn params (`name: HashMap<..>`) plus
/// constructor bindings (`let [mut] name = HashMap::new()`).  Per-file
/// by design — cross-file field types are out of syntactic reach and
/// covered by review plus the dynamic legs.
pub fn unordered_names(code: &[String]) -> Vec<String> {
    let mut names: Vec<String> = Vec::new();
    for line in code {
        for ty in UNORDERED_TYPES {
            for (pos, _) in line.match_indices(ty) {
                // Word boundary on both sides of the type name.
                if line[..pos].chars().next_back().is_some_and(is_ident_char) {
                    continue;
                }
                let after = &line[pos + ty.len()..];
                if after.chars().next().is_some_and(is_ident_char) {
                    continue;
                }
                let mut found = binding_before_type(line, pos);
                if found.is_none() && constructed_here(after) {
                    found = binding_before_constructor(line, pos);
                }
                if let Some(name) = found {
                    if !name.is_empty() && !names.iter().any(|n| n == name) {
                        names.push(name.to_string());
                    }
                }
            }
        }
    }
    names
}

fn constructed_here(after: &str) -> bool {
    after.starts_with("::new(")
        || after.starts_with("::with_capacity(")
        || after.starts_with("::default(")
        || after.starts_with("::from(")
}

/// For `name: [&]['a ][mut ][path::]HashMap<..>` at `type_pos`, the
/// bound `name`.
fn binding_before_type(line: &str, type_pos: usize) -> Option<&str> {
    let mut b = line[..type_pos].trim_end();
    // Peel reference / lifetime / `mut` / path-segment prefixes back to
    // the `name:` that introduces the binding.
    loop {
        if let Some(s) = b.strip_suffix("::") {
            // Path-qualified type: drop the preceding segment too.
            let s = s.trim_end();
            let cut = ident_at_end(s).map_or(s.len(), |id| s.len() - id.len());
            b = s[..cut].trim_end();
            continue;
        }
        if let Some(s) = b.strip_suffix('&') {
            b = s.trim_end();
            continue;
        }
        if let Some(s) = b.strip_suffix("mut") {
            if !s.chars().next_back().is_some_and(is_ident_char) {
                b = s.trim_end();
                continue;
            }
        }
        if let Some(id) = ident_at_end(b) {
            // `&'a` lifetime prefix: strip the lifetime name and tick.
            if let Some(rest) = b[..b.len() - id.len()].strip_suffix('\'') {
                b = rest.trim_end();
                continue;
            }
        }
        break;
    }
    let b = b.strip_suffix(':')?;
    if b.ends_with(':') {
        return None; // `::` — a path, not a binding
    }
    ident_at_end(b)
}

/// For `... = HashMap::new()` at `type_pos`, the identifier bound on
/// the left-hand side (`let [mut] name` or the final segment of an
/// assignment target).
fn binding_before_constructor(line: &str, type_pos: usize) -> Option<&str> {
    let lhs = line[..type_pos].trim_end().strip_suffix('=')?.trim_end();
    ident_at_end(lhs)
}

/// One unordered-iteration site: 0-based line plus a description of
/// what fired.
pub struct IterSite {
    pub line: usize,
    pub what: String,
}

/// Find unordered-iteration sites in a file given its unordered names.
/// Helper-routed lines (`util::det::sorted_*`) are not sites.
pub fn iter_sites(code: &[String], names: &[String]) -> Vec<IterSite> {
    let mut sites = Vec::new();
    for (i, line) in code.iter().enumerate() {
        if SORTED_ROUTES.iter().any(|h| line.contains(h)) {
            continue;
        }
        for m in ITER_METHODS {
            for (pos, _) in line.match_indices(m) {
                if let Some(r) = receiver_ident(code, i, pos) {
                    if names.iter().any(|n| n == &r) {
                        sites.push(IterSite {
                            line: i,
                            what: format!("`{}` on unordered `{r}`", &m[..m.len() - 1]),
                        });
                    }
                }
            }
        }
        // `for pat in &expr` / `for pat in &mut expr`
        for (pos, _) in line.match_indices(" in &") {
            let mut start = pos + " in &".len();
            if line[start..].starts_with("mut ") {
                start += 4;
            }
            let expr_end = line[start..]
                .find(|c: char| c == ' ' || c == '{')
                .map_or(line.len(), |e| start + e);
            let expr = &line[start..expr_end];
            if expr.ends_with(')') || expr.ends_with(']') {
                continue;
            }
            if let Some(seg) = ident_at_end(expr) {
                if names.iter().any(|n| n == seg) {
                    sites.push(IterSite {
                        line: i,
                        what: format!("`for .. in &{seg}` over an unordered collection"),
                    });
                }
            }
        }
    }
    sites
}

/// The receiver identifier of a method call at `pos` on `code[i]`,
/// looking at the previous code line when the call opens the line
/// (builder-style chains).
fn receiver_ident(code: &[String], i: usize, pos: usize) -> Option<String> {
    let line = &code[i];
    if line[..pos].trim().is_empty() {
        let prev = code[..i].iter().rev().find(|l| !l.trim().is_empty())?;
        return ident_at_end(prev).map(str::to_string);
    }
    let prev_char = line[..pos].chars().next_back()?;
    if !is_ident_char(prev_char) {
        return None; // `).iter()`, `].iter()` — unknown type, skip
    }
    ident_ending_at(line, pos).map(str::to_string)
}

/// Rule 1: unordered-map iteration in determinism-critical modules.
pub fn rule_unordered_iter(sc: &Scanned, suppressed: &mut usize) -> Vec<Violation> {
    if !is_critical(&sc.path) {
        return Vec::new();
    }
    let names = unordered_names(&sc.code);
    let mut out = Vec::new();
    for site in iter_sites(&sc.code, &names) {
        if sc.allowed(site.line, RULE_UNORDERED_ITER) {
            *suppressed += 1;
            continue;
        }
        out.push(Violation {
            path: sc.path.clone(),
            line: site.line + 1,
            rule: RULE_UNORDERED_ITER,
            message: format!(
                "{} in a determinism-critical module — route through \
                 util::det::sorted_* or annotate \
                 `// detlint: allow(unordered-iter, <reason>)`",
                site.what
            ),
        });
    }
    out
}

/// Rule 2: wall-clock / ambient randomness outside the bench bin.
pub fn rule_wall_clock(sc: &Scanned, suppressed: &mut usize) -> Vec<Violation> {
    if sc.path == WALL_CLOCK_EXEMPT {
        return Vec::new();
    }
    let mut out = Vec::new();
    for (i, line) in sc.code.iter().enumerate() {
        for t in WALL_TOKENS {
            for (pos, _) in line.match_indices(t) {
                if line[..pos].chars().next_back().is_some_and(is_ident_char) {
                    continue;
                }
                if line[pos + t.len()..].chars().next().is_some_and(is_ident_char) {
                    continue;
                }
                if sc.allowed(i, RULE_WALL_CLOCK) {
                    *suppressed += 1;
                    continue;
                }
                out.push(Violation {
                    path: sc.path.clone(),
                    line: i + 1,
                    rule: RULE_WALL_CLOCK,
                    message: format!(
                        "`{t}` outside the bench bin — simulations run on modeled \
                         time; annotate `// detlint: allow(wall-clock, <reason>)` \
                         only for genuine harness/runtime timing"
                    ),
                });
            }
        }
    }
    out
}

/// Rule 3: float reductions fed by an unordered iterator (accumulation
/// order is part of the bit-identity contract on report paths).
pub fn rule_float_reduce(sc: &Scanned, suppressed: &mut usize) -> Vec<Violation> {
    if !is_critical(&sc.path) {
        return Vec::new();
    }
    let names = unordered_names(&sc.code);
    let mut out = Vec::new();
    for site in iter_sites(&sc.code, &names) {
        // Gather the rest of the statement (a few lines) so a chained
        // `.values() ... .sum::<f64>()` across lines is still seen.
        let mut hay = String::new();
        for l in sc.code.iter().skip(site.line).take(4) {
            hay.push_str(l);
            if l.trim_end().ends_with(';') {
                break;
            }
        }
        if !FLOAT_REDUCERS.iter().any(|r| hay.contains(r)) {
            continue;
        }
        if sc.allowed(site.line, RULE_FLOAT_REDUCE) {
            *suppressed += 1;
            continue;
        }
        out.push(Violation {
            path: sc.path.clone(),
            line: site.line + 1,
            rule: RULE_FLOAT_REDUCE,
            message: format!(
                "float reduction over {} — accumulation order is part of the \
                 bit-identity contract; sort first (util::det::sorted_*)",
                site.what
            ),
        });
    }
    out
}

/// Rule 4: every fast-path reference flag stays exercised by the test
/// suite, so an optimized path can never silently lose its shadow
/// oracle.
pub fn rule_oracle_coverage(src: &[SourceFile], tests: &[SourceFile]) -> Vec<Violation> {
    let mut out = Vec::new();
    for flag in ORACLE_FLAGS {
        if !src.iter().any(|f| f.text.contains(flag)) {
            out.push(Violation {
                path: "rust/src".to_string(),
                line: 0,
                rule: RULE_ORACLE_COVERAGE,
                message: format!(
                    "oracle flag `{flag}` no longer exists under rust/src — if the \
                     reference path was renamed, update detlint::rules::ORACLE_FLAGS \
                     in the same change"
                ),
            });
            continue;
        }
        if !tests.iter().any(|f| f.text.contains(flag)) {
            out.push(Violation {
                path: "rust/tests".to_string(),
                line: 0,
                rule: RULE_ORACLE_COVERAGE,
                message: format!(
                    "reference-path flag `{flag}` is never exercised by any file \
                     under rust/tests/ — the fast path lost its shadow oracle"
                ),
            });
        }
    }
    out
}

/// Lock acquisitions on a code line: byte offsets where a
/// `.lock()`/`.read()`/`.write()` is immediately consumed by
/// `.unwrap…`/`.expect` — the shape every real site in this tree has.
fn lock_acquisitions(line: &str) -> Vec<usize> {
    let mut out = Vec::new();
    for m in [".lock()", ".read()", ".write()"] {
        for (pos, _) in line.match_indices(m) {
            let rest = &line[pos + m.len()..];
            if rest.starts_with(".unwrap") || rest.starts_with(".expect") {
                out.push(pos);
            }
        }
    }
    out.sort_unstable();
    out
}

/// Rule 5: no second lock acquisition while a bound guard is live, in
/// the files that document a read-peek / compute-outside-locks /
/// write-insert protocol.
pub fn rule_lock_discipline(sc: &Scanned, suppressed: &mut usize) -> Vec<Violation> {
    if !LOCK_FILES.contains(&sc.path.as_str()) && !sc.lock_marker {
        return Vec::new();
    }
    let mut out = Vec::new();
    // Live bound guards: (name, depth of the block that owns them).
    let mut guards: Vec<(String, usize)> = Vec::new();
    let mut depth = 0usize;
    for (i, line) in sc.code.iter().enumerate() {
        for (pos, _) in line.match_indices("drop(") {
            if line[..pos].chars().next_back().is_some_and(is_ident_char) {
                continue;
            }
            if let Some(name) = ident_starting_at(line, pos + "drop(".len()) {
                guards.retain(|(g, _)| g != name);
            }
        }
        let acqs = lock_acquisitions(line);
        for k in 0..acqs.len() {
            if guards.is_empty() && k == 0 {
                continue;
            }
            if sc.allowed(i, RULE_LOCK_DISCIPLINE) {
                *suppressed += 1;
                continue;
            }
            let held = if guards.is_empty() {
                "a lock acquired earlier on this statement".to_string()
            } else {
                let names: Vec<&str> = guards.iter().map(|(g, _)| g.as_str()).collect();
                format!("guard(s) [{}]", names.join(", "))
            };
            out.push(Violation {
                path: sc.path.clone(),
                line: i + 1,
                rule: RULE_LOCK_DISCIPLINE,
                message: format!(
                    "lock acquired while already holding {held} — the documented \
                     protocol is read-peek, compute outside locks, write-insert; \
                     annotate `// detlint: allow(lock-discipline, <reason>)` only \
                     with a pinned lock order"
                ),
            });
        }
        let depth_after = {
            let opens = line.matches('{').count();
            let closes = line.matches('}').count();
            (depth + opens).saturating_sub(closes)
        };
        if let Some(first_acq) = acqs.first() {
            if let Some(lp) = line.find("let ") {
                if lp < *first_acq {
                    let mut p = lp + "let ".len();
                    if line[p..].starts_with("mut ") {
                        p += "mut ".len();
                    }
                    if let Some(name) = ident_starting_at(line, p) {
                        guards.push((name.to_string(), depth_after.max(depth)));
                    }
                }
            }
        }
        depth = depth_after;
        guards.retain(|&(_, d)| d <= depth);
    }
    out
}

/// Annotation hygiene: every `allow(...)` must name a known rule and
/// carry a non-empty reason.  Malformed annotations are violations in
/// their own right (and never suppress anything).
pub fn rule_allow_syntax(sc: &Scanned) -> Vec<Violation> {
    let mut out = Vec::new();
    for (i, a) in &sc.all_allows {
        if !KNOWN_RULES.contains(&a.rule.as_str()) {
            out.push(Violation {
                path: sc.path.clone(),
                line: i + 1,
                rule: RULE_ALLOW_SYNTAX,
                message: format!(
                    "unknown rule `{}` in detlint allow annotation (known: {})",
                    a.rule,
                    KNOWN_RULES.join(", ")
                ),
            });
        } else if a.reason.trim().is_empty() {
            out.push(Violation {
                path: sc.path.clone(),
                line: i + 1,
                rule: RULE_ALLOW_SYNTAX,
                message: format!(
                    "allow({}) without a reason — suppression requires a non-empty \
                     justification and does not apply until one is written",
                    a.rule
                ),
            });
        }
    }
    out
}
