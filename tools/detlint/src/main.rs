//! `detlint` CLI: scan the tree, print violations, exit nonzero on any.
//!
//! Usage: `cargo run -p detlint` from anywhere inside the workspace
//! (walks up to the directory containing `rust/src`), or
//! `detlint --root <repo>`.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => {
                let Some(v) = args.next() else {
                    eprintln!("detlint: --root requires a path");
                    return ExitCode::from(2);
                };
                root = PathBuf::from(v);
            }
            "--help" | "-h" => {
                println!("usage: detlint [--root <repo>]");
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("detlint: unknown argument {other:?}");
                return ExitCode::from(2);
            }
        }
    }
    // Run from anywhere inside the workspace: walk up until `rust/src`
    // exists under the base directory.  Relative roots are resolved
    // first so `pop()` genuinely ascends.
    let mut base = if root.is_relative() {
        std::env::current_dir().map(|d| d.join(&root)).unwrap_or_else(|_| root.clone())
    } else {
        root.clone()
    };
    while !base.join("rust/src").is_dir() {
        if !base.pop() {
            eprintln!("detlint: no rust/src under {} or its parents", root.display());
            return ExitCode::from(2);
        }
    }
    let analysis = match detlint::analyze_tree(&base) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("detlint: read failed: {e}");
            return ExitCode::from(2);
        }
    };
    for v in &analysis.violations {
        if v.line == 0 {
            println!("{}: [{}] {}", v.path, v.rule, v.message);
        } else {
            println!("{}:{}: [{}] {}", v.path, v.line, v.rule, v.message);
        }
    }
    if analysis.violations.is_empty() {
        println!(
            "detlint: OK ({} files, {} suppression(s))",
            analysis.files_scanned, analysis.allows_used
        );
        ExitCode::SUCCESS
    } else {
        eprintln!("detlint: {} violation(s)", analysis.violations.len());
        ExitCode::FAILURE
    }
}
