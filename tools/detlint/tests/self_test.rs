//! detlint self-tests: one firing fixture per rule, allow-annotation
//! semantics (a suppression requires a non-empty reason), and the
//! repo-green gate — the actual tree must analyze clean.

use std::path::Path;

use detlint::rules::{
    RULE_ALLOW_SYNTAX, RULE_FLOAT_REDUCE, RULE_LOCK_DISCIPLINE, RULE_ORACLE_COVERAGE,
    RULE_UNORDERED_ITER, RULE_WALL_CLOCK,
};
use detlint::{analyze, Analysis, SourceFile, Violation};

fn src(path: &str, text: &str) -> SourceFile {
    SourceFile { path: path.to_string(), text: text.to_string() }
}

fn of<'a>(a: &'a Analysis, rule: &str) -> Vec<&'a Violation> {
    a.violations.iter().filter(|v| v.rule == rule).collect()
}

/// Source/tests pair that keeps the oracle-coverage rule quiet, so the
/// per-file tests can assert on their own rule in isolation.
fn oracle_src() -> SourceFile {
    src(
        "rust/src/simulator/flags.rs",
        "pub use_linear_reference: bool,\n\
         pub use_hash_reference: bool,\n\
         pub use_spawn_reference: bool,\n",
    )
}

fn oracle_tests() -> SourceFile {
    src(
        "rust/tests/flags.rs",
        "use_linear_reference; use_hash_reference; use_spawn_reference;\n",
    )
}

#[test]
fn fixture_unordered_iter_fires_in_critical_module() {
    let text = include_str!("fixtures/unordered_iter.rs");
    let a = analyze(&[oracle_src(), src("rust/src/simulator/fx.rs", text)], &[oracle_tests()]);
    let hits = of(&a, RULE_UNORDERED_ITER);
    assert_eq!(hits.len(), 2, "both iteration shapes must fire: {:?}", a.violations);
    assert!(hits.iter().all(|v| v.path == "rust/src/simulator/fx.rs"));
}

#[test]
fn unordered_iter_silent_outside_critical_modules() {
    let text = include_str!("fixtures/unordered_iter.rs");
    let a = analyze(&[oracle_src(), src("rust/src/util/fx.rs", text)], &[oracle_tests()]);
    assert!(of(&a, RULE_UNORDERED_ITER).is_empty(), "{:?}", a.violations);
}

#[test]
fn unordered_iter_silent_when_routed_through_det_helpers() {
    let text = "use std::collections::HashMap;\n\
                pub fn emit(m: &HashMap<usize, u64>) -> Vec<(usize, u64)> {\n\
                    crate::util::det::sorted_pairs(m.iter())\n\
                }\n";
    let a = analyze(&[oracle_src(), src("rust/src/simulator/fx.rs", text)], &[oracle_tests()]);
    assert!(of(&a, RULE_UNORDERED_ITER).is_empty(), "{:?}", a.violations);
}

#[test]
fn fixture_wall_clock_fires() {
    let text = include_str!("fixtures/wall_clock.rs");
    let a = analyze(&[oracle_src(), src("rust/src/util/bench.rs", text)], &[oracle_tests()]);
    let hits = of(&a, RULE_WALL_CLOCK);
    assert_eq!(hits.len(), 1, "{:?}", a.violations);
    assert_eq!(hits[0].line, 5);
}

#[test]
fn wall_clock_exempt_in_bench_sweep() {
    let text = include_str!("fixtures/wall_clock.rs");
    let a = analyze(&[oracle_src(), src("rust/src/bin/bench_sweep.rs", text)], &[oracle_tests()]);
    assert!(of(&a, RULE_WALL_CLOCK).is_empty(), "{:?}", a.violations);
}

#[test]
fn fixture_float_reduce_fires() {
    let text = include_str!("fixtures/float_reduce.rs");
    let a = analyze(&[oracle_src(), src("rust/src/metrics/fx.rs", text)], &[oracle_tests()]);
    assert_eq!(of(&a, RULE_FLOAT_REDUCE).len(), 1, "{:?}", a.violations);
}

#[test]
fn fixture_lock_discipline_fires_via_marker() {
    let text = include_str!("fixtures/lock_discipline.rs");
    let a = analyze(&[oracle_src(), src("rust/src/runtime/fx.rs", text)], &[oracle_tests()]);
    let hits = of(&a, RULE_LOCK_DISCIPLINE);
    assert_eq!(hits.len(), 1, "{:?}", a.violations);
    assert!(hits[0].message.contains("ga"), "held guard named: {}", hits[0].message);
}

#[test]
fn lock_discipline_applies_to_listed_files_without_marker() {
    let text = "pub fn f(a: &std::sync::Mutex<u8>, b: &std::sync::Mutex<u8>) {\n\
                    let ga = a.lock().unwrap();\n\
                    let gb = b.lock().unwrap();\n\
                    let _ = (*ga, *gb);\n\
                }\n";
    let a = analyze(&[oracle_src(), src("rust/src/util/pool.rs", text)], &[oracle_tests()]);
    assert_eq!(of(&a, RULE_LOCK_DISCIPLINE).len(), 1, "{:?}", a.violations);
}

#[test]
fn fixture_allow_with_reason_suppresses() {
    let text = include_str!("fixtures/allow_ok.rs");
    let a = analyze(&[oracle_src(), src("rust/src/simulator/fx.rs", text)], &[oracle_tests()]);
    assert!(of(&a, RULE_UNORDERED_ITER).is_empty(), "{:?}", a.violations);
    assert!(of(&a, RULE_ALLOW_SYNTAX).is_empty(), "{:?}", a.violations);
    assert_eq!(a.allows_used, 1);
}

#[test]
fn fixture_allow_without_reason_does_not_suppress() {
    let text = include_str!("fixtures/allow_empty_reason.rs");
    let a = analyze(&[oracle_src(), src("rust/src/simulator/fx.rs", text)], &[oracle_tests()]);
    assert_eq!(of(&a, RULE_UNORDERED_ITER).len(), 1, "{:?}", a.violations);
    assert_eq!(of(&a, RULE_ALLOW_SYNTAX).len(), 1, "{:?}", a.violations);
    assert_eq!(a.allows_used, 0);
}

#[test]
fn unknown_rule_name_is_an_allow_syntax_violation() {
    let text = "// detlint: allow(no-such-rule, because reasons)\npub fn f() {}\n";
    let a = analyze(&[oracle_src(), src("rust/src/policy/fx.rs", text)], &[oracle_tests()]);
    let hits = of(&a, RULE_ALLOW_SYNTAX);
    assert_eq!(hits.len(), 1, "{:?}", a.violations);
    assert!(hits[0].message.contains("no-such-rule"));
}

#[test]
fn oracle_coverage_fires_when_a_flag_loses_its_test() {
    let tests = src("rust/tests/flags.rs", "use_linear_reference; use_hash_reference;\n");
    let a = analyze(&[oracle_src()], &[tests]);
    let hits = of(&a, RULE_ORACLE_COVERAGE);
    assert_eq!(hits.len(), 1, "{:?}", a.violations);
    assert!(hits[0].message.contains("use_spawn_reference"));
    assert_eq!(hits[0].line, 0);
}

#[test]
fn oracle_coverage_fires_when_a_flag_leaves_the_source() {
    let source = src("rust/src/simulator/flags.rs", "pub use_linear_reference: bool,\n");
    let a = analyze(&[source], &[oracle_tests()]);
    let hits = of(&a, RULE_ORACLE_COVERAGE);
    assert_eq!(hits.len(), 2, "{:?}", a.violations);
    assert!(hits.iter().all(|v| v.path == "rust/src"));
}

#[test]
fn comments_and_strings_never_fire() {
    let text = "// HashMap iter() in a comment\n\
                pub fn f() -> &'static str {\n\
                    \"Instant::now() and map.keys() in a string\"\n\
                }\n";
    let a = analyze(&[oracle_src(), src("rust/src/simulator/fx.rs", text)], &[oracle_tests()]);
    assert!(of(&a, RULE_UNORDERED_ITER).is_empty(), "{:?}", a.violations);
    assert!(of(&a, RULE_WALL_CLOCK).is_empty(), "{:?}", a.violations);
}

/// The acceptance gate: the tree this crate ships in analyzes clean.
/// Every pre-existing violation was either fixed (routed through
/// `util::det`) or carries a reason-bearing allow annotation.
#[test]
fn repository_tree_is_green() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
    let a = detlint::analyze_tree(&root).expect("tree readable");
    assert!(a.files_scanned > 20, "expected the real tree, scanned {}", a.files_scanned);
    let rendered: Vec<String> = a
        .violations
        .iter()
        .map(|v| format!("{}:{}: [{}] {}", v.path, v.line, v.rule, v.message))
        .collect();
    assert!(a.violations.is_empty(), "tree has violations:\n{}", rendered.join("\n"));
    assert!(a.allows_used > 0, "the annotated sites should register as suppressions");
}
