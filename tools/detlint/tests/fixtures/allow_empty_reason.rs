// Fixture: an allow annotation with no reason must NOT suppress, and
// is itself an allow-syntax violation.
use std::collections::HashMap;

pub fn rebuild(m: &HashMap<usize, u64>) -> u64 {
    let mut acc = 0;
    // detlint: allow(unordered-iter)
    for (_k, v) in m.iter() {
        acc += *v;
    }
    acc
}
