// Fixture: rule 2 (wall-clock) must fire on an Instant::now() read.
use std::time::Instant;

pub fn stamp() -> Instant {
    Instant::now()
}
