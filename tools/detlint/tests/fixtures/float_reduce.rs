// Fixture: rule 3 (float-reduce) must fire on an order-dependent sum
// fed by an unordered iterator.
use std::collections::HashMap;

pub fn total(weights: &HashMap<u64, f64>) -> f64 {
    weights.values().sum::<f64>()
}
