// Fixture: rule 1 (unordered-iter) must fire on both iteration shapes.
use std::collections::HashMap;

pub fn emit(m: &HashMap<usize, u64>) -> Vec<(usize, u64)> {
    let mut out = Vec::new();
    for (k, v) in m.iter() {
        out.push((*k, *v));
    }
    out
}

pub fn emit_ref(m: &HashMap<usize, u64>) -> u64 {
    let mut acc = 0;
    for (_k, v) in &m {
        acc += *v;
    }
    acc
}
