// Fixture: rule 5 (lock-discipline) must fire on a nested acquisition.
// detlint: lock-protocol
use std::sync::Mutex;

pub struct Pair {
    a: Mutex<u64>,
    b: Mutex<u64>,
}

pub fn both(p: &Pair) -> u64 {
    let ga = p.a.lock().unwrap();
    let gb = p.b.lock().unwrap();
    *ga + *gb
}
