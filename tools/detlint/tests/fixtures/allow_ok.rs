// Fixture: a reason-carrying allow annotation suppresses rule 1.
use std::collections::HashMap;

pub fn rebuild(m: &HashMap<usize, u64>) -> u64 {
    let mut acc = 0;
    // detlint: allow(unordered-iter, keyed rebuild - order cannot affect the result)
    for (_k, v) in m.iter() {
        acc += *v;
    }
    acc
}
