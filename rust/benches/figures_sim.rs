//! Simulation-cell benches + ablations.
//!
//! Each paper figure cell (model x prompt x dataset x batch x kernel)
//! is one simulated serving run; these benches time representative
//! cells and run the policy ablation the paper's "Fall-back to Absorb"
//! section motivates: typhoon with vs without the B_theta fall-back.

use std::time::Duration;

use typhoon_mla::config::hardware::ascend_npu;
use typhoon_mla::config::model::deepseek_v3;
use typhoon_mla::config::KernelKind;
use typhoon_mla::simulator::{run_experiment, SimParams};
use typhoon_mla::util::bench::{Bench, BenchConfig};
use typhoon_mla::workload::datasets::mmlu;
use typhoon_mla::workload::prompts::PROMPT_A;

fn main() -> anyhow::Result<()> {
    let mut bench = Bench::with_config(BenchConfig {
        warmup: Duration::from_millis(100),
        min_iters: 5,
        min_time: Duration::from_millis(800),
        max_iters: 200,
    });

    for batch in [64usize, 256, 1024] {
        for kernel in [KernelKind::Typhoon, KernelKind::Absorb, KernelKind::Naive] {
            let mut p = SimParams::new(deepseek_v3(), ascend_npu(), kernel, batch);
            p.max_requests = Some(batch * 2);
            let ds = mmlu();
            bench.bench(
                &format!("simcell/{}_b{batch}", kernel.as_str()),
                || {
                    run_experiment(&p, &ds, &PROMPT_A).unwrap();
                },
            );
        }
    }

    // --- ablation: fall-back policy on/off at small batch ------------------
    // Without the fall-back, typhoon at B << B_theta pays the naive
    // stage's bandwidth cost without reuse; the policy recovers
    // absorb-level throughput (the paper's design argument).
    println!("\n# ablation: B_theta fall-back at small batch (modeled throughput)");
    let ds = mmlu();
    for batch in [8usize, 16, 32, 64, 128] {
        let mut with = SimParams::new(deepseek_v3(), ascend_npu(), KernelKind::Typhoon, batch);
        with.max_requests = Some(batch * 3);
        let r_with = run_experiment(&with, &ds, &PROMPT_A)?;
        // "No fallback": force typhoon via a naive policy trick — run the
        // same workload with kernel=Typhoon but threshold 0 is the
        // default policy; emulate no-fallback by comparing against the
        // pure kernels instead.
        let mut absorb = with.clone();
        absorb.kernel = KernelKind::Absorb;
        let r_absorb = run_experiment(&absorb, &ds, &PROMPT_A)?;
        println!(
            "b={batch:>4}: typhoon(+fallback) {:>9.0} tok/s  absorb {:>9.0} tok/s  ratio {:.3}",
            r_with.throughput,
            r_absorb.throughput,
            r_with.throughput / r_absorb.throughput
        );
    }

    bench.write_json("target/bench/figures_sim.json")?;
    Ok(())
}
