//! Kernel benches: real CPU-PJRT execution of the AOT attention
//! artifacts (sim config) across batch sizes and variants.
//!
//! This is the real-execution counterpart of Figs. 2/3: on this
//! interpret-mode CPU path absolute times mean little, but the *shape*
//! — typhoon tracking the cheaper of naive/absorb as batch grows — is
//! measured on genuinely executing kernels.
//!
//! Requires `make artifacts`.  Run: `cargo bench --bench kernels`.

use std::time::Duration;

use typhoon_mla::config::model::sim;
use typhoon_mla::runtime::client::random_f32;
use typhoon_mla::runtime::{default_artifacts_dir, literal_i32, Manifest, PjrtRuntime};
use typhoon_mla::util::bench::{Bench, BenchConfig};

fn main() -> anyhow::Result<()> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping kernel benches: run `make artifacts` first");
        return Ok(());
    }
    let manifest = Manifest::load(&dir)?;
    let mut rt = PjrtRuntime::new(&dir)?;
    let cfg = sim();
    let (h, dn, dr, dv, dl) =
        (cfg.n_heads, cfg.d_nope, cfg.d_rope, cfg.d_v, cfg.kv_lora_rank);
    let dqk = dn + dr;

    let mut bench = Bench::with_config(BenchConfig {
        warmup: Duration::from_millis(300),
        min_iters: 8,
        min_time: Duration::from_secs(1),
        max_iters: 2000,
    });

    // Naive over a batched uncompressed cache is extremely slow under the
    // CPU interpreter at large B; sample it with few iterations.
    let mut slow = Bench::with_config(BenchConfig {
        warmup: Duration::ZERO,
        min_iters: 3,
        min_time: Duration::from_millis(1),
        max_iters: 3,
    });

    println!("# attention kernels, sim config (H={h}, Dl={dl}), CPU PJRT");
    let mut batches: Vec<usize> = manifest
        .select("attention", Some("typhoon"), Some("sim"))
        .iter()
        .filter_map(|a| a.dims.get("b").copied())
        .collect();
    batches.sort();

    for &b in &batches {
        let (ls, ln) = (1024usize, 256usize);
        // Inputs (deterministic).
        let q_nope = random_f32(&[b, h, dn], 1, 0.5)?;
        let q_rope = random_f32(&[b, h, dr], 2, 0.5)?;
        let ckv_sh = random_f32(&[ls, dl], 3, 0.5)?;
        let krope_sh = random_f32(&[ls, dr], 4, 0.5)?;
        let k_sh = random_f32(&[ls, h, dqk], 5, 0.5)?;
        let v_sh = random_f32(&[ls, h, dv], 6, 0.5)?;
        let ckv = random_f32(&[b, ln, dl], 7, 0.5)?;
        let krope = random_f32(&[b, ln, dr], 8, 0.5)?;
        let k_n = random_f32(&[b, ln, h, dqk], 9, 0.5)?;
        let v_n = random_f32(&[b, ln, h, dv], 10, 0.5)?;
        let w1 = random_f32(&[h, dn, dl], 11, 0.1)?;
        let w2 = random_f32(&[h, dv, dl], 12, 0.1)?;
        let sl = literal_i32(&[1], &[ls as i32])?;
        let lens = literal_i32(&[b], &vec![ln as i32; b])?;

        let name = |v: &str| format!("attn_{v}_sim_b{b}_s{ls}_n{ln}");
        for v in ["typhoon", "absorb", "naive"] {
            rt.load(&name(v))?;
        }
        bench.bench(&format!("attn/typhoon/b{b}"), || {
            rt.execute_ref(
                &name("typhoon"),
                &[&q_nope, &q_rope, &k_sh, &v_sh, &sl, &ckv, &krope, &lens, &w1, &w2],
            )
            .unwrap();
        });
        bench.bench(&format!("attn/absorb/b{b}"), || {
            rt.execute_ref(
                &name("absorb"),
                &[&q_nope, &q_rope, &ckv_sh, &krope_sh, &sl, &ckv, &krope, &lens, &w1, &w2],
            )
            .unwrap();
        });
        let naive_bench = if b >= 64 { &mut slow } else { &mut bench };
        naive_bench.bench(&format!("attn/naive/b{b}"), || {
            rt.execute_ref(
                &name("naive"),
                &[&q_nope, &q_rope, &k_sh, &v_sh, &sl, &k_n, &v_n, &lens],
            )
            .unwrap();
        });
    }

    // Expansion kernel (prefill-time typhoon cache expansion).
    if let Some(a) = manifest.select("expand", None, Some("sim")).first() {
        let n = a.dim("n")?;
        let ckv = random_f32(&[n, dl], 21, 0.5)?;
        let krope = random_f32(&[n, dr], 22, 0.5)?;
        let w1 = random_f32(&[h, dn, dl], 23, 0.1)?;
        let w2 = random_f32(&[h, dv, dl], 24, 0.1)?;
        let name = a.name.clone();
        rt.load(&name)?;
        bench.bench(&format!("expand/n{n}"), || {
            rt.execute_ref(&name, &[&ckv, &krope, &w1, &w2]).unwrap();
        });
    }

    bench.write_json("target/bench/kernels.json")?;
    summarize_crossover(&bench, &slow);
    Ok(())
}

/// Print the per-batch typhoon-vs-baselines picture (the Fig. 2 analog
/// on real CPU execution).
fn summarize_crossover(bench: &Bench, slow: &Bench) {
    println!("\n# typhoon vs best baseline (real CPU execution)");
    let results: Vec<_> =
        bench.results().iter().chain(slow.results()).cloned().collect();
    let get = |name: &str| results.iter().find(|r| r.name == name).map(|r| r.median_s);
    let mut batches: Vec<usize> = results
        .iter()
        .filter_map(|r| {
            r.name
                .strip_prefix("attn/typhoon/b")
                .and_then(|s| s.parse().ok())
        })
        .collect();
    batches.sort();
    for b in batches {
        if let (Some(t), Some(a), Some(n)) = (
            get(&format!("attn/typhoon/b{b}")),
            get(&format!("attn/absorb/b{b}")),
            get(&format!("attn/naive/b{b}")),
        ) {
            println!(
                "b={b:>4}: typhoon {:.2}ms absorb {:.2}ms naive {:.2}ms -> speedup vs best {:.2}x",
                t * 1e3,
                a * 1e3,
                n * 1e3,
                a.min(n) / t
            );
        }
    }
}
