//! L3 hot-path benches: block allocator, radix matching, scheduler
//! iteration overhead (NullEngine isolates pure coordination cost).
//!
//! Targets (DESIGN.md §8): allocator O(1) per op, radix match O(len),
//! scheduler overhead per decode step ≪ any real kernel time.

use std::time::Duration;

use typhoon_mla::config::model::sim;
use typhoon_mla::config::{KernelKind, ServingConfig};
use typhoon_mla::coordinator::engine::NullEngine;
use typhoon_mla::coordinator::{Coordinator, KernelPolicy};
use typhoon_mla::kvcache::{BlockAllocator, KvCacheManager, RadixTree};
use typhoon_mla::util::bench::{Bench, BenchConfig};
use typhoon_mla::util::rng::Rng;
use typhoon_mla::workload::Request;

fn main() -> anyhow::Result<()> {
    let mut bench = Bench::with_config(BenchConfig {
        warmup: Duration::from_millis(200),
        min_iters: 50,
        min_time: Duration::from_secs(1),
        max_iters: 1_000_000,
    });

    // --- block allocator -------------------------------------------------
    {
        let mut alloc = BlockAllocator::new(65536, 128);
        bench.bench("alloc/allocate_release_pair", || {
            let b = alloc.allocate().unwrap();
            alloc.release(b);
        });
        let mut held = Vec::new();
        bench.bench("alloc/allocate_n_64", || {
            held = alloc.allocate_n(64).unwrap();
            for &b in &held {
                alloc.release(b);
            }
        });
    }

    // --- radix tree --------------------------------------------------------
    {
        let mut tree = RadixTree::new();
        let mut rng = Rng::new(7);
        let mut corpus = Vec::new();
        // 26k-token system prompt + 512 question branches (prompt-A scale).
        // Page-granular edges: one page id per 128-token block.
        let prompt: Vec<u32> = (0..26472).map(|_| rng.gen_range(0, 50000) as u32).collect();
        let pages: Vec<u32> = (0..prompt.len().div_ceil(128)).map(|j| j as u32).collect();
        tree.insert_chunked(&prompt, &pages, 128);
        for q in 0..512u32 {
            let mut s = prompt.clone();
            for _ in 0..rng.gen_range_usize(8, 128) {
                s.push(rng.gen_range(0, 50000) as u32);
            }
            let b: Vec<u32> =
                (0..s.len().div_ceil(128)).map(|j| j as u32 + q * 1000).collect();
            tree.insert_chunked(&s, &b, 128);
            corpus.push(s);
        }
        let probe = corpus[100].clone();
        bench.bench("radix/match_26k_prefix", || {
            let m = tree.match_prefix(&probe);
            assert_eq!(m.matched, probe.len());
        });
    }

    // --- cache manager ------------------------------------------------------
    {
        let mut kv = KvCacheManager::new(sim(), 65536, 128);
        let prefix: Vec<u32> = (0..4096u32).collect();
        let pid = kv.register_shared_prefix(&prefix).unwrap();
        let mut next = 0u64;
        bench.bench("kvcache/seq_lifecycle_128tok", || {
            kv.add_sequence(next, pid, 64).unwrap();
            for _ in 0..64 {
                kv.append_token(next).unwrap();
            }
            kv.remove_sequence(next).unwrap();
            next += 1;
        });
    }

    // --- full scheduler step (pure coordination overhead) ------------------
    for batch in [64usize, 512] {
        let cfg = ServingConfig {
            block_size: 128,
            max_batch: batch,
            max_seq_len: 2048,
            total_blocks: batch * 16 + 64,
            ..Default::default()
        };
        let policy = KernelPolicy::with_threshold(KernelKind::Typhoon, 61);
        let kv = KvCacheManager::new(sim(), cfg.total_blocks, cfg.block_size);
        let mut c = Coordinator::new(cfg, policy, kv, NullEngine::default())?;
        c.set_shared_prefix(&(0..4096u32).collect::<Vec<_>>())?;
        // Endless queue: keep the batch saturated so every measured
        // step is a full decode iteration, not a drained no-op.
        let mut i = 0u64;
        bench.bench(&format!("scheduler/step_b{batch}"), || {
            while c.queued() < 2 {
                c.submit(&Request { id: i, prompt_tokens: 64, max_new_tokens: 1_000_000 })
                    .unwrap();
                i += 1;
            }
            let worked = c.step().unwrap();
            assert!(worked);
        });
    }

    bench.write_json("target/bench/coordinator.json")?;
    Ok(())
}
