//! Multi-tenant integration regressions:
//! * the single-tenant configuration must reduce to the classic
//!   single-shared-prefix path **bit-for-bit** (so every pre-tenancy
//!   figure/table artifact stays byte-identical);
//! * the `tenants` sweep must be byte-identical serial vs parallel
//!   under `SweepExecutor`;
//! * grouped Typhoon must at least match the global-absorb baseline on
//!   a skewed multi-tenant workload.

use typhoon_mla::analysis::figures::format_tenants;
use typhoon_mla::config::hardware::ascend_npu;
use typhoon_mla::config::model::deepseek_v3;
use typhoon_mla::config::{KernelKind, ServingConfig};
use typhoon_mla::coordinator::{Coordinator, KernelPolicy};
use typhoon_mla::kvcache::KvCacheManager;
use typhoon_mla::simulator::sweep::{run_tenant_sweep, tenant_cells, SweepExecutor};
use typhoon_mla::simulator::{run_tenant_experiment, SimEngine, TenantSimParams};
use typhoon_mla::workload::tenants::tenant_set;
use typhoon_mla::workload::MultiTenantGenerator;

fn sim_coordinator(kernel: KernelKind, batch: usize) -> Coordinator<SimEngine> {
    let block_size = 128;
    let max_seq_len = 2048;
    let total_blocks = batch * (max_seq_len / block_size) + 512;
    let cfg = ServingConfig {
        block_size,
        max_batch: batch,
        max_seq_len,
        total_blocks,
        kernel,
        ..Default::default()
    };
    let policy = KernelPolicy::with_threshold(kernel, 61);
    let kv = KvCacheManager::new(deepseek_v3(), total_blocks, block_size);
    let mut engine = SimEngine::new(deepseek_v3(), ascend_npu());
    engine.include_prefill = false;
    Coordinator::new(cfg, policy, kv, engine).unwrap()
}

/// The single-tenant regression: one prefix group registered via the
/// tenancy API serves bitwise-identically to the classic
/// `set_shared_prefix` + `submit` path on the same request stream.
#[test]
fn single_tenant_reduces_to_classic_path() {
    let tenants = tenant_set(1, 0.0);
    let prompt = tenants[0].prompt_token_ids(50_000);
    let mut stream = MultiTenantGenerator::new(&tenants, 128, 7);

    let mut classic = sim_coordinator(KernelKind::Typhoon, 64);
    classic.set_shared_prefix(&prompt).unwrap();
    let mut grouped = sim_coordinator(KernelKind::Typhoon, 64);
    let pid = grouped.register_prefix_group(&prompt).unwrap();

    while let Some(tr) = stream.next_request() {
        assert_eq!(tr.tenant, 0);
        classic.submit(&tr.request).unwrap();
        grouped.submit_to(&tr.request, pid).unwrap();
    }
    classic.run_to_completion().unwrap();
    grouped.run_to_completion().unwrap();

    let (cm, gm) = (&classic.metrics, &grouped.metrics);
    assert_eq!(cm.tokens_generated, gm.tokens_generated);
    assert_eq!(cm.decode_iterations, gm.decode_iterations);
    assert_eq!(cm.decode_seconds.to_bits(), gm.decode_seconds.to_bits());
    assert_eq!(cm.typhoon_iters, gm.typhoon_iters);
    assert_eq!(cm.absorb_iters, gm.absorb_iters);
    assert_eq!(gm.mixed_iters, 0, "one group can never mix kernels");
}

/// The `tenants` sweep under `SweepExecutor`: serial and parallel runs
/// must produce byte-identical artifacts (text and CSV).
#[test]
fn tenants_artifact_serial_parallel_identical() {
    let hw = ascend_npu();
    let cells = tenant_cells(&deepseek_v3(), &[1, 2, 4], &[0.0, 2.0], 64, 128);
    let serial = run_tenant_sweep(&hw, &cells, &SweepExecutor::serial()).unwrap();
    let par = run_tenant_sweep(&hw, &cells, &SweepExecutor::with_threads(4)).unwrap();
    let a = format_tenants(&serial);
    let b = format_tenants(&par);
    assert_eq!(a.text, b.text, "text artifact must not drift");
    assert_eq!(a.csv, b.csv, "csv artifact must not drift");
    assert_eq!(a.csv.lines().count(), 7, "header + 6 cells");
}

/// Acceptance: on a skewed multi-tenant workload at a healthy batch,
/// per-group Typhoon models at least the global-absorb throughput (the
/// hot group clears B_theta; cold groups fall back and cost the same
/// as the baseline).
#[test]
fn grouped_typhoon_at_least_matches_global_absorb() {
    let mut p = TenantSimParams::new(
        deepseek_v3(),
        ascend_npu(),
        KernelKind::Typhoon,
        256,
        4,
        2.0,
    );
    p.total_requests = 512;
    let t = run_tenant_experiment(&p).unwrap();
    p.kernel = KernelKind::Absorb;
    let a = run_tenant_experiment(&p).unwrap();
    assert_eq!(t.tokens, a.tokens, "same workload, same tokens");
    assert!(
        t.throughput >= a.throughput,
        "grouped typhoon {} < global absorb {}",
        t.throughput,
        a.throughput
    );
    assert!(t.mixed_iters > 0, "skewed workload must split kernels per group");
}
