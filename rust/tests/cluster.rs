//! Cluster-scale serving regressions:
//! * **reduction** — a 1-replica cluster with round-robin routing and
//!   `ParallelismConfig::single()` is bit-identical to the pre-cluster
//!   serving path on the same request stream (same pattern as
//!   `single_tenant_reduces_to_classic_path`);
//! * **router conservation** — across random policy/seed/replica-count
//!   draws, every generated request completes exactly once across the
//!   fleet, token budgets conserve, no replica leaks KV pages, and
//!   every replica's clock is monotone;
//! * **prefix-affinity invariant** — a prefix group never occupies two
//!   replicas unless a spill was recorded;
//! * **prefix-migration invariant** — with the cost-driven
//!   migrate-vs-spill rule enabled, a migrated group's pages end on
//!   exactly one replica (unless a post-migration spill was recorded),
//!   its destination adopts without re-prefilling, and retired copies
//!   release their pages at drain;
//! * **fault-schedule conservation** — under arbitrary seeded
//!   crash/stall/degradation/loss plans, every request still completes
//!   exactly once fleet-wide, the fleet redoes exactly the tokens the
//!   crash threw away, no replica leaks KV pages, and a crashed
//!   replica ends with zero live pages.  The scheduled CI long-fuzz
//!   job scales the iteration count via `TYPHOON_FUZZ_ITERS`.

use typhoon_mla::config::hardware::ascend_npu;
use typhoon_mla::config::model::deepseek_v3;
use typhoon_mla::config::{KernelKind, ServingConfig};
use typhoon_mla::coordinator::{Coordinator, KernelPolicy};
use typhoon_mla::costmodel::{batch_threshold, ParallelismConfig};
use typhoon_mla::kvcache::KvCacheManager;
use typhoon_mla::simulator::{
    run_tenant_experiment, ClusterParams, ClusterReport, ClusterSim, ReplicaLifecycle,
    RouterPolicy, SimEngine, TenantSimParams,
};
use typhoon_mla::util::rng::Rng;
use typhoon_mla::workload::tenants::{tenant_set, timed_arrivals};

fn cluster_params(replicas: usize, router: RouterPolicy) -> ClusterParams {
    ClusterParams::new(deepseek_v3(), ascend_npu(), replicas, router, 64, 1, 0.0)
}

/// Iteration budget for a fuzz loop: `base` in tier-1, `base x
/// TYPHOON_FUZZ_ITERS` in the scheduled CI long-fuzz job (unset or
/// unparsable falls back to the tier-1 budget).
fn fuzz_iters(base: u64) -> u64 {
    std::env::var("TYPHOON_FUZZ_ITERS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .map_or(base, |m| base * m.max(1))
}

/// The reduction: with one replica, round-robin routing and no TP/SP
/// sharding, the cluster machinery must serve the stream **bit-for-bit**
/// like the single-device serving path — both the tenancy experiment
/// entry point and a hand-built classic coordinator fed the same
/// requests.
#[test]
fn one_replica_round_robin_reduces_to_serving_sim() {
    let batch = 64;
    let total_requests = 128;
    let seed = 7;

    let mut p = cluster_params(1, RouterPolicy::RoundRobin);
    p.batch = batch;
    p.total_requests = total_requests;
    p.seed = seed;
    p.parallelism = ParallelismConfig::single();
    let mut sim = ClusterSim::new(&p).unwrap();
    sim.run().unwrap();
    let cluster = sim.report();
    assert_eq!(cluster.replicas.len(), 1);
    assert_eq!(cluster.spills, 0);

    // Today's serving path #1: the tenancy experiment on the same
    // (tenants, seed, budget) draw.
    let mut tp = TenantSimParams::new(
        deepseek_v3(),
        ascend_npu(),
        KernelKind::Typhoon,
        batch,
        1,
        0.0,
    );
    tp.total_requests = total_requests;
    tp.seed = seed;
    let tenancy = run_tenant_experiment(&tp).unwrap();
    assert_eq!(cluster.tokens, tenancy.tokens);
    assert_eq!(cluster.replicas[0].iterations, tenancy.iterations);
    assert_eq!(
        cluster.decode_seconds.to_bits(),
        tenancy.decode_seconds.to_bits(),
        "1-replica cluster must be bit-identical to the tenancy path"
    );
    assert_eq!(cluster.replicas[0].typhoon_iters, tenancy.typhoon_iters);
    assert_eq!(cluster.replicas[0].absorb_iters, tenancy.absorb_iters);
    assert_eq!(cluster.replicas[0].mixed_iters, 0);

    // Today's serving path #2: a hand-built classic coordinator (the
    // pre-cluster `set_shared_prefix` + `submit` loop) on the same
    // stream, sized exactly like a cluster replica.
    let tenants = tenant_set(1, 0.0);
    let block_size = 128;
    let max_seq_len = 2048;
    let prefix_blocks: usize =
        tenants.iter().map(|t| t.prompt_tokens.div_ceil(block_size)).sum();
    let total_blocks = batch * (max_seq_len / block_size) + prefix_blocks + 64;
    let cfg = ServingConfig {
        block_size,
        max_batch: batch,
        max_seq_len,
        total_blocks,
        kernel: KernelKind::Typhoon,
        ..Default::default()
    };
    let b_theta = batch_threshold(&deepseek_v3(), &ascend_npu(), 1);
    let policy = KernelPolicy::with_threshold(KernelKind::Typhoon, b_theta);
    let kv = KvCacheManager::new(deepseek_v3(), total_blocks, block_size);
    let mut engine = SimEngine::new(deepseek_v3(), ascend_npu());
    engine.include_prefill = false;
    let mut classic = Coordinator::new(cfg, policy, kv, engine).unwrap();
    classic.set_shared_prefix(&tenants[0].prompt_token_ids(50_000)).unwrap();
    for a in timed_arrivals(&tenants, total_requests, None, seed).unwrap() {
        assert_eq!(a.at, 0.0, "batch protocol arrives at t = 0");
        classic.submit(&a.request).unwrap();
    }
    classic.run_to_completion().unwrap();
    let cm = &classic.metrics;
    assert_eq!(cluster.tokens, cm.tokens_generated);
    assert_eq!(cluster.requests_completed, cm.requests_completed);
    assert_eq!(cluster.replicas[0].iterations, cm.decode_iterations);
    assert_eq!(
        cluster.decode_seconds.to_bits(),
        cm.decode_seconds.to_bits(),
        "1-replica cluster must be bit-identical to the classic path"
    );
    assert_eq!(cluster.makespan.to_bits(), classic.now().to_bits());
}

/// Router conservation across random policy/seed/replica-count draws:
/// every request completes exactly once somewhere, token budgets
/// conserve exactly, KV pages return to each replica's prefix
/// baseline, and per-replica clocks never move backward.
#[test]
fn router_conservation_fuzz() {
    for seed in 0..10u64 {
        let mut rng = Rng::new(4000 + seed);
        let replicas = rng.gen_range_usize(1, 4);
        let policy = *rng.choose(&RouterPolicy::all());
        let tenants = rng.gen_range_usize(1, 4);
        let skew = [0.0, 1.0, 2.0][rng.gen_range_usize(0, 3)];
        let batch = rng.gen_range_usize(4, 13);
        let total_requests = rng.gen_range_usize(8, 33);
        let mut p =
            ClusterParams::new(deepseek_v3(), ascend_npu(), replicas, policy, batch, tenants, skew);
        p.total_requests = total_requests;
        p.seed = seed * 31 + 5;
        if rng.next_f64() < 0.5 {
            p.arrival_rate = Some(0.5 + rng.next_f64() * 50.0);
        }
        let mut sim = ClusterSim::new(&p).unwrap();

        // Expected totals from the arrival stream (cluster pools are
        // sized so no request is ever force-finished short).
        let max_seq_len = 2048usize;
        let n_arrivals = sim.arrivals().len();
        let expected_tokens: usize = sim
            .arrivals()
            .iter()
            .map(|a| {
                let prompt = a.request.prompt_tokens.min(max_seq_len - 1);
                a.request.max_new_tokens.min(max_seq_len - prompt).max(1)
            })
            .sum();

        let mut prev = sim.replica_clocks();
        let mut guard = 0u64;
        while sim.step_event().unwrap() {
            let now = sim.replica_clocks();
            for (r, (a, b)) in prev.iter().zip(&now).enumerate() {
                assert!(b >= a, "seed {seed}: replica {r} clock went backward");
            }
            prev = now;
            guard += 1;
            assert!(guard < 2_000_000, "seed {seed}: no progress");
        }

        let report = sim.report();
        assert_eq!(
            report.requests_completed as usize, n_arrivals,
            "seed {seed}: every request completes exactly once across the fleet"
        );
        let routed: u64 = report.replicas.iter().map(|r| r.routed).sum();
        assert_eq!(routed as usize, n_arrivals, "seed {seed}: no request routed twice");
        assert_eq!(
            report.tokens as usize, expected_tokens,
            "seed {seed}: token conservation"
        );
        assert!(
            report.ttft_p50.is_finite(),
            "seed {seed}: completed requests must report TTFT"
        );
        // No cross-replica page leaks: after drain, each replica holds
        // exactly its hosted prefixes' pages and nothing else.
        for i in 0..sim.replica_count() {
            let coord = sim.coordinator(i);
            let hosted_pages: usize = coord
                .prefix_groups()
                .iter()
                .map(|&(id, _)| coord.kv.prefix(id).unwrap().latent_blocks.len())
                .sum();
            assert_eq!(
                coord.kv.used_blocks(),
                hosted_pages,
                "seed {seed}: replica {i} leaked KV pages"
            );
            assert_eq!(coord.running(), 0, "seed {seed}: replica {i} drained");
            assert_eq!(coord.queued(), 0, "seed {seed}: replica {i} drained");
        }
    }
}

/// The prefix-affinity invariant: a prefix group's pages exist on at
/// most one replica unless the router recorded a spill for that group
/// — across random seeds, fleet sizes and arrival patterns.
#[test]
fn prefix_affinity_invariant_fuzz() {
    for seed in 0..8u64 {
        let mut rng = Rng::new(6000 + seed);
        let replicas = rng.gen_range_usize(2, 5);
        let tenants = rng.gen_range_usize(1, 5);
        let skew = [0.0, 1.0, 2.0][rng.gen_range_usize(0, 3)];
        let batch = rng.gen_range_usize(4, 10);
        let mut p = ClusterParams::new(
            deepseek_v3(),
            ascend_npu(),
            replicas,
            RouterPolicy::PrefixAffinity,
            batch,
            tenants,
            skew,
        );
        p.total_requests = rng.gen_range_usize(8, 40);
        p.seed = seed * 17 + 3;
        // Half the draws use a tight spill threshold so pressure spills
        // actually occur; half use a loose one (no spills expected).
        let tight = rng.next_f64() < 0.5;
        p.spill_queue_depth = if tight { 1 } else { 10_000 };
        if rng.next_f64() < 0.5 {
            p.arrival_rate = Some(1.0 + rng.next_f64() * 20.0);
        }
        let mut sim = ClusterSim::new(&p).unwrap();
        sim.run().unwrap();

        for t in 0..tenants {
            let hosting = sim.replicas_hosting(t);
            if hosting > 1 {
                assert!(
                    sim.tenant_spilled(t),
                    "seed {seed}: tenant {t} on {hosting} replicas without a spill"
                );
            }
        }
        if !tight {
            assert_eq!(
                sim.spills(),
                0,
                "seed {seed}: loose threshold must never spill"
            );
            for t in 0..tenants {
                assert!(
                    sim.replicas_hosting(t) <= 1,
                    "seed {seed}: unspilled tenant {t} concentrated on one replica"
                );
            }
        }
        let report = sim.report();
        assert_eq!(report.spills, sim.spills(), "report mirrors the router count");
    }
}

/// The migration fuzz (acceptance): across random fleets, pressures
/// and arrival patterns with migration enabled, every request still
/// completes exactly once; every migration's destination adopted the
/// pages without a re-prefill (its `shared_prefills` counter is flat
/// around the adoption); and once the fleet drains, a migrated group's
/// pages exist on exactly one replica unless a post-migration spill
/// was recorded — with every retired copy actually released.
#[test]
fn prefix_migration_invariant_fuzz() {
    let mut saw_migration = false;
    for seed in 0..8u64 {
        let mut rng = Rng::new(9000 + seed);
        let replicas = rng.gen_range_usize(2, 5);
        let tenants = rng.gen_range_usize(1, 5);
        let skew = [0.0, 1.0, 2.0][rng.gen_range_usize(0, 3)];
        let batch = rng.gen_range_usize(4, 10);
        let mut p = ClusterParams::new(
            deepseek_v3(),
            ascend_npu(),
            replicas,
            RouterPolicy::PrefixAffinity,
            batch,
            tenants,
            skew,
        );
        p.total_requests = rng.gen_range_usize(8, 40);
        p.seed = seed * 13 + 1;
        p.migrate = true;
        // Mostly tight thresholds so the rule actually fires; a few
        // loose draws pin the no-pressure no-op.
        let tight = rng.next_f64() < 0.75;
        p.spill_queue_depth = if tight { 1 } else { 10_000 };
        if rng.next_f64() < 0.5 {
            p.arrival_rate = Some(1.0 + rng.next_f64() * 20.0);
        }
        let mut sim = ClusterSim::new(&p).unwrap();
        sim.run().unwrap();

        let report = sim.report();
        assert_eq!(
            report.requests_completed as usize,
            sim.arrivals().len(),
            "seed {seed}: conservation under migration"
        );
        for e in sim.migration_log() {
            assert_eq!(
                e.dst_prefills_before, e.dst_prefills_after,
                "seed {seed}: destination re-prefilled a migrated prefix"
            );
        }
        assert!(
            sim.retired_copies_released(),
            "seed {seed}: a retired prefix copy still holds pages"
        );
        for t in 0..tenants {
            if sim.tenant_migrated(t) {
                saw_migration = true;
                if !sim.tenant_spilled_since_migration(t) {
                    assert_eq!(
                        sim.replicas_hosting(t),
                        1,
                        "seed {seed}: migrated tenant {t} pages on multiple replicas"
                    );
                }
            }
        }
        assert_eq!(report.migrations, sim.migrations());
        if !tight {
            assert_eq!(sim.migrations(), 0, "seed {seed}: loose threshold never migrates");
        }
    }
    assert!(saw_migration, "fuzz draws must exercise migration");
}

/// Migrate-enabled affinity must not lose to spill-only affinity on
/// the skewed multi-tenant cell (the new `cluster`-figure headline):
/// re-homing the hot group keeps its overflow one typhoon-eligible
/// group instead of scattering absorb-fallback fragments across the
/// fleet.
#[test]
fn migration_goodput_at_least_spill_only_on_skewed_cell() {
    let mut p = ClusterParams::new(
        deepseek_v3(),
        ascend_npu(),
        4,
        RouterPolicy::PrefixAffinity,
        128,
        4,
        2.0,
    );
    p.total_requests = 512;
    let spill_only = typhoon_mla::simulator::run_cluster_experiment(&p).unwrap();
    p.migrate = true;
    let migrate = typhoon_mla::simulator::run_cluster_experiment(&p).unwrap();
    assert_eq!(spill_only.tokens, migrate.tokens, "same workload either way");
    assert!(spill_only.spills > 0, "the cell must actually pressure the home");
    assert!(migrate.migrations > 0, "the cost rule must fire");
    assert!(
        migrate.goodput >= spill_only.goodput,
        "migrate {} < spill-only {}",
        migrate.goodput,
        spill_only.goodput
    );
}

/// API-stability pin: `ClusterParams::new` defaults keep the PR 3
/// router — migration off, SLO admission off, autoscaling off, the
/// fixed queue-depth trigger — so every pre-migration caller is
/// bit-identical.
#[test]
fn cluster_defaults_preserve_spill_only_router() {
    let p = cluster_params(2, RouterPolicy::PrefixAffinity);
    assert!(!p.migrate);
    assert!(p.slo_ttft.is_none());
    assert!(!p.scaling.enabled);
    assert!(p.arrival_burst.is_none());
    assert_eq!(p.spill_queue_depth, 2 * p.batch);
}

/// A deliberately tight spill threshold on a 2-replica fleet forces the
/// hot group off its home replica: spills are recorded and the group
/// legitimately occupies both replicas.
#[test]
fn forced_spill_is_recorded_and_audited() {
    let mut p = ClusterParams::new(
        deepseek_v3(),
        ascend_npu(),
        2,
        RouterPolicy::PrefixAffinity,
        8,
        1,
        0.0,
    );
    p.total_requests = 32;
    p.spill_queue_depth = 1; // queue depth 1 already counts as pressure
    let mut sim = ClusterSim::new(&p).unwrap();
    sim.run().unwrap();
    assert!(sim.spills() > 0, "tight threshold must spill the hot group");
    assert!(sim.tenant_spilled(0));
    assert_eq!(sim.replicas_hosting(0), 2, "spilled group pages on both replicas");
    let report = sim.report();
    assert_eq!(report.requests_completed, 32, "spilled requests still complete");
}

/// Determinism pin for the per-tenant audit fields: the report's
/// spilled/migrated tenant lists come out of `util::det::sorted_members`
/// strictly ascending (never `HashSet` iteration order), stay consistent
/// with the aggregate counters, and replay bit-identically.
#[test]
fn report_tenant_audit_is_sorted_and_replayable() {
    let mut p = ClusterParams::new(
        deepseek_v3(),
        ascend_npu(),
        4,
        RouterPolicy::PrefixAffinity,
        128,
        4,
        2.0,
    );
    p.total_requests = 512;
    p.migrate = true;
    let r = typhoon_mla::simulator::run_cluster_experiment(&p).unwrap();
    assert!(r.spills > 0, "the skewed cell must spill");
    assert!(r.migrations > 0, "the cost rule must fire");
    for list in [&r.spilled_tenants, &r.migrated_tenants] {
        assert!(!list.is_empty(), "counters fired, so the audit lists are populated");
        assert!(list.windows(2).all(|w| w[0] < w[1]), "strictly ascending: {list:?}");
        assert!(list.iter().all(|&t| t < p.tenants), "tenant ids in range: {list:?}");
    }
    assert!(
        r.spilled_tenants.len() as u64 <= r.spills,
        "each listed tenant spilled at least once"
    );
    assert!(r.migrated_tenants.len() as u64 <= r.migrations);
    let replay = typhoon_mla::simulator::run_cluster_experiment(&p).unwrap();
    assert_eq!(replay.spilled_tenants, r.spilled_tenants, "audit order must replay");
    assert_eq!(replay.migrated_tenants, r.migrated_tenants);
}

/// Prefix-affinity on a skewed multi-tenant workload must model at
/// least round-robin's goodput (the acceptance headline behind the
/// `cluster` artifact).
#[test]
fn affinity_goodput_at_least_round_robin_on_skewed_cell() {
    let mut p = ClusterParams::new(
        deepseek_v3(),
        ascend_npu(),
        4,
        RouterPolicy::RoundRobin,
        128,
        4,
        2.0,
    );
    p.total_requests = 512;
    let rr = typhoon_mla::simulator::run_cluster_experiment(&p).unwrap();
    p.router = RouterPolicy::PrefixAffinity;
    let aff = typhoon_mla::simulator::run_cluster_experiment(&p).unwrap();
    assert_eq!(rr.tokens, aff.tokens, "same workload either way");
    assert!(
        aff.goodput >= rr.goodput,
        "prefix-affinity {} < round-robin {}",
        aff.goodput,
        rr.goodput
    );
    assert!(
        aff.replicas.iter().map(|r| r.prefix_groups).sum::<usize>()
            <= rr.replicas.iter().map(|r| r.prefix_groups).sum::<usize>(),
        "affinity hosts no more prefix copies than round-robin"
    );
}

/// Shared shape of the bursty/skewed acceptance cell (the `cluster`
/// figure's autoscale row): a 4-replica fleet, one hot tenant (skew
/// 2), calm 200 req/s with 50x bursts, a pressure threshold a burst
/// actually reaches, migration on.
fn bursty_cell_params() -> ClusterParams {
    let mut p = ClusterParams::new(
        deepseek_v3(),
        ascend_npu(),
        4,
        RouterPolicy::PrefixAffinity,
        128,
        4,
        2.0,
    );
    p.total_requests = 512;
    p.arrival_rate = Some(200.0);
    p.arrival_burst = Some(50.0);
    p.spill_queue_depth = 32;
    p.migrate = true;
    p
}

/// The autoscaling acceptance pin behind the `cluster` figure: on the
/// bursty skewed cell, the autoscaled fleet must model at least the
/// fixed migrate-enabled fleet's goodput — scale-ups absorb burst
/// overflow on fresh replicas (the whole hot group re-homes instead of
/// fragmenting through spills) and scale-downs consolidate idle
/// replicas between bursts.
#[test]
fn autoscale_goodput_at_least_fixed_fleet_on_bursty_cell() {
    let p = bursty_cell_params();
    let fixed = typhoon_mla::simulator::run_cluster_experiment(&p).unwrap();
    let mut a = p.clone();
    a.scaling.enabled = true;
    let auto = typhoon_mla::simulator::run_cluster_experiment(&a).unwrap();
    assert_eq!(fixed.tokens, auto.tokens, "same workload either way");
    assert_eq!(fixed.scale_ups + fixed.scale_downs, 0, "fixed fleet never resizes");
    assert!(
        auto.scale_ups + auto.scale_downs > 0,
        "the bursty cell must actually exercise the autoscaler"
    );
    assert!(
        auto.goodput >= fixed.goodput,
        "autoscale {} < fixed fleet {}",
        auto.goodput,
        fixed.goodput
    );
}

/// A fleet pinned at its floor (min_replicas = starting size) under
/// bursty overload can only scale up — and must: fresh replicas join,
/// every request still completes exactly once, and the report keeps
/// the grown fleet visible.
#[test]
fn bursty_overload_forces_scale_up() {
    let mut p = bursty_cell_params();
    p.replicas = 2;
    p.scaling.enabled = true;
    p.scaling.min_replicas = 2;
    p.scaling.max_replicas = 6;
    let mut sim = ClusterSim::new(&p).unwrap();
    sim.run().unwrap();
    assert!(sim.scale_ups() > 0, "burst overload must spin replicas up");
    assert!(sim.replica_count() > 2, "fresh stacks joined the fleet");
    assert!(
        sim.active_replica_count() >= 2,
        "the fleet never shrinks below its floor"
    );
    let report = sim.report();
    assert_eq!(report.requests_completed as usize, sim.arrivals().len());
    assert_eq!(report.replicas.len(), sim.replica_count());
    // Scale-up bulk-migrations adopt, never re-prefill (same audit as
    // pressure migrations).
    for e in sim.migration_log() {
        assert_eq!(e.dst_prefills_before, e.dst_prefills_after);
    }
}

/// Satellite regression: `observed_arrival_rate` divides by the
/// *active* replica count at observation time, not the all-time fleet
/// size — after a scale event the surviving replicas each see a larger
/// share, and the admission threshold derived from lambda-hat moves
/// with it.
#[test]
fn observed_arrival_rate_tracks_active_replica_count() {
    let mut p = ClusterParams::new(
        deepseek_v3(),
        ascend_npu(),
        3,
        RouterPolicy::PrefixAffinity,
        16,
        3,
        1.0,
    );
    p.total_requests = 256;
    p.arrival_rate = Some(40.0); // far below capacity: consolidation fires
    p.migrate = true;
    p.scaling.enabled = true;
    p.scaling.cooldown_arrivals = 32;
    let mut sim = ClusterSim::new(&p).unwrap();
    sim.run().unwrap();
    assert!(sim.scale_downs() > 0, "calm stream must consolidate");
    let active = sim.active_replica_count();
    assert!(active < sim.replica_count(), "a replica must have left the fleet");

    let n = sim.arrivals().len();
    let span = sim.arrivals().last().unwrap().at;
    let expected = n as f64 / span / active as f64;
    let buggy = n as f64 / span / sim.replica_count() as f64;
    assert_eq!(
        sim.observed_arrival_rate().to_bits(),
        expected.to_bits(),
        "lambda-hat must divide by the active count"
    );
    assert!(
        sim.observed_arrival_rate() > buggy,
        "the all-time fleet size under-reports per-replica load"
    );
    // The threshold the admission policy derives from lambda-hat (its
    // mu fallback before completion history) moves with the fleet:
    // fewer active replicas -> larger per-replica share -> deeper
    // tolerable backlog at the same TTFT target.
    let slo = typhoon_mla::policy::SloAdmission::new(Some(1.0));
    assert!(
        slo.spill_depth(0.0, sim.observed_arrival_rate(), 1)
            >= slo.spill_depth(0.0, buggy, 1),
        "threshold pinned across the scale event"
    );
}

/// Satellite regression: the migration cool-down bounds re-homing by
/// transfer amortization — between two consecutive migrations of the
/// same group, the group must have been routed at least the first
/// migration's cool-down budget worth of generation tokens.  Fuzzed
/// across seeds, fleets and arrival patterns (with conservation still
/// holding).
#[test]
fn migration_cooldown_bounds_rehoming_fuzz() {
    let mut saw_cooldown = false;
    for seed in 0..8u64 {
        let mut rng = Rng::new(11_000 + seed);
        let replicas = rng.gen_range_usize(2, 5);
        let tenants = rng.gen_range_usize(1, 4);
        let skew = [0.0, 1.0, 2.0][rng.gen_range_usize(0, 3)];
        let batch = rng.gen_range_usize(4, 10);
        let mut p = ClusterParams::new(
            deepseek_v3(),
            ascend_npu(),
            replicas,
            RouterPolicy::PrefixAffinity,
            batch,
            tenants,
            skew,
        );
        p.total_requests = rng.gen_range_usize(16, 64);
        p.seed = seed * 29 + 7;
        p.migrate = true;
        p.spill_queue_depth = 1; // every queued request counts as pressure
        if rng.next_f64() < 0.5 {
            p.arrival_rate = Some(1.0 + rng.next_f64() * 20.0);
        }
        let mut sim = ClusterSim::new(&p).unwrap();
        sim.run().unwrap();
        assert_eq!(
            sim.report().requests_completed as usize,
            sim.arrivals().len(),
            "seed {seed}: conservation under the cool-down"
        );

        // Group the log per tenant, in firing order.
        for t in 0..tenants {
            let events: Vec<_> =
                sim.migration_log().iter().filter(|e| e.tenant == t).collect();
            for pair in events.windows(2) {
                let (e1, e2) = (pair[0], pair[1]);
                assert!(e1.arrival_index <= e2.arrival_index, "seed {seed}: log ordered");
                if e1.cooldown_tokens == 0 {
                    continue; // free consolidation: nothing to amortize
                }
                saw_cooldown = true;
                // Tokens the group was routed between the two re-homes
                // (the triggering arrival of e1 counts: it is served
                // post-migration; e2's does not: its routing decided
                // before its own budget amortized anything).
                let served: u64 = sim.arrivals()[e1.arrival_index..e2.arrival_index]
                    .iter()
                    .filter(|a| a.tenant == t)
                    .map(|a| a.request.max_new_tokens as u64)
                    .sum();
                assert!(
                    served >= e1.cooldown_tokens,
                    "seed {seed}: tenant {t} re-homed after {served} of {} \
                     amortization tokens",
                    e1.cooldown_tokens
                );
            }
        }
    }
    assert!(saw_cooldown, "fuzz draws must exercise a paid re-home followed by another");
}

/// Satellite fuzz: autoscaling invariants across seeds and rates —
/// every request completes exactly once fleet-wide across
/// scale-up/scale-down, a decommissioned replica holds zero pages
/// after drain, and no replica leaks KV pages or work.
#[test]
fn autoscale_conservation_and_drain_fuzz() {
    let mut saw_scale_event = false;
    for seed in 0..8u64 {
        let mut rng = Rng::new(13_000 + seed);
        let replicas = rng.gen_range_usize(2, 5);
        let tenants = rng.gen_range_usize(1, 4);
        let skew = [0.0, 1.0, 2.0][rng.gen_range_usize(0, 3)];
        let batch = rng.gen_range_usize(4, 13);
        let mut p = ClusterParams::new(
            deepseek_v3(),
            ascend_npu(),
            replicas,
            RouterPolicy::PrefixAffinity,
            batch,
            tenants,
            skew,
        );
        p.total_requests = rng.gen_range_usize(48, 160);
        p.seed = seed * 37 + 11;
        p.migrate = rng.next_f64() < 0.5;
        p.scaling.enabled = true;
        p.scaling.cooldown_arrivals = 16;
        // Calm-to-overloaded rates, half with bursts layered on.
        p.arrival_rate = Some(10.0 + rng.next_f64() * 400.0);
        if rng.next_f64() < 0.5 {
            p.arrival_burst = Some(2.0 + rng.next_f64() * 60.0);
        }
        let mut sim = ClusterSim::new(&p).unwrap();
        let mut guard = 0u64;
        while sim.step_event().unwrap() {
            guard += 1;
            assert!(guard < 4_000_000, "seed {seed}: no progress");
        }
        let report = sim.report();
        assert_eq!(
            report.requests_completed as usize,
            sim.arrivals().len(),
            "seed {seed}: every request completes exactly once across resizes"
        );
        let routed: u64 = report.replicas.iter().map(|r| r.routed).sum();
        assert_eq!(routed as usize, sim.arrivals().len(), "seed {seed}: no double-route");
        saw_scale_event |= report.scale_ups + report.scale_downs > 0;
        assert_eq!(report.active_replicas, sim.active_replica_count());
        for i in 0..sim.replica_count() {
            let coord = sim.coordinator(i);
            assert_eq!(coord.running(), 0, "seed {seed}: replica {i} drained");
            assert_eq!(coord.queued(), 0, "seed {seed}: replica {i} drained");
            let hosted_pages: usize = coord
                .prefix_groups()
                .iter()
                .map(|&(id, _)| coord.kv.prefix(id).unwrap().latent_blocks.len())
                .sum();
            assert_eq!(
                coord.kv.used_blocks(),
                hosted_pages,
                "seed {seed}: replica {i} leaked KV pages"
            );
            if sim.replica_state(i) != ReplicaLifecycle::Active {
                assert_eq!(
                    sim.replica_state(i),
                    ReplicaLifecycle::Retired,
                    "seed {seed}: spin-down victims finish draining"
                );
                assert_eq!(
                    coord.kv.used_blocks(),
                    0,
                    "seed {seed}: decommissioned replica {i} holds pages"
                );
            }
        }
        assert!(sim.retired_copies_released(), "seed {seed}");
        for e in sim.migration_log() {
            assert_eq!(
                e.dst_prefills_before, e.dst_prefills_after,
                "seed {seed}: a re-home re-prefilled at the destination"
            );
        }
    }
    assert!(saw_scale_event, "fuzz draws must exercise the autoscaler");
}

/// Satellite pin: `--autoscale` that never triggers is bit-identical
/// to the PR 4 fixed fleet — both with bounds pinched to the starting
/// size under a live arrival stream, and with wide bounds under the
/// batch protocol (infinite lambda is unobservable, the policy holds).
#[test]
fn autoscale_never_triggered_is_bit_identical() {
    fn report_bits_equal(a: &ClusterReport, b: &ClusterReport) {
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.requests_completed, b.requests_completed);
        assert_eq!(a.decode_seconds.to_bits(), b.decode_seconds.to_bits());
        assert_eq!(a.goodput.to_bits(), b.goodput.to_bits());
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.ttft_p99.to_bits(), b.ttft_p99.to_bits());
        assert_eq!(a.spills, b.spills);
        assert_eq!(a.migrations, b.migrations);
        assert_eq!(a.transfer_seconds.to_bits(), b.transfer_seconds.to_bits());
    }

    // Pinched bounds, live stream: the decision runs on every arrival
    // but min == start == max forbids both directions.
    let mut p = ClusterParams::new(
        deepseek_v3(),
        ascend_npu(),
        2,
        RouterPolicy::PrefixAffinity,
        16,
        3,
        1.0,
    );
    p.total_requests = 96;
    p.arrival_rate = Some(50.0);
    p.migrate = true;
    p.spill_queue_depth = 2;
    let fixed = typhoon_mla::simulator::run_cluster_experiment(&p).unwrap();
    let mut a = p.clone();
    a.scaling.enabled = true;
    a.scaling.min_replicas = 2;
    a.scaling.max_replicas = 2;
    let auto = typhoon_mla::simulator::run_cluster_experiment(&a).unwrap();
    assert_eq!(auto.scale_ups + auto.scale_downs, 0, "pinched bounds never scale");
    report_bits_equal(&fixed, &auto);

    // Wide bounds, batch protocol: lambda is infinite, the policy
    // holds on unobservable rates.
    let mut p = ClusterParams::new(
        deepseek_v3(),
        ascend_npu(),
        2,
        RouterPolicy::PrefixAffinity,
        16,
        3,
        1.0,
    );
    p.total_requests = 96;
    p.migrate = true;
    p.spill_queue_depth = 2;
    let fixed = typhoon_mla::simulator::run_cluster_experiment(&p).unwrap();
    let mut a = p.clone();
    a.scaling.enabled = true;
    let auto = typhoon_mla::simulator::run_cluster_experiment(&a).unwrap();
    assert_eq!(auto.scale_ups + auto.scale_downs, 0, "batch protocol never scales");
    report_bits_equal(&fixed, &auto);
}

/// The fault-injection acceptance fuzz (conservation spine): across
/// random fleets, routers knobs and **seeded fault schedules** —
/// crashes, stalls, interconnect degradation/partition windows, and
/// in-flight transfer loss — every request completes exactly once
/// fleet-wide, the fleet's token total is exactly the arrival budget
/// plus the tokens a crash threw away (re-queued work redoes them,
/// nothing is dropped and nothing double-counts), no replica leaks KV
/// pages, crashed replicas end with zero live pages, and per-replica
/// clocks never move backward.  Assertion messages embed the failing
/// seed so a red long-fuzz run replays as a one-seed unit test.
#[test]
fn fault_schedule_conservation_fuzz() {
    let mut saw_crash = false;
    let mut saw_requeue = false;
    for seed in 0..fuzz_iters(10) {
        let mut rng = Rng::new(17_000 + seed);
        let replicas = rng.gen_range_usize(2, 5);
        let tenants = rng.gen_range_usize(1, 4);
        let skew = [0.0, 1.0, 2.0][rng.gen_range_usize(0, 3)];
        let batch = rng.gen_range_usize(4, 13);
        let mut p = ClusterParams::new(
            deepseek_v3(),
            ascend_npu(),
            replicas,
            RouterPolicy::PrefixAffinity,
            batch,
            tenants,
            skew,
        );
        p.total_requests = rng.gen_range_usize(48, 160);
        p.seed = seed * 41 + 3;
        p.migrate = rng.next_f64() < 0.7;
        p.spill_queue_depth = if rng.next_f64() < 0.5 { 1 } else { 2 * batch };
        if rng.next_f64() < 0.5 {
            p.arrival_rate = Some(1.0 + rng.next_f64() * 50.0);
        }
        p.faults.enabled = true;
        p.faults.seed = seed * 97 + 13; // independent of the workload seed
        p.faults.crashes = rng.gen_range_usize(0, replicas); // survivor stays
        p.faults.stalls = rng.gen_range_usize(0, 4);
        p.faults.degradations = rng.gen_range_usize(0, 3);
        if rng.next_f64() < 0.5 {
            p.faults.transfer_loss = rng.next_f64() * 0.9;
        }
        p.faults.degrade_factor = [0.0, 0.25, 1.0][rng.gen_range_usize(0, 3)];
        let mut sim = ClusterSim::new(&p).unwrap();

        // Expected totals from the arrival stream (pools are sized so
        // no request is ever force-finished short; re-queued crash
        // victims resubmit the same prompt/budget, so the same clamp
        // applies on the survivor).
        let max_seq_len = 2048usize;
        let n_arrivals = sim.arrivals().len();
        let expected_tokens: u64 = sim
            .arrivals()
            .iter()
            .map(|a| {
                let prompt = a.request.prompt_tokens.min(max_seq_len - 1);
                a.request.max_new_tokens.min(max_seq_len - prompt).max(1) as u64
            })
            .sum();

        let mut prev = sim.replica_clocks();
        let mut guard = 0u64;
        while sim.step_event().unwrap() {
            let now = sim.replica_clocks();
            for (r, (a, b)) in prev.iter().zip(&now).enumerate() {
                assert!(b >= a, "seed {seed}: replica {r} clock went backward");
            }
            prev = now;
            guard += 1;
            assert!(guard < 4_000_000, "seed {seed}: no progress");
        }

        let report = sim.report();
        saw_crash |= report.crashes > 0;
        saw_requeue |= report.requeued_requests > 0;
        assert!(
            report.crashes as usize <= p.faults.crashes,
            "seed {seed}: more crashes than the plan scheduled"
        );
        assert_eq!(
            report.requests_completed as usize, n_arrivals,
            "seed {seed}: every request completes exactly once across the fleet"
        );
        let routed: u64 = report.replicas.iter().map(|r| r.routed).sum();
        assert_eq!(routed as usize, n_arrivals, "seed {seed}: no request routed twice");
        let requeued: u64 = report.replicas.iter().map(|r| r.requeued).sum();
        assert_eq!(
            requeued, report.requeued_requests,
            "seed {seed}: every extracted sequence lands on a survivor"
        );
        assert_eq!(
            report.tokens,
            expected_tokens + report.lost_tokens,
            "seed {seed}: token conservation — crashed work redone exactly once"
        );
        for i in 0..sim.replica_count() {
            let coord = sim.coordinator(i);
            assert_eq!(coord.running(), 0, "seed {seed}: replica {i} drained");
            assert_eq!(coord.queued(), 0, "seed {seed}: replica {i} drained");
            let hosted_pages: usize = coord
                .prefix_groups()
                .iter()
                .map(|&(id, _)| coord.kv.prefix(id).unwrap().latent_blocks.len())
                .sum();
            assert_eq!(
                coord.kv.used_blocks(),
                hosted_pages,
                "seed {seed}: replica {i} leaked KV pages"
            );
            if sim.replica_state(i) == ReplicaLifecycle::Failed {
                assert_eq!(
                    coord.kv.used_blocks(),
                    0,
                    "seed {seed}: crashed replica {i} still holds live pages"
                );
            }
        }
        assert!(sim.retired_copies_released(), "seed {seed}");
        if report.crashes > 0 {
            assert!(
                report.recovery_p99_s > 0.0,
                "seed {seed}: executed crashes must report a recovery time"
            );
        }
    }
    assert!(saw_crash, "fuzz draws must exercise a crash");
    assert!(saw_requeue, "fuzz draws must re-queue in-flight work");
}

/// Satellite pin: an **empty fault plan** is structurally inert.  A
/// `--faults` run whose plan schedules nothing (zero crashes, stalls
/// and degradation windows, zero loss probability) takes the exact
/// fault-free code path — no RNG draws, no clock perturbation — and
/// its report is bit-identical to the same cluster with fault
/// injection disabled.
#[test]
fn empty_fault_plan_is_bit_identical() {
    fn report_bits_equal(a: &ClusterReport, b: &ClusterReport) {
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.requests_completed, b.requests_completed);
        assert_eq!(a.decode_seconds.to_bits(), b.decode_seconds.to_bits());
        assert_eq!(a.goodput.to_bits(), b.goodput.to_bits());
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.ttft_p99.to_bits(), b.ttft_p99.to_bits());
        assert_eq!(a.spills, b.spills);
        assert_eq!(a.migrations, b.migrations);
        assert_eq!(a.transfer_seconds.to_bits(), b.transfer_seconds.to_bits());
    }

    let mut p = ClusterParams::new(
        deepseek_v3(),
        ascend_npu(),
        2,
        RouterPolicy::PrefixAffinity,
        16,
        3,
        1.0,
    );
    p.total_requests = 96;
    p.arrival_rate = Some(50.0);
    p.migrate = true;
    p.spill_queue_depth = 2;
    let plain = typhoon_mla::simulator::run_cluster_experiment(&p).unwrap();

    let mut f = p.clone();
    f.faults.enabled = true;
    f.faults.seed = 123; // a non-trivial seed must still draw nothing
    let faulty = typhoon_mla::simulator::run_cluster_experiment(&f).unwrap();
    report_bits_equal(&plain, &faulty);
    assert_eq!(faulty.crashes, 0);
    assert_eq!(faulty.stalls, 0);
    assert_eq!(faulty.transfer_retries, 0);
    assert_eq!(faulty.failovers, 0);
    assert_eq!(faulty.lost_pages, 0);
    assert_eq!(faulty.requeued_requests, 0);
    assert_eq!(faulty.lost_tokens, 0);
    assert_eq!(faulty.recovery_p99_s.to_bits(), 0.0f64.to_bits());
}

/// The event-core acceptance fuzz (PR 7 spine): across random fleets,
/// **all three router policies**, autoscale resizes and seeded fault
/// plans, the indexed event loop (clock heap + load index) and the
/// parallel replica stepper are **bit-identical** to the retained
/// linear-scan reference — same modeled times, same counters, same
/// event totals.  Debug builds additionally cross-check every single
/// heap/index query against the linear scan inside the sim itself.
/// Two pinned draws (a cell the autoscale smoke test proves
/// consolidates, and a cell the crash smoke test proves crashes)
/// guarantee resize/fault coverage independent of the random draw
/// sequence; `TYPHOON_FUZZ_ITERS` scales the random draws in the
/// long-fuzz job.
#[test]
fn event_core_bit_identity_fuzz() {
    fn report_bits_equal(seed: u64, label: &str, a: &ClusterReport, b: &ClusterReport) {
        assert_eq!(a.tokens, b.tokens, "seed {seed}: {label} tokens");
        assert_eq!(
            a.requests_completed, b.requests_completed,
            "seed {seed}: {label} completions"
        );
        assert_eq!(
            a.decode_seconds.to_bits(),
            b.decode_seconds.to_bits(),
            "seed {seed}: {label} decode seconds"
        );
        assert_eq!(
            a.goodput.to_bits(),
            b.goodput.to_bits(),
            "seed {seed}: {label} goodput"
        );
        assert_eq!(
            a.makespan.to_bits(),
            b.makespan.to_bits(),
            "seed {seed}: {label} makespan"
        );
        assert_eq!(
            a.ttft_p99.to_bits(),
            b.ttft_p99.to_bits(),
            "seed {seed}: {label} ttft p99"
        );
        assert_eq!(
            a.tpot_p99.to_bits(),
            b.tpot_p99.to_bits(),
            "seed {seed}: {label} tpot p99"
        );
        assert_eq!(a.spills, b.spills, "seed {seed}: {label} spills");
        assert_eq!(a.migrations, b.migrations, "seed {seed}: {label} migrations");
        assert_eq!(
            a.transfer_seconds.to_bits(),
            b.transfer_seconds.to_bits(),
            "seed {seed}: {label} transfer seconds"
        );
        assert_eq!(a.scale_ups, b.scale_ups, "seed {seed}: {label} scale-ups");
        assert_eq!(a.scale_downs, b.scale_downs, "seed {seed}: {label} scale-downs");
        assert_eq!(a.crashes, b.crashes, "seed {seed}: {label} crashes");
        assert_eq!(a.stalls, b.stalls, "seed {seed}: {label} stalls");
        assert_eq!(
            a.requeued_requests, b.requeued_requests,
            "seed {seed}: {label} re-queues"
        );
        assert_eq!(a.lost_tokens, b.lost_tokens, "seed {seed}: {label} lost tokens");
        assert_eq!(a.replicas.len(), b.replicas.len(), "seed {seed}: {label} fleet size");
        for (i, (ra, rb)) in a.replicas.iter().zip(&b.replicas).enumerate() {
            assert_eq!(
                ra.final_clock.to_bits(),
                rb.final_clock.to_bits(),
                "seed {seed}: {label} replica {i} clock"
            );
            assert_eq!(ra.tokens, rb.tokens, "seed {seed}: {label} replica {i} tokens");
            assert_eq!(ra.state, rb.state, "seed {seed}: {label} replica {i} state");
        }
    }

    /// Run the same cell three ways — linear-scan oracle, indexed
    /// serial loop, parallel stepper — assert bit-identity, and return
    /// the (identical) report.
    fn identity_triple(seed: u64, p: &ClusterParams) -> ClusterReport {
        let mut oracle = ClusterSim::new(p).unwrap();
        oracle.use_linear_reference(true);
        oracle.run().unwrap();
        let reference = oracle.report();

        let mut heap = ClusterSim::new(p).unwrap();
        heap.run().unwrap();
        report_bits_equal(seed, "heap vs linear", &reference, &heap.report());
        assert_eq!(
            oracle.events_processed(),
            heap.events_processed(),
            "seed {seed}: event totals diverged"
        );

        let mut par = ClusterSim::new(p).unwrap();
        par.run_parallel().unwrap();
        report_bits_equal(seed, "parallel vs linear", &reference, &par.report());
        assert_eq!(
            oracle.events_processed(),
            par.events_processed(),
            "seed {seed}: parallel event totals diverged"
        );
        assert_eq!(
            oracle.arena_peak(),
            par.arena_peak(),
            "seed {seed}: arena high-water diverged"
        );
        reference
    }

    // Pinned draw 1: the cell `autoscale_consolidates_an_overprovisioned_fleet`
    // proves scales down (resize coverage under lifecycle exits).
    let mut p = ClusterParams::new(
        deepseek_v3(),
        ascend_npu(),
        3,
        RouterPolicy::PrefixAffinity,
        16,
        3,
        1.0,
    );
    p.total_requests = 256;
    p.arrival_rate = Some(40.0);
    p.migrate = true;
    p.scaling.enabled = true;
    p.scaling.cooldown_arrivals = 32;
    let r = identity_triple(u64::MAX, &p);
    assert!(r.scale_downs > 0, "pinned draw must exercise a resize");

    // Pinned draw 2: the cell `crash_failover_requeues_and_completes_everything`
    // proves crashes (failover re-queue coverage).
    let mut p = ClusterParams::new(
        deepseek_v3(),
        ascend_npu(),
        2,
        RouterPolicy::PrefixAffinity,
        32,
        3,
        1.0,
    );
    p.total_requests = 64;
    p.migrate = true;
    p.faults.enabled = true;
    p.faults.seed = 9;
    p.faults.crashes = 1;
    let r = identity_triple(u64::MAX - 1, &p);
    assert_eq!(r.crashes, 1, "pinned draw must exercise a crash");

    // Random draws over routers, fleet shapes, arrival profiles and —
    // on the prefix-affinity draws, where the policy layers act —
    // migration, SLO admission, autoscaling and fault plans.
    for seed in 0..fuzz_iters(8) {
        let mut rng = Rng::new(23_000 + seed);
        let replicas = rng.gen_range_usize(2, 6);
        let tenants = rng.gen_range_usize(1, 4);
        let skew = [0.0, 1.0, 2.0][rng.gen_range_usize(0, 3)];
        let batch = rng.gen_range_usize(4, 13);
        let router = RouterPolicy::all()[rng.gen_range_usize(0, 3)];
        let mut p = ClusterParams::new(
            deepseek_v3(),
            ascend_npu(),
            replicas,
            router,
            batch,
            tenants,
            skew,
        );
        p.total_requests = rng.gen_range_usize(48, 160);
        p.seed = seed * 59 + 5;
        if rng.next_f64() < 0.7 {
            p.arrival_rate = Some(1.0 + rng.next_f64() * 50.0);
        }
        if router == RouterPolicy::PrefixAffinity {
            p.migrate = rng.next_f64() < 0.7;
            p.spill_queue_depth = if rng.next_f64() < 0.5 { 1 } else { 2 * batch };
            if rng.next_f64() < 0.3 {
                p.slo_ttft = Some(0.05 + rng.next_f64());
            }
            if p.arrival_rate.is_some() && rng.next_f64() < 0.6 {
                p.scaling.enabled = true;
                p.scaling.cooldown_arrivals = rng.gen_range_usize(16, 48);
                if rng.next_f64() < 0.5 {
                    p.arrival_burst = Some(2.0 + rng.next_f64() * 6.0);
                }
            }
            if rng.next_f64() < 0.6 {
                p.faults.enabled = true;
                p.faults.seed = seed * 89 + 7;
                p.faults.crashes = rng.gen_range_usize(0, replicas);
                p.faults.stalls = rng.gen_range_usize(0, 4);
                p.faults.degradations = rng.gen_range_usize(0, 3);
                if rng.next_f64() < 0.5 {
                    p.faults.transfer_loss = rng.next_f64() * 0.9;
                }
                p.faults.degrade_factor = [0.0, 0.25, 1.0][rng.gen_range_usize(0, 3)];
            }
        }
        identity_triple(seed, &p);
    }
}
