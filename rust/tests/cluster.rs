//! Cluster-scale serving regressions:
//! * **reduction** — a 1-replica cluster with round-robin routing and
//!   `ParallelismConfig::single()` is bit-identical to the pre-cluster
//!   serving path on the same request stream (same pattern as
//!   `single_tenant_reduces_to_classic_path`);
//! * **router conservation** — across random policy/seed/replica-count
//!   draws, every generated request completes exactly once across the
//!   fleet, token budgets conserve, no replica leaks KV pages, and
//!   every replica's clock is monotone;
//! * **prefix-affinity invariant** — a prefix group never occupies two
//!   replicas unless a spill was recorded;
//! * **prefix-migration invariant** — with the cost-driven
//!   migrate-vs-spill rule enabled, a migrated group's pages end on
//!   exactly one replica (unless a post-migration spill was recorded),
//!   its destination adopts without re-prefilling, and retired copies
//!   release their pages at drain.

use typhoon_mla::config::hardware::ascend_npu;
use typhoon_mla::config::model::deepseek_v3;
use typhoon_mla::config::{KernelKind, ServingConfig};
use typhoon_mla::coordinator::{Coordinator, KernelPolicy};
use typhoon_mla::costmodel::{batch_threshold, ParallelismConfig};
use typhoon_mla::kvcache::KvCacheManager;
use typhoon_mla::simulator::{
    run_tenant_experiment, ClusterParams, ClusterSim, RouterPolicy, SimEngine, TenantSimParams,
};
use typhoon_mla::util::rng::Rng;
use typhoon_mla::workload::tenants::{tenant_set, timed_arrivals};

fn cluster_params(replicas: usize, router: RouterPolicy) -> ClusterParams {
    ClusterParams::new(deepseek_v3(), ascend_npu(), replicas, router, 64, 1, 0.0)
}

/// The reduction: with one replica, round-robin routing and no TP/SP
/// sharding, the cluster machinery must serve the stream **bit-for-bit**
/// like the single-device serving path — both the tenancy experiment
/// entry point and a hand-built classic coordinator fed the same
/// requests.
#[test]
fn one_replica_round_robin_reduces_to_serving_sim() {
    let batch = 64;
    let total_requests = 128;
    let seed = 7;

    let mut p = cluster_params(1, RouterPolicy::RoundRobin);
    p.batch = batch;
    p.total_requests = total_requests;
    p.seed = seed;
    p.parallelism = ParallelismConfig::single();
    let mut sim = ClusterSim::new(&p).unwrap();
    sim.run().unwrap();
    let cluster = sim.report();
    assert_eq!(cluster.replicas.len(), 1);
    assert_eq!(cluster.spills, 0);

    // Today's serving path #1: the tenancy experiment on the same
    // (tenants, seed, budget) draw.
    let mut tp = TenantSimParams::new(
        deepseek_v3(),
        ascend_npu(),
        KernelKind::Typhoon,
        batch,
        1,
        0.0,
    );
    tp.total_requests = total_requests;
    tp.seed = seed;
    let tenancy = run_tenant_experiment(&tp).unwrap();
    assert_eq!(cluster.tokens, tenancy.tokens);
    assert_eq!(cluster.replicas[0].iterations, tenancy.iterations);
    assert_eq!(
        cluster.decode_seconds.to_bits(),
        tenancy.decode_seconds.to_bits(),
        "1-replica cluster must be bit-identical to the tenancy path"
    );
    assert_eq!(cluster.replicas[0].typhoon_iters, tenancy.typhoon_iters);
    assert_eq!(cluster.replicas[0].absorb_iters, tenancy.absorb_iters);
    assert_eq!(cluster.replicas[0].mixed_iters, 0);

    // Today's serving path #2: a hand-built classic coordinator (the
    // pre-cluster `set_shared_prefix` + `submit` loop) on the same
    // stream, sized exactly like a cluster replica.
    let tenants = tenant_set(1, 0.0);
    let block_size = 128;
    let max_seq_len = 2048;
    let prefix_blocks: usize =
        tenants.iter().map(|t| t.prompt_tokens.div_ceil(block_size)).sum();
    let total_blocks = batch * (max_seq_len / block_size) + prefix_blocks + 64;
    let cfg = ServingConfig {
        block_size,
        max_batch: batch,
        max_seq_len,
        total_blocks,
        kernel: KernelKind::Typhoon,
        ..Default::default()
    };
    let b_theta = batch_threshold(&deepseek_v3(), &ascend_npu(), 1);
    let policy = KernelPolicy::with_threshold(KernelKind::Typhoon, b_theta);
    let kv = KvCacheManager::new(deepseek_v3(), total_blocks, block_size);
    let mut engine = SimEngine::new(deepseek_v3(), ascend_npu());
    engine.include_prefill = false;
    let mut classic = Coordinator::new(cfg, policy, kv, engine).unwrap();
    classic.set_shared_prefix(&tenants[0].prompt_token_ids(50_000)).unwrap();
    for a in timed_arrivals(&tenants, total_requests, None, seed).unwrap() {
        assert_eq!(a.at, 0.0, "batch protocol arrives at t = 0");
        classic.submit(&a.request).unwrap();
    }
    classic.run_to_completion().unwrap();
    let cm = &classic.metrics;
    assert_eq!(cluster.tokens, cm.tokens_generated);
    assert_eq!(cluster.requests_completed, cm.requests_completed);
    assert_eq!(cluster.replicas[0].iterations, cm.decode_iterations);
    assert_eq!(
        cluster.decode_seconds.to_bits(),
        cm.decode_seconds.to_bits(),
        "1-replica cluster must be bit-identical to the classic path"
    );
    assert_eq!(cluster.makespan.to_bits(), classic.now().to_bits());
}

/// Router conservation across random policy/seed/replica-count draws:
/// every request completes exactly once somewhere, token budgets
/// conserve exactly, KV pages return to each replica's prefix
/// baseline, and per-replica clocks never move backward.
#[test]
fn router_conservation_fuzz() {
    for seed in 0..10u64 {
        let mut rng = Rng::new(4000 + seed);
        let replicas = rng.gen_range_usize(1, 4);
        let policy = *rng.choose(&RouterPolicy::all());
        let tenants = rng.gen_range_usize(1, 4);
        let skew = [0.0, 1.0, 2.0][rng.gen_range_usize(0, 3)];
        let batch = rng.gen_range_usize(4, 13);
        let total_requests = rng.gen_range_usize(8, 33);
        let mut p =
            ClusterParams::new(deepseek_v3(), ascend_npu(), replicas, policy, batch, tenants, skew);
        p.total_requests = total_requests;
        p.seed = seed * 31 + 5;
        if rng.next_f64() < 0.5 {
            p.arrival_rate = Some(0.5 + rng.next_f64() * 50.0);
        }
        let mut sim = ClusterSim::new(&p).unwrap();

        // Expected totals from the arrival stream (cluster pools are
        // sized so no request is ever force-finished short).
        let max_seq_len = 2048usize;
        let n_arrivals = sim.arrivals().len();
        let expected_tokens: usize = sim
            .arrivals()
            .iter()
            .map(|a| {
                let prompt = a.request.prompt_tokens.min(max_seq_len - 1);
                a.request.max_new_tokens.min(max_seq_len - prompt).max(1)
            })
            .sum();

        let mut prev = sim.replica_clocks();
        let mut guard = 0u64;
        while sim.step_event().unwrap() {
            let now = sim.replica_clocks();
            for (r, (a, b)) in prev.iter().zip(&now).enumerate() {
                assert!(b >= a, "seed {seed}: replica {r} clock went backward");
            }
            prev = now;
            guard += 1;
            assert!(guard < 2_000_000, "seed {seed}: no progress");
        }

        let report = sim.report();
        assert_eq!(
            report.requests_completed as usize, n_arrivals,
            "seed {seed}: every request completes exactly once across the fleet"
        );
        let routed: u64 = report.replicas.iter().map(|r| r.routed).sum();
        assert_eq!(routed as usize, n_arrivals, "seed {seed}: no request routed twice");
        assert_eq!(
            report.tokens as usize, expected_tokens,
            "seed {seed}: token conservation"
        );
        assert!(
            report.ttft_p50.is_finite(),
            "seed {seed}: completed requests must report TTFT"
        );
        // No cross-replica page leaks: after drain, each replica holds
        // exactly its hosted prefixes' pages and nothing else.
        for i in 0..sim.replica_count() {
            let coord = sim.coordinator(i);
            let hosted_pages: usize = coord
                .prefix_groups()
                .iter()
                .map(|&(id, _)| coord.kv.prefix(id).unwrap().latent_blocks.len())
                .sum();
            assert_eq!(
                coord.kv.used_blocks(),
                hosted_pages,
                "seed {seed}: replica {i} leaked KV pages"
            );
            assert_eq!(coord.running(), 0, "seed {seed}: replica {i} drained");
            assert_eq!(coord.queued(), 0, "seed {seed}: replica {i} drained");
        }
    }
}

/// The prefix-affinity invariant: a prefix group's pages exist on at
/// most one replica unless the router recorded a spill for that group
/// — across random seeds, fleet sizes and arrival patterns.
#[test]
fn prefix_affinity_invariant_fuzz() {
    for seed in 0..8u64 {
        let mut rng = Rng::new(6000 + seed);
        let replicas = rng.gen_range_usize(2, 5);
        let tenants = rng.gen_range_usize(1, 5);
        let skew = [0.0, 1.0, 2.0][rng.gen_range_usize(0, 3)];
        let batch = rng.gen_range_usize(4, 10);
        let mut p = ClusterParams::new(
            deepseek_v3(),
            ascend_npu(),
            replicas,
            RouterPolicy::PrefixAffinity,
            batch,
            tenants,
            skew,
        );
        p.total_requests = rng.gen_range_usize(8, 40);
        p.seed = seed * 17 + 3;
        // Half the draws use a tight spill threshold so pressure spills
        // actually occur; half use a loose one (no spills expected).
        let tight = rng.next_f64() < 0.5;
        p.spill_queue_depth = if tight { 1 } else { 10_000 };
        if rng.next_f64() < 0.5 {
            p.arrival_rate = Some(1.0 + rng.next_f64() * 20.0);
        }
        let mut sim = ClusterSim::new(&p).unwrap();
        sim.run().unwrap();

        for t in 0..tenants {
            let hosting = sim.replicas_hosting(t);
            if hosting > 1 {
                assert!(
                    sim.tenant_spilled(t),
                    "seed {seed}: tenant {t} on {hosting} replicas without a spill"
                );
            }
        }
        if !tight {
            assert_eq!(
                sim.spills(),
                0,
                "seed {seed}: loose threshold must never spill"
            );
            for t in 0..tenants {
                assert!(
                    sim.replicas_hosting(t) <= 1,
                    "seed {seed}: unspilled tenant {t} concentrated on one replica"
                );
            }
        }
        let report = sim.report();
        assert_eq!(report.spills, sim.spills(), "report mirrors the router count");
    }
}

/// The migration fuzz (acceptance): across random fleets, pressures
/// and arrival patterns with migration enabled, every request still
/// completes exactly once; every migration's destination adopted the
/// pages without a re-prefill (its `shared_prefills` counter is flat
/// around the adoption); and once the fleet drains, a migrated group's
/// pages exist on exactly one replica unless a post-migration spill
/// was recorded — with every retired copy actually released.
#[test]
fn prefix_migration_invariant_fuzz() {
    let mut saw_migration = false;
    for seed in 0..8u64 {
        let mut rng = Rng::new(9000 + seed);
        let replicas = rng.gen_range_usize(2, 5);
        let tenants = rng.gen_range_usize(1, 5);
        let skew = [0.0, 1.0, 2.0][rng.gen_range_usize(0, 3)];
        let batch = rng.gen_range_usize(4, 10);
        let mut p = ClusterParams::new(
            deepseek_v3(),
            ascend_npu(),
            replicas,
            RouterPolicy::PrefixAffinity,
            batch,
            tenants,
            skew,
        );
        p.total_requests = rng.gen_range_usize(8, 40);
        p.seed = seed * 13 + 1;
        p.migrate = true;
        // Mostly tight thresholds so the rule actually fires; a few
        // loose draws pin the no-pressure no-op.
        let tight = rng.next_f64() < 0.75;
        p.spill_queue_depth = if tight { 1 } else { 10_000 };
        if rng.next_f64() < 0.5 {
            p.arrival_rate = Some(1.0 + rng.next_f64() * 20.0);
        }
        let mut sim = ClusterSim::new(&p).unwrap();
        sim.run().unwrap();

        let report = sim.report();
        assert_eq!(
            report.requests_completed as usize,
            sim.arrivals().len(),
            "seed {seed}: conservation under migration"
        );
        for e in sim.migration_log() {
            assert_eq!(
                e.dst_prefills_before, e.dst_prefills_after,
                "seed {seed}: destination re-prefilled a migrated prefix"
            );
        }
        assert!(
            sim.retired_copies_released(),
            "seed {seed}: a retired prefix copy still holds pages"
        );
        for t in 0..tenants {
            if sim.tenant_migrated(t) {
                saw_migration = true;
                if !sim.tenant_spilled_since_migration(t) {
                    assert_eq!(
                        sim.replicas_hosting(t),
                        1,
                        "seed {seed}: migrated tenant {t} pages on multiple replicas"
                    );
                }
            }
        }
        assert_eq!(report.migrations, sim.migrations());
        if !tight {
            assert_eq!(sim.migrations(), 0, "seed {seed}: loose threshold never migrates");
        }
    }
    assert!(saw_migration, "fuzz draws must exercise migration");
}

/// Migrate-enabled affinity must not lose to spill-only affinity on
/// the skewed multi-tenant cell (the new `cluster`-figure headline):
/// re-homing the hot group keeps its overflow one typhoon-eligible
/// group instead of scattering absorb-fallback fragments across the
/// fleet.
#[test]
fn migration_goodput_at_least_spill_only_on_skewed_cell() {
    let mut p = ClusterParams::new(
        deepseek_v3(),
        ascend_npu(),
        4,
        RouterPolicy::PrefixAffinity,
        128,
        4,
        2.0,
    );
    p.total_requests = 512;
    let spill_only = typhoon_mla::simulator::run_cluster_experiment(&p).unwrap();
    p.migrate = true;
    let migrate = typhoon_mla::simulator::run_cluster_experiment(&p).unwrap();
    assert_eq!(spill_only.tokens, migrate.tokens, "same workload either way");
    assert!(spill_only.spills > 0, "the cell must actually pressure the home");
    assert!(migrate.migrations > 0, "the cost rule must fire");
    assert!(
        migrate.goodput >= spill_only.goodput,
        "migrate {} < spill-only {}",
        migrate.goodput,
        spill_only.goodput
    );
}

/// API-stability pin: `ClusterParams::new` defaults keep the PR 3
/// router — migration off, SLO admission off, the fixed queue-depth
/// trigger — so every pre-migration caller is bit-identical.
#[test]
fn cluster_defaults_preserve_spill_only_router() {
    let p = cluster_params(2, RouterPolicy::PrefixAffinity);
    assert!(!p.migrate);
    assert!(p.slo_ttft.is_none());
    assert_eq!(p.spill_queue_depth, 2 * p.batch);
}

/// A deliberately tight spill threshold on a 2-replica fleet forces the
/// hot group off its home replica: spills are recorded and the group
/// legitimately occupies both replicas.
#[test]
fn forced_spill_is_recorded_and_audited() {
    let mut p = ClusterParams::new(
        deepseek_v3(),
        ascend_npu(),
        2,
        RouterPolicy::PrefixAffinity,
        8,
        1,
        0.0,
    );
    p.total_requests = 32;
    p.spill_queue_depth = 1; // queue depth 1 already counts as pressure
    let mut sim = ClusterSim::new(&p).unwrap();
    sim.run().unwrap();
    assert!(sim.spills() > 0, "tight threshold must spill the hot group");
    assert!(sim.tenant_spilled(0));
    assert_eq!(sim.replicas_hosting(0), 2, "spilled group pages on both replicas");
    let report = sim.report();
    assert_eq!(report.requests_completed, 32, "spilled requests still complete");
}

/// Prefix-affinity on a skewed multi-tenant workload must model at
/// least round-robin's goodput (the acceptance headline behind the
/// `cluster` artifact).
#[test]
fn affinity_goodput_at_least_round_robin_on_skewed_cell() {
    let mut p = ClusterParams::new(
        deepseek_v3(),
        ascend_npu(),
        4,
        RouterPolicy::RoundRobin,
        128,
        4,
        2.0,
    );
    p.total_requests = 512;
    let rr = typhoon_mla::simulator::run_cluster_experiment(&p).unwrap();
    p.router = RouterPolicy::PrefixAffinity;
    let aff = typhoon_mla::simulator::run_cluster_experiment(&p).unwrap();
    assert_eq!(rr.tokens, aff.tokens, "same workload either way");
    assert!(
        aff.goodput >= rr.goodput,
        "prefix-affinity {} < round-robin {}",
        aff.goodput,
        rr.goodput
    );
    assert!(
        aff.replicas.iter().map(|r| r.prefix_groups).sum::<usize>()
            <= rr.replicas.iter().map(|r| r.prefix_groups).sum::<usize>(),
        "affinity hosts no more prefix copies than round-robin"
    );
}
