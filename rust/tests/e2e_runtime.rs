//! Integration tests over the real PJRT runtime + AOT artifacts.
//! Skipped (cleanly) when `make artifacts` hasn't been run.

use typhoon_mla::config::{KernelKind, ServingConfig};
use typhoon_mla::config::model::{sim, tiny};
use typhoon_mla::coordinator::{Coordinator, KernelPolicy};
use typhoon_mla::kvcache::KvCacheManager;
use typhoon_mla::runtime::{
    default_artifacts_dir, random_for_spec, to_vec_f32, Manifest, PjrtRuntime, TinyModelEngine,
};
use typhoon_mla::workload::Request;

fn artifacts_ready() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
    };
}

/// All three attention variants, executed through PJRT on identical
/// logical inputs, must agree — the paper's equivalence claim, verified
/// end-to-end through the HLO-text -> PJRT path.
#[test]
fn attention_variants_agree_through_pjrt() {
    require_artifacts!();
    let dir = default_artifacts_dir();
    let mut rt = PjrtRuntime::new(&dir).unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let b = 4usize;
    let cfg = sim();
    let (h, dn, dr, dv, dl) =
        (cfg.n_heads, cfg.d_nope, cfg.d_rope, cfg.d_v, cfg.kv_lora_rank);
    let (ls, ln) = (1024usize, 256usize);

    // Shared logical inputs.
    let q_nope = random_for_spec(
        &typhoon_mla::runtime::TensorSpec { shape: vec![b, h, dn], dtype: typhoon_mla::runtime::Dtype::F32 },
        1, 0,
    )
    .unwrap();
    let q_rope = typhoon_mla::runtime::client::random_f32(&[b, h, dr], 2, 0.5).unwrap();
    let ckv_shared = typhoon_mla::runtime::client::random_f32(&[ls, dl], 3, 0.5).unwrap();
    let krope_shared = typhoon_mla::runtime::client::random_f32(&[ls, dr], 4, 0.5).unwrap();
    let ckv = typhoon_mla::runtime::client::random_f32(&[b, ln, dl], 5, 0.5).unwrap();
    let krope = typhoon_mla::runtime::client::random_f32(&[b, ln, dr], 6, 0.5).unwrap();
    let w1 = typhoon_mla::runtime::client::random_f32(&[h, dn, dl], 7, 0.1).unwrap();
    let w2 = typhoon_mla::runtime::client::random_f32(&[h, dv, dl], 8, 0.1).unwrap();
    let shared_len = typhoon_mla::runtime::literal_i32(&[1], &[1000]).unwrap();
    let lens =
        typhoon_mla::runtime::literal_i32(&[b], &[256, 100, 17, 1]).unwrap();

    // Expand the shared latent cache via the expand artifact (the
    // typhoon/naive path's prefill-time expansion).
    let expand = manifest.select("expand", None, Some("sim"))[0].name.clone();
    let expanded = rt
        .execute(&expand, &[&ckv_shared, &krope_shared, &w1, &w2])
        .unwrap();
    let (k_sh, v_sh) = (&expanded[0], &expanded[1]);

    // Expand the per-request latent cache for the naive baseline.
    // (Do it per request through the same artifact by reshaping.)
    let ckv_flat = to_vec_f32(&ckv).unwrap();
    let krope_flat = to_vec_f32(&krope).unwrap();
    let mut k_n = Vec::new();
    let mut v_n = Vec::new();
    for r in 0..b {
        let ckv_r = typhoon_mla::runtime::literal_f32(
            &[ln, dl],
            &ckv_flat[r * ln * dl..(r + 1) * ln * dl],
        )
        .unwrap();
        // expand artifact is n=1024; pad Ln=256 to 1024.
        let mut padded_ckv = ckv_flat[r * ln * dl..(r + 1) * ln * dl].to_vec();
        padded_ckv.resize(1024 * dl, 0.0);
        let mut padded_kr = krope_flat[r * ln * dr..(r + 1) * ln * dr].to_vec();
        padded_kr.resize(1024 * dr, 0.0);
        let ckv_p = typhoon_mla::runtime::literal_f32(&[1024, dl], &padded_ckv).unwrap();
        let kr_p = typhoon_mla::runtime::literal_f32(&[1024, dr], &padded_kr).unwrap();
        let out = rt.execute(&expand, &[&ckv_p, &kr_p, &w1, &w2]).unwrap();
        let k_full = to_vec_f32(&out[0]).unwrap();
        let v_full = to_vec_f32(&out[1]).unwrap();
        let dqk = dn + dr;
        k_n.extend_from_slice(&k_full[..ln * h * dqk]);
        v_n.extend_from_slice(&v_full[..ln * h * dv]);
        drop(ckv_r);
    }
    let dqk = dn + dr;
    let k_n = typhoon_mla::runtime::literal_f32(&[b, ln, h, dqk], &k_n).unwrap();
    let v_n = typhoon_mla::runtime::literal_f32(&[b, ln, h, dv], &v_n).unwrap();

    let name = |v: &str| format!("attn_{v}_sim_b{b}_s{ls}_n{ln}");
    let o_typhoon = rt
        .execute(
            &name("typhoon"),
            &[&q_nope, &q_rope, k_sh, v_sh, &shared_len, &ckv, &krope, &lens, &w1, &w2],
        )
        .unwrap();
    let o_absorb = rt
        .execute(
            &name("absorb"),
            &[&q_nope, &q_rope, &ckv_shared, &krope_shared, &shared_len, &ckv, &krope, &lens,
              &w1, &w2],
        )
        .unwrap();
    let o_naive = rt
        .execute(
            &name("naive"),
            &[&q_nope, &q_rope, k_sh, v_sh, &shared_len, &k_n, &v_n, &lens],
        )
        .unwrap();

    let t = to_vec_f32(&o_typhoon[0]).unwrap();
    let a = to_vec_f32(&o_absorb[0]).unwrap();
    let n = to_vec_f32(&o_naive[0]).unwrap();
    assert_eq!(t.len(), b * h * dv);
    let max_ta = t.iter().zip(&a).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
    let max_tn = t.iter().zip(&n).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
    assert!(max_ta < 5e-4, "typhoon vs absorb max diff {max_ta}");
    assert!(max_tn < 5e-4, "typhoon vs naive max diff {max_tn}");
    // And they're not trivially zero.
    assert!(t.iter().any(|x| x.abs() > 1e-3));
}

/// Full serving stack over the real tiny transformer: coordinator +
/// paged KV + policy + PJRT engine.  Typhoon and absorb runs must
/// produce the same tokens (mathematical equivalence at system level).
#[test]
fn tiny_model_serving_end_to_end() {
    require_artifacts!();
    let dir = default_artifacts_dir();

    let run = |kernel: KernelKind, b_theta: usize| {
        let engine = TinyModelEngine::new(&dir, kernel).unwrap();
        let cfg = ServingConfig {
            block_size: 16,
            max_batch: 8,
            max_seq_len: 128,
            total_blocks: 1024,
            kernel,
            ..Default::default()
        };
        let policy = KernelPolicy::with_threshold(kernel, b_theta);
        let kv = KvCacheManager::new(tiny(), cfg.total_blocks, cfg.block_size);
        let mut c = Coordinator::new(cfg, policy, kv, engine).unwrap();
        let prompt: Vec<u32> = (0..200u32).map(|i| (i * 7 + 3) % 251 + 1).collect();
        c.set_shared_prefix(&prompt).unwrap();
        for i in 0..6 {
            c.submit(&Request {
                id: i,
                prompt_tokens: 8 + (i as usize) * 3,
                max_new_tokens: 5,
            })
            .unwrap();
        }
        c.run_to_completion().unwrap();
        assert_eq!(c.metrics.requests_completed, 6);
        assert_eq!(c.metrics.tokens_generated, 30);
        let mut gen: Vec<(u64, Vec<i32>)> =
            c.engine.generated.iter().map(|(k, v)| (*k, v.clone())).collect();
        gen.sort();
        gen
    };

    let typhoon_tokens = run(KernelKind::Typhoon, 1);
    let absorb_tokens = run(KernelKind::Absorb, 1);
    assert_eq!(
        typhoon_tokens, absorb_tokens,
        "typhoon and absorb must generate identical tokens"
    );
    // Fallback path: typhoon config with a high threshold decodes via
    // absorb kernels but must still match.
    let fallback_tokens = run(KernelKind::Typhoon, 1000);
    assert_eq!(typhoon_tokens, fallback_tokens);
}
