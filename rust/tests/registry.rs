//! Property-test suite for the kernel registry (DESIGN.md §16).
//!
//! Pins the three contracts the registry refactor must not break:
//!
//! 1. **Binary-restriction bit-identity** — a registry holding only the
//!    `{requested, absorb-fallback}` pair reproduces the pre-registry
//!    binary `KernelPolicy` decision for every randomized
//!    (model, hardware, parallelism, s_q, batch, shared-length) input.
//! 2. **Analytic-vs-numeric bracket** — each backend's floored Eq. 1
//!    threshold brackets the numeric crossover of the priced curves
//!    within +1, for both the classic and AMLA fallbacks.
//! 3. **Backend calibration** — the NPU/GPU presets reproduce the
//!    paper's 3x / 3.24x-shaped speedup ordering on the Table-2-shaped
//!    tenancy cell, with per-backend crossover batches pinned.
//!
//! Self-rolled randomization (no proptest offline): fuzz tests run a
//! base number of seeded scenarios, scaled by `TYPHOON_FUZZ_ITERS` in
//! the scheduled CI long-fuzz job (same convention as tests/cluster.rs).

use typhoon_mla::analysis::figures::{paper_models, CROSSOVER_BACKENDS};
use typhoon_mla::config::hardware::{
    ascend_npu, gpu_h800, gpu_h800_decode, host_cpu, Backend,
};
use typhoon_mla::config::model::{deepseek_v3, kimi_k2};
use typhoon_mla::config::KernelKind;
use typhoon_mla::costmodel::{parallel_batch_threshold, ParallelismConfig};
use typhoon_mla::policy::{KernelPolicy, KernelRegistry};
use typhoon_mla::simulator::sweep::{crossover_cells, run_crossover_sweep};
use typhoon_mla::simulator::{calibration_cell, SweepExecutor};
use typhoon_mla::util::rng::Rng;

/// Iteration budget for a fuzz loop: `base` in tier-1, `base x
/// TYPHOON_FUZZ_ITERS` in the scheduled CI long-fuzz job (unset or
/// unparsable falls back to the tier-1 budget).
fn fuzz_iters(base: u64) -> u64 {
    std::env::var("TYPHOON_FUZZ_ITERS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .map_or(base, |m| base * m.max(1))
}

/// The pre-registry policy, verbatim: the requested kernel runs unless
/// it is a naive-shared reader below its fall-back threshold (or the
/// group has no shared prefix), in which case its absorb-formulation
/// fallback runs instead.
fn legacy_select(
    requested: KernelKind,
    b_theta: usize,
    min_shared_len: usize,
    batch: usize,
    shared_len: usize,
) -> KernelKind {
    match requested {
        KernelKind::Typhoon if batch < b_theta || shared_len < min_shared_len => {
            KernelKind::Absorb
        }
        KernelKind::TyphoonAmla if batch < b_theta || shared_len < min_shared_len => {
            KernelKind::AmlaAbsorb
        }
        k => k,
    }
}

/// Contract 1, derived thresholds: across randomized model x hardware
/// x (TP, SP) x s_q, the binary registry's decision equals the legacy
/// rule at the analytically derived per-rank B_theta — for every
/// requested kernel, batch, and shared length, and regardless of the
/// group's mean non-shared length (which the binary population must
/// ignore).
#[test]
fn fuzz_binary_registry_is_bit_identical_to_legacy_policy() {
    let models = [deepseek_v3(), kimi_k2()];
    let hws = [ascend_npu(), gpu_h800(), gpu_h800_decode(), host_cpu()];
    for seed in 0..fuzz_iters(20) {
        let mut rng = Rng::new(0xBEEF_0000 + seed);
        let cfg = &models[rng.gen_range_usize(0, models.len())];
        let hw = &hws[rng.gen_range_usize(0, hws.len())];
        let par = ParallelismConfig {
            tp: 1u64 << rng.gen_range(0, 4),
            sp: 1u64 << rng.gen_range(0, 3),
        };
        let s_q = rng.gen_range(1, 5);
        for requested in KernelKind::all() {
            let p = KernelPolicy::from_parallelism(requested, cfg, hw, s_q, &par);
            // The classic-fallback threshold is the legacy Eq. 1 value...
            assert_eq!(p.b_theta, parallel_batch_threshold(cfg, hw, s_q, &par));
            // ...and the fallback actually priced is the family pair's.
            let fallback_theta = match requested {
                KernelKind::Typhoon => p.theta_for(KernelKind::Absorb).unwrap(),
                KernelKind::TyphoonAmla => p.theta_for(KernelKind::AmlaAbsorb).unwrap(),
                _ => p.b_theta,
            };
            for _ in 0..64 {
                let batch = rng.gen_range_usize(0, 2048);
                let shared = if rng.next_f64() < 0.2 {
                    0
                } else {
                    rng.gen_range_usize(1, 32768)
                };
                let want =
                    legacy_select(requested, fallback_theta, p.min_shared_len, batch, shared);
                assert_eq!(
                    p.select(batch, shared),
                    want,
                    "requested {requested} at (b={batch}, ls={shared}) on \
                     {}/{} tp{} sp{} s_q={s_q}",
                    cfg.name,
                    hw.name,
                    par.tp,
                    par.sp
                );
                let mns = rng.gen_range_usize(0, 8192);
                assert_eq!(
                    p.select_group(batch, shared, mns),
                    want,
                    "binary decision must ignore mean_non_shared ({mns})"
                );
            }
        }
    }
}

/// Contract 1, overridden thresholds: `with_threshold` (the calibrated
/// deployment path, no pricing context) matches the legacy rule at any
/// pinned B_theta.
#[test]
fn fuzz_threshold_override_is_bit_identical_to_legacy_policy() {
    for seed in 0..fuzz_iters(20) {
        let mut rng = Rng::new(0xFA11_0000 + seed);
        let b_theta = rng.gen_range_usize(0, 200);
        for requested in KernelKind::all() {
            let p = KernelPolicy::with_threshold(requested, b_theta);
            for _ in 0..64 {
                let batch = rng.gen_range_usize(0, 400);
                let shared =
                    if rng.next_f64() < 0.2 { 0 } else { rng.gen_range_usize(1, 8192) };
                assert_eq!(
                    p.select(batch, shared),
                    legacy_select(requested, b_theta, p.min_shared_len, batch, shared),
                    "requested {requested} at (b={batch}, ls={shared}), theta {b_theta}"
                );
            }
        }
    }
}

/// Contract 1, registry shape: the binary restriction really is binary
/// — naive readers carry exactly their fallback, baselines are
/// singletons, so no third kernel can ever leak into the decision.
#[test]
fn binary_registry_population_is_the_legacy_option_set() {
    for requested in KernelKind::all() {
        let kinds = KernelRegistry::binary(requested).kinds();
        let expect = match requested {
            KernelKind::Typhoon => vec![KernelKind::Typhoon, KernelKind::Absorb],
            KernelKind::TyphoonAmla => {
                vec![KernelKind::TyphoonAmla, KernelKind::AmlaAbsorb]
            }
            k => vec![k],
        };
        assert_eq!(kinds, expect, "{requested}");
    }
}

/// Contract 2: per-backend analytic thresholds bracket the numeric
/// priced-curve crossover within +1, across both paper models and both
/// fallback formulations; the DeepSeek-v3 decode thresholds are pinned
/// per backend (Ascend 61/70, decode-calibrated H800 29/33).
#[test]
fn analytic_thresholds_bracket_numeric_crossovers_per_backend() {
    let cells = crossover_cells(&CROSSOVER_BACKENDS, &paper_models(), 4096);
    let results = run_crossover_sweep(&cells, &SweepExecutor::serial()).unwrap();
    assert_eq!(results.len(), 8, "2 backends x 2 models x 2 fallbacks");
    for r in &results {
        let c = &r.cell;
        // Floored exact value is the integer threshold.
        assert!(
            (r.analytic as f64) <= r.analytic_exact
                && r.analytic_exact < (r.analytic + 1) as f64,
            "{}/{}/{}: floor({}) != {}",
            c.backend.as_str(),
            c.model.name,
            c.fallback,
            r.analytic_exact,
            r.analytic
        );
        // The numeric scan of the priced curves lands on the analytic
        // threshold or one past it (the boundary batch ties go to the
        // fallback in the priced scan, to the naive reader in Eq. 1).
        let n = r.numeric.expect("crossover must exist within the scan range");
        assert!(
            n == r.analytic || n == r.analytic + 1,
            "{}/{}/{}: numeric {} does not bracket analytic {}",
            c.backend.as_str(),
            c.model.name,
            c.fallback,
            n,
            r.analytic
        );
    }
    // Per-backend pins (DeepSeek-v3 rows; Eq. 1 is head-count
    // independent so Kimi K2 shares them, asserted via the bracket).
    let dv3 = |backend: Backend, fallback: KernelKind| {
        results
            .iter()
            .find(|r| {
                r.cell.backend == backend
                    && r.cell.model.name == "deepseek-v3"
                    && r.cell.fallback == fallback
            })
            .unwrap()
            .analytic
    };
    assert_eq!(dv3(Backend::Npu, KernelKind::Absorb), 61);
    assert_eq!(dv3(Backend::Npu, KernelKind::AmlaAbsorb), 70);
    assert_eq!(dv3(Backend::Gpu, KernelKind::Absorb), 29);
    assert_eq!(dv3(Backend::Gpu, KernelKind::AmlaAbsorb), 33);
}

/// Contract 3: backend calibration reproduces the paper's speedup
/// shape — ~3x on the NPU, ~3.24x (and strictly larger) on the GPU —
/// with the crossover batches pinned per backend.
#[test]
fn backend_calibration_orders_speedups_and_pins_crossovers() {
    let npu = calibration_cell(Backend::Npu);
    let gpu = calibration_cell(Backend::Gpu);
    assert!(
        npu.speedup > 2.95 && npu.speedup < 3.2,
        "NPU cell drifted off the paper's 3x shape: {:.4}",
        npu.speedup
    );
    assert!(
        gpu.speedup > 3.1 && gpu.speedup < 3.35,
        "GPU cell drifted off the paper's 3.24x shape: {:.4}",
        gpu.speedup
    );
    assert!(gpu.speedup > npu.speedup, "paper ordering: GPU > NPU");
    assert_eq!((npu.b_theta, npu.amla_theta), (61, 70));
    assert_eq!((gpu.b_theta, gpu.amla_theta), (29, 33));
}

/// KernelKind round-trips through parse/Display for every variant
/// (including the AMLA additions), and unknown names fail with the
/// candidate list.
#[test]
fn kernel_kind_parse_display_round_trip() {
    assert_eq!(KernelKind::all().len(), 5);
    for k in KernelKind::all() {
        assert_eq!(KernelKind::parse(k.as_str()).unwrap(), k);
        assert_eq!(k.to_string(), k.as_str(), "Display must match as_str");
    }
    let err = KernelKind::parse("flash-mla").unwrap_err().to_string();
    assert!(err.contains("amla-absorb") && err.contains("typhoon-amla"), "{err}");
}

/// N-way invariants under fuzz: the full registry's decision is
/// monotone in batch at fixed lengths (absorb family below, exactly
/// one flip to the naive family above), never picks a naive-shared
/// reader for a group without a shared prefix, and always returns an
/// applicable kernel.
#[test]
fn fuzz_n_way_registry_invariants() {
    let models = [deepseek_v3(), kimi_k2()];
    let backends = [Backend::Npu, Backend::Gpu, Backend::Cpu];
    for seed in 0..fuzz_iters(20) {
        let mut rng = Rng::new(0xD1CE_0000 + seed);
        let cfg = &models[rng.gen_range_usize(0, models.len())];
        let hw = backends[rng.gen_range_usize(0, backends.len())].preset();
        let p = KernelPolicy::n_way(
            KernelKind::Typhoon,
            cfg,
            &hw,
            1,
            &ParallelismConfig::single(),
        );
        let shared = rng.gen_range_usize(1, 32768);
        let mns = rng.gen_range_usize(0, 4096);
        let mut flipped = false;
        for batch in 1..512usize {
            let pick = p.select_group(batch, shared, mns);
            if pick.reads_shared_naive() {
                flipped = true;
            } else {
                assert!(
                    !flipped,
                    "absorb-family pick after the naive flip: b={batch} on {}/{}",
                    cfg.name, hw.name
                );
            }
            // Zero shared prefix predicates the naive readers out.
            assert!(
                p.select_group(batch, 0, mns).is_absorb_family(),
                "naive reader without a shared prefix (b={batch})"
            );
        }
    }
}
