//! Randomized property tests over the coordination substrate
//! (self-rolled: the proptest crate is unavailable offline — each test
//! runs many seeded random scenarios and asserts invariants).

use typhoon_mla::config::model::sim;
use typhoon_mla::config::{KernelKind, ServingConfig};
use typhoon_mla::coordinator::engine::NullEngine;
use typhoon_mla::coordinator::{Coordinator, KernelPolicy};
use typhoon_mla::kvcache::{
    spans_from_pages, spans_from_per_token, BlockAllocator, BlockId, KvCacheManager, RadixTree,
};
use typhoon_mla::util::rng::Rng;
use typhoon_mla::workload::Request;

/// Allocator fuzz: random allocate/retain/release sequences never leak
/// or double-count; free+held == total at every step.
#[test]
fn allocator_conservation_fuzz() {
    for seed in 0..20 {
        let mut rng = Rng::new(seed);
        let total = 64;
        let mut alloc = BlockAllocator::new(total, 16);
        let mut held: Vec<(u32, u32)> = Vec::new(); // (block, refcount)
        for _ in 0..2000 {
            match rng.gen_range(0, 3) {
                0 => {
                    if let Ok(b) = alloc.allocate() {
                        held.push((b, 1));
                    } else {
                        assert_eq!(alloc.free_blocks(), 0, "spurious exhaustion");
                    }
                }
                1 => {
                    if !held.is_empty() {
                        let i = rng.gen_range_usize(0, held.len());
                        alloc.retain(held[i].0);
                        held[i].1 += 1;
                    }
                }
                _ => {
                    if !held.is_empty() {
                        let i = rng.gen_range_usize(0, held.len());
                        alloc.release(held[i].0);
                        held[i].1 -= 1;
                        if held[i].1 == 0 {
                            held.swap_remove(i);
                        }
                    }
                }
            }
            let distinct_held = held.len();
            assert_eq!(
                alloc.free_blocks() + distinct_held,
                total,
                "conservation violated (seed {seed})"
            );
            for &(b, rc) in &held {
                assert_eq!(alloc.refcount(b), rc);
            }
        }
    }
}

/// Radix fuzz: longest-prefix match equals the brute-force oracle over
/// everything inserted, and the page spans always cover the match.
#[test]
fn radix_matches_oracle_fuzz() {
    for seed in 0..10 {
        let mut rng = Rng::new(100 + seed);
        let mut tree = RadixTree::new();
        let mut corpus: Vec<Vec<u32>> = Vec::new();
        let mut per_token: Vec<Vec<BlockId>> = Vec::new();
        let mut marked: Vec<Vec<u32>> = Vec::new();
        for i in 0..80u32 {
            let (mut s, mut blocks) = if corpus.is_empty() || rng.next_f64() < 0.25 {
                (Vec::new(), Vec::new())
            } else {
                let k = rng.gen_range_usize(0, corpus.len());
                let cut = rng.gen_range_usize(0, corpus[k].len() + 1);
                (corpus[k][..cut].to_vec(), per_token[k][..cut].to_vec())
            };
            for _ in 0..rng.gen_range_usize(1, 8) {
                s.push(rng.gen_range(0, 4) as u32); // tiny alphabet: max overlap
            }
            blocks.extend((blocks.len()..s.len()).map(|j| i * 1000 + j as u32));
            tree.insert(&s, &spans_from_per_token(&blocks));
            if rng.next_f64() < 0.3 {
                tree.mark_expanded(&s);
                marked.push(s.clone());
            }
            corpus.push(s);
            per_token.push(blocks);

            // Oracle check over random probes.
            for _ in 0..5 {
                let probe: Vec<u32> =
                    (0..rng.gen_range_usize(1, 12)).map(|_| rng.gen_range(0, 4) as u32).collect();
                let m = tree.match_prefix(&probe);
                let oracle = corpus
                    .iter()
                    .map(|s| s.iter().zip(&probe).take_while(|(a, b)| a == b).count())
                    .max()
                    .unwrap_or(0);
                assert_eq!(m.matched, oracle, "seed {seed} probe {probe:?}");
                assert_eq!(
                    m.spans.iter().map(|sp| sp.tokens as usize).sum::<usize>(),
                    m.matched,
                    "seed {seed}: spans must cover the match"
                );
                // Expanded-prefix oracle: marking a string marks every
                // edge on its root path, so the longest expanded prefix
                // of any probe is its max LCP with a marked string.
                let expanded_oracle = marked
                    .iter()
                    .map(|s| s.iter().zip(&probe).take_while(|(a, b)| a == b).count())
                    .max()
                    .unwrap_or(0);
                assert_eq!(
                    m.expanded_len, expanded_oracle,
                    "seed {seed} probe {probe:?}"
                );
            }
        }
        // Every corpus entry's page list equals the per-token dedup —
        // the page-granular representation is exact.
        for (s, blocks) in corpus.iter().zip(&per_token) {
            let m = tree.match_prefix(s);
            assert_eq!(m.matched, s.len());
            let mut expect: Vec<BlockId> = Vec::new();
            for &b in blocks.iter() {
                if expect.last() != Some(&b) {
                    expect.push(b);
                }
            }
            assert_eq!(m.page_list(), expect, "seed {seed}");
        }
    }
}

/// Page-granular equivalence: a tree fed block-aligned page spans must
/// report byte-identical `matched`, `expanded_len` and `page_list()` to
/// a tree fed the exploded per-token representation of the same pages,
/// across randomized insert orders, splits and mid-edge matches.
#[test]
fn radix_chunked_equals_per_token_semantics() {
    for seed in 0..8 {
        let mut rng = Rng::new(7000 + seed);
        let bs = [1usize, 2, 4, 16][rng.gen_range_usize(0, 4)];
        let mut chunked = RadixTree::new();
        let mut exploded = RadixTree::new();
        let mut corpus: Vec<Vec<u32>> = Vec::new();
        let mut next_page: BlockId = 0;
        for _ in 0..60 {
            // Extend a block-aligned prefix of an existing entry (the
            // manager's reuse discipline) or start fresh.
            let (mut s, mut pages) = if corpus.is_empty() || rng.next_f64() < 0.3 {
                (Vec::new(), Vec::new())
            } else {
                let k = rng.gen_range_usize(0, corpus.len());
                let keep_chunks = rng.gen_range_usize(0, corpus[k].len() / bs + 1);
                let keep = keep_chunks * bs;
                let m = chunked.match_prefix(&corpus[k][..keep]);
                assert_eq!(m.matched, keep);
                (corpus[k][..keep].to_vec(), m.page_list())
            };
            for _ in 0..rng.gen_range_usize(1, 3 * bs + 2) {
                s.push(rng.gen_range(0, 4) as u32);
            }
            while pages.len() < s.len().div_ceil(bs) {
                pages.push(1000 + next_page);
                next_page += 1;
            }
            let spans = spans_from_pages(&pages, s.len(), bs);
            chunked.insert(&s, &spans);
            let per_token: Vec<BlockId> = (0..s.len()).map(|i| pages[i / bs]).collect();
            exploded.insert(&s, &spans_from_per_token(&per_token));
            if rng.next_f64() < 0.3 {
                chunked.mark_expanded(&s);
                exploded.mark_expanded(&s);
            }
            corpus.push(s);

            // Probes: corpus entries, prefixes, and random strings.
            for _ in 0..6 {
                let probe: Vec<u32> = match rng.gen_range_usize(0, 3) {
                    0 => rng.choose(&corpus).clone(),
                    1 => {
                        let c = rng.choose(&corpus);
                        c[..rng.gen_range_usize(0, c.len() + 1)].to_vec()
                    }
                    _ => (0..rng.gen_range_usize(1, 3 * bs + 2))
                        .map(|_| rng.gen_range(0, 4) as u32)
                        .collect(),
                };
                let a = chunked.match_prefix(&probe);
                let b = exploded.match_prefix(&probe);
                assert_eq!(a.matched, b.matched, "seed {seed} bs {bs}");
                assert_eq!(a.expanded_len, b.expanded_len, "seed {seed} bs {bs}");
                assert_eq!(a.page_list(), b.page_list(), "seed {seed} bs {bs}");
            }
        }
    }
}

/// Scheduler fuzz: random workloads; invariants — every request
/// completes exactly once, token counts conserve, batch never exceeds
/// max, KV pages return to baseline, and the clock never goes backward.
#[test]
fn scheduler_invariants_fuzz() {
    for seed in 0..15 {
        let mut rng = Rng::new(1000 + seed);
        let max_batch = rng.gen_range_usize(1, 9);
        let block_size = 16;
        let total_blocks = rng.gen_range_usize(max_batch.max(4), 64);
        let cfg = ServingConfig {
            block_size,
            max_batch,
            max_seq_len: 128,
            total_blocks,
            ..Default::default()
        };
        let policy =
            KernelPolicy::with_threshold(KernelKind::Typhoon, rng.gen_range_usize(1, 6));
        let kv = KvCacheManager::new(sim(), total_blocks, block_size);
        let mut c = match Coordinator::new(cfg, policy, kv, NullEngine::default()) {
            Ok(c) => c,
            Err(_) => continue, // invalid random config (validated away)
        };
        let prefix_len = rng.gen_range_usize(1, 3) * block_size;
        if c.set_shared_prefix(&(0..prefix_len as u32).collect::<Vec<_>>()).is_err() {
            continue;
        }
        let baseline_blocks = c.kv.used_blocks();

        let n_reqs = rng.gen_range_usize(1, 40);
        let mut total_budget = 0usize;
        for i in 0..n_reqs {
            // Keep prompts admissible within the random pool.
            let prompt = rng.gen_range_usize(1, block_size * 2);
            let gen = rng.gen_range_usize(1, 20);
            total_budget += gen.min(128 - prompt);
            c.submit(&Request {
                id: i as u64,
                prompt_tokens: prompt,
                max_new_tokens: gen,
            })
            .unwrap();
        }
        let mut last_now = c.now();
        let mut guard = 0;
        loop {
            match c.step() {
                Ok(true) => {}
                Ok(false) => break,
                Err(e) => panic!("seed {seed}: step failed: {e}"),
            }
            assert!(c.now() >= last_now, "clock went backward");
            last_now = c.now();
            assert!(c.running() <= max_batch);
            guard += 1;
            assert!(guard < 100_000, "seed {seed}: no progress");
        }
        assert_eq!(c.metrics.requests_completed as usize, n_reqs, "seed {seed}");
        assert_eq!(
            c.metrics.tokens_generated as usize, total_budget,
            "seed {seed}: token conservation"
        );
        assert_eq!(
            c.kv.used_blocks(),
            baseline_blocks,
            "seed {seed}: leaked KV pages"
        );
    }
}

/// Prefix-page safety under pressure: preemption/eviction storms must
/// never release a registered prefix's pages while any sequence of its
/// group is queued or running — across 2+ prefix groups sharing a pool
/// barely larger than the prefixes themselves.
#[test]
fn prefix_pages_survive_eviction_storms() {
    use std::collections::HashMap;
    use typhoon_mla::kvcache::PrefixId;

    for seed in 0..12 {
        let mut rng = Rng::new(9000 + seed);
        let block_size = 16;
        let n_groups = 2 + (seed as usize % 2);
        let prefix_pages: Vec<usize> =
            (0..n_groups).map(|_| rng.gen_range_usize(1, 3)).collect();
        let total_prefix_pages: usize = prefix_pages.iter().sum();
        // Pool barely larger than the prefixes: constant eviction churn.
        let total_blocks = total_prefix_pages + rng.gen_range_usize(2, 5);
        let max_batch = rng.gen_range_usize(2, 5).min(total_blocks);
        let cfg = ServingConfig {
            block_size,
            max_batch,
            max_seq_len: 64,
            total_blocks,
            ..Default::default()
        };
        let policy = KernelPolicy::with_threshold(KernelKind::Typhoon, 2);
        let kv = KvCacheManager::new(sim(), total_blocks, block_size);
        let mut c = Coordinator::new(cfg, policy, kv, NullEngine::default()).unwrap();

        let mut prefixes: Vec<PrefixId> = Vec::new();
        let mut expected_blocks = Vec::new();
        for (g, &pages) in prefix_pages.iter().enumerate() {
            // Disjoint token ranges: no page sharing between groups.
            let lo = (g * 10_000) as u32;
            let tokens: Vec<u32> = (lo..lo + (pages * block_size) as u32).collect();
            let id = c.register_prefix_group(&tokens).unwrap();
            expected_blocks.push(c.kv.prefix(id).unwrap().latent_blocks.clone());
            prefixes.push(id);
        }

        let mut group_of: HashMap<u64, PrefixId> = HashMap::new();
        let mut outstanding: HashMap<PrefixId, usize> =
            prefixes.iter().map(|&p| (p, 0)).collect();
        let n_reqs = rng.gen_range_usize(4, 20);
        for i in 0..n_reqs {
            let g = rng.gen_range_usize(0, n_groups);
            let sid = c
                .submit_to(
                    &Request {
                        id: i as u64,
                        prompt_tokens: rng.gen_range_usize(1, block_size),
                        max_new_tokens: rng.gen_range_usize(1, 30),
                    },
                    prefixes[g],
                )
                .unwrap();
            group_of.insert(sid, prefixes[g]);
            *outstanding.get_mut(&prefixes[g]).unwrap() += 1;
        }

        let mut guard = 0;
        loop {
            let more = c.step().unwrap();
            for fin in c.take_finished() {
                *outstanding.get_mut(&group_of[&fin]).unwrap() -= 1;
            }
            for (i, &p) in prefixes.iter().enumerate() {
                let sp = c.kv.prefix(p).expect("prefix stays registered");
                assert_eq!(
                    sp.latent_blocks, expected_blocks[i],
                    "seed {seed}: prefix pages must never be swapped out"
                );
                if outstanding[&p] > 0 {
                    assert!(
                        c.kv.release_shared_prefix(p).is_err(),
                        "seed {seed}: release must refuse while group {p} is live"
                    );
                    assert!(
                        c.kv.prefix(p).is_some(),
                        "seed {seed}: failed release must not unregister"
                    );
                }
            }
            assert!(
                c.kv.used_blocks() >= total_prefix_pages,
                "seed {seed}: prefix pages freed under pressure"
            );
            if !more {
                break;
            }
            guard += 1;
            assert!(guard < 100_000, "seed {seed}: no progress");
        }
        assert!(outstanding.values().all(|&n| n == 0), "seed {seed}: {outstanding:?}");
        for &p in &prefixes {
            c.kv.release_shared_prefix(p).unwrap();
        }
        assert_eq!(c.kv.used_blocks(), 0, "seed {seed}: all pages returned");
    }
}

/// Failure injection: engines that error must surface errors, not hang
/// or corrupt state.
#[test]
fn failing_engine_surfaces_errors() {
    use anyhow::{bail, Result};
    use typhoon_mla::coordinator::{DecodeBatch, Engine, IterationOutcome, PrefillRequest};
    use typhoon_mla::kvcache::{PrefixId, SeqId};

    struct FailAfter {
        n: usize,
    }
    impl Engine for FailAfter {
        fn prepare_shared(&mut self, _: PrefixId, _: &[u32], _: KernelKind) -> Result<f64> {
            Ok(0.0)
        }
        fn prefill_requests(&mut self, _: &[PrefillRequest]) -> Result<f64> {
            Ok(0.0)
        }
        fn decode(&mut self, _: &DecodeBatch) -> Result<IterationOutcome> {
            if self.n == 0 {
                bail!("injected engine failure");
            }
            self.n -= 1;
            Ok(IterationOutcome::default())
        }
        fn release(&mut self, _: SeqId) {}
    }

    let cfg = ServingConfig {
        block_size: 16,
        max_batch: 2,
        max_seq_len: 64,
        total_blocks: 64,
        ..Default::default()
    };
    let policy = KernelPolicy::with_threshold(KernelKind::Absorb, 1);
    let kv = KvCacheManager::new(sim(), 64, 16);
    let mut c = Coordinator::new(cfg, policy, kv, FailAfter { n: 3 }).unwrap();
    c.set_shared_prefix(&[1, 2, 3]).unwrap();
    c.submit(&Request { id: 0, prompt_tokens: 4, max_new_tokens: 10 }).unwrap();
    let err = c.run_to_completion().unwrap_err();
    assert!(err.to_string().contains("injected"), "{err}");
}

/// Failure injection: corrupt manifest and missing artifacts produce
/// errors, not panics.
#[test]
fn runtime_failure_injection() {
    use typhoon_mla::runtime::Manifest;

    // Corrupt JSON.
    assert!(Manifest::parse("{not json", "/tmp".into()).is_err());
    // Valid JSON, missing keys.
    assert!(Manifest::parse(r#"{"artifacts": [{"name": "x"}]}"#, "/tmp".into()).is_err());
    // Missing directory.
    assert!(Manifest::load("/nonexistent/path").is_err());
}

/// Oversized request: budget clamped to max_seq_len, no overflow.
#[test]
fn oversized_requests_clamped() {
    let cfg = ServingConfig {
        block_size: 16,
        max_batch: 2,
        max_seq_len: 64,
        total_blocks: 128,
        ..Default::default()
    };
    let policy = KernelPolicy::with_threshold(KernelKind::Absorb, 1);
    let kv = KvCacheManager::new(sim(), 128, 16);
    let mut c = Coordinator::new(cfg, policy, kv, NullEngine::default()).unwrap();
    c.set_shared_prefix(&[1, 2, 3, 4]).unwrap();
    c.submit(&Request { id: 0, prompt_tokens: 10_000, max_new_tokens: usize::MAX }).unwrap();
    c.run_to_completion().unwrap();
    assert_eq!(c.metrics.requests_completed, 1);
    assert!(c.metrics.tokens_generated <= 64);
}
