//! PR 9 regressions: the dense interned pricing memo, the fleet-shared
//! price surface, and the persistent worker pool (DESIGN.md §17).
//!
//! * **dense-vs-hash bit-identity** — across random model / sharding /
//!   backend draws and randomized call sequences (repeated keys for
//!   hits, coordinates past the dense axis cap for the spill path),
//!   `CostTable::cost` and `PriceTable::time` return bit-identical
//!   values with the dense memo and the retained `HashMap` reference
//!   (`use_hash_reference`), and the hit/miss counter traces agree
//!   call-for-call;
//! * **shared-surface identity** — two cluster cells pricing
//!   concurrently through one `Arc<PriceSurface>` (the sweep's
//!   cross-cell sharing) report bit-identically to private-surface
//!   baselines, and the shared surface records warm hits;
//! * **pool determinism** — across random cluster draws, the serial
//!   event loop, the pooled parallel dispatch, and the retained
//!   spawn-per-window reference (`use_spawn_reference`) produce
//!   byte-identical reports; only the pooled run touches the pool.
//!
//! The scheduled CI long-fuzz job scales the iteration counts via
//! `TYPHOON_FUZZ_ITERS` (`--test pricing_pool fuzz`); assertion
//! messages embed the failing seed so a red run replays as a one-seed
//! unit test.

use std::sync::Arc;

use typhoon_mla::config::hardware::{ascend_npu, gpu_h800, gpu_h800_decode, host_cpu};
use typhoon_mla::config::model::{deepseek_v3, kimi_k2};
use typhoon_mla::config::KernelKind;
use typhoon_mla::costmodel::{CostTable, ParallelismConfig, PriceSurface, PriceTable};
use typhoon_mla::simulator::{ClusterParams, ClusterReport, ClusterSim, RouterPolicy};
use typhoon_mla::util::rng::Rng;

/// Iteration budget for a fuzz loop: `base` in tier-1, `base x
/// TYPHOON_FUZZ_ITERS` in the scheduled CI long-fuzz job (unset or
/// unparsable falls back to the tier-1 budget).
fn fuzz_iters(base: u64) -> u64 {
    std::env::var("TYPHOON_FUZZ_ITERS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .map_or(base, |m| base * m.max(1))
}

/// One random memo coordinate.  Half the draws revisit an
/// already-priced key (exercising the hit path on both memos); fresh
/// draws mix small coordinates with lengths past the dense axis cap
/// (`1 << 16`), so the sorted spill-list path is fuzzed too.
fn draw_key(
    rng: &mut Rng,
    seen: &mut Vec<(KernelKind, u64, u64, u64)>,
) -> (KernelKind, u64, u64, u64) {
    if !seen.is_empty() && rng.gen_range(0, 2) == 0 {
        return *rng.choose(seen);
    }
    let kernel = *rng.choose(&KernelKind::all());
    let batch = rng.gen_range(1, 2048);
    let l_s = match rng.gen_range(0, 4) {
        0 => rng.gen_range(0, 512),
        1 => rng.gen_range(0, 32768),
        // Past DENSE_AXIS_CAP: lands in the AxisMap spill list.
        _ => rng.gen_range(1 << 16, 1 << 18),
    };
    let l_n = match rng.gen_range(0, 3) {
        0 => 0,
        1 => rng.gen_range(1, 4096),
        _ => rng.gen_range(1 << 16, (1 << 16) + 4096),
    };
    let key = (kernel, batch, l_s, l_n);
    seen.push(key);
    key
}

/// `CostTable` with the dense memo (default) returns the same
/// `CostBreakdown` — and the same hit/miss trace — as the retained
/// `HashMap` reference across randomized models, sharding, and call
/// sequences.
#[test]
fn cost_table_dense_matches_hash_reference_fuzz() {
    for seed in 0..fuzz_iters(12) {
        let mut rng = Rng::new(0x9A11_0000 + seed);
        let cfg = rng.choose(&[deepseek_v3(), kimi_k2()]).clone();
        let par = ParallelismConfig {
            tp: 1u64 << rng.gen_range(0, 4),
            sp: 1u64 << rng.gen_range(0, 3),
        };
        let mut dense = CostTable::with_parallelism(cfg.clone(), par);
        let mut hash = CostTable::with_parallelism(cfg.clone(), par);
        hash.use_hash_reference = true;

        let mut seen = Vec::new();
        for call in 0..160 {
            let (kernel, b, ls, ln) = draw_key(&mut rng, &mut seen);
            let d = dense.cost(kernel, b, ls, ln);
            let h = hash.cost(kernel, b, ls, ln);
            assert_eq!(
                d,
                h,
                "seed {seed} call {call}: dense vs hash cost diverged on \
                 ({kernel:?}, {b}, {ls}, {ln}) for {} tp={} sp={}",
                cfg.name,
                par.tp,
                par.sp
            );
            assert_eq!(
                (dense.hits, dense.misses),
                (hash.hits, hash.misses),
                "seed {seed} call {call}: counter traces diverged"
            );
        }
        assert!(dense.hits > 0, "seed {seed}: repeated keys must hit");
        assert!(dense.misses > 0, "seed {seed}: fresh keys must miss");
        assert_eq!(dense.len(), hash.len(), "seed {seed}: memo sizes diverged");
    }
}

/// `PriceTable` with the dense memo returns bit-identical roofline
/// seconds — and the same hit/miss trace — as the `HashMap` reference
/// across randomized backends (up to all four hardware presets
/// registered) and call sequences.
#[test]
fn price_table_dense_matches_hash_reference_fuzz() {
    let presets = [ascend_npu(), gpu_h800(), gpu_h800_decode(), host_cpu()];
    for seed in 0..fuzz_iters(12) {
        let mut rng = Rng::new(0x9A12_0000 + seed);
        let cfg = rng.choose(&[deepseek_v3(), kimi_k2()]).clone();
        let par = ParallelismConfig {
            tp: 1u64 << rng.gen_range(0, 4),
            sp: 1u64 << rng.gen_range(0, 3),
        };
        let mut dense = PriceTable::new(cfg.clone(), par);
        let mut hash = PriceTable::new(cfg.clone(), par);
        hash.use_hash_reference = true;
        let n_backends = rng.gen_range_usize(1, presets.len() + 1);
        for hw in presets.iter().take(n_backends) {
            let a = dense.register_backend(hw.clone());
            let b = hash.register_backend(hw.clone());
            assert_eq!(a, b, "seed {seed}: backend ids must agree");
        }

        let mut seen = Vec::new();
        for call in 0..160 {
            let (kernel, b, ls, ln) = draw_key(&mut rng, &mut seen);
            let backend = rng.gen_range_usize(0, n_backends);
            let d = dense.time(kernel, backend, b, ls, ln);
            let h = hash.time(kernel, backend, b, ls, ln);
            assert_eq!(
                d.to_bits(),
                h.to_bits(),
                "seed {seed} call {call}: dense vs hash time diverged on \
                 ({kernel:?}, backend {backend}, {b}, {ls}, {ln}) for {} tp={} sp={}",
                cfg.name,
                par.tp,
                par.sp
            );
            assert_eq!(
                (dense.hits, dense.misses),
                (hash.hits, hash.misses),
                "seed {seed} call {call}: counter traces diverged"
            );
        }
        assert!(dense.hits > 0, "seed {seed}: repeated keys must hit");
        assert!(dense.misses > 0, "seed {seed}: fresh keys must miss");
    }
}

/// Assert two cluster reports are byte-identical on every audited
/// aggregate (floats compared by bit pattern).
fn assert_reports_identical(a: &ClusterReport, b: &ClusterReport, ctx: &str) {
    assert_eq!(a.tokens, b.tokens, "{ctx}: tokens");
    assert_eq!(a.requests_completed, b.requests_completed, "{ctx}: completed");
    assert_eq!(a.decode_seconds.to_bits(), b.decode_seconds.to_bits(), "{ctx}: decode");
    assert_eq!(a.goodput.to_bits(), b.goodput.to_bits(), "{ctx}: goodput");
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{ctx}: makespan");
    assert_eq!(a.ttft_p50.to_bits(), b.ttft_p50.to_bits(), "{ctx}: ttft_p50");
    assert_eq!(a.ttft_p95.to_bits(), b.ttft_p95.to_bits(), "{ctx}: ttft_p95");
    assert_eq!(a.ttft_p99.to_bits(), b.ttft_p99.to_bits(), "{ctx}: ttft_p99");
    assert_eq!(a.tpot_p50.to_bits(), b.tpot_p50.to_bits(), "{ctx}: tpot_p50");
    assert_eq!(a.tpot_p99.to_bits(), b.tpot_p99.to_bits(), "{ctx}: tpot_p99");
    assert_eq!(a.spills, b.spills, "{ctx}: spills");
    assert_eq!(a.migrations, b.migrations, "{ctx}: migrations");
    assert_eq!(a.transfer_seconds.to_bits(), b.transfer_seconds.to_bits(), "{ctx}: transfer");
    assert_eq!(a.scale_ups, b.scale_ups, "{ctx}: scale_ups");
    assert_eq!(a.scale_downs, b.scale_downs, "{ctx}: scale_downs");
    assert_eq!(a.active_replicas, b.active_replicas, "{ctx}: active_replicas");
}

/// The sweep's cross-cell sharing: two cluster cells adopting ONE
/// `Arc<PriceSurface>` via `ClusterParams::surface` and running
/// **concurrently** (each on its own thread, both dispatching decode
/// windows to the global pool) report bit-identically to
/// private-surface serial baselines — and the shared surface ends warm
/// (hits recorded, so the replicas really priced through it).
#[test]
fn shared_surface_concurrent_cells_bit_identical() {
    let mut cells = Vec::new();
    for (seed, skew) in [(11u64, 0.0f64), (29, 1.1)] {
        let mut p = ClusterParams::new(
            deepseek_v3(),
            ascend_npu(),
            2,
            RouterPolicy::PrefixAffinity,
            16,
            3,
            skew,
        );
        p.total_requests = 96;
        p.seed = seed;
        p.arrival_rate = Some(50.0);
        cells.push(p);
    }

    // Baselines: private surfaces (surface = None), serial event loop.
    let mut baselines = Vec::new();
    for p in &cells {
        let mut sim = ClusterSim::new(p).unwrap();
        sim.run().unwrap();
        baselines.push(sim.report());
    }

    // Shared: one warm surface adopted by both cells, run concurrently.
    let surface = PriceSurface::shared(deepseek_v3(), ascend_npu(), ParallelismConfig::single());
    let mut handles = Vec::new();
    for p in &cells {
        let mut p = p.clone();
        p.surface = Some(Arc::clone(&surface));
        handles.push(std::thread::spawn(move || {
            let mut sim = ClusterSim::new(&p).unwrap();
            sim.run_parallel().unwrap();
            sim.report()
        }));
    }
    let shared: Vec<ClusterReport> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    for (i, (base, shr)) in baselines.iter().zip(&shared).enumerate() {
        assert_reports_identical(base, shr, &format!("cell {i}"));
    }
    let (hits, misses) = surface.stats();
    assert!(misses > 0, "the cells must have priced something");
    assert!(hits > 0, "two cells on one surface must record warm hits");
}

/// Pool determinism: across random cluster draws, the serial event
/// loop (`run`), the pooled parallel dispatch (`run_parallel`), and
/// the retained spawn-per-window reference produce byte-identical
/// reports and event totals.  Only the pooled run touches the pool.
#[test]
fn pooled_dispatch_matches_spawn_and_serial_fuzz() {
    for seed in 0..fuzz_iters(4) {
        let mut rng = Rng::new(0x9A13_0000 + seed);
        let model = rng.choose(&[deepseek_v3(), kimi_k2()]).clone();
        let hw = rng.choose(&[ascend_npu(), gpu_h800()]).clone();
        let replicas = rng.gen_range_usize(1, 4);
        let router = *rng.choose(&[RouterPolicy::RoundRobin, RouterPolicy::PrefixAffinity]);
        let batch = *rng.choose(&[8usize, 16, 32]);
        let tenants = rng.gen_range_usize(1, 5);
        let skew = *rng.choose(&[0.0f64, 0.7, 1.2]);
        let mut p = ClusterParams::new(model, hw, replicas, router, batch, tenants, skew);
        p.seed = rng.next_u64();
        p.total_requests = rng.gen_range_usize(48, 160);
        if rng.gen_range(0, 2) == 0 {
            p.arrival_rate = Some(*rng.choose(&[40.0f64, 90.0]));
            if rng.gen_range(0, 2) == 0 {
                p.arrival_burst = Some(4.0);
            }
        }
        if p.router == RouterPolicy::PrefixAffinity {
            p.migrate = rng.gen_range(0, 2) == 0;
            if rng.gen_range(0, 3) == 0 {
                p.scaling.enabled = true;
                p.scaling.cooldown_arrivals = 24;
            }
        }

        let mut serial = ClusterSim::new(&p).unwrap();
        serial.run().unwrap();
        let mut pooled = ClusterSim::new(&p).unwrap();
        pooled.run_parallel().unwrap();
        let mut spawned = ClusterSim::new(&p).unwrap();
        spawned.use_spawn_reference(true);
        spawned.run_parallel().unwrap();

        let (rs, rp, rr) = (serial.report(), pooled.report(), spawned.report());
        assert_reports_identical(&rs, &rp, &format!("seed {seed}: serial vs pooled"));
        assert_reports_identical(&rp, &rr, &format!("seed {seed}: pooled vs spawn-ref"));
        assert_eq!(pooled.events_processed(), spawned.events_processed(), "seed {seed}: events");
        assert_eq!(pooled.arena_peak(), spawned.arena_peak(), "seed {seed}: arena peaks");
        assert!(pooled.pool_windows() > 0, "seed {seed}: pooled run must use the pool");
        assert_eq!(serial.pool_windows(), 0, "seed {seed}: serial loop never pools");
        assert_eq!(spawned.pool_windows(), 0, "seed {seed}: spawn reference never pools");
    }
}
