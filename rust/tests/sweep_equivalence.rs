//! Regression: the parallel sweep executor must produce *byte-identical*
//! figure artifacts to the serial path (deterministic per-cell seeds +
//! ordered result collection), and the memoized/bucketed cost engine
//! must leave simulation results exactly unchanged.

use typhoon_mla::analysis::figures::{fig_throughput, format_cluster};
use typhoon_mla::config::hardware::ascend_npu;
use typhoon_mla::config::model::deepseek_v3;
use typhoon_mla::config::KernelKind;
use typhoon_mla::simulator::sweep::{
    cluster_cells, run_cluster_sweep, run_throughput_sweep, throughput_cells, SweepExecutor,
};
use typhoon_mla::simulator::{run_experiment, SimParams};
use typhoon_mla::workload::datasets::mmlu;
use typhoon_mla::workload::prompts::PROMPT_C;

/// Serial and parallel fig2 slices are byte-identical, text and CSV.
#[test]
fn parallel_and_serial_fig_artifacts_identical() {
    let hw = ascend_npu();
    let serial =
        fig_throughput("fig2", &hw, &[64], Some(2), &SweepExecutor::serial()).unwrap();
    let parallel =
        fig_throughput("fig2", &hw, &[64], Some(2), &SweepExecutor::with_threads(4))
            .unwrap();
    assert_eq!(serial.text, parallel.text, "text artifact must not drift");
    assert_eq!(serial.csv, parallel.csv, "csv artifact must not drift");
    assert!(serial.csv.lines().count() > 10);
}

/// Per-cell reports are bitwise equal across executors, across
/// repeated runs (seeded determinism, no shared state), and across the
/// memoized vs per-sequence-reference engine paths.
#[test]
fn sweep_reports_bitwise_stable() {
    let hw = ascend_npu();
    let cells = throughput_cells(&[deepseek_v3()], &[64], Some(2));
    let cells = &cells[..4];
    let mut reference_cells = cells.to_vec();
    for c in &mut reference_cells {
        c.memoized = false;
    }
    let a = run_throughput_sweep(&hw, cells, &SweepExecutor::serial()).unwrap();
    let b = run_throughput_sweep(&hw, cells, &SweepExecutor::with_threads(3)).unwrap();
    let c = run_throughput_sweep(&hw, cells, &SweepExecutor::with_threads(3)).unwrap();
    let r = run_throughput_sweep(&hw, &reference_cells, &SweepExecutor::serial()).unwrap();
    for (((x, y), z), w) in a.iter().zip(&b).zip(&c).zip(&r) {
        for k in 0..3 {
            assert_eq!(x.reports[k].tokens, y.reports[k].tokens);
            assert_eq!(x.reports[k].iterations, y.reports[k].iterations);
            assert_eq!(x.reports[k].throughput.to_bits(), y.reports[k].throughput.to_bits());
            assert_eq!(y.reports[k].throughput.to_bits(), z.reports[k].throughput.to_bits());
            assert_eq!(
                x.reports[k].decode_seconds.to_bits(),
                y.reports[k].decode_seconds.to_bits()
            );
            // Unmemoized reference engine: identical to the last bit.
            assert_eq!(x.reports[k].tokens, w.reports[k].tokens);
            assert_eq!(x.reports[k].throughput.to_bits(), w.reports[k].throughput.to_bits());
            assert_eq!(
                x.reports[k].decode_seconds.to_bits(),
                w.reports[k].decode_seconds.to_bits()
            );
        }
    }
}

/// The cluster (replicas x skew x arrival-profile x router-config)
/// grid under `SweepExecutor`: serial and parallel runs must produce
/// byte-identical artifacts (text and CSV), the same discipline as the
/// figure grids — including the bursty autoscale cells, whose scale
/// decisions are pure functions of the modeled state.
#[test]
fn cluster_artifacts_serial_parallel_identical() {
    let hw = ascend_npu();
    let cells = cluster_cells(
        &deepseek_v3(),
        &[1, 2],
        &[0.0, 2.0],
        &[None, Some((150.0, 40.0))],
        3,
        32,
        64,
    );
    let serial = run_cluster_sweep(&hw, &cells, &SweepExecutor::serial()).unwrap();
    let par = run_cluster_sweep(&hw, &cells, &SweepExecutor::with_threads(4)).unwrap();
    let a = format_cluster(&serial);
    let b = format_cluster(&par);
    assert_eq!(a.text, b.text, "text artifact must not drift");
    assert_eq!(a.csv, b.csv, "csv artifact must not drift");
    assert_eq!(
        a.csv.lines().count(),
        9,
        "header + 8 (replicas x skew x profile) rows"
    );
}

/// The same experiment run twice in-process gives bitwise-equal output
/// (the memoized cost table may be cold or warm — results must not
/// depend on cache state).
#[test]
fn repeated_experiments_bitwise_equal() {
    let mut p = SimParams::new(deepseek_v3(), ascend_npu(), KernelKind::Typhoon, 32);
    p.max_requests = Some(64);
    let a = run_experiment(&p, &mmlu(), &PROMPT_C).unwrap();
    let b = run_experiment(&p, &mmlu(), &PROMPT_C).unwrap();
    assert_eq!(a.tokens, b.tokens);
    assert_eq!(a.iterations, b.iterations);
    assert_eq!(a.throughput.to_bits(), b.throughput.to_bits());
    assert_eq!(a.decode_seconds.to_bits(), b.decode_seconds.to_bits());
}
