//! Unit tests for `scripts/check_bench_artifact.sh` — the CI gate that
//! fails while the tracked `BENCH_sweep.json` still carries the
//! no-toolchain placeholder marker.  Exercised through the script's
//! `CHECK_BENCH_TRACKED` test seam so no git checkout (or HEAD state)
//! is assumed.
#![cfg(unix)]

use std::path::PathBuf;
use std::process::Command;

fn script_path() -> PathBuf {
    // CARGO_MANIFEST_DIR = <repo>/rust; the script lives one level up.
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../scripts/check_bench_artifact.sh")
}

fn run_gate(measured: &std::path::Path, tracked: &std::path::Path) -> std::process::Output {
    Command::new("bash")
        .arg(script_path())
        .arg(measured)
        .env("CHECK_BENCH_TRACKED", tracked)
        .output()
        .expect("bash must be runnable")
}

#[test]
fn gate_fails_on_placeholder_and_passes_on_measured() {
    let dir = std::env::temp_dir().join(format!("bench_gate_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let placeholder = dir.join("tracked_placeholder.json");
    std::fs::write(
        &placeholder,
        "{\n  \"note\": \"placeholder\",\n  \"wall_seconds\": 0\n}\n",
    )
    .unwrap();
    let measured = dir.join("measured.json");
    std::fs::write(&measured, "{\n  \"wall_seconds\": 1.5,\n  \"cells\": 8\n}\n").unwrap();

    // Tracked copy still the placeholder: the gate must fail and point
    // at the marker.
    let out = run_gate(&measured, &placeholder);
    assert!(
        !out.status.success(),
        "placeholder must fail the gate: {}",
        String::from_utf8_lossy(&out.stdout)
    );
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("placeholder marker"), "stderr: {err}");
    // The measured artifact is echoed so it can be committed verbatim.
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("\"cells\": 8"), "stdout: {stdout}");

    // Tracked copy is measured data (no "note" key): the gate passes.
    let out = run_gate(&measured, &measured);
    assert!(
        out.status.success(),
        "measured tracked copy must pass: {}",
        String::from_utf8_lossy(&out.stderr)
    );

    // Missing measured artifact: fail fast with the bench_sweep hint.
    let out = run_gate(&dir.join("does_not_exist.json"), &measured);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("run bench_sweep first"), "stderr: {err}");

    std::fs::remove_dir_all(&dir).ok();
}
