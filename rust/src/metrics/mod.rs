//! Serving metrics: token/iteration counters, latency breakdowns, and
//! the throughput report the benches print.

use std::time::Instant;

use crate::util::stats::{human_time, Percentiles, Summary};

/// Wall-clock or simulated-clock duration source.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Clock {
    Wall,
    /// Simulated time is fed in explicitly via `record_iteration`.
    Simulated,
}

/// Per-component latency accumulators matching the paper's Fig. 4
/// breakdown categories.
#[derive(Clone, Debug, Default)]
pub struct BreakdownTimers {
    pub stage1_attn: f64,
    pub stage2_attn: f64,
    pub proj_kvb1: f64,
    pub proj_kvb2: f64,
    pub combine: f64,
    pub other: f64,
}

impl BreakdownTimers {
    pub fn total(&self) -> f64 {
        self.stage1_attn + self.stage2_attn + self.proj_kvb1 + self.proj_kvb2 + self.combine
            + self.other
    }

    pub fn add(&mut self, other: &BreakdownTimers) {
        self.stage1_attn += other.stage1_attn;
        self.stage2_attn += other.stage2_attn;
        self.proj_kvb1 += other.proj_kvb1;
        self.proj_kvb2 += other.proj_kvb2;
        self.combine += other.combine;
        self.other += other.other;
    }
}

/// Metrics for one serving run.
#[derive(Debug)]
pub struct Metrics {
    start: Instant,
    /// Simulated elapsed seconds (when clock == Simulated).
    sim_elapsed: f64,
    clock: Clock,
    pub tokens_generated: u64,
    pub requests_completed: u64,
    pub requests_admitted: u64,
    pub decode_iterations: u64,
    pub prefill_calls: u64,
    /// Sequences evicted for recompute under KV pressure.
    pub preemptions: u64,
    pub iteration_time: Summary,
    pub batch_occupancy: Summary,
    pub request_latency: Percentiles,
    /// Time-to-first-token per completed request (submission to the
    /// first generated token, modeled/wall seconds).
    pub ttft: Percentiles,
    /// Time-per-output-token per completed request (mean inter-token
    /// gap after the first token; recorded only for requests that
    /// generated at least two tokens).
    pub tpot: Percentiles,
    pub breakdown: BreakdownTimers,
    /// Exact accumulated decode seconds (sum of iteration times, no
    /// mean x count reconstruction — reports use this directly).
    pub decode_seconds: f64,
    /// Group-iterations executed with each kernel (typhoon fallback
    /// tracking; one count per prefix group per decode iteration, which
    /// reduces to one per iteration for single-prefix configs).
    pub typhoon_iters: u64,
    pub absorb_iters: u64,
    pub naive_iters: u64,
    /// Decode iterations whose groups selected more than one kernel
    /// (a hot group on Typhoon while a cold one fell back to absorb).
    pub mixed_iters: u64,
    /// Shared prefixes prefilled locally (`register_prefix_group`) —
    /// migration adoptions do NOT count, which is what the
    /// never-re-prefilled audit leans on.
    pub shared_prefills: u64,
    /// Prefix groups adopted from a peer replica without a prefill
    /// (cross-replica page migration).
    pub prefix_imports: u64,
    /// Modeled interconnect seconds spent receiving migrated pages
    /// (wall time on the replica clock, never decode time).
    pub transfer_seconds: f64,
    // ---- fault / recovery counters (DESIGN.md §14); all stay zero on
    // ---- the fault-free path.
    /// Transfer attempts lost or truncated in flight and retried with
    /// backoff (charged to the receiving replica's clock).
    pub transfer_retries: u64,
    /// Retried transfers that exhausted their attempt budget and fell
    /// back (the group stays home / is re-prefilled).
    pub transfers_abandoned: u64,
    /// Prefix groups this replica adopted as failover home for a dead
    /// peer.
    pub failovers: u64,
    /// Tokens re-prefilled because a crash destroyed the only page copy
    /// of a group (the cost-priced failover fallback).
    pub reprefilled_tokens: u64,
    /// KV pages destroyed by a crash on this replica.
    pub lost_pages: u64,
    /// Sequences re-queued off this replica when it failed (in-flight
    /// work is never silently dropped).
    pub requeued_requests: u64,
    /// Generated tokens thrown away by a crash (the re-queued request
    /// restarts from scratch on a survivor and redoes them).
    pub lost_tokens: u64,
    /// Injected stall events absorbed by this replica.
    pub stalls: u64,
}

impl Metrics {
    #[allow(clippy::disallowed_methods)]
    pub fn new(clock: Clock) -> Self {
        Metrics {
            // detlint: allow(wall-clock, Clock::Wall is the real-runtime bench
            // mode; every simulation report reads sim_elapsed, never this stamp)
            start: Instant::now(),
            sim_elapsed: 0.0,
            clock,
            tokens_generated: 0,
            requests_completed: 0,
            requests_admitted: 0,
            decode_iterations: 0,
            prefill_calls: 0,
            preemptions: 0,
            iteration_time: Summary::new(),
            batch_occupancy: Summary::new(),
            request_latency: Percentiles::default(),
            ttft: Percentiles::default(),
            tpot: Percentiles::default(),
            breakdown: BreakdownTimers::default(),
            decode_seconds: 0.0,
            typhoon_iters: 0,
            absorb_iters: 0,
            naive_iters: 0,
            mixed_iters: 0,
            shared_prefills: 0,
            prefix_imports: 0,
            transfer_seconds: 0.0,
            transfer_retries: 0,
            transfers_abandoned: 0,
            failovers: 0,
            reprefilled_tokens: 0,
            lost_pages: 0,
            requeued_requests: 0,
            lost_tokens: 0,
            stalls: 0,
        }
    }

    pub fn record_iteration(&mut self, seconds: f64, batch: usize, new_tokens: u64) {
        self.decode_iterations += 1;
        self.tokens_generated += new_tokens;
        self.iteration_time.push(seconds);
        self.batch_occupancy.push(batch as f64);
        self.decode_seconds += seconds;
        if self.clock == Clock::Simulated {
            self.sim_elapsed += seconds;
        }
    }

    pub fn advance_sim_time(&mut self, seconds: f64) {
        debug_assert_eq!(self.clock, Clock::Simulated);
        self.sim_elapsed += seconds;
    }

    pub fn elapsed(&self) -> f64 {
        match self.clock {
            Clock::Wall => self.start.elapsed().as_secs_f64(),
            Clock::Simulated => self.sim_elapsed,
        }
    }

    /// Tokens per second over the run (the paper's Fig. 2/3 y-axis when
    /// normalized per layer).
    pub fn throughput(&self) -> f64 {
        let t = self.elapsed();
        if t == 0.0 {
            0.0
        } else {
            self.tokens_generated as f64 / t
        }
    }

    pub fn report(&self) -> String {
        format!(
            "tokens={} reqs={}/{} iters={} elapsed={} throughput={:.1} tok/s \
             mean_iter={} mean_batch={:.1} kernels(t/a/n)={}/{}/{}",
            self.tokens_generated,
            self.requests_completed,
            self.requests_admitted,
            self.decode_iterations,
            human_time(self.elapsed()),
            self.throughput(),
            human_time(self.iteration_time.mean()),
            self.batch_occupancy.mean(),
            self.typhoon_iters,
            self.absorb_iters,
            self.naive_iters,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simulated_clock_accumulates() {
        let mut m = Metrics::new(Clock::Simulated);
        m.record_iteration(0.25, 8, 8);
        m.record_iteration(0.75, 16, 16);
        assert_eq!(m.elapsed(), 1.0);
        assert_eq!(m.tokens_generated, 24);
        assert!((m.throughput() - 24.0).abs() < 1e-9);
        assert!((m.batch_occupancy.mean() - 12.0).abs() < 1e-9);
        assert_eq!(m.decode_seconds, 1.0, "exact sum, not mean x count");
    }

    /// The exact accumulator vs the Welford reconstruction: summing many
    /// irrational iteration times, the mean x count round trip drifts
    /// while `decode_seconds` is the plain f64 sum.
    #[test]
    fn decode_seconds_is_exact_sum() {
        let mut m = Metrics::new(Clock::Simulated);
        let mut expect = 0.0f64;
        let mut x = 0.1f64;
        for _ in 0..10_000 {
            x = (x * 1.000_1).rem_euclid(0.37) + 1e-4;
            m.record_iteration(x, 4, 4);
            expect += x;
        }
        assert_eq!(m.decode_seconds.to_bits(), expect.to_bits());
    }

    #[test]
    fn breakdown_totals() {
        let mut b = BreakdownTimers::default();
        b.stage1_attn = 1.0;
        b.stage2_attn = 0.5;
        b.combine = 0.1;
        let mut b2 = BreakdownTimers::default();
        b2.proj_kvb1 = 0.2;
        b.add(&b2);
        assert!((b.total() - 1.8).abs() < 1e-12);
    }
}
