//! `SimEngine`: the coordinator engine that *models* execution time via
//! the paper's Table-1 cost formulas + roofline, instead of running
//! kernels.  This is the substitution for the Ascend NPU / H800 GPU
//! testbeds (DESIGN.md §6): the paper itself validates that these
//! formulas match msprof-measured runtimes to within a few percent
//! (Fig. 4 discussion), and the scheduling/policy code driven here is
//! the same code the real PJRT engine runs under.

use anyhow::Result;

use crate::config::{HardwareSpec, KernelKind, ModelConfig};
use crate::coordinator::{DecodeBatch, Engine, IterationOutcome};
use crate::costmodel::exec_time::component_time;
use crate::costmodel::flops::{attention_cost, AttentionWorkload};
use crate::kvcache::{PrefixId, SeqId};
use crate::metrics::BreakdownTimers;

pub struct SimEngine {
    pub cfg: ModelConfig,
    pub hw: HardwareSpec,
    /// Model prefill as compute-bound naive attention + projections.
    pub include_prefill: bool,
    shared_len: usize,
}

impl SimEngine {
    pub fn new(cfg: ModelConfig, hw: HardwareSpec) -> Self {
        SimEngine { cfg, hw, include_prefill: true, shared_len: 0 }
    }

    /// Per-layer decode-attention time of one iteration with mixed
    /// per-request context lengths.  The shared part costs once per
    /// batch (B queries x one stream); non-shared parts are summed per
    /// request at their individual lengths.
    fn iteration_time(&self, batch: &DecodeBatch) -> (f64, BreakdownTimers) {
        let b = batch.seqs.len() as u64;
        // Shared component at the true batch size (l_n = 0 isolates it).
        let shared_wl = AttentionWorkload::decode(b, batch.shared_len as u64, 0);
        let shared_cost = attention_cost(&self.cfg, batch.kernel, &shared_wl);
        // Non-shared: per request at its own context length (B=1 each);
        // the +1 is this step's token (scattered before attention).
        let mut non_shared = crate::costmodel::flops::Component::default();
        for &l in &batch.context_lens {
            let wl = AttentionWorkload::decode(1, 0, l as u64 + 1);
            let c = attention_cost(&self.cfg, batch.kernel, &wl);
            non_shared = non_shared.add(c.non_shared);
        }
        let mut bd = BreakdownTimers::default();
        bd.stage1_attn = component_time(&shared_cost.shared, &self.hw);
        bd.stage2_attn = component_time(&non_shared, &self.hw);
        bd.proj_kvb1 = component_time(&shared_cost.proj_kvb1, &self.hw);
        bd.proj_kvb2 = component_time(&shared_cost.proj_kvb2, &self.hw);
        bd.combine = component_time(&shared_cost.combine, &self.hw);
        (bd.total(), bd)
    }
}

impl Engine for SimEngine {
    fn prepare_shared(
        &mut self,
        _prefix: PrefixId,
        tokens: &[u32],
        _kernel: KernelKind,
    ) -> Result<f64> {
        self.shared_len = tokens.len();
        if !self.include_prefill {
            return Ok(0.0);
        }
        // Causal prefill over Ls tokens: ~Ls^2/2 context pairs, naive
        // formulation (compute-bound).  The typhoon expansion is free —
        // K/V are computed by the naive prefill anyway (paper §3.1).
        let ls = tokens.len() as f64;
        let macs = 0.5 * ls * ls * self.cfg.naive_factor() as f64;
        Ok(macs / self.hw.macs_per_sec())
    }

    fn prefill_requests(&mut self, seqs: &[(SeqId, usize)]) -> Result<f64> {
        if !self.include_prefill {
            return Ok(0.0);
        }
        // Each admitted question attends to the shared prefix + itself.
        let mut macs = 0.0;
        for &(_, qlen) in seqs {
            let q = qlen as f64;
            macs +=
                q * (self.shared_len as f64 + 0.5 * q) * self.cfg.naive_factor() as f64;
        }
        Ok(macs / self.hw.macs_per_sec())
    }

    fn decode(&mut self, batch: &DecodeBatch) -> Result<IterationOutcome> {
        let (seconds, breakdown) = self.iteration_time(batch);
        Ok(IterationOutcome { seconds, breakdown })
    }

    fn release(&mut self, _seq: SeqId) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::ascend_npu;
    use crate::config::model::deepseek_v3;

    fn batch(kernel: KernelKind, b: usize, shared: usize, ln: usize) -> DecodeBatch {
        DecodeBatch {
            seqs: (0..b as u64).collect(),
            kernel,
            shared_len: shared,
            context_lens: vec![ln; b],
        }
    }

    #[test]
    fn typhoon_faster_than_absorb_at_large_batch() {
        let mut e = SimEngine::new(deepseek_v3(), ascend_npu());
        let t = e.decode(&batch(KernelKind::Typhoon, 512, 4096, 512)).unwrap();
        let a = e.decode(&batch(KernelKind::Absorb, 512, 4096, 512)).unwrap();
        assert!(t.seconds < a.seconds, "t={} a={}", t.seconds, a.seconds);
    }

    #[test]
    fn absorb_faster_at_small_batch() {
        let mut e = SimEngine::new(deepseek_v3(), ascend_npu());
        let t = e.decode(&batch(KernelKind::Typhoon, 8, 4096, 512)).unwrap();
        let a = e.decode(&batch(KernelKind::Absorb, 8, 4096, 512)).unwrap();
        assert!(a.seconds < t.seconds);
    }

    #[test]
    fn ragged_lengths_sum_not_max() {
        let mut e = SimEngine::new(deepseek_v3(), ascend_npu());
        let uniform = e
            .decode(&DecodeBatch {
                seqs: vec![0, 1],
                kernel: KernelKind::Absorb,
                shared_len: 0,
                context_lens: vec![100, 100],
            })
            .unwrap();
        let ragged = e
            .decode(&DecodeBatch {
                seqs: vec![0, 1],
                kernel: KernelKind::Absorb,
                shared_len: 0,
                context_lens: vec![180, 20],
            })
            .unwrap();
        assert!((uniform.seconds - ragged.seconds).abs() / uniform.seconds < 1e-9);
    }

    #[test]
    fn prefill_scales_quadratically() {
        let mut e = SimEngine::new(deepseek_v3(), ascend_npu());
        let t1 = e.prepare_shared(0, &vec![0; 1000], KernelKind::Typhoon).unwrap();
        let t2 = e.prepare_shared(0, &vec![0; 2000], KernelKind::Typhoon).unwrap();
        assert!((t2 / t1 - 4.0).abs() < 1e-9);
    }
}
