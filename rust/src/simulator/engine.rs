//! `SimEngine`: the coordinator engine that *models* execution time via
//! the paper's Table-1 cost formulas + roofline, instead of running
//! kernels.  This is the substitution for the Ascend NPU / H800 GPU
//! testbeds (DESIGN.md §6): the paper itself validates that these
//! formulas match msprof-measured runtimes to within a few percent
//! (Fig. 4 discussion), and the scheduling/policy code driven here is
//! the same code the real PJRT engine runs under.
//!
//! Hot path: one decode iteration used to evaluate the Table-1 model
//! once per sequence (`O(B)` per iteration, B up to 1024).  Context
//! lengths repeat heavily inside a batch (requests admitted in the same
//! wave advance in lockstep), so the engine now buckets
//! `batch.context_lens` by distinct length — counting-sort style over
//! a reusable scratch array — and evaluates the memoized `CostTable`
//! once per *distinct* length, scaling the resulting `Component` by the
//! bucket count.  Both steps are exact over integer MAC/word counts, so
//! modeled times are bit-identical to the per-sequence evaluation.

use anyhow::Result;

use crate::config::{HardwareSpec, KernelKind, ModelConfig};
use crate::coordinator::{DecodeBatch, Engine, IterationOutcome};
use crate::costmodel::exec_time::component_time;
use crate::costmodel::flops::Component;
use crate::costmodel::table::CostTable;
use crate::kvcache::{PrefixId, SeqId};
use crate::metrics::BreakdownTimers;

pub struct SimEngine {
    pub cfg: ModelConfig,
    pub hw: HardwareSpec,
    /// Model prefill as compute-bound naive attention + projections.
    pub include_prefill: bool,
    /// Hot-path switch: bucket lengths + memoize the cost table.  Off,
    /// the engine evaluates Table 1 once per sequence per iteration —
    /// the pre-optimization reference, kept as the measurable baseline
    /// (`bench_sweep`) and for equivalence tests.  Results are
    /// bit-identical either way.
    pub memoized: bool,
    shared_len: usize,
    /// Memoized Table-1 evaluations, shared across all iterations.
    table: CostTable,
    /// Counting-sort scratch: `len_counts[l]` = sequences at length `l`
    /// this iteration; `touched` lists the distinct lengths to reset.
    len_counts: Vec<u64>,
    touched: Vec<usize>,
}

impl SimEngine {
    pub fn new(cfg: ModelConfig, hw: HardwareSpec) -> Self {
        let table = CostTable::new(cfg.clone());
        SimEngine {
            cfg,
            hw,
            include_prefill: true,
            memoized: true,
            shared_len: 0,
            table,
            len_counts: Vec::new(),
            touched: Vec::new(),
        }
    }

    /// Cache statistics of the memoized cost table: (hits, misses).
    pub fn cost_cache_stats(&self) -> (u64, u64) {
        (self.table.hits, self.table.misses)
    }

    /// Per-layer decode-attention time of one iteration with mixed
    /// per-request context lengths.  The shared part costs once per
    /// batch (B queries x one stream); non-shared parts are summed per
    /// *distinct* request length, scaled by how many requests share it.
    fn iteration_time(&mut self, batch: &DecodeBatch) -> (f64, BreakdownTimers) {
        let b = batch.seqs.len() as u64;
        let (shared_cost, non_shared) = if self.memoized {
            // Shared component at the true batch size (l_n=0 isolates it).
            let shared_cost = self.table.cost(batch.kernel, b, batch.shared_len as u64, 0);
            // Bucket the context lengths (counting sort over the scratch).
            debug_assert!(self.touched.is_empty());
            for &l in &batch.context_lens {
                if l >= self.len_counts.len() {
                    self.len_counts.resize(l + 1, 0);
                }
                if self.len_counts[l] == 0 {
                    self.touched.push(l);
                }
                self.len_counts[l] += 1;
            }
            // Deterministic order (ascending length) so the walk is
            // reproducible; the u64 sums are order-independent anyway.
            self.touched.sort_unstable();
            // Non-shared: one cost-model evaluation per distinct length
            // (B=1 each; the +1 is this step's token, scattered before
            // attention), scaled by the bucket count — exactly the sum
            // the per-sequence loop produces.
            let mut non_shared = Component::default();
            for i in 0..self.touched.len() {
                let l = self.touched[i];
                let count = self.len_counts[l];
                self.len_counts[l] = 0;
                let c = self.table.cost(batch.kernel, 1, 0, l as u64 + 1);
                non_shared = non_shared.add(c.non_shared.scale(count));
            }
            self.touched.clear();
            (shared_cost, non_shared)
        } else {
            // Reference path: direct Table-1 evaluation per sequence.
            use crate::costmodel::flops::{attention_cost, AttentionWorkload};
            let shared_wl = AttentionWorkload::decode(b, batch.shared_len as u64, 0);
            let shared_cost = attention_cost(&self.cfg, batch.kernel, &shared_wl);
            let mut non_shared = Component::default();
            for &l in &batch.context_lens {
                let wl = AttentionWorkload::decode(1, 0, l as u64 + 1);
                non_shared =
                    non_shared.add(attention_cost(&self.cfg, batch.kernel, &wl).non_shared);
            }
            (shared_cost, non_shared)
        };
        let mut bd = BreakdownTimers::default();
        bd.stage1_attn = component_time(&shared_cost.shared, &self.hw);
        bd.stage2_attn = component_time(&non_shared, &self.hw);
        bd.proj_kvb1 = component_time(&shared_cost.proj_kvb1, &self.hw);
        bd.proj_kvb2 = component_time(&shared_cost.proj_kvb2, &self.hw);
        bd.combine = component_time(&shared_cost.combine, &self.hw);
        (bd.total(), bd)
    }
}

impl Engine for SimEngine {
    fn prepare_shared(
        &mut self,
        _prefix: PrefixId,
        tokens: &[u32],
        _kernel: KernelKind,
    ) -> Result<f64> {
        self.shared_len = tokens.len();
        if !self.include_prefill {
            return Ok(0.0);
        }
        // Causal prefill over Ls tokens: ~Ls^2/2 context pairs, naive
        // formulation (compute-bound).  The typhoon expansion is free —
        // K/V are computed by the naive prefill anyway (paper §3.1).
        let ls = tokens.len() as f64;
        let macs = 0.5 * ls * ls * self.cfg.naive_factor() as f64;
        Ok(macs / self.hw.macs_per_sec())
    }

    fn prefill_requests(&mut self, seqs: &[(SeqId, usize)]) -> Result<f64> {
        if !self.include_prefill {
            return Ok(0.0);
        }
        // Each admitted question attends to the shared prefix + itself.
        let mut macs = 0.0;
        for &(_, qlen) in seqs {
            let q = qlen as f64;
            macs +=
                q * (self.shared_len as f64 + 0.5 * q) * self.cfg.naive_factor() as f64;
        }
        Ok(macs / self.hw.macs_per_sec())
    }

    fn decode(&mut self, batch: &DecodeBatch) -> Result<IterationOutcome> {
        let (seconds, breakdown) = self.iteration_time(batch);
        Ok(IterationOutcome { seconds, breakdown })
    }

    fn release(&mut self, _seq: SeqId) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::ascend_npu;
    use crate::config::model::deepseek_v3;
    use crate::costmodel::flops::{attention_cost, AttentionWorkload};

    fn batch(kernel: KernelKind, b: usize, shared: usize, ln: usize) -> DecodeBatch {
        DecodeBatch {
            seqs: (0..b as u64).collect(),
            kernel,
            shared_len: shared,
            context_lens: vec![ln; b],
        }
    }

    #[test]
    fn typhoon_faster_than_absorb_at_large_batch() {
        let mut e = SimEngine::new(deepseek_v3(), ascend_npu());
        let t = e.decode(&batch(KernelKind::Typhoon, 512, 4096, 512)).unwrap();
        let a = e.decode(&batch(KernelKind::Absorb, 512, 4096, 512)).unwrap();
        assert!(t.seconds < a.seconds, "t={} a={}", t.seconds, a.seconds);
    }

    #[test]
    fn absorb_faster_at_small_batch() {
        let mut e = SimEngine::new(deepseek_v3(), ascend_npu());
        let t = e.decode(&batch(KernelKind::Typhoon, 8, 4096, 512)).unwrap();
        let a = e.decode(&batch(KernelKind::Absorb, 8, 4096, 512)).unwrap();
        assert!(a.seconds < t.seconds);
    }

    #[test]
    fn ragged_lengths_sum_not_max() {
        let mut e = SimEngine::new(deepseek_v3(), ascend_npu());
        let uniform = e
            .decode(&DecodeBatch {
                seqs: vec![0, 1],
                kernel: KernelKind::Absorb,
                shared_len: 0,
                context_lens: vec![100, 100],
            })
            .unwrap();
        let ragged = e
            .decode(&DecodeBatch {
                seqs: vec![0, 1],
                kernel: KernelKind::Absorb,
                shared_len: 0,
                context_lens: vec![180, 20],
            })
            .unwrap();
        assert!((uniform.seconds - ragged.seconds).abs() / uniform.seconds < 1e-9);
    }

    #[test]
    fn prefill_scales_quadratically() {
        let mut e = SimEngine::new(deepseek_v3(), ascend_npu());
        let t1 = e.prepare_shared(0, &vec![0; 1000], KernelKind::Typhoon).unwrap();
        let t2 = e.prepare_shared(0, &vec![0; 2000], KernelKind::Typhoon).unwrap();
        assert!((t2 / t1 - 4.0).abs() < 1e-9);
    }

    /// The bucketed + memoized iteration time must be *bit-identical*
    /// to the straightforward per-sequence evaluation — both against a
    /// hand-rolled reference and against the engine's own
    /// `memoized = false` path.
    #[test]
    fn bucketed_matches_per_sequence_reference() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        let mut e = SimEngine::new(cfg.clone(), hw.clone());
        let mut reference_engine = SimEngine::new(cfg.clone(), hw.clone());
        reference_engine.memoized = false;
        let mut rng = crate::util::rng::Rng::new(17);
        for kernel in KernelKind::all() {
            for trial in 0..10 {
                let b = rng.gen_range_usize(1, 300);
                let shared = rng.gen_range_usize(0, 8000);
                let lens: Vec<usize> =
                    (0..b).map(|_| rng.gen_range_usize(0, 64)).collect();
                let batch = DecodeBatch {
                    seqs: (0..b as u64).collect(),
                    kernel,
                    shared_len: shared,
                    context_lens: lens.clone(),
                };
                let got = e.decode(&batch).unwrap();
                let via_flag = reference_engine.decode(&batch).unwrap();
                assert_eq!(got.seconds, via_flag.seconds, "memoized flag must not drift");

                // Reference: the original per-sequence formulation.
                let shared_wl = AttentionWorkload::decode(b as u64, shared as u64, 0);
                let shared_cost = attention_cost(&cfg, kernel, &shared_wl);
                let mut non_shared = Component::default();
                for &l in &lens {
                    let wl = AttentionWorkload::decode(1, 0, l as u64 + 1);
                    non_shared = non_shared.add(attention_cost(&cfg, kernel, &wl).non_shared);
                }
                let mut bd = BreakdownTimers::default();
                bd.stage1_attn = component_time(&shared_cost.shared, &hw);
                bd.stage2_attn = component_time(&non_shared, &hw);
                bd.proj_kvb1 = component_time(&shared_cost.proj_kvb1, &hw);
                bd.proj_kvb2 = component_time(&shared_cost.proj_kvb2, &hw);
                bd.combine = component_time(&shared_cost.combine, &hw);
                assert_eq!(got.seconds, bd.total(), "kernel {kernel:?} trial {trial}");
            }
        }
        let (hits, misses) = e.cost_cache_stats();
        assert!(hits > 0, "repeated lengths must hit the cache");
        assert!(misses > 0);
    }

    /// Repeated identical batches do O(distinct lengths) model
    /// evaluations, not O(B) — everything after the first iteration is
    /// a cache hit.
    #[test]
    fn steady_state_is_all_cache_hits() {
        let mut e = SimEngine::new(deepseek_v3(), ascend_npu());
        let b = batch(KernelKind::Typhoon, 256, 4096, 512);
        e.decode(&b).unwrap();
        let (_, misses_after_first) = e.cost_cache_stats();
        // 256 equal lengths -> 1 shared + 1 non-shared evaluation.
        assert_eq!(misses_after_first, 2);
        for _ in 0..10 {
            e.decode(&b).unwrap();
        }
        let (hits, misses) = e.cost_cache_stats();
        assert_eq!(misses, misses_after_first, "steady state never misses");
        assert_eq!(hits, 20);
    }
}
