//! `SimEngine`: the coordinator engine that *models* execution time via
//! the paper's Table-1 cost formulas + roofline, instead of running
//! kernels.  This is the substitution for the Ascend NPU / H800 GPU
//! testbeds (DESIGN.md §6): the paper itself validates that these
//! formulas match msprof-measured runtimes to within a few percent
//! (Fig. 4 discussion), and the scheduling/policy code driven here is
//! the same code the real PJRT engine runs under.
//!
//! **Grouped iterations.**  A decode batch is partitioned into prefix
//! groups (multi-tenant serving); the shared-stage cost is charged
//! *once per group* at the group's occupancy and the group's kernel —
//! each group's prefix is a distinct KV stream, so the naive/absorb
//! reads and the projection/combine launches are per group — while the
//! non-shared stage is length-bucketed across the whole batch per
//! kernel class.  All sums are exact over integer MAC/word counts, so
//! a single-group batch is bit-identical to the pre-tenancy
//! formulation (shared cost at full batch + per-sequence non-shared).
//!
//! Hot path: `context_lens` are bucketed by distinct length
//! (counting-sort scratch, reused across iterations) and the memoized
//! cost surface is evaluated once per *distinct* length — O(#distinct)
//! cost evaluations per decode iteration instead of O(B), bit-identical
//! results.  The memo lives in an `Arc`-shared [`PriceSurface`]
//! (DESIGN.md §17): a standalone engine gets a private surface, while a
//! cluster replica adopts the fleet-shared one via `with_surface`, so
//! autoscale spin-ups start warm instead of rebuilding a cold table.

use std::sync::Arc;

use anyhow::Result;

use crate::config::{HardwareSpec, KernelKind, ModelConfig};
use crate::coordinator::{DecodeBatch, Engine, IterationOutcome, PrefillRequest};
use crate::costmodel::exec_time::component_time;
use crate::costmodel::flops::Component;
use crate::costmodel::parallel::ParallelismConfig;
use crate::costmodel::surface::PriceSurface;
use crate::kvcache::PrefixId;
use crate::metrics::BreakdownTimers;

pub struct SimEngine {
    pub cfg: ModelConfig,
    pub hw: HardwareSpec,
    /// Model prefill as compute-bound naive attention + projections.
    pub include_prefill: bool,
    /// Hot-path switch: bucket lengths + memoize the cost surface.  Off,
    /// the engine evaluates Table 1 once per sequence per iteration —
    /// the pre-optimization reference, kept as the measurable baseline
    /// (`bench_sweep`) and for equivalence tests.  Results are
    /// bit-identical either way.
    pub memoized: bool,
    /// Memoized Table-1 evaluations — private by default, fleet-shared
    /// when constructed via `with_surface`.
    surface: Arc<PriceSurface>,
    /// TP/SP sharding of the modeled device group.  `single()` (the
    /// default) is bit-identical to the pre-parallelism engine; set via
    /// `with_parallelism` so the memoized surface stays consistent.
    par: ParallelismConfig,
    /// Counting-sort scratch: `len_counts[l]` = sequences at length `l`
    /// this iteration; `touched` lists the distinct lengths to reset.
    len_counts: Vec<u64>,
    touched: Vec<usize>,
}

impl SimEngine {
    pub fn new(cfg: ModelConfig, hw: HardwareSpec) -> Self {
        Self::with_parallelism(cfg, hw, ParallelismConfig::single())
    }

    /// An engine modeling each decode iteration per TP/SP rank via
    /// `costmodel::parallel::parallel_attention_cost`; prefill compute
    /// splits across ranks.  TP must divide the model's head count.
    pub fn with_parallelism(cfg: ModelConfig, hw: HardwareSpec, par: ParallelismConfig) -> Self {
        let surface = Arc::new(PriceSurface::new(cfg.clone(), hw.clone(), par));
        Self::from_surface(cfg, hw, par, surface)
    }

    /// An engine adopting a fleet-shared [`PriceSurface`] — the cluster
    /// path, where every replica (and every autoscale spin-up) prices
    /// against the same warm memo.  The surface must cover this
    /// engine's `(model, hardware, parallelism)` cell; a mismatched
    /// surface is rejected in favor of a private one (debug-asserted),
    /// so results can never come from the wrong cell.
    pub fn with_surface(
        cfg: ModelConfig,
        hw: HardwareSpec,
        par: ParallelismConfig,
        surface: Arc<PriceSurface>,
    ) -> Self {
        debug_assert!(
            surface.covers(&cfg, &hw, &par, 1),
            "shared surface keyed for ({}, {}, {:?}) handed to engine ({}, {}, {:?})",
            surface.model().name,
            surface.hardware().name,
            surface.parallelism(),
            cfg.name,
            hw.name,
            par,
        );
        if !surface.covers(&cfg, &hw, &par, 1) {
            return Self::with_parallelism(cfg, hw, par);
        }
        Self::from_surface(cfg, hw, par, surface)
    }

    fn from_surface(
        cfg: ModelConfig,
        hw: HardwareSpec,
        par: ParallelismConfig,
        surface: Arc<PriceSurface>,
    ) -> Self {
        SimEngine {
            cfg,
            hw,
            include_prefill: true,
            memoized: true,
            surface,
            par,
            len_counts: Vec::new(),
            touched: Vec::new(),
        }
    }

    /// The engine's TP/SP configuration.
    pub fn parallelism(&self) -> ParallelismConfig {
        self.par
    }

    /// The pricing surface this engine evaluates through (shared by the
    /// whole fleet in cluster mode).
    pub fn surface(&self) -> &Arc<PriceSurface> {
        &self.surface
    }

    /// Cache statistics of the memoized cost surface: (hits, misses).
    /// For an engine on a fleet-shared surface these are fleet-wide.
    pub fn cost_cache_stats(&self) -> (u64, u64) {
        self.surface.stats()
    }

    /// The counting-sort scratch contract: both buffers fully cleared
    /// between decode iterations — `touched` drained and every
    /// `len_counts` bucket zeroed by the previous walk.  A leaked
    /// bucket would silently inflate the next iteration's length
    /// histogram, so the gate is checked (debug builds) at every
    /// iteration entry, not just per kernel class.
    fn debug_assert_scratch_clear(&self) {
        debug_assert!(self.touched.is_empty(), "scratch `touched` leaked entries");
        debug_assert!(
            self.len_counts.iter().all(|&c| c == 0),
            "scratch `len_counts` has nonzero buckets between iterations"
        );
    }

    /// Per-layer decode-attention time of one grouped iteration with
    /// mixed per-request context lengths.  Shared parts cost once per
    /// group (group occupancy x that group's prefix stream); non-shared
    /// parts are summed per *distinct* request length within each
    /// kernel class, scaled by how many requests share it.
    fn iteration_time(&mut self, batch: &DecodeBatch) -> (f64, BreakdownTimers) {
        let (shared_cost, non_shared) = if self.memoized {
            self.debug_assert_scratch_clear();
            // Shared stage: one memoized evaluation per group (l_n=0
            // isolates the shared component + projections/combine).
            let shared_cost = self.surface.grouped_shared_cost(
                batch.groups.iter().map(|g| (g.kernel, g.len as u64, g.shared_len as u64)),
            );
            // Non-shared stage: bucket context lengths per kernel class
            // (counting sort over the scratch).  Typhoon and its absorb
            // fall-back share the non-shared formulation, but keying by
            // the group's kernel keeps naive-requested configs exact.
            let mut non_shared = Component::default();
            for kernel in KernelKind::all() {
                debug_assert!(self.touched.is_empty());
                for g in batch.groups.iter().filter(|g| g.kernel == kernel) {
                    for &l in batch.group_lens(g) {
                        if l >= self.len_counts.len() {
                            self.len_counts.resize(l + 1, 0);
                        }
                        if self.len_counts[l] == 0 {
                            self.touched.push(l);
                        }
                        self.len_counts[l] += 1;
                    }
                }
                if self.touched.is_empty() {
                    continue;
                }
                // Deterministic order (ascending length) so the walk is
                // reproducible; the u64 sums are order-independent anyway.
                self.touched.sort_unstable();
                // One cost-model evaluation per distinct length (B=1
                // each; the +1 is this step's token, scattered before
                // attention), scaled by the bucket count — exactly the
                // sum the per-sequence loop produces.
                for i in 0..self.touched.len() {
                    let l = self.touched[i];
                    let count = self.len_counts[l];
                    self.len_counts[l] = 0;
                    let c = self.surface.cost(kernel, 1, 0, l as u64 + 1);
                    non_shared = non_shared.add(c.non_shared.scale(count));
                }
                self.touched.clear();
            }
            (shared_cost, non_shared)
        } else {
            // Reference path: direct Table-1 evaluation per group and
            // per sequence (the pre-optimization formulation), routed
            // through the same per-rank cost model as the table.
            use crate::costmodel::flops::{AttentionWorkload, CostBreakdown};
            use crate::costmodel::parallel::parallel_attention_cost;
            let mut shared_cost = CostBreakdown::default();
            let mut non_shared = Component::default();
            for g in &batch.groups {
                let wl = AttentionWorkload::decode(g.len as u64, g.shared_len as u64, 0);
                let c = parallel_attention_cost(&self.cfg, g.kernel, &wl, &self.par);
                shared_cost.shared = shared_cost.shared.add(c.shared);
                shared_cost.proj_kvb1 = shared_cost.proj_kvb1.add(c.proj_kvb1);
                shared_cost.proj_kvb2 = shared_cost.proj_kvb2.add(c.proj_kvb2);
                shared_cost.combine = shared_cost.combine.add(c.combine);
                for &l in batch.group_lens(g) {
                    let wl = AttentionWorkload::decode(1, 0, l as u64 + 1);
                    let c = parallel_attention_cost(&self.cfg, g.kernel, &wl, &self.par);
                    non_shared = non_shared.add(c.non_shared);
                }
            }
            (shared_cost, non_shared)
        };
        let mut bd = BreakdownTimers::default();
        bd.stage1_attn = component_time(&shared_cost.shared, &self.hw);
        bd.stage2_attn = component_time(&non_shared, &self.hw);
        bd.proj_kvb1 = component_time(&shared_cost.proj_kvb1, &self.hw);
        bd.proj_kvb2 = component_time(&shared_cost.proj_kvb2, &self.hw);
        bd.combine = component_time(&shared_cost.combine, &self.hw);
        (bd.total(), bd)
    }
}

impl Engine for SimEngine {
    fn prepare_shared(
        &mut self,
        _prefix: PrefixId,
        tokens: &[u32],
        _kernel: KernelKind,
    ) -> Result<f64> {
        if !self.include_prefill {
            return Ok(0.0);
        }
        // Causal prefill over Ls tokens: ~Ls^2/2 context pairs, naive
        // formulation (compute-bound).  The typhoon expansion is free —
        // K/V are computed by the naive prefill anyway (paper §3.1).
        // Called once per registered prefix group.  Prefill is
        // compute-bound and shards over TP/SP ranks (`/ ranks` is a
        // bit-exact no-op for a single device).
        let ls = tokens.len() as f64;
        let macs = 0.5 * ls * ls * self.cfg.naive_factor() as f64;
        Ok(macs / self.par.ranks() as f64 / self.hw.macs_per_sec())
    }

    fn prefill_requests(&mut self, seqs: &[PrefillRequest]) -> Result<f64> {
        if !self.include_prefill {
            return Ok(0.0);
        }
        // Each admitted question attends to its *group's* shared prefix
        // + itself.
        let mut macs = 0.0;
        for r in seqs {
            let q = r.context_len as f64;
            macs += q * (r.shared_len as f64 + 0.5 * q) * self.cfg.naive_factor() as f64;
        }
        Ok(macs / self.par.ranks() as f64 / self.hw.macs_per_sec())
    }

    fn decode(&mut self, batch: &DecodeBatch) -> Result<IterationOutcome> {
        let (seconds, breakdown) = self.iteration_time(batch);
        Ok(IterationOutcome { seconds, breakdown })
    }

    fn release(&mut self, _seq: crate::kvcache::SeqId) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::ascend_npu;
    use crate::config::model::deepseek_v3;
    use crate::coordinator::BatchGroup;
    use crate::costmodel::flops::{attention_cost, AttentionWorkload};

    fn batch(kernel: KernelKind, b: usize, shared: usize, ln: usize) -> DecodeBatch {
        DecodeBatch::single(kernel, shared, (0..b as u64).collect(), vec![ln; b])
    }

    #[test]
    fn typhoon_faster_than_absorb_at_large_batch() {
        let mut e = SimEngine::new(deepseek_v3(), ascend_npu());
        let t = e.decode(&batch(KernelKind::Typhoon, 512, 4096, 512)).unwrap();
        let a = e.decode(&batch(KernelKind::Absorb, 512, 4096, 512)).unwrap();
        assert!(t.seconds < a.seconds, "t={} a={}", t.seconds, a.seconds);
    }

    #[test]
    fn absorb_faster_at_small_batch() {
        let mut e = SimEngine::new(deepseek_v3(), ascend_npu());
        let t = e.decode(&batch(KernelKind::Typhoon, 8, 4096, 512)).unwrap();
        let a = e.decode(&batch(KernelKind::Absorb, 8, 4096, 512)).unwrap();
        assert!(a.seconds < t.seconds);
    }

    #[test]
    fn ragged_lengths_sum_not_max() {
        let mut e = SimEngine::new(deepseek_v3(), ascend_npu());
        let uniform = e
            .decode(&DecodeBatch::single(KernelKind::Absorb, 0, vec![0, 1], vec![100, 100]))
            .unwrap();
        let ragged = e
            .decode(&DecodeBatch::single(KernelKind::Absorb, 0, vec![0, 1], vec![180, 20]))
            .unwrap();
        assert!((uniform.seconds - ragged.seconds).abs() / uniform.seconds < 1e-9);
    }

    #[test]
    fn prefill_scales_quadratically() {
        let mut e = SimEngine::new(deepseek_v3(), ascend_npu());
        let t1 = e.prepare_shared(0, &vec![0; 1000], KernelKind::Typhoon).unwrap();
        let t2 = e.prepare_shared(0, &vec![0; 2000], KernelKind::Typhoon).unwrap();
        assert!((t2 / t1 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn prefill_uses_group_shared_len() {
        let mut e = SimEngine::new(deepseek_v3(), ascend_npu());
        let short = e
            .prefill_requests(&[PrefillRequest { seq: 0, context_len: 64, shared_len: 100 }])
            .unwrap();
        let long = e
            .prefill_requests(&[PrefillRequest { seq: 0, context_len: 64, shared_len: 10_000 }])
            .unwrap();
        assert!(long > short, "longer group prefix costs more prefill");
    }

    /// The bucketed + memoized iteration time must be *bit-identical*
    /// to the straightforward per-sequence evaluation — both against a
    /// hand-rolled reference (the pre-refactor single-prefix
    /// formulation) and against the engine's own `memoized = false`
    /// path.  This is the single-tenant regression: grouped machinery
    /// with one group == the old code, to the last bit.
    #[test]
    fn bucketed_matches_per_sequence_reference() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        let mut e = SimEngine::new(cfg.clone(), hw.clone());
        let mut reference_engine = SimEngine::new(cfg.clone(), hw.clone());
        reference_engine.memoized = false;
        let mut rng = crate::util::rng::Rng::new(17);
        for kernel in KernelKind::all() {
            for trial in 0..10 {
                let b = rng.gen_range_usize(1, 300);
                let shared = rng.gen_range_usize(0, 8000);
                let lens: Vec<usize> =
                    (0..b).map(|_| rng.gen_range_usize(0, 64)).collect();
                let batch = DecodeBatch::single(
                    kernel,
                    shared,
                    (0..b as u64).collect(),
                    lens.clone(),
                );
                let got = e.decode(&batch).unwrap();
                let via_flag = reference_engine.decode(&batch).unwrap();
                assert_eq!(got.seconds, via_flag.seconds, "memoized flag must not drift");

                // Reference: the original pre-tenancy formulation —
                // shared cost at the full batch size + per-sequence
                // non-shared terms.
                let shared_wl = AttentionWorkload::decode(b as u64, shared as u64, 0);
                let shared_cost = attention_cost(&cfg, kernel, &shared_wl);
                let mut non_shared = Component::default();
                for &l in &lens {
                    let wl = AttentionWorkload::decode(1, 0, l as u64 + 1);
                    non_shared = non_shared.add(attention_cost(&cfg, kernel, &wl).non_shared);
                }
                let mut bd = BreakdownTimers::default();
                bd.stage1_attn = component_time(&shared_cost.shared, &hw);
                bd.stage2_attn = component_time(&non_shared, &hw);
                bd.proj_kvb1 = component_time(&shared_cost.proj_kvb1, &hw);
                bd.proj_kvb2 = component_time(&shared_cost.proj_kvb2, &hw);
                bd.combine = component_time(&shared_cost.combine, &hw);
                assert_eq!(got.seconds, bd.total(), "kernel {kernel:?} trial {trial}");
            }
        }
        let (hits, misses) = e.cost_cache_stats();
        assert!(hits > 0, "repeated lengths must hit the cache");
        assert!(misses > 0);
    }

    /// Grouped batches: the memoized path must bit-match the reference
    /// engine across random multi-group partitions and kernel mixes.
    #[test]
    fn grouped_memoized_matches_reference() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        let mut e = SimEngine::new(cfg.clone(), hw.clone());
        let mut reference_engine = SimEngine::new(cfg, hw);
        reference_engine.memoized = false;
        let mut rng = crate::util::rng::Rng::new(23);
        for trial in 0..40 {
            let n_groups = rng.gen_range_usize(1, 5);
            let mut seqs = Vec::new();
            let mut lens = Vec::new();
            let mut groups = Vec::new();
            for gi in 0..n_groups {
                let members = rng.gen_range_usize(1, 100);
                let kernel = *rng.choose(&KernelKind::all());
                let shared_len = rng.gen_range_usize(0, 8000);
                groups.push(BatchGroup {
                    prefix: gi as u32,
                    shared_len,
                    kernel,
                    start: seqs.len(),
                    len: members,
                });
                for _ in 0..members {
                    lens.push(rng.gen_range_usize(0, 64));
                    seqs.push(seqs.len() as u64);
                }
            }
            let batch = DecodeBatch { seqs, context_lens: lens, groups };
            let got = e.decode(&batch).unwrap();
            let reference = reference_engine.decode(&batch).unwrap();
            assert_eq!(
                got.seconds.to_bits(),
                reference.seconds.to_bits(),
                "trial {trial}"
            );
        }
    }

    /// Two groups of the same kernel cost the shared stage per group
    /// but share non-shared length buckets; splitting one group into
    /// two with the same total occupancy must *increase* modeled time
    /// only via the per-group stream reads (never decrease).
    #[test]
    fn splitting_a_group_never_reduces_cost() {
        let mut e = SimEngine::new(deepseek_v3(), ascend_npu());
        let single = e.decode(&batch(KernelKind::Absorb, 64, 4096, 128)).unwrap();
        let split = e
            .decode(&DecodeBatch {
                seqs: (0..64).collect(),
                context_lens: vec![128; 64],
                groups: vec![
                    BatchGroup {
                        prefix: 0,
                        shared_len: 4096,
                        kernel: KernelKind::Absorb,
                        start: 0,
                        len: 32,
                    },
                    BatchGroup {
                        prefix: 1,
                        shared_len: 4096,
                        kernel: KernelKind::Absorb,
                        start: 32,
                        len: 32,
                    },
                ],
            })
            .unwrap();
        assert!(split.seconds >= single.seconds, "{} < {}", split.seconds, single.seconds);
    }

    /// TP/SP-sharded engines: per-rank iteration time differs from the
    /// single-device model, the memoized and reference paths agree to
    /// the bit under sharding, and `single()` is the identity.
    #[test]
    fn sharded_engine_matches_reference_and_single_is_identity() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        let par = ParallelismConfig { tp: 4, sp: 4 };
        let b = batch(KernelKind::Typhoon, 512, 26472, 512);

        let mut single = SimEngine::new(cfg.clone(), hw.clone());
        let mut explicit_single = SimEngine::with_parallelism(
            cfg.clone(),
            hw.clone(),
            ParallelismConfig::single(),
        );
        let s = single.decode(&b).unwrap();
        let es = explicit_single.decode(&b).unwrap();
        assert_eq!(s.seconds.to_bits(), es.seconds.to_bits());

        let mut sharded = SimEngine::with_parallelism(cfg.clone(), hw.clone(), par);
        let mut sharded_ref = SimEngine::with_parallelism(cfg, hw, par);
        sharded_ref.memoized = false;
        assert_eq!(sharded.parallelism(), par);
        let p = sharded.decode(&b).unwrap();
        let pr = sharded_ref.decode(&b).unwrap();
        assert_eq!(p.seconds.to_bits(), pr.seconds.to_bits(), "memoized == reference");
        assert!(p.seconds < s.seconds, "16 ranks beat one device: {} vs {}", p.seconds, s.seconds);

        // Prefill shards too (compute-bound: ~ranks-x faster).
        let mut e1 = SimEngine::new(deepseek_v3(), ascend_npu());
        let mut e16 = SimEngine::with_parallelism(deepseek_v3(), ascend_npu(), par);
        let t1 = e1.prepare_shared(0, &vec![0; 4096], KernelKind::Typhoon).unwrap();
        let t16 = e16.prepare_shared(0, &vec![0; 4096], KernelKind::Typhoon).unwrap();
        assert!((t1 / t16 - 16.0).abs() < 1e-9);
    }

    /// Repeated identical batches do O(distinct lengths) model
    /// evaluations, not O(B) — everything after the first iteration is
    /// a cache hit.
    #[test]
    fn steady_state_is_all_cache_hits() {
        let mut e = SimEngine::new(deepseek_v3(), ascend_npu());
        let b = batch(KernelKind::Typhoon, 256, 4096, 512);
        e.decode(&b).unwrap();
        let (_, misses_after_first) = e.cost_cache_stats();
        // 256 equal lengths -> 1 shared + 1 non-shared evaluation.
        assert_eq!(misses_after_first, 2);
        for _ in 0..10 {
            e.decode(&b).unwrap();
        }
        let (hits, misses) = e.cost_cache_stats();
        assert_eq!(misses, misses_after_first, "steady state never misses");
        assert_eq!(hits, 20);
    }

    /// Two engines adopting one shared surface produce the same bits
    /// as a private-surface engine, and the second engine starts warm:
    /// a workload the first engine already priced adds zero misses.
    #[test]
    fn shared_surface_warm_start_is_bit_identical() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        let par = ParallelismConfig::single();
        let surface = Arc::new(PriceSurface::new(cfg.clone(), hw.clone(), par));
        let mut first = SimEngine::with_surface(cfg.clone(), hw.clone(), par, Arc::clone(&surface));
        let mut second = SimEngine::with_surface(cfg.clone(), hw.clone(), par, surface);
        let mut private = SimEngine::new(cfg, hw);
        let b = batch(KernelKind::Typhoon, 256, 4096, 512);

        let r1 = first.decode(&b).unwrap();
        let (_, misses_after_first) = first.cost_cache_stats();
        assert!(misses_after_first > 0);
        let r2 = second.decode(&b).unwrap();
        let (_, misses_after_second) = second.cost_cache_stats();
        assert_eq!(
            misses_after_second, misses_after_first,
            "spin-up engine reuses the warm fleet surface"
        );
        let rp = private.decode(&b).unwrap();
        assert_eq!(r1.seconds.to_bits(), r2.seconds.to_bits());
        assert_eq!(r1.seconds.to_bits(), rp.seconds.to_bits(), "sharing never changes bits");
    }
}
