//! The hardware simulator: replays the paper's serving experiments with
//! cost-model timing on NPU/GPU hardware specs (the testbed
//! substitution of DESIGN.md §6).

pub mod e2e;
pub mod engine;
pub mod serving_sim;
pub mod sweep;
pub mod tenancy;

pub use e2e::{gpu_h800_calibrated, tgr_row, TgrEntry, TgrRow};
pub use engine::SimEngine;
pub use serving_sim::{run_experiment, run_kernel_comparison, SimParams, SimReport};
pub use sweep::{
    run_throughput_sweep, throughput_cells, SweepExecutor, ThroughputCell, ThroughputCellResult,
};
pub use tenancy::{
    run_tenant_comparison, run_tenant_experiment, run_tenant_experiment_with, TenantSimParams,
    TenantSimReport,
};
