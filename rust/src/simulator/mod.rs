//! The hardware simulator: replays the paper's serving experiments with
//! cost-model timing on NPU/GPU hardware specs (the testbed
//! substitution of DESIGN.md §6).

pub mod cluster;
pub mod e2e;
pub mod engine;
pub mod faults;
pub mod serving_sim;
pub mod sweep;
pub mod tenancy;

pub use cluster::{
    run_cluster_experiment, ClusterParams, ClusterReport, ClusterSim, MigrationEvent,
    ReplicaLifecycle, ReplicaReport, RouterPolicy, ScaleEvent, BURST_PHASES,
};
pub use e2e::{gpu_h800_calibrated, tgr_row, TgrEntry, TgrRow};
pub use engine::SimEngine;
pub use faults::{DegradeWindow, FaultEvent, FaultKind, FaultPlan};
pub use serving_sim::{run_experiment, run_kernel_comparison, SimParams, SimReport};
pub use sweep::{
    cluster_cells, cluster_row_configs, crossover_cells, run_cluster_sweep,
    run_crossover_sweep, run_throughput_sweep, throughput_cells, ClusterCell,
    ClusterCellResult, CrossoverCell, CrossoverCellResult, SweepExecutor, ThroughputCell,
    ThroughputCellResult,
};
pub use tenancy::{
    calibration_cell, run_tenant_comparison, run_tenant_experiment,
    run_tenant_experiment_with, tenant_serving_stack, CalibrationCell, TenantSimParams,
    TenantSimReport,
};
