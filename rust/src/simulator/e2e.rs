//! End-to-end token-generation-rate estimation (paper Table 3).
//!
//! The paper measures attention on a GPU and takes the non-attention
//! per-iteration time from DeepSeek's published profile data; we do the
//! same arithmetic with simulated attention times and the 28.1 ms
//! non-attention constant implied by Table 3 itself (127.2 - 99.1 ms).

use anyhow::Result;

use crate::config::{HardwareSpec, KernelKind, ModelConfig};
use crate::workload::{Dataset, SystemPrompt};

use super::serving_sim::{run_experiment, SimParams};

/// One Table-3 row for one kernel.
#[derive(Clone, Debug)]
pub struct TgrEntry {
    /// Full-model attention time per decode iteration, ms.
    pub attention_ms: f64,
    /// Attention + non-attention time, ms.
    pub total_ms: f64,
    /// Token generation rate, kToken/s (batch / total time).
    pub tgr_ktok_s: f64,
}

#[derive(Clone, Debug)]
pub struct TgrRow {
    pub prompt: &'static str,
    pub baseline: TgrEntry, // FlashMLA-analog (absorb-only)
    pub typhoon: TgrEntry,
}

/// GPU spec calibrated so the absorb baseline's Prompt-A attention time
/// lands near the paper's measured 99.1 ms (real kernels achieve ~60%
/// of peak; the ideal roofline would give ~57 ms).  Used for Table 3
/// regeneration only; Eq. 1 and the roofline figures use ideal specs,
/// as the paper does.
pub fn gpu_h800_calibrated() -> HardwareSpec {
    let mut hw = crate::config::hardware::gpu_h800();
    hw.name = "gpu-h800-calibrated";
    hw.compute_efficiency = 0.60;
    hw.bandwidth_efficiency = 0.80;
    hw
}

pub fn tgr_row(
    model: &ModelConfig,
    hw: &HardwareSpec,
    dataset: &Dataset,
    prompt: &SystemPrompt,
    batch: usize,
    max_requests: Option<usize>,
) -> Result<TgrRow> {
    let layers = model.n_layers as f64;
    let entry = |kernel: KernelKind| -> Result<TgrEntry> {
        let mut p = SimParams::new(model.clone(), hw.clone(), kernel, batch);
        p.max_requests = max_requests;
        let r = run_experiment(&p, dataset, prompt)?;
        let attention_ms = r.mean_iter_seconds * layers * 1e3;
        let total_ms = attention_ms + model.other_layer_ms;
        Ok(TgrEntry {
            attention_ms,
            total_ms,
            tgr_ktok_s: batch as f64 / total_ms, // B tokens per total_ms => ktok/s
        })
    };
    Ok(TgrRow {
        prompt: prompt.name,
        baseline: entry(KernelKind::Absorb)?,
        typhoon: entry(KernelKind::Typhoon)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::deepseek_v3;
    use crate::workload::datasets::mmlu;
    use crate::workload::prompts::{PROMPT_A, PROMPT_C};

    /// Table 3 shape: typhoon's end-to-end TGR gain is largest for
    /// Prompt A (~1.48x in the paper) and smaller for Prompt C (~1.1x).
    #[test]
    fn tgr_speedup_ordering() {
        let model = deepseek_v3();
        let hw = gpu_h800_calibrated();
        let a = tgr_row(&model, &hw, &mmlu(), &PROMPT_A, 128, Some(384)).unwrap();
        let c = tgr_row(&model, &hw, &mmlu(), &PROMPT_C, 128, Some(384)).unwrap();
        let speedup_a = a.typhoon.tgr_ktok_s / a.baseline.tgr_ktok_s;
        let speedup_c = c.typhoon.tgr_ktok_s / c.baseline.tgr_ktok_s;
        assert!(speedup_a > speedup_c, "A {speedup_a} vs C {speedup_c}");
        assert!(speedup_a > 1.2, "prompt A speedup {speedup_a}");
        assert!(speedup_c > 1.0, "prompt C speedup {speedup_c}");
        // Attention time with prompt A in the right decade (paper: 99.1ms).
        assert!(
            a.baseline.attention_ms > 40.0 && a.baseline.attention_ms < 200.0,
            "{}",
            a.baseline.attention_ms
        );
    }
}
