//! Multi-tenant serving simulation: the paper's continuous-batching
//! protocol generalized to N prefix groups (tenant system prompts),
//! with the Eq. 1 fall-back rule applied per group.
//!
//! Three deployments are comparable on the same workload:
//! * **grouped Typhoon** (`KernelKind::Typhoon`) — hot groups run the
//!   mixed kernel, cold groups fall back to absorb, per iteration;
//! * **global absorb** (`KernelKind::Absorb`) — the FlashMLA-style
//!   baseline, every group absorb-only;
//! * **per-tenant naive** (`KernelKind::Naive`) — each group naive on
//!   both stages (prefix-aware PagedAttention).

use std::sync::Arc;

use anyhow::Result;

use crate::config::hardware::Backend;
use crate::config::model::{deepseek_v3, kimi_k2};
use crate::config::{HardwareSpec, KernelKind, ModelConfig, ServingConfig};
use crate::coordinator::{Coordinator, KernelPolicy};
use crate::costmodel::flops::AttentionWorkload;
use crate::costmodel::parallel::{
    parallel_attention_time, parallel_batch_threshold, parallel_pair_threshold,
    ParallelismConfig,
};
use crate::costmodel::surface::PriceSurface;
use crate::kvcache::{KvCacheManager, PrefixId};
use crate::workload::tenants::{tenant_set, MultiTenantGenerator, TenantSpec};

use super::engine::SimEngine;

/// Build one single-device serving stack for a tenant workload — the
/// canonical sizing (paper-paged KV at block 128, full batch at max
/// length + every tenant's prefix + slack, Eq. 1 threshold policy)
/// shared by the tenancy experiment and every cluster replica.
/// `tests/cluster.rs` pins the 1-replica reduction against a
/// hand-built copy of this wiring, so changes here are caught there.
pub fn tenant_serving_stack(
    model: &ModelConfig,
    hw: &HardwareSpec,
    kernel: KernelKind,
    batch: usize,
    tenants: &[TenantSpec],
    include_prefill: bool,
    parallelism: ParallelismConfig,
) -> Result<Coordinator<SimEngine>> {
    let surface = PriceSurface::shared(model.clone(), hw.clone(), parallelism);
    tenant_serving_stack_with_surface(
        model,
        hw,
        kernel,
        batch,
        tenants,
        include_prefill,
        parallelism,
        &surface,
    )
}

/// The same stack priced against a fleet-shared [`PriceSurface`]
/// (DESIGN.md §17): the cluster router builds one surface and hands it
/// to every replica — including autoscale spin-ups, which previously
/// paid a full cold-memo rebuild — so the whole fleet shares one warm
/// pricing cache.  With a fresh surface this is `tenant_serving_stack`
/// bit-for-bit (the hit/miss *values* never differ, only who computes
/// them first).
#[allow(clippy::too_many_arguments)]
pub fn tenant_serving_stack_with_surface(
    model: &ModelConfig,
    hw: &HardwareSpec,
    kernel: KernelKind,
    batch: usize,
    tenants: &[TenantSpec],
    include_prefill: bool,
    parallelism: ParallelismConfig,
    surface: &Arc<PriceSurface>,
) -> Result<Coordinator<SimEngine>> {
    let block_size = 128; // paper: paged KV with block size 128
    let max_seq_len = 2048;
    let prefix_blocks: usize =
        tenants.iter().map(|t| t.prompt_tokens.div_ceil(block_size)).sum();
    let total_blocks = batch * (max_seq_len / block_size) + prefix_blocks + 64;
    let cfg = ServingConfig {
        block_size,
        max_batch: batch,
        max_seq_len,
        total_blocks,
        kernel,
        ..Default::default()
    };
    // Per-rank Eq. 1: a TP/SP-sharded replica derives its own B_theta
    // (ranks = 1 reproduces the classic single-device value exactly).
    let mut policy = KernelPolicy::from_parallelism(kernel, model, hw, 1, &parallelism);
    policy.attach_surface(surface);
    let kv = KvCacheManager::new(model.clone(), total_blocks, block_size);
    let mut engine = SimEngine::with_surface(
        model.clone(),
        hw.clone(),
        parallelism,
        Arc::clone(surface),
    );
    engine.include_prefill = include_prefill;
    Coordinator::new(cfg, policy, kv, engine)
}

/// One backend's calibration summary on the Table-2-shaped tenancy
/// cell (Kimi K2, B = 1024, L_s = 26472, L_n = 512 — the paper's
/// largest shared-prefix point).  The backend presets are calibrated
/// so this cell reproduces the paper's headline speedup shape: ~3x
/// Typhoon-over-absorb on the NPU, ~3.24x-shaped (strictly larger) on
/// the decode-calibrated GPU — with the per-backend Eq. 1 crossovers
/// alongside (DeepSeek-v3: 61 / 29 classic, 70 / 33 AMLA).
#[derive(Clone, Copy, Debug)]
pub struct CalibrationCell {
    pub backend: Backend,
    pub hw_name: &'static str,
    /// Modeled absorb-baseline time / Typhoon time at the cell.
    pub speedup: f64,
    /// Classic Eq. 1 crossover on this backend (DeepSeek-v3, s_q = 1).
    pub b_theta: usize,
    /// Pairwise crossover against the AMLA-absorb fallback.
    pub amla_theta: usize,
}

/// Evaluate the calibration cell on one backend's preset.
pub fn calibration_cell(backend: Backend) -> CalibrationCell {
    let hw = backend.preset();
    let par = ParallelismConfig::single();
    let cell = kimi_k2();
    let wl = AttentionWorkload::decode(1024, 26472, 512);
    let typhoon = parallel_attention_time(&cell, KernelKind::Typhoon, &wl, &hw, &par);
    let absorb = parallel_attention_time(&cell, KernelKind::Absorb, &wl, &hw, &par);
    let dv3 = deepseek_v3();
    CalibrationCell {
        backend,
        hw_name: hw.name,
        speedup: absorb / typhoon,
        b_theta: parallel_batch_threshold(&dv3, &hw, 1, &par),
        amla_theta: parallel_pair_threshold(&dv3, &hw, 1, &par, KernelKind::AmlaAbsorb),
    }
}

/// Parameters of one multi-tenant experiment.
#[derive(Clone, Debug)]
pub struct TenantSimParams {
    pub model: ModelConfig,
    pub hw: HardwareSpec,
    /// Requested kernel (per-group fall-back applies to Typhoon).
    pub kernel: KernelKind,
    pub batch: usize,
    /// Number of tenants (prefix groups).
    pub tenants: usize,
    /// Zipf exponent of the arrival shares (0 = uniform).
    pub skew: f64,
    /// Total request budget, split per tenant by arrival share.
    pub total_requests: usize,
    pub seed: u64,
    /// Include prefill time in the modeled clock (decode-only by
    /// default, matching the paper's throughput protocol).
    pub include_prefill: bool,
}

impl TenantSimParams {
    pub fn new(
        model: ModelConfig,
        hw: HardwareSpec,
        kernel: KernelKind,
        batch: usize,
        tenants: usize,
        skew: f64,
    ) -> Self {
        TenantSimParams {
            model,
            hw,
            kernel,
            batch,
            tenants,
            skew,
            total_requests: batch * 4,
            seed: 42,
            include_prefill: false,
        }
    }
}

/// Result of one multi-tenant experiment.
#[derive(Clone, Debug)]
pub struct TenantSimReport {
    pub tokens: u64,
    /// Exact accumulated decode seconds (from `Metrics`).
    pub decode_seconds: f64,
    /// Generated tokens per second per layer.
    pub throughput: f64,
    pub iterations: u64,
    pub mean_batch: f64,
    /// Group-iterations per kernel (one count per group per iteration).
    pub typhoon_iters: u64,
    pub absorb_iters: u64,
    pub naive_iters: u64,
    /// Iterations whose groups split across kernels (hot Typhoon +
    /// cold absorb fall-back in the same decode step).
    pub mixed_iters: u64,
    /// Uncompressed shared-prefix expansion held, bytes (all groups).
    pub expansion_bytes: u64,
}

/// Run one multi-tenant experiment over a generated tenant set.
pub fn run_tenant_experiment(params: &TenantSimParams) -> Result<TenantSimReport> {
    let tenants = tenant_set(params.tenants, params.skew);
    run_tenant_experiment_with(params, &tenants)
}

/// Run over an explicit tenant set (callers may hand-craft shares).
pub fn run_tenant_experiment_with(
    params: &TenantSimParams,
    tenants: &[TenantSpec],
) -> Result<TenantSimReport> {
    let mut coord = tenant_serving_stack(
        &params.model,
        &params.hw,
        params.kernel,
        params.batch,
        tenants,
        params.include_prefill,
        ParallelismConfig::single(),
    )?;

    let mut prefix_of: Vec<PrefixId> = Vec::with_capacity(tenants.len());
    for t in tenants {
        prefix_of.push(coord.register_prefix_group(&t.prompt_token_ids(50_000))?);
    }
    let mut gen = MultiTenantGenerator::new(tenants, params.total_requests, params.seed);
    while let Some(tr) = gen.next_request() {
        coord.submit_to(&tr.request, prefix_of[tr.tenant])?;
    }
    coord.run_to_completion()?;

    let m = &coord.metrics;
    Ok(TenantSimReport {
        tokens: m.tokens_generated,
        decode_seconds: m.decode_seconds,
        throughput: if m.decode_seconds > 0.0 {
            m.tokens_generated as f64 / m.decode_seconds
        } else {
            0.0
        },
        iterations: m.decode_iterations,
        mean_batch: m.batch_occupancy.mean(),
        typhoon_iters: m.typhoon_iters,
        absorb_iters: m.absorb_iters,
        naive_iters: m.naive_iters,
        mixed_iters: m.mixed_iters,
        expansion_bytes: coord.kv.expanded_bytes(),
    })
}

/// Run the three deployments (grouped typhoon / global absorb /
/// per-tenant naive) on the same workload.
pub fn run_tenant_comparison(
    params: &TenantSimParams,
) -> Result<[TenantSimReport; 3]> {
    let mut out = Vec::with_capacity(3);
    for kernel in [KernelKind::Typhoon, KernelKind::Absorb, KernelKind::Naive] {
        let mut p = params.clone();
        p.kernel = kernel;
        out.push(run_tenant_experiment(&p)?);
    }
    Ok(out.try_into().map_err(|_| anyhow::anyhow!("3 reports")).unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::ascend_npu;
    use crate::config::model::deepseek_v3;

    fn quick(kernel: KernelKind, tenants: usize, skew: f64, batch: usize) -> TenantSimReport {
        let mut p =
            TenantSimParams::new(deepseek_v3(), ascend_npu(), kernel, batch, tenants, skew);
        p.total_requests = batch * 2;
        run_tenant_experiment(&p).unwrap()
    }

    #[test]
    fn conservation_across_tenants() {
        let r = quick(KernelKind::Typhoon, 3, 1.0, 64);
        assert!(r.tokens > 0);
        assert!(r.iterations > 0);
        assert!(r.throughput > 0.0);
        assert!(r.expansion_bytes > 0, "typhoon expands every group");
    }

    /// Skewed traffic at a healthy batch: the hot group clears B_theta
    /// and runs Typhoon while cold groups fall back — mixed iterations
    /// must occur, and grouped Typhoon must beat the global-absorb
    /// baseline on modeled throughput.
    #[test]
    fn grouped_typhoon_beats_global_absorb_on_skew() {
        let t = quick(KernelKind::Typhoon, 4, 2.0, 256);
        let a = quick(KernelKind::Absorb, 4, 2.0, 256);
        let n = quick(KernelKind::Naive, 4, 2.0, 256);
        assert!(t.mixed_iters > 0, "hot+cold kernel split expected");
        assert!(
            t.throughput >= a.throughput,
            "grouped typhoon {} < global absorb {}",
            t.throughput,
            a.throughput
        );
        assert!(
            t.throughput > n.throughput,
            "grouped typhoon {} <= per-tenant naive {}",
            t.throughput,
            n.throughput
        );
    }

    /// Absorb never mixes (no fall-back concept) and never expands.
    #[test]
    fn absorb_baseline_uniform_and_unexpanded() {
        let a = quick(KernelKind::Absorb, 3, 1.0, 64);
        assert_eq!(a.mixed_iters, 0);
        assert_eq!(a.typhoon_iters, 0);
        assert_eq!(a.expansion_bytes, 0, "absorb keeps latent-only prefixes");
    }

    /// Backend calibration regression (satellite of the kernel-zoo PR):
    /// the NPU and GPU presets reproduce the paper's speedup shape on
    /// the Table-2 cell — ~3x on the NPU, ~3.24x-shaped (strictly
    /// larger) on the decode-calibrated GPU — and never drift out of
    /// their bands when the cost model or presets change.
    #[test]
    fn backend_calibration_reproduces_paper_speedup_shape() {
        let npu = calibration_cell(Backend::Npu);
        let gpu = calibration_cell(Backend::Gpu);
        assert_eq!(npu.hw_name, "ascend-npu");
        assert_eq!(gpu.hw_name, "gpu-h800-decode");
        assert!(
            npu.speedup > 2.95 && npu.speedup < 3.2,
            "NPU cell speedup {} out of the 3x-shaped band",
            npu.speedup
        );
        assert!(
            gpu.speedup > 3.1 && gpu.speedup < 3.35,
            "GPU cell speedup {} out of the 3.24x-shaped band",
            gpu.speedup
        );
        assert!(
            gpu.speedup > npu.speedup,
            "paper ordering: GPU {} must exceed NPU {}",
            gpu.speedup,
            npu.speedup
        );
    }

    /// The per-backend crossover batches are pinned: Ascend keeps the
    /// paper's B_theta = 61 (70 vs the AMLA fallback), the decode-
    /// calibrated GPU lands at 29 (33 AMLA) from its exact T/M = 100.
    #[test]
    fn backend_crossovers_pinned() {
        let npu = calibration_cell(Backend::Npu);
        assert_eq!((npu.b_theta, npu.amla_theta), (61, 70));
        let gpu = calibration_cell(Backend::Gpu);
        assert_eq!((gpu.b_theta, gpu.amla_theta), (29, 33));
    }
}
