//! The serving simulator: reproduces the paper's experimental protocol
//! (§4) — continuous batching with a paged KV-cache over a dataset,
//! repeated per (model x hardware x prompt x batch x kernel) — with the
//! cost-model engine supplying iteration times.

use anyhow::Result;

use crate::config::{HardwareSpec, KernelKind, ModelConfig, ServingConfig};
use crate::coordinator::{Coordinator, KernelPolicy};
use crate::costmodel::parallel::ParallelismConfig;
use crate::kvcache::KvCacheManager;
use crate::metrics::BreakdownTimers;
use crate::workload::{Dataset, RequestGenerator, SystemPrompt};

use super::engine::SimEngine;

/// Parameters of one simulated experiment.
#[derive(Clone, Debug)]
pub struct SimParams {
    pub model: ModelConfig,
    pub hw: HardwareSpec,
    pub kernel: KernelKind,
    pub batch: usize,
    /// Cap on requests processed (None = the whole dataset split, as in
    /// the paper; a cap keeps CI fast).
    pub max_requests: Option<usize>,
    pub seed: u64,
    /// Include prefill time in the modeled clock (the paper's
    /// throughput counts decode iterations; prefill is excluded there).
    pub include_prefill: bool,
    /// Use the memoized + length-bucketed engine hot path (default).
    /// `false` selects the per-sequence reference evaluation — slower,
    /// bit-identical results; `bench_sweep` uses it as the baseline.
    pub memoized_engine: bool,
    /// TP/SP sharding of the modeled device (paper §3.1): per-iteration
    /// costs route through `costmodel::parallel`.  `single()` (default)
    /// is bit-identical to the unsharded engine.
    pub parallelism: ParallelismConfig,
}

impl SimParams {
    pub fn new(model: ModelConfig, hw: HardwareSpec, kernel: KernelKind, batch: usize) -> Self {
        SimParams {
            model,
            hw,
            kernel,
            batch,
            max_requests: None,
            seed: 42,
            include_prefill: false,
            memoized_engine: true,
            parallelism: ParallelismConfig::single(),
        }
    }
}

/// Result of one simulated experiment.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub tokens: u64,
    pub decode_seconds: f64,
    /// Generated tokens per second per layer (Figs. 2-3 y-axis).
    pub throughput: f64,
    pub iterations: u64,
    pub mean_batch: f64,
    pub breakdown: BreakdownTimers,
    pub typhoon_iters: u64,
    pub absorb_iters: u64,
    /// Mean attention time per decode iteration (seconds, per layer).
    pub mean_iter_seconds: f64,
}

/// Run the paper's protocol once.
pub fn run_experiment(
    params: &SimParams,
    dataset: &Dataset,
    prompt: &SystemPrompt,
) -> Result<SimReport> {
    let block_size = 128; // paper: paged KV with block size 128
    let max_seq_len = 2048; // covers question + answer for all datasets
    // Pool: full batch at max length + the shared prefix + slack.
    let prefix_blocks = prompt.tokens.div_ceil(block_size);
    let total_blocks = params.batch * (max_seq_len / block_size) + prefix_blocks + 64;
    let cfg = ServingConfig {
        block_size,
        max_batch: params.batch,
        max_seq_len,
        total_blocks,
        kernel: params.kernel,
        ..Default::default()
    };
    // Per-rank Eq. 1: the threshold follows the stack's TP/SP sharding
    // (ranks = 1 reproduces the classic single-device value exactly).
    let mut policy = KernelPolicy::from_parallelism(
        params.kernel,
        &params.model,
        &params.hw,
        1,
        &params.parallelism,
    );
    let kv = KvCacheManager::new(params.model.clone(), total_blocks, block_size);
    let mut engine = SimEngine::with_parallelism(
        params.model.clone(),
        params.hw.clone(),
        params.parallelism,
    );
    // Policy and engine price against the same surface (registry
    // pricing memoizes into it; values are bit-identical either way).
    policy.attach_surface(engine.surface());
    engine.include_prefill = params.include_prefill;
    engine.memoized = params.memoized_engine;
    let mut coord = Coordinator::new(cfg, policy, kv, engine)?;

    // The shared prefix: register by token count (content-free model).
    let prefix_tokens = prompt.token_ids(50_000);
    coord.set_shared_prefix(&prefix_tokens)?;

    let mut gen = RequestGenerator::new(dataset, prompt.clone(), params.seed);
    if let Some(cap) = params.max_requests {
        gen = gen.take(cap);
    }
    while let Some(req) = gen.next_request() {
        coord.submit(&req)?;
    }
    coord.run_to_completion()?;

    let m = &coord.metrics;
    // Exact accumulated sum from Metrics — not mean() * iterations,
    // which would reintroduce the float round-trip the accumulator
    // exists to avoid.
    let decode_seconds = m.decode_seconds;
    Ok(SimReport {
        tokens: m.tokens_generated,
        decode_seconds,
        throughput: if decode_seconds > 0.0 {
            m.tokens_generated as f64 / decode_seconds
        } else {
            0.0
        },
        iterations: m.decode_iterations,
        mean_batch: m.batch_occupancy.mean(),
        breakdown: m.breakdown.clone(),
        typhoon_iters: m.typhoon_iters,
        absorb_iters: m.absorb_iters,
        mean_iter_seconds: m.iteration_time.mean(),
    })
}

/// Convenience: run all three kernels on the same workload and return
/// (typhoon, absorb, naive) reports.
pub fn run_kernel_comparison(
    model: &ModelConfig,
    hw: &HardwareSpec,
    batch: usize,
    dataset: &Dataset,
    prompt: &SystemPrompt,
    max_requests: Option<usize>,
) -> Result<[SimReport; 3]> {
    let mut out = Vec::new();
    for kernel in [KernelKind::Typhoon, KernelKind::Absorb, KernelKind::Naive] {
        let mut p = SimParams::new(model.clone(), hw.clone(), kernel, batch);
        p.max_requests = max_requests;
        out.push(run_experiment(&p, dataset, prompt)?);
    }
    Ok(out.try_into().map_err(|_| anyhow::anyhow!("3 reports")).unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::ascend_npu;
    use crate::config::model::deepseek_v3;
    use crate::workload::datasets::mmlu;
    use crate::workload::prompts::PROMPT_C;

    fn quick(kernel: KernelKind, batch: usize) -> SimReport {
        let mut p = SimParams::new(deepseek_v3(), ascend_npu(), kernel, batch);
        p.max_requests = Some(batch * 3);
        run_experiment(&p, &mmlu(), &PROMPT_C).unwrap()
    }

    #[test]
    fn conservation_and_occupancy() {
        let r = quick(KernelKind::Typhoon, 64);
        assert!(r.tokens > 0);
        assert!(r.mean_batch > 32.0, "batch stays mostly full: {}", r.mean_batch);
        assert!(r.throughput > 0.0);
    }

    /// The paper's headline: typhoon beats both baselines at large batch
    /// with a long shared prompt.
    #[test]
    fn typhoon_wins_at_large_batch() {
        let t = quick(KernelKind::Typhoon, 256);
        let a = quick(KernelKind::Absorb, 256);
        let n = quick(KernelKind::Naive, 256);
        assert!(
            t.throughput > a.throughput && t.throughput > n.throughput,
            "t={} a={} n={}",
            t.throughput,
            a.throughput,
            n.throughput
        );
    }

    /// TP/SP sharding routes iteration costs through the per-rank
    /// model: same workload/tokens, faster modeled decode.
    #[test]
    fn tp_sp_sharding_raises_modeled_throughput() {
        let mut p = SimParams::new(deepseek_v3(), ascend_npu(), KernelKind::Typhoon, 128);
        p.max_requests = Some(128);
        let single = run_experiment(&p, &mmlu(), &PROMPT_C).unwrap();
        p.parallelism = ParallelismConfig { tp: 4, sp: 4 };
        let sharded = run_experiment(&p, &mmlu(), &PROMPT_C).unwrap();
        assert_eq!(single.tokens, sharded.tokens, "same workload either way");
        assert!(
            sharded.throughput > single.throughput,
            "16 ranks must model faster decode: {} vs {}",
            sharded.throughput,
            single.throughput
        );
    }

    /// Below B_theta typhoon degenerates to absorb-only iterations.
    #[test]
    fn fallback_engaged_below_threshold() {
        let r = quick(KernelKind::Typhoon, 32); // B_theta = 61 on Ascend
        assert_eq!(r.typhoon_iters, 0);
        assert!(r.absorb_iters > 0);
        let a = quick(KernelKind::Absorb, 32);
        let rel = (r.throughput - a.throughput).abs() / a.throughput;
        assert!(rel < 0.05, "fallback ≈ absorb baseline, rel diff {rel}");
    }
}
