//! `SweepExecutor`: deterministic parallel evaluation of independent
//! experiment cells (the Fig. 2/3 grids, `run_kernel_comparison`, and
//! any other embarrassingly-parallel sweep).
//!
//! Each cell of the paper's evaluation grid — (model x hardware x
//! prompt x dataset x batch x kernel) — is a self-contained serving
//! simulation with its own coordinator, KV-cache and seeded RNG; cells
//! share no mutable state.  The executor fans cells out over the
//! process-wide persistent worker pool (`util::pool` — parked threads,
//! no per-sweep spawn cost), stores each result at its cell index, and
//! returns them **in cell order** — so any artifact formatted from the
//! results is byte-identical to a serial run (asserted by
//! `tests/sweep_equivalence.rs`).

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::config::hardware::Backend;
use crate::config::{HardwareSpec, KernelKind, ModelConfig};
use crate::costmodel::flops::AttentionWorkload;
use crate::costmodel::surface::PriceSurface;
use crate::costmodel::parallel::{
    parallel_attention_time, parallel_pair_threshold, parallel_pair_threshold_exact,
    ParallelismConfig,
};
use crate::workload::datasets::all_datasets;
use crate::workload::prompts::all_prompts;
use crate::workload::{Dataset, SystemPrompt};

use super::cluster::{run_cluster_experiment, ClusterParams, ClusterReport, RouterPolicy};
use super::serving_sim::{run_experiment, SimParams, SimReport};
use super::tenancy::{run_tenant_comparison, TenantSimParams, TenantSimReport};

/// Worker-count policy for a sweep.
#[derive(Clone, Copy, Debug)]
pub struct SweepExecutor {
    /// Number of worker threads (1 = run serially on the caller).
    pub threads: usize,
}

impl Default for SweepExecutor {
    fn default() -> Self {
        Self::from_env()
    }
}

impl SweepExecutor {
    /// Strictly serial execution on the calling thread.
    pub fn serial() -> Self {
        SweepExecutor { threads: 1 }
    }

    pub fn with_threads(threads: usize) -> Self {
        SweepExecutor { threads: threads.max(1) }
    }

    /// Parallel over the machine's cores; `TYPHOON_SWEEP_THREADS`
    /// overrides (0 or 1 forces serial).
    pub fn from_env() -> Self {
        if let Ok(v) = std::env::var("TYPHOON_SWEEP_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                return Self::with_threads(n);
            }
        }
        let n = std::thread::available_parallelism().map_or(1, |n| n.get());
        Self::with_threads(n)
    }

    pub fn is_serial(&self) -> bool {
        self.threads <= 1
    }

    /// Evaluate `f(0..n)` and return the results **in index order**.
    /// `f` must be a pure function of its index (all sweep cells are:
    /// they build their own seeded state).  The first error wins and is
    /// returned after all workers drain.
    pub fn run<T, F>(&self, n: usize, f: F) -> Result<Vec<T>>
    where
        T: Send,
        F: Fn(usize) -> Result<T> + Sync,
    {
        if self.is_serial() || n <= 1 {
            return (0..n).map(&f).collect();
        }
        let slots: Vec<Mutex<Option<Result<T>>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        let fill = |i: usize| {
            let out = f(i);
            *slots[i].lock().expect("sweep slot poisoned") = Some(out);
        };
        crate::util::pool::global().run(n, self.threads.min(n), &fill);
        // A worker panic is re-raised by the pool in this thread, so
        // reaching this point means every slot was filled exactly once.
        let mut results = Vec::with_capacity(n);
        for (i, slot) in slots.into_iter().enumerate() {
            let out = slot
                .into_inner()
                .unwrap_or_else(|_| unreachable!("poisoned slot survived scope"))
                .unwrap_or_else(|| unreachable!("sweep cell {i} never ran"));
            results.push(out?);
        }
        Ok(results)
    }
}

/// One cell of the Fig. 2/3 throughput grid (kernel comparison inside).
#[derive(Clone, Debug)]
pub struct ThroughputCell {
    pub model: ModelConfig,
    pub prompt: SystemPrompt,
    pub dataset: Dataset,
    pub batch: usize,
    pub max_requests: Option<usize>,
    /// Engine hot path: memoized + length-bucketed (default `true`).
    /// `false` is the per-sequence reference — `bench_sweep`'s
    /// unmemoized baseline.  Results are bit-identical either way.
    pub memoized: bool,
}

/// The grid in the paper's enumeration order: model (outer) x prompt x
/// dataset x batch (inner) — the order `fig_throughput` formats rows.
pub fn throughput_cells(
    models: &[ModelConfig],
    batches: &[usize],
    max_requests_factor: Option<usize>,
) -> Vec<ThroughputCell> {
    let mut cells = Vec::new();
    for model in models {
        for prompt in all_prompts() {
            for ds in all_datasets() {
                for &b in batches {
                    cells.push(ThroughputCell {
                        model: model.clone(),
                        prompt: prompt.clone(),
                        dataset: ds.clone(),
                        batch: b,
                        max_requests: max_requests_factor.map(|f| f * b),
                        memoized: true,
                    });
                }
            }
        }
    }
    cells
}

/// One evaluated grid cell: (typhoon, absorb, naive) reports.
#[derive(Clone, Debug)]
pub struct ThroughputCellResult {
    pub cell: ThroughputCell,
    pub reports: [SimReport; 3],
}

impl ThroughputCellResult {
    /// Generated tokens summed over the three kernel runs.
    pub fn tokens(&self) -> u64 {
        self.reports.iter().map(|r| r.tokens).sum()
    }
}

/// Evaluate the whole grid on `hw` under the executor.  Results come
/// back in cell order regardless of scheduling.
pub fn run_throughput_sweep(
    hw: &HardwareSpec,
    cells: &[ThroughputCell],
    exec: &SweepExecutor,
) -> Result<Vec<ThroughputCellResult>> {
    exec.run(cells.len(), |i| {
        let c = &cells[i];
        let mut reports = Vec::with_capacity(3);
        for kernel in [KernelKind::Typhoon, KernelKind::Absorb, KernelKind::Naive] {
            let mut p = SimParams::new(c.model.clone(), hw.clone(), kernel, c.batch);
            p.max_requests = c.max_requests;
            p.memoized_engine = c.memoized;
            reports.push(run_experiment(&p, &c.dataset, &c.prompt)?);
        }
        let reports: [SimReport; 3] =
            reports.try_into().expect("exactly three kernel reports");
        Ok(ThroughputCellResult { cell: c.clone(), reports })
    })
}

/// One cell of the `tenants` grid: tenant count x skew, with the
/// three-deployment kernel comparison evaluated inside the cell.
#[derive(Clone, Debug)]
pub struct TenantCell {
    pub model: ModelConfig,
    pub tenants: usize,
    pub skew: f64,
    pub batch: usize,
    pub total_requests: usize,
}

/// The tenants grid in row order: tenant count (outer) x skew (inner).
pub fn tenant_cells(
    model: &ModelConfig,
    tenant_counts: &[usize],
    skews: &[f64],
    batch: usize,
    total_requests: usize,
) -> Vec<TenantCell> {
    let mut cells = Vec::new();
    for &tenants in tenant_counts {
        for &skew in skews {
            cells.push(TenantCell {
                model: model.clone(),
                tenants,
                skew,
                batch,
                total_requests,
            });
        }
    }
    cells
}

/// One evaluated tenants cell: (grouped typhoon, global absorb,
/// per-tenant naive) reports.
#[derive(Clone, Debug)]
pub struct TenantCellResult {
    pub cell: TenantCell,
    pub reports: [TenantSimReport; 3],
}

/// Evaluate the tenants grid on `hw` under the executor; results come
/// back in cell order regardless of scheduling (byte-identical
/// artifacts serial vs parallel, same discipline as the Fig. 2/3 grid).
pub fn run_tenant_sweep(
    hw: &HardwareSpec,
    cells: &[TenantCell],
    exec: &SweepExecutor,
) -> Result<Vec<TenantCellResult>> {
    exec.run(cells.len(), |i| {
        let c = &cells[i];
        let mut p = TenantSimParams::new(
            c.model.clone(),
            hw.clone(),
            KernelKind::Typhoon,
            c.batch,
            c.tenants,
            c.skew,
        );
        p.total_requests = c.total_requests;
        let reports = run_tenant_comparison(&p)?;
        Ok(TenantCellResult { cell: c.clone(), reports })
    })
}

/// One cell of the per-backend B_theta crossover grid: (backend x
/// model x absorb-family fallback), the new grid axis the kernel
/// registry adds to `figures`/`bench_sweep`.  Each cell compares the
/// analytic pairwise Eq. 1 threshold against a numeric scan of the
/// priced curves — the same bracket discipline `tests/registry.rs`
/// fuzzes.
#[derive(Clone, Debug)]
pub struct CrossoverCell {
    pub backend: Backend,
    pub model: ModelConfig,
    /// The absorb-family fallback the naive-family curve crosses.
    pub fallback: KernelKind,
    /// Shared length of the scanned workload (L_n = 0 isolates the
    /// shared-stage trade-off Eq. 1 models).
    pub shared_len: u64,
}

/// The crossover grid in row order: backend (outer) x model x fallback
/// (inner; classic absorb first).
pub fn crossover_cells(
    backends: &[Backend],
    models: &[ModelConfig],
    shared_len: u64,
) -> Vec<CrossoverCell> {
    let mut cells = Vec::new();
    for &backend in backends {
        for model in models {
            for fallback in [KernelKind::Absorb, KernelKind::AmlaAbsorb] {
                cells.push(CrossoverCell {
                    backend,
                    model: model.clone(),
                    fallback,
                    shared_len,
                });
            }
        }
    }
    cells
}

/// One evaluated crossover cell.
#[derive(Clone, Debug)]
pub struct CrossoverCellResult {
    pub cell: CrossoverCell,
    pub hw_name: &'static str,
    /// Exact (real-valued) pairwise Eq. 1 crossover.
    pub analytic_exact: f64,
    /// The integer threshold the registry uses (floored, min 1).
    pub analytic: usize,
    /// First batch in `1..=4096` where the naive-family counterpart's
    /// priced curve stops losing to the fallback's (None if it never
    /// does in range).  Brackets `analytic` within +1 by construction.
    pub numeric: Option<usize>,
}

/// Evaluate the crossover grid under the executor (cells are pure
/// model evaluations; order-stable like every other grid).
pub fn run_crossover_sweep(
    cells: &[CrossoverCell],
    exec: &SweepExecutor,
) -> Result<Vec<CrossoverCellResult>> {
    exec.run(cells.len(), |i| {
        let c = &cells[i];
        let hw = c.backend.preset();
        let par = ParallelismConfig::single();
        let counterpart = match c.fallback {
            KernelKind::AmlaAbsorb => KernelKind::TyphoonAmla,
            _ => KernelKind::Typhoon,
        };
        let numeric = (1u64..=4096).find(|&b| {
            let wl = AttentionWorkload::decode(b, c.shared_len, 0);
            parallel_attention_time(&c.model, counterpart, &wl, &hw, &par)
                <= parallel_attention_time(&c.model, c.fallback, &wl, &hw, &par)
        });
        Ok(CrossoverCellResult {
            cell: c.clone(),
            hw_name: hw.name,
            analytic_exact: parallel_pair_threshold_exact(&c.model, &hw, 1, &par, c.fallback),
            analytic: parallel_pair_threshold(&c.model, &hw, 1, &par, c.fallback),
            numeric: numeric.map(|b| b as usize),
        })
    })
}

/// One cell of the `cluster` grid: (replicas x skew x arrival-profile
/// x router-config), with the router configuration innermost so the
/// formatter can pivot one artifact row per (replicas, skew, profile)
/// out of `cluster_row_configs().len()` consecutive cells.
#[derive(Clone, Debug)]
pub struct ClusterCell {
    pub model: ModelConfig,
    pub replicas: usize,
    pub skew: f64,
    pub router: RouterPolicy,
    /// Cost-driven prefix migration enabled (prefix-affinity only).
    pub migrate: bool,
    /// Replica autoscaling enabled (prefix-affinity only; the fleet
    /// starts at `replicas` and may resize within the default bounds).
    pub autoscale: bool,
    /// Fault injection enabled (prefix-affinity only): one mid-stream
    /// replica crash on multi-replica rows (no crash is schedulable on
    /// a single replica — at least one survivor must remain), recovered
    /// by the failover policy.  The graceful-degradation column.
    pub fault: bool,
    pub tenants: usize,
    pub batch: usize,
    pub total_requests: usize,
    /// Arrival profile: None = the paper's batch protocol (everything
    /// at t = 0); Some((rate, factor)) = Poisson at `rate` with
    /// calm/burst phases at `rate * factor` (factor 1 = plain Poisson).
    pub arrival: Option<(f64, f64)>,
    /// Prefix-affinity pressure threshold for this row's workload.
    pub spill_queue_depth: usize,
}

/// The per-row router configurations of the `cluster` artifact, in
/// column order — `(router, migrate, autoscale, fault)`: baselines,
/// spill-only affinity, migrate-enabled affinity, autoscaled
/// migrate-enabled affinity, and the fault-injected migrate-enabled
/// affinity column last.
pub fn cluster_row_configs() -> [(RouterPolicy, bool, bool, bool); 6] {
    [
        (RouterPolicy::RoundRobin, false, false, false),
        (RouterPolicy::LeastLoaded, false, false, false),
        (RouterPolicy::PrefixAffinity, false, false, false),
        (RouterPolicy::PrefixAffinity, true, false, false),
        (RouterPolicy::PrefixAffinity, true, true, false),
        (RouterPolicy::PrefixAffinity, true, false, true),
    ]
}

/// The cluster grid in row order: replicas (outer) x skew x
/// arrival-profile x router-config (inner, `cluster_row_configs`
/// order).  Every cell of one (replicas, skew, profile) row runs the
/// *same* workload — only the routing/migration/scaling decisions
/// differ.  Bursty rows tighten the pressure threshold to a quarter
/// of the batch (a burst must actually pressure the home for the
/// relief policies to differ); batch-protocol rows keep the
/// `ClusterParams` default, so the pre-autoscale columns reproduce
/// the PR 4 grid on those rows.
pub fn cluster_cells(
    model: &ModelConfig,
    replica_counts: &[usize],
    skews: &[f64],
    arrivals: &[Option<(f64, f64)>],
    tenants: usize,
    batch: usize,
    total_requests: usize,
) -> Vec<ClusterCell> {
    let mut cells = Vec::new();
    for &replicas in replica_counts {
        for &skew in skews {
            for &arrival in arrivals {
                let bursty = arrival.is_some_and(|(_, f)| f > 1.0);
                let spill_queue_depth =
                    if bursty { (batch / 4).max(1) } else { (2 * batch).max(1) };
                for (router, migrate, autoscale, fault) in cluster_row_configs() {
                    cells.push(ClusterCell {
                        model: model.clone(),
                        replicas,
                        skew,
                        router,
                        migrate,
                        autoscale,
                        fault,
                        tenants,
                        batch,
                        total_requests,
                        arrival,
                        spill_queue_depth,
                    });
                }
            }
        }
    }
    cells
}

/// One evaluated cluster cell.
#[derive(Clone, Debug)]
pub struct ClusterCellResult {
    pub cell: ClusterCell,
    pub report: ClusterReport,
}

/// Evaluate the cluster grid on `hw` under the executor; results come
/// back in cell order regardless of scheduling (byte-identical
/// artifacts serial vs parallel, same discipline as every other grid).
pub fn run_cluster_sweep(
    hw: &HardwareSpec,
    cells: &[ClusterCell],
    exec: &SweepExecutor,
) -> Result<Vec<ClusterCellResult>> {
    // One warm price surface for the whole grid: sibling cells share
    // `(model, hw, parallelism)`, so a sweep worker hits the memo a
    // neighboring cell already filled instead of re-pricing the same
    // workloads cold.  A cell that prices a different combination
    // (mixed-model grids) silently gets a private surface inside
    // `ClusterSim::new` — results are bit-identical either way.
    let surface = cells.first().map(|c| {
        PriceSurface::shared(c.model.clone(), hw.clone(), ParallelismConfig::single())
    });
    exec.run(cells.len(), |i| {
        let c = &cells[i];
        let mut p = ClusterParams::new(
            c.model.clone(),
            hw.clone(),
            c.replicas,
            c.router,
            c.batch,
            c.tenants,
            c.skew,
        );
        p.total_requests = c.total_requests;
        p.arrival_rate = c.arrival.map(|(rate, _)| rate);
        p.arrival_burst = c.arrival.and_then(|(_, f)| (f > 1.0).then_some(f));
        p.spill_queue_depth = c.spill_queue_depth;
        p.migrate = c.migrate;
        p.scaling.enabled = c.autoscale;
        if c.fault {
            // One mid-stream crash, seeded off the workload seed so the
            // column replays byte-identically across executors.  A
            // single-replica row schedules nothing (no survivor would
            // remain) and stays bit-identical to its migrate column.
            p.faults.enabled = true;
            p.faults.seed = p.seed;
            p.faults.crashes = if c.replicas > 1 { 1 } else { 0 };
        }
        p.surface = surface.as_ref().map(Arc::clone);
        let report = run_cluster_experiment(&p)?;
        Ok(ClusterCellResult { cell: c.clone(), report })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::ascend_npu;
    use crate::config::model::deepseek_v3;

    #[test]
    fn ordered_results_under_parallelism() {
        let exec = SweepExecutor::with_threads(4);
        let out = exec.run(37, |i| Ok(i * i)).unwrap();
        assert_eq!(out, (0..37).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn serial_and_parallel_agree() {
        let serial = SweepExecutor::serial().run(16, |i| Ok(i as u64 + 7)).unwrap();
        let par = SweepExecutor::with_threads(8).run(16, |i| Ok(i as u64 + 7)).unwrap();
        assert_eq!(serial, par);
    }

    #[test]
    fn errors_propagate() {
        let exec = SweepExecutor::with_threads(3);
        let out = exec.run(8, |i| {
            if i == 5 {
                anyhow::bail!("cell 5 exploded")
            } else {
                Ok(i)
            }
        });
        assert!(out.is_err());
    }

    #[test]
    fn cell_enumeration_matches_paper_order() {
        let cells = throughput_cells(&[deepseek_v3()], &[64, 128], Some(2));
        // 1 model x 3 prompts x 3 datasets x 2 batches.
        assert_eq!(cells.len(), 18);
        assert_eq!(cells[0].batch, 64);
        assert_eq!(cells[1].batch, 128);
        assert_eq!(cells[0].prompt.name, cells[5].prompt.name);
        assert_eq!(cells[0].max_requests, Some(128));
    }

    #[test]
    fn cluster_cell_enumeration_row_order() {
        let bursty = Some((200.0, 50.0));
        let cells =
            cluster_cells(&deepseek_v3(), &[1, 2], &[0.0, 2.0], &[None, bursty], 4, 32, 64);
        // 2 replica counts x 2 skews x 2 profiles x 6 router configs,
        // config innermost, profile next.
        assert_eq!(cells.len(), 48);
        assert_eq!(
            (cells[0].replicas, cells[0].skew, cells[0].router, cells[0].migrate),
            (1, 0.0, RouterPolicy::RoundRobin, false)
        );
        assert_eq!(
            (cells[2].router, cells[2].migrate, cells[2].autoscale, cells[2].fault),
            (RouterPolicy::PrefixAffinity, false, false, false)
        );
        assert_eq!(
            (cells[3].router, cells[3].migrate, cells[3].autoscale, cells[3].fault),
            (RouterPolicy::PrefixAffinity, true, false, false)
        );
        assert_eq!(
            (cells[4].router, cells[4].migrate, cells[4].autoscale, cells[4].fault),
            (RouterPolicy::PrefixAffinity, true, true, false)
        );
        assert_eq!(
            (cells[5].router, cells[5].migrate, cells[5].autoscale, cells[5].fault),
            (RouterPolicy::PrefixAffinity, true, false, true)
        );
        assert_eq!(cells[0].arrival, None);
        assert_eq!(cells[6].arrival, bursty, "profile pivots inside one skew");
        assert_eq!((cells[12].replicas, cells[12].skew), (1, 2.0));
        assert_eq!((cells[47].replicas, cells[47].skew), (2, 2.0));
        assert_eq!(cells[47].arrival, bursty);
        // Batch rows keep the PR 4 threshold; bursty rows tighten it.
        assert_eq!(cells[0].spill_queue_depth, 64);
        assert_eq!(cells[6].spill_queue_depth, 8);
        // Baselines never migrate, autoscale, or inject faults.
        assert!(cells
            .iter()
            .all(|c| c.router == RouterPolicy::PrefixAffinity
                || (!c.migrate && !c.autoscale && !c.fault)));
    }

    /// Cluster sweep determinism: serial and parallel executors produce
    /// bitwise-equal reports per cell — including the bursty autoscale
    /// cells (scale decisions are pure functions of the modeled state).
    #[test]
    fn cluster_sweep_deterministic_across_executors() {
        let hw = ascend_npu();
        let cells = cluster_cells(
            &deepseek_v3(),
            &[2],
            &[1.0],
            &[None, Some((150.0, 40.0))],
            3,
            16,
            32,
        );
        let serial = run_cluster_sweep(&hw, &cells, &SweepExecutor::serial()).unwrap();
        let par = run_cluster_sweep(&hw, &cells, &SweepExecutor::with_threads(3)).unwrap();
        for (s, p) in serial.iter().zip(&par) {
            assert_eq!(s.report.tokens, p.report.tokens);
            assert_eq!(s.report.requests_completed, p.report.requests_completed);
            assert_eq!(s.report.goodput.to_bits(), p.report.goodput.to_bits());
            assert_eq!(s.report.makespan.to_bits(), p.report.makespan.to_bits());
            assert_eq!(s.report.ttft_p99.to_bits(), p.report.ttft_p99.to_bits());
            assert_eq!(s.report.spills, p.report.spills);
            assert_eq!(s.report.migrations, p.report.migrations);
            assert_eq!(s.report.scale_ups, p.report.scale_ups);
            assert_eq!(s.report.scale_downs, p.report.scale_downs);
            assert_eq!(s.report.active_replicas, p.report.active_replicas);
            assert_eq!(s.report.crashes, p.report.crashes);
            assert_eq!(s.report.requeued_requests, p.report.requeued_requests);
            assert_eq!(s.report.lost_pages, p.report.lost_pages);
            assert_eq!(
                s.report.recovery_p99_s.to_bits(),
                p.report.recovery_p99_s.to_bits()
            );
        }
    }

    /// The crossover grid: enumeration order, analytic-vs-numeric
    /// bracketing on every cell, and the per-backend pinned values on
    /// DeepSeek-v3 (the `figures`/`bench_sweep` crossover axis).
    #[test]
    fn crossover_grid_brackets_and_pins() {
        let cells = crossover_cells(
            &[Backend::Npu, Backend::Gpu],
            &[deepseek_v3(), crate::config::model::kimi_k2()],
            4096,
        );
        // 2 backends x 2 models x 2 fallbacks, fallback innermost.
        assert_eq!(cells.len(), 8);
        assert_eq!(cells[0].fallback, KernelKind::Absorb);
        assert_eq!(cells[1].fallback, KernelKind::AmlaAbsorb);
        let serial = run_crossover_sweep(&cells, &SweepExecutor::serial()).unwrap();
        let par = run_crossover_sweep(&cells, &SweepExecutor::with_threads(4)).unwrap();
        for (s, p) in serial.iter().zip(&par) {
            assert_eq!(s.analytic, p.analytic);
            assert_eq!(s.numeric, p.numeric);
            assert_eq!(s.analytic_exact.to_bits(), p.analytic_exact.to_bits());
            // Bracket: the priced scan crosses at the analytic value or
            // one past it (flooring), never anywhere else.
            let n = s.numeric.expect("crossover exists in range");
            assert!(
                n == s.analytic || n == s.analytic + 1,
                "{} {} {:?}: numeric {} vs analytic {}",
                s.hw_name,
                s.cell.model.name,
                s.cell.fallback,
                n,
                s.analytic
            );
        }
        // DeepSeek-v3 pins: NPU 61/70, decode-GPU 29/33 (model index 0).
        assert_eq!(serial[0].analytic, 61);
        assert_eq!(serial[1].analytic, 70);
        assert_eq!(serial[4].analytic, 29);
        assert_eq!(serial[5].analytic, 33);
    }

    #[test]
    fn tenant_cell_enumeration_row_order() {
        let cells = tenant_cells(&deepseek_v3(), &[1, 4], &[0.0, 2.0], 64, 128);
        assert_eq!(cells.len(), 4);
        assert_eq!((cells[0].tenants, cells[0].skew), (1, 0.0));
        assert_eq!((cells[1].tenants, cells[1].skew), (1, 2.0));
        assert_eq!((cells[3].tenants, cells[3].skew), (4, 2.0));
    }

    /// Tenant sweep determinism: serial and parallel executors produce
    /// bitwise-equal reports per cell.
    #[test]
    fn tenant_sweep_deterministic_across_executors() {
        let hw = ascend_npu();
        let cells = tenant_cells(&deepseek_v3(), &[1, 2], &[1.0], 32, 64);
        let serial = run_tenant_sweep(&hw, &cells, &SweepExecutor::serial()).unwrap();
        let par = run_tenant_sweep(&hw, &cells, &SweepExecutor::with_threads(2)).unwrap();
        for (s, p) in serial.iter().zip(&par) {
            for k in 0..3 {
                assert_eq!(s.reports[k].tokens, p.reports[k].tokens);
                assert_eq!(
                    s.reports[k].throughput.to_bits(),
                    p.reports[k].throughput.to_bits()
                );
                assert_eq!(s.reports[k].iterations, p.reports[k].iterations);
            }
        }
    }

    /// A tiny real sweep: parallel report values equal the serial ones
    /// exactly (deterministic seeds, no shared state).
    #[test]
    fn real_cells_deterministic_across_executors() {
        let hw = ascend_npu();
        let cells = throughput_cells(&[deepseek_v3()], &[64], Some(1));
        let cells = &cells[..3]; // keep the test quick
        let serial = run_throughput_sweep(&hw, cells, &SweepExecutor::serial()).unwrap();
        let par =
            run_throughput_sweep(&hw, cells, &SweepExecutor::with_threads(3)).unwrap();
        for (s, p) in serial.iter().zip(&par) {
            for k in 0..3 {
                assert_eq!(s.reports[k].tokens, p.reports[k].tokens);
                assert_eq!(s.reports[k].throughput, p.reports[k].throughput);
                assert_eq!(s.reports[k].iterations, p.reports[k].iterations);
            }
        }
    }
}
