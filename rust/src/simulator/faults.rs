//! Deterministic fault injection for the cluster simulator.
//!
//! A [`FaultPlan`] is materialized once from a seeded [`FaultConfig`]
//! (via `util::rng`, so the schedule replays byte-identically across
//! serial and parallel sweep executors) and then consumed by
//! `simulator::cluster` at arrival boundaries.  Three fault families:
//!
//! * **Crash / stall events** — a replica dies (lifecycle `Failed`,
//!   recovered by `policy::recovery` failover) or goes silent for a
//!   sampled window while keeping its state.
//! * **Interconnect degradation windows** — the realized bandwidth of a
//!   replica pair is scaled by `degrade_factor` (0 = partition) for a
//!   span of arrivals; applied to transfer pricing at the call site so
//!   `PolicyEngine` memos are never poisoned by transient conditions.
//! * **Transfer loss** — a dedicated coin stream decides whether an
//!   in-flight `PrefixExport` arrives truncated or not at all, driving
//!   the recovery layer's retry-with-backoff path.
//!
//! An empty plan (disabled config, or an enabled config that schedules
//! nothing) is structurally inert: `is_empty()` gates every fault hook
//! in the cluster, so the fault-free path stays bit-identical.

use crate::config::FaultConfig;
use crate::util::rng::Rng;

/// Fraction of the arrival stream before the first fault may fire and
/// after the last may fire: faults land in the middle three fifths so
/// every schedule has traffic both before and after the disruption.
const SPAN_LEAD: usize = 5;

/// What a scheduled fault does when delivered.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// The replica dies: in-flight sequences are re-queued by the
    /// recovery layer, its pages are lost, lifecycle becomes `Failed`.
    Crash { replica: usize },
    /// The replica goes silent for `seconds` (clock advances, no work).
    Stall { replica: usize, seconds: f64 },
}

/// A fault scheduled at an arrival boundary: delivered just before the
/// arrival with index `at_arrival` is routed.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    pub at_arrival: usize,
    pub kind: FaultKind,
}

/// One interconnect degradation window: transfers between replicas
/// `a` and `b` (unordered pair) see their bandwidth scaled by `factor`
/// while the routed arrival index sits in `[from_arrival, to_arrival)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DegradeWindow {
    pub a: usize,
    pub b: usize,
    pub from_arrival: usize,
    pub to_arrival: usize,
    pub factor: f64,
}

/// A fully materialized, replayable fault schedule.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    /// Crash/stall events sorted by `at_arrival` (stable order).
    events: Vec<FaultEvent>,
    windows: Vec<DegradeWindow>,
    transfer_loss: f64,
    /// Dedicated coin stream for transfer-loss draws; `None` when the
    /// loss probability is zero so the fault-free path draws nothing.
    coin: Option<Rng>,
    cursor: usize,
}

impl FaultPlan {
    /// The inert plan: schedules nothing, draws nothing.
    pub fn empty() -> Self {
        FaultPlan {
            events: Vec::new(),
            windows: Vec::new(),
            transfer_loss: 0.0,
            coin: None,
            cursor: 0,
        }
    }

    /// True when the plan can never perturb a run.  The cluster gates
    /// every fault hook on this, which is what makes the empty plan
    /// bit-identical to the fault-free path.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty() && self.windows.is_empty() && self.coin.is_none()
    }

    /// Materialize a schedule for a fleet of `replicas` serving
    /// `total_arrivals` requests.  Deterministic in `cfg.seed`; a
    /// disabled config — or an enabled one that schedules nothing —
    /// yields the empty plan without constructing an RNG.
    pub fn build(cfg: &FaultConfig, replicas: usize, total_arrivals: usize) -> Self {
        let scheduled = cfg.crashes + cfg.stalls + cfg.degradations;
        if !cfg.enabled || (scheduled == 0 && cfg.transfer_loss <= 0.0) {
            return FaultPlan::empty();
        }
        let mut rng =
            Rng::new(cfg.seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(0xFA01));
        // Faults land in the middle of the arrival stream so each
        // schedule has traffic both before and after the disruption.
        let lo = total_arrivals / SPAN_LEAD;
        let hi = (total_arrivals - total_arrivals / SPAN_LEAD).max(lo + 1);
        let mut events = Vec::with_capacity(cfg.crashes + cfg.stalls);
        // Crashes hit distinct replicas (validation already capped the
        // count below the fleet size).
        let mut victims: Vec<usize> = (0..replicas).collect();
        rng.shuffle(&mut victims);
        for &replica in victims.iter().take(cfg.crashes.min(replicas.saturating_sub(1))) {
            let at_arrival = rng.gen_range_usize(lo, hi);
            events.push(FaultEvent { at_arrival, kind: FaultKind::Crash { replica } });
        }
        for _ in 0..cfg.stalls {
            let replica = rng.gen_range_usize(0, replicas);
            let seconds = 0.05 + 0.45 * rng.next_f64();
            let at_arrival = rng.gen_range_usize(lo, hi);
            events.push(FaultEvent { at_arrival, kind: FaultKind::Stall { replica, seconds } });
        }
        events.sort_by_key(|e| e.at_arrival);
        let mut windows = Vec::with_capacity(cfg.degradations);
        if replicas >= 2 {
            for _ in 0..cfg.degradations {
                let a = rng.gen_range_usize(0, replicas);
                let mut b = rng.gen_range_usize(0, replicas - 1);
                if b >= a {
                    b += 1;
                }
                let from_arrival = rng.gen_range_usize(lo, hi);
                let len = rng.gen_range_usize(1, (total_arrivals / 4).max(2));
                windows.push(DegradeWindow {
                    a,
                    b,
                    from_arrival,
                    to_arrival: from_arrival + len,
                    factor: cfg.degrade_factor,
                });
            }
        }
        let coin = (cfg.transfer_loss > 0.0).then(|| {
            Rng::new(cfg.seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(0xFA02))
        });
        FaultPlan {
            events,
            windows,
            transfer_loss: cfg.transfer_loss,
            coin,
            cursor: 0,
        }
    }

    /// Drain the next event due at or before `arrival_idx`, if any.
    /// Events come back in schedule order; call in a loop to deliver
    /// everything due at a boundary.
    pub fn pop_due(&mut self, arrival_idx: usize) -> Option<FaultEvent> {
        let ev = self.events.get(self.cursor)?;
        if ev.at_arrival <= arrival_idx {
            self.cursor += 1;
            Some(*ev)
        } else {
            None
        }
    }

    /// Realized-bandwidth multiplier for a transfer between replicas
    /// `x` and `y` while routing arrival `arrival_idx`: the product of
    /// every active degradation window covering the (unordered) pair.
    /// 1.0 outside all windows; 0.0 means the pair is partitioned.
    pub fn bw_factor(&self, x: usize, y: usize, arrival_idx: usize) -> f64 {
        let mut f = 1.0;
        for w in &self.windows {
            let pair = (w.a == x && w.b == y) || (w.a == y && w.b == x);
            if pair && (w.from_arrival..w.to_arrival).contains(&arrival_idx) {
                f *= w.factor;
            }
        }
        f
    }

    /// Coin flip: is this transfer attempt lost (or truncated) in
    /// flight?  Draws from the dedicated loss stream; always false —
    /// and draws nothing — when the loss probability is zero.
    pub fn transfer_lost(&mut self) -> bool {
        match self.coin.as_mut() {
            None => false,
            Some(rng) => rng.next_f64() < self.transfer_loss,
        }
    }

    /// Scheduled crash/stall events (schedule order), for audits.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Scheduled degradation windows, for audits.
    pub fn windows(&self) -> &[DegradeWindow] {
        &self.windows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(seed: u64) -> FaultConfig {
        FaultConfig {
            enabled: true,
            seed,
            crashes: 2,
            stalls: 3,
            degradations: 2,
            transfer_loss: 0.25,
            degrade_factor: 0.1,
        }
    }

    #[test]
    fn disabled_or_zero_intensity_plans_are_empty() {
        let plan = FaultPlan::build(&FaultConfig::disabled(), 4, 100);
        assert!(plan.is_empty());
        let mut enabled_but_inert = FaultConfig::disabled();
        enabled_but_inert.enabled = true;
        let plan = FaultPlan::build(&enabled_but_inert, 4, 100);
        assert!(plan.is_empty(), "enabled with nothing scheduled is still inert");
        assert!(FaultPlan::empty().is_empty());
    }

    #[test]
    fn build_is_deterministic_in_the_seed() {
        let a = FaultPlan::build(&cfg(7), 4, 200);
        let b = FaultPlan::build(&cfg(7), 4, 200);
        assert_eq!(a.events(), b.events());
        assert_eq!(a.windows(), b.windows());
        let c = FaultPlan::build(&cfg(8), 4, 200);
        assert!(
            a.events() != c.events() || a.windows() != c.windows(),
            "different seeds draw different schedules"
        );
    }

    #[test]
    fn crashes_hit_distinct_replicas_inside_the_traffic_span() {
        let plan = FaultPlan::build(&cfg(11), 4, 200);
        let mut crashed = Vec::new();
        for e in plan.events() {
            assert!((40..=160).contains(&e.at_arrival), "mid-stream: {e:?}");
            if let FaultKind::Crash { replica } = e.kind {
                assert!(replica < 4);
                assert!(!crashed.contains(&replica), "distinct victims");
                crashed.push(replica);
            }
        }
        assert_eq!(crashed.len(), 2);
        let stalls = plan
            .events()
            .iter()
            .filter(|e| matches!(e.kind, FaultKind::Stall { .. }))
            .count();
        assert_eq!(stalls, 3);
    }

    #[test]
    fn pop_due_drains_in_schedule_order() {
        let mut plan = FaultPlan::build(&cfg(3), 4, 200);
        let total = plan.events().len();
        assert!(plan.pop_due(0).is_none(), "nothing due before the span");
        let mut seen = 0;
        let mut last = 0;
        while let Some(ev) = plan.pop_due(usize::MAX) {
            assert!(ev.at_arrival >= last, "sorted delivery");
            last = ev.at_arrival;
            seen += 1;
        }
        assert_eq!(seen, total);
        assert!(plan.pop_due(usize::MAX).is_none(), "drained");
    }

    #[test]
    fn bw_factor_is_symmetric_and_windowed() {
        let mut plan = FaultPlan::empty();
        plan.windows.push(DegradeWindow {
            a: 0,
            b: 2,
            from_arrival: 10,
            to_arrival: 20,
            factor: 0.5,
        });
        assert_eq!(plan.bw_factor(0, 2, 15), 0.5);
        assert_eq!(plan.bw_factor(2, 0, 15), 0.5, "pair is unordered");
        assert_eq!(plan.bw_factor(0, 2, 20), 1.0, "window is half-open");
        assert_eq!(plan.bw_factor(0, 1, 15), 1.0, "other pairs untouched");
        plan.windows.push(DegradeWindow {
            a: 2,
            b: 0,
            from_arrival: 12,
            to_arrival: 18,
            factor: 0.0,
        });
        assert_eq!(plan.bw_factor(0, 2, 15), 0.0, "overlapping windows compound");
    }

    #[test]
    fn transfer_loss_coin_matches_probability_and_zero_never_fires() {
        let mut plan = FaultPlan::build(&cfg(5), 4, 200);
        let n = 10_000;
        let lost = (0..n).filter(|_| plan.transfer_lost()).count();
        let rate = lost as f64 / n as f64;
        assert!((rate - 0.25).abs() < 0.03, "rate {rate}");
        let mut lossless = cfg(5);
        lossless.transfer_loss = 0.0;
        let mut plan = FaultPlan::build(&lossless, 4, 200);
        assert!((0..1000).all(|_| !plan.transfer_lost()));
    }
}
