//! Cluster-scale serving: N replicas — each a full `Coordinator` +
//! `SimEngine` + `KvCacheManager` stack, optionally TP/SP-sharded —
//! fed by a timed (Poisson) arrival process through a router with
//! pluggable policies.
//!
//! The paper's Typhoon win comes from *concentrating* sequences that
//! share a prefix into one batch (Eq. 1 amortizes the shared stage
//! over group occupancy).  At fleet scale that concentration is a
//! **routing** decision: round-robin sprays every prefix group across
//! all replicas (each replica pays every group's shared-stage stream
//! at a fraction of the occupancy), while **prefix-affinity** sticks
//! each group to the replica already holding its pages — full
//! occupancy per group, one stream per prefix fleet-wide — and spills
//! to the least-loaded peer only under pressure (recorded, so the
//! "one group, one replica" invariant is auditable).
//!
//! The simulation is event-driven over modeled time: each replica owns
//! an independent clock (its coordinator's `now`), and the cluster
//! repeatedly processes the earliest event — the next arrival, or one
//! decode step of the earliest-clock busy replica.  Idle replicas
//! fast-forward to the arrival that wakes them.  With one replica,
//! round-robin routing and `ParallelismConfig::single()`, the whole
//! machinery reduces bit-for-bit to the single-device tenancy path
//! (pinned by `tests/cluster.rs`).

use std::collections::{HashMap, HashSet};

use anyhow::{bail, Result};

use crate::config::{HardwareSpec, KernelKind, ModelConfig};
use crate::coordinator::Coordinator;
use crate::costmodel::parallel::ParallelismConfig;
use crate::kvcache::PrefixId;
use crate::metrics::Metrics;
use crate::util::stats::{p50, p95, p99};
use crate::workload::tenants::{tenant_set, timed_arrivals, TenantSpec, TimedArrival};

use super::engine::SimEngine;
use super::tenancy::tenant_serving_stack;

/// Pluggable routing policy of the cluster front door.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouterPolicy {
    /// Arrival i goes to replica i mod N.
    RoundRobin,
    /// Fewest outstanding requests (queued + running), lowest index on
    /// ties.
    LeastLoaded,
    /// Stick each prefix group to the replica already holding its
    /// pages; spill to the least-loaded peer under queue/KV pressure.
    PrefixAffinity,
}

impl RouterPolicy {
    pub fn as_str(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "round-robin",
            RouterPolicy::LeastLoaded => "least-loaded",
            RouterPolicy::PrefixAffinity => "prefix-affinity",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "round-robin" | "rr" => RouterPolicy::RoundRobin,
            "least-loaded" | "ll" => RouterPolicy::LeastLoaded,
            "prefix-affinity" | "affinity" => RouterPolicy::PrefixAffinity,
            _ => bail!(
                "unknown router policy {s:?} (round-robin|least-loaded|prefix-affinity)"
            ),
        })
    }

    /// Artifact/grid order: baselines first, affinity last.
    pub fn all() -> [RouterPolicy; 3] {
        [
            RouterPolicy::RoundRobin,
            RouterPolicy::LeastLoaded,
            RouterPolicy::PrefixAffinity,
        ]
    }
}

/// Parameters of one cluster experiment.
#[derive(Clone, Debug)]
pub struct ClusterParams {
    pub model: ModelConfig,
    pub hw: HardwareSpec,
    /// Requested kernel (per-group fall-back applies to Typhoon).
    pub kernel: KernelKind,
    /// Number of serving replicas.
    pub replicas: usize,
    pub router: RouterPolicy,
    /// TP/SP sharding of every replica (`single()` = one device each).
    pub parallelism: ParallelismConfig,
    /// Per-replica decode batch capacity.
    pub batch: usize,
    /// Number of tenants (prefix groups) in the workload.
    pub tenants: usize,
    /// Zipf exponent of the arrival shares (0 = uniform).
    pub skew: f64,
    /// Total request budget across the cluster.
    pub total_requests: usize,
    /// Poisson arrival rate, requests/second; `None` drops the whole
    /// stream at t = 0 (the paper's batch protocol).
    pub arrival_rate: Option<f64>,
    pub seed: u64,
    /// Include prefill time in the modeled clocks (decode-only by
    /// default, matching the paper's throughput protocol).
    pub include_prefill: bool,
    /// Prefix-affinity spill threshold: abandon stickiness for one
    /// request when the home replica's queue depth reaches this.
    pub spill_queue_depth: usize,
}

impl ClusterParams {
    pub fn new(
        model: ModelConfig,
        hw: HardwareSpec,
        replicas: usize,
        router: RouterPolicy,
        batch: usize,
        tenants: usize,
        skew: f64,
    ) -> Self {
        ClusterParams {
            model,
            hw,
            kernel: KernelKind::Typhoon,
            replicas,
            router,
            parallelism: ParallelismConfig::single(),
            batch,
            tenants,
            skew,
            total_requests: batch * replicas.max(1) * 4,
            arrival_rate: None,
            seed: 42,
            include_prefill: false,
            spill_queue_depth: (2 * batch).max(1),
        }
    }
}

/// One replica: a full single-device serving stack plus the router's
/// view of which tenants it hosts.
struct Replica {
    coord: Coordinator<SimEngine>,
    /// Tenant -> prefix group registered on this replica (pages held).
    prefix_of: HashMap<usize, PrefixId>,
    /// Requests routed here.
    routed: u64,
}

/// Router state (policy + stickiness bookkeeping).
struct Router {
    policy: RouterPolicy,
    rr_next: usize,
    /// Prefix-affinity home replica per tenant.
    home: HashMap<usize, usize>,
    spills: u64,
    spilled: HashSet<usize>,
}

impl Router {
    fn new(policy: RouterPolicy) -> Self {
        Router {
            policy,
            rr_next: 0,
            home: HashMap::new(),
            spills: 0,
            spilled: HashSet::new(),
        }
    }

    fn least_loaded(replicas: &[Replica]) -> usize {
        Self::least_loaded_except(replicas, None)
    }

    /// Least-loaded replica, optionally excluding one index (spill
    /// target selection); lowest index wins ties.
    fn least_loaded_except(replicas: &[Replica], exclude: Option<usize>) -> usize {
        let mut best: Option<usize> = None;
        for (i, r) in replicas.iter().enumerate() {
            if Some(i) == exclude {
                continue;
            }
            let better = match best {
                None => true,
                Some(b) => r.coord.load() < replicas[b].coord.load(),
            };
            if better {
                best = Some(i);
            }
        }
        best.expect("at least one candidate replica")
    }

    /// Pick the replica for one arrival, probing replica queue depth,
    /// load and KV headroom.
    fn route(
        &mut self,
        tenant: usize,
        context_len: usize,
        replicas: &[Replica],
        spill_queue_depth: usize,
    ) -> usize {
        match self.policy {
            RouterPolicy::RoundRobin => {
                let r = self.rr_next % replicas.len();
                self.rr_next += 1;
                r
            }
            RouterPolicy::LeastLoaded => Self::least_loaded(replicas),
            RouterPolicy::PrefixAffinity => match self.home.get(&tenant).copied() {
                None => {
                    // First sighting: adopt the least-loaded replica as
                    // the group's home (it will hold the pages).
                    let r = Self::least_loaded(replicas);
                    self.home.insert(tenant, r);
                    r
                }
                Some(home) => {
                    let h = &replicas[home].coord;
                    let pressured = h.queued() >= spill_queue_depth
                        || !h.can_admit_now(context_len);
                    if pressured && replicas.len() > 1 {
                        // Spill this one request around the pressured
                        // home — the group's pages stay where they are,
                        // and the spill is recorded for the invariant
                        // audit (a group on two replicas implies a
                        // recorded spill).
                        let alt = Self::least_loaded_except(replicas, Some(home));
                        if replicas[alt].coord.load() < h.load() {
                            self.spills += 1;
                            self.spilled.insert(tenant);
                            return alt;
                        }
                    }
                    home
                }
            },
        }
    }
}

/// Per-replica slice of a finished cluster run.
#[derive(Clone, Debug)]
pub struct ReplicaReport {
    pub tokens: u64,
    pub requests_completed: u64,
    pub decode_seconds: f64,
    pub iterations: u64,
    pub mean_batch: f64,
    pub typhoon_iters: u64,
    pub absorb_iters: u64,
    pub naive_iters: u64,
    pub mixed_iters: u64,
    pub preemptions: u64,
    /// Prefix groups hosted (pages held) on this replica.
    pub prefix_groups: usize,
    /// Requests the router sent here.
    pub routed: u64,
    /// The replica's final clock (arrival-to-drain span).
    pub final_clock: f64,
}

/// Aggregate result of one cluster experiment.
#[derive(Clone, Debug)]
pub struct ClusterReport {
    pub replicas: Vec<ReplicaReport>,
    pub tokens: u64,
    pub requests_completed: u64,
    /// Aggregate busy decode seconds across replicas.
    pub decode_seconds: f64,
    /// Cluster goodput: generated tokens per aggregate replica decode
    /// second — the paper's decode-time throughput metric lifted to the
    /// fleet (it prices the shared-stage streams every replica pays,
    /// which is exactly what routing concentration buys back).
    pub goodput: f64,
    /// Latest replica clock: the wall span from first arrival to drain.
    pub makespan: f64,
    pub ttft_p50: f64,
    pub ttft_p95: f64,
    pub ttft_p99: f64,
    pub tpot_p50: f64,
    pub tpot_p95: f64,
    pub tpot_p99: f64,
    /// Prefix-affinity requests routed off their home replica.
    pub spills: u64,
}

/// The event-driven N-replica serving simulation.
pub struct ClusterSim {
    params: ClusterParams,
    tenants: Vec<TenantSpec>,
    arrivals: Vec<TimedArrival>,
    next_arrival: usize,
    replicas: Vec<Replica>,
    router: Router,
}

impl ClusterSim {
    pub fn new(params: &ClusterParams) -> Result<Self> {
        if params.replicas == 0 {
            bail!("cluster needs at least one replica");
        }
        if params.tenants == 0 {
            bail!("cluster needs at least one tenant");
        }
        let par = params.parallelism;
        if par.tp == 0 || par.sp == 0 {
            bail!("TP/SP ranks must be >= 1, got tp={} sp={}", par.tp, par.sp);
        }
        if params.model.n_heads as u64 % par.tp != 0 {
            bail!(
                "TP {} must divide the model's {} attention heads",
                par.tp,
                params.model.n_heads
            );
        }
        // (A non-positive arrival rate is rejected by `timed_arrivals`.)
        let tenants = tenant_set(params.tenants, params.skew);
        let arrivals = timed_arrivals(
            &tenants,
            params.total_requests,
            params.arrival_rate,
            params.seed,
        )?;
        // Per-replica stack: the canonical single-device tenancy sizing
        // (any replica may end up hosting every group, so each pool
        // budgets for all prefixes).
        let mut replicas = Vec::with_capacity(params.replicas);
        for _ in 0..params.replicas {
            let coord = tenant_serving_stack(
                &params.model,
                &params.hw,
                params.kernel,
                params.batch,
                &tenants,
                params.include_prefill,
                params.parallelism,
            )?;
            replicas.push(Replica { coord, prefix_of: HashMap::new(), routed: 0 });
        }
        Ok(ClusterSim {
            params: params.clone(),
            tenants,
            arrivals,
            next_arrival: 0,
            replicas,
            router: Router::new(params.router),
        })
    }

    /// The generated arrival stream (inspection/conservation checks).
    pub fn arrivals(&self) -> &[TimedArrival] {
        &self.arrivals
    }

    /// Per-replica clocks (monotonicity audits).
    pub fn replica_clocks(&self) -> Vec<f64> {
        self.replicas.iter().map(|r| r.coord.now()).collect()
    }

    /// A replica's coordinator (probes for tests and reports).
    pub fn coordinator(&self, replica: usize) -> &Coordinator<SimEngine> {
        &self.replicas[replica].coord
    }

    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// Requests the prefix-affinity router sent off their home replica.
    pub fn spills(&self) -> u64 {
        self.router.spills
    }

    /// Did this tenant ever spill off its home replica?
    pub fn tenant_spilled(&self, tenant: usize) -> bool {
        self.router.spilled.contains(&tenant)
    }

    /// Number of replicas holding this tenant's prefix pages.
    pub fn replicas_hosting(&self, tenant: usize) -> usize {
        self.replicas.iter().filter(|r| r.prefix_of.contains_key(&tenant)).count()
    }

    /// The earliest busy replica (has queued or running work), by
    /// clock, lowest index on ties.
    fn earliest_busy(&self) -> Option<(usize, f64)> {
        let mut best: Option<(usize, f64)> = None;
        for (i, r) in self.replicas.iter().enumerate() {
            if r.coord.running() > 0 || r.coord.queued() > 0 {
                let t = r.coord.now();
                let earlier = match best {
                    None => true,
                    Some((_, bt)) => t < bt,
                };
                if earlier {
                    best = Some((i, t));
                }
            }
        }
        best
    }

    /// Process one event: deliver the next arrival if it is due no
    /// later than every busy replica's clock (router probe + submit,
    /// fast-forwarding an idle replica), otherwise run one decode step
    /// of the earliest-clock busy replica.  Returns false when the
    /// stream is exhausted and every replica has drained.
    pub fn step_event(&mut self) -> Result<bool> {
        let busy = self.earliest_busy();
        if self.next_arrival < self.arrivals.len() {
            let due = match busy {
                None => true,
                Some((_, t)) => self.arrivals[self.next_arrival].at <= t,
            };
            if due {
                let a = self.arrivals[self.next_arrival].clone();
                self.next_arrival += 1;
                let r = self.router.route(
                    a.tenant,
                    a.request.prompt_tokens,
                    &self.replicas,
                    self.params.spill_queue_depth,
                );
                let rep = &mut self.replicas[r];
                rep.coord.advance_clock(a.at);
                let pid = match rep.prefix_of.get(&a.tenant) {
                    Some(&p) => p,
                    None => {
                        // First request of this group here: the replica
                        // prefills + pages the tenant's prefix (this is
                        // the state prefix-affinity preserves).
                        let tokens = self.tenants[a.tenant].prompt_token_ids(50_000);
                        let p = rep.coord.register_prefix_group(&tokens)?;
                        rep.prefix_of.insert(a.tenant, p);
                        p
                    }
                };
                // Anchor the submission at the *arrival* time: a busy
                // replica's clock may already be past `a.at` (arrivals
                // are only deliverable between decode iterations), and
                // that wait is real queueing delay TTFT must include.
                rep.coord.submit_to_at(&a.request, pid, a.at)?;
                rep.routed += 1;
                return Ok(true);
            }
        }
        if let Some((i, _)) = busy {
            self.replicas[i].coord.step()?;
            return Ok(true);
        }
        Ok(false)
    }

    /// Drive arrivals and replicas until everything drains.
    pub fn run(&mut self) -> Result<()> {
        while self.step_event()? {}
        Ok(())
    }

    /// Aggregate the per-replica metrics into the cluster report.
    pub fn report(&self) -> ClusterReport {
        let mut reps = Vec::with_capacity(self.replicas.len());
        let mut ttft: Vec<f64> = Vec::new();
        let mut tpot: Vec<f64> = Vec::new();
        let mut tokens = 0u64;
        let mut completed = 0u64;
        let mut decode_seconds = 0.0f64;
        let mut makespan = 0.0f64;
        for r in &self.replicas {
            let m: &Metrics = &r.coord.metrics;
            tokens += m.tokens_generated;
            completed += m.requests_completed;
            decode_seconds += m.decode_seconds;
            makespan = makespan.max(r.coord.now());
            ttft.extend_from_slice(m.ttft.values());
            tpot.extend_from_slice(m.tpot.values());
            reps.push(ReplicaReport {
                tokens: m.tokens_generated,
                requests_completed: m.requests_completed,
                decode_seconds: m.decode_seconds,
                iterations: m.decode_iterations,
                mean_batch: m.batch_occupancy.mean(),
                typhoon_iters: m.typhoon_iters,
                absorb_iters: m.absorb_iters,
                naive_iters: m.naive_iters,
                mixed_iters: m.mixed_iters,
                preemptions: m.preemptions,
                prefix_groups: r.prefix_of.len(),
                routed: r.routed,
                final_clock: r.coord.now(),
            });
        }
        ttft.sort_by(|a, b| a.partial_cmp(b).unwrap());
        tpot.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ClusterReport {
            replicas: reps,
            tokens,
            requests_completed: completed,
            decode_seconds,
            goodput: if decode_seconds > 0.0 {
                tokens as f64 / decode_seconds
            } else {
                0.0
            },
            makespan,
            ttft_p50: p50(&ttft),
            ttft_p95: p95(&ttft),
            ttft_p99: p99(&ttft),
            tpot_p50: p50(&tpot),
            tpot_p95: p95(&tpot),
            tpot_p99: p99(&tpot),
            spills: self.router.spills,
        }
    }
}

/// Run one cluster experiment end to end.
pub fn run_cluster_experiment(params: &ClusterParams) -> Result<ClusterReport> {
    let mut sim = ClusterSim::new(params)?;
    sim.run()?;
    Ok(sim.report())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::ascend_npu;
    use crate::config::model::deepseek_v3;

    fn quick_params(replicas: usize, router: RouterPolicy) -> ClusterParams {
        let mut p = ClusterParams::new(
            deepseek_v3(),
            ascend_npu(),
            replicas,
            router,
            32,
            3,
            1.0,
        );
        p.total_requests = 48;
        p
    }

    #[test]
    fn round_robin_spreads_requests() {
        let mut sim = ClusterSim::new(&quick_params(3, RouterPolicy::RoundRobin)).unwrap();
        sim.run().unwrap();
        let report = sim.report();
        assert_eq!(report.requests_completed as usize, sim.arrivals().len());
        for r in &report.replicas {
            assert!(r.routed > 0, "round-robin leaves no replica idle");
        }
        assert!(report.tokens > 0);
        assert!(report.goodput > 0.0);
        assert!(report.makespan > 0.0);
    }

    #[test]
    fn least_loaded_balances_queue_depth() {
        let mut p = quick_params(2, RouterPolicy::LeastLoaded);
        p.arrival_rate = Some(1000.0); // near-simultaneous arrivals
        let mut sim = ClusterSim::new(&p).unwrap();
        sim.run().unwrap();
        let report = sim.report();
        let routed: Vec<u64> = report.replicas.iter().map(|r| r.routed).collect();
        let spread = routed.iter().max().unwrap() - routed.iter().min().unwrap();
        assert!(
            spread * 4 <= *routed.iter().max().unwrap(),
            "least-loaded keeps routing near-even: {routed:?}"
        );
    }

    #[test]
    fn affinity_concentrates_groups() {
        let mut sim =
            ClusterSim::new(&quick_params(3, RouterPolicy::PrefixAffinity)).unwrap();
        sim.run().unwrap();
        for t in 0..3 {
            if !sim.tenant_spilled(t) {
                assert!(
                    sim.replicas_hosting(t) <= 1,
                    "unspilled tenant {t} must stay on one replica"
                );
            }
        }
        // Fewer prefix registrations fleet-wide than round-robin, which
        // pages every group on every replica it touches.
        let hosted: usize = (0..sim.replica_count())
            .map(|i| sim.coordinator(i).prefix_groups().len())
            .sum();
        let mut rr = ClusterSim::new(&quick_params(3, RouterPolicy::RoundRobin)).unwrap();
        rr.run().unwrap();
        let rr_hosted: usize = (0..rr.replica_count())
            .map(|i| rr.coordinator(i).prefix_groups().len())
            .sum();
        assert!(hosted <= rr_hosted, "affinity {hosted} vs round-robin {rr_hosted}");
    }

    #[test]
    fn ttft_tpot_percentiles_populated() {
        let mut sim = ClusterSim::new(&quick_params(2, RouterPolicy::RoundRobin)).unwrap();
        sim.run().unwrap();
        let r = sim.report();
        assert!(r.ttft_p50 >= 0.0 && r.ttft_p50.is_finite());
        assert!(r.ttft_p99 >= r.ttft_p50, "p99 dominates p50");
        assert!(r.tpot_p99 >= r.tpot_p50);
    }

    #[test]
    fn poisson_arrivals_advance_clocks_monotonically() {
        let mut p = quick_params(2, RouterPolicy::LeastLoaded);
        p.arrival_rate = Some(5.0);
        let mut sim = ClusterSim::new(&p).unwrap();
        let mut prev = sim.replica_clocks();
        while sim.step_event().unwrap() {
            let now = sim.replica_clocks();
            for (a, b) in prev.iter().zip(&now) {
                assert!(b >= a, "replica clock went backward: {prev:?} -> {now:?}");
            }
            prev = now;
        }
        assert!(prev.iter().any(|&t| t > 0.0));
    }

    #[test]
    fn router_policy_parse_roundtrip() {
        for p in RouterPolicy::all() {
            assert_eq!(RouterPolicy::parse(p.as_str()).unwrap(), p);
        }
        assert_eq!(RouterPolicy::parse("rr").unwrap(), RouterPolicy::RoundRobin);
        assert_eq!(RouterPolicy::parse("ll").unwrap(), RouterPolicy::LeastLoaded);
        assert_eq!(
            RouterPolicy::parse("affinity").unwrap(),
            RouterPolicy::PrefixAffinity
        );
        assert!(RouterPolicy::parse("random").is_err());
    }

    #[test]
    fn zero_replicas_rejected() {
        let mut p = quick_params(1, RouterPolicy::RoundRobin);
        p.replicas = 0;
        assert!(ClusterSim::new(&p).is_err());
    }

    /// Bad TP/SP/rate configurations surface as errors, not panics
    /// deep inside the cost model.
    #[test]
    fn invalid_parallelism_and_rate_rejected() {
        let mut p = quick_params(1, RouterPolicy::RoundRobin);
        p.parallelism = ParallelismConfig { tp: 0, sp: 1 };
        assert!(ClusterSim::new(&p).is_err(), "tp = 0 rejected");
        p.parallelism = ParallelismConfig { tp: 7, sp: 1 }; // 7 does not divide H
        assert!(ClusterSim::new(&p).is_err(), "tp must divide heads");
        p.parallelism = ParallelismConfig::single();
        p.arrival_rate = Some(0.0);
        assert!(ClusterSim::new(&p).is_err(), "rate must be positive");
    }
}
