//! Cluster-scale serving: N replicas — each a full `Coordinator` +
//! `SimEngine` + `KvCacheManager` stack, optionally TP/SP-sharded —
//! fed by a timed (Poisson) arrival process through a router with
//! pluggable policies.
//!
//! The paper's Typhoon win comes from *concentrating* sequences that
//! share a prefix into one batch (Eq. 1 amortizes the shared stage
//! over group occupancy).  At fleet scale that concentration is a
//! **routing** decision: round-robin sprays every prefix group across
//! all replicas (each replica pays every group's shared-stage stream
//! at a fraction of the occupancy), while **prefix-affinity** sticks
//! each group to the replica already holding its pages — full
//! occupancy per group, one stream per prefix fleet-wide — and spills
//! to the least-loaded peer only under pressure (recorded, so the
//! "one group, one replica" invariant is auditable).
//!
//! The simulation is event-driven over modeled time: each replica owns
//! an independent clock (its coordinator's `now`), and the cluster
//! repeatedly processes the earliest event — the next arrival, or one
//! decode step of the earliest-clock busy replica.  Idle replicas
//! fast-forward to the arrival that wakes them.  With one replica,
//! round-robin routing and `ParallelismConfig::single()`, the whole
//! machinery reduces bit-for-bit to the single-device tenancy path
//! (pinned by `tests/cluster.rs`).

use std::collections::{HashMap, HashSet};

use anyhow::{anyhow, bail, Result};

use crate::config::{HardwareSpec, KernelKind, ModelConfig};
use crate::coordinator::Coordinator;
use crate::costmodel::parallel::ParallelismConfig;
use crate::kvcache::PrefixId;
use crate::metrics::Metrics;
use crate::policy::{MigrationDecision, PolicyEngine};
use crate::util::stats::{p50, p95, p99};
use crate::workload::tenants::{tenant_set, timed_arrivals, TenantSpec, TimedArrival};

use super::engine::SimEngine;
use super::tenancy::tenant_serving_stack;

/// Pluggable routing policy of the cluster front door.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouterPolicy {
    /// Arrival i goes to replica i mod N.
    RoundRobin,
    /// Fewest outstanding requests (queued + running), lowest index on
    /// ties.
    LeastLoaded,
    /// Stick each prefix group to the replica already holding its
    /// pages; spill to the least-loaded peer under queue/KV pressure.
    PrefixAffinity,
}

impl RouterPolicy {
    pub fn as_str(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "round-robin",
            RouterPolicy::LeastLoaded => "least-loaded",
            RouterPolicy::PrefixAffinity => "prefix-affinity",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "round-robin" | "rr" => RouterPolicy::RoundRobin,
            "least-loaded" | "ll" => RouterPolicy::LeastLoaded,
            "prefix-affinity" | "affinity" => RouterPolicy::PrefixAffinity,
            _ => bail!(
                "unknown router policy {s:?} (round-robin|least-loaded|prefix-affinity)"
            ),
        })
    }

    /// Artifact/grid order: baselines first, affinity last.
    pub fn all() -> [RouterPolicy; 3] {
        [
            RouterPolicy::RoundRobin,
            RouterPolicy::LeastLoaded,
            RouterPolicy::PrefixAffinity,
        ]
    }
}

/// Parameters of one cluster experiment.
#[derive(Clone, Debug)]
pub struct ClusterParams {
    pub model: ModelConfig,
    pub hw: HardwareSpec,
    /// Requested kernel (per-group fall-back applies to Typhoon).
    pub kernel: KernelKind,
    /// Number of serving replicas.
    pub replicas: usize,
    pub router: RouterPolicy,
    /// TP/SP sharding of every replica (`single()` = one device each).
    pub parallelism: ParallelismConfig,
    /// Per-replica decode batch capacity.
    pub batch: usize,
    /// Number of tenants (prefix groups) in the workload.
    pub tenants: usize,
    /// Zipf exponent of the arrival shares (0 = uniform).
    pub skew: f64,
    /// Total request budget across the cluster.
    pub total_requests: usize,
    /// Poisson arrival rate, requests/second; `None` drops the whole
    /// stream at t = 0 (the paper's batch protocol).
    pub arrival_rate: Option<f64>,
    pub seed: u64,
    /// Include prefill time in the modeled clocks (decode-only by
    /// default, matching the paper's throughput protocol).
    pub include_prefill: bool,
    /// Prefix-affinity spill threshold: abandon stickiness for one
    /// request when the home replica's queue depth reaches this.  When
    /// `slo_ttft` is set the threshold is instead derived per arrival
    /// from the TTFT target and observed rates (`policy::SloAdmission`);
    /// this constant stays the fallback before rates are observable.
    pub spill_queue_depth: usize,
    /// Enable cost-driven prefix migration: a pressured home re-homes
    /// the whole group's pages to the least-loaded peer (modeled
    /// interconnect transfer, no re-prefill) when that beats spilling
    /// the overflow one request at a time.  Off reproduces the PR 3
    /// spill-only router bit-for-bit.
    pub migrate: bool,
    /// TTFT target in seconds for SLO-driven admission; `None` keeps
    /// the fixed `spill_queue_depth` trigger.
    pub slo_ttft: Option<f64>,
}

impl ClusterParams {
    pub fn new(
        model: ModelConfig,
        hw: HardwareSpec,
        replicas: usize,
        router: RouterPolicy,
        batch: usize,
        tenants: usize,
        skew: f64,
    ) -> Self {
        ClusterParams {
            model,
            hw,
            kernel: KernelKind::Typhoon,
            replicas,
            router,
            parallelism: ParallelismConfig::single(),
            batch,
            tenants,
            skew,
            total_requests: batch * replicas.max(1) * 4,
            arrival_rate: None,
            seed: 42,
            include_prefill: false,
            spill_queue_depth: (2 * batch).max(1),
            migrate: false,
            slo_ttft: None,
        }
    }
}

/// One replica: a full single-device serving stack plus the router's
/// view of which tenants it hosts.
struct Replica {
    coord: Coordinator<SimEngine>,
    /// Tenant -> prefix group registered on this replica (pages held).
    prefix_of: HashMap<usize, PrefixId>,
    /// Tenants whose group arrived here via migration import (adopted
    /// pages, never locally prefilled).
    imported: HashSet<usize>,
    /// Prefix copies retired by an outbound migration (released once
    /// their last sequence drains) — kept for the page audit.
    retired: Vec<(usize, PrefixId)>,
    /// Requests routed here.
    routed: u64,
}

/// Router state (stickiness + spill/migration bookkeeping; the
/// decisions themselves live in `policy::PolicyEngine`).
struct Router {
    policy: RouterPolicy,
    rr_next: usize,
    /// Prefix-affinity home replica per tenant.
    home: HashMap<usize, usize>,
    spills: u64,
    spilled: HashSet<usize>,
    /// Tenants spilled since their last migration — the escape hatch
    /// the one-replica page audit allows (a re-homed group fragments
    /// again only through a recorded spill).
    spilled_since_migration: HashSet<usize>,
    migrations: u64,
    migrated: HashSet<usize>,
}

impl Router {
    fn new(policy: RouterPolicy) -> Self {
        Router {
            policy,
            rr_next: 0,
            home: HashMap::new(),
            spills: 0,
            spilled: HashSet::new(),
            spilled_since_migration: HashSet::new(),
            migrations: 0,
            migrated: HashSet::new(),
        }
    }

    fn least_loaded(replicas: &[Replica]) -> usize {
        Self::least_loaded_except(replicas, None)
    }

    /// Least-loaded replica, optionally excluding one index (spill
    /// target selection); lowest index wins ties.
    fn least_loaded_except(replicas: &[Replica], exclude: Option<usize>) -> usize {
        let mut best: Option<usize> = None;
        for (i, r) in replicas.iter().enumerate() {
            if Some(i) == exclude {
                continue;
            }
            let better = match best {
                None => true,
                Some(b) => r.coord.load() < replicas[b].coord.load(),
            };
            if better {
                best = Some(i);
            }
        }
        best.expect("at least one candidate replica")
    }
}

/// Audit record of one prefix migration.
#[derive(Clone, Debug)]
pub struct MigrationEvent {
    pub tenant: usize,
    pub from: usize,
    pub to: usize,
    /// Modeled interconnect seconds charged to the destination clock
    /// (0 when an earlier spill already paged the group there).
    pub transfer_seconds: f64,
    /// Destination `shared_prefills` before/after adoption.  Equal —
    /// or the destination re-prefilled, which the fuzz audit forbids.
    pub dst_prefills_before: u64,
    pub dst_prefills_after: u64,
}

/// Per-replica slice of a finished cluster run.
#[derive(Clone, Debug)]
pub struct ReplicaReport {
    pub tokens: u64,
    pub requests_completed: u64,
    pub decode_seconds: f64,
    pub iterations: u64,
    pub mean_batch: f64,
    pub typhoon_iters: u64,
    pub absorb_iters: u64,
    pub naive_iters: u64,
    pub mixed_iters: u64,
    pub preemptions: u64,
    /// Prefix groups hosted (pages held) on this replica.
    pub prefix_groups: usize,
    /// Prefix groups adopted via migration import (no local prefill).
    pub prefix_imports: u64,
    /// Requests the router sent here.
    pub routed: u64,
    /// The replica's final clock (arrival-to-drain span).
    pub final_clock: f64,
}

/// Aggregate result of one cluster experiment.
#[derive(Clone, Debug)]
pub struct ClusterReport {
    pub replicas: Vec<ReplicaReport>,
    pub tokens: u64,
    pub requests_completed: u64,
    /// Aggregate busy decode seconds across replicas.
    pub decode_seconds: f64,
    /// Cluster goodput: generated tokens per aggregate replica decode
    /// second — the paper's decode-time throughput metric lifted to the
    /// fleet (it prices the shared-stage streams every replica pays,
    /// which is exactly what routing concentration buys back).
    pub goodput: f64,
    /// Latest replica clock: the wall span from first arrival to drain.
    pub makespan: f64,
    pub ttft_p50: f64,
    pub ttft_p95: f64,
    pub ttft_p99: f64,
    pub tpot_p50: f64,
    pub tpot_p95: f64,
    pub tpot_p99: f64,
    /// Prefix-affinity requests routed off their home replica.
    pub spills: u64,
    /// Prefix groups re-homed by the migrate-vs-spill rule.
    pub migrations: u64,
    /// Modeled interconnect seconds spent moving pages (fleet total;
    /// wall time on the receiving clocks, never decode time).
    pub transfer_seconds: f64,
}

/// The event-driven N-replica serving simulation.
pub struct ClusterSim {
    params: ClusterParams,
    tenants: Vec<TenantSpec>,
    arrivals: Vec<TimedArrival>,
    next_arrival: usize,
    replicas: Vec<Replica>,
    router: Router,
    /// The unified decision layer: kernel fall-back pricing, the
    /// migrate-vs-spill rule, and SLO-driven admission thresholds.
    policy: PolicyEngine,
    migration_log: Vec<MigrationEvent>,
}

impl ClusterSim {
    pub fn new(params: &ClusterParams) -> Result<Self> {
        if params.replicas == 0 {
            bail!("cluster needs at least one replica");
        }
        if params.tenants == 0 {
            bail!("cluster needs at least one tenant");
        }
        let par = params.parallelism;
        if par.tp == 0 || par.sp == 0 {
            bail!("TP/SP ranks must be >= 1, got tp={} sp={}", par.tp, par.sp);
        }
        if params.model.n_heads as u64 % par.tp != 0 {
            bail!(
                "TP {} must divide the model's {} attention heads",
                par.tp,
                params.model.n_heads
            );
        }
        if let Some(t) = params.slo_ttft {
            if !t.is_finite() || t <= 0.0 {
                bail!("TTFT target must be positive seconds, got {t}");
            }
        }
        if (params.migrate || params.slo_ttft.is_some())
            && params.router != RouterPolicy::PrefixAffinity
        {
            bail!(
                "migration / SLO admission act on prefix-affinity pressure \
                 relief; router {} never consults them",
                params.router.as_str()
            );
        }
        // (A non-positive arrival rate is rejected by `timed_arrivals`.)
        let tenants = tenant_set(params.tenants, params.skew);
        let arrivals = timed_arrivals(
            &tenants,
            params.total_requests,
            params.arrival_rate,
            params.seed,
        )?;
        // Per-replica stack: the canonical single-device tenancy sizing
        // (any replica may end up hosting every group, so each pool
        // budgets for all prefixes).
        let mut replicas = Vec::with_capacity(params.replicas);
        for _ in 0..params.replicas {
            let coord = tenant_serving_stack(
                &params.model,
                &params.hw,
                params.kernel,
                params.batch,
                &tenants,
                params.include_prefill,
                params.parallelism,
            )?;
            replicas.push(Replica {
                coord,
                prefix_of: HashMap::new(),
                imported: HashSet::new(),
                retired: Vec::new(),
                routed: 0,
            });
        }
        let mut policy = PolicyEngine::new(
            params.model.clone(),
            params.hw.clone(),
            params.kernel,
            params.parallelism,
        );
        policy.migration.enabled = params.migrate;
        policy.admission.ttft_target = params.slo_ttft;
        Ok(ClusterSim {
            params: params.clone(),
            tenants,
            arrivals,
            next_arrival: 0,
            replicas,
            router: Router::new(params.router),
            policy,
            migration_log: Vec::new(),
        })
    }

    /// The generated arrival stream (inspection/conservation checks).
    pub fn arrivals(&self) -> &[TimedArrival] {
        &self.arrivals
    }

    /// Per-replica clocks (monotonicity audits).
    pub fn replica_clocks(&self) -> Vec<f64> {
        self.replicas.iter().map(|r| r.coord.now()).collect()
    }

    /// A replica's coordinator (probes for tests and reports).
    pub fn coordinator(&self, replica: usize) -> &Coordinator<SimEngine> {
        &self.replicas[replica].coord
    }

    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// Requests the prefix-affinity router sent off their home replica.
    pub fn spills(&self) -> u64 {
        self.router.spills
    }

    /// Did this tenant ever spill off its home replica?
    pub fn tenant_spilled(&self, tenant: usize) -> bool {
        self.router.spilled.contains(&tenant)
    }

    /// Prefix groups re-homed by the migrate-vs-spill rule.
    pub fn migrations(&self) -> u64 {
        self.router.migrations
    }

    /// Was this tenant's group ever migrated?
    pub fn tenant_migrated(&self, tenant: usize) -> bool {
        self.router.migrated.contains(&tenant)
    }

    /// Did this tenant spill after its most recent migration?  (The
    /// only way a migrated group legitimately fragments again.)
    pub fn tenant_spilled_since_migration(&self, tenant: usize) -> bool {
        self.router.spilled_since_migration.contains(&tenant)
    }

    /// Per-migration audit records (destination prefill counters,
    /// modeled transfer time).
    pub fn migration_log(&self) -> &[MigrationEvent] {
        &self.migration_log
    }

    /// Did this replica adopt the tenant's group via migration import?
    pub fn tenant_imported(&self, replica: usize, tenant: usize) -> bool {
        self.replicas[replica].imported.contains(&tenant)
    }

    /// Every prefix copy retired by an outbound migration whose pages
    /// have actually been released (true once their groups drained).
    pub fn retired_copies_released(&self) -> bool {
        self.replicas
            .iter()
            .all(|r| r.retired.iter().all(|&(_, pid)| r.coord.kv.prefix(pid).is_none()))
    }

    /// Number of replicas holding this tenant's prefix pages.
    pub fn replicas_hosting(&self, tenant: usize) -> usize {
        self.replicas.iter().filter(|r| r.prefix_of.contains_key(&tenant)).count()
    }

    /// The earliest busy replica (has queued or running work), by
    /// clock, lowest index on ties.
    fn earliest_busy(&self) -> Option<(usize, f64)> {
        let mut best: Option<(usize, f64)> = None;
        for (i, r) in self.replicas.iter().enumerate() {
            if r.coord.running() > 0 || r.coord.queued() > 0 {
                let t = r.coord.now();
                let earlier = match best {
                    None => true,
                    Some((_, bt)) => t < bt,
                };
                if earlier {
                    best = Some((i, t));
                }
            }
        }
        best
    }

    /// Process one event: deliver the next arrival if it is due no
    /// later than every busy replica's clock (router probe + submit,
    /// fast-forwarding an idle replica), otherwise run one decode step
    /// of the earliest-clock busy replica.  Returns false when the
    /// stream is exhausted and every replica has drained.
    pub fn step_event(&mut self) -> Result<bool> {
        let busy = self.earliest_busy();
        if self.next_arrival < self.arrivals.len() {
            let due = match busy {
                None => true,
                Some((_, t)) => self.arrivals[self.next_arrival].at <= t,
            };
            if due {
                let a = self.arrivals[self.next_arrival].clone();
                self.next_arrival += 1;
                let r = self.route_arrival(&a)?;
                let rep = &mut self.replicas[r];
                rep.coord.advance_clock(a.at);
                let pid = match rep.prefix_of.get(&a.tenant) {
                    Some(&p) => p,
                    None => {
                        // First request of this group here: the replica
                        // prefills + pages the tenant's prefix (this is
                        // the state prefix-affinity preserves).
                        let tokens = self.tenants[a.tenant].prompt_token_ids(50_000);
                        let p = rep.coord.register_prefix_group(&tokens)?;
                        rep.prefix_of.insert(a.tenant, p);
                        p
                    }
                };
                // Anchor the submission at the *arrival* time: a busy
                // replica's clock may already be past `a.at` (arrivals
                // are only deliverable between decode iterations), and
                // that wait is real queueing delay TTFT must include.
                rep.coord.submit_to_at(&a.request, pid, a.at)?;
                rep.routed += 1;
                return Ok(true);
            }
        }
        if let Some((i, _)) = busy {
            self.replicas[i].coord.step()?;
            return Ok(true);
        }
        Ok(false)
    }

    /// Pick the replica for one arrival, probing replica queue depth,
    /// load and KV headroom; prefix-affinity pressure relief goes
    /// through the policy layer's migrate-vs-spill rule.
    fn route_arrival(&mut self, a: &TimedArrival) -> Result<usize> {
        match self.router.policy {
            RouterPolicy::RoundRobin => {
                let r = self.router.rr_next % self.replicas.len();
                self.router.rr_next += 1;
                Ok(r)
            }
            RouterPolicy::LeastLoaded => Ok(Router::least_loaded(&self.replicas)),
            RouterPolicy::PrefixAffinity => self.route_affinity(a),
        }
    }

    fn route_affinity(&mut self, a: &TimedArrival) -> Result<usize> {
        let tenant = a.tenant;
        let Some(home) = self.router.home.get(&tenant).copied() else {
            // First sighting: adopt the least-loaded replica as the
            // group's home (it will hold the pages).
            let r = Router::least_loaded(&self.replicas);
            self.router.home.insert(tenant, r);
            return Ok(r);
        };
        let h = &self.replicas[home].coord;
        // Pressure threshold: SLO-derived when a TTFT target is set,
        // the fixed queue-depth constant otherwise (bit-identical to
        // the pre-SLO router).
        let depth = if self.policy.admission.ttft_target.is_some() {
            self.policy.admission.spill_depth(
                h.service_rate(),
                self.observed_arrival_rate(),
                self.params.spill_queue_depth,
            )
        } else {
            self.params.spill_queue_depth
        };
        let pressured =
            h.queued() >= depth || !h.can_admit_now(a.request.prompt_tokens);
        if pressured && self.replicas.len() > 1 {
            let alt = Router::least_loaded_except(&self.replicas, Some(home));
            if self.replicas[alt].coord.load() < self.replicas[home].coord.load() {
                let len = self.tenants[tenant].prompt_tokens;
                let expanded = self.replicas[home]
                    .prefix_of
                    .get(&tenant)
                    .and_then(|&p| self.replicas[home].coord.kv.prefix(p))
                    .is_some_and(|p| p.expanded);
                // Residency at the peer (an earlier spill re-prefilled
                // it there) makes re-homing free — the policy layer
                // short-circuits the cost comparison for that case, so
                // the decision matches what `migrate_group` will
                // actually charge.
                let alt_hosts = self.replicas[alt].prefix_of.contains_key(&tenant);
                return match self.policy.migrate_or_spill(len, expanded, alt_hosts) {
                    MigrationDecision::Migrate => {
                        // Re-home the whole group: the overflow (and
                        // everything after it) lands on a replica that
                        // now holds the pages.
                        self.migrate_group(tenant, home, alt, a.at)?;
                        Ok(alt)
                    }
                    MigrationDecision::Spill => {
                        // Route this one request around the pressured
                        // home — the pages stay where they are, and the
                        // spill is recorded for the invariant audit (a
                        // group on two replicas implies a recorded
                        // spill).
                        self.router.spills += 1;
                        self.router.spilled.insert(tenant);
                        self.router.spilled_since_migration.insert(tenant);
                        Ok(alt)
                    }
                };
            }
        }
        Ok(home)
    }

    /// Observed fleet arrival rate over the delivered stream so far,
    /// per replica (the admission policy's lambda-hat).  Infinite
    /// under the batch protocol (everything at t = 0) — the admission
    /// policy falls back to the fixed depth then.
    fn observed_arrival_rate(&self) -> f64 {
        if self.next_arrival == 0 {
            return 0.0;
        }
        let span = self.arrivals[self.next_arrival - 1].at;
        if span > 0.0 {
            self.next_arrival as f64 / span / self.replicas.len() as f64
        } else {
            f64::INFINITY
        }
    }

    /// Re-home `tenant`'s prefix group from `src` to `dst`: the
    /// destination adopts the pages over the interconnect (no
    /// re-prefill — the audit log records its prefill counter around
    /// the adoption), every other replica's copy is retired (released
    /// the moment its last sequence drains), and the router's
    /// stickiness follows the pages.
    fn migrate_group(&mut self, tenant: usize, src: usize, dst: usize, at: f64) -> Result<()> {
        let src_pid = *self.replicas[src]
            .prefix_of
            .get(&tenant)
            .ok_or_else(|| anyhow!("migration source does not host tenant {tenant}"))?;
        let before = self.replicas[dst].coord.metrics.shared_prefills;
        let transfer = if self.replicas[dst].prefix_of.contains_key(&tenant) {
            // An earlier spill already paged the group here: adopt the
            // resident copy, nothing crosses the interconnect (and
            // nothing needs exporting).
            0.0
        } else {
            let export = self.replicas[src].coord.kv.export_prefix(src_pid)?;
            let pid = self.replicas[dst].coord.import_prefix_group(&export)?;
            let secs = self
                .policy
                .prefix_transfer_seconds(export.tokens.len(), export.expanded);
            let rep = &mut self.replicas[dst];
            rep.prefix_of.insert(tenant, pid);
            rep.imported.insert(tenant);
            rep.coord.advance_clock(at);
            rep.coord.charge_transfer(secs);
            secs
        };
        let after = self.replicas[dst].coord.metrics.shared_prefills;
        for (i, rep) in self.replicas.iter_mut().enumerate() {
            if i == dst {
                continue;
            }
            if let Some(pid) = rep.prefix_of.remove(&tenant) {
                rep.coord.retire_prefix_group(pid)?;
                rep.retired.push((tenant, pid));
            }
        }
        self.router.home.insert(tenant, dst);
        self.router.migrations += 1;
        self.router.migrated.insert(tenant);
        self.router.spilled_since_migration.remove(&tenant);
        self.migration_log.push(MigrationEvent {
            tenant,
            from: src,
            to: dst,
            transfer_seconds: transfer,
            dst_prefills_before: before,
            dst_prefills_after: after,
        });
        Ok(())
    }

    /// Drive arrivals and replicas until everything drains.
    pub fn run(&mut self) -> Result<()> {
        while self.step_event()? {}
        Ok(())
    }

    /// Aggregate the per-replica metrics into the cluster report.
    pub fn report(&self) -> ClusterReport {
        let mut reps = Vec::with_capacity(self.replicas.len());
        let mut ttft: Vec<f64> = Vec::new();
        let mut tpot: Vec<f64> = Vec::new();
        let mut tokens = 0u64;
        let mut completed = 0u64;
        let mut decode_seconds = 0.0f64;
        let mut makespan = 0.0f64;
        let mut transfer_seconds = 0.0f64;
        for r in &self.replicas {
            let m: &Metrics = &r.coord.metrics;
            tokens += m.tokens_generated;
            completed += m.requests_completed;
            decode_seconds += m.decode_seconds;
            transfer_seconds += m.transfer_seconds;
            makespan = makespan.max(r.coord.now());
            ttft.extend_from_slice(m.ttft.values());
            tpot.extend_from_slice(m.tpot.values());
            reps.push(ReplicaReport {
                tokens: m.tokens_generated,
                requests_completed: m.requests_completed,
                decode_seconds: m.decode_seconds,
                iterations: m.decode_iterations,
                mean_batch: m.batch_occupancy.mean(),
                typhoon_iters: m.typhoon_iters,
                absorb_iters: m.absorb_iters,
                naive_iters: m.naive_iters,
                mixed_iters: m.mixed_iters,
                preemptions: m.preemptions,
                prefix_groups: r.prefix_of.len(),
                prefix_imports: m.prefix_imports,
                routed: r.routed,
                final_clock: r.coord.now(),
            });
        }
        ttft.sort_by(|a, b| a.partial_cmp(b).unwrap());
        tpot.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ClusterReport {
            replicas: reps,
            tokens,
            requests_completed: completed,
            decode_seconds,
            goodput: if decode_seconds > 0.0 {
                tokens as f64 / decode_seconds
            } else {
                0.0
            },
            makespan,
            ttft_p50: p50(&ttft),
            ttft_p95: p95(&ttft),
            ttft_p99: p99(&ttft),
            tpot_p50: p50(&tpot),
            tpot_p95: p95(&tpot),
            tpot_p99: p99(&tpot),
            spills: self.router.spills,
            migrations: self.router.migrations,
            transfer_seconds,
        }
    }
}

/// Run one cluster experiment end to end.
pub fn run_cluster_experiment(params: &ClusterParams) -> Result<ClusterReport> {
    let mut sim = ClusterSim::new(params)?;
    sim.run()?;
    Ok(sim.report())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::ascend_npu;
    use crate::config::model::deepseek_v3;

    fn quick_params(replicas: usize, router: RouterPolicy) -> ClusterParams {
        let mut p = ClusterParams::new(
            deepseek_v3(),
            ascend_npu(),
            replicas,
            router,
            32,
            3,
            1.0,
        );
        p.total_requests = 48;
        p
    }

    #[test]
    fn round_robin_spreads_requests() {
        let mut sim = ClusterSim::new(&quick_params(3, RouterPolicy::RoundRobin)).unwrap();
        sim.run().unwrap();
        let report = sim.report();
        assert_eq!(report.requests_completed as usize, sim.arrivals().len());
        for r in &report.replicas {
            assert!(r.routed > 0, "round-robin leaves no replica idle");
        }
        assert!(report.tokens > 0);
        assert!(report.goodput > 0.0);
        assert!(report.makespan > 0.0);
    }

    #[test]
    fn least_loaded_balances_queue_depth() {
        let mut p = quick_params(2, RouterPolicy::LeastLoaded);
        p.arrival_rate = Some(1000.0); // near-simultaneous arrivals
        let mut sim = ClusterSim::new(&p).unwrap();
        sim.run().unwrap();
        let report = sim.report();
        let routed: Vec<u64> = report.replicas.iter().map(|r| r.routed).collect();
        let spread = routed.iter().max().unwrap() - routed.iter().min().unwrap();
        assert!(
            spread * 4 <= *routed.iter().max().unwrap(),
            "least-loaded keeps routing near-even: {routed:?}"
        );
    }

    #[test]
    fn affinity_concentrates_groups() {
        let mut sim =
            ClusterSim::new(&quick_params(3, RouterPolicy::PrefixAffinity)).unwrap();
        sim.run().unwrap();
        for t in 0..3 {
            if !sim.tenant_spilled(t) {
                assert!(
                    sim.replicas_hosting(t) <= 1,
                    "unspilled tenant {t} must stay on one replica"
                );
            }
        }
        // Fewer prefix registrations fleet-wide than round-robin, which
        // pages every group on every replica it touches.
        let hosted: usize = (0..sim.replica_count())
            .map(|i| sim.coordinator(i).prefix_groups().len())
            .sum();
        let mut rr = ClusterSim::new(&quick_params(3, RouterPolicy::RoundRobin)).unwrap();
        rr.run().unwrap();
        let rr_hosted: usize = (0..rr.replica_count())
            .map(|i| rr.coordinator(i).prefix_groups().len())
            .sum();
        assert!(hosted <= rr_hosted, "affinity {hosted} vs round-robin {rr_hosted}");
    }

    #[test]
    fn ttft_tpot_percentiles_populated() {
        let mut sim = ClusterSim::new(&quick_params(2, RouterPolicy::RoundRobin)).unwrap();
        sim.run().unwrap();
        let r = sim.report();
        assert!(r.ttft_p50 >= 0.0 && r.ttft_p50.is_finite());
        assert!(r.ttft_p99 >= r.ttft_p50, "p99 dominates p50");
        assert!(r.tpot_p99 >= r.tpot_p50);
    }

    #[test]
    fn poisson_arrivals_advance_clocks_monotonically() {
        let mut p = quick_params(2, RouterPolicy::LeastLoaded);
        p.arrival_rate = Some(5.0);
        let mut sim = ClusterSim::new(&p).unwrap();
        let mut prev = sim.replica_clocks();
        while sim.step_event().unwrap() {
            let now = sim.replica_clocks();
            for (a, b) in prev.iter().zip(&now) {
                assert!(b >= a, "replica clock went backward: {prev:?} -> {now:?}");
            }
            prev = now;
        }
        assert!(prev.iter().any(|&t| t > 0.0));
    }

    #[test]
    fn router_policy_parse_roundtrip() {
        for p in RouterPolicy::all() {
            assert_eq!(RouterPolicy::parse(p.as_str()).unwrap(), p);
            assert_eq!(RouterPolicy::parse(p.as_str()).unwrap().as_str(), p.as_str());
        }
        assert_eq!(RouterPolicy::parse("rr").unwrap(), RouterPolicy::RoundRobin);
        assert_eq!(RouterPolicy::parse("ll").unwrap(), RouterPolicy::LeastLoaded);
        assert_eq!(
            RouterPolicy::parse("affinity").unwrap(),
            RouterPolicy::PrefixAffinity
        );
        let err = RouterPolicy::parse("random").unwrap_err().to_string();
        assert!(
            err.contains("round-robin|least-loaded|prefix-affinity"),
            "{err}"
        );
        assert!(RouterPolicy::parse("RR").is_err(), "matching is exact");
    }

    /// A pressured single-tenant fleet with migration enabled re-homes
    /// the hot group instead of scattering requests; the adoption never
    /// re-prefills and retired copies drain to zero replicas.
    #[test]
    fn migration_rehomes_hot_group_without_reprefill() {
        let mut p = ClusterParams::new(
            deepseek_v3(),
            ascend_npu(),
            2,
            RouterPolicy::PrefixAffinity,
            8,
            1,
            0.0,
        );
        p.total_requests = 32;
        p.spill_queue_depth = 1; // queue depth 1 already counts as pressure
        p.migrate = true;
        let mut sim = ClusterSim::new(&p).unwrap();
        sim.run().unwrap();
        assert!(sim.migrations() > 0, "tight threshold must trigger migration");
        assert!(sim.tenant_migrated(0));
        for e in sim.migration_log() {
            assert_eq!(
                e.dst_prefills_before, e.dst_prefills_after,
                "destination must adopt, never re-prefill"
            );
        }
        assert!(sim.retired_copies_released(), "drained copies release their pages");
        if !sim.tenant_spilled_since_migration(0) {
            assert_eq!(sim.replicas_hosting(0), 1, "pages on exactly one replica");
        }
        let report = sim.report();
        assert_eq!(report.requests_completed, 32, "migrated group still serves");
        assert_eq!(report.migrations, sim.migrations());
        assert!(report.transfer_seconds > 0.0, "page moves charge the interconnect");
    }

    /// Migration machinery that never fires changes nothing: with a
    /// loose pressure threshold the migrate-enabled run is
    /// bit-identical to the spill-only run (the PR 3 reduction pin).
    #[test]
    fn migrate_flag_without_pressure_is_bit_identical() {
        let p = quick_params(3, RouterPolicy::PrefixAffinity); // loose depth
        let mut a = ClusterSim::new(&p).unwrap();
        a.run().unwrap();
        let mut m = p.clone();
        m.migrate = true;
        let mut b = ClusterSim::new(&m).unwrap();
        b.run().unwrap();
        assert_eq!(a.spills(), 0, "loose threshold never pressures");
        assert_eq!(b.migrations(), 0);
        let (ra, rb) = (a.report(), b.report());
        assert_eq!(ra.decode_seconds.to_bits(), rb.decode_seconds.to_bits());
        assert_eq!(ra.makespan.to_bits(), rb.makespan.to_bits());
        assert_eq!(ra.tokens, rb.tokens);
    }

    /// A slow interconnect confines migration to free re-homes: fresh
    /// destinations lose the cost comparison (their overflow spills
    /// instead), so every recorded migration is a residency
    /// consolidation with zero transfer seconds.
    #[test]
    fn slow_interconnect_migrations_are_free_consolidations_only() {
        let mut p = quick_params(3, RouterPolicy::PrefixAffinity);
        p.spill_queue_depth = 1;
        p.migrate = true;
        p.hw.interconnect_bw = 1e-3; // fresh transfers never pay off
        let mut sim = ClusterSim::new(&p).unwrap();
        sim.run().unwrap();
        assert!(sim.spills() > 0, "fresh destinations must spill on a slow link");
        for e in sim.migration_log() {
            assert_eq!(e.transfer_seconds, 0.0, "only resident peers re-home");
        }
        assert_eq!(sim.report().transfer_seconds, 0.0);
    }

    /// SLO-driven admission: a tight TTFT target spills under load that
    /// a loose fixed queue-depth threshold would absorb.
    #[test]
    fn slo_target_tightens_the_spill_threshold() {
        let mut p = quick_params(2, RouterPolicy::PrefixAffinity);
        p.tenants = 1;
        p.arrival_rate = Some(500.0);
        p.spill_queue_depth = 10_000; // fixed trigger never fires
        let mut fixed = ClusterSim::new(&p).unwrap();
        fixed.run().unwrap();
        assert_eq!(fixed.spills(), 0, "loose fixed threshold never spills");

        p.slo_ttft = Some(1e-6);
        let mut slo = ClusterSim::new(&p).unwrap();
        slo.run().unwrap();
        assert!(
            slo.spills() > 0,
            "a tight TTFT target must shed load the fixed threshold ignored"
        );
    }

    /// Nonsense TTFT targets are configuration errors, and
    /// migration/SLO flags on routers that never consult them are
    /// rejected instead of silently ignored.
    #[test]
    fn invalid_slo_target_rejected() {
        let mut p = quick_params(1, RouterPolicy::PrefixAffinity);
        p.slo_ttft = Some(0.0);
        assert!(ClusterSim::new(&p).is_err());
        p.slo_ttft = Some(f64::NAN);
        assert!(ClusterSim::new(&p).is_err());

        let mut p = quick_params(2, RouterPolicy::LeastLoaded);
        p.migrate = true;
        assert!(ClusterSim::new(&p).is_err(), "migrate needs prefix-affinity");
        let mut p = quick_params(2, RouterPolicy::RoundRobin);
        p.slo_ttft = Some(0.5);
        assert!(ClusterSim::new(&p).is_err(), "slo-ttft needs prefix-affinity");
    }

    #[test]
    fn zero_replicas_rejected() {
        let mut p = quick_params(1, RouterPolicy::RoundRobin);
        p.replicas = 0;
        assert!(ClusterSim::new(&p).is_err());
    }

    /// Bad TP/SP/rate configurations surface as errors, not panics
    /// deep inside the cost model.
    #[test]
    fn invalid_parallelism_and_rate_rejected() {
        let mut p = quick_params(1, RouterPolicy::RoundRobin);
        p.parallelism = ParallelismConfig { tp: 0, sp: 1 };
        assert!(ClusterSim::new(&p).is_err(), "tp = 0 rejected");
        p.parallelism = ParallelismConfig { tp: 7, sp: 1 }; // 7 does not divide H
        assert!(ClusterSim::new(&p).is_err(), "tp must divide heads");
        p.parallelism = ParallelismConfig::single();
        p.arrival_rate = Some(0.0);
        assert!(ClusterSim::new(&p).is_err(), "rate must be positive");
    }
}
