//! Cluster-scale serving: N replicas — each a full `Coordinator` +
//! `SimEngine` + `KvCacheManager` stack, optionally TP/SP-sharded —
//! fed by a timed (Poisson, optionally bursty) arrival process through
//! a router with pluggable policies.
//!
//! The paper's Typhoon win comes from *concentrating* sequences that
//! share a prefix into one batch (Eq. 1 amortizes the shared stage
//! over group occupancy).  At fleet scale that concentration is a
//! **routing** decision: round-robin sprays every prefix group across
//! all replicas (each replica pays every group's shared-stage stream
//! at a fraction of the occupancy), while **prefix-affinity** sticks
//! each group to the replica already holding its pages — full
//! occupancy per group, one stream per prefix fleet-wide — and spills
//! to the least-loaded peer only under pressure (recorded, so the
//! "one group, one replica" invariant is auditable).
//!
//! **Autoscaling.**  With `--autoscale` the fleet itself tracks the
//! load: `policy::ScalingPolicy` watches the windowed arrival rate
//! against the active replicas' summed service rates and spins
//! replicas up (a fresh stack joins the fleet; the hottest *pressured*
//! groups bulk-migrate onto it when the modeled page transfer beats a
//! re-prefill, over the same `migrate_group` path pressure relief
//! uses) or down (an *idle* victim drains — no new admissions, its
//! prefix copies re-home by the same pricing and its pages release —
//! then retires).  Replicas therefore have a lifecycle
//! ([`ReplicaLifecycle`]); retired stacks stay in the report so every
//! completion is accounted for.  A configuration whose bounds or
//! observed rates never trigger a scale event is bit-identical to the
//! fixed fleet (pinned by `tests/cluster.rs`).
//!
//! **Faults and recovery.**  With `--faults` a seeded
//! [`simulator::faults::FaultPlan`](super::faults) injects replica
//! crashes and stalls, interconnect degradation windows, and in-flight
//! transfer loss at arrival boundaries.  `policy::RecoveryPolicy`
//! answers: lost transfers retry on capped exponential backoff (each
//! attempt priced on the destination clock), a crashed replica is
//! detected after a heartbeat timeout and fails over — its in-flight
//! sequences re-queue on survivors (never dropped), its tenants re-home
//! to a surviving page copy when one exists and to a cost-priced
//! re-prefill otherwise — and the [`Failed`](ReplicaLifecycle::Failed)
//! replica ends the run with zero live pages.  An empty plan is
//! structurally inert: the fault-free path stays bit-identical
//! (pinned by `tests/cluster.rs`).
//!
//! The simulation is event-driven over modeled time: each replica owns
//! an independent clock (its coordinator's `now`), and the cluster
//! repeatedly processes the earliest event — the next arrival, or one
//! decode step of the earliest-clock busy replica.  Idle replicas
//! fast-forward to the arrival that wakes them.  With one replica,
//! round-robin routing and `ParallelismConfig::single()`, the whole
//! machinery reduces bit-for-bit to the single-device tenancy path
//! (pinned by `tests/cluster.rs`).
//!
//! **Event core (DESIGN.md §15).**  The loop's two priority questions
//! — "which busy replica has the earliest clock?" and "which active
//! replica is least loaded?" — are answered by indexes instead of
//! O(#replicas) scans: [`EventHeap`], a lazy-invalidation binary
//! min-heap of `(clock, replica)` keys guarded by per-replica
//! generation stamps (re-keying = bump the stamp, push a fresh entry;
//! stale generations pop off the root lazily), and a load-ordered
//! BTree index over the Active replicas, both re-synced at every
//! mutation site (arrival delivery, decode step, stall, crash,
//! migration, resize) so lifecycle transitions — Draining, Retired,
//! Failed — fall out of the indexes naturally.  The original linear
//! scans are retained behind [`ClusterSim::use_linear_reference`] as
//! the bit-identity oracle (fuzzed in `tests/cluster.rs`), and
//! [`ClusterSim::run_parallel`] decode-steps independent replicas
//! concurrently between consecutive router decisions with a
//! deterministic ordered merge — byte-identical to [`ClusterSim::run`]
//! because replicas only interact at arrival boundaries.

use std::collections::{BTreeSet, HashMap, HashSet};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Result};

use crate::config::{FaultConfig, HardwareSpec, KernelKind, ModelConfig, ScalingConfig};
use crate::coordinator::Coordinator;
use crate::costmodel::parallel::ParallelismConfig;
use crate::costmodel::surface::PriceSurface;
use crate::kvcache::PrefixId;
use crate::metrics::Metrics;
use crate::policy::{MigrationDecision, PolicyEngine, ScalingDecision, ScalingPolicy};
use crate::util::det;
use crate::util::pool;
use crate::util::stats::{p50, p95, p99};
use crate::workload::tenants::{
    tenant_set, timed_arrivals, timed_arrivals_bursty, TenantSpec, TimedArrival,
};
use crate::workload::Request;

use super::engine::SimEngine;
use super::faults::{FaultKind, FaultPlan};
use super::tenancy::tenant_serving_stack_with_surface;

/// Phases of the square-wave bursty arrival profile (calm/burst
/// alternation, starting calm).
pub const BURST_PHASES: usize = 6;

/// Pluggable routing policy of the cluster front door.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouterPolicy {
    /// Arrival i goes to replica i mod N.
    RoundRobin,
    /// Fewest outstanding requests (queued + running), lowest index on
    /// ties.
    LeastLoaded,
    /// Stick each prefix group to the replica already holding its
    /// pages; spill to the least-loaded peer under queue/KV pressure.
    PrefixAffinity,
}

impl RouterPolicy {
    pub fn as_str(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "round-robin",
            RouterPolicy::LeastLoaded => "least-loaded",
            RouterPolicy::PrefixAffinity => "prefix-affinity",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "round-robin" | "rr" => RouterPolicy::RoundRobin,
            "least-loaded" | "ll" => RouterPolicy::LeastLoaded,
            "prefix-affinity" | "affinity" => RouterPolicy::PrefixAffinity,
            _ => bail!(
                "unknown router policy {s:?} (round-robin|least-loaded|prefix-affinity)"
            ),
        })
    }

    /// Artifact/grid order: baselines first, affinity last.
    pub fn all() -> [RouterPolicy; 3] {
        [
            RouterPolicy::RoundRobin,
            RouterPolicy::LeastLoaded,
            RouterPolicy::PrefixAffinity,
        ]
    }
}

/// Lifecycle of one replica in a (possibly autoscaled) fleet.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplicaLifecycle {
    /// Serving and admitting new arrivals.
    Active,
    /// Spin-down victim: no new admissions, in-flight work finishes,
    /// prefix copies release at drain.
    Draining,
    /// Drained and decommissioned: zero pages, zero work; kept in the
    /// report so its completions stay accounted for.
    Retired,
    /// Crashed by the fault layer: pages lost, in-flight sequences
    /// re-queued on survivors, no further admissions; kept in the
    /// report so its pre-crash completions stay accounted for.
    Failed,
}

impl ReplicaLifecycle {
    pub fn as_str(&self) -> &'static str {
        match self {
            ReplicaLifecycle::Active => "active",
            ReplicaLifecycle::Draining => "draining",
            ReplicaLifecycle::Retired => "retired",
            ReplicaLifecycle::Failed => "failed",
        }
    }
}

/// Parameters of one cluster experiment.
#[derive(Clone, Debug)]
pub struct ClusterParams {
    pub model: ModelConfig,
    pub hw: HardwareSpec,
    /// Requested kernel (per-group fall-back applies to Typhoon).
    pub kernel: KernelKind,
    /// Number of serving replicas (the *starting* fleet when
    /// autoscaling is enabled).
    pub replicas: usize,
    pub router: RouterPolicy,
    /// TP/SP sharding of every replica (`single()` = one device each).
    pub parallelism: ParallelismConfig,
    /// Per-replica decode batch capacity.
    pub batch: usize,
    /// Number of tenants (prefix groups) in the workload.
    pub tenants: usize,
    /// Zipf exponent of the arrival shares (0 = uniform).
    pub skew: f64,
    /// Total request budget across the cluster.
    pub total_requests: usize,
    /// Poisson arrival rate, requests/second; `None` drops the whole
    /// stream at t = 0 (the paper's batch protocol).
    pub arrival_rate: Option<f64>,
    /// Burst factor layered on `arrival_rate`: the stream alternates
    /// calm (`rate`) and burst (`rate * factor`) phases
    /// (`BURST_PHASES` square wave).  Requires an arrival rate.
    pub arrival_burst: Option<f64>,
    pub seed: u64,
    /// Include prefill time in the modeled clocks (decode-only by
    /// default, matching the paper's throughput protocol).
    pub include_prefill: bool,
    /// Prefix-affinity spill threshold: abandon stickiness for one
    /// request when the home replica's queue depth reaches this.  When
    /// `slo_ttft` is set the threshold is instead derived per arrival
    /// from the TTFT target and observed rates (`policy::SloAdmission`);
    /// this constant stays the fallback before rates are observable.
    pub spill_queue_depth: usize,
    /// Enable cost-driven prefix migration: a pressured home re-homes
    /// the whole group's pages to the least-loaded peer (modeled
    /// interconnect transfer, no re-prefill) when that beats spilling
    /// the overflow one request at a time.  Off reproduces the PR 3
    /// spill-only router bit-for-bit.  Re-homes are rate-limited by a
    /// per-group cool-down priced on transfer amortization
    /// (`PolicyEngine::migration_cooldown_tokens`).
    pub migrate: bool,
    /// TTFT target in seconds for SLO-driven admission; `None` keeps
    /// the fixed `spill_queue_depth` trigger.
    pub slo_ttft: Option<f64>,
    /// Replica autoscaling (prefix-affinity router only): spin
    /// replicas up/down against the observed arrival rate and SLO
    /// headroom, re-homing prefix groups via the migration path.
    pub scaling: ScalingConfig,
    /// Seeded fault injection (prefix-affinity router only): replica
    /// crashes and stalls, interconnect degradation windows, transfer
    /// loss.  `FaultConfig::disabled()` reproduces the fault-free
    /// cluster bit-for-bit.
    pub faults: FaultConfig,
    /// Pre-warmed fleet-shared price surface to adopt (sweeps pass one
    /// so every cell of a grid reuses the same warm memo).  `None`
    /// builds a fresh surface; a surface that does not price this
    /// cell's `(model, hw, parallelism)` is ignored.  Either way the
    /// simulated results are bit-identical — the surface only memoizes
    /// a pure function.
    pub surface: Option<Arc<PriceSurface>>,
}

impl ClusterParams {
    pub fn new(
        model: ModelConfig,
        hw: HardwareSpec,
        replicas: usize,
        router: RouterPolicy,
        batch: usize,
        tenants: usize,
        skew: f64,
    ) -> Self {
        ClusterParams {
            model,
            hw,
            kernel: KernelKind::Typhoon,
            replicas,
            router,
            parallelism: ParallelismConfig::single(),
            batch,
            tenants,
            skew,
            total_requests: batch * replicas.max(1) * 4,
            arrival_rate: None,
            arrival_burst: None,
            seed: 42,
            include_prefill: false,
            spill_queue_depth: (2 * batch).max(1),
            migrate: false,
            slo_ttft: None,
            scaling: ScalingConfig::for_fleet(replicas),
            faults: FaultConfig::disabled(),
            surface: None,
        }
    }
}

/// One replica: a full single-device serving stack plus the router's
/// view of which tenants it hosts and its fleet lifecycle state.
struct Replica {
    coord: Coordinator<SimEngine>,
    state: ReplicaLifecycle,
    /// Tenant -> prefix group registered on this replica (pages held).
    prefix_of: HashMap<usize, PrefixId>,
    /// Tenants whose group arrived here via migration import (adopted
    /// pages, never locally prefilled).
    imported: HashSet<usize>,
    /// Prefix copies retired by an outbound migration or a spin-down
    /// (released once their last sequence drains) — kept for the page
    /// audit.
    retired: Vec<(usize, PrefixId)>,
    /// Requests routed here.
    routed: u64,
    /// Requests re-submitted here after a peer crashed (failover
    /// re-queue; kept apart from `routed` so the arrival-conservation
    /// audit `sum(routed) == arrivals` stays exact under faults).
    requeued: u64,
}

impl Replica {
    fn fresh(coord: Coordinator<SimEngine>) -> Self {
        Replica {
            coord,
            state: ReplicaLifecycle::Active,
            prefix_of: HashMap::new(),
            imported: HashSet::new(),
            retired: Vec::new(),
            routed: 0,
            requeued: 0,
        }
    }
}

/// Router state (stickiness + spill/migration bookkeeping; the
/// decisions themselves live in `policy::PolicyEngine`).
struct Router {
    policy: RouterPolicy,
    rr_next: usize,
    /// Prefix-affinity home replica per tenant.
    home: HashMap<usize, usize>,
    spills: u64,
    spilled: HashSet<usize>,
    /// Tenants spilled since their last migration — the escape hatch
    /// the one-replica page audit allows (a re-homed group fragments
    /// again only through a recorded spill).
    spilled_since_migration: HashSet<usize>,
    migrations: u64,
    migrated: HashSet<usize>,
    /// Remaining served-token budget before each tenant's group may
    /// re-home again (the migration cool-down; absent = no budget
    /// outstanding).
    cooldown_tokens: HashMap<usize, u64>,
    /// Scale-event re-homes where the pricing said "re-prefill": the
    /// source copy retires and the destination rebuilds the prefix on
    /// its next arrival.
    reprefill_rehomes: u64,
}

impl Router {
    fn new(policy: RouterPolicy) -> Self {
        Router {
            policy,
            rr_next: 0,
            home: HashMap::new(),
            spills: 0,
            spilled: HashSet::new(),
            spilled_since_migration: HashSet::new(),
            migrations: 0,
            migrated: HashSet::new(),
            cooldown_tokens: HashMap::new(),
            reprefill_rehomes: 0,
        }
    }

    fn least_loaded(replicas: &[Replica]) -> usize {
        Self::least_loaded_except(replicas, None)
    }

    /// Least-loaded **active** replica, optionally excluding one index
    /// (spill target selection); lowest index wins ties.
    fn least_loaded_except(replicas: &[Replica], exclude: Option<usize>) -> usize {
        let mut best: Option<usize> = None;
        for (i, r) in replicas.iter().enumerate() {
            if Some(i) == exclude || r.state != ReplicaLifecycle::Active {
                continue;
            }
            let better = match best {
                None => true,
                Some(b) => r.coord.load() < replicas[b].coord.load(),
            };
            if better {
                best = Some(i);
            }
        }
        best.expect("at least one active candidate replica")
    }
}

/// Lazy-invalidation binary min-heap over busy-replica clocks — the
/// event core's priority queue (DESIGN.md §15).
///
/// Keys are `(clock, replica)` tuples ordered ascending, so ties on
/// the clock resolve to the lowest replica index — exactly the order
/// the retained linear scan produces.  There is no in-place
/// decrease-key: every re-key bumps the replica's generation stamp and
/// (while the replica stays busy) pushes a fresh entry; entries whose
/// stamp no longer matches are stale and are popped lazily at the
/// root.  A replica leaves the heap by going idle, draining, failing
/// or retiring — all the same way: its next sync pushes nothing, and
/// the stamp bump orphans whatever entries it still had in flight.
struct EventHeap {
    /// `(clock, replica, stamp)` entries in binary-heap order.
    entries: Vec<(f64, usize, u64)>,
    /// Current generation stamp per replica; older stamps are stale.
    stamp: Vec<u64>,
}

impl EventHeap {
    fn new(replicas: usize) -> Self {
        EventHeap { entries: Vec::new(), stamp: vec![0; replicas] }
    }

    /// Register a new replica (scale-up).
    fn grow(&mut self) {
        self.stamp.push(0);
    }

    /// Re-key replica `i` — decrease-key, increase-key and delete in
    /// one operation.  The stamp bump invalidates every older entry;
    /// a fresh entry is pushed only while the replica is busy.
    fn update(&mut self, i: usize, clock: f64, busy: bool) {
        self.stamp[i] = self.stamp[i].wrapping_add(1);
        if busy {
            self.entries.push((clock, i, self.stamp[i]));
            self.sift_up(self.entries.len() - 1);
        }
        // Amortized-O(1) hygiene: at most one entry per replica is
        // live, so once stale entries dominate, drop them all and
        // re-heapify rather than waiting for them to surface.
        if self.entries.len() > 2 * self.stamp.len() + 64 {
            self.compact();
        }
    }

    /// The earliest-clock busy replica (lowest index on ties), or
    /// `None` when no replica is busy.  Pops stale generations off the
    /// root on the way.
    fn earliest(&mut self) -> Option<(usize, f64)> {
        while let Some(&(t, i, s)) = self.entries.first() {
            if self.stamp[i] == s {
                return Some((i, t));
            }
            self.pop_root();
        }
        None
    }

    fn less(a: &(f64, usize, u64), b: &(f64, usize, u64)) -> bool {
        a.0 < b.0 || (a.0 == b.0 && a.1 < b.1)
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if Self::less(&self.entries[i], &self.entries[parent]) {
                self.entries.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.entries.len();
        loop {
            let left = 2 * i + 1;
            if left >= n {
                break;
            }
            let right = left + 1;
            let child = if right < n && Self::less(&self.entries[right], &self.entries[left]) {
                right
            } else {
                left
            };
            if Self::less(&self.entries[child], &self.entries[i]) {
                self.entries.swap(i, child);
                i = child;
            } else {
                break;
            }
        }
    }

    fn pop_root(&mut self) {
        let last = self.entries.len() - 1;
        self.entries.swap(0, last);
        self.entries.pop();
        if !self.entries.is_empty() {
            self.sift_down(0);
        }
    }

    /// Drop every stale generation and re-heapify.
    fn compact(&mut self) {
        let stamp = &self.stamp;
        self.entries.retain(|&(_, i, s)| stamp[i] == s);
        for i in (0..self.entries.len() / 2).rev() {
            self.sift_down(i);
        }
    }
}

/// Load-ordered index over the **Active** replicas: a `BTreeSet` of
/// `(load, replica)` tuples, so the least-loaded active replica (lowest
/// index on ties — the linear scan's order) is the first entry.
/// Draining / Failed / Retired replicas are simply absent.
struct LoadIndex {
    by_load: BTreeSet<(usize, usize)>,
    /// Recorded load per replica; `None` = not indexed (non-Active).
    load: Vec<Option<usize>>,
}

impl LoadIndex {
    /// All `replicas` start Active at load 0.
    fn new(replicas: usize) -> Self {
        LoadIndex {
            by_load: (0..replicas).map(|i| (0, i)).collect(),
            load: vec![Some(0); replicas],
        }
    }

    /// Register a new replica slot (scale-up) as un-indexed; the
    /// caller's next sync inserts it with its real load.
    fn grow(&mut self) {
        self.load.push(None);
    }

    /// (Re-)index replica `i` at load `l`.
    fn set(&mut self, i: usize, l: usize) {
        if let Some(old) = self.load[i] {
            if old == l {
                return;
            }
            self.by_load.remove(&(old, i));
        }
        self.load[i] = Some(l);
        self.by_load.insert((l, i));
    }

    /// Drop replica `i` from the index (lifecycle exit).
    fn remove(&mut self, i: usize) {
        if let Some(old) = self.load[i].take() {
            self.by_load.remove(&(old, i));
        }
    }

    /// Least-loaded indexed replica, optionally excluding one index.
    fn least_loaded_except(&self, exclude: Option<usize>) -> Option<usize> {
        self.by_load.iter().map(|&(_, i)| i).find(|&i| Some(i) != exclude)
    }
}

/// Audit record of one prefix migration.
#[derive(Clone, Debug)]
pub struct MigrationEvent {
    pub tenant: usize,
    pub from: usize,
    pub to: usize,
    /// Index (into the arrival stream) of the arrival whose routing
    /// triggered this migration.
    pub arrival_index: usize,
    /// Modeled interconnect seconds charged to the destination clock
    /// (0 when an earlier spill already paged the group there).
    pub transfer_seconds: f64,
    /// Served-token budget the group must amortize before it may
    /// re-home again (0 for free consolidations).
    pub cooldown_tokens: u64,
    /// Destination `shared_prefills` before/after adoption.  Equal —
    /// or the destination re-prefilled, which the fuzz audit forbids.
    pub dst_prefills_before: u64,
    pub dst_prefills_after: u64,
}

/// Audit record of one fleet resize.
#[derive(Clone, Debug)]
pub struct ScaleEvent {
    /// Modeled time of the triggering arrival.
    pub at: f64,
    /// Index (into the arrival stream) of the triggering arrival.
    pub arrival_index: usize,
    /// Spin-up (a fresh replica joined) or spin-down (a victim
    /// started draining).
    pub up: bool,
    /// The replica that joined / started draining.
    pub replica: usize,
    /// Prefix groups re-homed as part of this event.
    pub groups_moved: usize,
}

/// Per-replica slice of a finished cluster run.
#[derive(Clone, Debug)]
pub struct ReplicaReport {
    pub tokens: u64,
    pub requests_completed: u64,
    pub decode_seconds: f64,
    pub iterations: u64,
    pub mean_batch: f64,
    pub typhoon_iters: u64,
    pub absorb_iters: u64,
    pub naive_iters: u64,
    pub mixed_iters: u64,
    pub preemptions: u64,
    /// Prefix groups hosted (pages held) on this replica.
    pub prefix_groups: usize,
    /// Prefix groups adopted via migration import (no local prefill).
    pub prefix_imports: u64,
    /// Requests the router sent here.
    pub routed: u64,
    /// Requests re-submitted here after a peer crashed.
    pub requeued: u64,
    /// KV pages destroyed here by a crash.
    pub lost_pages: u64,
    /// The replica's final clock (arrival-to-drain span).
    pub final_clock: f64,
    /// Fleet lifecycle state at the end of the run.
    pub state: ReplicaLifecycle,
}

/// Aggregate result of one cluster experiment.
#[derive(Clone, Debug)]
pub struct ClusterReport {
    pub replicas: Vec<ReplicaReport>,
    pub tokens: u64,
    pub requests_completed: u64,
    /// Aggregate busy decode seconds across replicas.
    pub decode_seconds: f64,
    /// Cluster goodput: generated tokens per aggregate replica decode
    /// second — the paper's decode-time throughput metric lifted to the
    /// fleet (it prices the shared-stage streams every replica pays,
    /// which is exactly what routing concentration buys back).
    pub goodput: f64,
    /// Latest replica clock: the wall span from first arrival to drain.
    pub makespan: f64,
    pub ttft_p50: f64,
    pub ttft_p95: f64,
    pub ttft_p99: f64,
    pub tpot_p50: f64,
    pub tpot_p95: f64,
    pub tpot_p99: f64,
    /// Prefix-affinity requests routed off their home replica.
    pub spills: u64,
    /// Tenants that spilled at least once, ascending tenant id — the
    /// per-tenant audit trail behind `spills`, sorted before emission so
    /// the report never leaks `HashSet` iteration order (detlint rule 1).
    pub spilled_tenants: Vec<usize>,
    /// Prefix groups re-homed by the migrate-vs-spill rule (pressure
    /// and scale-event migrations alike).
    pub migrations: u64,
    /// Tenants whose group re-homed at least once, ascending tenant id.
    pub migrated_tenants: Vec<usize>,
    /// Modeled interconnect seconds spent moving pages (fleet total;
    /// wall time on the receiving clocks, never decode time).
    pub transfer_seconds: f64,
    /// Replicas spun up / down by the autoscaler.
    pub scale_ups: u64,
    pub scale_downs: u64,
    /// Active replicas at the end of the run.
    pub active_replicas: usize,
    // ---- fault / recovery aggregates (DESIGN.md §14); all zero on the
    // ---- fault-free path.
    /// Replica crashes delivered by the fault plan.
    pub crashes: u64,
    /// Injected stall events absorbed.
    pub stalls: u64,
    /// Transfer attempts lost in flight and retried with backoff.
    pub transfer_retries: u64,
    /// Transfers that exhausted their retry budget.
    pub transfers_abandoned: u64,
    /// Prefix groups re-homed by crash failover.
    pub failovers: u64,
    /// Tokens re-prefilled because a crash destroyed the only copy.
    pub reprefilled_tokens: u64,
    /// KV pages destroyed by crashes, fleet-wide.
    pub lost_pages: u64,
    /// Sequences re-queued off crashed replicas (never dropped).
    pub requeued_requests: u64,
    /// Generated tokens redone because a crash threw them away.
    pub lost_tokens: u64,
    /// Time-to-recovery percentiles over crashes (crash instant to the
    /// last re-queued sequence re-submitted on a survivor), seconds.
    pub recovery_p50_s: f64,
    pub recovery_p99_s: f64,
}

/// The event-driven N-replica serving simulation.
pub struct ClusterSim {
    params: ClusterParams,
    tenants: Vec<TenantSpec>,
    arrivals: Vec<TimedArrival>,
    next_arrival: usize,
    replicas: Vec<Replica>,
    router: Router,
    /// The unified decision layer: kernel fall-back pricing, the
    /// migrate-vs-spill rule, SLO-driven admission thresholds and the
    /// autoscaling rule.
    policy: PolicyEngine,
    migration_log: Vec<MigrationEvent>,
    scale_log: Vec<ScaleEvent>,
    /// Arrival index of the last scale event (the rate limiter).
    last_scale_arrival: Option<usize>,
    /// The materialized fault schedule (empty = structurally inert).
    faults: FaultPlan,
    /// Crashes actually delivered (a scheduled crash that would kill
    /// the last active replica is skipped, not delivered).
    crashes: u64,
    /// Per-crash recovery spans, seconds (crash instant to the last
    /// re-queued sequence re-submitted on a survivor).
    recovery_times: Vec<f64>,
    /// Indexed event core (DESIGN.md §15): min-heap of busy-replica
    /// clocks, re-synced at every replica mutation site.
    clock_heap: EventHeap,
    /// Load-ordered index of Active replicas (least-loaded routing).
    load_index: LoadIndex,
    /// Test-only oracle switch: answer event/routing queries with the
    /// retained O(N) linear scans instead of the indexes.
    linear_oracle: bool,
    /// Test/bench oracle switch: dispatch parallel windows on freshly
    /// scoped threads (the pre-pool reference) instead of the
    /// persistent worker pool.
    spawn_oracle: bool,
    /// The fleet-shared pricing cache (DESIGN.md §17): every replica
    /// engine, every autoscale spin-up, and the policy engine price
    /// through this one Arc.
    surface: Arc<PriceSurface>,
    /// Parallel windows dispatched to the persistent worker pool.
    pool_windows: u64,
    /// Events processed (arrivals delivered + decode steps) — the
    /// numerator of the bench's `events_per_second`.
    events: u64,
}

impl ClusterSim {
    pub fn new(params: &ClusterParams) -> Result<Self> {
        if params.replicas == 0 {
            bail!("cluster needs at least one replica");
        }
        if params.tenants == 0 {
            bail!("cluster needs at least one tenant");
        }
        let par = params.parallelism;
        if par.tp == 0 || par.sp == 0 {
            bail!("TP/SP ranks must be >= 1, got tp={} sp={}", par.tp, par.sp);
        }
        if params.model.n_heads as u64 % par.tp != 0 {
            bail!(
                "TP {} must divide the model's {} attention heads",
                par.tp,
                params.model.n_heads
            );
        }
        if let Some(t) = params.slo_ttft {
            if !t.is_finite() || t <= 0.0 {
                bail!("TTFT target must be positive seconds, got {t}");
            }
        }
        if (params.migrate
            || params.slo_ttft.is_some()
            || params.scaling.enabled
            || params.faults.enabled)
            && params.router != RouterPolicy::PrefixAffinity
        {
            bail!(
                "migration / SLO admission / autoscaling / fault recovery act on \
                 prefix-affinity pressure relief; router {} never consults them",
                params.router.as_str()
            );
        }
        params.scaling.validate(params.replicas)?;
        params.faults.validate(params.replicas)?;
        if params.arrival_burst.is_some() && params.arrival_rate.is_none() {
            bail!("a burst factor needs an arrival rate (the batch protocol has no phases)");
        }
        // (A non-positive arrival rate / burst factor below one is
        // rejected by the arrival generators.)
        let tenants = tenant_set(params.tenants, params.skew);
        let arrivals = match (params.arrival_rate, params.arrival_burst) {
            (Some(rate), Some(factor)) => timed_arrivals_bursty(
                &tenants,
                params.total_requests,
                rate,
                factor,
                BURST_PHASES,
                params.seed,
            )?,
            _ => timed_arrivals(
                &tenants,
                params.total_requests,
                params.arrival_rate,
                params.seed,
            )?,
        };
        // One fleet-shared price surface: every replica stack below,
        // every autoscale spin-up, and the policy engine memoize into
        // (and hit) the same warm arrays.  A sweep may pass a surface
        // of its own so sibling cells share one warm memo too.
        let surface = match &params.surface {
            Some(s) if s.covers(&params.model, &params.hw, &params.parallelism, 1) => {
                Arc::clone(s)
            }
            _ => PriceSurface::shared(
                params.model.clone(),
                params.hw.clone(),
                params.parallelism,
            ),
        };
        // Per-replica stack: the canonical single-device tenancy sizing
        // (any replica may end up hosting every group, so each pool
        // budgets for all prefixes).
        let mut replicas = Vec::with_capacity(params.replicas);
        for _ in 0..params.replicas {
            let mut coord = tenant_serving_stack_with_surface(
                &params.model,
                &params.hw,
                params.kernel,
                params.batch,
                &tenants,
                params.include_prefill,
                params.parallelism,
                &surface,
            )?;
            // Recycle arena slots at completion: a million-request cell
            // runs in O(max outstanding) sequence memory.  Modeled
            // times are bit-identical either way.
            coord.set_retain_finished(false);
            replicas.push(Replica::fresh(coord));
        }
        let mut policy = PolicyEngine::with_surface(
            params.hw.clone(),
            params.kernel,
            params.parallelism,
            Arc::clone(&surface),
        );
        policy.migration.enabled = params.migrate;
        policy.admission.ttft_target = params.slo_ttft;
        policy.scaling = ScalingPolicy::from_config(&params.scaling);
        let faults = FaultPlan::build(&params.faults, params.replicas, arrivals.len());
        Ok(ClusterSim {
            params: params.clone(),
            tenants,
            arrivals,
            next_arrival: 0,
            replicas,
            router: Router::new(params.router),
            policy,
            migration_log: Vec::new(),
            scale_log: Vec::new(),
            last_scale_arrival: None,
            faults,
            crashes: 0,
            recovery_times: Vec::new(),
            clock_heap: EventHeap::new(params.replicas),
            load_index: LoadIndex::new(params.replicas),
            linear_oracle: false,
            spawn_oracle: false,
            surface,
            pool_windows: 0,
            events: 0,
        })
    }

    /// Route event-core queries through the retained linear scans (the
    /// pre-index reference implementation) instead of the heap and the
    /// load index.  Test-only: the bit-identity oracle the fuzz suite
    /// compares the indexed loop against.
    pub fn use_linear_reference(&mut self, on: bool) {
        self.linear_oracle = on;
    }

    /// Dispatch `run_parallel` windows on freshly scoped threads — the
    /// retained pre-pool reference implementation — instead of the
    /// persistent worker pool.  Bit-identity oracle for the pool path
    /// (fuzzed in `tests/pricing_pool.rs`), and the bench's
    /// `events_per_second_reference` measurement.
    pub fn use_spawn_reference(&mut self, on: bool) {
        self.spawn_oracle = on;
    }

    /// Events processed so far: arrivals delivered plus decode steps.
    pub fn events_processed(&self) -> u64 {
        self.events
    }

    /// Parallel stepping windows dispatched to the persistent worker
    /// pool so far (zero on the serial and spawn-reference paths).
    pub fn pool_windows(&self) -> u64 {
        self.pool_windows
    }

    /// `(hits, misses)` of the fleet-shared price surface — proof the
    /// replicas actually share one warm cache.
    pub fn price_cache_stats(&self) -> (u64, u64) {
        self.surface.stats()
    }

    /// Largest per-replica sequence-arena high-water mark — the peak
    /// number of concurrently reserved sequence slots on any replica.
    pub fn arena_peak(&self) -> usize {
        self.replicas.iter().map(|r| r.coord.arena_peak()).max().unwrap_or(0)
    }

    /// Re-sync replica `i` into the event core after any mutation that
    /// may have moved its clock, changed its load, or flipped its
    /// lifecycle state.  Reads current truth, so redundant syncs are
    /// harmless; a *missing* sync is caught by the debug asserts in
    /// `earliest_busy` / `least_loaded_except` and the identity fuzz.
    fn sync_replica(&mut self, i: usize) {
        let r = &self.replicas[i];
        let busy = r.coord.running() > 0 || r.coord.queued() > 0;
        self.clock_heap.update(i, r.coord.now(), busy);
        if r.state == ReplicaLifecycle::Active {
            self.load_index.set(i, r.coord.load());
        } else {
            self.load_index.remove(i);
        }
    }

    /// Re-sync the whole fleet (multi-replica mutations: crash
    /// recovery, the parallel stepping merge).  Performed in
    /// replica-index order so the merge is deterministic.
    fn sync_all(&mut self) {
        for i in 0..self.replicas.len() {
            self.sync_replica(i);
        }
    }

    /// Least-loaded active replica via the load index (linear scan
    /// under the oracle flag; debug builds cross-check the two).
    fn least_loaded(&self) -> usize {
        self.least_loaded_except(None)
    }

    fn least_loaded_except(&self, exclude: Option<usize>) -> usize {
        if self.linear_oracle {
            return Router::least_loaded_except(&self.replicas, exclude);
        }
        let best = self
            .load_index
            .least_loaded_except(exclude)
            .expect("at least one active candidate replica");
        debug_assert_eq!(
            best,
            Router::least_loaded_except(&self.replicas, exclude),
            "load index diverged from the linear scan"
        );
        best
    }

    /// The generated arrival stream (inspection/conservation checks).
    pub fn arrivals(&self) -> &[TimedArrival] {
        &self.arrivals
    }

    /// Per-replica clocks (monotonicity audits).
    pub fn replica_clocks(&self) -> Vec<f64> {
        self.replicas.iter().map(|r| r.coord.now()).collect()
    }

    /// A replica's coordinator (probes for tests and reports).
    pub fn coordinator(&self, replica: usize) -> &Coordinator<SimEngine> {
        &self.replicas[replica].coord
    }

    /// Every replica ever part of the fleet (including retired ones).
    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// Replicas currently admitting new arrivals.
    pub fn active_replica_count(&self) -> usize {
        self.replicas.iter().filter(|r| r.state == ReplicaLifecycle::Active).count()
    }

    /// A replica's fleet lifecycle state.
    pub fn replica_state(&self, replica: usize) -> ReplicaLifecycle {
        self.replicas[replica].state
    }

    /// Requests the prefix-affinity router sent off their home replica.
    pub fn spills(&self) -> u64 {
        self.router.spills
    }

    /// Did this tenant ever spill off its home replica?
    pub fn tenant_spilled(&self, tenant: usize) -> bool {
        self.router.spilled.contains(&tenant)
    }

    /// Prefix groups re-homed by the migrate-vs-spill rule.
    pub fn migrations(&self) -> u64 {
        self.router.migrations
    }

    /// Was this tenant's group ever migrated?
    pub fn tenant_migrated(&self, tenant: usize) -> bool {
        self.router.migrated.contains(&tenant)
    }

    /// Did this tenant spill after its most recent migration?  (The
    /// only way a migrated group legitimately fragments again.)
    pub fn tenant_spilled_since_migration(&self, tenant: usize) -> bool {
        self.router.spilled_since_migration.contains(&tenant)
    }

    /// Per-migration audit records (destination prefill counters,
    /// modeled transfer time, cool-down budgets).
    pub fn migration_log(&self) -> &[MigrationEvent] {
        &self.migration_log
    }

    /// Per-resize audit records.
    pub fn scale_log(&self) -> &[ScaleEvent] {
        &self.scale_log
    }

    /// Replicas spun up / down so far.
    pub fn scale_ups(&self) -> u64 {
        self.scale_log.iter().filter(|e| e.up).count() as u64
    }

    pub fn scale_downs(&self) -> u64 {
        self.scale_log.iter().filter(|e| !e.up).count() as u64
    }

    /// Scale-event re-homes that retired the source copy and left the
    /// destination to re-prefill (the pricing's "rebuild" branch).
    pub fn reprefill_rehomes(&self) -> u64 {
        self.router.reprefill_rehomes
    }

    /// Crashes actually delivered by the fault plan so far.
    pub fn crashes(&self) -> u64 {
        self.crashes
    }

    /// Per-crash recovery spans recorded so far, seconds.
    pub fn recovery_times(&self) -> &[f64] {
        &self.recovery_times
    }

    /// The materialized fault schedule (audits).
    pub fn fault_plan(&self) -> &FaultPlan {
        &self.faults
    }

    /// Did this replica adopt the tenant's group via migration import?
    pub fn tenant_imported(&self, replica: usize, tenant: usize) -> bool {
        self.replicas[replica].imported.contains(&tenant)
    }

    /// Every prefix copy retired by an outbound migration whose pages
    /// have actually been released (true once their groups drained).
    pub fn retired_copies_released(&self) -> bool {
        self.replicas
            .iter()
            .all(|r| r.retired.iter().all(|&(_, pid)| r.coord.kv.prefix(pid).is_none()))
    }

    /// Number of replicas holding this tenant's prefix pages.
    pub fn replicas_hosting(&self, tenant: usize) -> usize {
        self.replicas.iter().filter(|r| r.prefix_of.contains_key(&tenant)).count()
    }

    /// The earliest busy replica (has queued or running work), by
    /// clock, lowest index on ties.  Draining replicas stay in the loop
    /// until their in-flight work finishes.  Answered by the clock heap
    /// (linear scan under the oracle flag; debug builds cross-check).
    fn earliest_busy(&mut self) -> Option<(usize, f64)> {
        if self.linear_oracle {
            return self.earliest_busy_linear();
        }
        let best = self.clock_heap.earliest();
        debug_assert_eq!(
            best,
            self.earliest_busy_linear(),
            "clock heap diverged from the linear scan"
        );
        best
    }

    /// The retained O(#replicas) reference scan (bit-identity oracle).
    fn earliest_busy_linear(&self) -> Option<(usize, f64)> {
        let mut best: Option<(usize, f64)> = None;
        for (i, r) in self.replicas.iter().enumerate() {
            if r.coord.running() > 0 || r.coord.queued() > 0 {
                let t = r.coord.now();
                let earlier = match best {
                    None => true,
                    Some((_, bt)) => t < bt,
                };
                if earlier {
                    best = Some((i, t));
                }
            }
        }
        best
    }

    /// Flip drained spin-down victims to `Retired` (no work left, every
    /// prefix copy released).  A no-op until a scale-down happened.
    fn finalize_drained(&mut self) {
        for r in &mut self.replicas {
            if r.state == ReplicaLifecycle::Draining
                && r.coord.running() == 0
                && r.coord.queued() == 0
                && r.coord.prefix_groups().is_empty()
            {
                r.state = ReplicaLifecycle::Retired;
            }
        }
    }

    /// Process one event: deliver the next arrival if it is due no
    /// later than every busy replica's clock (autoscale check + router
    /// probe + submit, fast-forwarding an idle replica), otherwise run
    /// one decode step of the earliest-clock busy replica.  Returns
    /// false when the stream is exhausted and every replica has
    /// drained.
    pub fn step_event(&mut self) -> Result<bool> {
        let busy = self.earliest_busy();
        if self.next_arrival < self.arrivals.len() {
            let due = match busy {
                None => true,
                Some((_, t)) => self.arrivals[self.next_arrival].at <= t,
            };
            if due {
                self.deliver_next_arrival()?;
                return Ok(true);
            }
        }
        if let Some((i, _)) = busy {
            self.replicas[i].coord.step()?;
            self.events += 1;
            self.sync_replica(i);
            return Ok(true);
        }
        if self.policy.scaling.enabled {
            self.finalize_drained();
        }
        Ok(false)
    }

    /// Deliver arrival `self.next_arrival` (the caller has established
    /// it is due): fault delivery, autoscale check, router probe,
    /// submit — the fully serialized part of the event loop, shared by
    /// `step_event` and `run_parallel`.
    fn deliver_next_arrival(&mut self) -> Result<()> {
        let idx = self.next_arrival;
        let a = self.arrivals[idx].clone();
        self.next_arrival += 1;
        if !self.faults.is_empty() {
            self.deliver_faults(idx, a.at)?;
        }
        if self.policy.scaling.enabled {
            self.finalize_drained();
            self.maybe_scale(&a, idx)?;
        }
        let r = self.route_arrival(&a)?;
        let rep = &mut self.replicas[r];
        rep.coord.advance_clock(a.at);
        let pid = match rep.prefix_of.get(&a.tenant) {
            Some(&p) => p,
            None => {
                // First request of this group here: the replica
                // prefills + pages the tenant's prefix (this is
                // the state prefix-affinity preserves).
                let tokens = self.tenants[a.tenant].prompt_token_ids(50_000);
                let p = rep.coord.register_prefix_group(&tokens)?;
                rep.prefix_of.insert(a.tenant, p);
                p
            }
        };
        // Anchor the submission at the *arrival* time: a busy
        // replica's clock may already be past `a.at` (arrivals
        // are only deliverable between decode iterations), and
        // that wait is real queueing delay TTFT must include.
        rep.coord.submit_to_at(&a.request, pid, a.at)?;
        rep.routed += 1;
        // This arrival's generation budget amortizes its
        // group's outstanding re-home cool-down (served-token
        // budget; pools are sized so budgets are served in
        // full).
        if let Some(c) = self.router.cooldown_tokens.get_mut(&a.tenant) {
            *c = c.saturating_sub(a.request.max_new_tokens as u64);
        }
        self.events += 1;
        self.sync_replica(r);
        Ok(())
    }

    /// Pick the replica for one arrival, probing replica queue depth,
    /// load and KV headroom; prefix-affinity pressure relief goes
    /// through the policy layer's migrate-vs-spill rule.  Only active
    /// replicas admit.
    fn route_arrival(&mut self, a: &TimedArrival) -> Result<usize> {
        match self.router.policy {
            RouterPolicy::RoundRobin => {
                // Autoscaling requires prefix-affinity, so under
                // round-robin every replica is always Active and the
                // plain modulo stays correct (no per-arrival filter).
                debug_assert!(self
                    .replicas
                    .iter()
                    .all(|r| r.state == ReplicaLifecycle::Active));
                let r = self.router.rr_next % self.replicas.len();
                self.router.rr_next += 1;
                Ok(r)
            }
            RouterPolicy::LeastLoaded => Ok(self.least_loaded()),
            RouterPolicy::PrefixAffinity => self.route_affinity(a),
        }
    }

    /// The queue depth at which a replica counts as pressured:
    /// SLO-derived when a TTFT target is set, the fixed queue-depth
    /// constant otherwise (bit-identical to the pre-SLO router).
    fn pressure_depth(&self, replica: usize) -> usize {
        if self.policy.admission.ttft_target.is_some() {
            self.policy.admission.spill_depth(
                self.replicas[replica].coord.service_rate(),
                self.observed_arrival_rate(),
                self.params.spill_queue_depth,
            )
        } else {
            self.params.spill_queue_depth
        }
    }

    fn route_affinity(&mut self, a: &TimedArrival) -> Result<usize> {
        let tenant = a.tenant;
        let home = match self.router.home.get(&tenant).copied() {
            Some(h) if self.replicas[h].state == ReplicaLifecycle::Active => h,
            // First sighting (or a home lost to a spin-down that found
            // nothing to re-home): adopt the least-loaded active
            // replica as the group's home (it will hold the pages).
            _ => {
                let r = self.least_loaded();
                self.router.home.insert(tenant, r);
                return Ok(r);
            }
        };
        let depth = self.pressure_depth(home);
        let h = &self.replicas[home].coord;
        let pressured =
            h.queued() >= depth || !h.can_admit_now(a.request.prompt_tokens);
        if pressured && self.active_replica_count() > 1 {
            let alt = self.least_loaded_except(Some(home));
            if self.replicas[alt].coord.load() < self.replicas[home].coord.load() {
                let len = self.tenants[tenant].prompt_tokens;
                let expanded = self.replicas[home]
                    .prefix_of
                    .get(&tenant)
                    .and_then(|&p| self.replicas[home].coord.kv.prefix(p))
                    .is_some_and(|p| p.expanded);
                // Residency at the peer (an earlier spill re-prefilled
                // it there) makes re-homing free — the policy layer
                // short-circuits the cost comparison for that case, so
                // the decision matches what `migrate_group` will
                // actually charge.  A group still amortizing its last
                // transfer may not re-home again (the ping-pong
                // cool-down): its overflow spills instead.
                let alt_hosts = self.replicas[alt].prefix_of.contains_key(&tenant);
                let cooling =
                    self.router.cooldown_tokens.get(&tenant).copied().unwrap_or(0) > 0;
                let decision = if cooling {
                    MigrationDecision::Spill
                } else {
                    self.policy.migrate_or_spill(len, expanded, alt_hosts)
                };
                return match decision {
                    MigrationDecision::Migrate => {
                        // Re-home the whole group: the overflow (and
                        // everything after it) lands on a replica that
                        // now holds the pages.  A refused transfer (the
                        // fault layer lost it beyond the retry budget)
                        // leaves the pages home — this one request
                        // degrades to a recorded spill instead.
                        if !self.migrate_group(tenant, home, alt, a.at, self.next_arrival - 1)? {
                            self.router.spills += 1;
                            self.router.spilled.insert(tenant);
                            self.router.spilled_since_migration.insert(tenant);
                        }
                        Ok(alt)
                    }
                    MigrationDecision::Spill => {
                        // Route this one request around the pressured
                        // home — the pages stay where they are, and the
                        // spill is recorded for the invariant audit (a
                        // group on two replicas implies a recorded
                        // spill).
                        self.router.spills += 1;
                        self.router.spilled.insert(tenant);
                        self.router.spilled_since_migration.insert(tenant);
                        Ok(alt)
                    }
                };
            }
        }
        Ok(home)
    }

    /// Observed fleet arrival rate over the delivered stream so far,
    /// per **active** replica (the admission policy's lambda-hat).
    /// Dividing by the full fleet size would under-report the load the
    /// moment the fleet resizes — a drained replica takes no arrivals,
    /// so the survivors each see a larger share.  Infinite under the
    /// batch protocol (everything at t = 0) — the admission policy
    /// falls back to the fixed depth then.
    pub fn observed_arrival_rate(&self) -> f64 {
        if self.next_arrival == 0 {
            return 0.0;
        }
        let span = self.arrivals[self.next_arrival - 1].at;
        if span > 0.0 {
            self.next_arrival as f64 / span / self.active_replica_count().max(1) as f64
        } else {
            f64::INFINITY
        }
    }

    /// Windowed fleet arrival rate over the last `rate_window`
    /// delivered arrivals — the autoscaler's lambda-hat (a burst must
    /// be visible against a calm history, which the cumulative average
    /// smooths away).  Infinite when the window collapsed to one
    /// instant (batch protocol); 0 before two arrivals.
    pub fn observed_burst_rate(&self) -> f64 {
        let n = self.next_arrival;
        if n < 2 {
            return 0.0;
        }
        let w = self.policy.scaling.rate_window.max(2).min(n);
        let span = self.arrivals[n - 1].at - self.arrivals[n - w].at;
        if span > 0.0 {
            (w - 1) as f64 / span
        } else {
            f64::INFINITY
        }
    }

    /// The autoscaling check, run as each arrival is delivered: observe
    /// the windowed arrival rate against the active fleet's summed
    /// service rates and spin a replica up or down.  Rate-limited to
    /// one scale event per `cooldown_arrivals` arrivals.  A `Hold` (or
    /// a down-decision with no idle victim) mutates nothing — the
    /// never-triggered run is bit-identical to the fixed fleet.
    fn maybe_scale(&mut self, a: &TimedArrival, idx: usize) -> Result<()> {
        if let Some(last) = self.last_scale_arrival {
            if idx - last < self.policy.scaling.cooldown_arrivals {
                return Ok(());
            }
        }
        let lambda = self.observed_burst_rate();
        let mu: f64 = self
            .replicas
            .iter()
            .filter(|r| r.state == ReplicaLifecycle::Active)
            .map(|r| r.coord.service_rate())
            .sum();
        let active = self.active_replica_count();
        match self.policy.scaling.decide(lambda, mu, active) {
            ScalingDecision::Hold => Ok(()),
            ScalingDecision::Up => self.scale_up(a.at, idx),
            ScalingDecision::Down => self.scale_down(a.at, idx),
        }
    }

    /// Spin a fresh replica up and bulk-migrate the hottest *pressured*
    /// groups onto it: for every active replica whose queue has reached
    /// the pressure depth, its hottest hosted group (largest arrival
    /// share, lowest tenant id on ties) re-homes to the new replica —
    /// by page transfer when `PolicyEngine` prices the interconnect
    /// stream under a fresh re-prefill, by retire-and-rebuild
    /// otherwise.  Scale-event re-homes bypass (and reset) the
    /// per-group ping-pong cool-down: a capacity change is not thrash,
    /// and the event itself is rate-limited.
    fn scale_up(&mut self, at: f64, idx: usize) -> Result<()> {
        // A spin-up adopts the fleet surface: it joins with the warm
        // pricing cache instead of rebuilding a cold memo.
        let mut coord = tenant_serving_stack_with_surface(
            &self.params.model,
            &self.params.hw,
            self.params.kernel,
            self.params.batch,
            &self.tenants,
            self.params.include_prefill,
            self.params.parallelism,
            &self.surface,
        )?;
        coord.set_retain_finished(false);
        let mut rep = Replica::fresh(coord);
        rep.coord.advance_clock(at);
        let new_idx = self.replicas.len();
        self.replicas.push(rep);
        self.clock_heap.grow();
        self.load_index.grow();
        self.sync_replica(new_idx);

        let mut moves: Vec<(usize, usize)> = Vec::new(); // (src, tenant)
        for src in 0..new_idx {
            if self.replicas[src].state != ReplicaLifecycle::Active {
                continue;
            }
            if self.replicas[src].coord.queued() < self.pressure_depth(src) {
                continue;
            }
            let mut best: Option<(f64, usize)> = None;
            for t in 0..self.tenants.len() {
                if self.router.home.get(&t) != Some(&src)
                    || !self.replicas[src].prefix_of.contains_key(&t)
                {
                    continue;
                }
                let share = self.tenants[t].share;
                let better = match best {
                    None => true,
                    Some((s, _)) => share > s,
                };
                if better {
                    best = Some((share, t));
                }
            }
            if let Some((_, t)) = best {
                moves.push((src, t));
            }
        }
        let mut moved = 0usize;
        for (src, tenant) in moves {
            let len = self.tenants[tenant].prompt_tokens;
            let expanded = self.replicas[src]
                .prefix_of
                .get(&tenant)
                .and_then(|&p| self.replicas[src].coord.kv.prefix(p))
                .is_some_and(|p| p.expanded);
            if !(self.policy.rehome_by_transfer(len, expanded, false)
                && self.migrate_group(tenant, src, new_idx, at, idx)?)
            {
                // Pricing said rebuild — or the fault layer refused the
                // transfer: the re-home still happens, by re-prefill.
                self.rehome_without_pages(tenant, src, new_idx)?;
            }
            moved += 1;
        }
        self.scale_log.push(ScaleEvent {
            at,
            arrival_index: idx,
            up: true,
            replica: new_idx,
            groups_moved: moved,
        });
        self.last_scale_arrival = Some(idx);
        Ok(())
    }

    /// Spin a replica down: the **idle** active replica hosting the
    /// fewest groups (lowest index on ties) drains — every group it
    /// hosts re-homes to the least-loaded survivor (page transfer or
    /// retire-and-rebuild, by the same pricing), stray spilled copies
    /// just retire, and the victim takes no further admissions.  No
    /// idle victim means no event (draining a busy replica would
    /// fragment its live groups, the exact cost concentration exists
    /// to avoid).
    fn scale_down(&mut self, at: f64, idx: usize) -> Result<()> {
        let victim = (0..self.replicas.len())
            .filter(|&i| {
                self.replicas[i].state == ReplicaLifecycle::Active
                    && self.replicas[i].coord.load() == 0
            })
            .min_by_key(|&i| (self.replicas[i].prefix_of.len(), i));
        let Some(victim) = victim else {
            return Ok(());
        };
        self.replicas[victim].state = ReplicaLifecycle::Draining;
        self.sync_replica(victim);
        let hosted: Vec<usize> = det::sorted_keys(&self.replicas[victim].prefix_of);
        let mut moved = 0usize;
        for tenant in hosted {
            if self.router.home.get(&tenant) == Some(&victim) {
                let dst = self.least_loaded();
                let len = self.tenants[tenant].prompt_tokens;
                let expanded = self.replicas[victim]
                    .prefix_of
                    .get(&tenant)
                    .and_then(|&p| self.replicas[victim].coord.kv.prefix(p))
                    .is_some_and(|p| p.expanded);
                let dst_hosts = self.replicas[dst].prefix_of.contains_key(&tenant);
                if !(self.policy.rehome_by_transfer(len, expanded, dst_hosts)
                    && self.migrate_group(tenant, victim, dst, at, idx)?)
                {
                    // The victim must still vacate: fall back to the
                    // re-prefill re-home when the transfer is refused.
                    self.rehome_without_pages(tenant, victim, dst)?;
                }
                moved += 1;
            } else if let Some(pid) = self.replicas[victim].prefix_of.remove(&tenant) {
                // A stray spilled copy: retire it in place (released
                // immediately — the victim is idle).
                self.replicas[victim].coord.retire_prefix_group(pid)?;
                self.replicas[victim].retired.push((tenant, pid));
            }
        }
        self.scale_log.push(ScaleEvent {
            at,
            arrival_index: idx,
            up: false,
            replica: victim,
            groups_moved: moved,
        });
        self.last_scale_arrival = Some(idx);
        self.finalize_drained();
        Ok(())
    }

    /// Scale-event re-home on the "rebuild" branch of the pricing: the
    /// source copy retires (pages release at drain) and the stickiness
    /// moves, so the destination re-prefills the prefix on the group's
    /// next arrival.
    fn rehome_without_pages(&mut self, tenant: usize, src: usize, dst: usize) -> Result<()> {
        if let Some(pid) = self.replicas[src].prefix_of.remove(&tenant) {
            self.replicas[src].coord.retire_prefix_group(pid)?;
            self.replicas[src].retired.push((tenant, pid));
        }
        self.router.home.insert(tenant, dst);
        self.router.reprefill_rehomes += 1;
        Ok(())
    }

    /// Re-home `tenant`'s prefix group from `src` to `dst`: the
    /// destination adopts the pages over the interconnect (no
    /// re-prefill — the audit log records its prefill counter around
    /// the adoption), every other replica's copy is retired (released
    /// the moment its last sequence drains), the router's stickiness
    /// follows the pages, and the group starts a served-token cool-down
    /// amortizing the transfer.
    ///
    /// Returns whether the group actually re-homed.  `false` means the
    /// migration was refused — the destination is no longer admitting
    /// (drain/crash raced the decision) or the fault layer lost the
    /// transfer beyond its retry budget — and nothing moved: the caller
    /// spills or falls back to a re-prefill re-home instead.
    fn migrate_group(
        &mut self,
        tenant: usize,
        src: usize,
        dst: usize,
        at: f64,
        arrival_index: usize,
    ) -> Result<bool> {
        if self.replicas[dst].state != ReplicaLifecycle::Active {
            // A draining (or failed) replica refuses imports: its pages
            // are on their way out, adopting new ones would wedge the
            // drain.  Refuse cleanly and let the caller re-route.
            return Ok(false);
        }
        let src_pid = *self.replicas[src]
            .prefix_of
            .get(&tenant)
            .ok_or_else(|| anyhow!("migration source does not host tenant {tenant}"))?;
        let before = self.replicas[dst].coord.metrics.shared_prefills;
        let (transfer, cooldown) = if self.replicas[dst].prefix_of.contains_key(&tenant) {
            // An earlier spill already paged the group here: adopt the
            // resident copy, nothing crosses the interconnect (and
            // nothing needs exporting, amortizing, or losing in
            // flight).
            (0.0, 0)
        } else {
            let export = self.replicas[src].coord.kv.export_prefix(src_pid)?;
            let secs = self
                .policy
                .prefix_transfer_seconds(export.tokens.len(), export.expanded);
            let cooldown = self
                .policy
                .migration_cooldown_tokens(export.tokens.len(), export.expanded);
            let (delivered, secs) = if self.faults.is_empty() {
                (true, secs)
            } else {
                self.fault_adjusted_transfer(src, dst, arrival_index, secs)
            };
            {
                let rep = &mut self.replicas[dst];
                rep.coord.advance_clock(at);
                rep.coord.charge_transfer(secs);
            }
            if !delivered {
                // Every attempt was lost (or the pair is partitioned)
                // and the retry budget ran out: the time was spent, but
                // the pages never landed — the group stays home.  The
                // destination clock still moved: re-key it.
                self.sync_replica(dst);
                return Ok(false);
            }
            let rep = &mut self.replicas[dst];
            let pid = rep.coord.import_prefix_group(&export)?;
            rep.prefix_of.insert(tenant, pid);
            rep.imported.insert(tenant);
            (secs, cooldown)
        };
        let after = self.replicas[dst].coord.metrics.shared_prefills;
        for (i, rep) in self.replicas.iter_mut().enumerate() {
            if i == dst {
                continue;
            }
            if let Some(pid) = rep.prefix_of.remove(&tenant) {
                rep.coord.retire_prefix_group(pid)?;
                rep.retired.push((tenant, pid));
            }
        }
        self.router.home.insert(tenant, dst);
        self.router.migrations += 1;
        self.router.migrated.insert(tenant);
        self.router.spilled_since_migration.remove(&tenant);
        if cooldown > 0 {
            self.router.cooldown_tokens.insert(tenant, cooldown);
        } else {
            self.router.cooldown_tokens.remove(&tenant);
        }
        self.migration_log.push(MigrationEvent {
            tenant,
            from: src,
            to: dst,
            arrival_index,
            transfer_seconds: transfer,
            cooldown_tokens: cooldown,
            dst_prefills_before: before,
            dst_prefills_after: after,
        });
        // The adoption moved the destination clock (transfer charge):
        // re-key it in the event core.
        self.sync_replica(dst);
        Ok(true)
    }

    /// Realized cost of one prefix transfer under the fault layer:
    /// degradation windows scale the wire time, and each lost attempt
    /// is retried on capped exponential backoff (priced and recorded on
    /// the destination).  Returns `(delivered, seconds_to_charge)`.  A
    /// partitioned pair (`bw_factor == 0`) times out on every attempt,
    /// each priced at the nominal wire time.
    fn fault_adjusted_transfer(
        &mut self,
        src: usize,
        dst: usize,
        arrival_index: usize,
        base: f64,
    ) -> (bool, f64) {
        let factor = self.faults.bw_factor(src, dst, arrival_index);
        let partitioned = factor <= 0.0;
        let eff = if partitioned { base } else { base / factor };
        let mut total = 0.0;
        let mut attempt = 1u32;
        loop {
            if !(partitioned || self.faults.transfer_lost()) {
                return (true, total + eff);
            }
            self.replicas[dst].coord.metrics.transfer_retries += 1;
            total += self.policy.recovery.attempt_seconds(attempt, eff);
            if !self.policy.recovery.should_retry(attempt) {
                self.replicas[dst].coord.metrics.transfers_abandoned += 1;
                return (false, total);
            }
            attempt += 1;
        }
    }

    /// Deliver every fault event due at this arrival boundary.  Stalls
    /// push the target's clock forward (queued work really waits behind
    /// the silence); crashes run detection, failover and re-queue.
    fn deliver_faults(&mut self, idx: usize, now: f64) -> Result<()> {
        while let Some(ev) = self.faults.pop_due(idx) {
            match ev.kind {
                FaultKind::Stall { replica, seconds } => {
                    let rep = &mut self.replicas[replica];
                    if matches!(
                        rep.state,
                        ReplicaLifecycle::Active | ReplicaLifecycle::Draining
                    ) {
                        let t = rep.coord.now().max(now) + seconds;
                        rep.coord.advance_clock(t);
                        rep.coord.metrics.stalls += 1;
                        self.sync_replica(replica);
                    }
                }
                FaultKind::Crash { replica } => self.fail_replica(replica, now)?,
            }
        }
        Ok(())
    }

    /// Kill one replica and survive it: its pages are counted lost and
    /// destroyed, its in-flight sequences are extracted for re-queue
    /// (never dropped), every tenant it homed fails over — to a
    /// surviving page copy when one exists, to a cost-priced re-prefill
    /// on the least-loaded survivor otherwise — and the extracted work
    /// re-submits on the new homes once the crash is detected
    /// (`RecoveryPolicy::crash_timeout` after the crash instant).
    fn fail_replica(&mut self, victim: usize, now: f64) -> Result<()> {
        if self.replicas[victim].state != ReplicaLifecycle::Active {
            return Ok(());
        }
        if self.active_replica_count() < 2 {
            // Never kill the last admitting replica: validation caps
            // *scheduled* crashes below the fleet size, but autoscaling
            // or earlier crashes may have thinned the fleet since.
            return Ok(());
        }
        self.crashes += 1;
        let crash_time = self.replicas[victim].coord.now().max(now);
        let detect_at = crash_time + self.policy.recovery.crash_timeout;

        // Tear the victim down: count the destroyed pages, extract the
        // in-flight sequences, retire every hosted prefix copy (its
        // users and pins are gone, so the pages release immediately — a
        // failed replica ends at zero live pages).
        let rep = &mut self.replicas[victim];
        rep.state = ReplicaLifecycle::Failed;
        rep.coord.metrics.lost_pages += rep.coord.kv.used_blocks() as u64;
        let work = rep.coord.fail_and_extract()?;
        let mut tenant_of: HashMap<PrefixId, usize> =
            rep.retired.iter().map(|&(t, p)| (p, t)).collect();
        tenant_of.extend(det::sorted_pairs(&rep.prefix_of).into_iter().map(|(t, p)| (p, t)));
        let hosted: Vec<(usize, PrefixId)> = det::drain_sorted(&mut rep.prefix_of);
        for &(tenant, pid) in &hosted {
            rep.coord.retire_prefix_group(pid)?;
            rep.retired.push((tenant, pid));
        }

        // Fail the dead homes over: prefer a surviving page copy (free
        // adoption, nothing crosses the wire), fall back to the
        // least-loaded survivor — which re-prefills the prefix on the
        // group's next arrival through the normal lazy registration
        // path — when the crash destroyed the only copy.
        let dead_homes: Vec<usize> = det::sorted_pairs(&self.router.home)
            .into_iter()
            .filter(|&(_, h)| h == victim)
            .map(|(t, _)| t)
            .collect();
        for tenant in dead_homes {
            let copies: Vec<usize> = (0..self.replicas.len())
                .filter(|&i| {
                    self.replicas[i].state == ReplicaLifecycle::Active
                        && self.replicas[i].prefix_of.contains_key(&tenant)
                })
                .collect();
            let dst = if self.policy.recovery.prefer_copy_import(copies.len()) {
                *copies
                    .iter()
                    .min_by_key(|&&i| (self.replicas[i].coord.load(), i))
                    .unwrap()
            } else {
                let d = Router::least_loaded(&self.replicas);
                self.replicas[d].coord.metrics.reprefilled_tokens +=
                    self.tenants[tenant].prompt_tokens as u64;
                self.router.reprefill_rehomes += 1;
                d
            };
            self.router.home.insert(tenant, dst);
            self.replicas[dst].coord.metrics.failovers += 1;
        }

        // Re-queue the extracted work on the survivors at detection
        // time: each request re-submits exactly once, restarting from
        // its prompt (the tokens it had generated are booked lost on
        // the victim and redone here).
        let mut recovered_at = detect_at;
        for w in &work {
            let tenant = *tenant_of.get(&w.prefix).ok_or_else(|| {
                anyhow!("re-queued sequence references a prefix the victim never hosted")
            })?;
            let dst = match self.router.home.get(&tenant).copied() {
                Some(h) if self.replicas[h].state == ReplicaLifecycle::Active => h,
                _ => {
                    let d = Router::least_loaded(&self.replicas);
                    self.router.home.insert(tenant, d);
                    d
                }
            };
            let rep = &mut self.replicas[dst];
            rep.coord.advance_clock(detect_at);
            let pid = match rep.prefix_of.get(&tenant) {
                Some(&p) => p,
                None => {
                    let tokens = self.tenants[tenant].prompt_token_ids(50_000);
                    let p = rep.coord.register_prefix_group(&tokens)?;
                    rep.prefix_of.insert(tenant, p);
                    p
                }
            };
            let req = Request {
                id: u64::MAX,
                prompt_tokens: w.prompt_tokens,
                max_new_tokens: w.max_new_tokens,
            };
            rep.coord.submit_to_at(&req, pid, detect_at)?;
            rep.requeued += 1;
            recovered_at = recovered_at.max(rep.coord.now());
        }
        self.recovery_times.push(recovered_at - crash_time);
        // Crash recovery touched many replicas at once (the Failed
        // victim left, the survivors gained clock and work): re-key the
        // whole fleet.
        self.sync_all();
        Ok(())
    }

    /// Drive arrivals and replicas until everything drains.
    pub fn run(&mut self) -> Result<()> {
        while self.step_event()? {}
        Ok(())
    }

    /// Drive the same simulation, decode-stepping independent replicas
    /// **concurrently** between consecutive router decisions
    /// (DESIGN.md §15) — byte-identical to [`ClusterSim::run`].
    ///
    /// Why identity holds: the serial loop only ever steps the
    /// clock-minimum busy replica, and only while that minimum precedes
    /// the next arrival's timestamp — so between two consecutive
    /// deliveries, each busy replica independently steps until its own
    /// clock reaches the arrival instant (or it drains), an isolated
    /// per-replica computation.  Replicas interact *only* inside
    /// `deliver_next_arrival` (routing, faults, autoscaling and
    /// migration are all serialized there, keyed to arrival indices).
    /// The parallel interval computes exactly those per-replica step
    /// sequences on the persistent worker pool (`util::pool`; the
    /// original `std::thread::scope` dispatch is retained behind
    /// [`ClusterSim::use_spawn_reference`]) and merges the results
    /// into the event core in replica-index order.
    pub fn run_parallel(&mut self) -> Result<()> {
        loop {
            // Serialized phase: deliver every arrival that is due (at
            // or before the earliest busy clock), in exactly the order
            // `step_event` delivers them.
            while self.next_arrival < self.arrivals.len() {
                let due = match self.earliest_busy() {
                    None => true,
                    Some((_, t)) => self.arrivals[self.next_arrival].at <= t,
                };
                if !due {
                    break;
                }
                self.deliver_next_arrival()?;
            }
            if self.next_arrival >= self.arrivals.len() {
                // Stream exhausted: drain every replica, then settle
                // lifecycle exactly as the serial loop's final
                // `step_event` does.
                self.step_replicas_until(None)?;
                if self.policy.scaling.enabled {
                    self.finalize_drained();
                }
                return Ok(());
            }
            // Parallel phase: every busy replica steps privately up to
            // the next arrival instant.
            let horizon = self.arrivals[self.next_arrival].at;
            self.step_replicas_until(Some(horizon))?;
        }
    }

    /// Decode-step every busy replica whose clock precedes `horizon`
    /// until it reaches the horizon or drains (`None` = drain
    /// everything).  Each worker owns one replica at a time — the
    /// computation touches only that replica's stack — and the event
    /// core is re-synced in replica-index order afterwards, so the
    /// merge is deterministic regardless of worker scheduling or how
    /// the windows are dispatched.  Dispatch goes to the persistent
    /// worker pool by default (one publish + wakeup per window instead
    /// of per-window thread spawns — DESIGN.md §17); the original
    /// scoped-spawn body is retained behind
    /// [`ClusterSim::use_spawn_reference`] as the bit-identity oracle.
    fn step_replicas_until(&mut self, horizon: Option<f64>) -> Result<()> {
        let stepped = AtomicU64::new(0);
        let first_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        let use_pool = !self.spawn_oracle;
        {
            let slots: Vec<Mutex<&mut Replica>> =
                self.replicas.iter_mut().map(Mutex::new).collect();
            let workers = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(slots.len())
                .max(1);
            // One replica's private window: step until the horizon (or
            // drain).  Identical under either dispatcher — work
            // distribution cannot affect results because replicas only
            // interact inside `deliver_next_arrival`.
            let step_replica = |i: usize| {
                let mut rep = slots[i].lock().unwrap();
                let mut local = 0u64;
                loop {
                    let busy = rep.coord.running() > 0 || rep.coord.queued() > 0;
                    if !busy || horizon.is_some_and(|h| rep.coord.now() >= h) {
                        break;
                    }
                    if let Err(e) = rep.coord.step() {
                        let mut slot = first_err.lock().unwrap();
                        if slot.is_none() {
                            *slot = Some(e);
                        }
                        break;
                    }
                    local += 1;
                }
                stepped.fetch_add(local, Ordering::Relaxed);
            };
            if use_pool {
                pool::global().run(slots.len(), workers, &step_replica);
            } else {
                let cursor = AtomicUsize::new(0);
                std::thread::scope(|scope| {
                    for _ in 0..workers {
                        scope.spawn(|| loop {
                            let i = cursor.fetch_add(1, Ordering::Relaxed);
                            if i >= slots.len() {
                                break;
                            }
                            step_replica(i);
                        });
                    }
                });
            }
        }
        if use_pool {
            self.pool_windows += 1;
        }
        if let Some(e) = first_err.into_inner().unwrap() {
            return Err(e);
        }
        self.events += stepped.into_inner();
        self.sync_all();
        Ok(())
    }

    /// Aggregate the per-replica metrics into the cluster report.
    pub fn report(&self) -> ClusterReport {
        let mut reps = Vec::with_capacity(self.replicas.len());
        let mut ttft: Vec<f64> = Vec::new();
        let mut tpot: Vec<f64> = Vec::new();
        let mut tokens = 0u64;
        let mut completed = 0u64;
        let mut decode_seconds = 0.0f64;
        let mut makespan = 0.0f64;
        let mut transfer_seconds = 0.0f64;
        let mut stalls = 0u64;
        let mut transfer_retries = 0u64;
        let mut transfers_abandoned = 0u64;
        let mut failovers = 0u64;
        let mut reprefilled_tokens = 0u64;
        let mut lost_pages = 0u64;
        let mut requeued_requests = 0u64;
        let mut lost_tokens = 0u64;
        for r in &self.replicas {
            let m: &Metrics = &r.coord.metrics;
            tokens += m.tokens_generated;
            completed += m.requests_completed;
            decode_seconds += m.decode_seconds;
            transfer_seconds += m.transfer_seconds;
            stalls += m.stalls;
            transfer_retries += m.transfer_retries;
            transfers_abandoned += m.transfers_abandoned;
            failovers += m.failovers;
            reprefilled_tokens += m.reprefilled_tokens;
            lost_pages += m.lost_pages;
            requeued_requests += m.requeued_requests;
            lost_tokens += m.lost_tokens;
            makespan = makespan.max(r.coord.now());
            ttft.extend_from_slice(m.ttft.values());
            tpot.extend_from_slice(m.tpot.values());
            reps.push(ReplicaReport {
                tokens: m.tokens_generated,
                requests_completed: m.requests_completed,
                decode_seconds: m.decode_seconds,
                iterations: m.decode_iterations,
                mean_batch: m.batch_occupancy.mean(),
                typhoon_iters: m.typhoon_iters,
                absorb_iters: m.absorb_iters,
                naive_iters: m.naive_iters,
                mixed_iters: m.mixed_iters,
                preemptions: m.preemptions,
                prefix_groups: r.prefix_of.len(),
                prefix_imports: m.prefix_imports,
                routed: r.routed,
                requeued: r.requeued,
                lost_pages: m.lost_pages,
                final_clock: r.coord.now(),
                state: r.state,
            });
        }
        let mut recovery = self.recovery_times.clone();
        recovery.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ttft.sort_by(|a, b| a.partial_cmp(b).unwrap());
        tpot.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ClusterReport {
            replicas: reps,
            tokens,
            requests_completed: completed,
            decode_seconds,
            goodput: if decode_seconds > 0.0 {
                tokens as f64 / decode_seconds
            } else {
                0.0
            },
            makespan,
            ttft_p50: p50(&ttft),
            ttft_p95: p95(&ttft),
            ttft_p99: p99(&ttft),
            tpot_p50: p50(&tpot),
            tpot_p95: p95(&tpot),
            tpot_p99: p99(&tpot),
            spills: self.router.spills,
            spilled_tenants: det::sorted_members(&self.router.spilled),
            migrations: self.router.migrations,
            migrated_tenants: det::sorted_members(&self.router.migrated),
            transfer_seconds,
            scale_ups: self.scale_ups(),
            scale_downs: self.scale_downs(),
            active_replicas: self.active_replica_count(),
            crashes: self.crashes,
            stalls,
            transfer_retries,
            transfers_abandoned,
            failovers,
            reprefilled_tokens,
            lost_pages,
            requeued_requests,
            lost_tokens,
            recovery_p50_s: if recovery.is_empty() { 0.0 } else { p50(&recovery) },
            recovery_p99_s: if recovery.is_empty() { 0.0 } else { p99(&recovery) },
        }
    }
}

/// Run one cluster experiment end to end.
pub fn run_cluster_experiment(params: &ClusterParams) -> Result<ClusterReport> {
    let mut sim = ClusterSim::new(params)?;
    sim.run()?;
    Ok(sim.report())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::ascend_npu;
    use crate::config::model::deepseek_v3;

    fn quick_params(replicas: usize, router: RouterPolicy) -> ClusterParams {
        let mut p = ClusterParams::new(
            deepseek_v3(),
            ascend_npu(),
            replicas,
            router,
            32,
            3,
            1.0,
        );
        p.total_requests = 48;
        p
    }

    #[test]
    fn round_robin_spreads_requests() {
        let mut sim = ClusterSim::new(&quick_params(3, RouterPolicy::RoundRobin)).unwrap();
        sim.run().unwrap();
        let report = sim.report();
        assert_eq!(report.requests_completed as usize, sim.arrivals().len());
        for r in &report.replicas {
            assert!(r.routed > 0, "round-robin leaves no replica idle");
            assert_eq!(r.state, ReplicaLifecycle::Active, "fixed fleets never drain");
        }
        assert!(report.tokens > 0);
        assert!(report.goodput > 0.0);
        assert!(report.makespan > 0.0);
        assert_eq!(report.scale_ups + report.scale_downs, 0);
        assert_eq!(report.active_replicas, 3);
    }

    #[test]
    fn least_loaded_balances_queue_depth() {
        let mut p = quick_params(2, RouterPolicy::LeastLoaded);
        p.arrival_rate = Some(1000.0); // near-simultaneous arrivals
        let mut sim = ClusterSim::new(&p).unwrap();
        sim.run().unwrap();
        let report = sim.report();
        let routed: Vec<u64> = report.replicas.iter().map(|r| r.routed).collect();
        let spread = routed.iter().max().unwrap() - routed.iter().min().unwrap();
        assert!(
            spread * 4 <= *routed.iter().max().unwrap(),
            "least-loaded keeps routing near-even: {routed:?}"
        );
    }

    #[test]
    fn affinity_concentrates_groups() {
        let mut sim =
            ClusterSim::new(&quick_params(3, RouterPolicy::PrefixAffinity)).unwrap();
        sim.run().unwrap();
        for t in 0..3 {
            if !sim.tenant_spilled(t) {
                assert!(
                    sim.replicas_hosting(t) <= 1,
                    "unspilled tenant {t} must stay on one replica"
                );
            }
        }
        // Fewer prefix registrations fleet-wide than round-robin, which
        // pages every group on every replica it touches.
        let hosted: usize = (0..sim.replica_count())
            .map(|i| sim.coordinator(i).prefix_groups().len())
            .sum();
        let mut rr = ClusterSim::new(&quick_params(3, RouterPolicy::RoundRobin)).unwrap();
        rr.run().unwrap();
        let rr_hosted: usize = (0..rr.replica_count())
            .map(|i| rr.coordinator(i).prefix_groups().len())
            .sum();
        assert!(hosted <= rr_hosted, "affinity {hosted} vs round-robin {rr_hosted}");
    }

    #[test]
    fn ttft_tpot_percentiles_populated() {
        let mut sim = ClusterSim::new(&quick_params(2, RouterPolicy::RoundRobin)).unwrap();
        sim.run().unwrap();
        let r = sim.report();
        assert!(r.ttft_p50 >= 0.0 && r.ttft_p50.is_finite());
        assert!(r.ttft_p99 >= r.ttft_p50, "p99 dominates p50");
        assert!(r.tpot_p99 >= r.tpot_p50);
    }

    #[test]
    fn poisson_arrivals_advance_clocks_monotonically() {
        let mut p = quick_params(2, RouterPolicy::LeastLoaded);
        p.arrival_rate = Some(5.0);
        let mut sim = ClusterSim::new(&p).unwrap();
        let mut prev = sim.replica_clocks();
        while sim.step_event().unwrap() {
            let now = sim.replica_clocks();
            for (a, b) in prev.iter().zip(&now) {
                assert!(b >= a, "replica clock went backward: {prev:?} -> {now:?}");
            }
            prev = now;
        }
        assert!(prev.iter().any(|&t| t > 0.0));
    }

    #[test]
    fn router_policy_parse_roundtrip() {
        for p in RouterPolicy::all() {
            assert_eq!(RouterPolicy::parse(p.as_str()).unwrap(), p);
            assert_eq!(RouterPolicy::parse(p.as_str()).unwrap().as_str(), p.as_str());
        }
        assert_eq!(RouterPolicy::parse("rr").unwrap(), RouterPolicy::RoundRobin);
        assert_eq!(RouterPolicy::parse("ll").unwrap(), RouterPolicy::LeastLoaded);
        assert_eq!(
            RouterPolicy::parse("affinity").unwrap(),
            RouterPolicy::PrefixAffinity
        );
        let err = RouterPolicy::parse("random").unwrap_err().to_string();
        assert!(
            err.contains("round-robin|least-loaded|prefix-affinity"),
            "{err}"
        );
        assert!(RouterPolicy::parse("RR").is_err(), "matching is exact");
    }

    /// A pressured single-tenant fleet with migration enabled re-homes
    /// the hot group instead of scattering requests; the adoption never
    /// re-prefills and retired copies drain to zero replicas.
    #[test]
    fn migration_rehomes_hot_group_without_reprefill() {
        let mut p = ClusterParams::new(
            deepseek_v3(),
            ascend_npu(),
            2,
            RouterPolicy::PrefixAffinity,
            8,
            1,
            0.0,
        );
        p.total_requests = 32;
        p.spill_queue_depth = 1; // queue depth 1 already counts as pressure
        p.migrate = true;
        let mut sim = ClusterSim::new(&p).unwrap();
        sim.run().unwrap();
        assert!(sim.migrations() > 0, "tight threshold must trigger migration");
        assert!(sim.tenant_migrated(0));
        for e in sim.migration_log() {
            assert_eq!(
                e.dst_prefills_before, e.dst_prefills_after,
                "destination must adopt, never re-prefill"
            );
        }
        assert!(sim.retired_copies_released(), "drained copies release their pages");
        if !sim.tenant_spilled_since_migration(0) {
            assert_eq!(sim.replicas_hosting(0), 1, "pages on exactly one replica");
        }
        let report = sim.report();
        assert_eq!(report.requests_completed, 32, "migrated group still serves");
        assert_eq!(report.migrations, sim.migrations());
        assert!(report.transfer_seconds > 0.0, "page moves charge the interconnect");
    }

    /// Migration machinery that never fires changes nothing: with a
    /// loose pressure threshold the migrate-enabled run is
    /// bit-identical to the spill-only run (the PR 3 reduction pin).
    #[test]
    fn migrate_flag_without_pressure_is_bit_identical() {
        let p = quick_params(3, RouterPolicy::PrefixAffinity); // loose depth
        let mut a = ClusterSim::new(&p).unwrap();
        a.run().unwrap();
        let mut m = p.clone();
        m.migrate = true;
        let mut b = ClusterSim::new(&m).unwrap();
        b.run().unwrap();
        assert_eq!(a.spills(), 0, "loose threshold never pressures");
        assert_eq!(b.migrations(), 0);
        let (ra, rb) = (a.report(), b.report());
        assert_eq!(ra.decode_seconds.to_bits(), rb.decode_seconds.to_bits());
        assert_eq!(ra.makespan.to_bits(), rb.makespan.to_bits());
        assert_eq!(ra.tokens, rb.tokens);
    }

    /// A slow interconnect confines migration to free re-homes: fresh
    /// destinations lose the cost comparison (their overflow spills
    /// instead), so every recorded migration is a residency
    /// consolidation with zero transfer seconds — and zero cool-down
    /// (nothing to amortize).
    #[test]
    fn slow_interconnect_migrations_are_free_consolidations_only() {
        let mut p = quick_params(3, RouterPolicy::PrefixAffinity);
        p.spill_queue_depth = 1;
        p.migrate = true;
        p.hw.interconnect_bw = 1e-3; // fresh transfers never pay off
        let mut sim = ClusterSim::new(&p).unwrap();
        sim.run().unwrap();
        assert!(sim.spills() > 0, "fresh destinations must spill on a slow link");
        for e in sim.migration_log() {
            assert_eq!(e.transfer_seconds, 0.0, "only resident peers re-home");
            assert_eq!(e.cooldown_tokens, 0, "free re-homes amortize instantly");
        }
        assert_eq!(sim.report().transfer_seconds, 0.0);
    }

    /// SLO-driven admission: a tight TTFT target spills under load that
    /// a loose fixed queue-depth threshold would absorb.
    #[test]
    fn slo_target_tightens_the_spill_threshold() {
        let mut p = quick_params(2, RouterPolicy::PrefixAffinity);
        p.tenants = 1;
        p.arrival_rate = Some(500.0);
        p.spill_queue_depth = 10_000; // fixed trigger never fires
        let mut fixed = ClusterSim::new(&p).unwrap();
        fixed.run().unwrap();
        assert_eq!(fixed.spills(), 0, "loose fixed threshold never spills");

        p.slo_ttft = Some(1e-6);
        let mut slo = ClusterSim::new(&p).unwrap();
        slo.run().unwrap();
        assert!(
            slo.spills() > 0,
            "a tight TTFT target must shed load the fixed threshold ignored"
        );
    }

    /// Nonsense TTFT targets are configuration errors, and
    /// migration/SLO/autoscale flags on routers that never consult
    /// them are rejected instead of silently ignored.
    #[test]
    fn invalid_slo_target_rejected() {
        let mut p = quick_params(1, RouterPolicy::PrefixAffinity);
        p.slo_ttft = Some(0.0);
        assert!(ClusterSim::new(&p).is_err());
        p.slo_ttft = Some(f64::NAN);
        assert!(ClusterSim::new(&p).is_err());

        let mut p = quick_params(2, RouterPolicy::LeastLoaded);
        p.migrate = true;
        assert!(ClusterSim::new(&p).is_err(), "migrate needs prefix-affinity");
        let mut p = quick_params(2, RouterPolicy::RoundRobin);
        p.slo_ttft = Some(0.5);
        assert!(ClusterSim::new(&p).is_err(), "slo-ttft needs prefix-affinity");
        let mut p = quick_params(2, RouterPolicy::LeastLoaded);
        p.scaling.enabled = true;
        assert!(ClusterSim::new(&p).is_err(), "autoscale needs prefix-affinity");
    }

    /// Nonsense scaling shapes and burst profiles are configuration
    /// errors too.
    #[test]
    fn invalid_scaling_and_burst_rejected() {
        let mut p = quick_params(2, RouterPolicy::PrefixAffinity);
        p.scaling.enabled = true;
        p.scaling.headroom = 0.0;
        assert!(ClusterSim::new(&p).is_err(), "headroom must be positive");
        let mut p = quick_params(2, RouterPolicy::PrefixAffinity);
        p.scaling.enabled = true;
        p.scaling.max_replicas = 1;
        assert!(ClusterSim::new(&p).is_err(), "cap below the starting fleet");
        let mut p = quick_params(2, RouterPolicy::PrefixAffinity);
        p.arrival_burst = Some(8.0);
        assert!(ClusterSim::new(&p).is_err(), "burst needs an arrival rate");
        p.arrival_rate = Some(50.0);
        p.arrival_burst = Some(0.5);
        assert!(ClusterSim::new(&p).is_err(), "burst factor below one");
        p.arrival_burst = Some(8.0);
        ClusterSim::new(&p).unwrap();
    }

    #[test]
    fn zero_replicas_rejected() {
        let mut p = quick_params(1, RouterPolicy::RoundRobin);
        p.replicas = 0;
        assert!(ClusterSim::new(&p).is_err());
    }

    /// Bad TP/SP/rate configurations surface as errors, not panics
    /// deep inside the cost model.
    #[test]
    fn invalid_parallelism_and_rate_rejected() {
        let mut p = quick_params(1, RouterPolicy::RoundRobin);
        p.parallelism = ParallelismConfig { tp: 0, sp: 1 };
        assert!(ClusterSim::new(&p).is_err(), "tp = 0 rejected");
        p.parallelism = ParallelismConfig { tp: 7, sp: 1 }; // 7 does not divide H
        assert!(ClusterSim::new(&p).is_err(), "tp must divide heads");
        p.parallelism = ParallelismConfig::single();
        p.arrival_rate = Some(0.0);
        assert!(ClusterSim::new(&p).is_err(), "rate must be positive");
    }

    /// A draining replica refuses migration imports: the transfer is
    /// refused cleanly (nothing moves, no pages land, stickiness stays
    /// put) and the same migration completes once the destination is
    /// active again — the drain-while-migrating regression.
    #[test]
    fn draining_replica_refuses_migration_imports() {
        let mut p = quick_params(2, RouterPolicy::PrefixAffinity);
        p.migrate = true;
        let mut sim = ClusterSim::new(&p).unwrap();
        while sim.replicas_hosting(0) == 0 {
            assert!(sim.step_event().unwrap(), "tenant 0 must arrive before drain");
        }
        let home = *sim.router.home.get(&0).unwrap();
        let other = 1 - home;
        sim.replicas[other].state = ReplicaLifecycle::Draining;
        let groups_before = sim.coordinator(other).prefix_groups().len();
        let moved = sim.migrate_group(0, home, other, 0.0, 0).unwrap();
        assert!(!moved, "draining destination must refuse the import");
        assert_eq!(sim.coordinator(other).prefix_groups().len(), groups_before);
        assert_eq!(sim.router.home.get(&0), Some(&home), "stickiness unchanged");
        assert_eq!(sim.migrations(), 0, "a refused migration is not a migration");
        sim.replicas[other].state = ReplicaLifecycle::Active;
        let moved = sim.migrate_group(0, home, other, 0.0, 0).unwrap();
        assert!(moved, "the re-issued migration completes on an active destination");
        assert_eq!(sim.router.home.get(&0), Some(&other));
    }

    /// Fault smoke: a mid-stream crash on a two-replica fleet destroys
    /// pages and re-queues in-flight work, yet every request completes
    /// and the dead replica ends at zero live pages.
    #[test]
    fn crash_failover_requeues_and_completes_everything() {
        let mut p = quick_params(2, RouterPolicy::PrefixAffinity);
        p.total_requests = 64;
        p.migrate = true;
        p.faults.enabled = true;
        p.faults.seed = 9;
        p.faults.crashes = 1;
        let mut sim = ClusterSim::new(&p).unwrap();
        sim.run().unwrap();
        assert_eq!(sim.crashes(), 1, "the scheduled crash must fire");
        let report = sim.report();
        assert_eq!(report.crashes, 1);
        assert_eq!(
            report.requests_completed as usize,
            sim.arrivals().len(),
            "every request completes exactly once despite the crash"
        );
        let failed: Vec<usize> = (0..sim.replica_count())
            .filter(|&i| sim.replica_state(i) == ReplicaLifecycle::Failed)
            .collect();
        assert_eq!(failed.len(), 1);
        assert_eq!(
            sim.coordinator(failed[0]).kv.used_blocks(),
            0,
            "a failed replica must end at zero live pages"
        );
        assert!(report.lost_pages > 0, "the crash destroyed live pages");
        assert!(report.failovers > 0, "the dead home must fail over");
        assert_eq!(report.active_replicas, 1);
        assert_eq!(report.recovery_p50_s, report.recovery_p99_s, "one sample");
        assert!(
            report.recovery_p50_s >= sim.policy.recovery.crash_timeout,
            "recovery includes the detection timeout"
        );
    }

    /// Autoscale smoke: an over-provisioned fleet on a calm stream
    /// consolidates (scale-downs fire, victims drain to zero pages and
    /// retire), every request still completes, and the retired
    /// replicas stay in the report.
    #[test]
    fn autoscale_consolidates_an_overprovisioned_fleet() {
        let mut p = ClusterParams::new(
            deepseek_v3(),
            ascend_npu(),
            3,
            RouterPolicy::PrefixAffinity,
            16,
            3,
            1.0,
        );
        p.total_requests = 256;
        p.arrival_rate = Some(40.0); // far below fleet capacity
        p.migrate = true;
        p.scaling.enabled = true;
        p.scaling.cooldown_arrivals = 32;
        let mut sim = ClusterSim::new(&p).unwrap();
        sim.run().unwrap();
        let report = sim.report();
        assert_eq!(report.requests_completed as usize, sim.arrivals().len());
        assert!(report.scale_downs > 0, "calm stream must consolidate the fleet");
        assert!(report.active_replicas < 3, "a replica must have retired");
        assert_eq!(report.active_replicas, sim.active_replica_count());
        for i in 0..sim.replica_count() {
            if sim.replica_state(i) != ReplicaLifecycle::Active {
                assert_eq!(
                    sim.replica_state(i),
                    ReplicaLifecycle::Retired,
                    "victims finish draining by the end of the run"
                );
                assert_eq!(
                    sim.coordinator(i).kv.used_blocks(),
                    0,
                    "decommissioned replica {i} must hold zero pages"
                );
            }
        }
        assert!(sim.retired_copies_released());
    }

    /// EventHeap invariants: re-key (decrease- and increase-key),
    /// lifecycle exits, tie ordering, and scale-up growth — the
    /// operations every replica mutation site performs via
    /// `sync_replica`.
    #[test]
    fn event_heap_rekeys_and_survives_lifecycle_exits() {
        let mut h = EventHeap::new(3);
        assert_eq!(h.earliest(), None, "empty heap has no busy replica");
        h.update(0, 5.0, true);
        h.update(1, 3.0, true);
        h.update(2, 9.0, true);
        assert_eq!(h.earliest(), Some((1, 3.0)));
        // Decrease-key: replica 2 jumps to the front.
        h.update(2, 1.0, true);
        assert_eq!(h.earliest(), Some((2, 1.0)));
        // Increase-key: it falls behind again.
        h.update(2, 7.0, true);
        assert_eq!(h.earliest(), Some((1, 3.0)));
        // Ties resolve to the lowest replica index — the linear scan's
        // order.
        h.update(0, 3.0, true);
        assert_eq!(h.earliest(), Some((0, 3.0)));
        // Lifecycle exits (going idle, Draining with no work, Failed,
        // Retired all sync as not-busy): the replica leaves the heap
        // without touching the others.
        h.update(0, 3.0, false);
        h.update(1, 3.0, false);
        assert_eq!(h.earliest(), Some((2, 7.0)));
        h.update(2, 7.0, false);
        assert_eq!(h.earliest(), None);
        // Scale-up: a fresh slot keys in like any other.
        h.grow();
        h.update(3, 2.0, true);
        assert_eq!(h.earliest(), Some((3, 2.0)));
    }

    /// Lazy invalidation stays bounded: a long run of re-keys on a
    /// two-replica heap compacts instead of accumulating one stale
    /// entry per decode step.
    #[test]
    fn event_heap_compacts_stale_generations() {
        let mut h = EventHeap::new(2);
        for k in 0..10_000u64 {
            h.update(0, k as f64, true);
            h.update(1, (k + 1) as f64, true);
        }
        assert!(
            h.entries.len() <= 2 * h.stamp.len() + 64 + 1,
            "stale generations must be compacted away, got {} entries",
            h.entries.len()
        );
        assert_eq!(h.earliest(), Some((0, 9_999.0)));
    }

    /// LoadIndex orders Active replicas by (load, index) and forgets
    /// replicas on lifecycle exit, matching the linear scan's tie
    /// order.
    #[test]
    fn load_index_orders_active_replicas() {
        let mut x = LoadIndex::new(3);
        assert_eq!(x.least_loaded_except(None), Some(0), "all-zero ties pick lowest");
        x.set(0, 4);
        x.set(1, 2);
        x.set(2, 2);
        assert_eq!(x.least_loaded_except(None), Some(1));
        assert_eq!(x.least_loaded_except(Some(1)), Some(2));
        x.remove(1); // lifecycle exit
        assert_eq!(x.least_loaded_except(None), Some(2));
        x.grow(); // scale-up: un-indexed until the first sync
        assert_eq!(x.least_loaded_except(None), Some(2));
        x.set(3, 0);
        assert_eq!(x.least_loaded_except(None), Some(3));
        x.remove(2);
        x.remove(3);
        assert_eq!(x.least_loaded_except(None), Some(0));
        x.remove(0);
        assert_eq!(x.least_loaded_except(None), None, "no active replica left");
    }

    /// `run_parallel` is byte-identical to the serial event loop on a
    /// bursty autoscaling + migration cell — the richest fixed-seed
    /// configuration (resizes, re-homes and timed arrivals all in
    /// play).  The fuzz suite widens this across random draws.
    #[test]
    fn parallel_stepping_bit_identical_to_serial() {
        let mut p = ClusterParams::new(
            deepseek_v3(),
            ascend_npu(),
            2,
            RouterPolicy::PrefixAffinity,
            16,
            3,
            1.0,
        );
        p.total_requests = 192;
        p.arrival_rate = Some(60.0);
        p.arrival_burst = Some(6.0);
        p.migrate = true;
        p.scaling.enabled = true;
        p.scaling.cooldown_arrivals = 24;
        let mut serial = ClusterSim::new(&p).unwrap();
        serial.run().unwrap();
        let mut par = ClusterSim::new(&p).unwrap();
        par.run_parallel().unwrap();
        let (a, b) = (serial.report(), par.report());
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.requests_completed, b.requests_completed);
        assert_eq!(a.decode_seconds.to_bits(), b.decode_seconds.to_bits());
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.goodput.to_bits(), b.goodput.to_bits());
        assert_eq!(a.ttft_p99.to_bits(), b.ttft_p99.to_bits());
        assert_eq!(a.spills, b.spills);
        assert_eq!(a.migrations, b.migrations);
        assert_eq!(a.scale_ups, b.scale_ups);
        assert_eq!(a.scale_downs, b.scale_downs);
        assert_eq!(serial.events_processed(), par.events_processed());
        assert_eq!(serial.arena_peak(), par.arena_peak());
    }

    /// The persistent-pool dispatcher is byte-identical to the retained
    /// scoped-spawn reference on the same rich cell, and only the
    /// pooled run counts pool windows.  The fuzz suite
    /// (`tests/pricing_pool.rs`) widens this across random draws.
    #[test]
    fn pooled_dispatch_bit_identical_to_spawn_reference() {
        let mut p = ClusterParams::new(
            deepseek_v3(),
            ascend_npu(),
            2,
            RouterPolicy::PrefixAffinity,
            16,
            3,
            1.0,
        );
        p.total_requests = 192;
        p.arrival_rate = Some(60.0);
        p.arrival_burst = Some(6.0);
        p.migrate = true;
        p.scaling.enabled = true;
        p.scaling.cooldown_arrivals = 24;
        let mut pooled = ClusterSim::new(&p).unwrap();
        pooled.run_parallel().unwrap();
        let mut spawned = ClusterSim::new(&p).unwrap();
        spawned.use_spawn_reference(true);
        spawned.run_parallel().unwrap();
        assert!(pooled.pool_windows() > 0, "the pooled run must use the pool");
        assert_eq!(spawned.pool_windows(), 0, "the reference never does");
        let (a, b) = (pooled.report(), spawned.report());
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.requests_completed, b.requests_completed);
        assert_eq!(a.decode_seconds.to_bits(), b.decode_seconds.to_bits());
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.goodput.to_bits(), b.goodput.to_bits());
        assert_eq!(a.ttft_p99.to_bits(), b.ttft_p99.to_bits());
        assert_eq!(a.spills, b.spills);
        assert_eq!(a.migrations, b.migrations);
        assert_eq!(a.scale_ups, b.scale_ups);
        assert_eq!(a.scale_downs, b.scale_downs);
        assert_eq!(pooled.events_processed(), spawned.events_processed());
        assert_eq!(pooled.arena_peak(), spawned.arena_peak());
    }

    /// The fleet prices through ONE surface: every replica engine and
    /// the policy engine hold the same Arc, and a finished run shows a
    /// warm cache (hits recorded fleet-wide, not per-replica cold
    /// memos).
    #[test]
    fn fleet_shares_one_price_surface() {
        let mut sim = ClusterSim::new(&quick_params(3, RouterPolicy::RoundRobin)).unwrap();
        for i in 0..sim.replica_count() {
            assert!(
                Arc::ptr_eq(sim.coordinator(i).engine.surface(), &sim.surface),
                "replica {i} must adopt the fleet surface"
            );
        }
        assert!(Arc::ptr_eq(sim.policy.surface(), &sim.surface));
        sim.run().unwrap();
        let (hits, misses) = sim.price_cache_stats();
        assert!(misses > 0, "the run must price something");
        assert!(
            hits > misses,
            "a shared warm cache mostly hits: {hits} hits vs {misses} misses"
        );
    }
}
