//! Paged KV-cache with radix-tree prefix sharing (the PagedAttention /
//! RadixAttention substrate) plus TyphoonMLA's uncompressed
//! shared-prefix expansion accounting.

pub mod block;
pub mod manager;
pub mod radix;

pub use block::{BlockAllocator, BlockId, BlockTable};
pub use manager::{KvCacheManager, PrefixExport, PrefixId, SeqId, SharedPrefix};
pub use radix::{spans_from_pages, spans_from_per_token, MatchResult, PageSpan, RadixTree};
