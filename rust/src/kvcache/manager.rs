//! The KV-cache manager: paged latent cache for per-sequence suffixes,
//! shared-prefix registry with radix-tree reuse, and TyphoonMLA's
//! uncompressed shared-prefix expansion accounting.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use crate::config::ModelConfig;

use super::block::{BlockAllocator, BlockId, BlockTable};
use super::radix::{spans_from_pages, PageSpan, RadixTree};

pub type SeqId = u64;
pub type PrefixId = u32;

/// A registered shared prefix (e.g. one tenant's system prompt).
#[derive(Debug)]
pub struct SharedPrefix {
    pub id: PrefixId,
    pub tokens: Vec<u32>,
    /// Latent-form pages (always present).
    pub latent_blocks: Vec<BlockId>,
    /// TyphoonMLA: uncompressed K/V copy exists (naive-stage cache).
    pub expanded: bool,
    /// Uncompressed expansion bytes held for *this* prefix (0 until
    /// `expand_shared_prefix`; per-group accounting for the tenancy
    /// layer — the manager-wide total is the sum over prefixes).
    pub expanded_bytes: u64,
    /// Active (admitted) sequences attached to this prefix.
    pub users: usize,
    /// Submitted-but-not-admitted sequences of this prefix's group
    /// (queued or preempted-for-recompute).  Pinned via `pin_pending`;
    /// the prefix cannot be released while `users + pending > 0`.
    pub pending: usize,
}

impl SharedPrefix {
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

/// A prefix group's cache content packaged for cross-replica
/// migration: `tokens` identify the radix run (and size the transfer),
/// `expanded` says whether the uncompressed naive-stage copy travels
/// too, and `spans` records the source page layout for audits and
/// span-count diagnostics — the importer allocates its own pages.
#[derive(Clone, Debug)]
pub struct PrefixExport {
    pub tokens: Vec<u32>,
    pub expanded: bool,
    /// Source-side page spans covering `tokens`.
    pub spans: Vec<PageSpan>,
}

impl PrefixExport {
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

/// Per-sequence cache state: the non-shared suffix in latent form.
#[derive(Debug)]
pub struct SequenceCache {
    pub prefix: PrefixId,
    pub table: BlockTable,
}

#[derive(Debug)]
pub struct KvCacheManager {
    cfg: ModelConfig,
    alloc: BlockAllocator,
    radix: RadixTree,
    prefixes: HashMap<PrefixId, SharedPrefix>,
    /// Per-sequence suffix caches, indexed directly by the dense
    /// `SeqId` (the coordinator's arena recycles ids, so this slab is
    /// bounded by the highest outstanding id — and `append_token`, the
    /// per-token hot path, indexes instead of hashing).
    seqs: Vec<Option<SequenceCache>>,
    /// Number of occupied `seqs` slots.
    active: usize,
    next_prefix: PrefixId,
    /// Bytes of uncompressed expansion currently held (the "3%").
    /// Tracked outside the block pool: expansion is ≈71x denser than
    /// latent pages, so it gets dedicated accounting, not pool pages.
    expanded_bytes: u64,
    bytes_per_elem: u64,
}

impl KvCacheManager {
    pub fn new(cfg: ModelConfig, total_blocks: usize, block_size: usize) -> Self {
        KvCacheManager {
            cfg,
            alloc: BlockAllocator::new(total_blocks, block_size),
            radix: RadixTree::new(),
            prefixes: HashMap::new(),
            seqs: Vec::new(),
            active: 0,
            next_prefix: 0,
            expanded_bytes: 0,
            bytes_per_elem: 2,
        }
    }

    pub fn block_size(&self) -> usize {
        self.alloc.block_size()
    }

    pub fn free_blocks(&self) -> usize {
        self.alloc.free_blocks()
    }

    pub fn used_blocks(&self) -> usize {
        self.alloc.used_blocks()
    }

    pub fn active_sequences(&self) -> usize {
        self.active
    }

    // ---- shared prefixes --------------------------------------------------

    /// Register a shared prefix.  If a (block-aligned) prefix of the
    /// tokens is already cached, its pages are reused; only the new tail
    /// is allocated.
    pub fn register_shared_prefix(&mut self, tokens: &[u32]) -> Result<PrefixId> {
        let bs = self.block_size();
        let m = self.radix.match_prefix(tokens);
        // Reuse only whole matched pages (block-aligned token count).
        let reuse_tokens = (m.matched / bs) * bs;
        let reused = m.pages_for_tokens(reuse_tokens);
        let fresh_tokens = tokens.len() - reuse_tokens;
        let need_blocks = fresh_tokens.div_ceil(bs);
        if !self.alloc.can_allocate(need_blocks) {
            bail!("cannot register prefix: need {need_blocks} blocks");
        }
        for &b in &reused {
            self.alloc.retain(b);
        }
        let fresh = self.alloc.allocate_n(need_blocks)?;
        // Page spans for the radix tree: the reused run layout as
        // matched, then block-aligned spans over the fresh pages.
        let mut spans: Vec<PageSpan> = Vec::with_capacity(reused.len() + fresh.len());
        {
            let mut left = reuse_tokens;
            for s in &m.spans {
                if left == 0 {
                    break;
                }
                let take = (s.tokens as usize).min(left);
                spans.push(PageSpan::new(s.page, take));
                left -= take;
            }
        }
        spans.extend(spans_from_pages(&fresh, fresh_tokens, bs));
        let mut blocks = reused;
        blocks.extend(&fresh);
        self.radix.insert(tokens, &spans);
        self.radix.pin(tokens);
        let id = self.next_prefix;
        self.next_prefix += 1;
        self.prefixes.insert(
            id,
            SharedPrefix {
                id,
                tokens: tokens.to_vec(),
                latent_blocks: blocks,
                expanded: false,
                expanded_bytes: 0,
                users: 0,
                pending: 0,
            },
        );
        Ok(id)
    }

    /// TyphoonMLA expansion: materialize the uncompressed K/V copy of a
    /// shared prefix.  Returns the extra bytes held (0 if already done).
    pub fn expand_shared_prefix(&mut self, id: PrefixId) -> Result<u64> {
        let words = self.cfg.uncompressed_words();
        let bpe = self.bytes_per_elem;
        let p = self
            .prefixes
            .get_mut(&id)
            .ok_or_else(|| anyhow!("unknown prefix {id}"))?;
        if p.expanded {
            return Ok(0);
        }
        p.expanded = true;
        let bytes = p.tokens.len() as u64 * words * bpe;
        p.expanded_bytes = bytes;
        self.expanded_bytes += bytes;
        let tokens = p.tokens.clone();
        self.radix.mark_expanded(&tokens);
        Ok(bytes)
    }

    pub fn prefix(&self, id: PrefixId) -> Option<&SharedPrefix> {
        self.prefixes.get(&id)
    }

    /// Package a prefix group for migration to a peer replica: tokens,
    /// expansion state, and the source page-span layout from the radix
    /// tree.
    pub fn export_prefix(&self, id: PrefixId) -> Result<PrefixExport> {
        let p = self
            .prefixes
            .get(&id)
            .ok_or_else(|| anyhow!("unknown prefix {id}"))?;
        let spans = self
            .radix
            .export_spans(&p.tokens)
            .ok_or_else(|| anyhow!("prefix {id} is not fully resident in the radix tree"))?;
        Ok(PrefixExport { tokens: p.tokens.clone(), expanded: p.expanded, spans })
    }

    /// Destination side of a migration: install the exported group on
    /// freshly allocated local pages (the KV payload arrives over the
    /// interconnect; an identical run already cached here is reused via
    /// the radix tree, exactly like registration).  No prefill is
    /// implied — that is the whole point of migrating.
    pub fn import_prefix(&mut self, export: &PrefixExport) -> Result<PrefixId> {
        let id = self.register_shared_prefix(&export.tokens)?;
        if export.expanded {
            self.expand_shared_prefix(id)?;
        }
        Ok(id)
    }

    /// Number of registered shared prefixes (prefix groups).
    pub fn registered_prefixes(&self) -> usize {
        self.prefixes.len()
    }

    /// Pin a prefix for a submitted-but-not-admitted sequence of its
    /// group.  Balanced by `unpin_pending` at admission (or release of
    /// the request).  While pinned, `release_shared_prefix` refuses.
    pub fn pin_pending(&mut self, id: PrefixId) -> Result<()> {
        let p = self
            .prefixes
            .get_mut(&id)
            .ok_or_else(|| anyhow!("unknown prefix {id}"))?;
        p.pending += 1;
        Ok(())
    }

    /// Drop one pending pin (the sequence was admitted or abandoned).
    pub fn unpin_pending(&mut self, id: PrefixId) -> Result<()> {
        let p = self
            .prefixes
            .get_mut(&id)
            .ok_or_else(|| anyhow!("unknown prefix {id}"))?;
        if p.pending == 0 {
            bail!("prefix {id}: unbalanced unpin_pending");
        }
        p.pending -= 1;
        Ok(())
    }

    /// Bytes of uncompressed expansion currently held (all prefixes).
    pub fn expanded_bytes(&self) -> u64 {
        self.expanded_bytes
    }

    /// Uncompressed expansion bytes held for one prefix group.
    pub fn prefix_expanded_bytes(&self, id: PrefixId) -> u64 {
        self.prefixes.get(&id).map_or(0, |p| p.expanded_bytes)
    }

    /// Bytes of latent KV currently held in pages.
    pub fn latent_bytes(&self) -> u64 {
        (self.used_blocks() * self.block_size()) as u64
            * self.cfg.latent_words()
            * self.bytes_per_elem
    }

    /// The paper's HBM-overhead ratio for the current state.
    pub fn expansion_overhead(&self) -> f64 {
        let base = self.latent_bytes();
        if base == 0 {
            0.0
        } else {
            self.expanded_bytes as f64 / base as f64
        }
    }

    /// Release a prefix group's pages.  Refuses while the group has any
    /// live sequence — admitted (`users`) *or* queued/preempted
    /// (`pending`) — so eviction storms can never free a prefix out
    /// from under its tenants.
    pub fn release_shared_prefix(&mut self, id: PrefixId) -> Result<()> {
        let p = self
            .prefixes
            .remove(&id)
            .ok_or_else(|| anyhow!("unknown prefix {id}"))?;
        if p.users > 0 || p.pending > 0 {
            let msg = format!(
                "prefix {id} still has {} admitted + {} queued sequences",
                p.users, p.pending
            );
            self.prefixes.insert(id, p);
            bail!(msg);
        }
        for &b in &p.latent_blocks {
            self.alloc.release(b);
        }
        self.radix.unpin(&p.tokens);
        if p.expanded {
            self.expanded_bytes -= p.expanded_bytes;
        }
        Ok(())
    }

    // ---- sequences ---------------------------------------------------------

    /// Would a new sequence with `prompt_tokens` non-shared tokens fit?
    pub fn can_admit(&self, prompt_tokens: usize) -> bool {
        self.alloc.can_allocate(self.alloc.blocks_for(prompt_tokens.max(1)))
    }

    /// Attach a sequence to a shared prefix and reserve pages for its
    /// non-shared prompt suffix.
    pub fn add_sequence(
        &mut self,
        seq: SeqId,
        prefix: PrefixId,
        prompt_tokens: usize,
    ) -> Result<()> {
        let i = seq as usize;
        if i >= self.seqs.len() {
            self.seqs.resize_with(i + 1, || None);
        }
        if self.seqs[i].is_some() {
            bail!("sequence {seq} already exists");
        }
        let p = self
            .prefixes
            .get_mut(&prefix)
            .ok_or_else(|| anyhow!("unknown prefix {prefix}"))?;
        p.users += 1;
        let mut table = BlockTable::default();
        if let Err(e) = table.reserve(prompt_tokens.max(1), &mut self.alloc) {
            table.release_all(&mut self.alloc);
            self.prefixes.get_mut(&prefix).unwrap().users -= 1;
            return Err(e);
        }
        table.len = prompt_tokens;
        self.seqs[i] = Some(SequenceCache { prefix, table });
        self.active += 1;
        Ok(())
    }

    /// Append one generated token to a sequence (may allocate a page).
    pub fn append_token(&mut self, seq: SeqId) -> Result<()> {
        let s = self
            .seqs
            .get_mut(seq as usize)
            .and_then(|s| s.as_mut())
            .ok_or_else(|| anyhow!("unknown sequence {seq}"))?;
        s.table.append_token(&mut self.alloc)
    }

    pub fn sequence_len(&self, seq: SeqId) -> Option<usize> {
        self.seqs.get(seq as usize).and_then(|s| s.as_ref()).map(|s| s.table.len)
    }

    /// Remove a finished/cancelled sequence, releasing its pages.
    pub fn remove_sequence(&mut self, seq: SeqId) -> Result<()> {
        let mut s = self
            .seqs
            .get_mut(seq as usize)
            .and_then(|s| s.take())
            .ok_or_else(|| anyhow!("unknown sequence {seq}"))?;
        self.active -= 1;
        s.table.release_all(&mut self.alloc);
        if let Some(p) = self.prefixes.get_mut(&s.prefix) {
            p.users -= 1;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::sim;

    fn mgr(blocks: usize) -> KvCacheManager {
        KvCacheManager::new(sim(), blocks, 16)
    }

    fn prefix_tokens(n: usize) -> Vec<u32> {
        (0..n as u32).collect()
    }

    #[test]
    fn prefix_registration_allocates_pages() {
        let mut m = mgr(16);
        let id = m.register_shared_prefix(&prefix_tokens(40)).unwrap();
        let p = m.prefix(id).unwrap();
        assert_eq!(p.len(), 40);
        assert_eq!(p.latent_blocks.len(), 3); // ceil(40/16)
        assert_eq!(m.used_blocks(), 3);
    }

    #[test]
    fn identical_prefix_reuses_blocks() {
        let mut m = mgr(16);
        let a = m.register_shared_prefix(&prefix_tokens(32)).unwrap();
        let used = m.used_blocks();
        let b = m.register_shared_prefix(&prefix_tokens(32)).unwrap();
        assert_eq!(m.used_blocks(), used, "radix hit: no new pages");
        assert_eq!(
            m.prefix(a).unwrap().latent_blocks,
            m.prefix(b).unwrap().latent_blocks
        );
    }

    #[test]
    fn extended_prefix_reuses_aligned_overlap() {
        let mut m = mgr(16);
        let a = m.register_shared_prefix(&prefix_tokens(32)).unwrap(); // 2 pages
        let b = m.register_shared_prefix(&prefix_tokens(48)).unwrap(); // +1 page
        assert_eq!(m.used_blocks(), 3);
        assert_eq!(
            m.prefix(b).unwrap().latent_blocks[..2],
            m.prefix(a).unwrap().latent_blocks[..]
        );
    }

    #[test]
    fn expansion_accounting_matches_cost_model() {
        let mut m = mgr(64);
        let id = m.register_shared_prefix(&prefix_tokens(64)).unwrap();
        let bytes = m.expand_shared_prefix(id).unwrap();
        let cfg = sim();
        assert_eq!(bytes, 64 * cfg.uncompressed_words() * 2);
        assert_eq!(m.expanded_bytes(), bytes);
        // Idempotent.
        assert_eq!(m.expand_shared_prefix(id).unwrap(), 0);
    }

    #[test]
    fn sequence_lifecycle_conserves_blocks() {
        let mut m = mgr(32);
        let id = m.register_shared_prefix(&prefix_tokens(16)).unwrap();
        let base = m.used_blocks();
        m.add_sequence(1, id, 20).unwrap();
        m.add_sequence(2, id, 5).unwrap();
        assert_eq!(m.used_blocks(), base + 2 + 1);
        for _ in 0..12 {
            m.append_token(1).unwrap();
        }
        assert_eq!(m.sequence_len(1), Some(32));
        assert_eq!(m.used_blocks(), base + 2 + 1); // 32 tokens = 2 pages exactly
        m.append_token(1).unwrap(); // 33rd token: new page
        assert_eq!(m.used_blocks(), base + 3 + 1);
        m.remove_sequence(1).unwrap();
        m.remove_sequence(2).unwrap();
        assert_eq!(m.used_blocks(), base);
    }

    #[test]
    fn admission_control() {
        let mut m = mgr(4);
        let id = m.register_shared_prefix(&prefix_tokens(32)).unwrap(); // 2 pages
        assert!(m.can_admit(32)); // 2 pages free
        assert!(!m.can_admit(33)); // would need 3
        m.add_sequence(1, id, 32).unwrap();
        assert!(!m.can_admit(1));
        assert!(m.append_token(1).is_err(), "pool exhausted is an error");
    }

    #[test]
    fn cannot_release_prefix_in_use() {
        let mut m = mgr(8);
        let id = m.register_shared_prefix(&prefix_tokens(8)).unwrap();
        m.add_sequence(1, id, 4).unwrap();
        assert!(m.release_shared_prefix(id).is_err());
        m.remove_sequence(1).unwrap();
        m.release_shared_prefix(id).unwrap();
    }

    #[test]
    fn pending_pins_block_release() {
        let mut m = mgr(8);
        let id = m.register_shared_prefix(&prefix_tokens(8)).unwrap();
        m.pin_pending(id).unwrap();
        assert!(m.release_shared_prefix(id).is_err(), "queued sequence pins pages");
        m.unpin_pending(id).unwrap();
        assert!(m.unpin_pending(id).is_err(), "unbalanced unpin rejected");
        m.release_shared_prefix(id).unwrap();
        assert!(m.pin_pending(id).is_err(), "released prefix unknown");
    }

    #[test]
    fn per_prefix_expansion_accounting() {
        let mut m = mgr(64);
        let a = m.register_shared_prefix(&prefix_tokens(32)).unwrap();
        let b = m.register_shared_prefix(&(100..164u32).collect::<Vec<_>>()).unwrap();
        let ba = m.expand_shared_prefix(a).unwrap();
        let bb = m.expand_shared_prefix(b).unwrap();
        assert!(ba > 0 && bb == 2 * ba, "64 vs 32 tokens");
        assert_eq!(m.prefix_expanded_bytes(a), ba);
        assert_eq!(m.prefix_expanded_bytes(b), bb);
        assert_eq!(m.expanded_bytes(), ba + bb);
        m.release_shared_prefix(a).unwrap();
        assert_eq!(m.expanded_bytes(), bb);
        assert_eq!(m.registered_prefixes(), 1);
        assert_eq!(m.prefix_expanded_bytes(a), 0, "released prefix reports 0");
    }

    #[test]
    fn export_import_round_trip() {
        let mut src = mgr(32);
        let id = src.register_shared_prefix(&prefix_tokens(40)).unwrap();
        src.expand_shared_prefix(id).unwrap();
        let ex = src.export_prefix(id).unwrap();
        assert_eq!(ex.len(), 40);
        assert!(ex.expanded);
        assert_eq!(ex.spans.iter().map(|s| s.tokens as usize).sum::<usize>(), 40);
        let mut dst = mgr(32);
        let did = dst.import_prefix(&ex).unwrap();
        let p = dst.prefix(did).unwrap();
        assert_eq!(p.len(), 40);
        assert!(p.expanded, "expansion state travels with the export");
        assert_eq!(dst.used_blocks(), src.used_blocks());
        assert_eq!(dst.expanded_bytes(), src.expanded_bytes());
        assert!(src.export_prefix(999).is_err());
    }

    #[test]
    fn unexpanded_export_imports_latent_only() {
        let mut src = mgr(8);
        let id = src.register_shared_prefix(&prefix_tokens(16)).unwrap();
        let ex = src.export_prefix(id).unwrap();
        assert!(!ex.expanded);
        let mut dst = mgr(8);
        let did = dst.import_prefix(&ex).unwrap();
        assert!(!dst.prefix(did).unwrap().expanded);
        assert_eq!(dst.expanded_bytes(), 0);
    }

    #[test]
    fn overhead_ratio_sane() {
        let mut m = mgr(256);
        let id = m.register_shared_prefix(&prefix_tokens(64)).unwrap();
        m.expand_shared_prefix(id).unwrap();
        for s in 0..16 {
            m.add_sequence(s, id, 128).unwrap();
        }
        let ov = m.expansion_overhead();
        let cfg = sim();
        let expect = (64 * cfg.uncompressed_words()) as f64
            / ((16 * 128 + 64) as f64 * cfg.latent_words() as f64);
        assert!((ov - expect).abs() / expect < 0.05, "ov={ov} expect={expect}");
    }

    #[test]
    fn release_after_expansion_returns_bytes() {
        let mut m = mgr(8);
        let id = m.register_shared_prefix(&prefix_tokens(16)).unwrap();
        m.expand_shared_prefix(id).unwrap();
        m.release_shared_prefix(id).unwrap();
        assert_eq!(m.expanded_bytes(), 0);
        assert_eq!(m.used_blocks(), 0);
    }
}
