//! Paged KV-cache block allocator (the PagedAttention substrate).
//!
//! Blocks are fixed-size pages of `block_size` tokens.  Reference
//! counting supports copy-on-write sharing of prefix blocks between
//! sequences (RadixAttention-style reuse).

use anyhow::{bail, Result};

pub type BlockId = u32;

#[derive(Clone, Debug)]
struct BlockMeta {
    refcount: u32,
}

/// O(1) alloc/free block pool with refcounting.
#[derive(Debug)]
pub struct BlockAllocator {
    block_size: usize,
    meta: Vec<BlockMeta>,
    free: Vec<BlockId>,
}

impl BlockAllocator {
    pub fn new(total_blocks: usize, block_size: usize) -> Self {
        assert!(block_size > 0);
        BlockAllocator {
            block_size,
            meta: (0..total_blocks).map(|_| BlockMeta { refcount: 0 }).collect(),
            free: (0..total_blocks as u32).rev().collect(),
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn total_blocks(&self) -> usize {
        self.meta.len()
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.total_blocks() - self.free_blocks()
    }

    /// Blocks needed to hold `tokens` tokens.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    pub fn can_allocate(&self, n: usize) -> bool {
        self.free.len() >= n
    }

    /// Allocate one block with refcount 1.
    pub fn allocate(&mut self) -> Result<BlockId> {
        match self.free.pop() {
            Some(id) => {
                debug_assert_eq!(self.meta[id as usize].refcount, 0);
                self.meta[id as usize].refcount = 1;
                Ok(id)
            }
            None => bail!("KV cache exhausted: 0 of {} blocks free", self.total_blocks()),
        }
    }

    /// Allocate `n` blocks atomically (all or nothing).
    pub fn allocate_n(&mut self, n: usize) -> Result<Vec<BlockId>> {
        if !self.can_allocate(n) {
            bail!(
                "KV cache exhausted: need {n} blocks, {} of {} free",
                self.free.len(),
                self.total_blocks()
            );
        }
        Ok((0..n).map(|_| self.allocate().expect("checked")).collect())
    }

    /// Increment the refcount (prefix sharing).
    pub fn retain(&mut self, id: BlockId) {
        let m = &mut self.meta[id as usize];
        assert!(m.refcount > 0, "retain of free block {id}");
        m.refcount += 1;
    }

    /// Decrement the refcount; frees the block when it reaches zero.
    pub fn release(&mut self, id: BlockId) {
        let m = &mut self.meta[id as usize];
        assert!(m.refcount > 0, "double free of block {id}");
        m.refcount -= 1;
        if m.refcount == 0 {
            self.free.push(id);
        }
    }

    pub fn refcount(&self, id: BlockId) -> u32 {
        self.meta[id as usize].refcount
    }
}

/// The block table of one sequence: logical token index -> block list.
#[derive(Clone, Debug, Default)]
pub struct BlockTable {
    pub blocks: Vec<BlockId>,
    /// Tokens stored (may be less than capacity of the block list).
    pub len: usize,
}

impl BlockTable {
    pub fn capacity(&self, block_size: usize) -> usize {
        self.blocks.len() * block_size
    }

    /// Ensure capacity for one more token, allocating if needed.
    pub fn append_token(&mut self, alloc: &mut BlockAllocator) -> Result<()> {
        if self.len + 1 > self.capacity(alloc.block_size()) {
            self.blocks.push(alloc.allocate()?);
        }
        self.len += 1;
        Ok(())
    }

    /// Ensure capacity for `n` tokens total, allocating if needed.
    pub fn reserve(&mut self, tokens: usize, alloc: &mut BlockAllocator) -> Result<()> {
        let need = alloc.blocks_for(tokens);
        while self.blocks.len() < need {
            self.blocks.push(alloc.allocate()?);
        }
        Ok(())
    }

    pub fn release_all(&mut self, alloc: &mut BlockAllocator) {
        for &b in &self.blocks {
            alloc.release(b);
        }
        self.blocks.clear();
        self.len = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_roundtrip() {
        let mut a = BlockAllocator::new(4, 128);
        let b1 = a.allocate().unwrap();
        let b2 = a.allocate().unwrap();
        assert_ne!(b1, b2);
        assert_eq!(a.free_blocks(), 2);
        a.release(b1);
        a.release(b2);
        assert_eq!(a.free_blocks(), 4);
    }

    #[test]
    fn exhaustion_is_error_not_panic() {
        let mut a = BlockAllocator::new(2, 128);
        a.allocate().unwrap();
        a.allocate().unwrap();
        assert!(a.allocate().is_err());
    }

    #[test]
    fn allocate_n_is_atomic() {
        let mut a = BlockAllocator::new(3, 128);
        let _held = a.allocate().unwrap();
        assert!(a.allocate_n(3).is_err());
        assert_eq!(a.free_blocks(), 2, "failed bulk alloc must not leak");
        let got = a.allocate_n(2).unwrap();
        assert_eq!(got.len(), 2);
    }

    #[test]
    fn refcounted_sharing() {
        let mut a = BlockAllocator::new(2, 128);
        let b = a.allocate().unwrap();
        a.retain(b);
        assert_eq!(a.refcount(b), 2);
        a.release(b);
        assert_eq!(a.free_blocks(), 1, "still held by second ref");
        a.release(b);
        assert_eq!(a.free_blocks(), 2);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut a = BlockAllocator::new(1, 128);
        let b = a.allocate().unwrap();
        a.release(b);
        a.release(b);
    }

    #[test]
    fn block_table_growth() {
        let mut a = BlockAllocator::new(8, 4);
        let mut t = BlockTable::default();
        for i in 1..=9 {
            t.append_token(&mut a).unwrap();
            assert_eq!(t.len, i);
        }
        assert_eq!(t.blocks.len(), 3); // ceil(9/4)
        t.release_all(&mut a);
        assert_eq!(a.free_blocks(), 8);
    }

    #[test]
    fn blocks_for_rounding() {
        let a = BlockAllocator::new(1, 128);
        assert_eq!(a.blocks_for(0), 0);
        assert_eq!(a.blocks_for(1), 1);
        assert_eq!(a.blocks_for(128), 1);
        assert_eq!(a.blocks_for(129), 2);
    }
}
