//! Radix tree over token sequences (the RadixAttention/SGLang substrate).
//!
//! Maps token prefixes to KV-cache pages so that requests sharing a
//! prefix (system prompt, tree-of-thought branches, speculative drafts)
//! reuse cached entries instead of recomputing them.  TyphoonMLA
//! additionally tags prefixes that have been *expanded* to uncompressed
//! K/V form (the naive-stage cache).
//!
//! Design notes:
//! * Edges carry **page spans** — `(page id, token count)` runs — not
//!   one `BlockId` per token.  With block size 128 this shrinks edge
//!   metadata and the match/insert/split page bookkeeping by ~128x
//!   while remaining *exact*: a span split mid-run keeps the page on
//!   both sides, which is precisely what the per-token representation
//!   encoded (adjacent tokens in one page).  The per-token semantics
//!   (`matched`, `expanded_len`, deduped `page_list()`) are preserved
//!   bit-for-bit; `tests/properties.rs` asserts the equivalence against
//!   a per-token oracle on randomized streams.
//! * Pin/unpin/mark operate on *token sequences*, not node handles, so
//!   they stay valid across edge splits.

use std::collections::HashMap;

use super::block::BlockId;

/// A run of consecutive tokens stored in one page.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PageSpan {
    pub page: BlockId,
    /// Tokens of the run covered by `page` (>= 1).
    pub tokens: u32,
}

impl PageSpan {
    pub fn new(page: BlockId, tokens: usize) -> Self {
        debug_assert!(tokens > 0);
        PageSpan { page, tokens: tokens as u32 }
    }
}

/// Append `span` to `out`, merging with the last run when the page id
/// continues (keeps span lists canonical: adjacent runs differ).
fn push_span(out: &mut Vec<PageSpan>, span: PageSpan) {
    if span.tokens == 0 {
        return;
    }
    if let Some(last) = out.last_mut() {
        if last.page == span.page {
            last.tokens += span.tokens;
            return;
        }
    }
    out.push(span);
}

/// RLE-compress a per-token page list into canonical spans.
pub fn spans_from_per_token(blocks: &[BlockId]) -> Vec<PageSpan> {
    let mut out = Vec::new();
    for &b in blocks {
        push_span(&mut out, PageSpan { page: b, tokens: 1 });
    }
    out
}

/// Spans for `tokens` tokens stored in block-aligned pages: page `j`
/// covers tokens `[j*block_size, (j+1)*block_size)` (tail partial).
/// `pages.len()` must be `tokens.div_ceil(block_size)`.
pub fn spans_from_pages(pages: &[BlockId], tokens: usize, block_size: usize) -> Vec<PageSpan> {
    assert!(block_size > 0);
    assert_eq!(pages.len(), tokens.div_ceil(block_size), "one page per chunk");
    let mut out = Vec::new();
    for (j, &p) in pages.iter().enumerate() {
        let covered = (tokens - j * block_size).min(block_size);
        push_span(&mut out, PageSpan::new(p, covered));
    }
    out
}

#[derive(Debug, Default)]
struct Node {
    /// Edge label: the token run leading into this node.
    tokens: Vec<u32>,
    /// Page spans of `tokens` (span token counts sum to tokens.len()).
    spans: Vec<PageSpan>,
    children: HashMap<u32, usize>, // first token of child edge -> node id
    /// Sequences currently pinning this edge.
    refcount: usize,
    /// TyphoonMLA: this edge's tokens also exist in uncompressed form.
    expanded: bool,
}

/// Result of a prefix match.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MatchResult {
    /// Number of tokens matched from the root.
    pub matched: usize,
    /// Page spans covering the matched tokens (canonical: adjacent runs
    /// have distinct pages; token counts sum to `matched`).
    pub spans: Vec<PageSpan>,
    /// Longest fully-*expanded* prefix within the match.
    pub expanded_len: usize,
}

impl MatchResult {
    /// Page list with consecutive duplicates removed — identical to the
    /// old per-token `page_list()` (spans are the dedup runs).
    pub fn page_list(&self) -> Vec<BlockId> {
        self.spans.iter().map(|s| s.page).collect()
    }

    /// Pages covering the first `n` matched tokens (run boundaries that
    /// straddle `n` include the straddling page, matching the per-token
    /// dedup of `blocks[..n]`).  `n` must be <= `matched`.
    pub fn pages_for_tokens(&self, n: usize) -> Vec<BlockId> {
        debug_assert!(n <= self.matched);
        let mut out = Vec::new();
        let mut consumed = 0usize;
        for s in &self.spans {
            if consumed >= n {
                break;
            }
            out.push(s.page);
            consumed += s.tokens as usize;
        }
        out
    }
}

/// Token-sequence radix tree with page-span edges.
#[derive(Debug)]
pub struct RadixTree {
    nodes: Vec<Node>,
}

impl Default for RadixTree {
    fn default() -> Self {
        Self::new()
    }
}

/// Split a canonical span list after `keep` tokens; returns
/// (prefix, suffix).  A run straddling the cut appears in both halves
/// with its token count split (same page on both sides — exactly the
/// per-token behavior).
fn split_spans(spans: &[PageSpan], keep: usize) -> (Vec<PageSpan>, Vec<PageSpan>) {
    let mut head = Vec::new();
    let mut tail = Vec::new();
    let mut consumed = 0usize;
    for s in spans {
        let len = s.tokens as usize;
        if consumed + len <= keep {
            push_span(&mut head, *s);
        } else if consumed >= keep {
            push_span(&mut tail, *s);
        } else {
            let head_part = keep - consumed;
            push_span(&mut head, PageSpan::new(s.page, head_part));
            push_span(&mut tail, PageSpan::new(s.page, len - head_part));
        }
        consumed += len;
    }
    (head, tail)
}

/// Prefix of a canonical span list covering `n` tokens.
fn truncate_spans(spans: &[PageSpan], n: usize) -> Vec<PageSpan> {
    split_spans(spans, n).0
}

impl RadixTree {
    pub fn new() -> Self {
        RadixTree { nodes: vec![Node::default()] } // 0 = root
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Total page spans held across all edges (memory diagnostic: in the
    /// per-token representation this was the total token count).
    pub fn span_count(&self) -> usize {
        self.nodes.iter().map(|n| n.spans.len()).sum()
    }

    /// Longest-prefix match of `tokens` against the tree.  Matches may
    /// end mid-edge (span splitting keeps partial reuse exact).
    pub fn match_prefix(&self, tokens: &[u32]) -> MatchResult {
        let mut result = MatchResult::default();
        let mut node = 0usize;
        let mut pos = 0usize;
        let mut expanded_run = true;
        loop {
            let Some(&next) = tokens.get(pos).and_then(|t| self.nodes[node].children.get(t))
            else {
                return result;
            };
            let edge = &self.nodes[next];
            let common = edge
                .tokens
                .iter()
                .zip(&tokens[pos..])
                .take_while(|(a, b)| a == b)
                .count();
            pos += common;
            result.matched = pos;
            if common == edge.tokens.len() {
                for &s in &edge.spans {
                    push_span(&mut result.spans, s);
                }
            } else {
                for s in truncate_spans(&edge.spans, common) {
                    push_span(&mut result.spans, s);
                }
            }
            expanded_run &= edge.expanded;
            if expanded_run {
                result.expanded_len = pos;
            }
            if common < edge.tokens.len() {
                return result; // diverged mid-edge
            }
            node = next;
        }
    }

    /// Split the edge into `node` so its label has exactly `keep`
    /// tokens; the remainder moves to a new child.  Both halves inherit
    /// refcount/expanded; a page run straddling the split is kept on
    /// both sides.
    fn split_edge(&mut self, node: usize, keep: usize) {
        debug_assert!(keep > 0 && keep < self.nodes[node].tokens.len());
        let rest_tokens = self.nodes[node].tokens.split_off(keep);
        let (head_spans, rest_spans) = split_spans(&self.nodes[node].spans, keep);
        self.nodes[node].spans = head_spans;
        let rest = Node {
            tokens: rest_tokens,
            spans: rest_spans,
            children: std::mem::take(&mut self.nodes[node].children),
            refcount: self.nodes[node].refcount,
            expanded: self.nodes[node].expanded,
        };
        let rest_id = self.nodes.len();
        let first = rest.tokens[0];
        self.nodes.push(rest);
        self.nodes[node].children.insert(first, rest_id);
    }

    /// Insert a fully-cached token run (absolute prefix from the root)
    /// with its page spans.  Existing overlap is left untouched; only
    /// the new suffix is added (splitting an edge if needed).
    pub fn insert(&mut self, tokens: &[u32], spans: &[PageSpan]) {
        let covered: usize = spans.iter().map(|s| s.tokens as usize).sum();
        assert_eq!(covered, tokens.len(), "spans must cover the token run exactly");
        let mut node = 0usize;
        let mut pos = 0usize;
        loop {
            if pos == tokens.len() {
                return;
            }
            match self.nodes[node].children.get(&tokens[pos]).copied() {
                None => {
                    let id = self.nodes.len();
                    self.nodes.push(Node {
                        tokens: tokens[pos..].to_vec(),
                        spans: split_spans(spans, pos).1,
                        children: HashMap::new(),
                        refcount: 0,
                        expanded: false,
                    });
                    self.nodes[node].children.insert(tokens[pos], id);
                    return;
                }
                Some(next) => {
                    let common = self.nodes[next]
                        .tokens
                        .iter()
                        .zip(&tokens[pos..])
                        .take_while(|(a, b)| a == b)
                        .count();
                    if common < self.nodes[next].tokens.len() {
                        self.split_edge(next, common);
                    }
                    pos += common;
                    node = next;
                }
            }
        }
    }

    /// Convenience: insert with block-aligned pages (page `j` covers
    /// tokens `[j*block_size, (j+1)*block_size)`).
    pub fn insert_chunked(&mut self, tokens: &[u32], pages: &[BlockId], block_size: usize) {
        let spans = spans_from_pages(pages, tokens.len(), block_size);
        self.insert(tokens, &spans);
    }

    /// Walk `tokens` applying `f` to every fully-covered edge.
    /// Panics if `tokens` is not fully present (caller bug).
    fn for_each_edge<F: FnMut(&mut Node)>(&mut self, tokens: &[u32], mut f: F) {
        let mut node = 0usize;
        let mut pos = 0usize;
        while pos < tokens.len() {
            let next = *self.nodes[node]
                .children
                .get(&tokens[pos])
                .unwrap_or_else(|| panic!("token run not present at pos {pos}"));
            let edge_len = self.nodes[next].tokens.len();
            assert!(
                tokens[pos..].len() >= edge_len
                    && self.nodes[next].tokens == tokens[pos..pos + edge_len],
                "token run diverges mid-edge at pos {pos}; split first via insert()"
            );
            f(&mut self.nodes[next]);
            pos += edge_len;
            node = next;
        }
    }

    /// Pin a token run (one count per active user).  The run must be
    /// edge-aligned — i.e. previously `insert`ed exactly.
    pub fn pin(&mut self, tokens: &[u32]) {
        self.for_each_edge(tokens, |n| n.refcount += 1);
    }

    pub fn unpin(&mut self, tokens: &[u32]) {
        self.for_each_edge(tokens, |n| {
            assert!(n.refcount > 0, "unpin of unpinned edge");
            n.refcount -= 1;
        });
    }

    /// Mark a token run as expanded to uncompressed form.
    pub fn mark_expanded(&mut self, tokens: &[u32]) {
        self.for_each_edge(tokens, |n| n.expanded = true);
    }

    /// Export the page spans covering a fully-cached token run (a
    /// prefix group about to migrate): the canonical span layout a peer
    /// needs to size and stream the transfer.  `None` when the run is
    /// not fully resident.
    pub fn export_spans(&self, tokens: &[u32]) -> Option<Vec<PageSpan>> {
        let m = self.match_prefix(tokens);
        (m.matched == tokens.len()).then_some(m.spans)
    }

    /// Evict all unpinned leaves (transitively), returning the page ids
    /// they held — one entry per span run (dedup before releasing
    /// refcounts once per page; the manager owns that policy, and a
    /// page straddling an edge split may appear in a surviving edge
    /// too).
    pub fn evict_unpinned(&mut self) -> Vec<BlockId> {
        let mut released = Vec::new();
        loop {
            let mut parent_of: HashMap<usize, (usize, u32)> = HashMap::new();
            for (pid, node) in self.nodes.iter().enumerate() {
                // detlint: allow(unordered-iter, keyed parent_of rebuild - every
                // child id is a distinct key, so insertion order cannot matter)
                for (&tok, &cid) in &node.children {
                    parent_of.insert(cid, (pid, tok));
                }
            }
            let victim = (1..self.nodes.len()).find(|&i| {
                self.nodes[i].refcount == 0
                    && self.nodes[i].children.is_empty()
                    && !self.nodes[i].tokens.is_empty()
            });
            match victim {
                None => return released,
                Some(v) => {
                    released.extend(self.nodes[v].spans.drain(..).map(|s| s.page));
                    self.nodes[v].tokens.clear();
                    if let Some(&(p, tok)) = parent_of.get(&v) {
                        self.nodes[p].children.remove(&tok);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<u32> {
        s.bytes().map(|b| b as u32).collect()
    }

    /// One page per 4 tokens, page ids starting at `base` — as a
    /// per-token list, RLE-compressed at the API boundary.
    fn per_token_pages(n: usize, base: u32) -> Vec<BlockId> {
        (0..n).map(|i| base + (i / 4) as u32).collect()
    }

    fn spans(n: usize, base: u32) -> Vec<PageSpan> {
        spans_from_per_token(&per_token_pages(n, base))
    }

    #[test]
    fn empty_tree_matches_nothing() {
        let t = RadixTree::new();
        let m = t.match_prefix(&toks("hello"));
        assert_eq!(m.matched, 0);
        assert!(m.spans.is_empty());
    }

    #[test]
    fn insert_then_full_match() {
        let mut t = RadixTree::new();
        let s = toks("system prompt");
        t.insert(&s, &spans(s.len(), 0));
        let m = t.match_prefix(&s);
        assert_eq!(m.matched, 13);
        assert_eq!(m.page_list(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn longest_prefix_of_longer_query() {
        let mut t = RadixTree::new();
        let s = toks("shared");
        t.insert(&s, &spans(s.len(), 0));
        let m = t.match_prefix(&toks("shared suffix"));
        assert_eq!(m.matched, 6);
    }

    #[test]
    fn mid_edge_partial_match_counts_tokens() {
        let mut t = RadixTree::new();
        t.insert(&toks("abcdef"), &spans(6, 0));
        let m = t.match_prefix(&toks("abcxyz"));
        assert_eq!(m.matched, 3);
        assert_eq!(m.spans.iter().map(|s| s.tokens as usize).sum::<usize>(), 3);
        assert_eq!(m.page_list(), vec![0]);
    }

    #[test]
    fn divergent_insert_splits_edge() {
        let mut t = RadixTree::new();
        t.insert(&toks("abcdef"), &spans(6, 0));
        t.insert(&toks("abcxyz"), &{
            let mut b = per_token_pages(3, 0);
            b.extend(per_token_pages(3, 100));
            spans_from_per_token(&b)
        });
        for (q, want) in [("abcdef", 6), ("abcxyz", 6), ("abcq", 3), ("ab", 2)] {
            assert_eq!(t.match_prefix(&toks(q)).matched, want, "{q}");
        }
    }

    #[test]
    fn pin_survives_split() {
        let mut t = RadixTree::new();
        let a = toks("abcdef");
        t.insert(&a, &spans(6, 0));
        t.pin(&a);
        // Divergent insert splits the pinned edge.
        t.insert(&toks("abcxyz"), &{
            let mut b = per_token_pages(3, 0);
            b.extend(per_token_pages(3, 100));
            spans_from_per_token(&b)
        });
        // Eviction must not touch the pinned run, but may take the
        // unpinned new suffix.
        let released = t.evict_unpinned();
        assert!(!released.is_empty());
        assert_eq!(t.match_prefix(&a).matched, 6, "pinned run intact");
        t.unpin(&a);
        t.evict_unpinned();
        assert_eq!(t.match_prefix(&a).matched, 0);
    }

    #[test]
    fn expanded_len_tracks_typhoon_coverage() {
        let mut t = RadixTree::new();
        let sys = toks("sys");
        t.insert(&sys, &spans(3, 0));
        t.insert(&toks("sysq1"), &{
            let mut b = per_token_pages(3, 0);
            b.extend(per_token_pages(2, 50));
            spans_from_per_token(&b)
        });
        t.mark_expanded(&sys);
        let m = t.match_prefix(&toks("sysq1"));
        assert_eq!(m.matched, 5);
        assert_eq!(m.expanded_len, 3, "only the marked prefix is expanded");
    }

    #[test]
    fn export_spans_requires_full_residency() {
        let mut t = RadixTree::new();
        let s = toks("system prompt");
        t.insert(&s, &spans(s.len(), 0));
        let ex = t.export_spans(&s).unwrap();
        assert_eq!(ex.iter().map(|x| x.tokens as usize).sum::<usize>(), s.len());
        assert_eq!(ex, t.match_prefix(&s).spans);
        assert!(t.export_spans(&toks("system prompt tail")).is_none());
        assert_eq!(t.export_spans(&[]), Some(vec![]));
    }

    #[test]
    fn page_list_dedups() {
        let m = MatchResult {
            matched: 6,
            spans: spans_from_per_token(&[4, 4, 4, 7, 7, 9]),
            expanded_len: 0,
        };
        assert_eq!(m.page_list(), vec![4, 7, 9]);
    }

    #[test]
    fn pages_for_tokens_matches_per_token_dedup() {
        let blocks = [4u32, 4, 4, 7, 7, 9, 9, 9];
        let m = MatchResult {
            matched: 8,
            spans: spans_from_per_token(&blocks),
            expanded_len: 0,
        };
        for n in 0..=8usize {
            let mut expect: Vec<BlockId> = Vec::new();
            for &b in &blocks[..n] {
                if expect.last() != Some(&b) {
                    expect.push(b);
                }
            }
            assert_eq!(m.pages_for_tokens(n), expect, "n={n}");
        }
    }

    #[test]
    fn span_helpers_roundtrip() {
        // Block-aligned construction matches per-token expansion.
        let pages = [10u32, 11, 12];
        let aligned = spans_from_pages(&pages, 9, 4); // 4+4+1 tokens
        let per_token: Vec<BlockId> =
            (0..9).map(|i| pages[i / 4]).collect();
        assert_eq!(aligned, spans_from_per_token(&per_token));
        // Splitting mid-run keeps the page on both sides.
        let (head, tail) = split_spans(&aligned, 6);
        assert_eq!(head, vec![PageSpan::new(10, 4), PageSpan::new(11, 2)]);
        assert_eq!(tail, vec![PageSpan::new(11, 2), PageSpan::new(12, 1)]);
    }

    #[test]
    fn match_against_naive_scan_randomized() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(99);
        let mut t = RadixTree::new();
        let mut corpus: Vec<Vec<u32>> = Vec::new();
        let mut per_token: Vec<Vec<BlockId>> = Vec::new();
        for i in 0..60u32 {
            let (mut s, mut blocks) = if corpus.is_empty() || rng.next_f64() < 0.3 {
                (Vec::new(), Vec::new())
            } else {
                let k = rng.gen_range_usize(0, corpus.len());
                let cut = rng.gen_range_usize(0, corpus[k].len() + 1);
                (corpus[k][..cut].to_vec(), per_token[k][..cut].to_vec())
            };
            for _ in 0..rng.gen_range_usize(1, 6) {
                s.push(rng.gen_range(0, 5) as u32);
            }
            // Fresh per-token pages for the new suffix (may start
            // mid-"page" — the per-token model the spans must replicate).
            blocks.extend((blocks.len()..s.len()).map(|j| i * 1000 + j as u32));
            let m = t.match_prefix(&s);
            assert_eq!(
                m.spans.iter().map(|x| x.tokens as usize).sum::<usize>(),
                m.matched
            );
            t.insert(&s, &spans_from_per_token(&blocks));
            corpus.push(s);
            per_token.push(blocks);
        }
        // Oracle: longest common prefix against every inserted string.
        for (probe, blocks) in corpus.iter().zip(&per_token) {
            let m = t.match_prefix(probe);
            let oracle = corpus
                .iter()
                .map(|s| s.iter().zip(probe).take_while(|(a, b)| a == b).count())
                .max()
                .unwrap();
            assert_eq!(m.matched, oracle);
            // Page list identical to per-token dedup.
            let mut expect: Vec<BlockId> = Vec::new();
            for &b in &blocks[..m.matched] {
                if expect.last() != Some(&b) {
                    expect.push(b);
                }
            }
            assert_eq!(m.page_list(), expect);
        }
    }
}
