//! Radix tree over token sequences (the RadixAttention/SGLang substrate).
//!
//! Maps token prefixes to KV-cache blocks so that requests sharing a
//! prefix (system prompt, tree-of-thought branches, speculative drafts)
//! reuse cached entries instead of recomputing them.  TyphoonMLA
//! additionally tags prefixes that have been *expanded* to uncompressed
//! K/V form (the naive-stage cache).
//!
//! Design notes:
//! * Edges carry one `BlockId` **per token** (the page id that token
//!   lives in); the cache manager dedups consecutive ids back into page
//!   lists.  Per-token granularity makes mid-edge splits exact.
//! * Pin/unpin/mark operate on *token sequences*, not node handles, so
//!   they stay valid across edge splits.

use std::collections::HashMap;

use super::block::BlockId;

#[derive(Debug, Default)]
struct Node {
    /// Edge label: the token run leading into this node.
    tokens: Vec<u32>,
    /// Page id of each token in `tokens` (same length).
    blocks: Vec<BlockId>,
    children: HashMap<u32, usize>, // first token of child edge -> node id
    /// Sequences currently pinning this edge.
    refcount: usize,
    /// TyphoonMLA: this edge's tokens also exist in uncompressed form.
    expanded: bool,
}

/// Result of a prefix match.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MatchResult {
    /// Number of tokens matched from the root.
    pub matched: usize,
    /// Page id per matched token (dedup for a page list).
    pub blocks: Vec<BlockId>,
    /// Longest fully-*expanded* prefix within the match.
    pub expanded_len: usize,
}

impl MatchResult {
    /// Page list with consecutive duplicates removed.
    pub fn page_list(&self) -> Vec<BlockId> {
        let mut out: Vec<BlockId> = Vec::new();
        for &b in &self.blocks {
            if out.last() != Some(&b) {
                out.push(b);
            }
        }
        out
    }
}

/// Token-sequence radix tree.
#[derive(Debug)]
pub struct RadixTree {
    nodes: Vec<Node>,
}

impl Default for RadixTree {
    fn default() -> Self {
        Self::new()
    }
}

impl RadixTree {
    pub fn new() -> Self {
        RadixTree { nodes: vec![Node::default()] } // 0 = root
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Longest-prefix match of `tokens` against the tree.  Matches may
    /// end mid-edge (per-token blocks make partial reuse exact).
    pub fn match_prefix(&self, tokens: &[u32]) -> MatchResult {
        let mut result = MatchResult::default();
        let mut node = 0usize;
        let mut pos = 0usize;
        let mut expanded_run = true;
        loop {
            let Some(&next) = tokens.get(pos).and_then(|t| self.nodes[node].children.get(t))
            else {
                return result;
            };
            let edge = &self.nodes[next];
            let common = edge
                .tokens
                .iter()
                .zip(&tokens[pos..])
                .take_while(|(a, b)| a == b)
                .count();
            pos += common;
            result.matched = pos;
            result.blocks.extend_from_slice(&edge.blocks[..common]);
            expanded_run &= edge.expanded;
            if expanded_run {
                result.expanded_len = pos;
            }
            if common < edge.tokens.len() {
                return result; // diverged mid-edge
            }
            node = next;
        }
    }

    /// Split the edge into `node` so its label has exactly `keep`
    /// tokens; the remainder moves to a new child.  Both halves inherit
    /// refcount/expanded.
    fn split_edge(&mut self, node: usize, keep: usize) {
        debug_assert!(keep > 0 && keep < self.nodes[node].tokens.len());
        let rest_tokens = self.nodes[node].tokens.split_off(keep);
        let rest_blocks = self.nodes[node].blocks.split_off(keep);
        let rest = Node {
            tokens: rest_tokens,
            blocks: rest_blocks,
            children: std::mem::take(&mut self.nodes[node].children),
            refcount: self.nodes[node].refcount,
            expanded: self.nodes[node].expanded,
        };
        let rest_id = self.nodes.len();
        let first = rest.tokens[0];
        self.nodes.push(rest);
        self.nodes[node].children.insert(first, rest_id);
    }

    /// Insert a fully-cached token run (absolute prefix from the root)
    /// with one page id per token.  Existing overlap is left untouched;
    /// only the new suffix is added (splitting an edge if needed).
    pub fn insert(&mut self, tokens: &[u32], blocks_per_token: &[BlockId]) {
        assert_eq!(tokens.len(), blocks_per_token.len());
        let mut node = 0usize;
        let mut pos = 0usize;
        loop {
            if pos == tokens.len() {
                return;
            }
            match self.nodes[node].children.get(&tokens[pos]).copied() {
                None => {
                    let id = self.nodes.len();
                    self.nodes.push(Node {
                        tokens: tokens[pos..].to_vec(),
                        blocks: blocks_per_token[pos..].to_vec(),
                        children: HashMap::new(),
                        refcount: 0,
                        expanded: false,
                    });
                    self.nodes[node].children.insert(tokens[pos], id);
                    return;
                }
                Some(next) => {
                    let common = self.nodes[next]
                        .tokens
                        .iter()
                        .zip(&tokens[pos..])
                        .take_while(|(a, b)| a == b)
                        .count();
                    if common < self.nodes[next].tokens.len() {
                        self.split_edge(next, common);
                    }
                    pos += common;
                    node = next;
                }
            }
        }
    }

    /// Walk `tokens` applying `f` to every fully-covered edge.
    /// Panics if `tokens` is not fully present (caller bug).
    fn for_each_edge<F: FnMut(&mut Node)>(&mut self, tokens: &[u32], mut f: F) {
        let mut node = 0usize;
        let mut pos = 0usize;
        while pos < tokens.len() {
            let next = *self.nodes[node]
                .children
                .get(&tokens[pos])
                .unwrap_or_else(|| panic!("token run not present at pos {pos}"));
            let edge_len = self.nodes[next].tokens.len();
            assert!(
                tokens[pos..].len() >= edge_len
                    && self.nodes[next].tokens == tokens[pos..pos + edge_len],
                "token run diverges mid-edge at pos {pos}; split first via insert()"
            );
            f(&mut self.nodes[next]);
            pos += edge_len;
            node = next;
        }
    }

    /// Pin a token run (one count per active user).  The run must be
    /// edge-aligned — i.e. previously `insert`ed exactly.
    pub fn pin(&mut self, tokens: &[u32]) {
        self.for_each_edge(tokens, |n| n.refcount += 1);
    }

    pub fn unpin(&mut self, tokens: &[u32]) {
        self.for_each_edge(tokens, |n| {
            assert!(n.refcount > 0, "unpin of unpinned edge");
            n.refcount -= 1;
        });
    }

    /// Mark a token run as expanded to uncompressed form.
    pub fn mark_expanded(&mut self, tokens: &[u32]) {
        self.for_each_edge(tokens, |n| n.expanded = true);
    }

    /// Evict all unpinned leaves (transitively), returning the per-token
    /// page ids they held (dedup before releasing refcounts once per
    /// page — the manager owns that policy).
    pub fn evict_unpinned(&mut self) -> Vec<BlockId> {
        let mut released = Vec::new();
        loop {
            let mut parent_of: HashMap<usize, (usize, u32)> = HashMap::new();
            for (pid, node) in self.nodes.iter().enumerate() {
                for (&tok, &cid) in &node.children {
                    parent_of.insert(cid, (pid, tok));
                }
            }
            let victim = (1..self.nodes.len()).find(|&i| {
                self.nodes[i].refcount == 0
                    && self.nodes[i].children.is_empty()
                    && !self.nodes[i].tokens.is_empty()
            });
            match victim {
                None => return released,
                Some(v) => {
                    released.extend(self.nodes[v].blocks.drain(..));
                    self.nodes[v].tokens.clear();
                    if let Some(&(p, tok)) = parent_of.get(&v) {
                        self.nodes[p].children.remove(&tok);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<u32> {
        s.bytes().map(|b| b as u32).collect()
    }

    /// One page per 4 tokens, page ids starting at `base`.
    fn pages(n: usize, base: u32) -> Vec<BlockId> {
        (0..n).map(|i| base + (i / 4) as u32).collect()
    }

    #[test]
    fn empty_tree_matches_nothing() {
        let t = RadixTree::new();
        let m = t.match_prefix(&toks("hello"));
        assert_eq!(m.matched, 0);
        assert!(m.blocks.is_empty());
    }

    #[test]
    fn insert_then_full_match() {
        let mut t = RadixTree::new();
        let s = toks("system prompt");
        t.insert(&s, &pages(s.len(), 0));
        let m = t.match_prefix(&s);
        assert_eq!(m.matched, 13);
        assert_eq!(m.page_list(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn longest_prefix_of_longer_query() {
        let mut t = RadixTree::new();
        let s = toks("shared");
        t.insert(&s, &pages(s.len(), 0));
        let m = t.match_prefix(&toks("shared suffix"));
        assert_eq!(m.matched, 6);
    }

    #[test]
    fn mid_edge_partial_match_counts_tokens() {
        let mut t = RadixTree::new();
        t.insert(&toks("abcdef"), &pages(6, 0));
        let m = t.match_prefix(&toks("abcxyz"));
        assert_eq!(m.matched, 3);
        assert_eq!(m.blocks.len(), 3);
    }

    #[test]
    fn divergent_insert_splits_edge() {
        let mut t = RadixTree::new();
        t.insert(&toks("abcdef"), &pages(6, 0));
        t.insert(&toks("abcxyz"), &{
            let mut b = pages(3, 0);
            b.extend(pages(3, 100));
            b
        });
        for (q, want) in [("abcdef", 6), ("abcxyz", 6), ("abcq", 3), ("ab", 2)] {
            assert_eq!(t.match_prefix(&toks(q)).matched, want, "{q}");
        }
    }

    #[test]
    fn pin_survives_split() {
        let mut t = RadixTree::new();
        let a = toks("abcdef");
        t.insert(&a, &pages(6, 0));
        t.pin(&a);
        // Divergent insert splits the pinned edge.
        t.insert(&toks("abcxyz"), &{
            let mut b = pages(3, 0);
            b.extend(pages(3, 100));
            b
        });
        // Eviction must not touch the pinned run, but may take the
        // unpinned new suffix.
        let released = t.evict_unpinned();
        assert!(!released.is_empty());
        assert_eq!(t.match_prefix(&a).matched, 6, "pinned run intact");
        t.unpin(&a);
        t.evict_unpinned();
        assert_eq!(t.match_prefix(&a).matched, 0);
    }

    #[test]
    fn expanded_len_tracks_typhoon_coverage() {
        let mut t = RadixTree::new();
        let sys = toks("sys");
        t.insert(&sys, &pages(3, 0));
        t.insert(&toks("sysq1"), &{
            let mut b = pages(3, 0);
            b.extend(pages(2, 50));
            b
        });
        t.mark_expanded(&sys);
        let m = t.match_prefix(&toks("sysq1"));
        assert_eq!(m.matched, 5);
        assert_eq!(m.expanded_len, 3, "only the marked prefix is expanded");
    }

    #[test]
    fn page_list_dedups() {
        let m = MatchResult { matched: 6, blocks: vec![4, 4, 4, 7, 7, 9], expanded_len: 0 };
        assert_eq!(m.page_list(), vec![4, 7, 9]);
    }

    #[test]
    fn match_against_naive_scan_randomized() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(99);
        let mut t = RadixTree::new();
        let mut corpus: Vec<Vec<u32>> = Vec::new();
        for i in 0..60u32 {
            let base = if corpus.is_empty() || rng.next_f64() < 0.3 {
                Vec::new()
            } else {
                let b = rng.choose(&corpus).clone();
                let cut = rng.gen_range_usize(0, b.len() + 1);
                b[..cut].to_vec()
            };
            let mut s = base;
            for _ in 0..rng.gen_range_usize(1, 6) {
                s.push(rng.gen_range(0, 5) as u32);
            }
            let m = t.match_prefix(&s);
            let mut blocks = m.blocks.clone();
            blocks.extend((blocks.len()..s.len()).map(|j| i * 1000 + j as u32));
            t.insert(&s, &blocks);
            corpus.push(s);
        }
        // Oracle: longest common prefix against every inserted string.
        for probe in &corpus {
            let m = t.match_prefix(probe);
            let oracle = corpus
                .iter()
                .map(|s| s.iter().zip(probe).take_while(|(a, b)| a == b).count())
                .max()
                .unwrap();
            assert_eq!(m.matched, oracle);
            assert_eq!(m.blocks.len(), m.matched);
        }
    }
}
