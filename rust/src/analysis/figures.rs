//! Figures 2-8: throughput sweeps, latency breakdown, HBM footprint,
//! roofline, theoretical analysis and batch-size sensitivity.

use std::fmt::Write as _;

use anyhow::Result;

use crate::config::hardware::{ascend_npu, gpu_h800, roofline_npu, Backend, HardwareSpec};
use crate::config::model::{deepseek_v3, kimi_k2};
use crate::config::KernelKind;
use crate::costmodel::exec_time::{time_breakdown, TimeBreakdown};
use crate::costmodel::flops::{attention_cost, AttentionWorkload};
use crate::costmodel::memory::{cloudmatrix_384, hbm_footprint, typhoon_overhead};
use crate::costmodel::roofline::roofline_point;
use crate::simulator::cluster::RouterPolicy;
use crate::simulator::sweep::{
    cluster_cells, cluster_row_configs, crossover_cells, run_cluster_sweep,
    run_crossover_sweep, run_tenant_sweep, run_throughput_sweep, tenant_cells,
    throughput_cells, ClusterCellResult, CrossoverCellResult, SweepExecutor,
    TenantCellResult, ThroughputCellResult,
};
use crate::simulator::tenancy::calibration_cell;

use super::Artifact;

pub const PAPER_BATCHES: [usize; 5] = [64, 128, 256, 512, 1024];

/// The `tenants` artifact grid: tenant count x arrival skew.
pub const TENANT_COUNTS: [usize; 4] = [1, 2, 4, 8];
pub const TENANT_SKEWS: [f64; 3] = [0.0, 1.0, 2.0];

/// The `cluster` artifact grid: replica count x arrival skew x arrival
/// profile (router/migration/autoscale configurations compared inside
/// each row).
pub const CLUSTER_REPLICAS: [usize; 3] = [1, 2, 4];
pub const CLUSTER_SKEWS: [f64; 2] = [0.0, 2.0];
pub const CLUSTER_TENANTS: usize = 4;
/// Arrival profiles: the paper's batch protocol (autoscaling holds —
/// an infinite lambda is unobservable) and a bursty Poisson square
/// wave (calm 200 req/s, bursts 50x) that exercises admission
/// pressure and fleet resizing.
pub const CLUSTER_ARRIVALS: [Option<(f64, f64)>; 2] = [None, Some((200.0, 50.0))];

/// The Fig. 2/3 model pair.
pub fn paper_models() -> Vec<crate::config::ModelConfig> {
    vec![deepseek_v3(), kimi_k2()]
}

/// Format evaluated throughput-grid cells into the Fig. 2/3 artifact.
/// Cells must be in `throughput_cells` order with `batches_per_group`
/// batches per (model x prompt x dataset) group; the output is
/// byte-identical however the cells were evaluated (serial or
/// parallel) — only their order matters.
pub fn format_throughput(
    id: &'static str,
    hw: &HardwareSpec,
    results: &[ThroughputCellResult],
    batches_per_group: usize,
) -> Artifact {
    let mut text = String::new();
    let mut csv = String::from(
        "model,prompt,dataset,batch,typhoon_tok_s,absorb_tok_s,naive_tok_s,speedup_vs_best_baseline\n",
    );
    for (i, r) in results.iter().enumerate() {
        let c = &r.cell;
        if batches_per_group > 0 && i % batches_per_group == 0 {
            writeln!(
                text,
                "-- {} / {} / {} ({} tokens shared) --",
                c.model.name, c.prompt.name, c.dataset.name, c.prompt.tokens
            )
            .unwrap();
            writeln!(
                text,
                "{:>6} {:>14} {:>14} {:>14} {:>9}",
                "batch", "typhoon tok/s", "absorb tok/s", "naive tok/s", "speedup"
            )
            .unwrap();
        }
        let [t, a, n] = &r.reports;
        let best = a.throughput.max(n.throughput);
        let speedup = t.throughput / best;
        writeln!(
            text,
            "{:>6} {:>14.0} {:>14.0} {:>14.0} {:>8.2}x",
            c.batch, t.throughput, a.throughput, n.throughput, speedup
        )
        .unwrap();
        writeln!(
            csv,
            "{},{},{},{},{:.1},{:.1},{:.1},{:.3}",
            c.model.name,
            c.prompt.name,
            c.dataset.name,
            c.batch,
            t.throughput,
            a.throughput,
            n.throughput,
            speedup
        )
        .unwrap();
    }
    Artifact {
        id: if id == "fig2" { "fig2" } else { "fig3" },
        title: format!("Decode throughput sweep on {}", hw.name),
        text,
        csv,
    }
}

/// Figs. 2 (NPU) and 3 (GPU): normalized decode throughput, per
/// (model x prompt x dataset x batch), typhoon vs absorb vs naive.
/// Cells are evaluated under `exec` (parallel workers with ordered
/// collection by default; the artifact is byte-identical to serial).
pub fn fig_throughput(
    id: &'static str,
    hw: &HardwareSpec,
    batches: &[usize],
    max_requests_factor: Option<usize>,
    exec: &SweepExecutor,
) -> Result<Artifact> {
    let cells = throughput_cells(&paper_models(), batches, max_requests_factor);
    let results = run_throughput_sweep(hw, &cells, exec)?;
    Ok(format_throughput(id, hw, &results, batches.len()))
}

/// Format evaluated tenants-grid cells into the `tenants` artifact.
/// Byte-identical however the cells were evaluated (serial or
/// parallel) — only their order matters.
pub fn format_tenants(results: &[TenantCellResult]) -> Artifact {
    let gib = (1u64 << 30) as f64;
    let mut text = String::new();
    let mut csv = String::from(
        "tenants,skew,typhoon_tok_s,absorb_tok_s,naive_tok_s,\
         speedup_vs_best_baseline,mixed_iters,typhoon_group_iters,expansion_gib\n",
    );
    writeln!(
        text,
        "{:>7} {:>5} {:>14} {:>14} {:>14} {:>9} {:>7} {:>10}",
        "tenants", "skew", "typhoon tok/s", "absorb tok/s", "naive tok/s", "speedup",
        "mixed", "expand GiB"
    )
    .unwrap();
    for r in results {
        let c = &r.cell;
        let [t, a, n] = &r.reports;
        let best = a.throughput.max(n.throughput);
        let speedup = t.throughput / best;
        writeln!(
            text,
            "{:>7} {:>5.1} {:>14.0} {:>14.0} {:>14.0} {:>8.2}x {:>7} {:>10.3}",
            c.tenants,
            c.skew,
            t.throughput,
            a.throughput,
            n.throughput,
            speedup,
            t.mixed_iters,
            t.expansion_bytes as f64 / gib
        )
        .unwrap();
        writeln!(
            csv,
            "{},{:.1},{:.1},{:.1},{:.1},{:.3},{},{},{:.4}",
            c.tenants,
            c.skew,
            t.throughput,
            a.throughput,
            n.throughput,
            speedup,
            t.mixed_iters,
            t.typhoon_iters,
            t.expansion_bytes as f64 / gib
        )
        .unwrap();
    }
    text.push_str(
        "(grouped typhoon: per-group fall-back — hot tenants run the mixed \
         kernel while cold ones absorb; baselines: global absorb, per-tenant \
         naive)\n",
    );
    Artifact {
        id: "tenants",
        title: "Multi-tenant prefix groups: tenant count x skew, DeepSeek-v3 (Ascend)"
            .into(),
        text,
        csv,
    }
}

/// `tenants` artifact: tenant-count x skew sweep comparing grouped
/// Typhoon against the global-absorb and per-tenant-naive baselines on
/// the same multi-tenant workload.  Cells run under `exec` with
/// ordered collection — byte-identical to a serial run.
pub fn fig_tenants(
    max_requests_factor: Option<usize>,
    exec: &SweepExecutor,
) -> Result<Artifact> {
    let batch = 256;
    let total_requests = max_requests_factor.unwrap_or(8) * batch;
    let cells = tenant_cells(
        &deepseek_v3(),
        &TENANT_COUNTS,
        &TENANT_SKEWS,
        batch,
        total_requests,
    );
    let results = run_tenant_sweep(&ascend_npu(), &cells, exec)?;
    Ok(format_tenants(&results))
}

/// Format evaluated cluster-grid cells into the `cluster` artifact.
/// Cells must be in `cluster_cells` order (router configuration
/// innermost, in `cluster_row_configs()` order): each artifact row
/// pivots one (replicas, skew, arrival-profile) workload across
/// round-robin, least-loaded, spill-only prefix-affinity,
/// migrate-enabled prefix-affinity, autoscaled prefix-affinity and
/// fault-injected prefix-affinity (one mid-stream crash, recovered).
/// Byte-identical however the cells were evaluated — only their order
/// matters.
pub fn format_cluster(results: &[ClusterCellResult]) -> Artifact {
    let configs = cluster_row_configs();
    assert_eq!(
        results.len() % configs.len(),
        0,
        "cluster results must tile into per-row config groups"
    );
    let mut text = String::new();
    let mut csv = String::from(
        "replicas,skew,rate,burst,round_robin_tok_s,least_loaded_tok_s,\
         prefix_affinity_tok_s,affinity_migrate_tok_s,autoscale_tok_s,\
         affinity_vs_round_robin,migrate_vs_spill,autoscale_vs_fixed,spills,\
         migrations,scale_ups,scale_downs,affinity_ttft_p99_s,\
         affinity_tpot_p99_s,affinity_makespan_s,fault_tok_s,fault_vs_migrate,\
         crashes,failovers,requeued,lost_pages,recovery_p99_s\n",
    );
    writeln!(
        text,
        "{:>8} {:>5} {:>7} {:>14} {:>14} {:>14} {:>14} {:>14} {:>14} {:>7} {:>7} \
         {:>7} {:>7} {:>7} {:>5} {:>5} {:>11}",
        "replicas", "skew", "profile", "rrobin tok/s", "least-ld tok/s",
        "affinity tok/s", "aff+mig tok/s", "autoscale t/s", "fault tok/s", "aff/rr",
        "mig/aff", "auto/mig", "flt/mig", "spills", "migs", "+/-", "ttft p99"
    )
    .unwrap();
    for row in results.chunks(configs.len()) {
        // Hard assert: a mis-ordered grid would silently swap policy
        // columns (and invert the speedups) in release builds otherwise.
        for (cell, &(router, migrate, autoscale, fault)) in row.iter().zip(&configs) {
            assert_eq!(
                (cell.cell.router, cell.cell.migrate, cell.cell.autoscale, cell.cell.fault),
                (router, migrate, autoscale, fault),
                "rows must pivot in cluster_row_configs() order"
            );
        }
        let c = &row[0].cell;
        let (rate, burst) = c.arrival.unwrap_or((0.0, 1.0));
        let profile = match c.arrival {
            None => "batch",
            Some((_, f)) if f > 1.0 => "bursty",
            Some(_) => "poisson",
        };
        let [rr, ll, aff, mig, auto, fault] = [
            &row[0].report,
            &row[1].report,
            &row[2].report,
            &row[3].report,
            &row[4].report,
            &row[5].report,
        ];
        let speedup = if rr.goodput > 0.0 { aff.goodput / rr.goodput } else { 1.0 };
        let mig_speedup = if aff.goodput > 0.0 { mig.goodput / aff.goodput } else { 1.0 };
        let auto_speedup =
            if mig.goodput > 0.0 { auto.goodput / mig.goodput } else { 1.0 };
        let fault_ratio =
            if mig.goodput > 0.0 { fault.goodput / mig.goodput } else { 1.0 };
        writeln!(
            text,
            "{:>8} {:>5.1} {:>7} {:>14.0} {:>14.0} {:>14.0} {:>14.0} {:>14.0} \
             {:>14.0} {:>6.2}x {:>6.2}x {:>6.2}x {:>6.2}x {:>7} {:>5} {:>2}/{:<2} \
             {:>10.3}s",
            c.replicas,
            c.skew,
            profile,
            rr.goodput,
            ll.goodput,
            aff.goodput,
            mig.goodput,
            auto.goodput,
            fault.goodput,
            speedup,
            mig_speedup,
            auto_speedup,
            fault_ratio,
            aff.spills,
            mig.migrations,
            auto.scale_ups,
            auto.scale_downs,
            aff.ttft_p99
        )
        .unwrap();
        writeln!(
            csv,
            "{},{:.1},{:.1},{:.1},{:.1},{:.1},{:.1},{:.1},{:.1},{:.3},{:.3},{:.3},{},{},\
             {},{},{:.4},{:.5},{:.3},{:.1},{:.3},{},{},{},{},{:.4}",
            c.replicas,
            c.skew,
            rate,
            burst,
            rr.goodput,
            ll.goodput,
            aff.goodput,
            mig.goodput,
            auto.goodput,
            speedup,
            mig_speedup,
            auto_speedup,
            aff.spills,
            mig.migrations,
            auto.scale_ups,
            auto.scale_downs,
            aff.ttft_p99,
            aff.tpot_p99,
            aff.makespan,
            fault.goodput,
            fault_ratio,
            fault.crashes,
            fault.failovers,
            fault.requeued_requests,
            fault.lost_pages,
            fault.recovery_p99_s
        )
        .unwrap();
    }
    text.push_str(
        "(goodput = generated tokens per aggregate replica decode second; \
         prefix-affinity concentrates each prefix group's occupancy on the \
         replica holding its pages — spill-only relief scatters a pressured \
         group's overflow one request at a time, while migrate re-homes the \
         group's pages over the interconnect so the overflow stays one \
         group; autoscale additionally resizes the fleet against the \
         observed arrival rate, bulk-migrating hot groups onto fresh \
         replicas and consolidating idle ones; on batch-protocol rows the \
         arrival rate is unobservable and autoscale reproduces the fixed \
         fleet; round-robin pays every group's shared-stage stream on every \
         replica; the fault column injects one mid-stream replica crash into \
         the migrate-enabled fleet — in-flight work re-queues on survivors, \
         dead homes fail over, and goodput degrades gracefully)\n",
    );
    Artifact {
        id: "cluster",
        title: "Prefix-affinity routing across sharded replicas, DeepSeek-v3 (Ascend)"
            .into(),
        text,
        csv,
    }
}

/// `cluster` artifact: the (replicas x skew x arrival-profile x
/// router-config) grid under the sweep executor, one row per
/// (replicas, skew, profile) workload.  Asserts the headlines at the
/// largest fleet and max skew: prefix-affinity models at least
/// round-robin's goodput and migrate-enabled affinity at least
/// spill-only affinity's (batch-protocol row), autoscaled affinity at
/// least the fixed migrate-enabled fleet's (bursty row), and graceful
/// degradation under a single-replica crash — zero requests lost and
/// goodput within a bounded factor of the fault-free fleet.
pub fn fig_cluster(
    max_requests_factor: Option<usize>,
    exec: &SweepExecutor,
) -> Result<Artifact> {
    let batch = 128;
    let total_requests = max_requests_factor.unwrap_or(8) * batch;
    let cells = cluster_cells(
        &deepseek_v3(),
        &CLUSTER_REPLICAS,
        &CLUSTER_SKEWS,
        &CLUSTER_ARRIVALS,
        CLUSTER_TENANTS,
        batch,
        total_requests,
    );
    let results = run_cluster_sweep(&ascend_npu(), &cells, exec)?;
    // The acceptance cells: max replicas x max skew, with columns
    // located by config and rows by workload key rather than position,
    // so a reordered grid cannot silently swap reports.
    let configs = cluster_row_configs();
    let col = |router, migrate, autoscale, fault| {
        configs
            .iter()
            .position(|&c| c == (router, migrate, autoscale, fault))
            .expect("row config present")
    };
    let max_replicas = *CLUSTER_REPLICAS.iter().max().unwrap();
    let max_skew = CLUSTER_SKEWS.iter().cloned().fold(f64::MIN, f64::max);
    let row = |arrival: Option<(f64, f64)>| {
        let start = results
            .iter()
            .position(|r| {
                r.cell.replicas == max_replicas
                    && r.cell.skew == max_skew
                    && r.cell.arrival == arrival
            })
            .expect("acceptance row present");
        &results[start..start + configs.len()]
    };
    let batch_row = row(None);
    let rr = &batch_row[col(RouterPolicy::RoundRobin, false, false, false)].report;
    let aff = &batch_row[col(RouterPolicy::PrefixAffinity, false, false, false)].report;
    let mig = &batch_row[col(RouterPolicy::PrefixAffinity, true, false, false)].report;
    let fault = &batch_row[col(RouterPolicy::PrefixAffinity, true, false, true)].report;
    anyhow::ensure!(
        aff.goodput >= rr.goodput,
        "prefix-affinity must not lose to round-robin on the skewed cell: \
         affinity {} < round-robin {}",
        aff.goodput,
        rr.goodput
    );
    anyhow::ensure!(
        mig.goodput >= aff.goodput,
        "migrate-enabled affinity must not lose to spill-only affinity on the \
         skewed cell: migrate {} < spill-only {}",
        mig.goodput,
        aff.goodput
    );
    anyhow::ensure!(
        fault.crashes == 1,
        "the fault column must deliver its scheduled crash on the {}-replica row",
        max_replicas
    );
    anyhow::ensure!(
        fault.requests_completed == mig.requests_completed,
        "graceful degradation: zero requests lost under a crash ({} vs {})",
        fault.requests_completed,
        mig.requests_completed
    );
    anyhow::ensure!(
        fault.goodput >= 0.25 * mig.goodput,
        "graceful degradation: goodput under a single-replica crash must stay \
         within a bounded factor of fault-free: {} < 0.25 x {}",
        fault.goodput,
        mig.goodput
    );
    let bursty_row = row(CLUSTER_ARRIVALS[1]);
    let fixed = &bursty_row[col(RouterPolicy::PrefixAffinity, true, false, false)].report;
    let auto = &bursty_row[col(RouterPolicy::PrefixAffinity, true, true, false)].report;
    anyhow::ensure!(
        auto.tokens == fixed.tokens,
        "autoscale must serve the same workload: {} vs {} tokens",
        auto.tokens,
        fixed.tokens
    );
    anyhow::ensure!(
        auto.goodput >= fixed.goodput,
        "autoscale must not lose to the fixed fleet on the bursty skewed cell: \
         autoscale {} < fixed {}",
        auto.goodput,
        fixed.goodput
    );
    Ok(format_cluster(&results))
}

/// Fig. 4: latency breakdown, Kimi K2, Ls=4096, Ln=512, B in 128..1024,
/// typhoon vs the absorb-only baseline.
pub fn fig4() -> Artifact {
    let cfg = kimi_k2();
    let hw = ascend_npu();
    let mut text = String::new();
    let mut csv = String::from(
        "batch,kernel,stage1_ms,stage2_ms,wkvb1_ms,wkvb2_ms,combine_ms,total_ms\n",
    );
    writeln!(
        text,
        "{:>6} {:<8} {:>9} {:>9} {:>9} {:>9} {:>10} {:>9}",
        "batch", "kernel", "stage1", "stage2", "Wkvb1", "Wkvb2", "combine", "total"
    )
    .unwrap();
    let fmt_row = |text: &mut String, csv: &mut String, b: usize, name: &str, t: &TimeBreakdown| {
        writeln!(
            text,
            "{:>6} {:<8} {:>7.2}ms {:>7.2}ms {:>7.3}ms {:>7.3}ms {:>8.4}ms {:>7.2}ms",
            b,
            name,
            t.shared * 1e3,
            t.non_shared * 1e3,
            t.proj_kvb1 * 1e3,
            t.proj_kvb2 * 1e3,
            t.combine * 1e3,
            t.total() * 1e3
        )
        .unwrap();
        writeln!(
            csv,
            "{},{},{:.4},{:.4},{:.4},{:.4},{:.5},{:.4}",
            b,
            name,
            t.shared * 1e3,
            t.non_shared * 1e3,
            t.proj_kvb1 * 1e3,
            t.proj_kvb2 * 1e3,
            t.combine * 1e3,
            t.total() * 1e3
        )
        .unwrap();
    };
    for b in [128u64, 256, 512, 1024] {
        let wl = AttentionWorkload::decode(b, 4096, 512);
        let t = time_breakdown(&attention_cost(&cfg, KernelKind::Typhoon, &wl), &hw);
        let a = time_breakdown(&attention_cost(&cfg, KernelKind::Absorb, &wl), &hw);
        fmt_row(&mut text, &mut csv, b as usize, "typhoon", &t);
        fmt_row(&mut text, &mut csv, b as usize, "absorb", &a);
    }
    // The paper's headline check at B=1024.
    let wl = AttentionWorkload::decode(1024, 4096, 512);
    let t = time_breakdown(&attention_cost(&cfg, KernelKind::Typhoon, &wl), &hw);
    let a = time_breakdown(&attention_cost(&cfg, KernelKind::Absorb, &wl), &hw);
    let est_shared_baseline = a.total() - t.non_shared;
    writeln!(
        text,
        "\nB=1024: baseline {:.2}ms, typhoon stage1 {:.2}ms + stage2 {:.2}ms; \
         shared-part ratio {:.2} (paper: 6.43ms, 1.63ms, 1.06ms, ratio 3.3)",
        a.total() * 1e3,
        t.shared * 1e3,
        t.non_shared * 1e3,
        est_shared_baseline / t.shared
    )
    .unwrap();
    Artifact {
        id: "fig4",
        title: "Latency breakdown, Kimi K2, Ls=4096 Ln=512 (Ascend)".into(),
        text,
        csv,
    }
}

/// Fig. 5: HBM footprint, DeepSeek-v3 FP8, CloudMatrix-384.
pub fn fig5() -> Artifact {
    let cfg = deepseek_v3();
    let cl = cloudmatrix_384();
    let shared = 26472; // Prompt A
    let gib = (1u64 << 30) as f64;
    let mut text = String::new();
    let mut csv = String::from(
        "batch,max_seq,absorb_gib,typhoon_gib,overhead_pct\n",
    );
    writeln!(
        text,
        "{:>7} {:>9} {:>13} {:>13} {:>9}",
        "batch", "max_seq", "absorb GiB", "typhoon GiB", "overhead"
    )
    .unwrap();
    for batch in [4096u64, 8192, 16384, 32768] {
        for seq in [32768u64, 65536, 131072, 262144] {
            let base = hbm_footprint(&cfg, &cl, batch, seq, shared, false).total();
            let typ = hbm_footprint(&cfg, &cl, batch, seq, shared, true).total();
            let ov = typhoon_overhead(&cfg, &cl, batch, seq, shared);
            writeln!(
                text,
                "{:>7} {:>9} {:>13.0} {:>13.0} {:>8.2}%",
                batch,
                seq,
                base / gib,
                typ / gib,
                ov * 100.0
            )
            .unwrap();
            writeln!(
                csv,
                "{},{},{:.1},{:.1},{:.3}",
                batch,
                seq,
                base / gib,
                typ / gib,
                ov * 100.0
            )
            .unwrap();
        }
    }
    text.push_str("(paper: overhead limited to ~3% across the grid)\n");
    Artifact {
        id: "fig5",
        title: "HBM footprint, DeepSeek-v3 FP8, Prompt A, 384 NPUs".into(),
        text,
        csv,
    }
}

/// Fig. 6: roofline curves for naive and absorb.
pub fn fig6() -> Artifact {
    let hw = roofline_npu();
    let l_ctx = 4096;
    let batches: Vec<u64> = (0..=12).map(|i| 1u64 << i).collect();
    let mut text = String::new();
    let mut csv =
        String::from("model,kernel,batch,intensity_mac_per_word,throughput_qtok_s,compute_bound\n");
    for model in [deepseek_v3(), kimi_k2()] {
        writeln!(text, "-- {} (L={l_ctx}, {} ) --", model.name, hw.name).unwrap();
        writeln!(
            text,
            "{:>6} {:>22} {:>22}",
            "batch", "naive q-tok/s", "absorb q-tok/s"
        )
        .unwrap();
        for &b in &batches {
            let n = roofline_point(&model, KernelKind::Naive, &hw, b, l_ctx);
            let a = roofline_point(&model, KernelKind::Absorb, &hw, b, l_ctx);
            writeln!(
                text,
                "{:>6} {:>15.0} ({}) {:>15.0} ({})",
                b,
                n.throughput,
                if n.compute_bound { "C" } else { "M" },
                a.throughput,
                if a.compute_bound { "C" } else { "M" },
            )
            .unwrap();
            for (kind, p) in [("naive", n), ("absorb", a)] {
                writeln!(
                    csv,
                    "{},{},{},{:.2},{:.1},{}",
                    model.name, kind, b, p.intensity, p.throughput, p.compute_bound
                )
                .unwrap();
            }
        }
    }
    text.push_str("(C=compute-bound, M=memory-bound; naive ceiling = 3.4x absorb's)\n");
    Artifact {
        id: "fig6",
        title: "Roofline analysis (Appendix A.1)".into(),
        text,
        csv,
    }
}

/// Fig. 7: theoretical execution time vs batch (shared / non-shared /
/// total), naive vs absorb vs typhoon.
pub fn fig7() -> Artifact {
    let cfg = deepseek_v3();
    let hw = ascend_npu();
    let (ls, ln) = (4096u64, 512u64);
    let mut text = String::new();
    let mut csv = String::from("part,batch,naive_ms,absorb_ms,typhoon_ms\n");
    for (part, ls_p, ln_p) in
        [("shared", ls, 0u64), ("non-shared", 0u64, ln), ("total", ls, ln)]
    {
        writeln!(text, "-- {part} part (Ls={ls_p}, Ln={ln_p}) --").unwrap();
        writeln!(
            text,
            "{:>6} {:>12} {:>12} {:>12}",
            "batch", "naive ms", "absorb ms", "typhoon ms"
        )
        .unwrap();
        for b in [1u64, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024] {
            let wl = AttentionWorkload::decode(b, ls_p, ln_p);
            let ms = |k| {
                time_breakdown(&attention_cost(&cfg, k, &wl), &hw).total() * 1e3
            };
            let (n, a, t) = (
                ms(KernelKind::Naive),
                ms(KernelKind::Absorb),
                // Below B_theta=61 typhoon falls back to absorb.
                if b < 61 { ms(KernelKind::Absorb) } else { ms(KernelKind::Typhoon) },
            );
            writeln!(text, "{:>6} {:>12.3} {:>12.3} {:>12.3}", b, n, a, t).unwrap();
            writeln!(csv, "{part},{b},{n:.4},{a:.4},{t:.4}").unwrap();
        }
    }
    Artifact {
        id: "fig7",
        title: "Theoretical analysis (Appendix A.2)".into(),
        text,
        csv,
    }
}

/// Fig. 8: batch-size sensitivity via the serving simulator,
/// DeepSeek-v3, Ls=4096, query length 128.
pub fn fig8() -> Result<Artifact> {
    let cfg = deepseek_v3();
    let hw = ascend_npu();
    let mut text = String::new();
    let mut csv = String::from(
        "batch,part,naive_ms,absorb_ms,typhoon_ms\n",
    );
    writeln!(
        text,
        "{:>6} {:>30} {:>30} {:>30}",
        "batch", "shared (n/a/t) ms", "non-shared (n/a/t) ms", "overall (n/a/t) ms"
    )
    .unwrap();
    for b in [8u64, 16, 32, 64, 128, 256, 512, 1024] {
        let wl_s = AttentionWorkload::decode(b, 4096, 0);
        let wl_n = AttentionWorkload::decode(b, 0, 128);
        let wl_t = AttentionWorkload::decode(b, 4096, 128);
        let part = |wl: &AttentionWorkload, k: KernelKind, fallback: bool| {
            let kind = if fallback && b < 61 { KernelKind::Absorb } else { k };
            time_breakdown(&attention_cost(&cfg, kind, wl), &hw).total() * 1e3
        };
        let row = |wl: &AttentionWorkload| {
            (
                part(wl, KernelKind::Naive, false),
                part(wl, KernelKind::Absorb, false),
                part(wl, KernelKind::Typhoon, true),
            )
        };
        let (sn, sa, st) = row(&wl_s);
        let (nn, na, nt) = row(&wl_n);
        let (tn, ta, tt) = row(&wl_t);
        writeln!(
            text,
            "{:>6} {:>9.2}/{:>8.2}/{:>8.2} {:>10.2}/{:>8.2}/{:>8.2} {:>10.2}/{:>8.2}/{:>8.2}",
            b, sn, sa, st, nn, na, nt, tn, ta, tt
        )
        .unwrap();
        writeln!(csv, "{b},shared,{sn:.3},{sa:.3},{st:.3}").unwrap();
        writeln!(csv, "{b},non-shared,{nn:.3},{na:.3},{nt:.3}").unwrap();
        writeln!(csv, "{b},overall,{tn:.3},{ta:.3},{tt:.3}").unwrap();
    }
    text.push_str(
        "(paper: naive overtakes absorb on the shared part near B=64; absorb \
         always wins the non-shared part; typhoon ~2x faster overall at B=512)\n",
    );
    Ok(Artifact {
        id: "fig8",
        title: "Batch-size sensitivity, DeepSeek-v3 (Ascend)".into(),
        text,
        csv,
    })
}

/// The backends the crossover artifact sweeps (the accelerator grid
/// axis; host-cpu is bench contextualization only and stays out).
pub const CROSSOVER_BACKENDS: [Backend; 2] = [Backend::Npu, Backend::Gpu];

/// Format evaluated crossover-grid cells into the `crossover`
/// artifact: per (backend x model x fallback), the analytic pairwise
/// Eq. 1 threshold next to the numeric crossover of the priced
/// curves, with the per-backend calibration-cell speedups appended.
/// Byte-identical however the cells were evaluated.
pub fn format_crossover(results: &[CrossoverCellResult]) -> Artifact {
    let mut text = String::new();
    let mut csv = String::from(
        "backend,hardware,model,fallback,analytic_exact,analytic,numeric\n",
    );
    writeln!(
        text,
        "{:>7} {:<16} {:<12} {:<12} {:>10} {:>9} {:>8}",
        "backend", "hardware", "model", "fallback", "exact", "analytic", "numeric"
    )
    .unwrap();
    for r in results {
        let c = &r.cell;
        let numeric = r.numeric.map_or_else(|| "-".into(), |n| n.to_string());
        writeln!(
            text,
            "{:>7} {:<16} {:<12} {:<12} {:>10.4} {:>9} {:>8}",
            c.backend.as_str(),
            r.hw_name,
            c.model.name,
            c.fallback.as_str(),
            r.analytic_exact,
            r.analytic,
            numeric
        )
        .unwrap();
        writeln!(
            csv,
            "{},{},{},{},{:.6},{},{}",
            c.backend.as_str(),
            r.hw_name,
            c.model.name,
            c.fallback.as_str(),
            r.analytic_exact,
            r.analytic,
            numeric
        )
        .unwrap();
    }
    writeln!(text).unwrap();
    for backend in CROSSOVER_BACKENDS {
        let cal = calibration_cell(backend);
        writeln!(
            text,
            "calibration cell ({}, Kimi K2, B=1024 Ls=26472 Ln=512): \
             typhoon-over-absorb {:.2}x",
            cal.hw_name, cal.speedup
        )
        .unwrap();
    }
    text.push_str(
        "(analytic = floored pairwise Eq. 1 threshold the registry uses; \
         numeric = first batch where the priced naive-family curve stops \
         losing — brackets analytic within +1 by construction)\n",
    );
    Artifact {
        id: "crossover",
        title: "Per-backend B_theta crossover grid (kernel registry)".into(),
        text,
        csv,
    }
}

/// `crossover` artifact: the per-backend B_theta grid over the paper
/// model pair, classic and AMLA fallbacks, at the Fig. 7 shared length.
pub fn fig_crossover(exec: &SweepExecutor) -> Result<Artifact> {
    let cells = crossover_cells(&CROSSOVER_BACKENDS, &paper_models(), 4096);
    let results = run_crossover_sweep(&cells, exec)?;
    Ok(format_crossover(&results))
}

/// The two throughput figures with paper batch sweeps.
pub fn fig2(max_requests_factor: Option<usize>) -> Result<Artifact> {
    fig_throughput(
        "fig2",
        &ascend_npu(),
        &PAPER_BATCHES,
        max_requests_factor,
        &SweepExecutor::from_env(),
    )
}

pub fn fig3(max_requests_factor: Option<usize>) -> Result<Artifact> {
    fig_throughput(
        "fig3",
        &gpu_h800(),
        &PAPER_BATCHES,
        max_requests_factor,
        &SweepExecutor::from_env(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_shared_ratio_matches_paper() {
        let a = fig4();
        assert!(a.text.contains("ratio 3.3") || a.text.contains("ratio 3.4"), "{}", a.text);
    }

    #[test]
    fn fig5_overhead_band() {
        let a = fig5();
        // Every overhead value below 3.5%.
        for line in a.csv.lines().skip(1) {
            let ov: f64 = line.split(',').last().unwrap().parse().unwrap();
            assert!(ov < 3.5, "{line}");
        }
    }

    #[test]
    fn fig6_absorb_flat_naive_rises() {
        let a = fig6();
        assert!(a.csv.contains("deepseek-v3,naive"));
    }

    #[test]
    fn fig7_has_crossover() {
        let a = fig7();
        // In the shared part at batch 1024 naive must beat absorb.
        let line = a
            .csv
            .lines()
            .find(|l| l.starts_with("shared,1024"))
            .unwrap();
        let f: Vec<f64> =
            line.split(',').skip(1).map(|x| x.parse().unwrap()).collect();
        let (n, abs) = (f[1], f[2]);
        assert!(n < abs, "naive {n} < absorb {abs} at B=1024");
    }

    #[test]
    fn tenants_artifact_shapes_and_wins() {
        let cells = tenant_cells(&deepseek_v3(), &[1, 4], &[2.0], 128, 256);
        let results =
            run_tenant_sweep(&ascend_npu(), &cells, &SweepExecutor::from_env()).unwrap();
        let a = format_tenants(&results);
        assert_eq!(a.id, "tenants");
        assert_eq!(a.csv.lines().count(), 3, "header + 2 rows");
        // The skewed 4-tenant row: grouped typhoon at least matches the
        // best baseline (hot group clears B_theta at batch 128).
        let row = a.csv.lines().last().unwrap();
        assert!(row.starts_with("4,2.0"), "{row}");
        let fields: Vec<&str> = row.split(',').collect();
        let speedup: f64 = fields[5].parse().unwrap();
        assert!(speedup >= 0.99, "grouped typhoon should win: {row}");
        let mixed: u64 = fields[6].parse().unwrap();
        assert!(mixed > 0, "skewed cell must mix kernels: {row}");
    }

    #[test]
    fn cluster_artifact_shapes_and_affinity_wins() {
        // A small slice of the cluster grid: the skewed 2-replica row,
        // batch protocol only (autoscale holds there — lambda is
        // unobservable — so the column reproduces the fixed fleet).
        let cells = cluster_cells(&deepseek_v3(), &[2], &[2.0], &[None], 4, 128, 256);
        let results =
            run_cluster_sweep(&ascend_npu(), &cells, &SweepExecutor::from_env()).unwrap();
        let a = format_cluster(&results);
        assert_eq!(a.id, "cluster");
        assert_eq!(a.csv.lines().count(), 2, "header + 1 row");
        let row = a.csv.lines().last().unwrap();
        assert!(row.starts_with("2,2.0,0.0,1.0"), "{row}");
        let fields: Vec<&str> = row.split(',').collect();
        let speedup: f64 = fields[9].parse().unwrap();
        assert!(
            speedup >= 0.999,
            "prefix-affinity must at least match round-robin: {row}"
        );
        let mig_speedup: f64 = fields[10].parse().unwrap();
        assert!(
            mig_speedup >= 0.999,
            "migrate-enabled affinity must at least match spill-only: {row}"
        );
        let auto_speedup: f64 = fields[11].parse().unwrap();
        assert!(
            (auto_speedup - 1.0).abs() < 1e-9,
            "never-triggered autoscale reproduces the fixed fleet: {row}"
        );
        let scale_events: u64 =
            fields[14].parse::<u64>().unwrap() + fields[15].parse::<u64>().unwrap();
        assert_eq!(scale_events, 0, "batch protocol never scales: {row}");
        // Same workload under every fault-free router config: identical
        // tokens.  The fault column redoes whatever the crash threw
        // away, so its total is the baseline plus the lost tokens.
        for r in &results[1..] {
            if r.cell.fault {
                continue;
            }
            assert_eq!(results[0].report.tokens, r.report.tokens);
        }
        let fault = &results.last().unwrap().report;
        assert_eq!(fault.crashes, 1, "fault column crashes one replica: {row}");
        assert_eq!(
            fault.requests_completed, results[0].report.requests_completed,
            "crash recovery loses zero requests: {row}"
        );
        assert_eq!(
            fault.tokens,
            results[0].report.tokens + fault.lost_tokens,
            "crashed work is redone exactly once: {row}"
        );
        let csv_crashes: u64 = fields[21].parse().unwrap();
        assert_eq!(csv_crashes, 1, "fault CSV column records the crash: {row}");
    }

    /// The crossover artifact pins the per-backend thresholds and the
    /// calibration-speedup ordering the backend presets are tuned for.
    #[test]
    fn crossover_artifact_pins_backend_thresholds() {
        let a = fig_crossover(&SweepExecutor::from_env()).unwrap();
        assert_eq!(a.id, "crossover");
        // 2 backends x 2 models x 2 fallbacks + header.
        assert_eq!(a.csv.lines().count(), 9);
        let pinned = [
            ("npu,ascend-npu,deepseek-v3,absorb,", ",61,62"),
            ("npu,ascend-npu,deepseek-v3,amla-absorb,", ",70,71"),
            ("gpu,gpu-h800-decode,deepseek-v3,absorb,", ",29,30"),
            ("gpu,gpu-h800-decode,deepseek-v3,amla-absorb,", ",33,34"),
        ];
        for (prefix, suffix) in pinned {
            assert!(
                a.csv
                    .lines()
                    .any(|l| l.starts_with(prefix) && l.ends_with(suffix)),
                "missing pinned row {prefix}..{suffix} in\n{}",
                a.csv
            );
        }
        assert!(a.text.contains("calibration cell (ascend-npu"), "{}", a.text);
        assert!(a.text.contains("calibration cell (gpu-h800-decode"), "{}", a.text);
    }

    #[test]
    fn fig2_small_slice_shapes() {
        // One cell only (batch 64, capped) to keep the test fast.
        let a = fig_throughput(
            "fig2",
            &ascend_npu(),
            &[64],
            Some(2),
            &crate::simulator::SweepExecutor::from_env(),
        )
        .unwrap();
        assert!(a.csv.lines().count() > 10);
        // typhoon >= best baseline (speedup >= ~1) everywhere at B=64
        // with prompt A.
        for line in a.csv.lines().skip(1).filter(|l| l.contains("prompt-a")) {
            let speedup: f64 = line.split(',').last().unwrap().parse().unwrap();
            assert!(speedup > 0.95, "{line}");
        }
    }
}
