//! Table 1 (computational analysis), Eq. 1 (B_theta) and Table 3 (TGR).

use std::fmt::Write as _;

use anyhow::Result;

use crate::config::hardware::{ascend_npu, gpu_h800};
use crate::config::model::{deepseek_v3, kimi_k2};
use crate::config::KernelKind;
use crate::costmodel::flops::{attention_cost, AttentionWorkload};
use crate::costmodel::threshold::{batch_threshold, batch_threshold_exact};
use crate::simulator::{gpu_h800_calibrated, tgr_row};
use crate::workload::datasets::mmlu;
use crate::workload::prompts::all_prompts;

use super::Artifact;

/// Table 1: per-kernel MAC / HBM formulas with DeepSeek-v3 constants.
pub fn table1() -> Artifact {
    let cfg = deepseek_v3();
    let ki = 1024.0;
    let mut text = String::new();
    let mut csv = String::from("kernel,mac_shared_ki,mac_nonshared_ki,hbm_shared_ki,hbm_nonshared_ki\n");
    writeln!(text, "DeepSeek-v3 constants (x1024, per token):").unwrap();
    writeln!(
        text,
        "  naive factor  H*(Dqk+Dv)  = {:>6.2} Ki   (paper: 40)",
        cfg.naive_factor() as f64 / ki
    )
    .unwrap();
    writeln!(
        text,
        "  absorb factor H*(2Dl+Dr)  = {:>6.2} Ki   (paper: 136)",
        cfg.absorb_factor() as f64 / ki
    )
    .unwrap();
    writeln!(
        text,
        "  latent words  Dl+Dr       = {:>6.4} Ki   (paper: 0.56)",
        cfg.latent_words() as f64 / ki
    )
    .unwrap();
    writeln!(text).unwrap();
    writeln!(
        text,
        "{:<10} {:>14} {:>16} {:>14} {:>16}",
        "kernel", "MAC shared", "MAC non-shared", "HBM shared", "HBM non-shared"
    )
    .unwrap();
    // Unit workload (B=1, Ls=1, Ln=1) exposes the per-token factors.
    let wl = AttentionWorkload::decode(1, 1, 1);
    for kind in KernelKind::all() {
        let c = attention_cost(&cfg, kind, &wl);
        writeln!(
            text,
            "{:<10} {:>11.2} Ki {:>13.2} Ki {:>11.4} Ki {:>13.4} Ki",
            kind.as_str(),
            c.shared.macs as f64 / ki,
            c.non_shared.macs as f64 / ki,
            c.shared.hbm_words as f64 / ki,
            c.non_shared.hbm_words as f64 / ki,
        )
        .unwrap();
        writeln!(
            csv,
            "{},{},{},{},{}",
            kind.as_str(),
            c.shared.macs as f64 / ki,
            c.non_shared.macs as f64 / ki,
            c.shared.hbm_words as f64 / ki,
            c.non_shared.hbm_words as f64 / ki,
        )
        .unwrap();
    }
    Artifact {
        id: "table1",
        title: "Computational analysis (MAC & HBM, DeepSeek-v3 x1024)".into(),
        text,
        csv,
    }
}

/// Eq. 1: B_theta on the paper's hardware points.
pub fn eq1() -> Artifact {
    let mut text = String::new();
    let mut csv = String::from("model,hardware,b_theta_exact,b_theta\n");
    for cfg in [deepseek_v3(), kimi_k2()] {
        for hw in [ascend_npu(), gpu_h800()] {
            let exact = batch_threshold_exact(&cfg, &hw, 1);
            let b = batch_threshold(&cfg, &hw, 1);
            writeln!(
                text,
                "{:<12} on {:<12}: B_theta = {:>6.2} -> {}",
                cfg.name, hw.name, exact, b
            )
            .unwrap();
            writeln!(csv, "{},{},{},{}", cfg.name, hw.name, exact, b).unwrap();
        }
    }
    text.push_str("(paper: B_theta = 61 for DeepSeek-v3 on the Ascend NPU)\n");
    Artifact { id: "eq1", title: "Fall-back batch threshold (Eq. 1)".into(), text, csv }
}

/// Table 3: end-to-end TGR for DeepSeek-v3, MMLU, batch 128/GPU.
pub fn table3(max_requests: Option<usize>) -> Result<Artifact> {
    let model = deepseek_v3();
    let hw = gpu_h800_calibrated();
    let ds = mmlu();
    let mut text = String::new();
    let mut csv = String::from(
        "prompt,base_attn_ms,base_total_ms,base_tgr,typhoon_attn_ms,typhoon_total_ms,typhoon_tgr,speedup\n",
    );
    writeln!(
        text,
        "{:<10} | {:>9} {:>9} {:>7} | {:>9} {:>9} {:>7} | {:>7}",
        "", "attn ms", "total ms", "TGR", "attn ms", "total ms", "TGR", "speedup"
    )
    .unwrap();
    writeln!(text, "{:<10} | {:^27} | {:^27} |", "", "FlashMLA (absorb)", "TyphoonMLA").unwrap();
    for prompt in all_prompts() {
        let row = tgr_row(&model, &hw, &ds, &prompt, 128, max_requests)?;
        let speedup = row.typhoon.tgr_ktok_s / row.baseline.tgr_ktok_s;
        writeln!(
            text,
            "{:<10} | {:>9.1} {:>9.1} {:>7.2} | {:>9.1} {:>9.1} {:>7.2} | {:>6.2}x",
            prompt.name,
            row.baseline.attention_ms,
            row.baseline.total_ms,
            row.baseline.tgr_ktok_s,
            row.typhoon.attention_ms,
            row.typhoon.total_ms,
            row.typhoon.tgr_ktok_s,
            speedup
        )
        .unwrap();
        writeln!(
            csv,
            "{},{:.2},{:.2},{:.3},{:.2},{:.2},{:.3},{:.3}",
            prompt.name,
            row.baseline.attention_ms,
            row.baseline.total_ms,
            row.baseline.tgr_ktok_s,
            row.typhoon.attention_ms,
            row.typhoon.total_ms,
            row.typhoon.tgr_ktok_s,
            speedup
        )
        .unwrap();
    }
    text.push_str("(paper prompt-A row: 99.1 / 127.2 / 1.01 vs 58.1 / 86.3 / 1.48 -> 1.48x)\n");
    Ok(Artifact {
        id: "table3",
        title: "Token generation rate, DeepSeek-v3 + MMLU, B=128/GPU".into(),
        text,
        csv,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_renders_paper_constants() {
        let a = table1();
        assert!(a.text.contains("40.00 Ki"));
        assert!(a.text.contains("136.00 Ki"));
        // Header + one row per registry kernel (5 since the AMLA pair).
        assert!(a.csv.lines().count() == 1 + KernelKind::all().len());
        assert!(a.csv.contains("amla-absorb,"));
        assert!(a.csv.contains("typhoon-amla,"));
    }

    #[test]
    fn eq1_contains_61() {
        let a = eq1();
        assert!(a.text.contains("B_theta =  61.44 -> 61"), "{}", a.text);
    }

    #[test]
    fn table3_speedups_in_paper_band() {
        let a = table3(Some(256)).unwrap();
        // Prompt-A speedup between 1.2x and 1.8x (paper: 1.48x).
        let row_a = a.csv.lines().nth(1).unwrap();
        let speedup: f64 = row_a.split(',').last().unwrap().parse().unwrap();
        assert!(speedup > 1.2 && speedup < 1.8, "prompt-A speedup {speedup}");
    }
}
