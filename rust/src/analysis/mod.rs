//! Regeneration of every table and figure in the paper's evaluation
//! (the per-experiment index of DESIGN.md §3).  Each function returns
//! the rows as CSV-ish records plus a pretty-printed block; the
//! `figures` binary writes them under `target/figures/`.

pub mod figures;
pub mod tables;

/// A regenerated artifact: a text block + machine-readable CSV.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub id: &'static str,
    pub title: String,
    pub text: String,
    pub csv: String,
}

impl Artifact {
    pub fn print(&self) {
        println!("==== {} — {} ====", self.id, self.title);
        println!("{}", self.text);
    }

    pub fn write(&self, dir: &std::path::Path) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{}.txt", self.id)), &self.text)?;
        std::fs::write(dir.join(format!("{}.csv", self.id)), &self.csv)?;
        Ok(())
    }
}
