//! Deterministic PRNG (xoshiro256**), self-contained because the crate
//! registry is offline in this build environment.
//!
//! Used everywhere randomness is needed — workload sampling, property
//! tests, simulator arrivals — so every run is reproducible from a seed.

/// xoshiro256** by Blackman & Vigna (public domain reference impl).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64, per the xoshiro authors' recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [lo, hi) — panics if lo >= hi.
    pub fn gen_range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range [{lo}, {hi})");
        // Lemire's method without the rejection refinement is fine here:
        // ranges are tiny relative to 2^64, bias is negligible (< 2^-40).
        lo + (((self.next_u64() as u128 * (hi - lo) as u128) >> 64) as u64)
    }

    pub fn gen_range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.gen_range(lo as u64, hi as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn next_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given underlying mu/sigma.
    pub fn next_lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.next_normal()).exp()
    }

    /// Exponential with the given rate (mean 1/rate).
    pub fn next_exp(&mut self, rate: f64) -> f64 {
        -(1.0 - self.next_f64()).ln() / rate
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range_usize(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range_usize(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.gen_range(5, 15);
            assert!((5..15).contains(&x));
            seen[(x - 5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all values in range reachable");
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
