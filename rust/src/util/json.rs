//! Minimal JSON parser + writer (offline environment: no serde).
//!
//! Covers the subset the project needs: the AOT `manifest.json`
//! (objects, arrays, strings, numbers, bools, null) and report/figure
//! emission.  Not a general-purpose library — no \u escapes beyond
//! BMP passthrough, no arbitrary-precision numbers.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- accessors -------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- construction helpers -------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<T: Into<f64>>(n: T) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ---- emit ------------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing garbage at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow!("unexpected end of input"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            bail!("expected {:?} at byte {}, got {:?}", b as char, self.pos - 1, got as char);
        }
        Ok(())
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("unexpected end of input"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected byte {:?} at {}", c as char, self.pos),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(m)),
                c => bail!("expected ',' or '}}' got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(v)),
                c => bail!("expected ',' or ']' got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'n' => s.push('\n'),
                    b't' => s.push('\t'),
                    b'r' => s.push('\r'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16).ok_or_else(|| anyhow!("bad \\u escape"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    c => bail!("bad escape \\{:?}", c as char),
                },
                c if c < 0x80 => s.push(c as char),
                c => {
                    // Re-decode multi-byte UTF-8 starting at pos-1.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    let chunk = &self.bytes[start..start + len];
                    s.push_str(std::str::from_utf8(chunk).map_err(|e| anyhow!(e))?);
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number {text:?}: {e}"))?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let j = Json::obj(vec![
            ("name", Json::str("attn_typhoon")),
            ("dims", Json::obj(vec![("b", Json::num(64.0)), ("ls", Json::num(1024.0))])),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
            ("xs", Json::arr([Json::num(1.0), Json::num(2.5), Json::num(-3.0)])),
        ]);
        let text = j.to_string();
        let back = parse(&text).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_nested_manifest_like() {
        let text = r#"{
          "version": 1,
          "artifacts": [
            {"name": "a", "inputs": [{"shape": [4, 8, 16], "dtype": "f32"}]},
            {"name": "b", "inputs": []}
          ]
        }"#;
        let j = parse(text).unwrap();
        assert_eq!(j.req("version").unwrap().as_usize(), Some(1));
        let arts = j.req("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 2);
        let shape: Vec<usize> = arts[0].req("inputs").unwrap().as_arr().unwrap()[0]
            .req("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![4, 8, 16]);
    }

    #[test]
    fn string_escapes() {
        let j = parse(r#""a\n\"b\"A\\""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\n\"b\"A\\");
        let back = parse(&j.to_string()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn unicode_passthrough() {
        let j = parse("\"héllo — 世界\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo — 世界");
    }

    #[test]
    fn numbers() {
        for (text, want) in [("0", 0.0), ("-12", -12.0), ("3.5", 3.5), ("1e3", 1000.0),
                             ("-2.5e-2", -0.025)] {
            assert_eq!(parse(text).unwrap().as_f64().unwrap(), want, "{text}");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nope").is_err());
        assert!(parse("{} x").is_err());
    }

    #[test]
    fn integers_emit_without_decimal_point() {
        assert_eq!(Json::num(64.0).to_string(), "64");
        assert_eq!(Json::num(2.5).to_string(), "2.5");
    }
}
