//! Deterministic iteration over unordered collections (DESIGN.md §18).
//!
//! `HashMap`/`HashSet` iteration order is unspecified and varies run to
//! run, so any traversal that feeds a report, an artifact, a migration
//! decision, or a float accumulation must be sorted first.  These
//! helpers are the sanctioned route the `detlint` gate
//! (`tools/detlint`) recognizes: collect, sort by key, return —
//! O(n log n) on fleet-sized maps, which is negligible next to the
//! machine-checkable determinism it buys.

use std::collections::{HashMap, HashSet};
use std::hash::BuildHasher;

/// Key-sorted `(key, value)` pairs of a map (entries cloned).
pub fn sorted_pairs<K, V, S>(m: &HashMap<K, V, S>) -> Vec<(K, V)>
where
    K: Ord + Clone,
    V: Clone,
    S: BuildHasher,
{
    let mut v: Vec<(K, V)> = m.iter().map(|(k, val)| (k.clone(), val.clone())).collect();
    v.sort_by(|a, b| a.0.cmp(&b.0));
    v
}

/// Sorted keys of a map.
pub fn sorted_keys<K, V, S>(m: &HashMap<K, V, S>) -> Vec<K>
where
    K: Ord + Clone,
    S: BuildHasher,
{
    let mut v: Vec<K> = m.keys().cloned().collect();
    v.sort();
    v
}

/// Sorted members of a set.
pub fn sorted_members<T, S>(s: &HashSet<T, S>) -> Vec<T>
where
    T: Ord + Clone,
    S: BuildHasher,
{
    let mut v: Vec<T> = s.iter().cloned().collect();
    v.sort();
    v
}

/// Drain a map into key-sorted `(key, value)` pairs, leaving it empty.
pub fn drain_sorted<K, V, S>(m: &mut HashMap<K, V, S>) -> Vec<(K, V)>
where
    K: Ord,
    S: BuildHasher,
{
    let mut v: Vec<(K, V)> = m.drain().collect();
    v.sort_by(|a, b| a.0.cmp(&b.0));
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pairs_and_keys_come_out_key_sorted() {
        let m: HashMap<usize, &str> = [(3, "c"), (1, "a"), (2, "b")].into_iter().collect();
        assert_eq!(sorted_pairs(&m), vec![(1, "a"), (2, "b"), (3, "c")]);
        assert_eq!(sorted_keys(&m), vec![1, 2, 3]);
    }

    #[test]
    fn members_come_out_sorted() {
        let s: HashSet<u64> = [9, 4, 7].into_iter().collect();
        assert_eq!(sorted_members(&s), vec![4, 7, 9]);
    }

    #[test]
    fn drain_sorts_and_empties() {
        let mut m: HashMap<u32, u32> = [(5, 50), (2, 20)].into_iter().collect();
        assert_eq!(drain_sorted(&mut m), vec![(2, 20), (5, 50)]);
        assert!(m.is_empty());
    }
}
