//! Small statistics helpers for metrics and the bench harness.

/// Online mean/variance (Welford) plus min/max.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact nearest-rank percentile over a **pre-sorted** buffer: the
/// smallest element whose cumulative rank covers `q`% of the sample
/// (rank `ceil(q/100 * n)`, 1-based).  Unlike the interpolated
/// `Percentiles::percentile`, the result is always an element of the
/// sample — the convention tail-latency SLOs (p95/p99) are quoted in.
/// `q = 0` returns the minimum; an empty buffer returns NaN.
pub fn percentile_nearest_rank(sorted: &[f64], q: f64) -> f64 {
    assert!((0.0..=100.0).contains(&q), "percentile out of range: {q}");
    if sorted.is_empty() {
        return f64::NAN;
    }
    let n = sorted.len();
    let rank = (q / 100.0 * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

/// Median via nearest rank (lower median for even n).
pub fn p50(sorted: &[f64]) -> f64 {
    percentile_nearest_rank(sorted, 50.0)
}

pub fn p95(sorted: &[f64]) -> f64 {
    percentile_nearest_rank(sorted, 95.0)
}

pub fn p99(sorted: &[f64]) -> f64 {
    percentile_nearest_rank(sorted, 99.0)
}

/// Percentile over a sample set (kept in full; sizes here are small).
#[derive(Clone, Debug, Default)]
pub struct Percentiles {
    xs: Vec<f64>,
    sorted: bool,
}

impl Percentiles {
    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Linear-interpolated percentile, q in [0, 100].
    pub fn percentile(&mut self, q: f64) -> f64 {
        assert!((0.0..=100.0).contains(&q));
        if self.xs.is_empty() {
            return f64::NAN;
        }
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let rank = q / 100.0 * (self.xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            self.xs[lo]
        } else {
            let w = rank - lo as f64;
            self.xs[lo] * (1.0 - w) + self.xs[hi] * w
        }
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    /// Exact nearest-rank percentile of the sample (sorts on demand).
    pub fn nearest_rank(&mut self, q: f64) -> f64 {
        percentile_nearest_rank(self.sorted_values(), q)
    }

    /// The raw sample values, in push order (cluster reports merge the
    /// per-replica buffers before ranking).
    pub fn values(&self) -> &[f64] {
        &self.xs
    }

    /// The sample values, sorted ascending.
    pub fn sorted_values(&mut self) -> &[f64] {
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        &self.xs
    }
}

/// Pretty-print a quantity of bytes.
pub fn human_bytes(b: f64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut x = b;
    let mut u = 0;
    while x >= 1024.0 && u < UNITS.len() - 1 {
        x /= 1024.0;
        u += 1;
    }
    format!("{x:.2} {}", UNITS[u])
}

/// Pretty-print a duration given in seconds.
pub fn human_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.2} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_matches_closed_form() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let mut p = Percentiles::default();
        for x in 1..=100 {
            p.push(x as f64);
        }
        assert!((p.percentile(0.0) - 1.0).abs() < 1e-12);
        assert!((p.percentile(100.0) - 100.0).abs() < 1e-12);
        assert!((p.median() - 50.5).abs() < 1e-12);
        assert!((p.percentile(99.0) - 99.01).abs() < 1e-9);
    }

    #[test]
    fn nearest_rank_singleton() {
        // n = 1: every percentile is the lone sample.
        let xs = [7.5];
        for q in [0.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(percentile_nearest_rank(&xs, q), 7.5, "q={q}");
        }
    }

    #[test]
    fn nearest_rank_two_elements() {
        // n = 2: rank ceil(q/100 * 2) — p50 is the lower element (rank
        // 1), everything above 50% is the upper one.
        let xs = [1.0, 2.0];
        assert_eq!(p50(&xs), 1.0);
        assert_eq!(percentile_nearest_rank(&xs, 50.1), 2.0);
        assert_eq!(p95(&xs), 2.0);
        assert_eq!(p99(&xs), 2.0);
        assert_eq!(percentile_nearest_rank(&xs, 0.0), 1.0);
        assert_eq!(percentile_nearest_rank(&xs, 100.0), 2.0);
    }

    #[test]
    fn nearest_rank_ties_and_all_equal() {
        let ties = [1.0, 2.0, 2.0, 2.0, 9.0];
        assert_eq!(p50(&ties), 2.0); // rank ceil(2.5) = 3
        assert_eq!(p95(&ties), 9.0); // rank ceil(4.75) = 5
        let equal = [4.0; 8];
        for q in [0.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(percentile_nearest_rank(&equal, q), 4.0, "q={q}");
        }
    }

    #[test]
    fn nearest_rank_is_always_a_sample_element() {
        let xs: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        // Nearest rank on 1..=100: pXX is exactly element XX.
        assert_eq!(p50(&xs), 50.0);
        assert_eq!(p95(&xs), 95.0);
        assert_eq!(p99(&xs), 99.0);
        assert!(percentile_nearest_rank(&[], 50.0).is_nan());
    }

    /// Empty-sample behavior, documented and pinned: every percentile
    /// form returns NaN on an empty buffer (a cluster report with zero
    /// completed requests must not panic or fabricate a latency), and
    /// NaN never compares equal — callers must gate on emptiness.
    #[test]
    fn empty_samples_yield_nan_everywhere() {
        for q in [0.0, 50.0, 95.0, 99.0, 100.0] {
            assert!(percentile_nearest_rank(&[], q).is_nan(), "q={q}");
        }
        assert!(p50(&[]).is_nan());
        assert!(p95(&[]).is_nan());
        assert!(p99(&[]).is_nan());
        let mut p = Percentiles::default();
        assert!(p.is_empty());
        assert!(p.percentile(50.0).is_nan());
        assert!(p.median().is_nan());
        assert!(p.nearest_rank(99.0).is_nan());
        assert_eq!(p.sorted_values(), &[] as &[f64]);
        // One push ends the NaN regime.
        p.push(3.25);
        assert_eq!(p.nearest_rank(99.0), 3.25);
    }

    /// Out-of-range percentiles are caller bugs, not NaNs.
    #[test]
    #[should_panic(expected = "percentile out of range")]
    fn out_of_range_percentile_panics() {
        percentile_nearest_rank(&[1.0], 100.5);
    }

    #[test]
    fn percentiles_struct_nearest_rank() {
        let mut p = Percentiles::default();
        for x in [3.0, 1.0, 2.0] {
            p.push(x);
        }
        assert_eq!(p.values().len(), 3);
        assert_eq!(p.nearest_rank(50.0), 2.0);
        assert_eq!(p.sorted_values(), &[1.0, 2.0, 3.0]);
        p.push(0.5); // re-sorts lazily after a push
        assert_eq!(p.nearest_rank(50.0), 1.0);
    }

    #[test]
    fn humanize() {
        assert_eq!(human_bytes(1536.0), "1.50 KiB");
        assert_eq!(human_time(0.0025), "2.50 ms");
    }
}
