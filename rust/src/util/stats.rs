//! Small statistics helpers for metrics and the bench harness.

/// Online mean/variance (Welford) plus min/max.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile over a sample set (kept in full; sizes here are small).
#[derive(Clone, Debug, Default)]
pub struct Percentiles {
    xs: Vec<f64>,
    sorted: bool,
}

impl Percentiles {
    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Linear-interpolated percentile, q in [0, 100].
    pub fn percentile(&mut self, q: f64) -> f64 {
        assert!((0.0..=100.0).contains(&q));
        if self.xs.is_empty() {
            return f64::NAN;
        }
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let rank = q / 100.0 * (self.xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            self.xs[lo]
        } else {
            let w = rank - lo as f64;
            self.xs[lo] * (1.0 - w) + self.xs[hi] * w
        }
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }
}

/// Pretty-print a quantity of bytes.
pub fn human_bytes(b: f64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut x = b;
    let mut u = 0;
    while x >= 1024.0 && u < UNITS.len() - 1 {
        x /= 1024.0;
        u += 1;
    }
    format!("{x:.2} {}", UNITS[u])
}

/// Pretty-print a duration given in seconds.
pub fn human_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.2} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_matches_closed_form() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let mut p = Percentiles::default();
        for x in 1..=100 {
            p.push(x as f64);
        }
        assert!((p.percentile(0.0) - 1.0).abs() < 1e-12);
        assert!((p.percentile(100.0) - 100.0).abs() < 1e-12);
        assert!((p.median() - 50.5).abs() < 1e-12);
        assert!((p.percentile(99.0) - 99.01).abs() < 1e-9);
    }

    #[test]
    fn humanize() {
        assert_eq!(human_bytes(1536.0), "1.50 KiB");
        assert_eq!(human_time(0.0025), "2.50 ms");
    }
}
