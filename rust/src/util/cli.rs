//! Minimal command-line argument parser (offline environment: no clap).
//!
//! Supports `bin <subcommand> [--flag] [--key value] [positional...]`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse process args.  `flag_names` lists options that take NO value.
    pub fn parse(flag_names: &[&str]) -> Result<Args> {
        Self::parse_from(std::env::args().skip(1), flag_names)
    }

    pub fn parse_from<I: IntoIterator<Item = String>>(
        it: I,
        flag_names: &[&str],
    ) -> Result<Args> {
        let mut args = Args::default();
        let mut it = it.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&name) {
                    args.flags.push(name.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("option --{name} requires a value"))?;
                    args.options.insert(name.to_string(), v);
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(a);
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects a number, got {v:?}")),
        }
    }

    /// Like `get_f64`, but rejects NaN/inf and non-positive values —
    /// the validated accessor for rates, targets, and headrooms where a
    /// zero or NaN would silently wedge the simulation.
    pub fn get_positive_f64(&self, name: &str, default: f64) -> Result<f64> {
        let v = self.get_f64(name, default)?;
        if !v.is_finite() || v <= 0.0 {
            bail!("--{name} expects a positive finite number, got {v}");
        }
        Ok(v)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an unsigned integer, got {v:?}")),
        }
    }

    /// Parse "64,128,256" style lists.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|x| {
                    x.trim()
                        .parse()
                        .map_err(|_| anyhow!("--{name}: bad integer {x:?}"))
                })
                .collect(),
        }
    }

    /// Validated enumerated option: the value must be one of `choices`.
    /// Returns the matched candidate (with the `choices` lifetime, so
    /// callers can hold it past `self`), `None` when absent, or an
    /// error naming every candidate on a miss.
    pub fn get_choice<'c>(&self, name: &str, choices: &[&'c str]) -> Result<Option<&'c str>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => choices.iter().find(|c| **c == v).copied().map(Some).ok_or_else(|| {
                anyhow!("--{name}: unknown value {v:?} (expected one of: {})", choices.join("|"))
            }),
        }
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow!("missing required option --{name}"))
    }

    pub fn reject_unknown(&self, known: &[&str]) -> Result<()> {
        for k in self.options.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown option --{k}");
            }
        }
        for f in &self.flags {
            if !known.contains(&f.as_str()) {
                bail!("unknown flag --{f}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str, flags: &[&str]) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from), flags).unwrap()
    }

    #[test]
    fn subcommand_options_flags() {
        let a = parse("serve --port 8080 --verbose --mode=fast input.txt", &["verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("port"), Some("8080"));
        assert_eq!(a.get("mode"), Some("fast"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["input.txt"]);
    }

    #[test]
    fn usize_list() {
        let a = parse("x --batches 64,128,256", &[]);
        assert_eq!(a.get_usize_list("batches", &[1]).unwrap(), vec![64, 128, 256]);
        assert_eq!(a.get_usize_list("other", &[1, 2]).unwrap(), vec![1, 2]);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse_from(["--port".to_string()], &[]).is_err());
    }

    #[test]
    fn positive_f64_rejects_nonpositive_and_nan() {
        for bad in ["0", "-1.5", "NaN", "inf", "-inf"] {
            let a = parse(&format!("x --rate {bad}"), &[]);
            let err = a.get_positive_f64("rate", 1.0).unwrap_err().to_string();
            assert!(err.contains("--rate"), "error must name the flag: {err}");
        }
        let a = parse("x --rate 2.5", &[]);
        assert_eq!(a.get_positive_f64("rate", 1.0).unwrap(), 2.5);
        // The default passes through untouched when the flag is absent.
        assert_eq!(parse("x", &[]).get_positive_f64("rate", 7.0).unwrap(), 7.0);
    }

    #[test]
    fn u64_accessor_parses_and_rejects() {
        let a = parse("x --fault-seed 12345", &[]);
        assert_eq!(a.get_u64("fault-seed", 0).unwrap(), 12345);
        assert_eq!(a.get_u64("absent", 9).unwrap(), 9);
        let bad = parse("x --fault-seed -3", &[]);
        assert!(bad.get_u64("fault-seed", 0).is_err());
        let bad = parse("x --fault-seed abc", &[]);
        assert!(bad.get_u64("fault-seed", 0).is_err());
    }

    #[test]
    fn get_choice_validates_against_candidates() {
        let a = parse("x --backend npu", &[]);
        assert_eq!(a.get_choice("backend", &["npu", "gpu", "cpu"]).unwrap(), Some("npu"));
        // Absent option passes through as None.
        assert_eq!(parse("x", &[]).get_choice("backend", &["npu"]).unwrap(), None);
        // A miss names the flag and lists every candidate.
        let err = parse("x --backend tpu", &[])
            .get_choice("backend", &["npu", "gpu", "cpu"])
            .unwrap_err()
            .to_string();
        assert!(err.contains("--backend"), "must name the flag: {err}");
        assert!(err.contains("tpu"), "must echo the bad value: {err}");
        assert!(err.contains("npu|gpu|cpu"), "must list candidates: {err}");
    }

    #[test]
    fn reject_unknown_works() {
        let a = parse("run --good 1 --bad 2", &[]);
        assert!(a.reject_unknown(&["good"]).is_err());
        assert!(a.reject_unknown(&["good", "bad"]).is_ok());
    }
}
