//! A process-global persistent worker pool (DESIGN.md §17).
//!
//! `ClusterSim::run_parallel` previously re-spawned `std::thread::scope`
//! workers for **every arrival window** — at `--million` scale, ~one
//! million spawn/join cycles fencing a few replica decode steps each.
//! This pool parks its workers on a condvar between jobs instead:
//! dispatching a window is one mutex publish + wakeup, not N thread
//! spawns.
//!
//! ## Handoff protocol
//!
//! A job is published under the state mutex as `(epoch+1, task, limit)`
//! and workers are woken; each worker copies the current job, drains
//! indices from its shared cursor (`fetch_add` work stealing, exactly
//! like the scoped code this replaces), and checks out by decrementing
//! `active`.  The caller blocks until `active == 0`, so by the time
//! [`WorkerPool::run`] returns no worker holds the task reference —
//! that blocking is what makes the internal lifetime erasure of the
//! caller's borrowed closure sound.  Concurrent callers are serialized
//! by a caller-side mutex; `limit` caps how many workers participate
//! (the executor's `threads` semantic).
//!
//! ## Panics and re-entrancy
//!
//! A panicking task is caught in the worker (`catch_unwind`), the first
//! payload is stashed, the remaining workers keep draining, and the
//! caller re-raises it (`resume_unwind`) after the job completes — the
//! same observable behavior as a scoped-thread panic, but the pool
//! survives for the next job.  A `run` issued *from inside* a pool
//! worker (nested parallelism, e.g. a parallel sweep cell whose cluster
//! sim steps replicas) executes inline and serially on that worker —
//! the pool's threads are already saturated, and inlining cannot
//! deadlock.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock, PoisonError};

/// One published job: a borrowed task with its lifetime erased (sound —
/// see module docs), the shared index cursor, and the participation cap.
#[derive(Clone, Copy)]
struct Job {
    task: &'static (dyn Fn(usize) + Sync),
    cursor: &'static AtomicUsize,
    items: usize,
    limit: usize,
}

struct State {
    /// Bumped once per published job; workers wait for it to advance.
    epoch: u64,
    job: Option<Job>,
    /// Participants yet to check out of the current job.
    active: usize,
}

pub struct WorkerPool {
    state: Mutex<State>,
    /// Wakes workers when a job is published.
    work: Condvar,
    /// Wakes the caller when the last participant checks out.
    done: Condvar,
    /// Serializes callers: one job in flight at a time.
    caller: Mutex<()>,
    /// First panic payload of the current job, re-raised by the caller.
    panicked: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    workers: usize,
}

thread_local! {
    /// Set for the lifetime of a pool worker thread: a nested `run`
    /// from task code executes inline instead of re-entering the pool.
    static IN_POOL_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// The process-global pool, spawned lazily on first use with one worker
/// per available core.  Living in a `OnceLock` keeps pool users `Copy`
/// (`SweepExecutor`) and lets every simulator and sweep share the same
/// parked threads.
pub fn global() -> &'static WorkerPool {
    static POOL: OnceLock<&'static WorkerPool> = OnceLock::new();
    *POOL.get_or_init(|| {
        WorkerPool::with_workers(
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        )
    })
}

impl WorkerPool {
    /// A pool with exactly `workers` parked threads (the global pool
    /// sizes this to the machine; tests may build small private pools).
    pub fn with_workers(workers: usize) -> &'static Self {
        // Pools are immortal by design (workers park forever between
        // jobs and die with the process), so leaking the allocation is
        // the honest lifetime — it also gives worker threads a plain
        // `&'static` to borrow.
        let pool: &'static WorkerPool = Box::leak(Box::new(WorkerPool {
            state: Mutex::new(State { epoch: 0, job: None, active: 0 }),
            work: Condvar::new(),
            done: Condvar::new(),
            caller: Mutex::new(()),
            panicked: Mutex::new(None),
            workers: workers.max(1),
        }));
        for worker_id in 0..pool.workers {
            std::thread::Builder::new()
                .name(format!("typhoon-pool-{worker_id}"))
                .spawn(move || pool.worker_loop(worker_id))
                .expect("spawn pool worker");
        }
        pool
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// True when called from inside a pool worker (where a nested
    /// `run` executes inline).
    pub fn on_worker_thread() -> bool {
        IN_POOL_WORKER.with(|f| f.get())
    }

    /// Run `task(i)` for every `i in 0..items` across up to `limit`
    /// pool workers (work-stealing index distribution), blocking until
    /// all indices are done.  Serial cases — `limit <= 1`, one item, or
    /// a nested call from a pool worker — execute inline on the caller.
    /// A task panic is re-raised here after the job drains.
    pub fn run(&self, items: usize, limit: usize, task: &(dyn Fn(usize) + Sync)) {
        if items == 0 {
            return;
        }
        if limit <= 1 || items == 1 || Self::on_worker_thread() {
            for i in 0..items {
                task(i);
            }
            return;
        }
        let _serialize = self.caller.lock().unwrap_or_else(PoisonError::into_inner);
        let cursor = AtomicUsize::new(0);
        // Erase the borrows to 'static for the Job. Sound: this caller
        // blocks below until every participant has checked out, so no
        // worker can touch either reference after `run` returns.
        let task: &'static (dyn Fn(usize) + Sync) =
            unsafe { &*(task as *const (dyn Fn(usize) + Sync)) };
        let cursor_ref: &'static AtomicUsize = unsafe { &*(&cursor as *const AtomicUsize) };
        let participants = self.workers.min(limit);
        {
            // detlint: allow(lock-discipline, caller mutex is the documented outer
            // lock; caller -> state is the pinned order, loom-modeled in
            // tools/loom_models)
            let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
            st.job = Some(Job { task, cursor: cursor_ref, items, limit });
            st.epoch += 1;
            st.active = participants;
            self.work.notify_all();
        }
        // detlint: allow(lock-discipline, caller -> state is the pinned order; the
        // completion wait must hold state while the caller mutex serializes jobs)
        let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        while st.active != 0 {
            st = self.done.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
        st.job = None;
        drop(st);
        // detlint: allow(lock-discipline, caller -> panicked is the pinned order;
        // workers only touch panicked outside state, so this cannot invert)
        let payload = self.panicked.lock().unwrap_or_else(PoisonError::into_inner).take();
        if let Some(p) = payload {
            resume_unwind(p);
        }
    }

    fn worker_loop(&'static self, worker_id: usize) {
        IN_POOL_WORKER.with(|f| f.set(true));
        let mut seen_epoch = 0u64;
        loop {
            let job = {
                let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
                while st.epoch == seen_epoch {
                    st = self.work.wait(st).unwrap_or_else(PoisonError::into_inner);
                }
                seen_epoch = st.epoch;
                st.job
            };
            // A worker above the cap sleeps through the job entirely —
            // it is not counted in `active`, so nobody waits on it.
            let Some(job) = job else { continue };
            if worker_id >= job.limit {
                continue;
            }
            let outcome = catch_unwind(AssertUnwindSafe(|| loop {
                let i = job.cursor.fetch_add(1, Ordering::Relaxed);
                if i >= job.items {
                    break;
                }
                (job.task)(i);
            }));
            if let Err(payload) = outcome {
                let mut slot =
                    self.panicked.lock().unwrap_or_else(PoisonError::into_inner);
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
            let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
            st.active -= 1;
            if st.active == 0 {
                self.done.notify_all();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn runs_every_index_exactly_once() {
        let pool = global();
        let counts: Vec<AtomicUsize> = (0..257).map(|_| AtomicUsize::new(0)).collect();
        pool.run(counts.len(), 8, &|i| {
            counts[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    #[test]
    fn limit_caps_concurrency() {
        let pool = global();
        let in_flight = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        pool.run(64, 2, &|_| {
            let now = in_flight.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_micros(200));
            in_flight.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(peak.load(Ordering::SeqCst) <= 2, "peak {}", peak.load(Ordering::SeqCst));
    }

    #[test]
    fn sequential_jobs_reuse_the_pool() {
        let pool = global();
        for round in 0..50 {
            let sum = AtomicUsize::new(0);
            pool.run(100, 4, &|i| {
                sum.fetch_add(i + round, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), 4950 + 100 * round);
        }
    }

    #[test]
    fn nested_run_executes_inline_without_deadlock() {
        let pool = global();
        let total = AtomicUsize::new(0);
        pool.run(4, 4, &|_| {
            assert!(WorkerPool::on_worker_thread());
            // Nested: must inline on this worker, not re-enter the pool.
            pool.run(10, 4, &|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 40);
    }

    #[test]
    fn task_panic_propagates_and_pool_survives() {
        let pool = global();
        let err = catch_unwind(AssertUnwindSafe(|| {
            pool.run(16, 4, &|i| {
                if i == 7 {
                    panic!("boom at {i}");
                }
            });
        }));
        assert!(err.is_err(), "panic must re-raise in the caller");
        // The pool keeps working after a panicked job.
        let sum = AtomicUsize::new(0);
        pool.run(8, 4, &|i| {
            sum.fetch_add(i, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 28);
    }

    #[test]
    fn serial_paths_inline_on_the_caller() {
        let pool = global();
        let hit = AtomicUsize::new(0);
        pool.run(1, 8, &|i| {
            assert_eq!(i, 0);
            assert!(!WorkerPool::on_worker_thread(), "single item inlines");
            hit.fetch_add(1, Ordering::Relaxed);
        });
        pool.run(5, 1, &|_| {
            assert!(!WorkerPool::on_worker_thread(), "limit 1 inlines");
            hit.fetch_add(1, Ordering::Relaxed);
        });
        pool.run(0, 8, &|_| unreachable!("zero items"));
        assert_eq!(hit.load(Ordering::Relaxed), 6);
    }
}
