//! Tiny criterion-style benchmark harness (offline environment: no
//! criterion crate).  `cargo bench` targets use this via
//! `harness = false` binaries.
//!
//! Protocol per benchmark: warm up for a fixed wall-clock budget, then
//! run measured iterations until both a minimum iteration count and a
//! minimum measuring time are reached; report mean ± std and median.

use std::time::{Duration, Instant};

use super::stats::{human_time, Percentiles, Summary};

pub struct BenchConfig {
    pub warmup: Duration,
    pub min_iters: u32,
    pub min_time: Duration,
    pub max_iters: u32,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(300),
            min_iters: 10,
            min_time: Duration::from_secs(1),
            max_iters: 10_000,
        }
    }
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_s: f64,
    pub std_s: f64,
    pub median_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn report_line(&self) -> String {
        format!(
            "{:<48} {:>12} ± {:<10} (median {:>10}, min {:>10}, n={})",
            self.name,
            human_time(self.mean_s),
            human_time(self.std_s),
            human_time(self.median_s),
            human_time(self.min_s),
            self.iters,
        )
    }
}

pub struct Bench {
    cfg: BenchConfig,
    results: Vec<BenchResult>,
    filter: Option<String>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        // `cargo bench -- <filter>` passes the filter as an argument.
        let filter = std::env::args().skip(1).find(|a| !a.starts_with('-'));
        Bench { cfg: BenchConfig::default(), results: Vec::new(), filter }
    }

    pub fn with_config(cfg: BenchConfig) -> Self {
        let mut b = Self::new();
        b.cfg = cfg;
        b
    }

    pub fn enabled(&self, name: &str) -> bool {
        self.filter.as_deref().map_or(true, |f| name.contains(f))
    }

    /// Measure `f`, which performs ONE logical iteration per call.
    #[allow(clippy::disallowed_methods)]
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> Option<BenchResult> {
        if !self.enabled(name) {
            return None;
        }
        // Warmup.
        // detlint: allow(wall-clock, real-runtime bench harness)
        let start = Instant::now();
        while start.elapsed() < self.cfg.warmup {
            f();
        }
        // Measure.
        let mut summary = Summary::new();
        let mut pct = Percentiles::default();
        // detlint: allow(wall-clock, real-runtime bench harness)
        let measure_start = Instant::now();
        let mut iters = 0u64;
        while (iters < self.cfg.min_iters as u64
            || measure_start.elapsed() < self.cfg.min_time)
            && iters < self.cfg.max_iters as u64
        {
            // detlint: allow(wall-clock, real-runtime bench harness)
            let t0 = Instant::now();
            f();
            let dt = t0.elapsed().as_secs_f64();
            summary.push(dt);
            pct.push(dt);
            iters += 1;
        }
        let result = BenchResult {
            name: name.to_string(),
            iters,
            mean_s: summary.mean(),
            std_s: summary.std(),
            median_s: pct.median(),
            min_s: summary.min(),
        };
        println!("{}", result.report_line());
        self.results.push(result.clone());
        Some(result)
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Write results as JSON for downstream tooling.
    pub fn write_json(&self, path: &str) -> anyhow::Result<()> {
        use super::json::Json;
        let arr = Json::arr(self.results.iter().map(|r| {
            Json::obj(vec![
                ("name", Json::str(r.name.clone())),
                ("iters", Json::num(r.iters as f64)),
                ("mean_s", Json::num(r.mean_s)),
                ("std_s", Json::num(r.std_s)),
                ("median_s", Json::num(r.median_s)),
                ("min_s", Json::num(r.min_s)),
            ])
        }));
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, arr.to_string_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut b = Bench::with_config(BenchConfig {
            warmup: Duration::from_millis(1),
            min_iters: 5,
            min_time: Duration::from_millis(5),
            max_iters: 1000,
        });
        let mut x = 0u64;
        let r = b
            .bench("noop", || {
                x = x.wrapping_add(std::hint::black_box(1));
            })
            .unwrap();
        assert!(r.iters >= 5);
        assert!(r.mean_s >= 0.0);
    }
}
