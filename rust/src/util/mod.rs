//! Self-contained utilities (the crate registry is offline in this
//! build environment, so PRNG / JSON / CLI / bench harness are local).

pub mod bench;
pub mod cli;
pub mod det;
pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;
