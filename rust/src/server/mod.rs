//! Thread-based inference server: a worker thread owns the coordinator
//! + engine; clients submit requests over a channel and receive
//! completions on per-request channels.
//!
//! (The crate registry is offline in this environment, so this is a
//! std-thread + mpsc event loop rather than a tokio service; the
//! architecture — Rust event loop owning a PJRT engine, zero Python on
//! the request path — is identical.)

use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::coordinator::{Coordinator, Engine};
use crate::kvcache::SeqId;
use crate::workload::Request;

/// Completion notification for one request.
#[derive(Clone, Debug)]
pub struct Completion {
    pub request_id: u64,
    pub seq_id: SeqId,
    pub generated_tokens: usize,
    /// End-to-end latency in engine seconds (queue + prefill + decode).
    pub latency: f64,
}

enum Msg {
    Submit { req: Request, reply: Sender<Completion> },
    Shutdown,
}

/// Final run statistics returned at shutdown.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub tokens_generated: u64,
    pub requests_completed: u64,
    pub decode_iterations: u64,
    pub elapsed_seconds: f64,
    pub throughput: f64,
}

pub struct InferenceServer {
    tx: Sender<Msg>,
    handle: Option<JoinHandle<Result<ServerStats>>>,
}

impl InferenceServer {
    /// Start the worker.  `make_coordinator` runs *inside* the worker
    /// thread (PJRT handles are not Send); it must also install the
    /// shared prefix.
    pub fn start<E, F>(make_coordinator: F) -> Self
    where
        E: Engine,
        F: FnOnce() -> Result<Coordinator<E>> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Msg>();
        let handle = std::thread::spawn(move || worker(make_coordinator()?, rx));
        InferenceServer { tx, handle: Some(handle) }
    }

    /// Submit a request; returns the channel the completion arrives on.
    pub fn submit(&self, req: Request) -> Result<Receiver<Completion>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Submit { req, reply })
            .map_err(|_| anyhow!("server is down"))?;
        Ok(rx)
    }

    /// Graceful shutdown: drains in-flight work, returns statistics.
    pub fn shutdown(mut self) -> Result<ServerStats> {
        let _ = self.tx.send(Msg::Shutdown);
        self.handle
            .take()
            .expect("shutdown called once")
            .join()
            .map_err(|_| anyhow!("server thread panicked"))?
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn worker<E: Engine>(
    mut coord: Coordinator<E>,
    rx: Receiver<Msg>,
) -> Result<ServerStats> {
    use std::collections::HashMap;
    let mut replies: HashMap<SeqId, (u64, Sender<Completion>)> = HashMap::new();
    let mut shutting_down = false;
    loop {
        // Drain the mailbox: block briefly when idle, never when busy.
        let has_work = coord.running() > 0 || coord.queued() > 0;
        let first = if has_work || shutting_down {
            rx.try_recv().ok()
        } else {
            match rx.recv_timeout(Duration::from_millis(50)) {
                Ok(m) => Some(m),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => Some(Msg::Shutdown),
            }
        };
        let mut msg = first;
        while let Some(m) = msg {
            match m {
                Msg::Submit { req, reply } => {
                    let seq = coord.submit(&req)?;
                    replies.insert(seq, (req.id, reply));
                }
                Msg::Shutdown => shutting_down = true,
            }
            msg = rx.try_recv().ok();
        }

        let worked = coord.step()?;
        for seq in coord.take_finished() {
            if let Some((request_id, reply)) = replies.remove(&seq) {
                let s = coord.sequence(seq).expect("finished seq exists");
                let _ = reply.send(Completion {
                    request_id,
                    seq_id: seq,
                    generated_tokens: s.generated,
                    latency: s.latency().unwrap_or(0.0),
                });
            }
        }
        if shutting_down && !worked && coord.running() == 0 && coord.queued() == 0 {
            let m = &coord.metrics;
            return Ok(ServerStats {
                tokens_generated: m.tokens_generated,
                requests_completed: m.requests_completed,
                decode_iterations: m.decode_iterations,
                elapsed_seconds: m.elapsed(),
                throughput: m.throughput(),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::sim;
    use crate::config::{KernelKind, ServingConfig};
    use crate::coordinator::engine::NullEngine;
    use crate::coordinator::KernelPolicy;
    use crate::kvcache::KvCacheManager;

    fn start_test_server() -> InferenceServer {
        InferenceServer::start(move || {
            let cfg = ServingConfig {
                block_size: 16,
                max_batch: 4,
                max_seq_len: 256,
                total_blocks: 1024,
                ..Default::default()
            };
            let policy = KernelPolicy::with_threshold(KernelKind::Typhoon, 2);
            let kv = KvCacheManager::new(sim(), cfg.total_blocks, cfg.block_size);
            let mut c = Coordinator::new(
                cfg,
                policy,
                kv,
                NullEngine { prefill_seconds: 0.001, decode_seconds: 0.001 },
            )?;
            c.set_shared_prefix(&(0..64u32).collect::<Vec<_>>())?;
            Ok(c)
        })
    }

    #[test]
    fn serves_concurrent_requests() {
        let server = start_test_server();
        let rxs: Vec<_> = (0..6)
            .map(|i| {
                server
                    .submit(Request { id: i, prompt_tokens: 8, max_new_tokens: 4 })
                    .unwrap()
            })
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let c = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(c.request_id, i as u64);
            assert_eq!(c.generated_tokens, 4);
            assert!(c.latency > 0.0);
        }
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.requests_completed, 6);
        assert_eq!(stats.tokens_generated, 24);
    }

    #[test]
    fn shutdown_drains_inflight() {
        let server = start_test_server();
        let rx = server
            .submit(Request { id: 0, prompt_tokens: 4, max_new_tokens: 8 })
            .unwrap();
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.requests_completed, 1);
        let c = rx.try_recv().unwrap();
        assert_eq!(c.generated_tokens, 8);
    }

    #[test]
    fn drop_without_shutdown_is_clean() {
        let server = start_test_server();
        let _rx = server
            .submit(Request { id: 0, prompt_tokens: 4, max_new_tokens: 2 })
            .unwrap();
        drop(server); // must not hang or panic
    }
}
