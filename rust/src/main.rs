//! `typhoon-mla` — the serving CLI.
//!
//! Subcommands:
//!   serve      run the real tiny-model serving stack on PJRT and a
//!              synthetic workload, reporting latency/throughput
//!   simulate   run a paper-scale serving simulation
//!   threshold  print the Eq. 1 fall-back threshold for a model/hardware
//!   info       show artifact manifest + runtime info

use anyhow::{bail, Result};
use typhoon_mla::config::hardware::{self, Backend, HardwareSpec};
use typhoon_mla::config::model;
use typhoon_mla::config::{KernelKind, ServingConfig};
use typhoon_mla::coordinator::{Coordinator, KernelPolicy};
use typhoon_mla::costmodel::threshold::batch_threshold;
use typhoon_mla::kvcache::KvCacheManager;
use typhoon_mla::costmodel::ParallelismConfig;
use typhoon_mla::runtime::{default_artifacts_dir, Manifest, TinyModelEngine};
use typhoon_mla::simulator::{
    run_cluster_experiment, run_experiment, run_tenant_experiment, ClusterParams, RouterPolicy,
    SimParams, TenantSimParams,
};
use typhoon_mla::util::cli::Args;
use typhoon_mla::workload::{datasets, prompts, Request};

fn main() -> Result<()> {
    let args = Args::parse(&["full", "migrate", "autoscale", "faults"])?;
    match args.subcommand.as_deref() {
        Some("serve") => serve(&args),
        Some("simulate") => simulate(&args),
        Some("threshold") => threshold(&args),
        Some("info") => info(),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand {o:?}");
            }
            eprintln!(
                "usage: typhoon-mla <serve|simulate|threshold|info> [options]\n\
                 serve    --kernel typhoon|absorb|naive --requests N --gen N\n\
                 simulate --model deepseek-v3|kimi-k2 [--hw ascend-npu|gpu | \
                 --backend npu|gpu|cpu] \
                 --kernel K --batch B --dataset mmlu|gsm8k|simpleqa --prompt a|b|c \
                 [--tenants N --skew S]\n\
                 simulate --replicas N --router round-robin|least-loaded|prefix-affinity \
                 [--tenants N --skew S --rate R --burst F --tp N --sp N --migrate \
                 --slo-ttft S --autoscale --scale-headroom H --min-replicas N \
                 --max-replicas N --faults --fault-seed S --crashes N --stalls N \
                 --degradations N --transfer-loss P --degrade-factor F]\n\
                 threshold --model M [--hw H | --backend npu|gpu|cpu]"
            );
            Ok(())
        }
    }
}

/// Resolve the hardware spec from `--hw` (a spec name) or `--backend`
/// (an accelerator preset: npu|gpu|cpu); passing both is a conflict.
/// Absent both, `default_hw` wins — so existing invocations without
/// the new flag stay bit-identical to the old CLI.
fn resolve_hw(args: &Args, default_hw: &str) -> Result<HardwareSpec> {
    let backend = args.get_choice("backend", &["npu", "gpu", "cpu"])?;
    if backend.is_some() && args.get("hw").is_some() {
        bail!("--backend and --hw conflict; pass exactly one");
    }
    match backend {
        Some(name) => Ok(Backend::parse(name)?.preset()),
        None => hardware::by_name(args.get_or("hw", default_hw))
            .ok_or_else(|| anyhow::anyhow!("unknown hardware")),
    }
}

fn serve(args: &Args) -> Result<()> {
    let kernel = KernelKind::parse(args.get_or("kernel", "typhoon"))?;
    let n_requests = args.get_usize("requests", 16)?;
    let gen_tokens = args.get_usize("gen", 8)?;
    let dir = default_artifacts_dir();
    let engine = TinyModelEngine::new(&dir, kernel)?;
    println!("[serve] engine ready (compile {:.2}s)", engine.compile_seconds());
    let cfg = ServingConfig {
        block_size: 16,
        max_batch: 8,
        max_seq_len: 128,
        total_blocks: 2048,
        kernel,
        ..Default::default()
    };
    let policy = KernelPolicy::with_threshold(kernel, 2);
    let kv = KvCacheManager::new(model::tiny(), cfg.total_blocks, cfg.block_size);
    let mut c = Coordinator::new(cfg, policy, kv, engine)?;
    let prompt: Vec<u32> = (0..200u32).map(|i| (i * 31 + 7) % 255 + 1).collect();
    c.set_shared_prefix(&prompt)?;
    for i in 0..n_requests as u64 {
        c.submit(&Request {
            id: i,
            prompt_tokens: 8 + (i as usize % 24),
            max_new_tokens: gen_tokens,
        })?;
    }
    c.run_to_completion()?;
    println!("[serve] {}", c.metrics.report());
    Ok(())
}

fn simulate(args: &Args) -> Result<()> {
    let model = model::by_name(args.get_or("model", "deepseek-v3"))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let hw = resolve_hw(args, "ascend-npu")?;
    let kernel = KernelKind::parse(args.get_or("kernel", "typhoon"))?;
    let batch = args.get_usize("batch", 256)?;
    // Multi-tenant mode: N prefix groups with Zipf(skew) arrivals.
    let tenants = args.get_usize("tenants", 1)?;
    // Cluster mode: N replicas behind a router.  --rate/--tp/--sp also
    // select it (a 1-replica cluster is the single device with timed
    // arrivals and TP/SP sharding) so those flags are never silently
    // dropped by the plain simulation branches.
    let replicas = args.get_usize("replicas", 1)?;
    let cluster_mode = [
        "replicas",
        "router",
        "rate",
        "burst",
        "tp",
        "sp",
        "slo-ttft",
        "scale-headroom",
        "min-replicas",
        "max-replicas",
        "fault-seed",
        "crashes",
        "stalls",
        "degradations",
        "transfer-loss",
        "degrade-factor",
    ]
    .iter()
    .any(|k| args.get(k).is_some())
        || args.flag("migrate")
        || args.flag("autoscale")
        || args.flag("faults");
    if cluster_mode {
        let router = RouterPolicy::parse(args.get_or("router", "prefix-affinity"))?;
        // Cluster mode defaults to a multi-tenant workload (that is
        // what routing concentration is for); --tenants still wins.
        let cluster_tenants = if args.get("tenants").is_some() { tenants } else { 4 };
        let mut p = ClusterParams::new(
            model,
            hw,
            replicas,
            router,
            batch,
            cluster_tenants,
            args.get_f64("skew", 1.0)?,
        );
        p.kernel = kernel;
        p.parallelism = ParallelismConfig {
            tp: args.get_usize("tp", 1)? as u64,
            sp: args.get_usize("sp", 1)? as u64,
        };
        let default_requests =
            if args.flag("full") { batch * replicas * 16 } else { batch * replicas * 4 };
        p.total_requests = args.get_usize("requests", default_requests)?;
        if args.get("rate").is_some() {
            p.arrival_rate = Some(args.get_positive_f64("rate", 1.0)?);
        }
        if args.get("burst").is_some() {
            p.arrival_burst = Some(args.get_positive_f64("burst", 1.0)?);
        }
        p.migrate = args.flag("migrate");
        if args.get("slo-ttft").is_some() {
            p.slo_ttft = Some(args.get_positive_f64("slo-ttft", 1.0)?);
        }
        p.scaling.enabled = args.flag("autoscale");
        if !p.scaling.enabled
            && ["scale-headroom", "min-replicas", "max-replicas"]
                .iter()
                .any(|k| args.get(k).is_some())
        {
            // Same convention as --migrate/--slo-ttft on the wrong
            // router: a knob that would be silently ignored (and skip
            // validation) is a configuration error.
            bail!("--scale-headroom/--min-replicas/--max-replicas need --autoscale");
        }
        p.scaling.headroom = args.get_positive_f64("scale-headroom", p.scaling.headroom)?;
        p.scaling.min_replicas = args.get_usize("min-replicas", p.scaling.min_replicas)?;
        p.scaling.max_replicas = args.get_usize("max-replicas", p.scaling.max_replicas)?;
        p.faults.enabled = args.flag("faults");
        if !p.faults.enabled
            && [
                "fault-seed",
                "crashes",
                "stalls",
                "degradations",
                "transfer-loss",
                "degrade-factor",
            ]
            .iter()
            .any(|k| args.get(k).is_some())
        {
            // Same convention as the scaling knobs: a fault knob that
            // would be silently ignored is a configuration error.
            bail!(
                "--fault-seed/--crashes/--stalls/--degradations/--transfer-loss/\
                 --degrade-factor need --faults"
            );
        }
        if p.faults.enabled {
            // Schedule seed defaults to the workload seed (replay the
            // same traffic under different draws via --fault-seed).
            p.faults.seed = args.get_u64("fault-seed", p.seed)?;
            p.faults.crashes = args.get_usize("crashes", 1)?;
            p.faults.stalls = args.get_usize("stalls", 0)?;
            p.faults.degradations = args.get_usize("degradations", 0)?;
            // Range/NaN checks live in FaultConfig::validate (run by
            // the experiment) so the CLI and sweep share one error.
            p.faults.transfer_loss = args.get_f64("transfer-loss", 0.0)?;
            p.faults.degrade_factor = args.get_f64("degrade-factor", 1.0)?;
        }
        let r = run_cluster_experiment(&p)?;
        println!(
            "[simulate] cluster: {} replicas ({}), {} tenants: {} tokens, {} requests \
             -> goodput {:.0} tok/s/layer over {:.3}s aggregate decode \
             (makespan {:.3}s, spills {}, migrations {}, scale +{}/-{}, \
             {} active at drain)",
            replicas,
            router.as_str(),
            p.tenants,
            r.tokens,
            r.requests_completed,
            r.goodput,
            r.decode_seconds,
            r.makespan,
            r.spills,
            r.migrations,
            r.scale_ups,
            r.scale_downs,
            r.active_replicas
        );
        println!(
            "[simulate] ttft p50/p95/p99 = {:.4}/{:.4}/{:.4}s, \
             tpot p50/p95/p99 = {:.5}/{:.5}/{:.5}s",
            r.ttft_p50, r.ttft_p95, r.ttft_p99, r.tpot_p50, r.tpot_p95, r.tpot_p99
        );
        if !r.spilled_tenants.is_empty() || !r.migrated_tenants.is_empty() {
            // Sorted by tenant id in report() — never HashSet order.
            println!(
                "[simulate] tenant audit: spilled {:?}, migrated {:?}",
                r.spilled_tenants, r.migrated_tenants
            );
        }
        if p.faults.enabled {
            println!(
                "[simulate] faults: {} crashes, {} stalls, {} failovers, \
                 {} re-queued, {} pages lost, {} tokens redone \
                 ({} re-prefilled), retries {} (abandoned {}), \
                 recovery p50/p99 = {:.3}/{:.3}s",
                r.crashes,
                r.stalls,
                r.failovers,
                r.requeued_requests,
                r.lost_pages,
                r.lost_tokens,
                r.reprefilled_tokens,
                r.transfer_retries,
                r.transfers_abandoned,
                r.recovery_p50_s,
                r.recovery_p99_s
            );
        }
        for (i, rep) in r.replicas.iter().enumerate() {
            println!(
                "[simulate]   replica {i} ({}): {} routed, {} tokens, {} groups hosted \
                 ({} imported), mean batch {:.1}, group-iters t/a/n {}/{}/{} (mixed {})",
                rep.state.as_str(),
                rep.routed,
                rep.tokens,
                rep.prefix_groups,
                rep.prefix_imports,
                rep.mean_batch,
                rep.typhoon_iters,
                rep.absorb_iters,
                rep.naive_iters,
                rep.mixed_iters
            );
        }
        return Ok(());
    }
    if tenants > 1 {
        let mut p = TenantSimParams::new(
            model,
            hw,
            kernel,
            batch,
            tenants,
            args.get_f64("skew", 1.0)?,
        );
        // --requests always wins; --full only raises the default budget.
        let default_requests = if args.flag("full") { batch * 16 } else { batch * 4 };
        p.total_requests = args.get_usize("requests", default_requests)?;
        let r = run_tenant_experiment(&p)?;
        println!(
            "[simulate] {} tenants: {} tokens in {:.3}s of modeled decode -> \
             {:.0} tok/s/layer (iters {}, mean batch {:.1}, \
             group-iters t/a/n {}/{}/{}, mixed {})",
            tenants,
            r.tokens,
            r.decode_seconds,
            r.throughput,
            r.iterations,
            r.mean_batch,
            r.typhoon_iters,
            r.absorb_iters,
            r.naive_iters,
            r.mixed_iters
        );
        return Ok(());
    }
    let ds = datasets::by_name(args.get_or("dataset", "mmlu"))
        .ok_or_else(|| anyhow::anyhow!("unknown dataset"))?;
    let prompt = prompts::by_name(args.get_or("prompt", "a"))
        .ok_or_else(|| anyhow::anyhow!("unknown prompt"))?;
    let mut p = SimParams::new(model, hw, kernel, batch);
    if !args.flag("full") {
        p.max_requests = Some(args.get_usize("requests", batch * 4)?);
    }
    let r = run_experiment(&p, &ds, &prompt)?;
    println!(
        "[simulate] {} tokens in {:.3}s of modeled decode -> {:.0} tok/s/layer \
         (iters {}, mean batch {:.1}, typhoon/absorb iters {}/{})",
        r.tokens,
        r.decode_seconds,
        r.throughput,
        r.iterations,
        r.mean_batch,
        r.typhoon_iters,
        r.absorb_iters
    );
    Ok(())
}

fn threshold(args: &Args) -> Result<()> {
    let model = model::by_name(args.get_or("model", "deepseek-v3"))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let hw = resolve_hw(args, "ascend-npu")?;
    println!(
        "B_theta({}, {}) = {}",
        model.name,
        hw.name,
        batch_threshold(&model, &hw, 1)
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from), &[]).unwrap()
    }

    #[test]
    fn backend_flag_resolves_presets_and_rejects_unknown() {
        assert_eq!(resolve_hw(&parse("simulate"), "ascend-npu").unwrap().name, "ascend-npu");
        assert_eq!(
            resolve_hw(&parse("simulate --backend gpu"), "ascend-npu").unwrap().name,
            "gpu-h800-decode"
        );
        assert_eq!(
            resolve_hw(&parse("simulate --backend cpu"), "ascend-npu").unwrap().name,
            "host-cpu"
        );
        // Unknown names are rejected with the candidate list.
        let err = resolve_hw(&parse("simulate --backend tpu"), "ascend-npu")
            .unwrap_err()
            .to_string();
        assert!(err.contains("--backend") && err.contains("npu|gpu|cpu"), "{err}");
        // Passing both selectors is a conflict, not a silent override.
        let err = resolve_hw(&parse("simulate --backend npu --hw gpu-h800"), "ascend-npu")
            .unwrap_err()
            .to_string();
        assert!(err.contains("conflict"), "{err}");
    }

    /// `--backend npu` resolves to the very same spec as the historical
    /// default — every field bit-identical — so adding the flag to a
    /// single-kernel run cannot perturb its results.
    #[test]
    fn backend_npu_is_bit_identical_to_default_hw() {
        let old = resolve_hw(&parse("simulate"), "ascend-npu").unwrap();
        let new = resolve_hw(&parse("simulate --backend npu"), "ascend-npu").unwrap();
        assert_eq!(old.name, new.name);
        assert_eq!(old.peak_ops.to_bits(), new.peak_ops.to_bits());
        assert_eq!(old.hbm_bw.to_bits(), new.hbm_bw.to_bits());
        assert_eq!(old.hbm_bytes, new.hbm_bytes);
        assert_eq!(old.interconnect_bw.to_bits(), new.interconnect_bw.to_bits());
        assert_eq!(old.bytes_per_word.to_bits(), new.bytes_per_word.to_bits());
        assert_eq!(old.compute_efficiency.to_bits(), new.compute_efficiency.to_bits());
        assert_eq!(old.bandwidth_efficiency.to_bits(), new.bandwidth_efficiency.to_bits());
        assert_eq!(old.backend, new.backend);
    }
}

fn info() -> Result<()> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        bail!("no artifacts at {dir:?}; run `make artifacts`");
    }
    let m = Manifest::load(&dir)?;
    println!("artifacts dir: {dir:?}");
    for a in &m.artifacts {
        println!(
            "  {:<44} kind={:<16} inputs={} outputs={}",
            a.name,
            a.kind,
            a.inputs.len(),
            a.outputs.len()
        );
    }
    Ok(())
}
