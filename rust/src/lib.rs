//! # TyphoonMLA
//!
//! A serving-oriented reproduction of *TyphoonMLA: A Mixed Naive-Absorb
//! MLA Kernel For Shared Prefix* (Yüzügüler et al., 2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`): naive, absorb and mixed
//!   (TyphoonMLA) flash-decode attention kernels in Pallas.
//! * **L2** (`python/compile/`): the MLA model graphs, AOT-lowered to
//!   HLO text in `artifacts/`.
//! * **L3** (this crate): a vLLM-style serving runtime — continuous
//!   batching, paged KV-cache with radix-tree prefix sharing, the
//!   naive/absorb kernel-selection policy, a PJRT execution engine for
//!   the AOT artifacts, the paper's analytical cost model, and a
//!   hardware simulator that regenerates every table and figure of the
//!   paper's evaluation.
//!
//! See `DESIGN.md` for the system inventory and per-experiment index.

pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod kvcache;
pub mod metrics;
pub mod policy;
pub mod runtime;
pub mod server;
pub mod simulator;
pub mod util;
pub mod workload;
