//! Hardware specifications for the cost model and simulator.
//!
//! Substitution note (DESIGN.md §6): we have neither an Ascend NPU nor
//! an H800-class GPU; these specs parameterize the paper's own roofline
//! formulas (§3.2, Appendix A.1) which the paper validates against
//! msprof measurements to within a few percent.

/// An accelerator described by its two roofline parameters plus memory.
#[derive(Clone, Debug, PartialEq)]
pub struct HardwareSpec {
    pub name: &'static str,
    /// Peak throughput in *operations*/second as vendors quote it
    /// (multiply and add counted separately).  The cost model divides by
    /// two to get MAC/s — this convention reproduces the paper's
    /// B_theta = 61 exactly.
    pub peak_ops: f64,
    /// HBM bandwidth, bytes/second.
    pub hbm_bw: f64,
    /// HBM capacity, bytes.
    pub hbm_bytes: u64,
    /// Device-to-device interconnect bandwidth, bytes/second (the
    /// per-device share of the scale-up fabric: CloudMatrix unified bus
    /// / NVLink class).  Prices cross-replica page migration in the
    /// cluster simulator.
    pub interconnect_bw: f64,
    /// Bytes per element of the KV-cache/activation dtype (2 = FP16).
    pub bytes_per_word: f64,
    /// Fraction of peak actually achievable by a well-tuned kernel
    /// (MXU/cube utilization ceiling). 1.0 = ideal roofline.
    pub compute_efficiency: f64,
    /// Same for memory streams.
    pub bandwidth_efficiency: f64,
}

impl HardwareSpec {
    /// Achievable MAC throughput (multiply-accumulate per second).
    pub fn macs_per_sec(&self) -> f64 {
        self.peak_ops / 2.0 * self.compute_efficiency
    }

    /// Achievable HBM stream rate in *words* per second.
    pub fn words_per_sec(&self) -> f64 {
        self.hbm_bw / self.bytes_per_word * self.bandwidth_efficiency
    }

    /// Achievable HBM stream rate in bytes per second.
    pub fn effective_bw(&self) -> f64 {
        self.hbm_bw * self.bandwidth_efficiency
    }

    /// Ridge point of the roofline, MACs per word.
    pub fn ridge_intensity(&self) -> f64 {
        self.macs_per_sec() / self.words_per_sec()
    }
}

/// Ascend NPU used in the paper's §4: 376 TOPS FP16, 1.8 TB/s, 64 GB.
pub fn ascend_npu() -> HardwareSpec {
    HardwareSpec {
        name: "ascend-npu",
        peak_ops: 376e12,
        hbm_bw: 1.8e12,
        hbm_bytes: 64 * (1u64 << 30),
        // CloudMatrix-class unified bus, per-NPU share.
        interconnect_bw: 392e9,
        bytes_per_word: 2.0,
        compute_efficiency: 1.0,
        bandwidth_efficiency: 1.0,
    }
}

/// GPU used in the paper's §4: "1 PetaFLOPS/s FP16, 3.3 TB/s" (H800-class).
pub fn gpu_h800() -> HardwareSpec {
    HardwareSpec {
        name: "gpu-h800",
        peak_ops: 1.0e15,
        hbm_bw: 3.3e12,
        hbm_bytes: 80 * (1u64 << 30),
        // H800 NVLink (export-trimmed): 400 GB/s.
        interconnect_bw: 400e9,
        bytes_per_word: 2.0,
        compute_efficiency: 1.0,
        bandwidth_efficiency: 1.0,
    }
}

/// Appendix A.1 roofline figure uses 400 TFLOPS "cube" + 1.8 TB/s.
pub fn roofline_npu() -> HardwareSpec {
    HardwareSpec {
        name: "roofline-npu",
        peak_ops: 400e12,
        hbm_bw: 1.8e12,
        hbm_bytes: 64 * (1u64 << 30),
        interconnect_bw: 392e9,
        bytes_per_word: 2.0,
        compute_efficiency: 1.0,
        bandwidth_efficiency: 1.0,
    }
}

/// The CPU this repo actually executes kernels on (for CPU-bench
/// contextualization only; measured numbers come from PJRT wall clock).
pub fn host_cpu() -> HardwareSpec {
    HardwareSpec {
        name: "host-cpu",
        peak_ops: 2e11,
        hbm_bw: 2e10,
        hbm_bytes: 16 * (1u64 << 30),
        // PCIe-class host link.
        interconnect_bw: 1e9,
        bytes_per_word: 4.0, // f32 on CPU
        compute_efficiency: 1.0,
        bandwidth_efficiency: 1.0,
    }
}

pub fn by_name(name: &str) -> Option<HardwareSpec> {
    match name {
        "ascend-npu" => Some(ascend_npu()),
        "gpu-h800" | "gpu" => Some(gpu_h800()),
        "roofline-npu" => Some(roofline_npu()),
        "host-cpu" => Some(host_cpu()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascend_matches_paper_quote() {
        let hw = ascend_npu();
        assert_eq!(hw.peak_ops, 376e12);
        assert_eq!(hw.hbm_bw, 1.8e12);
    }

    #[test]
    fn ridge_point_sane() {
        // Ascend: 188e12 MAC/s / 0.9e12 words/s ≈ 209 MACs/word.
        let r = ascend_npu().ridge_intensity();
        assert!((r - 208.9).abs() < 1.0, "{r}");
    }
}
