//! Hardware specifications for the cost model and simulator.
//!
//! Substitution note (DESIGN.md §6): we have neither an Ascend NPU nor
//! an H800-class GPU; these specs parameterize the paper's own roofline
//! formulas (§3.2, Appendix A.1) which the paper validates against
//! msprof measurements to within a few percent.

use anyhow::{bail, Result};

/// The accelerator class a spec belongs to — the axis the kernel
/// registry prices B_theta crossovers along (DESIGN.md §16).  The
/// hardware-centric MLA analysis (arxiv 2506.02523) shows the
/// naive/absorb crossover is a pure function of the backend's
/// compute-to-bandwidth ratio, so each class carries a calibrated
/// preset rather than a single shared roofline.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Backend {
    /// Ascend-class NPU (the paper's §4 platform).
    Npu,
    /// H800-class GPU.
    Gpu,
    /// Host CPU (bench contextualization only).
    Cpu,
}

impl Backend {
    pub fn as_str(&self) -> &'static str {
        match self {
            Backend::Npu => "npu",
            Backend::Gpu => "gpu",
            Backend::Cpu => "cpu",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "npu" => Backend::Npu,
            "gpu" => Backend::Gpu,
            "cpu" => Backend::Cpu,
            _ => bail!("unknown backend {s:?} (npu|gpu|cpu)"),
        })
    }

    pub fn all() -> [Backend; 3] {
        [Backend::Npu, Backend::Gpu, Backend::Cpu]
    }

    /// The calibrated preset for this backend class: the spec whose
    /// tenancy cells reproduce the paper's headline speedup shape
    /// (3x-shaped on the NPU, 3.24x-shaped on the GPU — §4).
    pub fn preset(&self) -> HardwareSpec {
        match self {
            Backend::Npu => ascend_npu(),
            Backend::Gpu => gpu_h800_decode(),
            Backend::Cpu => host_cpu(),
        }
    }
}

/// An accelerator described by its two roofline parameters plus memory.
#[derive(Clone, Debug, PartialEq)]
pub struct HardwareSpec {
    pub name: &'static str,
    /// Peak throughput in *operations*/second as vendors quote it
    /// (multiply and add counted separately).  The cost model divides by
    /// two to get MAC/s — this convention reproduces the paper's
    /// B_theta = 61 exactly.
    pub peak_ops: f64,
    /// HBM bandwidth, bytes/second.
    pub hbm_bw: f64,
    /// HBM capacity, bytes.
    pub hbm_bytes: u64,
    /// Device-to-device interconnect bandwidth, bytes/second (the
    /// per-device share of the scale-up fabric: CloudMatrix unified bus
    /// / NVLink class).  Prices cross-replica page migration in the
    /// cluster simulator.
    pub interconnect_bw: f64,
    /// Bytes per element of the KV-cache/activation dtype (2 = FP16).
    pub bytes_per_word: f64,
    /// Fraction of peak actually achievable by a well-tuned kernel
    /// (MXU/cube utilization ceiling). 1.0 = ideal roofline.
    pub compute_efficiency: f64,
    /// Same for memory streams.
    pub bandwidth_efficiency: f64,
    /// Which accelerator class this spec parameterizes — the grid axis
    /// the per-backend B_theta crossover sweep runs along.
    pub backend: Backend,
}

impl HardwareSpec {
    /// Achievable MAC throughput (multiply-accumulate per second).
    pub fn macs_per_sec(&self) -> f64 {
        self.peak_ops / 2.0 * self.compute_efficiency
    }

    /// Achievable HBM stream rate in *words* per second.
    pub fn words_per_sec(&self) -> f64 {
        self.hbm_bw / self.bytes_per_word * self.bandwidth_efficiency
    }

    /// Achievable HBM stream rate in bytes per second.
    pub fn effective_bw(&self) -> f64 {
        self.hbm_bw * self.bandwidth_efficiency
    }

    /// Ridge point of the roofline, MACs per word.
    pub fn ridge_intensity(&self) -> f64 {
        self.macs_per_sec() / self.words_per_sec()
    }
}

/// Ascend NPU used in the paper's §4: 376 TOPS FP16, 1.8 TB/s, 64 GB.
pub fn ascend_npu() -> HardwareSpec {
    HardwareSpec {
        name: "ascend-npu",
        peak_ops: 376e12,
        hbm_bw: 1.8e12,
        hbm_bytes: 64 * (1u64 << 30),
        // CloudMatrix-class unified bus, per-NPU share.
        interconnect_bw: 392e9,
        bytes_per_word: 2.0,
        compute_efficiency: 1.0,
        bandwidth_efficiency: 1.0,
        backend: Backend::Npu,
    }
}

/// GPU used in the paper's §4: "1 PetaFLOPS/s FP16, 3.3 TB/s" (H800-class).
pub fn gpu_h800() -> HardwareSpec {
    HardwareSpec {
        name: "gpu-h800",
        peak_ops: 1.0e15,
        hbm_bw: 3.3e12,
        hbm_bytes: 80 * (1u64 << 30),
        // H800 NVLink (export-trimmed): 400 GB/s.
        interconnect_bw: 400e9,
        bytes_per_word: 2.0,
        compute_efficiency: 1.0,
        bandwidth_efficiency: 1.0,
        backend: Backend::Gpu,
    }
}

/// H800-class GPU calibrated for decode attention (the `Backend::Gpu`
/// preset).  The hardware-centric MLA analysis (arxiv 2506.02523)
/// shows decode-attention GEMM shapes (skinny `B x D` activations
/// against streamed KV) reach only a fraction of the tensor-core peak;
/// 0.33 puts the achievable compute-to-bandwidth ratio at exactly
/// T/M = 100 MACs/word, which (a) lands the tenancy calibration cell
/// on the paper's 3.24x-shaped GPU speedup (§4, vs 3x-shaped on the
/// NPU) and (b) pins the per-backend Eq. 1 crossover at B_theta = 29.
/// The ideal-roofline `gpu_h800` stays untouched for Eq. 1 regeneration
/// (B_theta = 89), as does the Table-3 `gpu_h800_calibrated`.
pub fn gpu_h800_decode() -> HardwareSpec {
    HardwareSpec {
        name: "gpu-h800-decode",
        peak_ops: 1.0e15,
        hbm_bw: 3.3e12,
        hbm_bytes: 80 * (1u64 << 30),
        interconnect_bw: 400e9,
        bytes_per_word: 2.0,
        compute_efficiency: 0.33,
        bandwidth_efficiency: 1.0,
        backend: Backend::Gpu,
    }
}

/// Appendix A.1 roofline figure uses 400 TFLOPS "cube" + 1.8 TB/s.
pub fn roofline_npu() -> HardwareSpec {
    HardwareSpec {
        name: "roofline-npu",
        peak_ops: 400e12,
        hbm_bw: 1.8e12,
        hbm_bytes: 64 * (1u64 << 30),
        interconnect_bw: 392e9,
        bytes_per_word: 2.0,
        compute_efficiency: 1.0,
        bandwidth_efficiency: 1.0,
        backend: Backend::Npu,
    }
}

/// The CPU this repo actually executes kernels on (for CPU-bench
/// contextualization only; measured numbers come from PJRT wall clock).
pub fn host_cpu() -> HardwareSpec {
    HardwareSpec {
        name: "host-cpu",
        peak_ops: 2e11,
        hbm_bw: 2e10,
        hbm_bytes: 16 * (1u64 << 30),
        // PCIe-class host link.
        interconnect_bw: 1e9,
        bytes_per_word: 4.0, // f32 on CPU
        compute_efficiency: 1.0,
        bandwidth_efficiency: 1.0,
        backend: Backend::Cpu,
    }
}

pub fn by_name(name: &str) -> Option<HardwareSpec> {
    match name {
        "ascend-npu" => Some(ascend_npu()),
        "gpu-h800" | "gpu" => Some(gpu_h800()),
        "gpu-h800-decode" => Some(gpu_h800_decode()),
        "roofline-npu" => Some(roofline_npu()),
        "host-cpu" => Some(host_cpu()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascend_matches_paper_quote() {
        let hw = ascend_npu();
        assert_eq!(hw.peak_ops, 376e12);
        assert_eq!(hw.hbm_bw, 1.8e12);
    }

    #[test]
    fn ridge_point_sane() {
        // Ascend: 188e12 MAC/s / 0.9e12 words/s ≈ 209 MACs/word.
        let r = ascend_npu().ridge_intensity();
        assert!((r - 208.9).abs() < 1.0, "{r}");
    }

    /// Backend names round-trip, the parse failure names the candidate
    /// list, and matching is exact (no case folding) — the contract the
    /// `--backend` CLI flag relies on.
    #[test]
    fn backend_roundtrip_and_presets() {
        for b in Backend::all() {
            assert_eq!(Backend::parse(b.as_str()).unwrap(), b);
            assert_eq!(b.preset().backend, b, "{b:?} preset carries its class");
        }
        let err = Backend::parse("tpu").unwrap_err().to_string();
        assert!(err.contains("npu|gpu|cpu"), "{err}");
        assert!(Backend::parse("NPU").is_err(), "matching is exact");
        assert!(Backend::parse("").is_err());
        assert_eq!(Backend::Npu.preset().name, "ascend-npu");
        assert_eq!(Backend::Gpu.preset().name, "gpu-h800-decode");
        assert_eq!(Backend::Cpu.preset().name, "host-cpu");
    }

    /// The decode-calibrated GPU preset's compute-to-bandwidth ratio is
    /// exactly 100 MACs/word: 1e15/2 * 0.33 MAC/s over 3.3e12/2 words/s.
    /// Eq. 1 then gives B_theta = floor(320/1088 * 100) = 29 — pinned
    /// end-to-end in `costmodel::threshold`.
    #[test]
    fn gpu_decode_ratio_is_100() {
        let hw = gpu_h800_decode();
        assert!((hw.ridge_intensity() - 100.0).abs() < 1e-9, "{}", hw.ridge_intensity());
        // The ideal-roofline GPU preset is untouched by calibration.
        assert_eq!(gpu_h800().compute_efficiency, 1.0);
        assert_eq!(by_name("gpu").unwrap(), gpu_h800());
        assert_eq!(by_name("gpu-h800-decode").unwrap(), gpu_h800_decode());
    }
}
