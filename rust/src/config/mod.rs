//! Configuration: model geometries, hardware specs, serving knobs.

pub mod hardware;
pub mod model;
pub mod serving;

pub use hardware::{Backend, HardwareSpec};
pub use model::ModelConfig;
pub use serving::{FaultConfig, KernelKind, ScalingConfig, ServingConfig};
