//! Model (attention-geometry) configurations, mirroring
//! `python/compile/configs.py` and the paper's Table 1 notation.

/// MLA attention geometry.  Field names follow the paper:
/// `H, D_n, D_r, D_qk = D_n + D_r, D_v, D_l` (KV LoRA rank).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: &'static str,
    pub d_model: usize,
    pub n_heads: usize,      // H
    pub d_nope: usize,       // D_n
    pub d_rope: usize,       // D_r
    pub d_v: usize,          // D_v
    pub kv_lora_rank: usize, // D_l
    pub q_lora_rank: usize,
    /// Layer count of the full model (used by memory/e2e models).
    pub n_layers: usize,
    /// MoE/dense weight bytes of the full model, used by the Fig. 5
    /// HBM-footprint model (FP8 for DeepSeek-v3: ~671 GB).
    pub weight_bytes: u64,
    /// Non-attention time per decode iteration per device, ms — from the
    /// DeepSeek profile-data substitution (Table 3).
    pub other_layer_ms: f64,
}

impl ModelConfig {
    pub fn d_qk(&self) -> usize {
        self.d_nope + self.d_rope
    }

    // ---- Table 1 factors (per query x context-token) ----
    /// Naive-formulation MACs per (query, context token): H*(D_qk+D_v).
    pub fn naive_factor(&self) -> u64 {
        (self.n_heads * (self.d_qk() + self.d_v)) as u64
    }

    /// Absorb-formulation MACs per (query, context token): H*(2*D_l+D_r).
    pub fn absorb_factor(&self) -> u64 {
        (self.n_heads * (2 * self.kv_lora_rank + self.d_rope)) as u64
    }

    /// Words per cached token in latent form: D_l + D_r.
    pub fn latent_words(&self) -> u64 {
        (self.kv_lora_rank + self.d_rope) as u64
    }

    /// Words per cached token in uncompressed form: H*(D_qk + D_v).
    pub fn uncompressed_words(&self) -> u64 {
        (self.n_heads * (self.d_qk() + self.d_v)) as u64
    }

    /// The paper's naive/absorb MAC ratio (3.4x for DeepSeek-v3).
    pub fn absorb_naive_mac_ratio(&self) -> f64 {
        self.absorb_factor() as f64 / self.naive_factor() as f64
    }
}

/// DeepSeek-v3: H=128. Table 1 constants: 40 Ki / 136 Ki / 0.5625 Ki.
pub fn deepseek_v3() -> ModelConfig {
    ModelConfig {
        name: "deepseek-v3",
        d_model: 7168,
        n_heads: 128,
        d_nope: 128,
        d_rope: 64,
        d_v: 128,
        kv_lora_rank: 512,
        q_lora_rank: 1536,
        n_layers: 61,
        // 671B params in FP8.
        weight_bytes: 671_000_000_000,
        // Table 3: total 127.2 ms at 99.1 ms attention => 28.1 ms other.
        other_layer_ms: 28.1,
    }
}

/// Kimi K2: same head geometry, half the heads (H=64).
pub fn kimi_k2() -> ModelConfig {
    ModelConfig {
        name: "kimi-k2",
        d_model: 7168,
        n_heads: 64,
        d_nope: 128,
        d_rope: 64,
        d_v: 128,
        kv_lora_rank: 512,
        q_lora_rank: 1536,
        n_layers: 61,
        weight_bytes: 1_000_000_000_000,
        other_layer_ms: 28.1,
    }
}

/// Scaled-down geometry used for real CPU-PJRT execution.
pub fn sim() -> ModelConfig {
    ModelConfig {
        name: "sim",
        d_model: 512,
        n_heads: 8,
        d_nope: 64,
        d_rope: 32,
        d_v: 64,
        kv_lora_rank: 128,
        q_lora_rank: 192,
        n_layers: 4,
        weight_bytes: 0,
        other_layer_ms: 0.0,
    }
}

/// Tiny end-to-end transformer (matches `python/compile/configs.py`).
pub fn tiny() -> ModelConfig {
    ModelConfig {
        name: "tiny",
        d_model: 256,
        n_heads: 4,
        d_nope: 32,
        d_rope: 16,
        d_v: 32,
        kv_lora_rank: 64,
        q_lora_rank: 96,
        n_layers: 4,
        weight_bytes: 0,
        other_layer_ms: 0.0,
    }
}

pub fn by_name(name: &str) -> Option<ModelConfig> {
    match name {
        "deepseek-v3" => Some(deepseek_v3()),
        "kimi-k2" => Some(kimi_k2()),
        "sim" => Some(sim()),
        "tiny" => Some(tiny()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 1, right-most column: the x1024 constants for DeepSeek-v3.
    #[test]
    fn table1_deepseek_constants() {
        let c = deepseek_v3();
        assert_eq!(c.naive_factor(), 40 * 1024);
        assert_eq!(c.absorb_factor(), 136 * 1024);
        assert_eq!(c.uncompressed_words(), 40 * 1024);
        // 0.5625 Ki = 576 words.
        assert_eq!(c.latent_words(), 576);
        // "~3.4x smaller in the shared portion" (paper §3.2).
        assert!((c.absorb_naive_mac_ratio() - 3.4).abs() < 0.01);
    }

    #[test]
    fn kimi_half_heads() {
        let k = kimi_k2();
        let d = deepseek_v3();
        assert_eq!(k.naive_factor() * 2, d.naive_factor());
        assert_eq!(k.absorb_factor() * 2, d.absorb_factor());
        // Latent cache is head-independent.
        assert_eq!(k.latent_words(), d.latent_words());
    }

    #[test]
    fn lookup() {
        assert_eq!(by_name("deepseek-v3").unwrap().n_heads, 128);
        assert!(by_name("nope").is_none());
    }
}
