//! Serving-runtime configuration (the L3 coordinator's knobs).

use anyhow::{bail, Result};

/// Which attention formulation the engine runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// Mixed naive(shared)+absorb(non-shared) — the paper's contribution.
    Typhoon,
    /// Absorb-only (FlashMLA / CATLASS baseline; also the fallback).
    Absorb,
    /// Naive-only (TorchNPU PagedAttention / FlashAttention baseline).
    Naive,
}

impl KernelKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            KernelKind::Typhoon => "typhoon",
            KernelKind::Absorb => "absorb",
            KernelKind::Naive => "naive",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "typhoon" => KernelKind::Typhoon,
            "absorb" => KernelKind::Absorb,
            "naive" => KernelKind::Naive,
            _ => bail!("unknown kernel kind {s:?} (typhoon|absorb|naive)"),
        })
    }

    pub fn all() -> [KernelKind; 3] {
        [KernelKind::Typhoon, KernelKind::Absorb, KernelKind::Naive]
    }
}

/// Continuous-batching / KV-cache knobs.
#[derive(Clone, Debug)]
pub struct ServingConfig {
    /// Paged KV-cache block size in tokens (paper experiments: 128).
    pub block_size: usize,
    /// Max sequences resident in a decode batch.
    pub max_batch: usize,
    /// Max non-shared tokens per sequence (prompt suffix + generation).
    pub max_seq_len: usize,
    /// Total KV-cache blocks available to the allocator.
    pub total_blocks: usize,
    /// Requested kernel. For `Typhoon` the policy may still fall back to
    /// `Absorb` below the batch threshold.
    pub kernel: KernelKind,
    /// Override for the fallback threshold B_theta; `None` derives it
    /// from hardware + model via the Eq. 1 cost model.
    pub batch_threshold_override: Option<usize>,
    /// Scheduler admits new requests only when at least this many slots
    /// are free (hysteresis to avoid thrashing).
    pub admit_hysteresis: usize,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            block_size: 128,
            max_batch: 64,
            max_seq_len: 4096,
            total_blocks: 4096,
            kernel: KernelKind::Typhoon,
            batch_threshold_override: None,
            admit_hysteresis: 0,
        }
    }
}

impl ServingConfig {
    pub fn validate(&self) -> Result<()> {
        if self.block_size == 0 || !self.block_size.is_power_of_two() {
            bail!("block_size must be a power of two, got {}", self.block_size);
        }
        if self.max_batch == 0 {
            bail!("max_batch must be positive");
        }
        if self.max_seq_len % self.block_size != 0 {
            bail!(
                "max_seq_len {} must be a multiple of block_size {}",
                self.max_seq_len,
                self.block_size
            );
        }
        if self.total_blocks < self.max_batch {
            bail!("total_blocks {} < max_batch {}", self.total_blocks, self.max_batch);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        ServingConfig::default().validate().unwrap();
    }

    #[test]
    fn rejects_bad_block_size() {
        let mut c = ServingConfig::default();
        c.block_size = 100;
        assert!(c.validate().is_err());
    }

    #[test]
    fn rejects_unaligned_seq_len() {
        let mut c = ServingConfig::default();
        c.max_seq_len = 1000;
        assert!(c.validate().is_err());
    }

    /// Round-trip every kernel through its string form, and pin the
    /// parse failure mode (error names the accepted forms; matching is
    /// exact, no case folding).
    #[test]
    fn kernel_kind_roundtrip() {
        for k in KernelKind::all() {
            assert_eq!(KernelKind::parse(k.as_str()).unwrap(), k);
            assert_eq!(KernelKind::parse(k.as_str()).unwrap().as_str(), k.as_str());
        }
        let err = KernelKind::parse("x").unwrap_err().to_string();
        assert!(err.contains("typhoon|absorb|naive"), "{err}");
        assert!(KernelKind::parse("Typhoon").is_err(), "matching is exact");
        assert!(KernelKind::parse("").is_err());
    }
}
