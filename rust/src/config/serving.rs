//! Serving-runtime configuration (the L3 coordinator's knobs).

use anyhow::{bail, Result};

/// Which attention formulation the engine runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// Mixed naive(shared)+absorb(non-shared) — the paper's contribution.
    Typhoon,
    /// Absorb-only (FlashMLA / CATLASS baseline; also the fallback).
    Absorb,
    /// Naive-only (TorchNPU PagedAttention / FlashAttention baseline).
    Naive,
    /// Absorb with AMLA's add-based FlashAttention rescaling (arxiv
    /// 2509.25224): the running-output rescale becomes an exponent add,
    /// discounting the absorb-side attention MACs (costmodel::flops).
    AmlaAbsorb,
    /// Typhoon whose non-shared (absorb) stage runs the AMLA variant.
    TyphoonAmla,
}

impl KernelKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            KernelKind::Typhoon => "typhoon",
            KernelKind::Absorb => "absorb",
            KernelKind::Naive => "naive",
            KernelKind::AmlaAbsorb => "amla-absorb",
            KernelKind::TyphoonAmla => "typhoon-amla",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "typhoon" => KernelKind::Typhoon,
            "absorb" => KernelKind::Absorb,
            "naive" => KernelKind::Naive,
            "amla-absorb" => KernelKind::AmlaAbsorb,
            "typhoon-amla" => KernelKind::TyphoonAmla,
            _ => bail!(
                "unknown kernel kind {s:?} \
                 (typhoon|absorb|naive|amla-absorb|typhoon-amla)"
            ),
        })
    }

    pub fn all() -> [KernelKind; 5] {
        [
            KernelKind::Typhoon,
            KernelKind::Absorb,
            KernelKind::Naive,
            KernelKind::AmlaAbsorb,
            KernelKind::TyphoonAmla,
        ]
    }

    /// Kernels whose *shared* stage reads the prefix in uncompressed
    /// (naive) form — these need the expanded K/V copy materialized
    /// (`KvCacheManager::expand_shared_prefix`) and amortize the stream
    /// across the group, which is what the Eq. 1 threshold prices.
    pub fn reads_shared_naive(&self) -> bool {
        matches!(
            self,
            KernelKind::Typhoon | KernelKind::TyphoonAmla | KernelKind::Naive
        )
    }

    /// The absorb-formulation kernels — the fall-back family the naive
    /// readers switch to below their crossover batch.
    pub fn is_absorb_family(&self) -> bool {
        !self.reads_shared_naive()
    }
}

impl std::fmt::Display for KernelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Continuous-batching / KV-cache knobs.
#[derive(Clone, Debug)]
pub struct ServingConfig {
    /// Paged KV-cache block size in tokens (paper experiments: 128).
    pub block_size: usize,
    /// Max sequences resident in a decode batch.
    pub max_batch: usize,
    /// Max non-shared tokens per sequence (prompt suffix + generation).
    pub max_seq_len: usize,
    /// Total KV-cache blocks available to the allocator.
    pub total_blocks: usize,
    /// Requested kernel. For `Typhoon` the policy may still fall back to
    /// `Absorb` below the batch threshold.
    pub kernel: KernelKind,
    /// Override for the fallback threshold B_theta; `None` derives it
    /// from hardware + model via the Eq. 1 cost model.
    pub batch_threshold_override: Option<usize>,
    /// Scheduler admits new requests only when at least this many slots
    /// are free (hysteresis to avoid thrashing).
    pub admit_hysteresis: usize,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            block_size: 128,
            max_batch: 64,
            max_seq_len: 4096,
            total_blocks: 4096,
            kernel: KernelKind::Typhoon,
            batch_threshold_override: None,
            admit_hysteresis: 0,
        }
    }
}

impl ServingConfig {
    pub fn validate(&self) -> Result<()> {
        if self.block_size == 0 || !self.block_size.is_power_of_two() {
            bail!("block_size must be a power of two, got {}", self.block_size);
        }
        if self.max_batch == 0 {
            bail!("max_batch must be positive");
        }
        if self.max_seq_len % self.block_size != 0 {
            bail!(
                "max_seq_len {} must be a multiple of block_size {}",
                self.max_seq_len,
                self.block_size
            );
        }
        if self.total_blocks < self.max_batch {
            bail!("total_blocks {} < max_batch {}", self.total_blocks, self.max_batch);
        }
        Ok(())
    }
}

/// Replica-autoscaling knobs (cluster mode).  The operator-facing
/// configuration `policy::ScalingPolicy` adopts — validated here so a
/// nonsense fleet shape is a configuration error, not a silent hold.
#[derive(Clone, Copy, Debug)]
pub struct ScalingConfig {
    /// Master switch (`--autoscale`); disabled holds the fleet exactly
    /// as configured.
    pub enabled: bool,
    /// Target utilization rho* in (0, 1] (`--scale-headroom`): scale up
    /// when the observed arrival rate exceeds this fraction of the
    /// fleet's observed service capacity.
    pub headroom: f64,
    /// Scale-down hysteresis in (0, 1): one fewer replica must still
    /// sit under `down_factor * headroom` utilization before a replica
    /// retires.
    pub down_factor: f64,
    /// The fleet never shrinks below this.
    pub min_replicas: usize,
    /// The fleet never grows past this.
    pub max_replicas: usize,
    /// Arrivals in the windowed arrival-rate estimate.
    pub rate_window: usize,
    /// Minimum arrivals between scale events.
    pub cooldown_arrivals: usize,
}

impl ScalingConfig {
    /// Defaults for a fleet starting at `replicas`: disabled, 80%
    /// utilization target, 2x hysteresis gap, shrink to one replica,
    /// grow to twice the starting size.
    pub fn for_fleet(replicas: usize) -> Self {
        ScalingConfig {
            enabled: false,
            headroom: 0.8,
            down_factor: 0.5,
            min_replicas: 1,
            max_replicas: replicas.saturating_mul(2).max(1),
            rate_window: 32,
            cooldown_arrivals: 64,
        }
    }

    /// Validate against the fleet's starting size.
    pub fn validate(&self, replicas: usize) -> Result<()> {
        if !self.enabled {
            return Ok(());
        }
        if !self.headroom.is_finite() || self.headroom <= 0.0 || self.headroom > 1.0 {
            bail!("scale headroom must be in (0, 1], got {}", self.headroom);
        }
        if !self.down_factor.is_finite() || self.down_factor <= 0.0 || self.down_factor >= 1.0
        {
            bail!(
                "scale-down hysteresis factor must be in (0, 1), got {}",
                self.down_factor
            );
        }
        if self.min_replicas == 0 {
            bail!("min_replicas must be at least 1");
        }
        if self.min_replicas > replicas || replicas > self.max_replicas {
            bail!(
                "starting fleet of {replicas} must sit inside [min_replicas, \
                 max_replicas] = [{}, {}]",
                self.min_replicas,
                self.max_replicas
            );
        }
        if self.rate_window < 2 {
            bail!("rate_window needs at least 2 arrivals, got {}", self.rate_window);
        }
        if self.cooldown_arrivals == 0 {
            bail!("cooldown_arrivals must be at least 1");
        }
        Ok(())
    }
}

/// Fault-injection knobs (cluster mode; DESIGN.md §14).  Like
/// `ScalingConfig` this is the operator-facing shape — the simulator
/// materializes it into a seeded `simulator::faults::FaultPlan`.
#[derive(Clone, Copy, Debug)]
pub struct FaultConfig {
    /// Master switch (`--faults`); disabled takes the exact fault-free
    /// code path (no RNG draws, bit-identical reports).
    pub enabled: bool,
    /// Seed for the fault schedule.  Independent of the workload seed
    /// so the same traffic can be replayed under different fault draws.
    pub seed: u64,
    /// Replica crashes to schedule.  Must stay below the fleet size so
    /// at least one survivor can absorb the failover.
    pub crashes: usize,
    /// Replica stall events to schedule (the replica goes silent for a
    /// sampled window but keeps its state).
    pub stalls: usize,
    /// Interconnect degradation windows to schedule (per replica pair).
    pub degradations: usize,
    /// Probability in [0, 1) that one in-flight prefix transfer attempt
    /// is lost or arrives truncated (and is then retried with backoff).
    pub transfer_loss: f64,
    /// Bandwidth multiplier inside a degradation window, in [0, 1]:
    /// 0 partitions the pair, 1 is a no-op window.
    pub degrade_factor: f64,
}

impl FaultConfig {
    /// The fault-free default: disabled, nothing scheduled.
    pub fn disabled() -> Self {
        FaultConfig {
            enabled: false,
            seed: 0,
            crashes: 0,
            stalls: 0,
            degradations: 0,
            transfer_loss: 0.0,
            degrade_factor: 1.0,
        }
    }

    /// Validate against the fleet's starting size.
    pub fn validate(&self, replicas: usize) -> Result<()> {
        if !self.enabled {
            return Ok(());
        }
        if replicas == 0 {
            bail!("fault injection needs at least one replica");
        }
        if self.crashes >= replicas {
            bail!(
                "fault plan schedules {} crashes but the fleet only has {replicas} \
                 replica(s); at least one survivor must remain",
                self.crashes
            );
        }
        if !self.transfer_loss.is_finite()
            || !(0.0..1.0).contains(&self.transfer_loss)
        {
            bail!(
                "transfer-loss probability must be in [0, 1), got {}",
                self.transfer_loss
            );
        }
        if !self.degrade_factor.is_finite()
            || !(0.0..=1.0).contains(&self.degrade_factor)
        {
            bail!(
                "interconnect degrade factor must be in [0, 1], got {}",
                self.degrade_factor
            );
        }
        if self.degradations > 0 && replicas < 2 {
            bail!("interconnect degradation needs at least two replicas");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        ServingConfig::default().validate().unwrap();
    }

    #[test]
    fn fault_config_disabled_skips_checks_and_enabled_validates() {
        let mut f = FaultConfig::disabled();
        f.crashes = 99; // nonsense, but disabled: anything goes
        f.validate(1).unwrap();

        let mut f = FaultConfig::disabled();
        f.enabled = true;
        f.crashes = 1;
        f.validate(2).unwrap();
        f.validate(1).unwrap_err(); // would kill the whole fleet
        f.crashes = 0;
        f.transfer_loss = 1.0;
        assert!(f.validate(2).is_err(), "loss probability must stay below 1");
        f.transfer_loss = f64::NAN;
        assert!(f.validate(2).is_err());
        f.transfer_loss = 0.25;
        f.degrade_factor = -0.5;
        assert!(f.validate(2).is_err());
        f.degrade_factor = 0.0; // partition is legal
        f.validate(2).unwrap();
        f.degradations = 1;
        assert!(f.validate(1).is_err(), "degradation needs a pair");
        f.validate(2).unwrap();
    }

    #[test]
    fn scaling_defaults_validate_and_disabled_skips_checks() {
        for replicas in [1usize, 2, 4, 7] {
            let mut c = ScalingConfig::for_fleet(replicas);
            assert!(!c.enabled);
            c.validate(replicas).unwrap(); // disabled: anything goes
            c.enabled = true;
            c.validate(replicas).unwrap();
            assert!(c.max_replicas >= replicas.max(1));
        }
    }

    #[test]
    fn scaling_rejects_bad_shapes() {
        let mut c = ScalingConfig::for_fleet(2);
        c.enabled = true;
        c.headroom = 0.0;
        assert!(c.validate(2).is_err());
        c.headroom = 1.5;
        assert!(c.validate(2).is_err());
        c.headroom = 0.8;
        c.down_factor = 1.0;
        assert!(c.validate(2).is_err());
        c.down_factor = 0.5;
        c.min_replicas = 0;
        assert!(c.validate(2).is_err());
        c.min_replicas = 3;
        assert!(c.validate(2).is_err(), "floor above the starting fleet");
        c.min_replicas = 1;
        c.max_replicas = 1;
        assert!(c.validate(2).is_err(), "cap below the starting fleet");
        c.max_replicas = 4;
        c.rate_window = 1;
        assert!(c.validate(2).is_err());
        c.rate_window = 32;
        c.cooldown_arrivals = 0;
        assert!(c.validate(2).is_err());
        c.cooldown_arrivals = 64;
        c.validate(2).unwrap();
    }

    #[test]
    fn rejects_bad_block_size() {
        let mut c = ServingConfig::default();
        c.block_size = 100;
        assert!(c.validate().is_err());
    }

    #[test]
    fn rejects_unaligned_seq_len() {
        let mut c = ServingConfig::default();
        c.max_seq_len = 1000;
        assert!(c.validate().is_err());
    }

    /// Round-trip every kernel through its string form, and pin the
    /// parse failure mode (error names the accepted forms; matching is
    /// exact, no case folding).
    #[test]
    fn kernel_kind_roundtrip() {
        for k in KernelKind::all() {
            assert_eq!(KernelKind::parse(k.as_str()).unwrap(), k);
            assert_eq!(KernelKind::parse(k.as_str()).unwrap().as_str(), k.as_str());
        }
        let err = KernelKind::parse("x").unwrap_err().to_string();
        assert!(err.contains("typhoon|absorb|naive|amla-absorb|typhoon-amla"), "{err}");
        assert!(KernelKind::parse("Typhoon").is_err(), "matching is exact");
        assert!(KernelKind::parse("").is_err());
    }

    /// Family partition: every kernel is exactly one of naive-shared or
    /// absorb-family, and the split matches the expansion requirement
    /// the coordinator enforces.
    #[test]
    fn kernel_families_partition() {
        for k in KernelKind::all() {
            assert_ne!(k.reads_shared_naive(), k.is_absorb_family(), "{k:?}");
        }
        assert!(KernelKind::Typhoon.reads_shared_naive());
        assert!(KernelKind::TyphoonAmla.reads_shared_naive());
        assert!(KernelKind::Naive.reads_shared_naive());
        assert!(KernelKind::Absorb.is_absorb_family());
        assert!(KernelKind::AmlaAbsorb.is_absorb_family());
    }
}
