//! `bench_sweep` — times the Fig. 2/3 end-to-end figure sweep and
//! tracks the speedup of the parallel+memoized hot path across PRs.
//!
//! Usage:
//!   bench_sweep [--quick] [--full] [--threads N] [--out FILE]
//!               [--skip-serial] [--million] [--million-requests N]
//!               [--backend npu|gpu]
//!
//! * `--quick`  caps `max_requests` and shrinks the batch set to a
//!   tier-1-friendly load (default mode is a middle ground; `--full`
//!   is the paper's whole-split protocol).
//! * `--backend` restricts the grids to one accelerator preset:
//!   `npu` runs the fig2 (Ascend) leg, `gpu` runs the fig3 leg on the
//!   decode-calibrated H800 preset, and the cluster + crossover grids
//!   follow the same preset.  Absent, both figure legs run exactly as
//!   before and the crossover grid covers every backend axis value.
//!   Unknown names are rejected with the candidate list.
//! * By default the sweep runs twice — a **serial, unmemoized**
//!   baseline (pre-optimization hot path: per-sequence Table-1
//!   evaluation, single thread), then the optimized parallel+memoized
//!   path — asserts the figure text/CSV artifacts are
//!   **byte-identical**, and reports the speedup.  `--skip-serial`
//!   times only the parallel run.
//! * `--million` additionally times one large prefix-affinity cluster
//!   cell (default 1M timed arrivals; `--million-requests` rescales,
//!   e.g. the CI leg's 100k) through the indexed event core with
//!   parallel replica stepping, recording `events_per_second`,
//!   `million_wall_seconds` and the peak sequence-arena occupancy —
//!   plus a spawn-reference replay (the retained per-window
//!   `thread::scope` dispatch) recorded as
//!   `events_per_second_reference`, the fleet-shared price-cache
//!   `price_cache_hits`/`price_cache_misses` counters and the pool's
//!   `pool_windows` count, plus a serial replay asserted
//!   byte-identical unless `--skip-serial`.
//!
//! Emits `BENCH_sweep.json` with schema
//! `{wall_seconds, cells, tokens_simulated}` (plus serial baseline and
//! speedup fields when measured, plus `cluster_*` fields for the
//! replicas x skew x router grid, which is timed and
//! byte-identity-asserted the same way, plus `backend`, `crossover_*`
//! and per-backend `b_theta_*` registry-threshold fields for the
//! crossover grid, plus `million_*` /
//! `events_per_second` fields under `--million`) via
//! util::bench-style JSON — to `--out` (default `target/bench/`)
//! *and* to the tracked repo-root copy `BENCH_sweep.json`, so the perf
//! trajectory survives PRs.

// The one wall-clock-legal target (detlint rule 2 exempts this path):
// the sweep's whole job is timing real runs.
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use anyhow::{ensure, Result};
use typhoon_mla::analysis::figures::{
    format_cluster, format_crossover, format_throughput, paper_models, CLUSTER_ARRIVALS,
    CLUSTER_REPLICAS, CLUSTER_SKEWS, CLUSTER_TENANTS, CROSSOVER_BACKENDS, PAPER_BATCHES,
};
use typhoon_mla::analysis::Artifact;
use typhoon_mla::config::hardware::{ascend_npu, gpu_h800, Backend, HardwareSpec};
use typhoon_mla::config::model::deepseek_v3;
use typhoon_mla::costmodel::{parallel_batch_threshold, ParallelismConfig};
use typhoon_mla::simulator::sweep::{
    cluster_cells, cluster_row_configs, crossover_cells, run_cluster_sweep,
    run_crossover_sweep, run_throughput_sweep, throughput_cells, ClusterCell, SweepExecutor,
    ThroughputCell,
};
use typhoon_mla::simulator::{run_cluster_experiment, ClusterParams, ClusterSim, RouterPolicy};
use typhoon_mla::util::cli::Args;
use typhoon_mla::util::json::Json;

struct SweepOutcome {
    wall_seconds: f64,
    cells: usize,
    tokens: u64,
    artifacts: Vec<Artifact>,
}

/// Run the selected figure grids (fig2 Ascend / fig3 H800, or the
/// `--backend` subset) under one executor.
fn run_sweep(
    figs: &[(&'static str, HardwareSpec)],
    cells: &[ThroughputCell],
    batches_per_group: usize,
    exec: &SweepExecutor,
) -> Result<SweepOutcome> {
    let t0 = Instant::now();
    let mut artifacts = Vec::new();
    let mut tokens = 0u64;
    let mut n_cells = 0usize;
    for &(id, ref hw) in figs {
        let results = run_throughput_sweep(hw, cells, exec)?;
        n_cells += results.len();
        tokens += results.iter().map(|r| r.tokens()).sum::<u64>();
        artifacts.push(format_throughput(id, hw, &results, batches_per_group));
    }
    Ok(SweepOutcome {
        wall_seconds: t0.elapsed().as_secs_f64(),
        cells: n_cells,
        tokens,
        artifacts,
    })
}

/// One timed cluster-grid run.
struct ClusterOutcome {
    wall_seconds: f64,
    tokens: u64,
    migrations: u64,
    scale_events: u64,
    crashes: u64,
    failovers: u64,
    requeued: u64,
    lost_pages: u64,
    artifact: Artifact,
}

/// Run the cluster (replicas x skew x arrival-profile x router-config)
/// grid under one executor.
fn run_cluster_grid(
    hw: &HardwareSpec,
    cells: &[ClusterCell],
    exec: &SweepExecutor,
) -> Result<ClusterOutcome> {
    let t0 = Instant::now();
    let results = run_cluster_sweep(hw, cells, exec)?;
    let tokens: u64 = results.iter().map(|r| r.report.tokens).sum();
    let migrations: u64 = results.iter().map(|r| r.report.migrations).sum();
    let scale_events: u64 = results
        .iter()
        .map(|r| r.report.scale_ups + r.report.scale_downs)
        .sum();
    let crashes: u64 = results.iter().map(|r| r.report.crashes).sum();
    let failovers: u64 = results.iter().map(|r| r.report.failovers).sum();
    let requeued: u64 = results.iter().map(|r| r.report.requeued_requests).sum();
    let lost_pages: u64 = results.iter().map(|r| r.report.lost_pages).sum();
    Ok(ClusterOutcome {
        wall_seconds: t0.elapsed().as_secs_f64(),
        tokens,
        migrations,
        scale_events,
        crashes,
        failovers,
        requeued,
        lost_pages,
        artifact: format_cluster(&results),
    })
}

fn main() -> Result<()> {
    let args = Args::parse(&["quick", "full", "skip-serial", "million"])?;
    args.reject_unknown(&[
        "quick",
        "full",
        "skip-serial",
        "million",
        "million-requests",
        "threads",
        "out",
        "backend",
    ])?;
    let out_path = args.get_or("out", "target/bench/BENCH_sweep.json").to_string();

    // `--backend` narrows every grid to one accelerator preset.  The
    // candidate list is npu|gpu — host-cpu is a contextualization
    // preset, not a figure axis.  Absent, behavior (and the figure
    // artifacts) match the historical two-leg sweep exactly.
    let backend = match args.get_choice("backend", &["npu", "gpu"])? {
        Some(name) => Some(Backend::parse(name)?),
        None => None,
    };
    let figs: Vec<(&'static str, HardwareSpec)> = match backend {
        None => vec![("fig2", ascend_npu()), ("fig3", gpu_h800())],
        Some(Backend::Npu) => vec![("fig2", ascend_npu())],
        Some(Backend::Gpu) => vec![("fig3", Backend::Gpu.preset())],
        Some(Backend::Cpu) => unreachable!("cpu is filtered by get_choice"),
    };
    let cluster_hw = backend.map_or_else(ascend_npu, |b| b.preset());

    // Batch set + request cap per mode.
    let (batches, factor): (Vec<usize>, Option<usize>) = if args.flag("quick") {
        (vec![64, 128], Some(2))
    } else if args.flag("full") {
        (PAPER_BATCHES.to_vec(), None)
    } else {
        (PAPER_BATCHES.to_vec(), Some(4))
    };

    let parallel = match args.get("threads") {
        Some(_) => SweepExecutor::with_threads(args.get_usize("threads", 0)?),
        None => SweepExecutor::from_env(),
    };
    let cells = throughput_cells(&paper_models(), &batches, factor);
    eprintln!(
        "[bench_sweep] {} cells/figure x {} figure(s) x 3 kernels, {} worker(s)",
        cells.len(),
        figs.len(),
        parallel.threads
    );

    let par = run_sweep(&figs, &cells, batches.len(), &parallel)?;
    println!(
        "parallel: {:.3}s wall, {} cells, {} tokens simulated",
        par.wall_seconds, par.cells, par.tokens
    );

    // The cluster grid (now including the autoscaled affinity column
    // and the bursty arrival rows): timed and byte-identity-asserted
    // like the figure sweeps (smaller request budget in --quick mode).
    let cluster_requests = if args.flag("quick") { 256 } else { 512 };
    let cl_cells = cluster_cells(
        &deepseek_v3(),
        &CLUSTER_REPLICAS,
        &CLUSTER_SKEWS,
        &CLUSTER_ARRIVALS,
        CLUSTER_TENANTS,
        128,
        cluster_requests,
    );
    let cl = run_cluster_grid(&cluster_hw, &cl_cells, &parallel)?;
    println!(
        "cluster:  {:.3}s wall, {} cells, {} tokens simulated, {} migrations, \
         {} scale events, {} crashes ({} failovers, {} re-queued, {} pages lost)",
        cl.wall_seconds,
        cl_cells.len(),
        cl.tokens,
        cl.migrations,
        cl.scale_events,
        cl.crashes,
        cl.failovers,
        cl.requeued,
        cl.lost_pages
    );

    // Per-backend B_theta crossover grid (kernel registry, DESIGN.md
    // §16): the analytic pairwise Eq. 1 thresholds next to the numeric
    // priced-curve scan, timed and byte-identity-asserted like every
    // other grid.  `--backend` narrows the axis to one preset.
    let xover_backends: Vec<Backend> = match backend {
        Some(b) => vec![b],
        None => CROSSOVER_BACKENDS.to_vec(),
    };
    let x_cells = crossover_cells(&xover_backends, &paper_models(), 4096);
    let t0 = Instant::now();
    let x_results = run_crossover_sweep(&x_cells, &parallel)?;
    let x_wall = t0.elapsed().as_secs_f64();
    let x_art = format_crossover(&x_results);
    println!(
        "crossover: {:.3}s wall, {} cells over {} backend(s)",
        x_wall,
        x_cells.len(),
        xover_backends.len()
    );

    // `--million`: one large prefix-affinity cell driven through the
    // indexed event core with parallel replica stepping (DESIGN.md
    // §15) — the throughput probe of the event loop itself.  The
    // Poisson rate is calibrated against fleet capacity from a short
    // batch-protocol pilot (deterministic: modeled time only), so the
    // cell runs near saturation with bounded queues and the sequence
    // arena proves out its O(max outstanding) memory claim.
    let million_fields = if args.flag("million") {
        let requests = args.get_usize("million-requests", 1_000_000)?;
        ensure!(requests > 0, "--million-requests must be positive");
        let mut p = ClusterParams::new(
            deepseek_v3(),
            ascend_npu(),
            8,
            RouterPolicy::PrefixAffinity,
            128,
            8,
            1.0,
        );
        p.total_requests = requests.min(4096);
        let pilot = run_cluster_experiment(&p)?;
        let capacity = pilot.requests_completed as f64 / pilot.makespan.max(1e-9);
        let rate = 0.9 * capacity;
        p.total_requests = requests;
        p.arrival_rate = Some(rate);

        let mut sim = ClusterSim::new(&p)?;
        let t0 = Instant::now();
        sim.run_parallel()?;
        let wall = t0.elapsed().as_secs_f64();
        let report = sim.report();
        ensure!(
            report.requests_completed as usize == requests,
            "million cell dropped requests: {} of {requests}",
            report.requests_completed
        );
        let events = sim.events_processed();
        let eps = events as f64 / wall.max(1e-12);
        ensure!(sim.pool_windows() > 0, "million: pooled dispatch never engaged");
        let (hits, misses) = sim.price_cache_stats();
        println!(
            "million:  {wall:.3}s wall, {requests} requests, {events} events \
             ({eps:.0} events/s), arena peak {}, {} spills, price cache \
             {hits} hits / {misses} misses, {} pool windows",
            sim.arena_peak(),
            report.spills,
            sim.pool_windows(),
        );

        // Reference dispatch: the same parallel event loop on the
        // retained per-window `thread::scope` spawn path (the pre-pool
        // hot path).  Byte-identical by construction; CI gates on the
        // pooled path at least matching this throughput.
        let mut reference = ClusterSim::new(&p)?;
        reference.use_spawn_reference(true);
        let t0 = Instant::now();
        reference.run_parallel()?;
        let ref_wall = t0.elapsed().as_secs_f64();
        let rr = reference.report();
        ensure!(rr.tokens == report.tokens, "million: spawn-reference tokens diverged");
        ensure!(
            rr.makespan.to_bits() == report.makespan.to_bits(),
            "million: spawn-reference makespan diverged"
        );
        ensure!(
            reference.events_processed() == events,
            "million: spawn-reference event totals diverged"
        );
        ensure!(
            reference.pool_windows() == 0,
            "million: the spawn-reference path must not touch the pool"
        );
        let eps_ref = reference.events_processed() as f64 / ref_wall.max(1e-12);
        println!(
            "million reference: {ref_wall:.3}s wall ({eps_ref:.0} events/s on \
             spawn-per-window dispatch, byte-identical)"
        );

        let mut extra = vec![
            ("million_requests", Json::num(requests as f64)),
            ("million_events", Json::num(events as f64)),
            ("events_per_second", Json::num(eps)),
            ("events_per_second_reference", Json::num(eps_ref)),
            ("million_wall_seconds", Json::num(wall)),
            ("million_arena_peak", Json::num(sim.arena_peak() as f64)),
            ("million_arrival_rate", Json::num(rate)),
            ("million_tokens", Json::num(report.tokens as f64)),
            ("price_cache_hits", Json::num(hits as f64)),
            ("price_cache_misses", Json::num(misses as f64)),
            ("pool_windows", Json::num(sim.pool_windows() as f64)),
        ];
        if !args.flag("skip-serial") {
            // The serial event loop must replay the cell
            // byte-identically — the same identity the fuzz suite
            // asserts, on the bench cell itself.
            let mut serial = ClusterSim::new(&p)?;
            let t0 = Instant::now();
            serial.run()?;
            let serial_wall = t0.elapsed().as_secs_f64();
            let sr = serial.report();
            ensure!(sr.tokens == report.tokens, "million: token totals diverged");
            ensure!(
                sr.makespan.to_bits() == report.makespan.to_bits(),
                "million: makespan diverged"
            );
            ensure!(serial.events_processed() == events, "million: event totals diverged");
            let speedup = serial_wall / wall.max(1e-12);
            println!(
                "million serial: {serial_wall:.3}s wall ({speedup:.2}x parallel \
                 speedup, byte-identical)"
            );
            extra.push(("million_serial_wall_seconds", Json::num(serial_wall)));
            extra.push(("million_speedup", Json::num(speedup)));
        }
        extra
    } else {
        Vec::new()
    };

    let mut fields: Vec<(&str, Json)> = vec![
        ("wall_seconds", Json::num(par.wall_seconds)),
        ("cells", Json::num(par.cells as f64)),
        ("tokens_simulated", Json::num(par.tokens as f64)),
        ("threads", Json::num(parallel.threads as f64)),
        ("quick", Json::Bool(args.flag("quick"))),
        ("backend", Json::str(backend.map_or("all", |b| b.as_str()))),
        ("crossover_wall_seconds", Json::num(x_wall)),
        ("crossover_cells", Json::num(x_cells.len() as f64)),
        ("cluster_wall_seconds", Json::num(cl.wall_seconds)),
        ("cluster_cells", Json::num(cl_cells.len() as f64)),
        ("cluster_row_width", Json::num(cluster_row_configs().len() as f64)),
        ("cluster_tokens_simulated", Json::num(cl.tokens as f64)),
        ("cluster_migrations", Json::num(cl.migrations as f64)),
        ("cluster_scale_events", Json::num(cl.scale_events as f64)),
        ("cluster_crashes", Json::num(cl.crashes as f64)),
        ("cluster_failovers", Json::num(cl.failovers as f64)),
        ("cluster_requeued", Json::num(cl.requeued as f64)),
        ("cluster_lost_pages", Json::num(cl.lost_pages as f64)),
    ];
    // Pin the per-backend registry B_theta (DeepSeek-v3, s_q = 1,
    // single-device) into the artifact so threshold drift shows up in
    // the tracked perf trajectory, not just in tests.
    for b in &xover_backends {
        let key = match b {
            Backend::Npu => "b_theta_npu",
            Backend::Gpu => "b_theta_gpu",
            Backend::Cpu => "b_theta_cpu",
        };
        let theta =
            parallel_batch_threshold(&deepseek_v3(), &b.preset(), 1, &ParallelismConfig::single());
        fields.push((key, Json::num(theta as f64)));
    }
    fields.extend(million_fields);

    if !args.flag("skip-serial") {
        // Baseline: single worker + the per-sequence reference engine
        // (no memoization, no length bucketing) — the pre-optimization
        // hot path.  Its artifacts must still be byte-identical.
        let mut baseline_cells = cells.clone();
        for c in &mut baseline_cells {
            c.memoized = false;
        }
        let serial = run_sweep(&figs, &baseline_cells, batches.len(), &SweepExecutor::serial())?;
        println!(
            "serial/unmemoized: {:.3}s wall, {} cells, {} tokens simulated",
            serial.wall_seconds, serial.cells, serial.tokens
        );
        // The whole point of ordered collection: artifacts must be
        // byte-identical between the serial and parallel paths.
        ensure!(
            serial.artifacts.len() == par.artifacts.len(),
            "artifact count diverged"
        );
        for (s, p) in serial.artifacts.iter().zip(&par.artifacts) {
            ensure!(s.text == p.text, "{}: text artifact diverged", s.id);
            ensure!(s.csv == p.csv, "{}: csv artifact diverged", s.id);
        }
        ensure!(serial.tokens == par.tokens, "token totals diverged");
        let speedup = serial.wall_seconds / par.wall_seconds.max(1e-12);
        println!("speedup:           {speedup:.2}x (artifacts byte-identical)");
        fields.push(("serial_wall_seconds", Json::num(serial.wall_seconds)));
        fields.push(("speedup", Json::num(speedup)));
        fields.push(("artifacts_identical", Json::Bool(true)));

        // Cluster grid byte-identity: serial run of the same cells must
        // reproduce the parallel artifact exactly — including every
        // migration and scale decision.
        let cl_serial = run_cluster_grid(&cluster_hw, &cl_cells, &SweepExecutor::serial())?;
        ensure!(
            cl_serial.artifact.text == cl.artifact.text,
            "cluster: text artifact diverged"
        );
        ensure!(
            cl_serial.artifact.csv == cl.artifact.csv,
            "cluster: csv artifact diverged"
        );
        ensure!(cl_serial.tokens == cl.tokens, "cluster token totals diverged");
        ensure!(
            cl_serial.migrations == cl.migrations,
            "cluster migration counts diverged"
        );
        ensure!(
            cl_serial.scale_events == cl.scale_events,
            "cluster scale-event counts diverged"
        );
        // Fault schedules are seeded off the cell, never the executor:
        // every crash and failover must replay identically.
        ensure!(cl_serial.crashes == cl.crashes, "cluster crash counts diverged");
        ensure!(
            cl_serial.failovers == cl.failovers,
            "cluster failover counts diverged"
        );
        let cl_speedup = cl_serial.wall_seconds / cl.wall_seconds.max(1e-12);
        println!("cluster speedup:   {cl_speedup:.2}x (artifacts byte-identical)");
        fields.push(("cluster_serial_wall_seconds", Json::num(cl_serial.wall_seconds)));
        fields.push(("cluster_speedup", Json::num(cl_speedup)));

        // Crossover grid byte-identity: the serial scan must reproduce
        // the parallel artifact exactly for the selected backend axis
        // — the identity the CI backend-matrix leg gates on.
        let x_serial = format_crossover(&run_crossover_sweep(&x_cells, &SweepExecutor::serial())?);
        ensure!(x_serial.text == x_art.text, "crossover: text artifact diverged");
        ensure!(x_serial.csv == x_art.csv, "crossover: csv artifact diverged");
        println!("crossover: serial scan byte-identical");
        fields.push(("crossover_identical", Json::Bool(true)));
    }

    let json = Json::obj(fields);
    if let Some(dir) = std::path::Path::new(&out_path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&out_path, json.to_string_pretty())?;
    // Tracked copy at the repo root, so the perf trajectory survives
    // across PRs in version control.  Resolved at *runtime*: the
    // topmost Cargo.toml-bearing ancestor of the cwd (the workspace
    // root under `cargo run`); skipped with a note when the binary
    // runs outside any checkout.
    match workspace_root() {
        Some(root) => {
            let root_copy = root.join("BENCH_sweep.json");
            std::fs::write(&root_copy, json.to_string_pretty())?;
            eprintln!("[bench_sweep] wrote {out_path} and {}", root_copy.display());
        }
        None => eprintln!(
            "[bench_sweep] wrote {out_path} (no Cargo.toml ancestor; tracked copy skipped)"
        ),
    }
    Ok(())
}

/// The topmost ancestor of the cwd containing a `Cargo.toml` (the
/// workspace root when invoked via cargo), or None outside a checkout.
fn workspace_root() -> Option<std::path::PathBuf> {
    let mut dir = std::env::current_dir().ok()?;
    let mut found = None;
    loop {
        if dir.join("Cargo.toml").exists() {
            found = Some(dir.clone());
        }
        if !dir.pop() {
            return found;
        }
    }
}
