//! Regenerate every table and figure of the paper's evaluation.
//!
//! Usage:
//!   figures all [--out DIR] [--full]      # everything
//!   figures table1|eq1|table3|fig2|...|fig8|tenants|cluster|crossover
//!
//! `--full` runs the throughput sweeps over whole dataset splits (the
//! paper's protocol); the default caps requests at 4x batch per cell so
//! the full grid finishes in seconds.

use anyhow::{bail, Result};
use typhoon_mla::analysis::{figures, tables, Artifact};
use typhoon_mla::simulator::SweepExecutor;
use typhoon_mla::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(&["full"])?;
    let which = args.subcommand.clone().unwrap_or_else(|| "all".to_string());
    let out = args.get_or("out", "target/figures").to_string();
    let cap = if args.flag("full") { None } else { Some(4) };
    let cap_reqs = if args.flag("full") { None } else { Some(512) };

    let mut artifacts: Vec<Artifact> = Vec::new();
    let all = which == "all";
    if all || which == "table1" {
        artifacts.push(tables::table1());
    }
    if all || which == "eq1" {
        artifacts.push(tables::eq1());
    }
    if all || which == "fig2" {
        artifacts.push(figures::fig2(cap)?);
    }
    if all || which == "fig3" {
        artifacts.push(figures::fig3(cap)?);
    }
    if all || which == "fig4" {
        artifacts.push(figures::fig4());
    }
    if all || which == "table3" {
        artifacts.push(tables::table3(cap_reqs)?);
    }
    if all || which == "fig5" {
        artifacts.push(figures::fig5());
    }
    if all || which == "fig6" {
        artifacts.push(figures::fig6());
    }
    if all || which == "fig7" {
        artifacts.push(figures::fig7());
    }
    if all || which == "fig8" {
        artifacts.push(figures::fig8()?);
    }
    if all || which == "tenants" {
        artifacts.push(figures::fig_tenants(cap, &SweepExecutor::from_env())?);
    }
    if all || which == "cluster" {
        artifacts.push(figures::fig_cluster(cap, &SweepExecutor::from_env())?);
    }
    if all || which == "crossover" {
        artifacts.push(figures::fig_crossover(&SweepExecutor::from_env())?);
    }
    if artifacts.is_empty() {
        bail!(
            "unknown artifact {which:?} \
             (all|table1|eq1|table3|fig2..fig8|tenants|cluster|crossover)"
        );
    }

    let dir = std::path::Path::new(&out);
    for a in &artifacts {
        a.print();
        a.write(dir)?;
    }
    eprintln!("[figures] wrote {} artifacts to {}", artifacts.len(), out);
    Ok(())
}
