//! Real-execution engine: drives the AOT-compiled tiny MLA transformer
//! on the PJRT CPU client.  Implements the coordinator's `Engine` trait
//! so the same serving loop runs against real numerics (here) or the
//! cost-model simulator (`simulator::SimEngine`).
//!
//! The engine owns the canonical host-side latent KV cache (layers x
//! slots x L_n x D) and scatters each decode step's returned entries —
//! Python never touches the request path.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};
use xla::Literal;

use crate::config::KernelKind;
use crate::coordinator::{DecodeBatch, Engine, IterationOutcome, PrefillRequest};
use crate::kvcache::{PrefixId, SeqId};
use crate::metrics::BreakdownTimers;
use crate::util::rng::Rng;

use super::client::{literal_i32, to_vec_f32, to_vec_i32, PjrtRuntime};

struct SharedState {
    len: i32,
    /// Latent form [Lyr,Ls,Dl]/[Lyr,Ls,Dr] (absorb path).
    ckv: Literal,
    krope: Literal,
    /// Uncompressed form [Lyr,Ls,H,Dqk]/[Lyr,Ls,H,Dv] (typhoon/naive).
    k: Literal,
    v: Literal,
}

pub struct TinyModelEngine {
    rt: PjrtRuntime,
    /// Default kernel this engine was configured for (informational;
    /// the per-iteration kernel comes from the DecodeBatch).
    pub variant: KernelKind,
    // Artifact dims.
    b: usize,
    ls: usize,
    ln: usize,
    lq: usize,
    layers: usize,
    dl: usize,
    dr: usize,
    vocab: u32,
    weights: Vec<Literal>,
    shared: Option<SharedState>,
    // Slot state.
    slot_of: HashMap<SeqId, usize>,
    free_slots: Vec<usize>,
    lengths: Vec<i32>,
    last_token: Vec<i32>,
    // Host latent caches, row-major [layers][b][ln][d].
    ckv: Vec<f32>,
    krope: Vec<f32>,
    /// Generated token history per sequence (for the examples).
    pub generated: HashMap<SeqId, Vec<i32>>,
    decode_names: HashMap<KernelKind, String>,
    prefill_shared_name: String,
    prefill_req_name: String,
}

impl TinyModelEngine {
    /// Build from the artifacts directory; `variant` picks which shared
    /// cache layout decode iterations default to (the policy may still
    /// request absorb fall-back at runtime — both caches are retained).
    pub fn new(artifacts_dir: impl Into<std::path::PathBuf>, variant: KernelKind) -> Result<Self> {
        let rt = PjrtRuntime::new(artifacts_dir)?;
        let decode_infos = rt.manifest.select("decode_step", None, Some("tiny"));
        if decode_infos.is_empty() {
            bail!("no tiny decode_step artifacts; run `make artifacts`");
        }
        let mut decode_names = HashMap::new();
        for info in &decode_infos {
            if let Some(v) = &info.variant {
                decode_names.insert(KernelKind::parse(v)?, info.name.clone());
            }
        }
        let d0 = decode_infos[0];
        let (b, ls, ln) = (d0.dim("b")?, d0.dim("ls")?, d0.dim("ln")?);
        // decode inputs: ckv cache is input 5 for typhoon/naive layouts.
        let prefill_shared = rt
            .manifest
            .select("prefill_shared", None, Some("tiny"))
            .first()
            .map(|a| a.name.clone())
            .ok_or_else(|| anyhow!("no prefill_shared artifact"))?;
        let prefill_req_info = *rt
            .manifest
            .select("prefill_requests", None, Some("tiny"))
            .first()
            .ok_or_else(|| anyhow!("no prefill_requests artifact"))?;
        let lq = prefill_req_info.dim("lq")?;
        let prefill_req = prefill_req_info.name.clone();
        // Cache dims from the decode artifact's ckv input (index 5).
        let typhoon_name = decode_names
            .get(&KernelKind::Typhoon)
            .ok_or_else(|| anyhow!("missing typhoon decode artifact"))?;
        let tinfo = rt.manifest.find(typhoon_name)?;
        let ckv_spec = &tinfo.inputs[5];
        let krope_spec = &tinfo.inputs[6];
        let (layers, dl) = (ckv_spec.shape[0], ckv_spec.shape[3]);
        let dr = krope_spec.shape[3];

        let weights = rt.load_weights("tiny")?;
        let vocab = 256;
        Ok(TinyModelEngine {
            rt,
            variant,
            b,
            ls,
            ln,
            lq,
            layers,
            dl,
            dr,
            vocab,
            weights,
            shared: None,
            slot_of: HashMap::new(),
            free_slots: (0..b).rev().collect(),
            lengths: vec![0; b],
            last_token: vec![0; b],
            ckv: vec![0.0; layers * b * ln * dl],
            krope: vec![0.0; layers * b * ln * dr],
            generated: HashMap::new(),
            decode_names,
            prefill_shared_name: prefill_shared,
            prefill_req_name: prefill_req,
        })
    }

    pub fn compile_seconds(&self) -> f64 {
        self.rt.compile_seconds
    }

    pub fn dims(&self) -> (usize, usize, usize, usize) {
        (self.b, self.ls, self.ln, self.lq)
    }

    /// Deterministic synthetic question tokens for a sequence
    /// (workload substitution: content-free throughput benchmarks).
    fn question_tokens(&self, seq: SeqId, len: usize) -> Vec<i32> {
        let mut rng = Rng::new(0x5E9_u64 ^ seq.wrapping_mul(0x9E3779B97F4A7C15));
        (0..len).map(|_| rng.gen_range(1, self.vocab as u64) as i32).collect()
    }

    fn weight_refs(&self) -> Vec<&Literal> {
        self.weights.iter().collect()
    }

    fn cache_literals(&self) -> Result<(Literal, Literal)> {
        use super::client::literal_f32;
        Ok((
            literal_f32(&[self.layers, self.b, self.ln, self.dl], &self.ckv)?,
            literal_f32(&[self.layers, self.b, self.ln, self.dr], &self.krope)?,
        ))
    }
}

impl Engine for TinyModelEngine {
    #[allow(clippy::disallowed_methods)]
    fn prepare_shared(
        &mut self,
        _prefix: PrefixId,
        tokens: &[u32],
        _kernel: KernelKind,
    ) -> Result<f64> {
        // detlint: allow(wall-clock, real PJRT execution is timed, not simulated)
        let t0 = Instant::now();
        // Compile everything up front so decode wall-times are clean.
        let names: Vec<String> = std::iter::once(self.prefill_shared_name.clone())
            .chain(std::iter::once(self.prefill_req_name.clone()))
            .chain(self.decode_names.values().cloned())
            .collect();
        for n in &names {
            self.rt.load(n)?;
        }
        let shared_len = tokens.len().min(self.ls);
        let mut padded: Vec<i32> = tokens.iter().take(shared_len).map(|&t| t as i32).collect();
        padded.resize(self.ls, 0);
        let tokens_l = literal_i32(&[self.ls], &padded)?;
        let len_l = literal_i32(&[1], &[shared_len as i32])?;
        let mut args: Vec<&Literal> = vec![&tokens_l, &len_l];
        let w = self.weight_refs();
        args.extend(w);
        let mut out = self.rt.execute_ref(&self.prefill_shared_name, &args)?;
        // outputs: (ckv [Lyr,Ls,Dl], krope, k [Lyr,Ls,H,Dqk], v)
        let v = out.pop().unwrap();
        let k = out.pop().unwrap();
        let krope = out.pop().unwrap();
        let ckv = out.pop().unwrap();
        self.shared = Some(SharedState { len: shared_len as i32, ckv, krope, k, v });
        Ok(t0.elapsed().as_secs_f64())
    }

    #[allow(clippy::disallowed_methods)]
    fn prefill_requests(&mut self, seqs: &[PrefillRequest]) -> Result<f64> {
        // detlint: allow(wall-clock, real PJRT execution is timed, not simulated)
        let t0 = Instant::now();
        let shared = self.shared.as_ref().ok_or_else(|| anyhow!("no shared prefix"))?;
        if seqs.len() > self.free_slots.len() {
            bail!("prefill wave {} exceeds free slots {}", seqs.len(), self.free_slots.len());
        }
        // Assign slots and build the [B, Lq] token matrix.
        let mut tokens = vec![0i32; self.b * self.lq];
        let mut qlens = vec![1i32; self.b]; // dummy slots: 1 token
        let mut wave_slots = Vec::new();
        for r in seqs {
            let slot = self.free_slots.pop().expect("checked above");
            self.slot_of.insert(r.seq, slot);
            wave_slots.push((r.seq, slot));
            let qlen = r.context_len.clamp(1, self.lq.min(self.ln));
            qlens[slot] = qlen as i32;
            let q = self.question_tokens(r.seq, qlen);
            tokens[slot * self.lq..slot * self.lq + qlen].copy_from_slice(&q);
        }
        let tokens_l = literal_i32(&[self.b, self.lq], &tokens)?;
        let qlens_l = literal_i32(&[self.b], &qlens)?;
        let len_l = literal_i32(&[1], &[shared.len])?;
        let mut args: Vec<&Literal> = vec![&tokens_l, &qlens_l, &len_l, &shared.k, &shared.v];
        args.extend(self.weights.iter());
        let out = self.rt.execute_ref(&self.prefill_req_name, &args)?;
        // outputs: ckv_init [Lyr,B,Lq,Dl], krope_init [Lyr,B,Lq,Dr],
        //          first_tokens [B]
        let ckv_init = to_vec_f32(&out[0])?;
        let krope_init = to_vec_f32(&out[1])?;
        let first = to_vec_i32(&out[2])?;
        for &(seq, slot) in &wave_slots {
            let qlen = qlens[slot] as usize;
            for l in 0..self.layers {
                for p in 0..qlen {
                    let src = ((l * self.b + slot) * self.lq + p) * self.dl;
                    let dst = ((l * self.b + slot) * self.ln + p) * self.dl;
                    self.ckv[dst..dst + self.dl]
                        .copy_from_slice(&ckv_init[src..src + self.dl]);
                    let src_r = ((l * self.b + slot) * self.lq + p) * self.dr;
                    let dst_r = ((l * self.b + slot) * self.ln + p) * self.dr;
                    self.krope[dst_r..dst_r + self.dr]
                        .copy_from_slice(&krope_init[src_r..src_r + self.dr]);
                }
            }
            self.lengths[slot] = qlens[slot];
            self.last_token[slot] = first[slot];
            self.generated.entry(seq).or_default().push(first[slot]);
        }
        Ok(t0.elapsed().as_secs_f64())
    }

    #[allow(clippy::disallowed_methods)]
    fn decode(&mut self, batch: &DecodeBatch) -> Result<IterationOutcome> {
        // detlint: allow(wall-clock, real PJRT execution is timed, not simulated)
        let t0 = Instant::now();
        let shared = self.shared.as_ref().ok_or_else(|| anyhow!("no shared prefix"))?;
        // The tiny AOT artifacts bake in a single shared cache layout
        // (prepare_shared keeps only the last prefix), so this engine
        // serves single-group batches only — a multi-group batch would
        // silently attend every sequence to the wrong prefix.
        if batch.groups.len() != 1 {
            bail!(
                "tiny engine supports single-prefix batches only, got {} groups",
                batch.groups.len()
            );
        }
        let kernel = batch.groups[0].kernel;
        let name = self
            .decode_names
            .get(&kernel)
            .ok_or_else(|| anyhow!("no decode artifact for {kernel:?}"))?
            .clone();
        // Guard: every sequence's cache (suffix + 1 new token) must fit.
        for &seq in &batch.seqs {
            let slot = *self
                .slot_of
                .get(&seq)
                .ok_or_else(|| anyhow!("sequence {seq} not prefilled"))?;
            if self.lengths[slot] as usize >= self.ln {
                bail!("sequence {seq} exceeded engine cache Ln={}", self.ln);
            }
        }
        let tokens_l = literal_i32(&[self.b], &self.last_token)?;
        let lens_l = literal_i32(&[self.b], &self.lengths)?;
        let sl_l = literal_i32(&[1], &[shared.len])?;
        let (ckv_l, krope_l) = self.cache_literals()?;
        let (sa, sb): (&Literal, &Literal) = if kernel.is_absorb_family() {
            (&shared.ckv, &shared.krope)
        } else {
            (&shared.k, &shared.v)
        };
        let mut args: Vec<&Literal> = vec![&tokens_l, &lens_l, &sl_l, sa, sb, &ckv_l, &krope_l];
        args.extend(self.weights.iter());
        let out = self.rt.execute_ref(&name, &args)?;
        let next = to_vec_i32(&out[0])?;
        let new_ckv = to_vec_f32(&out[1])?; // [Lyr, B, Dl]
        let new_krope = to_vec_f32(&out[2])?; // [Lyr, B, Dr]
        // Scatter this step's entries and advance active slots only.
        for &seq in &batch.seqs {
            let slot = self.slot_of[&seq];
            let pos = self.lengths[slot] as usize;
            for l in 0..self.layers {
                let src = (l * self.b + slot) * self.dl;
                let dst = ((l * self.b + slot) * self.ln + pos) * self.dl;
                self.ckv[dst..dst + self.dl].copy_from_slice(&new_ckv[src..src + self.dl]);
                let src_r = (l * self.b + slot) * self.dr;
                let dst_r = ((l * self.b + slot) * self.ln + pos) * self.dr;
                self.krope[dst_r..dst_r + self.dr]
                    .copy_from_slice(&new_krope[src_r..src_r + self.dr]);
            }
            self.lengths[slot] += 1;
            self.last_token[slot] = next[slot];
            self.generated.entry(seq).or_default().push(next[slot]);
        }
        let seconds = t0.elapsed().as_secs_f64();
        let mut breakdown = BreakdownTimers::default();
        breakdown.other = seconds;
        Ok(IterationOutcome { seconds, breakdown })
    }

    fn release(&mut self, seq: SeqId) {
        if let Some(slot) = self.slot_of.remove(&seq) {
            self.lengths[slot] = 0;
            self.last_token[slot] = 0;
            self.free_slots.push(slot);
        }
    }

    fn max_batch(&self) -> usize {
        self.b
    }
}
