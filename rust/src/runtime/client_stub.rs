//! API-compatible stub of the PJRT client, compiled when the `pjrt`
//! cargo feature is off (the `xla` crate is unavailable in the offline
//! build environment).
//!
//! Every constructor fails with a clear error; the free helpers return
//! inert `Literal` placeholders so call sites (benches, e2e tests,
//! examples) type-check unchanged.  Code paths that would actually
//! execute kernels are only reachable after `make artifacts` +
//! `PjrtRuntime::new`, which is where the stub reports itself.

use std::path::PathBuf;

use anyhow::{bail, Result};

use super::manifest::{Dtype, Manifest, TensorSpec};

const STUB_MSG: &str =
    "typhoon_mla was built without the `pjrt` feature; real PJRT execution \
     requires the `xla` crate (see rust/Cargo.toml)";

/// Inert placeholder for `xla::Literal`.
#[derive(Clone, Debug, Default)]
pub struct Literal;

pub struct PjrtRuntime {
    pub manifest: Manifest,
    pub compile_seconds: f64,
}

impl PjrtRuntime {
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
        // Parse the manifest first so missing-artifact errors still win
        // (tests rely on that distinction), then report the stub.
        let _ = Manifest::load(artifacts_dir.into())?;
        bail!(STUB_MSG)
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    pub fn load(&mut self, _name: &str) -> Result<()> {
        bail!(STUB_MSG)
    }

    pub fn is_loaded(&self, _name: &str) -> bool {
        false
    }

    pub fn execute(&mut self, _name: &str, _args: &[&Literal]) -> Result<Vec<Literal>> {
        bail!(STUB_MSG)
    }

    pub fn execute_ref(&self, _name: &str, _args: &[&Literal]) -> Result<Vec<Literal>> {
        bail!(STUB_MSG)
    }

    pub fn load_weights(&self, _bundle: &str) -> Result<Vec<Literal>> {
        bail!(STUB_MSG)
    }
}

pub fn literal_f32(dims: &[usize], data: &[f32]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        bail!("literal_f32: {dims:?} needs {n} elems, got {}", data.len());
    }
    Ok(Literal)
}

pub fn literal_i32(dims: &[usize], data: &[i32]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        bail!("literal_i32: {dims:?} needs {n} elems, got {}", data.len());
    }
    Ok(Literal)
}

pub fn to_vec_f32(_l: &Literal) -> Result<Vec<f32>> {
    bail!(STUB_MSG)
}

pub fn to_vec_i32(_l: &Literal) -> Result<Vec<i32>> {
    bail!(STUB_MSG)
}

/// Deterministic random f32 tensor (stub: shape-checked placeholder).
pub fn random_f32(dims: &[usize], _seed: u64, _scale: f32) -> Result<Literal> {
    let _n: usize = dims.iter().product();
    Ok(Literal)
}

/// Literal for a TensorSpec (stub: dtype-checked placeholder).
pub fn random_for_spec(spec: &TensorSpec, _seed: u64, _int_hi: i32) -> Result<Literal> {
    match spec.dtype {
        Dtype::F32 | Dtype::I32 => Ok(Literal),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_runtime_reports_itself() {
        // Missing artifacts dir: the manifest error wins.
        assert!(PjrtRuntime::new("/nonexistent/path").is_err());
    }

    #[test]
    fn literal_helpers_shape_check() {
        assert!(literal_f32(&[2, 2], &[1.0; 4]).is_ok());
        assert!(literal_f32(&[2, 2], &[1.0; 3]).is_err());
        assert!(literal_i32(&[3], &[1, 2, 3]).is_ok());
    }
}
