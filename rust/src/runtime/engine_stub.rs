//! API-compatible stub of `TinyModelEngine`, compiled when the `pjrt`
//! cargo feature is off.  Construction fails with the stub message, so
//! no Engine method is ever reachable; they exist only so the serving
//! CLI, examples and e2e tests type-check without the `xla` crate.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::config::KernelKind;
use crate::coordinator::{DecodeBatch, Engine, IterationOutcome, PrefillRequest};
use crate::kvcache::{PrefixId, SeqId};

const STUB_MSG: &str =
    "typhoon_mla was built without the `pjrt` feature; real PJRT execution \
     requires the `xla` crate (see rust/Cargo.toml)";

pub struct TinyModelEngine {
    pub variant: KernelKind,
    /// Generated token history per sequence (for the examples).
    pub generated: HashMap<SeqId, Vec<i32>>,
}

impl TinyModelEngine {
    pub fn new(
        _artifacts_dir: impl Into<std::path::PathBuf>,
        _variant: KernelKind,
    ) -> Result<Self> {
        bail!(STUB_MSG)
    }

    pub fn compile_seconds(&self) -> f64 {
        0.0
    }

    pub fn dims(&self) -> (usize, usize, usize, usize) {
        (0, 0, 0, 0)
    }
}

impl Engine for TinyModelEngine {
    fn prepare_shared(
        &mut self,
        _prefix: PrefixId,
        _tokens: &[u32],
        _kernel: KernelKind,
    ) -> Result<f64> {
        bail!(STUB_MSG)
    }

    fn prefill_requests(&mut self, _seqs: &[PrefillRequest]) -> Result<f64> {
        bail!(STUB_MSG)
    }

    fn decode(&mut self, _batch: &DecodeBatch) -> Result<IterationOutcome> {
        bail!(STUB_MSG)
    }

    fn release(&mut self, _seq: SeqId) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_engine_fails_to_construct() {
        assert!(TinyModelEngine::new("/tmp", KernelKind::Typhoon).is_err());
    }
}
