//! Parser for `artifacts/manifest.json` — the contract between the
//! Python AOT compiler and the Rust runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Dtype::F32),
            "s32" | "i32" => Ok(Dtype::I32),
            _ => bail!("unsupported dtype {s:?}"),
        }
    }

    pub fn bytes(&self) -> usize {
        4
    }
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Result<Self> {
        let shape = j
            .req("shape")?
            .as_arr()
            .ok_or_else(|| anyhow!("shape not an array"))?
            .iter()
            .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = Dtype::parse(
            j.req("dtype")?.as_str().ok_or_else(|| anyhow!("dtype not a string"))?,
        )?;
        Ok(TensorSpec { shape, dtype })
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub variant: Option<String>,
    pub config: Option<String>,
    pub dims: BTreeMap<String, usize>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactInfo {
    pub fn dim(&self, name: &str) -> Result<usize> {
        self.dims
            .get(name)
            .copied()
            .ok_or_else(|| anyhow!("artifact {} has no dim {name:?}", self.name))
    }
}

#[derive(Clone, Debug)]
pub struct WeightsInfo {
    pub file: String,
    pub names: Vec<String>,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactInfo>,
    pub weights: BTreeMap<String, WeightsInfo>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let j = json::parse(text)?;
        let mut artifacts = Vec::new();
        for a in j.req("artifacts")?.as_arr().ok_or_else(|| anyhow!("artifacts not array"))? {
            let dims = a
                .get("dims")
                .and_then(|d| d.as_obj())
                .map(|m| {
                    m.iter()
                        .filter_map(|(k, v)| v.as_usize().map(|u| (k.clone(), u)))
                        .collect()
                })
                .unwrap_or_default();
            artifacts.push(ArtifactInfo {
                name: a.req("name")?.as_str().unwrap_or_default().to_string(),
                file: a.req("file")?.as_str().unwrap_or_default().to_string(),
                kind: a.req("kind")?.as_str().unwrap_or_default().to_string(),
                variant: a.get("variant").and_then(|v| v.as_str()).map(String::from),
                config: a.get("config").and_then(|v| v.as_str()).map(String::from),
                dims,
                inputs: a
                    .req("inputs")?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSpec::parse)
                    .collect::<Result<_>>()?,
                outputs: a
                    .req("outputs")?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSpec::parse)
                    .collect::<Result<_>>()?,
            });
        }
        let mut weights = BTreeMap::new();
        if let Some(w) = j.get("weights").and_then(|w| w.as_obj()) {
            for (k, v) in w {
                weights.insert(
                    k.clone(),
                    WeightsInfo {
                        file: v.req("file")?.as_str().unwrap_or_default().to_string(),
                        names: v
                            .req("names")?
                            .as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .filter_map(|x| x.as_str().map(String::from))
                            .collect(),
                    },
                );
            }
        }
        Ok(Manifest { dir, artifacts, weights })
    }

    pub fn find(&self, name: &str) -> Result<&ArtifactInfo> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))
    }

    /// All artifacts of a kind (and optionally variant/config).
    pub fn select(
        &self,
        kind: &str,
        variant: Option<&str>,
        config: Option<&str>,
    ) -> Vec<&ArtifactInfo> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == kind)
            .filter(|a| variant.is_none() || a.variant.as_deref() == variant)
            .filter(|a| config.is_none() || a.config.as_deref() == config)
            .collect()
    }

    pub fn artifact_path(&self, a: &ArtifactInfo) -> PathBuf {
        self.dir.join(&a.file)
    }
}

/// Default artifacts directory: `$TYPHOON_ARTIFACTS` or `./artifacts`
/// relative to the crate root / current dir.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("TYPHOON_ARTIFACTS") {
        return PathBuf::from(d);
    }
    for base in [".", "..", env!("CARGO_MANIFEST_DIR")] {
        let p = Path::new(base).join("artifacts");
        if p.join("manifest.json").exists() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "attn_typhoon_sim_b4_s1024_n256", "file": "a.hlo.txt",
         "kind": "attention", "variant": "typhoon", "config": "sim",
         "dims": {"b": 4, "ls": 1024, "ln": 256},
         "inputs": [{"shape": [4, 8, 64], "dtype": "f32"},
                    {"shape": [4], "dtype": "s32"}],
         "outputs": [{"shape": [4, 8, 64], "dtype": "f32"}]},
        {"name": "expand_sim_n1024", "file": "e.hlo.txt", "kind": "expand",
         "config": "sim", "dims": {"n": 1024}, "inputs": [], "outputs": []}
      ],
      "weights": {"tiny": {"file": "tiny_weights.npz", "names": ["embedding", "w_qa"]}}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.find("attn_typhoon_sim_b4_s1024_n256").unwrap();
        assert_eq!(a.dim("b").unwrap(), 4);
        assert_eq!(a.inputs[0].shape, vec![4, 8, 64]);
        assert_eq!(a.inputs[1].dtype, Dtype::I32);
        assert_eq!(m.weights["tiny"].names, vec!["embedding", "w_qa"]);
    }

    #[test]
    fn select_filters() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.select("attention", Some("typhoon"), Some("sim")).len(), 1);
        assert_eq!(m.select("attention", Some("absorb"), None).len(), 0);
        assert_eq!(m.select("expand", None, None).len(), 1);
    }

    #[test]
    fn missing_artifact_is_error() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert!(m.find("nope").is_err());
    }

    /// The real manifest (if artifacts are built) parses cleanly.
    #[test]
    fn real_manifest_if_present() {
        let dir = default_artifacts_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(!m.artifacts.is_empty());
            for a in &m.artifacts {
                assert!(m.artifact_path(a).exists(), "missing {}", a.file);
            }
        }
    }
}
