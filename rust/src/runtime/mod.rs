//! The PJRT runtime: loads AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them once on the CPU PJRT client,
//! and executes them from the Rust request path (Python is never on
//! the hot path).
//!
//! The real client/engine need the `xla` crate (bindings over
//! xla_extension), which the offline build environment does not ship.
//! They are gated behind the `pjrt` cargo feature; without it this
//! module compiles API-compatible stubs that error at construction, so
//! the rest of the stack (simulator, coordinator, figures, benches)
//! builds and runs everywhere.  `manifest` is pure JSON and always real.

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(not(feature = "pjrt"))]
#[path = "client_stub.rs"]
pub mod client;

#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
pub mod engine;

pub mod manifest;

pub use client::{literal_f32, literal_i32, random_for_spec, to_vec_f32, to_vec_i32, PjrtRuntime};
pub use engine::TinyModelEngine;
pub use manifest::{default_artifacts_dir, ArtifactInfo, Dtype, Manifest, TensorSpec};
