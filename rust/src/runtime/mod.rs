//! The PJRT runtime: loads AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them once on the CPU PJRT client,
//! and executes them from the Rust request path (Python is never on
//! the hot path).

pub mod client;
pub mod engine;
pub mod manifest;

pub use client::{literal_f32, literal_i32, random_for_spec, to_vec_f32, to_vec_i32, PjrtRuntime};
pub use engine::TinyModelEngine;
pub use manifest::{default_artifacts_dir, ArtifactInfo, Dtype, Manifest, TensorSpec};
