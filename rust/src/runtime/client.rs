//! PJRT execution client: loads AOT HLO-text artifacts, compiles them
//! once, caches the executables, and marshals literals.
//!
//! The interchange format is HLO *text* — jax >= 0.5 emits
//! HloModuleProtos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see DESIGN.md / aot.py).

use std::collections::HashMap;
use std::path::PathBuf;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};
use xla::{FromRawBytes, Literal, PjRtClient, PjRtLoadedExecutable};

use super::manifest::{ArtifactInfo, Dtype, Manifest, TensorSpec};

pub struct PjrtRuntime {
    client: PjRtClient,
    pub manifest: Manifest,
    executables: HashMap<String, PjRtLoadedExecutable>,
    /// Cumulative compile time (perf accounting).
    pub compile_seconds: f64,
}

impl PjrtRuntime {
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir.into())?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(PjrtRuntime {
            client,
            manifest,
            executables: HashMap::new(),
            compile_seconds: 0.0,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by name.
    #[allow(clippy::disallowed_methods)]
    pub fn load(&mut self, name: &str) -> Result<&PjRtLoadedExecutable> {
        if !self.executables.contains_key(name) {
            let info = self.manifest.find(name)?.clone();
            let path = self.manifest.artifact_path(&info);
            // detlint: allow(wall-clock, real XLA compile time is measured wall time)
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e}"))?;
            self.compile_seconds += t0.elapsed().as_secs_f64();
            self.executables.insert(name.to_string(), exe);
        }
        Ok(&self.executables[name])
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Execute an artifact with literal inputs; returns the flattened
    /// tuple outputs.  Compiles on first use.
    pub fn execute(&mut self, name: &str, args: &[&Literal]) -> Result<Vec<Literal>> {
        self.load(name)?;
        self.execute_ref(name, args)
    }

    /// Execute an already-loaded artifact (shared borrow — lets callers
    /// keep references into `self`-owned literals while executing).
    /// Validates argument count/shapes against the manifest first.
    pub fn execute_ref(&self, name: &str, args: &[&Literal]) -> Result<Vec<Literal>> {
        let info = self.manifest.find(name)?;
        validate_args(info, args)?;
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("{name} not loaded; call load() first"))?;
        let result = exe
            .execute::<&Literal>(args)
            .map_err(|e| anyhow!("executing {name}: {e}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e}"))?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = out.to_tuple().map_err(|e| anyhow!("untupling {name}: {e}"))?;
        if parts.len() != info.outputs.len() {
            bail!(
                "{name}: manifest promises {} outputs, runtime returned {}",
                info.outputs.len(),
                parts.len()
            );
        }
        Ok(parts)
    }

    /// Load the named weights bundle as literals in manifest order.
    pub fn load_weights(&self, bundle: &str) -> Result<Vec<Literal>> {
        let info = self
            .manifest
            .weights
            .get(bundle)
            .ok_or_else(|| anyhow!("no weights bundle {bundle:?}"))?;
        let path = self.manifest.dir.join(&info.file);
        let named: HashMap<String, Literal> =
            Literal::read_npz(&path, &())
                .map_err(|e| anyhow!("reading {path:?}: {e}"))?
                .into_iter()
                .collect();
        info.names
            .iter()
            .map(|n| {
                named
                    .get(n)
                    .map(shallow_clone)
                    .ok_or_else(|| anyhow!("weights bundle missing {n:?}"))
            })
            .collect()
    }
}

/// Literal has no Clone; round-trip through raw bytes.
fn shallow_clone(l: &Literal) -> Literal {
    let shape = l.array_shape().expect("array literal");
    let mut bytes = vec![0u8; l.size_bytes()];
    match l.ty().expect("typed literal") {
        xla::ElementType::F32 => {
            let mut v = vec![0f32; l.element_count()];
            l.copy_raw_to(&mut v).unwrap();
            bytes.copy_from_slice(bytemuck_cast_f32(&v));
        }
        xla::ElementType::S32 => {
            let mut v = vec![0i32; l.element_count()];
            l.copy_raw_to(&mut v).unwrap();
            bytes.copy_from_slice(bytemuck_cast_i32(&v));
        }
        t => panic!("unsupported literal type {t:?}"),
    }
    Literal::create_from_shape_and_untyped_data(
        l.element_type().unwrap(),
        &shape.dims().iter().map(|&d| d as usize).collect::<Vec<_>>(),
        &bytes,
    )
    .unwrap()
}

fn bytemuck_cast_f32(v: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

fn bytemuck_cast_i32(v: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

fn validate_args(info: &ArtifactInfo, args: &[&Literal]) -> Result<()> {
    if args.len() != info.inputs.len() {
        bail!(
            "{}: expected {} args, got {}",
            info.name,
            info.inputs.len(),
            args.len()
        );
    }
    for (i, (spec, arg)) in info.inputs.iter().zip(args).enumerate() {
        let shape = arg
            .array_shape()
            .with_context(|| format!("{} arg {i} not an array", info.name))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        if dims != spec.shape {
            bail!(
                "{} arg {i}: shape {:?} != manifest {:?}",
                info.name,
                dims,
                spec.shape
            );
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Host tensor helpers
// ---------------------------------------------------------------------------

/// Build an f32 literal with the given dims.
pub fn literal_f32(dims: &[usize], data: &[f32]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        bail!("literal_f32: {dims:?} needs {n} elems, got {}", data.len());
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims_i64)?)
}

/// Build an i32 literal with the given dims.
pub fn literal_i32(dims: &[usize], data: &[i32]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        bail!("literal_i32: {dims:?} needs {n} elems, got {}", data.len());
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims_i64)?)
}

/// Extract an f32 vec (row-major) from a literal.
pub fn to_vec_f32(l: &Literal) -> Result<Vec<f32>> {
    Ok(l.to_vec::<f32>()?)
}

/// Extract an i32 vec from a literal.
pub fn to_vec_i32(l: &Literal) -> Result<Vec<i32>> {
    Ok(l.to_vec::<i32>()?)
}

/// Deterministic random f32 tensor (for bench inputs).
pub fn random_f32(dims: &[usize], seed: u64, scale: f32) -> Result<Literal> {
    let mut rng = crate::util::rng::Rng::new(seed);
    let n: usize = dims.iter().product();
    let data: Vec<f32> = (0..n).map(|_| (rng.next_f32() * 2.0 - 1.0) * scale).collect();
    literal_f32(dims, &data)
}

/// Literal for a TensorSpec filled deterministically (bench inputs).
pub fn random_for_spec(spec: &TensorSpec, seed: u64, int_hi: i32) -> Result<Literal> {
    match spec.dtype {
        Dtype::F32 => random_f32(&spec.shape, seed, 0.5),
        Dtype::I32 => {
            let mut rng = crate::util::rng::Rng::new(seed);
            let data: Vec<i32> =
                (0..spec.elems()).map(|_| rng.gen_range(1, int_hi.max(2) as u64) as i32).collect();
            literal_i32(&spec.shape, &data)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = literal_f32(&[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        assert_eq!(to_vec_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(literal_f32(&[2, 2], &[1.0]).is_err());
    }

    #[test]
    fn literal_roundtrip_i32() {
        let l = literal_i32(&[4], &[7, 8, 9, 10]).unwrap();
        assert_eq!(to_vec_i32(&l).unwrap(), vec![7, 8, 9, 10]);
    }

    #[test]
    fn shallow_clone_preserves_contents() {
        let l = literal_f32(&[2, 2], &[1.0, 2.0, 3.0, 4.0]).unwrap();
        let c = shallow_clone(&l);
        assert_eq!(to_vec_f32(&c).unwrap(), to_vec_f32(&l).unwrap());
    }

    #[test]
    fn random_is_deterministic() {
        let a = random_f32(&[8], 42, 1.0).unwrap();
        let b = random_f32(&[8], 42, 1.0).unwrap();
        assert_eq!(to_vec_f32(&a).unwrap(), to_vec_f32(&b).unwrap());
    }
}
