//! HBM-footprint model (paper Fig. 5).
//!
//! Deployment: DeepSeek-v3 in FP8 (weights and KV-cache), distributed
//! over a 384-NPU CloudMatrix-style cluster with full expert
//! parallelism on MoE layers and data/tensor/sequence parallelism of
//! 24 x 4 x 4 on attention.  TyphoonMLA additionally stores the shared
//! prefix in uncompressed form — one logical copy per data-parallel
//! group (sharded across that group's TP x SP devices) — which is the
//! paper's "~3% HBM overhead".

use crate::config::ModelConfig;

#[derive(Clone, Copy, Debug)]
pub struct ClusterConfig {
    pub n_devices: u64,
    pub dp: u64,
    pub tp: u64,
    pub sp: u64,
    /// Bytes per KV-cache/weight element (1 = FP8).
    pub bytes_per_elem: f64,
    /// HBM per device, bytes.
    pub hbm_per_device: u64,
    /// Layers of KV-cache accounted.  Fig. 5 of the paper is only
    /// reproducible with per-layer KV accounting (weights could not
    /// dominate at B=4K x 32K otherwise — 61-layer KV alone would be
    /// ~4.7 TB vs 671 GB of weights), so the Fig. 5 preset uses 1.
    /// Set to `cfg.n_layers` for whole-model accounting.
    pub kv_layers: u64,
}

pub fn cloudmatrix_384() -> ClusterConfig {
    ClusterConfig {
        n_devices: 384,
        dp: 24,
        tp: 4,
        sp: 4,
        bytes_per_elem: 1.0, // FP8
        hbm_per_device: 64 * (1u64 << 30),
        kv_layers: 1,
    }
}

/// Aggregate-cluster HBM breakdown, bytes.
#[derive(Clone, Copy, Debug, Default)]
pub struct HbmFootprint {
    pub weights: f64,
    /// Non-shared (per-request) latent KV-cache.
    pub kv_non_shared: f64,
    /// Shared prefix in latent form (needed by absorb and typhoon).
    pub kv_shared_latent: f64,
    /// Shared prefix in uncompressed form (typhoon only).
    pub kv_shared_uncompressed: f64,
}

impl HbmFootprint {
    pub fn total(&self) -> f64 {
        self.weights + self.kv_non_shared + self.kv_shared_latent + self.kv_shared_uncompressed
    }
}

/// Footprint of a deployment serving `global_batch` concurrent requests
/// of up to `max_seq_len` non-shared tokens over a shared prefix of
/// `shared_len` tokens.
pub fn hbm_footprint(
    cfg: &ModelConfig,
    cluster: &ClusterConfig,
    global_batch: u64,
    max_seq_len: u64,
    shared_len: u64,
    typhoon: bool,
) -> HbmFootprint {
    let layers = cluster.kv_layers as f64;
    let be = cluster.bytes_per_elem;
    // Weights: one logical copy cluster-wide (full EP for experts;
    // attention weights are negligible at this scale and folded in).
    let weights = cfg.weight_bytes as f64;
    // Per-request latent cache lives once (its DP group), sharded inside.
    let kv_non_shared =
        global_batch as f64 * max_seq_len as f64 * cfg.latent_words() as f64 * be * layers;
    // Shared prefix, latent form: one copy per DP group.
    let kv_shared_latent =
        cluster.dp as f64 * shared_len as f64 * cfg.latent_words() as f64 * be * layers;
    // Shared prefix, uncompressed form (typhoon): one copy per DP group,
    // sharded over the group's TP x SP devices.
    let kv_shared_uncompressed = if typhoon {
        cluster.dp as f64 * shared_len as f64 * cfg.uncompressed_words() as f64 * be * layers
    } else {
        0.0
    };
    HbmFootprint { weights, kv_non_shared, kv_shared_latent, kv_shared_uncompressed }
}

/// Relative HBM overhead of TyphoonMLA vs the absorb baseline.
pub fn typhoon_overhead(
    cfg: &ModelConfig,
    cluster: &ClusterConfig,
    global_batch: u64,
    max_seq_len: u64,
    shared_len: u64,
) -> f64 {
    let base = hbm_footprint(cfg, cluster, global_batch, max_seq_len, shared_len, false).total();
    let typhoon =
        hbm_footprint(cfg, cluster, global_batch, max_seq_len, shared_len, true).total();
    typhoon / base - 1.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::deepseek_v3;

    const PROMPT_A: u64 = 26472; // Claude-4 system prompt (Table 2)

    /// Fig. 5 claim: "TyphoonMLA incurs only a minimal HBM overhead,
    /// limited to approximately 3% across a wide range of deployment
    /// scenarios" — the paper's grid is B in 4K..32K, L in 32K..256K.
    #[test]
    fn overhead_at_most_a_few_percent_on_fig5_grid() {
        let cfg = deepseek_v3();
        let cl = cloudmatrix_384();
        let mut worst: f64 = 0.0;
        for batch in [4096u64, 8192, 16384, 32768] {
            for seq in [32768u64, 65536, 131072, 262144] {
                let ov = typhoon_overhead(&cfg, &cl, batch, seq, PROMPT_A);
                assert!(ov > 0.0);
                worst = worst.max(ov);
            }
        }
        assert!(worst < 0.035, "worst-case overhead {worst}");
    }

    /// Overhead shrinks as batch/seq grow (non-shared KV dominates).
    #[test]
    fn overhead_decreases_with_scale() {
        let cfg = deepseek_v3();
        let cl = cloudmatrix_384();
        let small = typhoon_overhead(&cfg, &cl, 4096, 32768, PROMPT_A);
        let large = typhoon_overhead(&cfg, &cl, 32768, 262144, PROMPT_A);
        assert!(large < small);
    }

    /// At small scale the weights dominate the footprint.
    #[test]
    fn weights_dominate_small_configs() {
        let cfg = deepseek_v3();
        let cl = cloudmatrix_384();
        let f = hbm_footprint(&cfg, &cl, 1024, 8192, PROMPT_A, false);
        assert!(f.weights > f.kv_non_shared);
    }

    /// The uncompressed shared prefix is H*(D_qk+D_v)/(D_l+D_r) ≈ 71x the
    /// latent copy — the reason the naive baseline cannot cache-expand
    /// everything.
    #[test]
    fn uncompressed_expansion_ratio() {
        let cfg = deepseek_v3();
        let cl = cloudmatrix_384();
        let f = hbm_footprint(&cfg, &cl, 4096, 32768, PROMPT_A, true);
        let ratio = f.kv_shared_uncompressed / f.kv_shared_latent;
        assert!((ratio - 71.1).abs() < 0.5, "{ratio}");
    }
}
