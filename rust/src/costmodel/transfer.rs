//! Cross-replica prefix movement costs: what it takes to re-home a
//! prefix group's pages over the scale-up interconnect versus
//! rebuilding them with a fresh prefill.
//!
//! The cluster router's migrate-vs-spill rule compares exactly these
//! two quantities: spilling a hot group's overflow re-prefills the
//! shared prefix on the peer (quadratic compute in `L_s`), while
//! migration streams the already-materialized pages (linear bytes over
//! `HardwareSpec::interconnect_bw`).  For paper-scale prefixes the
//! transfer is milliseconds where the re-prefill is tens — but the rule
//! stays cost-driven, so a slow interconnect flips it back to spilling.

use crate::config::{HardwareSpec, ModelConfig};

use super::parallel::ParallelismConfig;

/// Bytes each rank pair must stream to re-home a prefix group: every
/// source rank sends its shard to the matching destination rank over
/// its own link, so the wall clock sees the *per-pair* payload.  SP
/// shards both cache forms by length; the uncompressed naive-stage
/// copy (present when the group is expanded) additionally shards by
/// heads under TP, while the latent copy is head-shared — every TP
/// rank holds (and therefore streams) its full-length share.  At
/// `single()` this is simply the whole group's bytes, matching the
/// `/ ranks` sharding `shared_prefill_seconds` applies to the
/// competing re-prefill — the migrate-vs-spill rule compares like with
/// like on sharded fleets.
pub fn prefix_transfer_bytes(
    cfg: &ModelConfig,
    hw: &HardwareSpec,
    tokens: usize,
    expanded: bool,
    par: &ParallelismConfig,
) -> f64 {
    let latent = tokens as f64 * cfg.latent_words() as f64 / par.sp as f64;
    let uncompressed = if expanded {
        tokens as f64 * cfg.uncompressed_words() as f64 / par.ranks() as f64
    } else {
        0.0
    };
    (latent + uncompressed) * hw.bytes_per_word
}

/// Modeled seconds to stream a prefix group's pages replica-to-replica
/// (rank pairs transfer concurrently; the per-pair payload bounds the
/// wall time).
pub fn prefix_transfer_seconds(
    cfg: &ModelConfig,
    hw: &HardwareSpec,
    tokens: usize,
    expanded: bool,
    par: &ParallelismConfig,
) -> f64 {
    prefix_transfer_bytes(cfg, hw, tokens, expanded, par) / hw.interconnect_bw
}

/// Modeled seconds to rebuild a shared prefix from its tokens: causal
/// naive prefill over `L_s` tokens (~L_s^2/2 context pairs), sharded
/// over the stack's ranks — the same formulation
/// `SimEngine::prepare_shared` charges, so the migrate-vs-spill rule
/// prices the spill path with the engine's own prefill model.
pub fn shared_prefill_seconds(
    cfg: &ModelConfig,
    hw: &HardwareSpec,
    tokens: usize,
    ranks: u64,
) -> f64 {
    let ls = tokens as f64;
    0.5 * ls * ls * cfg.naive_factor() as f64 / ranks as f64 / hw.macs_per_sec()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::ascend_npu;
    use crate::config::model::deepseek_v3;

    fn single() -> ParallelismConfig {
        ParallelismConfig::single()
    }

    #[test]
    fn transfer_bytes_count_both_cache_forms() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        let latent_only = prefix_transfer_bytes(&cfg, &hw, 1000, false, &single());
        let both = prefix_transfer_bytes(&cfg, &hw, 1000, true, &single());
        assert_eq!(latent_only, 1000.0 * 576.0 * 2.0);
        assert_eq!(both, 1000.0 * (576.0 + 40960.0) * 2.0);
    }

    /// Sharding the transfer mirrors the cache layout: SP shards both
    /// forms by length, TP shards only the head-carrying uncompressed
    /// copy (the latent stream is head-shared and stays replicated).
    #[test]
    fn transfer_shards_like_the_caches() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        let sp4 = ParallelismConfig { tp: 1, sp: 4 };
        assert_eq!(
            prefix_transfer_bytes(&cfg, &hw, 1000, true, &sp4) * 4.0,
            prefix_transfer_bytes(&cfg, &hw, 1000, true, &single())
        );
        let tp4 = ParallelismConfig { tp: 4, sp: 1 };
        let latent = 1000.0 * 576.0 * 2.0;
        let unc = 1000.0 * 40960.0 * 2.0;
        assert_eq!(
            prefix_transfer_bytes(&cfg, &hw, 1000, true, &tp4),
            latent + unc / 4.0,
            "TP replicates latent, shards uncompressed"
        );
    }

    /// Paper-scale Prompt A (26472 tokens, expanded): the page transfer
    /// is milliseconds where the re-prefill is tens of milliseconds —
    /// the structural reason migration beats per-request spilling.
    /// The ordering survives TP/SP sharding because both sides shard.
    #[test]
    fn transfer_beats_reprefill_at_paper_scale() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        let transfer = prefix_transfer_seconds(&cfg, &hw, 26472, true, &single());
        let prefill = shared_prefill_seconds(&cfg, &hw, 26472, 1);
        assert!(transfer < 0.02, "transfer {transfer}s");
        assert!(prefill > 0.05, "prefill {prefill}s");
        assert!(transfer < prefill);
        let par = ParallelismConfig { tp: 4, sp: 4 };
        let transfer16 = prefix_transfer_seconds(&cfg, &hw, 26472, true, &par);
        let prefill16 = shared_prefill_seconds(&cfg, &hw, 26472, par.ranks());
        assert!(transfer16 < prefill16, "{transfer16} vs {prefill16} at TP4xSP4");
    }

    /// A slow interconnect flips the rule: on a PCIe-class link the
    /// stream of a short prefix costs more than recomputing it.
    #[test]
    fn slow_interconnect_flips_to_reprefill() {
        let cfg = deepseek_v3();
        let mut hw = ascend_npu();
        hw.interconnect_bw = 1e6; // pathologically slow link
        let transfer = prefix_transfer_seconds(&cfg, &hw, 64, false, &single());
        let prefill = shared_prefill_seconds(&cfg, &hw, 64, 1);
        assert!(transfer > prefill);
    }

    /// Prefill shards over ranks exactly like the engine's model.
    #[test]
    fn prefill_shards_over_ranks() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        let one = shared_prefill_seconds(&cfg, &hw, 4096, 1);
        let sixteen = shared_prefill_seconds(&cfg, &hw, 4096, 16);
        assert!((one / sixteen - 16.0).abs() < 1e-9);
    }
}
