//! The paper's analytical model: Table 1 operation counts, Eq. 1
//! fall-back threshold, roofline analysis (Fig. 6), execution-time
//! estimation (Figs. 4/7/8) and the HBM-footprint model (Fig. 5).

pub mod exec_time;
pub mod flops;
pub mod memory;
pub mod parallel;
pub mod roofline;
pub mod surface;
pub mod table;
pub mod threshold;
pub mod transfer;

pub use exec_time::{attention_time, time_breakdown, tokens_per_sec, TimeBreakdown};
pub use flops::{amla_macs, attention_cost, AttentionWorkload, Component, CostBreakdown};
pub use surface::PriceSurface;
pub use table::{BackendId, CostTable, PriceTable};
pub use parallel::{
    parallel_attention_time, parallel_batch_threshold, parallel_batch_threshold_exact,
    parallel_pair_threshold, parallel_pair_threshold_exact, scaling_efficiency,
    ParallelismConfig,
};
pub use memory::{cloudmatrix_384, hbm_footprint, typhoon_overhead, ClusterConfig};
pub use roofline::{ridge_batch, roofline_curve, roofline_point, RooflinePoint};
pub use threshold::{batch_threshold, batch_threshold_exact, use_typhoon};
pub use transfer::{prefix_transfer_bytes, prefix_transfer_seconds, shared_prefill_seconds};
