//! Roofline execution-time estimation (paper §3.2, Appendix A.2).
//!
//! Each component of the attention computation takes
//! `max(macs / MAC-throughput, words / word-bandwidth)` — the roofline
//! bound — and components execute back-to-back (they are separate
//! kernels / kernel stages on real hardware).

use crate::config::{HardwareSpec, KernelKind, ModelConfig};

use super::flops::{attention_cost, AttentionWorkload, Component, CostBreakdown};

/// Roofline time of a single component, in seconds.
pub fn component_time(c: &Component, hw: &HardwareSpec) -> f64 {
    let compute = c.macs as f64 / hw.macs_per_sec();
    let memory = c.hbm_words as f64 / hw.words_per_sec();
    compute.max(memory)
}

/// Per-component execution-time breakdown, seconds.
#[derive(Clone, Copy, Debug, Default)]
pub struct TimeBreakdown {
    pub shared: f64,
    pub non_shared: f64,
    pub proj_kvb1: f64,
    pub proj_kvb2: f64,
    pub combine: f64,
}

impl TimeBreakdown {
    pub fn total(&self) -> f64 {
        self.shared + self.non_shared + self.proj_kvb1 + self.proj_kvb2 + self.combine
    }
}

pub fn time_breakdown(cost: &CostBreakdown, hw: &HardwareSpec) -> TimeBreakdown {
    TimeBreakdown {
        shared: component_time(&cost.shared, hw),
        non_shared: component_time(&cost.non_shared, hw),
        proj_kvb1: component_time(&cost.proj_kvb1, hw),
        proj_kvb2: component_time(&cost.proj_kvb2, hw),
        combine: component_time(&cost.combine, hw),
    }
}

/// Estimated attention time for one decode iteration, seconds.
pub fn attention_time(
    cfg: &ModelConfig,
    kind: KernelKind,
    wl: &AttentionWorkload,
    hw: &HardwareSpec,
) -> f64 {
    time_breakdown(&attention_cost(cfg, kind, wl), hw).total()
}

/// Decode throughput in generated tokens per second per layer
/// (the y-axis of the paper's Figs. 2-3): batch tokens per iteration
/// divided by the iteration's attention time.
pub fn tokens_per_sec(
    cfg: &ModelConfig,
    kind: KernelKind,
    wl: &AttentionWorkload,
    hw: &HardwareSpec,
) -> f64 {
    wl.batch as f64 / attention_time(cfg, kind, wl, hw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::ascend_npu;
    use crate::config::model::deepseek_v3;

    /// Appendix A.2 / Fig. 7: on the shared part, absorb time grows
    /// linearly with batch while naive stays flat until ~B=128; naive
    /// overtakes absorb past B≈64.
    #[test]
    fn fig7_crossover_on_shared_part() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        let shared_time = |kind, b| {
            let wl = AttentionWorkload::decode(b, 4096, 0);
            time_breakdown(&attention_cost(&cfg, kind, &wl), &hw).shared
        };
        // Small batch: absorb faster on shared part.
        assert!(shared_time(KernelKind::Absorb, 8) < shared_time(KernelKind::Naive, 8));
        // Large batch: naive (= typhoon stage 1) faster.
        assert!(shared_time(KernelKind::Naive, 256) < shared_time(KernelKind::Absorb, 256));
        // Naive flat between B=1 and B=32 (memory-bound region).
        let t1 = shared_time(KernelKind::Naive, 1);
        let t32 = shared_time(KernelKind::Naive, 32);
        assert!((t32 - t1).abs() / t1 < 1e-9, "naive shared is bandwidth-bound");
        // Absorb linear: time(2B) = 2*time(B) in the compute-bound regime.
        let a256 = shared_time(KernelKind::Absorb, 256);
        let a512 = shared_time(KernelKind::Absorb, 512);
        assert!((a512 / a256 - 2.0).abs() < 0.01);
    }

    /// Non-shared part: absorb always wins (paper Fig. 8b).
    #[test]
    fn absorb_wins_non_shared_at_all_batches() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        for b in [1u64, 8, 64, 512, 1024] {
            let wl = AttentionWorkload::decode(b, 0, 512);
            let n = time_breakdown(&attention_cost(&cfg, KernelKind::Naive, &wl), &hw);
            let a = time_breakdown(&attention_cost(&cfg, KernelKind::Absorb, &wl), &hw);
            assert!(a.non_shared <= n.non_shared, "b={b}");
        }
    }

    /// Fig. 4 observation: at B=1024 (Kimi K2, Ls=4096, Ln=512) the ratio
    /// between the baseline's shared-part time and typhoon's stage-1 time
    /// is ~3.3x.
    #[test]
    fn fig4_shared_part_ratio() {
        let cfg = crate::config::model::kimi_k2();
        let hw = ascend_npu();
        let wl = AttentionWorkload::decode(1024, 4096, 512);
        let absorb = time_breakdown(&attention_cost(&cfg, KernelKind::Absorb, &wl), &hw);
        let typhoon = time_breakdown(&attention_cost(&cfg, KernelKind::Typhoon, &wl), &hw);
        let ratio = absorb.shared / typhoon.shared;
        assert!((ratio - 3.4).abs() < 0.15, "shared-part speedup {ratio}");
    }

    /// Typhoon is never slower than the better baseline by more than the
    /// (tiny) epilogue overhead, and the policy would fall back anyway.
    #[test]
    fn typhoon_attention_no_worse_than_best_baseline_large_batch() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        for b in [128u64, 256, 1024] {
            let wl = AttentionWorkload::decode(b, 26472, 512);
            let t = attention_time(&cfg, KernelKind::Typhoon, &wl, &hw);
            let n = attention_time(&cfg, KernelKind::Naive, &wl, &hw);
            let a = attention_time(&cfg, KernelKind::Absorb, &wl, &hw);
            assert!(t <= n.min(a) * 1.02, "b={b}: t={t} n={n} a={a}");
        }
    }
}
