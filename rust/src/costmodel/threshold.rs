//! Eq. 1 of the paper: the fall-back batch threshold B_theta.
//!
//! TyphoonMLA pays off only when reading the shared prefix in
//! uncompressed (naive) form is faster than recomputing it in latent
//! (absorb) form.  Equating the naive memory time with the absorb
//! compute time on the shared part:
//!
//! ```text
//! L_s H (D_qk + D_v) / M  =  B S_q L_s H (2 D_l + D_r) / T
//!   => B_theta = (D_qk + D_v) / (S_q (2 D_l + D_r)) * T / M
//! ```
//!
//! with T the MAC throughput and M the HBM word bandwidth.  For
//! DeepSeek-v3 on the paper's Ascend NPU this gives B_theta = 61.

use crate::config::{HardwareSpec, ModelConfig};

/// Exact (real-valued) Eq. 1 threshold.
pub fn batch_threshold_exact(cfg: &ModelConfig, hw: &HardwareSpec, s_q: u64) -> f64 {
    let num = (cfg.d_qk() + cfg.d_v) as f64;
    let den = s_q as f64 * (2 * cfg.kv_lora_rank + cfg.d_rope) as f64;
    num / den * hw.macs_per_sec() / hw.words_per_sec()
}

/// Integer threshold as the paper reports it (floor).
pub fn batch_threshold(cfg: &ModelConfig, hw: &HardwareSpec, s_q: u64) -> usize {
    batch_threshold_exact(cfg, hw, s_q).floor() as usize
}

/// The decision the kernel policy makes each iteration.
pub fn use_typhoon(cfg: &ModelConfig, hw: &HardwareSpec, batch: usize, s_q: u64) -> bool {
    batch as f64 >= batch_threshold_exact(cfg, hw, s_q)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::{ascend_npu, gpu_h800};
    use crate::config::model::{deepseek_v3, kimi_k2};

    /// "we obtain B_theta = 61" (paper §3.2).
    #[test]
    fn eq1_deepseek_ascend_is_61() {
        assert_eq!(batch_threshold(&deepseek_v3(), &ascend_npu(), 1), 61);
    }

    /// Kimi K2 has the same per-head dims, so the threshold is identical:
    /// Eq. 1 has no H dependence.
    #[test]
    fn threshold_head_count_independent() {
        assert_eq!(
            batch_threshold(&kimi_k2(), &ascend_npu(), 1),
            batch_threshold(&deepseek_v3(), &ascend_npu(), 1)
        );
    }

    /// Larger S_q (speculative/tree decode) lowers the threshold
    /// proportionally: more query tokens reuse the same stream.
    #[test]
    fn threshold_scales_inverse_with_sq() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        let t1 = batch_threshold_exact(&cfg, &hw, 1);
        let t4 = batch_threshold_exact(&cfg, &hw, 4);
        assert!((t1 / t4 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn gpu_threshold_reflects_its_roofline() {
        // H800-class: T/M = 0.5e15 / 1.65e12 words/s ≈ 303 MACs/word
        // => B_theta ≈ 0.294 * 303 ≈ 89.
        let t = batch_threshold(&deepseek_v3(), &gpu_h800(), 1);
        assert_eq!(t, 89);
    }

    #[test]
    fn policy_flips_exactly_at_threshold() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        let b = batch_threshold(&cfg, &hw, 1);
        assert!(!use_typhoon(&cfg, &hw, b - 1, 1));
        assert!(use_typhoon(&cfg, &hw, b + 1, 1));
    }
}
