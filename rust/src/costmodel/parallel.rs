//! Tensor/sequence-parallel scaling of the attention kernels
//! (paper §3.1 "Parallelization").
//!
//! * **Tensor parallelism (TP)** splits attention heads.  The
//!   uncompressed (naive/typhoon stage-1) cache has a head dimension
//!   and shards perfectly.  The latent cache is *head-shared*, so every
//!   TP rank streams the full `D_l + D_r` words — TP cuts absorb's
//!   compute but not its bandwidth.
//! * **Sequence parallelism (SP)** splits the KV length.  Both cache
//!   forms shard; partial outputs are merged exactly with CombineLSE
//!   (associative — see `combine_associative_three_way`), costing one
//!   O(B*H/TP*D_v) exchange per extra rank.

use crate::config::{HardwareSpec, KernelKind, ModelConfig};

use super::exec_time::component_time;
use super::flops::{attention_cost, AttentionWorkload, Component};

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ParallelismConfig {
    pub tp: u64,
    pub sp: u64,
}

impl ParallelismConfig {
    pub fn single() -> Self {
        ParallelismConfig { tp: 1, sp: 1 }
    }

    pub fn ranks(&self) -> u64 {
        self.tp * self.sp
    }
}

/// Per-rank cost of one decode attention iteration under (TP, SP).
pub fn parallel_attention_cost(
    cfg: &ModelConfig,
    kind: KernelKind,
    wl: &AttentionWorkload,
    par: &ParallelismConfig,
) -> super::flops::CostBreakdown {
    assert!(cfg.n_heads as u64 % par.tp == 0, "TP must divide H");
    // Per-rank view: H/tp heads, L/sp context.
    let mut cfg_rank = cfg.clone();
    cfg_rank.n_heads = cfg.n_heads / par.tp as usize;
    let wl_rank = AttentionWorkload {
        batch: wl.batch,
        s_q: wl.s_q,
        l_s: wl.l_s.div_ceil(par.sp),
        l_n: wl.l_n.div_ceil(par.sp),
    };
    let mut cost = attention_cost(&cfg_rank, kind, &wl_rank);
    // Latent streams are head-shared: TP does NOT shrink them.  The
    // per-rank head-split cost above undercounts absorb-path words by
    // nothing (latent words have no H term), so they are already
    // per-rank exact.  Naive-path words carry H/tp — also exact.
    // SP merge: (sp-1) extra CombineLSE exchanges per stage.
    if par.sp > 1 {
        let merge = 2 * wl.batch * wl.s_q * (cfg_rank.n_heads * cfg_rank.d_v) as u64;
        let extra = (par.sp - 1) * merge;
        cost.combine = Component {
            macs: cost.combine.macs + extra,
            hbm_words: cost.combine.hbm_words + extra,
        };
    }
    cost
}

/// Per-rank roofline time under (TP, SP).
pub fn parallel_attention_time(
    cfg: &ModelConfig,
    kind: KernelKind,
    wl: &AttentionWorkload,
    hw: &HardwareSpec,
    par: &ParallelismConfig,
) -> f64 {
    let c = parallel_attention_cost(cfg, kind, wl, par);
    [c.shared, c.non_shared, c.proj_kvb1, c.proj_kvb2, c.combine]
        .iter()
        .map(|comp| component_time(comp, hw))
        .sum()
}

/// Scaling efficiency: T(1 rank) / (ranks * T(per-rank)).
pub fn scaling_efficiency(
    cfg: &ModelConfig,
    kind: KernelKind,
    wl: &AttentionWorkload,
    hw: &HardwareSpec,
    par: &ParallelismConfig,
) -> f64 {
    let t1 = parallel_attention_time(cfg, kind, wl, hw, &ParallelismConfig::single());
    let tp = parallel_attention_time(cfg, kind, wl, hw, par);
    t1 / (par.ranks() as f64 * tp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::ascend_npu;
    use crate::config::model::deepseek_v3;

    fn wl() -> AttentionWorkload {
        AttentionWorkload::decode(512, 26472, 512)
    }

    /// The typhoon speedup survives the paper's TP=4 x SP=4 deployment.
    #[test]
    fn typhoon_speedup_survives_tp4_sp4() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        let par = ParallelismConfig { tp: 4, sp: 4 };
        let t = parallel_attention_time(&cfg, KernelKind::Typhoon, &wl(), &hw, &par);
        let a = parallel_attention_time(&cfg, KernelKind::Absorb, &wl(), &hw, &par);
        assert!(a / t > 1.5, "speedup {:.2} under TP4xSP4", a / t);
    }

    /// Naive/typhoon stage-1 shards near-perfectly in TP (heads split
    /// both compute and bandwidth).
    #[test]
    fn naive_tp_scales_nearly_linearly() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        let par = ParallelismConfig { tp: 4, sp: 1 };
        let eff = scaling_efficiency(&cfg, KernelKind::Naive, &wl(), &hw, &par);
        assert!(eff > 0.95, "naive TP efficiency {eff}");
    }

    /// The latent stream is head-shared: TP leaves every rank reading
    /// the full shared-prefix stream (replication), while SP shards it.
    /// This is the structural reason TP alone can't rescue the absorb
    /// baseline's bandwidth in the memory-bound regime.
    #[test]
    fn absorb_tp_bandwidth_replication() {
        let cfg = deepseek_v3();
        let w = wl();
        let single = parallel_attention_cost(
            &cfg, KernelKind::Absorb, &w, &ParallelismConfig::single());
        let tp4 = parallel_attention_cost(
            &cfg, KernelKind::Absorb, &w, &ParallelismConfig { tp: 4, sp: 1 });
        let sp4 = parallel_attention_cost(
            &cfg, KernelKind::Absorb, &w, &ParallelismConfig { tp: 1, sp: 4 });
        // TP: per-rank latent words unchanged (replicated)...
        assert_eq!(tp4.shared.hbm_words, single.shared.hbm_words);
        // ...but compute splits 4x.
        assert_eq!(tp4.shared.macs * 4, single.shared.macs);
        // SP: the stream itself shards 4x.
        assert_eq!(sp4.shared.hbm_words * 4, single.shared.hbm_words);
        // Naive shards its (head-carrying) stream under TP.
        let n_tp4 = parallel_attention_cost(
            &cfg, KernelKind::Naive, &w, &ParallelismConfig { tp: 4, sp: 1 });
        let n1 = parallel_attention_cost(
            &cfg, KernelKind::Naive, &w, &ParallelismConfig::single());
        assert_eq!(n_tp4.shared.hbm_words * 4, n1.shared.hbm_words);
    }

    /// SP merge overhead is visible but small (CombineLSE is
    /// context-length free).
    #[test]
    fn sp_merge_overhead_bounded() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        let par = ParallelismConfig { tp: 1, sp: 4 };
        let eff = scaling_efficiency(&cfg, KernelKind::Typhoon, &wl(), &hw, &par);
        assert!(eff > 0.80, "typhoon SP efficiency {eff}");
        assert!(eff <= 1.0 + 1e-9);
    }

    #[test]
    #[should_panic(expected = "TP must divide H")]
    fn tp_must_divide_heads() {
        let cfg = deepseek_v3();
        parallel_attention_cost(
            &cfg,
            KernelKind::Naive,
            &wl(),
            &ParallelismConfig { tp: 7, sp: 1 },
        );
    }
}
