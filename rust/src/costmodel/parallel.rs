//! Tensor/sequence-parallel scaling of the attention kernels
//! (paper §3.1 "Parallelization").
//!
//! * **Tensor parallelism (TP)** splits attention heads.  The
//!   uncompressed (naive/typhoon stage-1) cache has a head dimension
//!   and shards perfectly.  The latent cache is *head-shared*, so every
//!   TP rank streams the full `D_l + D_r` words — TP cuts absorb's
//!   compute but not its bandwidth.
//! * **Sequence parallelism (SP)** splits the KV length.  Both cache
//!   forms shard; partial outputs are merged exactly with CombineLSE
//!   (associative — see `combine_associative_three_way`), costing one
//!   O(B*H/TP*D_v) exchange per extra rank.

use crate::config::{HardwareSpec, KernelKind, ModelConfig};

use super::exec_time::component_time;
use super::flops::{
    attention_cost, AttentionWorkload, Component, AMLA_RESCALE_DEN, AMLA_RESCALE_NUM,
};
use super::threshold::batch_threshold_exact;

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ParallelismConfig {
    pub tp: u64,
    pub sp: u64,
}

impl ParallelismConfig {
    pub fn single() -> Self {
        ParallelismConfig { tp: 1, sp: 1 }
    }

    pub fn ranks(&self) -> u64 {
        self.tp * self.sp
    }
}

/// Per-rank Eq. 1 threshold under (TP, SP), exact (real-valued).
///
/// Derivation from the per-rank roofline: the naive (typhoon stage-1)
/// shared stream carries a head dimension — each rank reads
/// `(H/tp)(D_qk+D_v)` words per shared token — while absorb's latent
/// stream is *head-shared*, so every rank reads the full `D_l+D_r`
/// words (TP replicates it; only SP shards it, paper §3.1).  Two
/// regimes follow:
///
/// * `(H/tp)(D_qk+D_v) > D_l+D_r` (every realistic TP): the crossover
///   sits where absorb's growing per-rank compute overtakes naive's
///   flat per-rank memory time.  Both sides carry the same `H/tp` and
///   `L_s/sp` factors, which cancel — the threshold *is* the classic
///   Eq. 1 value, and `ranks = 1` reproduces `batch_threshold_exact`
///   bit-identically.
/// * TP deep enough that the replicated latent stream costs at least
///   the per-rank naive stream: absorb's shared stage can never
///   undercut naive's (its memory floor alone already loses), so the
///   threshold collapses to 1 — the shifted-crossover regime of the
///   Hardware-Centric Analysis of MLA (Geens & Verhelst, 2025).
pub fn parallel_batch_threshold_exact(
    cfg: &ModelConfig,
    hw: &HardwareSpec,
    s_q: u64,
    par: &ParallelismConfig,
) -> f64 {
    assert!(par.tp > 0 && par.sp > 0, "TP/SP ranks must be >= 1");
    let h_rank = cfg.n_heads as f64 / par.tp as f64;
    let naive_words_per_token = h_rank * (cfg.d_qk() + cfg.d_v) as f64;
    let latent_words_per_token = cfg.latent_words() as f64;
    if naive_words_per_token <= latent_words_per_token {
        return 1.0;
    }
    batch_threshold_exact(cfg, hw, s_q)
}

/// Integer per-rank threshold (floor, at least 1), the form
/// `KernelPolicy` consumes.
pub fn parallel_batch_threshold(
    cfg: &ModelConfig,
    hw: &HardwareSpec,
    s_q: u64,
    par: &ParallelismConfig,
) -> usize {
    (parallel_batch_threshold_exact(cfg, hw, s_q, par).floor() as usize).max(1)
}

/// Exact per-rank crossover between a naive-shared-stage kernel and a
/// specific absorb-family fallback — the N-way generalization of Eq. 1
/// the kernel registry prices per entry.
///
/// Derivation: Eq. 1 equates the naive shared stage's memory time with
/// the absorb shared stage's compute time.  An AMLA-discounted absorb
/// does `7/8` of those MACs (`flops::amla_macs`), so its compute line
/// crosses the flat naive memory line later by exactly `8/7`:
/// `B_theta(amla) = B_theta * DEN/NUM`.  The latent-replication
/// collapse (deep TP) is fallback-independent — absorb's memory floor
/// alone already loses, with or without the MAC discount.
///
/// `fallback = Absorb` reproduces `parallel_batch_threshold_exact`
/// bit-identically (the factor is exactly 1) — the reduction the
/// registry's binary mode is pinned on.
pub fn parallel_pair_threshold_exact(
    cfg: &ModelConfig,
    hw: &HardwareSpec,
    s_q: u64,
    par: &ParallelismConfig,
    fallback: KernelKind,
) -> f64 {
    let base = parallel_batch_threshold_exact(cfg, hw, s_q, par);
    match fallback {
        KernelKind::Absorb => base,
        KernelKind::AmlaAbsorb => {
            if base <= 1.0 {
                // Latent-replication regime: naive wins at any batch.
                base
            } else {
                base * AMLA_RESCALE_DEN as f64 / AMLA_RESCALE_NUM as f64
            }
        }
        k => panic!("pair threshold needs an absorb-family fallback, got {k:?}"),
    }
}

/// Integer pair threshold (floor, at least 1).
pub fn parallel_pair_threshold(
    cfg: &ModelConfig,
    hw: &HardwareSpec,
    s_q: u64,
    par: &ParallelismConfig,
    fallback: KernelKind,
) -> usize {
    (parallel_pair_threshold_exact(cfg, hw, s_q, par, fallback).floor() as usize).max(1)
}

/// Per-rank cost of one decode attention iteration under (TP, SP).
pub fn parallel_attention_cost(
    cfg: &ModelConfig,
    kind: KernelKind,
    wl: &AttentionWorkload,
    par: &ParallelismConfig,
) -> super::flops::CostBreakdown {
    assert!(cfg.n_heads as u64 % par.tp == 0, "TP must divide H");
    // Per-rank view: H/tp heads, L/sp context.
    let mut cfg_rank = cfg.clone();
    cfg_rank.n_heads = cfg.n_heads / par.tp as usize;
    let wl_rank = AttentionWorkload {
        batch: wl.batch,
        s_q: wl.s_q,
        l_s: wl.l_s.div_ceil(par.sp),
        l_n: wl.l_n.div_ceil(par.sp),
    };
    let mut cost = attention_cost(&cfg_rank, kind, &wl_rank);
    // Latent streams are head-shared: TP does NOT shrink them.  The
    // per-rank head-split cost above undercounts absorb-path words by
    // nothing (latent words have no H term), so they are already
    // per-rank exact.  Naive-path words carry H/tp — also exact.
    // SP merge: (sp-1) extra CombineLSE exchanges per stage.
    if par.sp > 1 {
        let merge = 2 * wl.batch * wl.s_q * (cfg_rank.n_heads * cfg_rank.d_v) as u64;
        let extra = (par.sp - 1) * merge;
        cost.combine = Component {
            macs: cost.combine.macs + extra,
            hbm_words: cost.combine.hbm_words + extra,
        };
    }
    cost
}

/// Per-rank roofline time under (TP, SP).
pub fn parallel_attention_time(
    cfg: &ModelConfig,
    kind: KernelKind,
    wl: &AttentionWorkload,
    hw: &HardwareSpec,
    par: &ParallelismConfig,
) -> f64 {
    let c = parallel_attention_cost(cfg, kind, wl, par);
    [c.shared, c.non_shared, c.proj_kvb1, c.proj_kvb2, c.combine]
        .iter()
        .map(|comp| component_time(comp, hw))
        .sum()
}

/// Scaling efficiency: T(1 rank) / (ranks * T(per-rank)).
pub fn scaling_efficiency(
    cfg: &ModelConfig,
    kind: KernelKind,
    wl: &AttentionWorkload,
    hw: &HardwareSpec,
    par: &ParallelismConfig,
) -> f64 {
    let t1 = parallel_attention_time(cfg, kind, wl, hw, &ParallelismConfig::single());
    let tp = parallel_attention_time(cfg, kind, wl, hw, par);
    t1 / (par.ranks() as f64 * tp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::ascend_npu;
    use crate::config::model::deepseek_v3;

    fn wl() -> AttentionWorkload {
        AttentionWorkload::decode(512, 26472, 512)
    }

    /// The typhoon speedup survives the paper's TP=4 x SP=4 deployment.
    #[test]
    fn typhoon_speedup_survives_tp4_sp4() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        let par = ParallelismConfig { tp: 4, sp: 4 };
        let t = parallel_attention_time(&cfg, KernelKind::Typhoon, &wl(), &hw, &par);
        let a = parallel_attention_time(&cfg, KernelKind::Absorb, &wl(), &hw, &par);
        assert!(a / t > 1.5, "speedup {:.2} under TP4xSP4", a / t);
    }

    /// Naive/typhoon stage-1 shards near-perfectly in TP (heads split
    /// both compute and bandwidth).
    #[test]
    fn naive_tp_scales_nearly_linearly() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        let par = ParallelismConfig { tp: 4, sp: 1 };
        let eff = scaling_efficiency(&cfg, KernelKind::Naive, &wl(), &hw, &par);
        assert!(eff > 0.95, "naive TP efficiency {eff}");
    }

    /// The latent stream is head-shared: TP leaves every rank reading
    /// the full shared-prefix stream (replication), while SP shards it.
    /// This is the structural reason TP alone can't rescue the absorb
    /// baseline's bandwidth in the memory-bound regime.
    #[test]
    fn absorb_tp_bandwidth_replication() {
        let cfg = deepseek_v3();
        let w = wl();
        let single = parallel_attention_cost(
            &cfg, KernelKind::Absorb, &w, &ParallelismConfig::single());
        let tp4 = parallel_attention_cost(
            &cfg, KernelKind::Absorb, &w, &ParallelismConfig { tp: 4, sp: 1 });
        let sp4 = parallel_attention_cost(
            &cfg, KernelKind::Absorb, &w, &ParallelismConfig { tp: 1, sp: 4 });
        // TP: per-rank latent words unchanged (replicated)...
        assert_eq!(tp4.shared.hbm_words, single.shared.hbm_words);
        // ...but compute splits 4x.
        assert_eq!(tp4.shared.macs * 4, single.shared.macs);
        // SP: the stream itself shards 4x.
        assert_eq!(sp4.shared.hbm_words * 4, single.shared.hbm_words);
        // Naive shards its (head-carrying) stream under TP.
        let n_tp4 = parallel_attention_cost(
            &cfg, KernelKind::Naive, &w, &ParallelismConfig { tp: 4, sp: 1 });
        let n1 = parallel_attention_cost(
            &cfg, KernelKind::Naive, &w, &ParallelismConfig::single());
        assert_eq!(n_tp4.shared.hbm_words * 4, n1.shared.hbm_words);
    }

    /// SP merge overhead is visible but small (CombineLSE is
    /// context-length free).
    #[test]
    fn sp_merge_overhead_bounded() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        let par = ParallelismConfig { tp: 1, sp: 4 };
        let eff = scaling_efficiency(&cfg, KernelKind::Typhoon, &wl(), &hw, &par);
        assert!(eff > 0.80, "typhoon SP efficiency {eff}");
        assert!(eff <= 1.0 + 1e-9);
    }

    /// `ranks = 1` reproduces the classic Eq. 1 threshold to the bit —
    /// the reduction every pre-parallelism artifact depends on.
    #[test]
    fn ranks_one_threshold_is_eq1_bitwise() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        for s_q in [1u64, 2, 4] {
            let single =
                parallel_batch_threshold_exact(&cfg, &hw, s_q, &ParallelismConfig::single());
            assert_eq!(single.to_bits(), batch_threshold_exact(&cfg, &hw, s_q).to_bits());
        }
        assert_eq!(
            parallel_batch_threshold(&cfg, &hw, 1, &ParallelismConfig::single()),
            61
        );
    }

    /// Realistic sharding leaves the crossover unchanged (both sides of
    /// Eq. 1 shard by the same `H/tp` and `L_s/sp` factors); TP deep
    /// enough that the replicated latent stream dominates the per-rank
    /// naive stream collapses the threshold to 1.
    #[test]
    fn threshold_shifts_only_in_the_replication_regime() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        for (tp, sp) in [(1u64, 4u64), (4, 1), (4, 4), (8, 2), (64, 1)] {
            let par = ParallelismConfig { tp, sp };
            assert_eq!(
                parallel_batch_threshold(&cfg, &hw, 1, &par),
                61,
                "tp={tp} sp={sp}"
            );
        }
        // H = 128, tp = 128: one head per rank — the per-rank naive
        // stream (320 words/token) undercuts the replicated latent
        // stream (576 words/token), so naive wins at any batch.
        let deep = ParallelismConfig { tp: 128, sp: 1 };
        assert_eq!(parallel_batch_threshold(&cfg, &hw, 1, &deep), 1);
    }

    /// The analytic per-rank threshold agrees with a numeric crossover
    /// scan over the same parallel cost model the engines run: the
    /// smallest batch where typhoon's modeled time undercuts absorb's
    /// is within one of the analytic value (Eq. 1 floors the exact
    /// crossover; the scan ceils it).
    #[test]
    fn analytic_threshold_brackets_cost_model_crossover() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        for par in [
            ParallelismConfig::single(),
            ParallelismConfig { tp: 4, sp: 1 },
            ParallelismConfig { tp: 4, sp: 4 },
            ParallelismConfig { tp: 128, sp: 1 },
        ] {
            let analytic = parallel_batch_threshold(&cfg, &hw, 1, &par);
            // Shared-only workload, Ls divisible by sp so div_ceil is
            // exact; typhoon vs absorb differ only in the shared stage.
            let numeric = (1..=256u64)
                .find(|&b| {
                    let wl = AttentionWorkload::decode(b, 4096, 0);
                    parallel_attention_time(&cfg, KernelKind::Typhoon, &wl, &hw, &par)
                        <= parallel_attention_time(&cfg, KernelKind::Absorb, &wl, &hw, &par)
                })
                .expect("crossover within scan range") as usize;
            assert!(
                numeric == analytic || numeric == analytic + 1,
                "tp={} sp={}: numeric {numeric} vs analytic {analytic}",
                par.tp,
                par.sp
            );
        }
    }

    /// `fallback = Absorb` reduces the pair threshold to the classic
    /// per-rank Eq. 1 bit-identically — the registry's binary-mode pin.
    #[test]
    fn absorb_pair_threshold_is_eq1_bitwise() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        for par in [
            ParallelismConfig::single(),
            ParallelismConfig { tp: 4, sp: 4 },
            ParallelismConfig { tp: 128, sp: 1 },
        ] {
            for s_q in [1u64, 2, 4] {
                assert_eq!(
                    parallel_pair_threshold_exact(&cfg, &hw, s_q, &par, KernelKind::Absorb)
                        .to_bits(),
                    parallel_batch_threshold_exact(&cfg, &hw, s_q, &par).to_bits()
                );
            }
        }
    }

    /// The AMLA fallback shifts the crossover up by exactly 8/7:
    /// the cheaper absorb stage stays competitive to a larger batch.
    /// Ascend: 61.44 * 8/7 = 70.21 -> 70; the deep-TP collapse is
    /// fallback-independent.
    #[test]
    fn amla_pair_threshold_scales_8_over_7() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        let single = ParallelismConfig::single();
        assert_eq!(
            parallel_pair_threshold(&cfg, &hw, 1, &single, KernelKind::AmlaAbsorb),
            70
        );
        let classic = parallel_batch_threshold_exact(&cfg, &hw, 1, &single);
        let amla =
            parallel_pair_threshold_exact(&cfg, &hw, 1, &single, KernelKind::AmlaAbsorb);
        assert!((amla / classic - 8.0 / 7.0).abs() < 1e-12);
        let deep = ParallelismConfig { tp: 128, sp: 1 };
        assert_eq!(
            parallel_pair_threshold(&cfg, &hw, 1, &deep, KernelKind::AmlaAbsorb),
            1
        );
    }

    #[test]
    #[should_panic(expected = "absorb-family fallback")]
    fn pair_threshold_rejects_naive_fallback() {
        let cfg = deepseek_v3();
        parallel_pair_threshold_exact(
            &cfg,
            &ascend_npu(),
            1,
            &ParallelismConfig::single(),
            KernelKind::Naive,
        );
    }

    /// The AMLA analytic pair threshold brackets the numeric crossover
    /// of the priced AMLA curves, exactly like the classic Eq. 1 test
    /// above brackets typhoon-vs-absorb.
    #[test]
    fn amla_analytic_threshold_brackets_cost_model_crossover() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        for par in [ParallelismConfig::single(), ParallelismConfig { tp: 4, sp: 4 }] {
            let analytic =
                parallel_pair_threshold(&cfg, &hw, 1, &par, KernelKind::AmlaAbsorb);
            let numeric = (1..=256u64)
                .find(|&b| {
                    let wl = AttentionWorkload::decode(b, 4096, 0);
                    parallel_attention_time(&cfg, KernelKind::TyphoonAmla, &wl, &hw, &par)
                        <= parallel_attention_time(
                            &cfg,
                            KernelKind::AmlaAbsorb,
                            &wl,
                            &hw,
                            &par,
                        )
                })
                .expect("crossover within scan range") as usize;
            assert!(
                numeric == analytic || numeric == analytic + 1,
                "tp={} sp={}: numeric {numeric} vs analytic {analytic}",
                par.tp,
                par.sp
            );
        }
    }

    #[test]
    #[should_panic(expected = "TP must divide H")]
    fn tp_must_divide_heads() {
        let cfg = deepseek_v3();
        parallel_attention_cost(
            &cfg,
            KernelKind::Naive,
            &wl(),
            &ParallelismConfig { tp: 7, sp: 1 },
        );
    }
}
