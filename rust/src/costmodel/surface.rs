//! The fleet-shared price surface (DESIGN.md §17).
//!
//! Kernel pricing is a pure function of `(kernel, B, L_s, L_n)` given a
//! model, hardware spec, and sharding — so a fleet of replicas has no
//! reason to each warm a private memo.  [`PriceSurface`] hoists the
//! dense interned memo of `costmodel::table` into one `Arc`-shared,
//! read-mostly structure: every replica engine, the cluster's policy
//! engine, and autoscale spin-ups (which previously rebuilt a
//! stone-cold table) price against the same warm arrays.
//!
//! Concurrency protocol: hits take a read lock only (`DenseMemo::get`
//! never mutates); a miss computes **outside** any lock, then takes the
//! write lock to store.  Two threads missing the same key concurrently
//! both compute — harmless, the function is pure, so the stored value
//! is bit-identical whichever insert wins.  Consequently the *values*
//! returned are deterministic always; only the hit/miss *split* can
//! vary under concurrency (the total always equals the call count).
//! Nothing in any simulation report reads the counters, which is why
//! the serial-vs-parallel byte-identity artifacts are unaffected.
//!
//! The surface is keyed by `(model, hardware, parallelism, s_q)` at
//! construction; constructors downstream (`SimEngine::with_surface`,
//! `KernelPolicy::attach_surface`) verify the key matches before
//! adopting it, so a mismatched surface degrades to unshared pricing
//! rather than returning wrong numbers.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::config::{HardwareSpec, KernelKind, ModelConfig};

use super::flops::{AttentionWorkload, CostBreakdown};
use super::parallel::{parallel_attention_cost, ParallelismConfig};
use super::table::{kernel_index, DenseMemo, MAX_ENTRIES};

/// One shared, read-mostly pricing cache for a `(model, hardware,
/// parallelism, s_q)` cell.  See the module docs for the sharing and
/// locking protocol.
#[derive(Debug)]
pub struct PriceSurface {
    cfg: ModelConfig,
    hw: HardwareSpec,
    par: ParallelismConfig,
    /// Query length the kernel-pricing memo is evaluated at (plain
    /// decode = 1; a policy priced at a different s_q must not share
    /// this surface's `kernel_seconds` memo).
    s_q: u64,
    /// Memoized `parallel_attention_cost`, group = kernel index.
    costs: RwLock<DenseMemo<CostBreakdown>>,
    /// Memoized registry kernel pricing (roofline seconds), group =
    /// kernel index; filled through [`PriceSurface::kernel_seconds`].
    prices: RwLock<DenseMemo<f64>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PriceSurface {
    pub fn new(cfg: ModelConfig, hw: HardwareSpec, par: ParallelismConfig) -> Self {
        Self::with_query_len(cfg, hw, par, 1)
    }

    pub fn with_query_len(
        cfg: ModelConfig,
        hw: HardwareSpec,
        par: ParallelismConfig,
        s_q: u64,
    ) -> Self {
        PriceSurface {
            cfg,
            hw,
            par,
            s_q,
            costs: RwLock::new(DenseMemo::new()),
            prices: RwLock::new(DenseMemo::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Convenience: a fresh surface already behind its `Arc`.
    pub fn shared(cfg: ModelConfig, hw: HardwareSpec, par: ParallelismConfig) -> Arc<Self> {
        Arc::new(Self::new(cfg, hw, par))
    }

    pub fn model(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn hardware(&self) -> &HardwareSpec {
        &self.hw
    }

    pub fn parallelism(&self) -> ParallelismConfig {
        self.par
    }

    pub fn query_len(&self) -> u64 {
        self.s_q
    }

    /// Whether this surface prices the given cell — the adoption check
    /// used by `SimEngine::with_surface` / `KernelPolicy::attach_surface`.
    pub fn covers(
        &self,
        cfg: &ModelConfig,
        hw: &HardwareSpec,
        par: &ParallelismConfig,
        s_q: u64,
    ) -> bool {
        self.s_q == s_q && self.par == *par && self.cfg == *cfg && self.hw == *hw
    }

    /// `(hits, misses)` across both memos since construction.  Under
    /// concurrent use the split is schedule-dependent (see module
    /// docs); the sum always equals the number of memoized calls.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Memoized `parallel_attention_cost` for a plain-decode workload —
    /// the shared-surface equivalent of `CostTable::cost`, `&self` so a
    /// whole fleet can price through one `Arc`.
    pub fn cost(&self, kernel: KernelKind, batch: u64, l_s: u64, l_n: u64) -> CostBreakdown {
        let group = kernel_index(kernel);
        if let Some(c) =
            self.costs.read().expect("price surface poisoned").get(group, batch, l_s, l_n)
        {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return c;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let wl = AttentionWorkload::decode(batch, l_s, l_n);
        let c = parallel_attention_cost(&self.cfg, kernel, &wl, &self.par);
        let mut memo = self.costs.write().expect("price surface poisoned");
        if memo.len() >= MAX_ENTRIES {
            memo.clear();
        }
        memo.insert(group, batch, l_s, l_n, c);
        c
    }

    /// Shared-stage cost of a grouped decode iteration — the shared
    /// equivalent of `CostTable::grouped_shared_cost`, summing the
    /// shared/projection/combine components per prefix group exactly
    /// (`l_n = 0` isolates the shared stage; `non_shared` stays zero).
    pub fn grouped_shared_cost<I>(&self, groups: I) -> CostBreakdown
    where
        I: IntoIterator<Item = (KernelKind, u64, u64)>,
    {
        let mut total = CostBreakdown::default();
        for (kernel, occupancy, l_s) in groups {
            let c = self.cost(kernel, occupancy, l_s, 0);
            total.shared = total.shared.add(c.shared);
            total.proj_kvb1 = total.proj_kvb1.add(c.proj_kvb1);
            total.proj_kvb2 = total.proj_kvb2.add(c.proj_kvb2);
            total.combine = total.combine.add(c.combine);
        }
        total
    }

    /// Memoized registry kernel pricing: roofline seconds of `kernel`
    /// on `(batch, l_s, l_n)` at this surface's cell, computed by
    /// `compute` on a miss.  The memo is keyed by kernel *kind*, so a
    /// caller must guarantee `compute` is the standard Table-1 pricing
    /// for that kind at this surface's `(model, hw, par, s_q)` —
    /// `KernelPolicy::attach_surface` checks exactly that before
    /// routing its registry pricing here.
    pub fn kernel_seconds(
        &self,
        kernel: KernelKind,
        batch: u64,
        l_s: u64,
        l_n: u64,
        compute: impl FnOnce() -> f64,
    ) -> f64 {
        let group = kernel_index(kernel);
        if let Some(t) =
            self.prices.read().expect("price surface poisoned").get(group, batch, l_s, l_n)
        {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return t;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let t = compute();
        let mut memo = self.prices.write().expect("price surface poisoned");
        if memo.len() >= MAX_ENTRIES {
            memo.clear();
        }
        memo.insert(group, batch, l_s, l_n, t);
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::ascend_npu;
    use crate::config::model::deepseek_v3;
    use crate::costmodel::flops::attention_cost;
    use crate::costmodel::table::CostTable;

    fn surface() -> PriceSurface {
        PriceSurface::new(deepseek_v3(), ascend_npu(), ParallelismConfig::single())
    }

    #[test]
    fn shared_cost_matches_cost_table_bit_for_bit() {
        let s = surface();
        let mut t = CostTable::new(deepseek_v3());
        for kernel in KernelKind::all() {
            for (b, ls, ln) in [(1u64, 0u64, 17u64), (256, 4096, 512), (1024, 26472, 1)] {
                assert_eq!(s.cost(kernel, b, ls, ln), t.cost(kernel, b, ls, ln));
                assert_eq!(s.cost(kernel, b, ls, ln), t.cost(kernel, b, ls, ln));
            }
        }
        let (hits, misses) = s.stats();
        assert_eq!((hits, misses), (t.hits, t.misses), "serial counter parity");
        assert_eq!(misses, 15);
        assert_eq!(hits, 15);
    }

    #[test]
    fn grouped_shared_cost_matches_table() {
        let s = surface();
        let mut t = CostTable::new(deepseek_v3());
        let groups = [
            (KernelKind::Typhoon, 100u64, 4096u64),
            (KernelKind::Absorb, 8, 7069),
        ];
        assert_eq!(s.grouped_shared_cost(groups), t.grouped_shared_cost(groups));
    }

    #[test]
    fn kernel_seconds_memoizes_and_never_recomputes_on_hit() {
        let s = surface();
        let priced = s.kernel_seconds(KernelKind::Typhoon, 256, 4096, 512, || 0.125);
        assert_eq!(priced, 0.125);
        // A hit must return the stored bits without calling compute.
        let again = s.kernel_seconds(KernelKind::Typhoon, 256, 4096, 512, || {
            panic!("hit path must not recompute")
        });
        assert_eq!(again.to_bits(), priced.to_bits());
        // Distinct kind or workload: distinct slot.
        assert_eq!(s.kernel_seconds(KernelKind::Absorb, 256, 4096, 512, || 0.5), 0.5);
        assert_eq!(s.kernel_seconds(KernelKind::Typhoon, 256, 4096, 513, || 0.75), 0.75);
        let (hits, misses) = s.stats();
        assert_eq!((hits, misses), (1, 3));
    }

    #[test]
    fn covers_is_exact_on_the_cell_key() {
        let s = surface();
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        let single = ParallelismConfig::single();
        assert!(s.covers(&cfg, &hw, &single, 1));
        assert!(!s.covers(&cfg, &hw, &single, 2), "s_q mismatch");
        assert!(!s.covers(&cfg, &hw, &ParallelismConfig { tp: 2, sp: 1 }, 1));
        let mut other = cfg.clone();
        other.name = "other";
        assert!(!s.covers(&other, &hw, &single, 1));
    }

    /// Two threads pricing the same keys concurrently agree with a
    /// serial table to the bit, and the counter totals account for
    /// every call even though the hit/miss split is schedule-dependent.
    #[test]
    fn concurrent_pricing_agrees_with_serial() {
        let s = Arc::new(surface());
        let cfg = deepseek_v3();
        let keys: Vec<(KernelKind, u64, u64, u64)> = KernelKind::all()
            .into_iter()
            .flat_map(|k| {
                (0..8u64).map(move |i| (k, 1 + i * 31, 4096, 1 + (i * 7) % 512))
            })
            .collect();
        let mut handles = Vec::new();
        for _ in 0..2 {
            let s = Arc::clone(&s);
            let keys = keys.clone();
            handles.push(std::thread::spawn(move || {
                keys.iter()
                    .map(|&(k, b, ls, ln)| s.cost(k, b, ls, ln))
                    .collect::<Vec<_>>()
            }));
        }
        let results: Vec<Vec<CostBreakdown>> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        for (i, &(k, b, ls, ln)) in keys.iter().enumerate() {
            let direct = attention_cost(&cfg, k, &AttentionWorkload::decode(b, ls, ln));
            for r in &results {
                assert_eq!(r[i], direct, "({k:?}, {b}, {ls}, {ln})");
            }
        }
        let (hits, misses) = s.stats();
        assert_eq!(hits + misses, 2 * keys.len() as u64, "every call counted");
        assert!(misses >= keys.len() as u64, "each distinct key misses at least once");
    }
}
