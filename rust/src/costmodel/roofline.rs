//! Roofline analysis of the naive and absorb formulations
//! (paper Appendix A.1, Fig. 6).
//!
//! Scenario: B decode queries attend to one shared context of length L.
//! Batch size controls operational intensity: the KV stream is read
//! once and reused by all B queries, so intensity grows linearly in B
//! until the compute ceiling.

use crate::config::{HardwareSpec, KernelKind, ModelConfig};

/// One point of the roofline curve.
#[derive(Clone, Copy, Debug)]
pub struct RooflinePoint {
    pub batch: u64,
    /// MACs per HBM word (operational intensity).
    pub intensity: f64,
    /// Query tokens processed per second.
    pub throughput: f64,
    /// True if this point is limited by compute, not bandwidth.
    pub compute_bound: bool,
}

fn kernel_factor(cfg: &ModelConfig, kind: KernelKind) -> (u64, u64) {
    // (MACs per query-token per context-token, words per context-token)
    match kind {
        KernelKind::Naive => (cfg.naive_factor(), cfg.uncompressed_words()),
        KernelKind::Absorb => (cfg.absorb_factor(), cfg.latent_words()),
        KernelKind::AmlaAbsorb => {
            (crate::costmodel::flops::amla_macs(cfg.absorb_factor()), cfg.latent_words())
        }
        KernelKind::Typhoon | KernelKind::TyphoonAmla => {
            unreachable!("typhoon mixes both; plot its parts")
        }
    }
}

/// Evaluate one roofline point for a batch of B queries over a shared
/// context of length `l_ctx`.
pub fn roofline_point(
    cfg: &ModelConfig,
    kind: KernelKind,
    hw: &HardwareSpec,
    batch: u64,
    l_ctx: u64,
) -> RooflinePoint {
    let (f_mac, f_words) = kernel_factor(cfg, kind);
    let macs = (batch * l_ctx * f_mac) as f64;
    let words = (l_ctx * f_words) as f64;
    let t_compute = macs / hw.macs_per_sec();
    let t_memory = words / hw.words_per_sec();
    let time = t_compute.max(t_memory);
    RooflinePoint {
        batch,
        intensity: macs / words,
        throughput: batch as f64 / time,
        compute_bound: t_compute >= t_memory,
    }
}

/// Full curve over a batch sweep.
pub fn roofline_curve(
    cfg: &ModelConfig,
    kind: KernelKind,
    hw: &HardwareSpec,
    batches: &[u64],
    l_ctx: u64,
) -> Vec<RooflinePoint> {
    batches.iter().map(|&b| roofline_point(cfg, kind, hw, b, l_ctx)).collect()
}

/// Batch size at which the formulation becomes compute-bound
/// (the ridge crossing), in exact real arithmetic.
pub fn ridge_batch(cfg: &ModelConfig, kind: KernelKind, hw: &HardwareSpec) -> f64 {
    let (f_mac, f_words) = kernel_factor(cfg, kind);
    f_words as f64 / f_mac as f64 * hw.ridge_intensity()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::roofline_npu;
    use crate::config::model::{deepseek_v3, kimi_k2};

    /// "the absorb implementation ... throughput quickly saturates beyond
    /// a batch size of two" (Kimi K2, Appendix A.1).
    #[test]
    fn absorb_saturates_by_batch_two() {
        let hw = roofline_npu();
        for cfg in [deepseek_v3(), kimi_k2()] {
            let ridge = ridge_batch(&cfg, KernelKind::Absorb, &hw);
            assert!(ridge <= 2.0, "{}: ridge {ridge}", cfg.name);
            let p2 = roofline_point(&cfg, KernelKind::Absorb, &hw, 2, 4096);
            let p64 = roofline_point(&cfg, KernelKind::Absorb, &hw, 64, 4096);
            assert!(p64.throughput / p2.throughput < 1.05, "flat after saturation");
        }
    }

    /// "At batch sizes larger than 64 ... the naive implementation
    /// achieves up to 3.4x higher throughput than the absorb".
    #[test]
    fn naive_ceiling_is_3_4x_absorb() {
        let hw = roofline_npu();
        let cfg = deepseek_v3();
        let n = roofline_point(&cfg, KernelKind::Naive, &hw, 4096, 4096);
        let a = roofline_point(&cfg, KernelKind::Absorb, &hw, 4096, 4096);
        assert!(n.compute_bound && a.compute_bound);
        let ratio = n.throughput / a.throughput;
        assert!((ratio - 3.4).abs() < 0.01, "{ratio}");
    }

    /// Naive is bandwidth-bound at small batch (throughput grows ~linearly),
    /// compute-bound past its ridge (~T/M ≈ 209 queries).
    #[test]
    fn naive_regions() {
        let hw = roofline_npu();
        let cfg = deepseek_v3();
        let ridge = ridge_batch(&cfg, KernelKind::Naive, &hw);
        assert!((ridge - hw.ridge_intensity()).abs() < 1e-9); // f_mac == f_words
        let p8 = roofline_point(&cfg, KernelKind::Naive, &hw, 8, 4096);
        let p16 = roofline_point(&cfg, KernelKind::Naive, &hw, 16, 4096);
        assert!(!p8.compute_bound);
        assert!((p16.throughput / p8.throughput - 2.0).abs() < 1e-9);
        let big = roofline_point(&cfg, KernelKind::Naive, &hw, 1024, 4096);
        assert!(big.compute_bound);
    }

    /// Throughput scales exactly as 1/L in both regimes (both ops and
    /// bytes scale linearly with context length).
    #[test]
    fn context_length_scaling() {
        let hw = roofline_npu();
        let cfg = kimi_k2();
        for kind in [KernelKind::Naive, KernelKind::Absorb] {
            let a = roofline_point(&cfg, kind, &hw, 128, 1024);
            let b = roofline_point(&cfg, kind, &hw, 128, 65536);
            let ratio = a.throughput / b.throughput;
            assert!((ratio - 64.0).abs() < 1e-9, "{ratio}");
            // Intensity (MACs/word) is L-independent.
            assert!((a.intensity - b.intensity).abs() < 1e-9);
        }
    }
}
