//! Memoized Table-1 cost evaluation for the simulator hot path.
//!
//! A serving simulation evaluates `attention_cost` once per sequence
//! per decode iteration; across a figure sweep (model x hardware x
//! prompt x dataset x batch x kernel, batch up to 1024, tens of
//! thousands of iterations per cell) the same `(kernel, B, L_s, L_n)`
//! workloads recur constantly — context lengths are bounded by
//! `max_seq_len` and the shared length is fixed per cell.  `CostTable`
//! caches the exact `CostBreakdown` per key.
//!
//! Storage is a **dense interned memo** (DESIGN.md §17), not a hash
//! map: each axis value (`B`, `L_s`, `L_n`) is interned to a small
//! consecutive slot the first time it is seen, and entries live in
//! nested arrays indexed `[kernel][b][l_s][l_n]` — a lookup is three
//! array reads, no hashing.  Per sweep cell the axis domains are tiny
//! (one `L_s`, `L_n <= max_seq_len`, a handful of batch occupancies),
//! so the arrays stay small and hot.  The pre-dense `HashMap` memo is
//! retained behind the [`CostTable::use_hash_reference`] oracle flag
//! and fuzz-pinned bit-identical (tests/pricing_pool.rs), the same way
//! PR 7 pinned the cluster loop against `use_linear_reference`.
//!
//! Exactness: `attention_cost` is a pure function of
//! `(ModelConfig, KernelKind, AttentionWorkload)` over integers, so a
//! cache hit returns bit-identical results to direct evaluation — the
//! figure artifacts cannot drift.  The hit/miss *counters* are also
//! path-independent: both stores memo exact keys and clear at the same
//! entry cap, so a call sequence produces the same counter trace dense
//! or hashed.

use std::collections::HashMap;

use crate::config::{HardwareSpec, KernelKind, ModelConfig};

use super::flops::{attention_cost, AttentionWorkload, CostBreakdown};
use super::parallel::{parallel_attention_cost, parallel_attention_time, ParallelismConfig};

/// Cache key: (kernel, batch, shared_len, nonshared_len) with s_q = 1
/// (plain decode; speculative s_q > 1 bypasses the table).
type CostKey = (KernelKind, u64, u64, u64);

/// Entry cap — a full Fig. 2/3 sweep stays far below this (distinct
/// lengths are bounded by `max_seq_len`), but a runaway caller must not
/// grow the table without bound.  Shared with the fleet-wide
/// `PriceSurface`, which applies the same cap per memo.
pub(crate) const MAX_ENTRIES: usize = 1 << 20;

/// Dense position of a kernel in memo group arrays — the `all()` order.
pub(crate) fn kernel_index(kernel: KernelKind) -> usize {
    match kernel {
        KernelKind::Typhoon => 0,
        KernelKind::Absorb => 1,
        KernelKind::Naive => 2,
        KernelKind::AmlaAbsorb => 3,
        KernelKind::TyphoonAmla => 4,
    }
}

/// Number of dense kernel slots (`KernelKind::all().len()`).
pub(crate) const KERNEL_SLOTS: usize = 5;

/// Axis values below this are interned through a direct-indexed array
/// (value -> slot); rarer larger values go through a sorted spill list.
/// Every axis in the repo (batch <= 4096, `L_s` <= ~50k prompt tokens
/// interned once per cell, `L_n` <= `max_seq_len`) fits the direct
/// range, so the spill path is a correctness escape hatch, not a hot
/// path.
const DENSE_AXIS_CAP: u64 = 1 << 16;

/// Interner for one workload axis: assigns each distinct `u64` value a
/// small consecutive slot.  Lookup is one array read for values under
/// [`DENSE_AXIS_CAP`] (slot + 1 stored, 0 = unassigned); a sorted spill
/// list covers the tail.  `get` never mutates, so shared callers can
/// peek under a read lock.
#[derive(Clone, Debug, Default)]
struct AxisMap {
    direct: Vec<u32>,
    spill: Vec<(u64, u32)>,
    len: u32,
}

impl AxisMap {
    fn get(&self, v: u64) -> Option<usize> {
        if v < DENSE_AXIS_CAP {
            match self.direct.get(v as usize) {
                Some(&s) if s != 0 => Some(s as usize - 1),
                _ => None,
            }
        } else {
            self.spill
                .binary_search_by_key(&v, |&(val, _)| val)
                .ok()
                .map(|i| self.spill[i].1 as usize)
        }
    }

    fn intern(&mut self, v: u64) -> usize {
        if let Some(s) = self.get(v) {
            return s;
        }
        let slot = self.len;
        self.len += 1;
        if v < DENSE_AXIS_CAP {
            if self.direct.len() <= v as usize {
                self.direct.resize(v as usize + 1, 0);
            }
            self.direct[v as usize] = slot + 1;
        } else {
            let at = self.spill.partition_point(|&(val, _)| val < v);
            self.spill.insert(at, (v, slot));
        }
        slot as usize
    }
}

/// The dense memo core shared by [`CostTable`], [`PriceTable`], and the
/// fleet-shared `PriceSurface`: values stored in nested arrays indexed
/// `[group][b_slot][ls_slot][ln_slot]`, with each axis interned through
/// an [`AxisMap`].  The group dimension is caller-defined (kernel index
/// for `CostTable`, `backend x kernel` for `PriceTable`).
///
/// `get` is non-mutating (slot peeks only), so a shared owner can serve
/// hits under a read lock; `insert` interns and grows lazily — axis
/// growth never re-scatters existing entries, because slots are
/// append-only.
#[derive(Clone, Debug)]
pub(crate) struct DenseMemo<V> {
    b: AxisMap,
    ls: AxisMap,
    ln: AxisMap,
    groups: Vec<Vec<Vec<Vec<Option<V>>>>>,
    len: usize,
}

impl<V: Copy> DenseMemo<V> {
    pub(crate) fn new() -> Self {
        DenseMemo {
            b: AxisMap::default(),
            ls: AxisMap::default(),
            ln: AxisMap::default(),
            groups: Vec::new(),
            len: 0,
        }
    }

    pub(crate) fn get(&self, group: usize, b: u64, ls: u64, ln: u64) -> Option<V> {
        let b = self.b.get(b)?;
        let ls = self.ls.get(ls)?;
        let ln = self.ln.get(ln)?;
        *self.groups.get(group)?.get(b)?.get(ls)?.get(ln)?
    }

    pub(crate) fn insert(&mut self, group: usize, b: u64, ls: u64, ln: u64, v: V) {
        let b = self.b.intern(b);
        let ls = self.ls.intern(ls);
        let ln = self.ln.intern(ln);
        if self.groups.len() <= group {
            self.groups.resize_with(group + 1, Vec::new);
        }
        let by_b = &mut self.groups[group];
        if by_b.len() <= b {
            by_b.resize_with(b + 1, Vec::new);
        }
        let by_ls = &mut by_b[b];
        if by_ls.len() <= ls {
            by_ls.resize_with(ls + 1, Vec::new);
        }
        let by_ln = &mut by_ls[ls];
        if by_ln.len() <= ln {
            by_ln.resize(ln + 1, None);
        }
        if by_ln[ln].is_none() {
            self.len += 1;
        }
        by_ln[ln] = Some(v);
    }

    pub(crate) fn len(&self) -> usize {
        self.len
    }

    /// Drop every stored value (interned axis slots are kept — they
    /// stay valid and re-interning would churn the direct arrays).
    pub(crate) fn clear(&mut self) {
        self.groups.clear();
        self.len = 0;
    }
}

#[derive(Debug)]
pub struct CostTable {
    cfg: ModelConfig,
    /// TP/SP sharding the cached costs are evaluated under.  `single()`
    /// routes through `parallel_attention_cost` with one rank, which is
    /// definitionally `attention_cost` — bit-identical to the
    /// pre-parallelism table.
    par: ParallelismConfig,
    dense: DenseMemo<CostBreakdown>,
    /// The pre-dense `HashMap` memo, retained as the reference oracle.
    map: HashMap<CostKey, CostBreakdown>,
    /// Route lookups through the retained `HashMap` path instead of the
    /// dense arrays — the PR 9 analogue of the cluster's
    /// `use_linear_reference`: results *and* hit/miss counters must be
    /// identical either way (fuzz-pinned in tests/pricing_pool.rs).
    pub use_hash_reference: bool,
    pub hits: u64,
    pub misses: u64,
}

impl CostTable {
    pub fn new(cfg: ModelConfig) -> Self {
        Self::with_parallelism(cfg, ParallelismConfig::single())
    }

    /// A table evaluating per-rank costs under (TP, SP).  TP must
    /// divide the model's head count (asserted on first evaluation).
    pub fn with_parallelism(cfg: ModelConfig, par: ParallelismConfig) -> Self {
        CostTable {
            cfg,
            par,
            dense: DenseMemo::new(),
            map: HashMap::new(),
            use_hash_reference: false,
            hits: 0,
            misses: 0,
        }
    }

    pub fn model(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn parallelism(&self) -> ParallelismConfig {
        self.par
    }

    /// Entries in the active store (dense by default, hash under the
    /// reference flag) — the stores are not kept in sync, each fills
    /// from its own miss traffic.
    pub fn len(&self) -> usize {
        if self.use_hash_reference {
            self.map.len()
        } else {
            self.dense.len()
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Memoized `attention_cost` for a plain-decode workload.
    pub fn cost(&mut self, kernel: KernelKind, batch: u64, l_s: u64, l_n: u64) -> CostBreakdown {
        if self.use_hash_reference {
            return self.cost_hash(kernel, batch, l_s, l_n);
        }
        let group = kernel_index(kernel);
        if let Some(c) = self.dense.get(group, batch, l_s, l_n) {
            self.hits += 1;
            return c;
        }
        self.misses += 1;
        let wl = AttentionWorkload::decode(batch, l_s, l_n);
        let c = parallel_attention_cost(&self.cfg, kernel, &wl, &self.par);
        if self.dense.len() >= MAX_ENTRIES {
            self.dense.clear();
        }
        self.dense.insert(group, batch, l_s, l_n, c);
        c
    }

    /// The retained reference path: the pre-PR-9 `HashMap` memo,
    /// verbatim (including the entry-cap clear, so the counter trace
    /// matches the dense path call for call).
    fn cost_hash(&mut self, kernel: KernelKind, batch: u64, l_s: u64, l_n: u64) -> CostBreakdown {
        let key = (kernel, batch, l_s, l_n);
        if let Some(c) = self.map.get(&key) {
            self.hits += 1;
            return *c;
        }
        self.misses += 1;
        let wl = AttentionWorkload::decode(batch, l_s, l_n);
        let c = parallel_attention_cost(&self.cfg, kernel, &wl, &self.par);
        if self.map.len() >= MAX_ENTRIES {
            self.map.clear();
        }
        self.map.insert(key, c);
        c
    }

    pub fn clear(&mut self) {
        self.dense.clear();
        self.map.clear();
    }

    /// Shared-stage cost of a *grouped* decode iteration: one memoized
    /// Table-1 evaluation per prefix group — `(kernel, occupancy,
    /// shared_len)` — with `l_n = 0` isolating the shared component;
    /// shared/projection/combine components are summed exactly (u64).
    /// The non-shared stage is length-bucketed across the whole batch
    /// by the engine and is *not* included (`non_shared` stays zero).
    /// A single-group iteration reduces to one `cost` call — the
    /// pre-tenancy formulation, bit for bit.
    pub fn grouped_shared_cost<I>(&mut self, groups: I) -> CostBreakdown
    where
        I: IntoIterator<Item = (KernelKind, u64, u64)>,
    {
        let mut total = CostBreakdown::default();
        for (kernel, occupancy, l_s) in groups {
            let c = self.cost(kernel, occupancy, l_s, 0);
            total.shared = total.shared.add(c.shared);
            total.proj_kvb1 = total.proj_kvb1.add(c.proj_kvb1);
            total.proj_kvb2 = total.proj_kvb2.add(c.proj_kvb2);
            total.combine = total.combine.add(c.combine);
        }
        total
    }
}

/// Opaque handle to a backend registered with a [`PriceTable`].
pub type BackendId = usize;

/// Roofline-*time* memo keyed by `(kernel, backend, B, L_s, L_n)` —
/// the pricing companion to [`CostTable`].  The kernel registry prices
/// N kernels per prefix group each iteration and the per-backend
/// crossover sweep scans the same curves across hardware presets; both
/// recur on identical keys, so the table turns repeated roofline
/// evaluations into dense-array lookups (group = backend x kernel; the
/// `HashMap` path is retained behind the same `use_hash_reference`
/// oracle flag as [`CostTable`]).  Exactness: `parallel_attention_time`
/// is a pure function of its integer workload and the two specs, so a
/// hit returns the identical f64 bits.
#[derive(Debug)]
pub struct PriceTable {
    cfg: ModelConfig,
    par: ParallelismConfig,
    /// Registered hardware presets; `BackendId` indexes this.
    backends: Vec<HardwareSpec>,
    dense: DenseMemo<f64>,
    map: HashMap<(KernelKind, BackendId, u64, u64, u64), f64>,
    /// See [`CostTable::use_hash_reference`].
    pub use_hash_reference: bool,
    pub hits: u64,
    pub misses: u64,
}

impl PriceTable {
    pub fn new(cfg: ModelConfig, par: ParallelismConfig) -> Self {
        PriceTable {
            cfg,
            par,
            backends: Vec::new(),
            dense: DenseMemo::new(),
            map: HashMap::new(),
            use_hash_reference: false,
            hits: 0,
            misses: 0,
        }
    }

    /// Register a hardware preset as a pricing backend; re-registering
    /// a spec with the same name returns the existing id (the memo
    /// stays valid because presets are keyed by name).
    pub fn register_backend(&mut self, hw: HardwareSpec) -> BackendId {
        if let Some(i) = self.backends.iter().position(|b| b.name == hw.name) {
            return i;
        }
        self.backends.push(hw);
        self.backends.len() - 1
    }

    pub fn backend(&self, id: BackendId) -> &HardwareSpec {
        &self.backends[id]
    }

    pub fn model(&self) -> &ModelConfig {
        &self.cfg
    }

    /// Memoized per-rank roofline seconds of one decode iteration.
    pub fn time(
        &mut self,
        kernel: KernelKind,
        backend: BackendId,
        batch: u64,
        l_s: u64,
        l_n: u64,
    ) -> f64 {
        if self.use_hash_reference {
            return self.time_hash(kernel, backend, batch, l_s, l_n);
        }
        let group = backend * KERNEL_SLOTS + kernel_index(kernel);
        if let Some(t) = self.dense.get(group, batch, l_s, l_n) {
            self.hits += 1;
            return t;
        }
        self.misses += 1;
        let wl = AttentionWorkload::decode(batch, l_s, l_n);
        let t = parallel_attention_time(&self.cfg, kernel, &wl, &self.backends[backend], &self.par);
        if self.dense.len() >= MAX_ENTRIES {
            self.dense.clear();
        }
        self.dense.insert(group, batch, l_s, l_n, t);
        t
    }

    /// The retained pre-PR-9 `HashMap` reference path.
    fn time_hash(
        &mut self,
        kernel: KernelKind,
        backend: BackendId,
        batch: u64,
        l_s: u64,
        l_n: u64,
    ) -> f64 {
        let key = (kernel, backend, batch, l_s, l_n);
        if let Some(&t) = self.map.get(&key) {
            self.hits += 1;
            return t;
        }
        self.misses += 1;
        let wl = AttentionWorkload::decode(batch, l_s, l_n);
        let t = parallel_attention_time(&self.cfg, kernel, &wl, &self.backends[backend], &self.par);
        if self.map.len() >= MAX_ENTRIES {
            self.map.clear();
        }
        self.map.insert(key, t);
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::deepseek_v3;

    #[test]
    fn memoized_equals_direct() {
        let cfg = deepseek_v3();
        let mut table = CostTable::new(cfg.clone());
        for kernel in KernelKind::all() {
            for (b, ls, ln) in [(1u64, 0u64, 17u64), (64, 4096, 512), (1024, 26472, 1)] {
                let direct =
                    attention_cost(&cfg, kernel, &AttentionWorkload::decode(b, ls, ln));
                assert_eq!(table.cost(kernel, b, ls, ln), direct);
                // Second lookup hits the cache and is still identical.
                assert_eq!(table.cost(kernel, b, ls, ln), direct);
            }
        }
        // 5 kernels x 3 workloads.
        assert_eq!(table.misses, 15);
        assert_eq!(table.hits, 15);
    }

    /// `PriceTable` memoizes `parallel_attention_time` bit-identically
    /// per (kernel, backend, workload) key, and backend registration
    /// dedups by name.
    #[test]
    fn price_table_memoizes_per_backend() {
        use crate::config::hardware::{ascend_npu, gpu_h800_decode};
        use crate::costmodel::parallel::parallel_attention_time;

        let cfg = deepseek_v3();
        let par = ParallelismConfig { tp: 4, sp: 2 };
        let mut prices = PriceTable::new(cfg.clone(), par);
        let npu = prices.register_backend(ascend_npu());
        let gpu = prices.register_backend(gpu_h800_decode());
        assert_ne!(npu, gpu);
        assert_eq!(prices.register_backend(ascend_npu()), npu, "dedup by name");
        assert_eq!(prices.backend(gpu).name, "gpu-h800-decode");

        for kernel in KernelKind::all() {
            for (id, hw) in [(npu, ascend_npu()), (gpu, gpu_h800_decode())] {
                let wl = AttentionWorkload::decode(128, 4096, 256);
                let direct = parallel_attention_time(&cfg, kernel, &wl, &hw, &par);
                assert_eq!(prices.time(kernel, id, 128, 4096, 256).to_bits(), direct.to_bits());
                // Hit path returns identical bits.
                assert_eq!(prices.time(kernel, id, 128, 4096, 256).to_bits(), direct.to_bits());
            }
        }
        assert_eq!(prices.misses, 10);
        assert_eq!(prices.hits, 10);
        // Same workload, different backend: distinct keys, different times.
        assert_ne!(
            prices.time(KernelKind::Typhoon, npu, 128, 4096, 256),
            prices.time(KernelKind::Typhoon, gpu, 128, 4096, 256)
        );
    }

    #[test]
    fn grouped_shared_cost_sums_per_group() {
        let cfg = deepseek_v3();
        let mut table = CostTable::new(cfg.clone());
        let groups = [
            (KernelKind::Typhoon, 100u64, 4096u64),
            (KernelKind::Absorb, 8, 7069),
        ];
        let got = table.grouped_shared_cost(groups);
        let mut expect_shared = 0u64;
        for &(k, b, ls) in &groups {
            expect_shared +=
                attention_cost(&cfg, k, &AttentionWorkload::decode(b, ls, 0)).shared.macs;
        }
        assert_eq!(got.shared.macs, expect_shared);
        assert_eq!(got.non_shared, Default::default(), "shared stage only");
        // Single group == plain cost call (the legacy reduction).
        let single = table.grouped_shared_cost([(KernelKind::Typhoon, 64u64, 1000u64)]);
        let direct = table.cost(KernelKind::Typhoon, 64, 1000, 0);
        assert_eq!(single.shared, direct.shared);
        assert_eq!(single.combine, direct.combine);
    }

    #[test]
    fn single_parallelism_is_identity() {
        // `new` and an explicit single() table agree with direct
        // `attention_cost` to the bit — the pre-parallelism behavior.
        let cfg = deepseek_v3();
        let mut a = CostTable::new(cfg.clone());
        let mut b = CostTable::with_parallelism(cfg.clone(), ParallelismConfig::single());
        for kernel in KernelKind::all() {
            let direct =
                attention_cost(&cfg, kernel, &AttentionWorkload::decode(128, 4096, 256));
            assert_eq!(a.cost(kernel, 128, 4096, 256), direct);
            assert_eq!(b.cost(kernel, 128, 4096, 256), direct);
        }
    }

    #[test]
    fn sharded_table_matches_parallel_cost_model() {
        let cfg = deepseek_v3();
        let par = ParallelismConfig { tp: 4, sp: 2 };
        let mut table = CostTable::with_parallelism(cfg.clone(), par);
        assert_eq!(table.parallelism(), par);
        for kernel in KernelKind::all() {
            let wl = AttentionWorkload::decode(256, 8192, 512);
            let direct = parallel_attention_cost(&cfg, kernel, &wl, &par);
            assert_eq!(table.cost(kernel, 256, 8192, 512), direct);
            // Cached hit stays identical.
            assert_eq!(table.cost(kernel, 256, 8192, 512), direct);
            // Sharding must change the numbers vs a single device.
            let single = attention_cost(&cfg, kernel, &wl);
            assert_ne!(direct.total(), single.total(), "{kernel:?}");
        }
    }

    #[test]
    fn keys_are_distinguished() {
        let mut table = CostTable::new(deepseek_v3());
        let a = table.cost(KernelKind::Absorb, 8, 100, 10);
        let b = table.cost(KernelKind::Naive, 8, 100, 10);
        let c = table.cost(KernelKind::Absorb, 8, 100, 11);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(table.hits, 0);
        assert_eq!(table.misses, 3);
    }

    /// The dense store and the retained hash reference agree to the
    /// bit — values *and* hit/miss counters — on an interleaved call
    /// sequence that exercises axis growth, spill values past the
    /// direct-index cap, and repeated keys.
    #[test]
    fn dense_matches_hash_reference_on_mixed_sequence() {
        let cfg = deepseek_v3();
        let mut dense = CostTable::new(cfg.clone());
        let mut hash = CostTable::new(cfg);
        hash.use_hash_reference = true;
        let calls: &[(KernelKind, u64, u64, u64)] = &[
            (KernelKind::Typhoon, 256, 4096, 512),
            (KernelKind::Absorb, 1, 0, 17),
            (KernelKind::Typhoon, 256, 4096, 512),
            (KernelKind::Naive, 1024, 26472, 1),
            // Past DENSE_AXIS_CAP: exercises the axis spill list.
            (KernelKind::Absorb, 8, 1 << 17, 3),
            (KernelKind::Absorb, 8, 1 << 17, 3),
            (KernelKind::TyphoonAmla, 64, 0, 2047),
            (KernelKind::AmlaAbsorb, 64, 0, 2047),
            (KernelKind::Typhoon, 256, 4096, 512),
        ];
        for &(k, b, ls, ln) in calls {
            assert_eq!(dense.cost(k, b, ls, ln), hash.cost(k, b, ls, ln));
            assert_eq!((dense.hits, dense.misses), (hash.hits, hash.misses));
        }
        assert_eq!(dense.len(), hash.len());
        assert_eq!(dense.misses, 6);
        assert_eq!(dense.hits, 3);
    }

    /// Same contract for `PriceTable` across two backends.
    #[test]
    fn price_table_dense_matches_hash_reference() {
        use crate::config::hardware::{ascend_npu, gpu_h800_decode};

        let cfg = deepseek_v3();
        let par = ParallelismConfig { tp: 2, sp: 2 };
        let mut dense = PriceTable::new(cfg.clone(), par);
        let mut hash = PriceTable::new(cfg, par);
        hash.use_hash_reference = true;
        for t in [&mut dense, &mut hash] {
            t.register_backend(ascend_npu());
            t.register_backend(gpu_h800_decode());
        }
        for _ in 0..2 {
            for kernel in KernelKind::all() {
                for backend in [0usize, 1] {
                    for (b, ls, ln) in [(1u64, 0u64, 1u64), (128, 4096, 256), (61, 26472, 0)] {
                        let d = dense.time(kernel, backend, b, ls, ln);
                        let h = hash.time(kernel, backend, b, ls, ln);
                        assert_eq!(d.to_bits(), h.to_bits());
                    }
                }
            }
        }
        assert_eq!((dense.hits, dense.misses), (hash.hits, hash.misses));
        assert_eq!(dense.misses, 30, "5 kernels x 2 backends x 3 workloads");
        assert_eq!(dense.hits, 30);
    }
}
