//! Memoized Table-1 cost evaluation for the simulator hot path.
//!
//! A serving simulation evaluates `attention_cost` once per sequence
//! per decode iteration; across a figure sweep (model x hardware x
//! prompt x dataset x batch x kernel, batch up to 1024, tens of
//! thousands of iterations per cell) the same `(kernel, B, L_s, L_n)`
//! workloads recur constantly — context lengths are bounded by
//! `max_seq_len` and the shared length is fixed per cell.  `CostTable`
//! caches the exact `CostBreakdown` per key, turning the dominant
//! per-iteration cost into hash lookups.
//!
//! Exactness: `attention_cost` is a pure function of
//! `(ModelConfig, KernelKind, AttentionWorkload)` over integers, so a
//! cache hit returns bit-identical results to direct evaluation — the
//! figure artifacts cannot drift.

use std::collections::HashMap;

use crate::config::{HardwareSpec, KernelKind, ModelConfig};

use super::flops::{attention_cost, AttentionWorkload, CostBreakdown};
use super::parallel::{parallel_attention_cost, parallel_attention_time, ParallelismConfig};

/// Cache key: (kernel, batch, shared_len, nonshared_len) with s_q = 1
/// (plain decode; speculative s_q > 1 bypasses the table).
type CostKey = (KernelKind, u64, u64, u64);

/// Entry cap — a full Fig. 2/3 sweep stays far below this (distinct
/// lengths are bounded by `max_seq_len`), but a runaway caller must not
/// grow the table without bound.
const MAX_ENTRIES: usize = 1 << 20;

#[derive(Debug)]
pub struct CostTable {
    cfg: ModelConfig,
    /// TP/SP sharding the cached costs are evaluated under.  `single()`
    /// routes through `parallel_attention_cost` with one rank, which is
    /// definitionally `attention_cost` — bit-identical to the
    /// pre-parallelism table.
    par: ParallelismConfig,
    map: HashMap<CostKey, CostBreakdown>,
    pub hits: u64,
    pub misses: u64,
}

impl CostTable {
    pub fn new(cfg: ModelConfig) -> Self {
        Self::with_parallelism(cfg, ParallelismConfig::single())
    }

    /// A table evaluating per-rank costs under (TP, SP).  TP must
    /// divide the model's head count (asserted on first evaluation).
    pub fn with_parallelism(cfg: ModelConfig, par: ParallelismConfig) -> Self {
        CostTable { cfg, par, map: HashMap::new(), hits: 0, misses: 0 }
    }

    pub fn model(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn parallelism(&self) -> ParallelismConfig {
        self.par
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Memoized `attention_cost` for a plain-decode workload.
    pub fn cost(&mut self, kernel: KernelKind, batch: u64, l_s: u64, l_n: u64) -> CostBreakdown {
        let key = (kernel, batch, l_s, l_n);
        if let Some(c) = self.map.get(&key) {
            self.hits += 1;
            return *c;
        }
        self.misses += 1;
        let wl = AttentionWorkload::decode(batch, l_s, l_n);
        let c = parallel_attention_cost(&self.cfg, kernel, &wl, &self.par);
        if self.map.len() >= MAX_ENTRIES {
            self.map.clear();
        }
        self.map.insert(key, c);
        c
    }

    pub fn clear(&mut self) {
        self.map.clear();
    }

    /// Shared-stage cost of a *grouped* decode iteration: one memoized
    /// Table-1 evaluation per prefix group — `(kernel, occupancy,
    /// shared_len)` — with `l_n = 0` isolating the shared component;
    /// shared/projection/combine components are summed exactly (u64).
    /// The non-shared stage is length-bucketed across the whole batch
    /// by the engine and is *not* included (`non_shared` stays zero).
    /// A single-group iteration reduces to one `cost` call — the
    /// pre-tenancy formulation, bit for bit.
    pub fn grouped_shared_cost<I>(&mut self, groups: I) -> CostBreakdown
    where
        I: IntoIterator<Item = (KernelKind, u64, u64)>,
    {
        let mut total = CostBreakdown::default();
        for (kernel, occupancy, l_s) in groups {
            let c = self.cost(kernel, occupancy, l_s, 0);
            total.shared = total.shared.add(c.shared);
            total.proj_kvb1 = total.proj_kvb1.add(c.proj_kvb1);
            total.proj_kvb2 = total.proj_kvb2.add(c.proj_kvb2);
            total.combine = total.combine.add(c.combine);
        }
        total
    }
}

/// Opaque handle to a backend registered with a [`PriceTable`].
pub type BackendId = usize;

/// Roofline-*time* memo keyed by `(kernel, backend, B, L_s, L_n)` —
/// the pricing companion to [`CostTable`].  The kernel registry prices
/// N kernels per prefix group each iteration and the per-backend
/// crossover sweep scans the same curves across hardware presets; both
/// recur on identical keys, so the table turns repeated roofline
/// evaluations into hash lookups.  Exactness: `parallel_attention_time`
/// is a pure function of its integer workload and the two specs, so a
/// hit returns the identical f64 bits.
#[derive(Debug)]
pub struct PriceTable {
    cfg: ModelConfig,
    par: ParallelismConfig,
    /// Registered hardware presets; `BackendId` indexes this.
    backends: Vec<HardwareSpec>,
    map: HashMap<(KernelKind, BackendId, u64, u64, u64), f64>,
    pub hits: u64,
    pub misses: u64,
}

impl PriceTable {
    pub fn new(cfg: ModelConfig, par: ParallelismConfig) -> Self {
        PriceTable { cfg, par, backends: Vec::new(), map: HashMap::new(), hits: 0, misses: 0 }
    }

    /// Register a hardware preset as a pricing backend; re-registering
    /// a spec with the same name returns the existing id (the memo
    /// stays valid because presets are keyed by name).
    pub fn register_backend(&mut self, hw: HardwareSpec) -> BackendId {
        if let Some(i) = self.backends.iter().position(|b| b.name == hw.name) {
            return i;
        }
        self.backends.push(hw);
        self.backends.len() - 1
    }

    pub fn backend(&self, id: BackendId) -> &HardwareSpec {
        &self.backends[id]
    }

    pub fn model(&self) -> &ModelConfig {
        &self.cfg
    }

    /// Memoized per-rank roofline seconds of one decode iteration.
    pub fn time(
        &mut self,
        kernel: KernelKind,
        backend: BackendId,
        batch: u64,
        l_s: u64,
        l_n: u64,
    ) -> f64 {
        let key = (kernel, backend, batch, l_s, l_n);
        if let Some(&t) = self.map.get(&key) {
            self.hits += 1;
            return t;
        }
        self.misses += 1;
        let wl = AttentionWorkload::decode(batch, l_s, l_n);
        let t = parallel_attention_time(&self.cfg, kernel, &wl, &self.backends[backend], &self.par);
        if self.map.len() >= MAX_ENTRIES {
            self.map.clear();
        }
        self.map.insert(key, t);
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::deepseek_v3;

    #[test]
    fn memoized_equals_direct() {
        let cfg = deepseek_v3();
        let mut table = CostTable::new(cfg.clone());
        for kernel in KernelKind::all() {
            for (b, ls, ln) in [(1u64, 0u64, 17u64), (64, 4096, 512), (1024, 26472, 1)] {
                let direct =
                    attention_cost(&cfg, kernel, &AttentionWorkload::decode(b, ls, ln));
                assert_eq!(table.cost(kernel, b, ls, ln), direct);
                // Second lookup hits the cache and is still identical.
                assert_eq!(table.cost(kernel, b, ls, ln), direct);
            }
        }
        // 5 kernels x 3 workloads.
        assert_eq!(table.misses, 15);
        assert_eq!(table.hits, 15);
    }

    /// `PriceTable` memoizes `parallel_attention_time` bit-identically
    /// per (kernel, backend, workload) key, and backend registration
    /// dedups by name.
    #[test]
    fn price_table_memoizes_per_backend() {
        use crate::config::hardware::{ascend_npu, gpu_h800_decode};
        use crate::costmodel::parallel::parallel_attention_time;

        let cfg = deepseek_v3();
        let par = ParallelismConfig { tp: 4, sp: 2 };
        let mut prices = PriceTable::new(cfg.clone(), par);
        let npu = prices.register_backend(ascend_npu());
        let gpu = prices.register_backend(gpu_h800_decode());
        assert_ne!(npu, gpu);
        assert_eq!(prices.register_backend(ascend_npu()), npu, "dedup by name");
        assert_eq!(prices.backend(gpu).name, "gpu-h800-decode");

        for kernel in KernelKind::all() {
            for (id, hw) in [(npu, ascend_npu()), (gpu, gpu_h800_decode())] {
                let wl = AttentionWorkload::decode(128, 4096, 256);
                let direct = parallel_attention_time(&cfg, kernel, &wl, &hw, &par);
                assert_eq!(prices.time(kernel, id, 128, 4096, 256).to_bits(), direct.to_bits());
                // Hit path returns identical bits.
                assert_eq!(prices.time(kernel, id, 128, 4096, 256).to_bits(), direct.to_bits());
            }
        }
        assert_eq!(prices.misses, 10);
        assert_eq!(prices.hits, 10);
        // Same workload, different backend: distinct keys, different times.
        assert_ne!(
            prices.time(KernelKind::Typhoon, npu, 128, 4096, 256),
            prices.time(KernelKind::Typhoon, gpu, 128, 4096, 256)
        );
    }

    #[test]
    fn grouped_shared_cost_sums_per_group() {
        let cfg = deepseek_v3();
        let mut table = CostTable::new(cfg.clone());
        let groups = [
            (KernelKind::Typhoon, 100u64, 4096u64),
            (KernelKind::Absorb, 8, 7069),
        ];
        let got = table.grouped_shared_cost(groups);
        let mut expect_shared = 0u64;
        for &(k, b, ls) in &groups {
            expect_shared +=
                attention_cost(&cfg, k, &AttentionWorkload::decode(b, ls, 0)).shared.macs;
        }
        assert_eq!(got.shared.macs, expect_shared);
        assert_eq!(got.non_shared, Default::default(), "shared stage only");
        // Single group == plain cost call (the legacy reduction).
        let single = table.grouped_shared_cost([(KernelKind::Typhoon, 64u64, 1000u64)]);
        let direct = table.cost(KernelKind::Typhoon, 64, 1000, 0);
        assert_eq!(single.shared, direct.shared);
        assert_eq!(single.combine, direct.combine);
    }

    #[test]
    fn single_parallelism_is_identity() {
        // `new` and an explicit single() table agree with direct
        // `attention_cost` to the bit — the pre-parallelism behavior.
        let cfg = deepseek_v3();
        let mut a = CostTable::new(cfg.clone());
        let mut b = CostTable::with_parallelism(cfg.clone(), ParallelismConfig::single());
        for kernel in KernelKind::all() {
            let direct =
                attention_cost(&cfg, kernel, &AttentionWorkload::decode(128, 4096, 256));
            assert_eq!(a.cost(kernel, 128, 4096, 256), direct);
            assert_eq!(b.cost(kernel, 128, 4096, 256), direct);
        }
    }

    #[test]
    fn sharded_table_matches_parallel_cost_model() {
        let cfg = deepseek_v3();
        let par = ParallelismConfig { tp: 4, sp: 2 };
        let mut table = CostTable::with_parallelism(cfg.clone(), par);
        assert_eq!(table.parallelism(), par);
        for kernel in KernelKind::all() {
            let wl = AttentionWorkload::decode(256, 8192, 512);
            let direct = parallel_attention_cost(&cfg, kernel, &wl, &par);
            assert_eq!(table.cost(kernel, 256, 8192, 512), direct);
            // Cached hit stays identical.
            assert_eq!(table.cost(kernel, 256, 8192, 512), direct);
            // Sharding must change the numbers vs a single device.
            let single = attention_cost(&cfg, kernel, &wl);
            assert_ne!(direct.total(), single.total(), "{kernel:?}");
        }
    }

    #[test]
    fn keys_are_distinguished() {
        let mut table = CostTable::new(deepseek_v3());
        let a = table.cost(KernelKind::Absorb, 8, 100, 10);
        let b = table.cost(KernelKind::Naive, 8, 100, 10);
        let c = table.cost(KernelKind::Absorb, 8, 100, 11);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(table.hits, 0);
        assert_eq!(table.misses, 3);
    }
}
