//! Table 1 of the paper: MAC and HBM read/write requirements of the
//! naive, absorb and TyphoonMLA attention formulations, broken into the
//! shared-prefix and non-shared components plus the epilogue and the
//! absorb-path projections (the Fig. 4 breakdown units).
//!
//! Notation (paper Table 1): B batch, S_q query length, L_s shared
//! context, L_n non-shared context, H heads, D_qk/D_v head dims,
//! D_l KV LoRA rank, D_n noPE dim, D_r RoPE dim.

use crate::config::{KernelKind, ModelConfig};

/// A decode-attention workload instance.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AttentionWorkload {
    /// Batch size (queries attending to the same shared prefix).
    pub batch: u64,
    /// Query tokens per request (1 for plain decode; >1 for speculative
    /// or tree decode).
    pub s_q: u64,
    /// Shared prefix length (tokens).
    pub l_s: u64,
    /// Non-shared context length per request (tokens).
    pub l_n: u64,
}

impl AttentionWorkload {
    pub fn decode(batch: u64, l_s: u64, l_n: u64) -> Self {
        AttentionWorkload { batch, s_q: 1, l_s, l_n }
    }
}

/// AMLA MAC discount (arxiv 2509.25224), as an exact rational.
///
/// AMLA replaces FlashAttention's multiply-based rescaling of the
/// running output with an exponent *add* on the accumulator, deleting
/// one multiply per accumulated element of the `P x V` update.  Per
/// context token the absorb inner loop does `2*(2*D_l+D_r)` MACs of
/// which the rescale multiply is one per output element — we model the
/// saving as 1/8 of the attention-stream MACs (the fraction the AMLA
/// paper's Ascend kernels recover on the absorb GEMMs).  HBM words are
/// untouched: the trick is arithmetic-only.
pub const AMLA_RESCALE_NUM: u64 = 7;
pub const AMLA_RESCALE_DEN: u64 = 8;

/// Apply the AMLA rescaling discount to an attention-stream MAC count.
pub fn amla_macs(macs: u64) -> u64 {
    macs * AMLA_RESCALE_NUM / AMLA_RESCALE_DEN
}

/// MACs + HBM words of one component of the attention computation.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Component {
    pub macs: u64,
    pub hbm_words: u64,
}

impl Component {
    pub fn add(self, other: Component) -> Component {
        Component { macs: self.macs + other.macs, hbm_words: self.hbm_words + other.hbm_words }
    }

    /// `n` identical components summed — exact (u64 multiplication is
    /// repeated addition), used by the simulator's length-bucketed
    /// iteration cost.
    pub fn scale(self, n: u64) -> Component {
        Component { macs: self.macs * n, hbm_words: self.hbm_words * n }
    }
}

/// Full per-kernel cost breakdown.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CostBreakdown {
    /// Attention over the shared prefix ("Stage 1" for typhoon).
    pub shared: Component,
    /// Attention over the non-shared suffix ("Stage 2" for typhoon).
    pub non_shared: Component,
    /// W_KVb1 query absorption (absorb-path prologue).
    pub proj_kvb1: Component,
    /// W_KVb2 output up-projection (absorb-path epilogue).
    pub proj_kvb2: Component,
    /// CombineLSE merge of the two partial outputs.
    pub combine: Component,
}

impl CostBreakdown {
    pub fn total(&self) -> Component {
        self.shared
            .add(self.non_shared)
            .add(self.proj_kvb1)
            .add(self.proj_kvb2)
            .add(self.combine)
    }

    /// Attention-only total (the Table 1 rows exclude projections).
    pub fn attention_only(&self) -> Component {
        self.shared.add(self.non_shared)
    }
}

/// Table 1, evaluated exactly.
pub fn attention_cost(
    cfg: &ModelConfig,
    kind: KernelKind,
    wl: &AttentionWorkload,
) -> CostBreakdown {
    let b = wl.batch;
    let sq = wl.s_q;
    let (ls, ln) = (wl.l_s, wl.l_n);
    let h = cfg.n_heads as u64;
    let (d_qk, d_v) = (cfg.d_qk() as u64, cfg.d_v as u64);
    let (d_l, d_n) = (cfg.kv_lora_rank as u64, cfg.d_nope as u64);

    let naive_f = cfg.naive_factor(); // H*(D_qk+D_v)
    let absorb_f = cfg.absorb_factor(); // H*(2*D_l+D_r)
    let lat_w = cfg.latent_words(); // D_l+D_r
    let unc_w = cfg.uncompressed_words(); // H*(D_qk+D_v)

    // Query/output streams are O(B*H*D) and included in the component
    // that owns them via the combine/proj terms; Table 1 counts only the
    // KV streams, which dominate.
    let mut cost = CostBreakdown::default();
    match kind {
        KernelKind::Naive => {
            // Shared K/V read once (prefix-aware), reused across batch.
            cost.shared = Component { macs: b * sq * ls * naive_f, hbm_words: ls * unc_w };
            cost.non_shared =
                Component { macs: b * sq * ln * naive_f, hbm_words: b * ln * unc_w };
            // Two softmax branches still need an LSE merge.
            cost.combine = combine_cost(cfg, b, sq);
        }
        KernelKind::Absorb => {
            cost.shared = Component { macs: b * sq * ls * absorb_f, hbm_words: ls * lat_w };
            cost.non_shared =
                Component { macs: b * sq * ln * absorb_f, hbm_words: b * ln * lat_w };
            cost.proj_kvb1 = proj_cost(b, sq, h, d_n, d_l);
            cost.proj_kvb2 = proj_cost(b, sq, h, d_v, d_l);
            cost.combine = combine_cost(cfg, b, sq);
        }
        KernelKind::Typhoon => {
            // Naive on shared, absorb on non-shared (Alg. 1).
            cost.shared = Component { macs: b * sq * ls * naive_f, hbm_words: ls * unc_w };
            cost.non_shared =
                Component { macs: b * sq * ln * absorb_f, hbm_words: b * ln * lat_w };
            cost.proj_kvb1 = proj_cost(b, sq, h, d_n, d_l);
            cost.proj_kvb2 = proj_cost(b, sq, h, d_v, d_l);
            cost.combine = combine_cost(cfg, b, sq);
        }
        KernelKind::AmlaAbsorb => {
            // Absorb with the AMLA rescaling discount on both attention
            // streams; projections/combine and all words are unchanged.
            cost.shared = Component {
                macs: amla_macs(b * sq * ls * absorb_f),
                hbm_words: ls * lat_w,
            };
            cost.non_shared = Component {
                macs: amla_macs(b * sq * ln * absorb_f),
                hbm_words: b * ln * lat_w,
            };
            cost.proj_kvb1 = proj_cost(b, sq, h, d_n, d_l);
            cost.proj_kvb2 = proj_cost(b, sq, h, d_v, d_l);
            cost.combine = combine_cost(cfg, b, sq);
        }
        KernelKind::TyphoonAmla => {
            // Naive on shared, AMLA-absorb on non-shared.
            cost.shared = Component { macs: b * sq * ls * naive_f, hbm_words: ls * unc_w };
            cost.non_shared = Component {
                macs: amla_macs(b * sq * ln * absorb_f),
                hbm_words: b * ln * lat_w,
            };
            cost.proj_kvb1 = proj_cost(b, sq, h, d_n, d_l);
            cost.proj_kvb2 = proj_cost(b, sq, h, d_v, d_l);
            cost.combine = combine_cost(cfg, b, sq);
        }
    }
    let _ = (d_qk, d_v, cfg.d_rope);
    cost
}

fn proj_cost(b: u64, sq: u64, h: u64, d_small: u64, d_l: u64) -> Component {
    // Per query head: [d_small] x [d_small, D_l] einsum.
    Component { macs: b * sq * h * d_small * d_l, hbm_words: h * d_small * d_l + b * sq * h * d_l }
}

fn combine_cost(cfg: &ModelConfig, b: u64, sq: u64) -> Component {
    // Paper §3.2: 2*B*S_q*H*D_v reads and MACs, context-length free.
    let n = 2 * b * sq * (cfg.n_heads * cfg.d_v) as u64;
    Component { macs: n, hbm_words: n }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::deepseek_v3;

    fn dsv3_wl() -> AttentionWorkload {
        AttentionWorkload::decode(1, 1, 1)
    }

    /// The Table 1 rows with DeepSeek-v3 parameters substituted:
    /// naive  MAC 40Ki*(B L_s + B L_n)   HBM 40Ki*L_s + 40Ki*B*L_n
    /// absorb MAC 136Ki*(B L_s + B L_n)  HBM 0.5625Ki*(L_s + B*L_n)
    /// typhoon MAC 40Ki*B L_s+136Ki*B L_n HBM 40Ki*L_s+0.5625Ki*B*L_n
    #[test]
    fn table1_formulas_deepseek() {
        let cfg = deepseek_v3();
        let ki = 1024u64;
        let wl = AttentionWorkload::decode(8, 1000, 200); // B=8, Ls=1000, Ln=200

        let n = attention_cost(&cfg, KernelKind::Naive, &wl);
        assert_eq!(n.shared.macs, 8 * 1000 * 40 * ki);
        assert_eq!(n.non_shared.macs, 8 * 200 * 40 * ki);
        assert_eq!(n.shared.hbm_words, 1000 * 40 * ki);
        assert_eq!(n.non_shared.hbm_words, 8 * 200 * 40 * ki);

        let a = attention_cost(&cfg, KernelKind::Absorb, &wl);
        assert_eq!(a.shared.macs, 8 * 1000 * 136 * ki);
        assert_eq!(a.non_shared.macs, 8 * 200 * 136 * ki);
        assert_eq!(a.shared.hbm_words, 1000 * 576);
        assert_eq!(a.non_shared.hbm_words, 8 * 200 * 576);

        let t = attention_cost(&cfg, KernelKind::Typhoon, &wl);
        assert_eq!(t.shared.macs, n.shared.macs, "typhoon shared = naive shared");
        assert_eq!(t.non_shared.macs, a.non_shared.macs, "typhoon non-shared = absorb");
        assert_eq!(t.shared.hbm_words, n.shared.hbm_words);
        assert_eq!(t.non_shared.hbm_words, a.non_shared.hbm_words);
        let _ = dsv3_wl();
    }

    /// Paper claims: typhoon's HBM read of the non-shared part is ~70x
    /// smaller than naive's; shared MACs 3.4x smaller than absorb's.
    #[test]
    fn headline_ratios() {
        let cfg = deepseek_v3();
        let wl = AttentionWorkload::decode(64, 4096, 512);
        let n = attention_cost(&cfg, KernelKind::Naive, &wl);
        let a = attention_cost(&cfg, KernelKind::Absorb, &wl);
        let t = attention_cost(&cfg, KernelKind::Typhoon, &wl);
        let hbm_ratio = n.non_shared.hbm_words as f64 / t.non_shared.hbm_words as f64;
        assert!((hbm_ratio - 71.1).abs() < 0.5, "{hbm_ratio}"); // 40Ki/576 ≈ 71
        let mac_ratio = a.shared.macs as f64 / t.shared.macs as f64;
        assert!((mac_ratio - 3.4).abs() < 0.01, "{mac_ratio}");
    }

    /// TyphoonMLA dominates: <= naive in HBM and <= absorb in MACs
    /// (the highlighted cells of Table 1), for any workload.
    #[test]
    fn typhoon_pareto_dominates() {
        let cfg = deepseek_v3();
        for b in [1u64, 4, 64, 1024] {
            for ls in [0u64, 128, 4096, 26472] {
                for ln in [0u64, 64, 512, 8192] {
                    let wl = AttentionWorkload::decode(b, ls, ln);
                    let n = attention_cost(&cfg, KernelKind::Naive, &wl).attention_only();
                    let a = attention_cost(&cfg, KernelKind::Absorb, &wl).attention_only();
                    let t = attention_cost(&cfg, KernelKind::Typhoon, &wl).attention_only();
                    assert!(t.hbm_words <= n.hbm_words, "b={b} ls={ls} ln={ln}");
                    assert!(t.macs <= a.macs, "b={b} ls={ls} ln={ln}");
                }
            }
        }
    }

    /// The AMLA variants discount exactly the attention-stream MACs by
    /// 7/8 and change nothing else: words, projections and combine are
    /// bit-identical to their non-AMLA counterparts.
    #[test]
    fn amla_discounts_attention_macs_only() {
        let cfg = deepseek_v3();
        for (base, amla) in [
            (KernelKind::Absorb, KernelKind::AmlaAbsorb),
            (KernelKind::Typhoon, KernelKind::TyphoonAmla),
        ] {
            for wl in [
                AttentionWorkload::decode(8, 1000, 200),
                AttentionWorkload::decode(1024, 26472, 512),
                AttentionWorkload::decode(1, 0, 17),
            ] {
                let b = attention_cost(&cfg, base, &wl);
                let a = attention_cost(&cfg, amla, &wl);
                // Shared stage: discounted for absorb-family, identical
                // (naive) for the typhoon pair.
                if base == KernelKind::Absorb {
                    assert_eq!(a.shared.macs, amla_macs(b.shared.macs));
                } else {
                    assert_eq!(a.shared, b.shared);
                }
                assert_eq!(a.non_shared.macs, amla_macs(b.non_shared.macs));
                assert_eq!(a.shared.hbm_words, b.shared.hbm_words);
                assert_eq!(a.non_shared.hbm_words, b.non_shared.hbm_words);
                assert_eq!(a.proj_kvb1, b.proj_kvb1);
                assert_eq!(a.proj_kvb2, b.proj_kvb2);
                assert_eq!(a.combine, b.combine);
                // The discount is real whenever the stream is nonempty.
                if wl.l_n > 0 {
                    assert!(a.non_shared.macs < b.non_shared.macs);
                }
            }
        }
    }

    /// `amla_macs` is the exact rational 7/8 on the absorb factors (all
    /// divisible by 8), and never rounds up.
    #[test]
    fn amla_macs_exact_on_absorb_factors() {
        let cfg = deepseek_v3();
        assert_eq!(cfg.absorb_factor() % AMLA_RESCALE_DEN, 0);
        assert_eq!(amla_macs(cfg.absorb_factor()), cfg.absorb_factor() / 8 * 7);
        assert_eq!(amla_macs(0), 0);
        assert!(amla_macs(9) <= 9 * 7 / 8);
    }

    #[test]
    fn combine_cost_is_context_free() {
        let cfg = deepseek_v3();
        let c1 = attention_cost(&cfg, KernelKind::Typhoon, &AttentionWorkload::decode(8, 100, 10));
        let c2 =
            attention_cost(&cfg, KernelKind::Typhoon, &AttentionWorkload::decode(8, 100_000, 10_000));
        assert_eq!(c1.combine, c2.combine);
    }
}
