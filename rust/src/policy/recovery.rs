//! Recovery policy: what the cluster does when the fault layer
//! (`simulator::faults`) bites (DESIGN.md §14).
//!
//! Three decisions, all priced and deterministic:
//!
//! * **Transfer retry** — a lost or truncated `PrefixExport` is retried
//!   with exponential backoff; every attempt burns the (degraded)
//!   modeled transfer seconds plus the backoff wait, and the attempt
//!   count is capped so a partitioned pair gives up instead of
//!   spinning.
//! * **Crash detection** — a replica is declared dead only after it has
//!   been silent past `crash_timeout`; failover work is charged from
//!   the detection time, not the crash time.
//! * **Failover placement** — a dead replica's prefix groups re-home to
//!   survivors, preferring a surviving page copy (free consolidation,
//!   the pages are already resident) and falling back to a cost-priced
//!   re-prefill when no copy exists anywhere in the fleet.

use anyhow::{bail, Result};

/// One recorded attempt of a retried transfer, for audits: `attempt`
/// is 1-based, `transfer_seconds` is the (degradation-adjusted) time
/// the attempt burned, `backoff_seconds` the wait before the next try
/// (0 for the final/successful attempt).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetryAttempt {
    pub attempt: u32,
    pub transfer_seconds: f64,
    pub backoff_seconds: f64,
}

/// The recovery knobs one cluster owns (a `PolicyEngine` field, like
/// migration/admission/scaling).
#[derive(Clone, Copy, Debug)]
pub struct RecoveryPolicy {
    /// Total tries per transfer (first attempt included), at least 1.
    pub max_attempts: u32,
    /// Backoff before retry k+1 is `backoff_base * 2^(k-1)` seconds...
    pub backoff_base: f64,
    /// ...capped at this, so a long outage waits linearly, not
    /// exponentially.
    pub backoff_cap: f64,
    /// A replica silent this long past its last heartbeat is declared
    /// dead; failover is charged from crash time + this.
    pub crash_timeout: f64,
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        RecoveryPolicy {
            max_attempts: 4,
            backoff_base: 0.05,
            backoff_cap: 2.0,
            crash_timeout: 0.5,
        }
    }
}

impl RecoveryPolicy {
    pub fn validate(&self) -> Result<()> {
        if self.max_attempts == 0 {
            bail!("recovery needs at least one transfer attempt");
        }
        if !self.backoff_base.is_finite() || self.backoff_base < 0.0 {
            bail!("backoff base must be finite and nonnegative, got {}", self.backoff_base);
        }
        if !self.backoff_cap.is_finite() || self.backoff_cap < self.backoff_base {
            bail!(
                "backoff cap must be finite and at least the base, got {}",
                self.backoff_cap
            );
        }
        if !self.crash_timeout.is_finite() || self.crash_timeout < 0.0 {
            bail!("crash timeout must be finite and nonnegative, got {}", self.crash_timeout);
        }
        Ok(())
    }

    /// Exponential backoff after failed attempt `attempt` (1-based):
    /// `base * 2^(attempt-1)`, capped.
    pub fn backoff(&self, attempt: u32) -> f64 {
        let doublings = attempt.saturating_sub(1).min(32);
        (self.backoff_base * (1u64 << doublings) as f64).min(self.backoff_cap)
    }

    /// May another attempt follow failed attempt `attempt` (1-based)?
    pub fn should_retry(&self, attempt: u32) -> bool {
        attempt < self.max_attempts
    }

    /// Priced cost of one *failed* attempt: the wire time burned plus
    /// the backoff wait before the next try (no wait after the last).
    pub fn attempt_seconds(&self, attempt: u32, transfer_seconds: f64) -> f64 {
        let wait = if self.should_retry(attempt) { self.backoff(attempt) } else { 0.0 };
        transfer_seconds + wait
    }

    /// Timeout-based crash detection: true once a replica has been
    /// silent for `silent_for` seconds.
    pub fn detects_crash(&self, silent_for: f64) -> bool {
        silent_for >= self.crash_timeout
    }

    /// Failover placement: import the pages from a surviving copy when
    /// any exists; otherwise the caller re-prefills at the new home.
    pub fn prefer_copy_import(&self, surviving_copies: usize) -> bool {
        surviving_copies > 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        RecoveryPolicy::default().validate().unwrap();
    }

    #[test]
    fn validate_rejects_degenerate_knobs() {
        let mut p = RecoveryPolicy::default();
        p.max_attempts = 0;
        assert!(p.validate().is_err());
        p = RecoveryPolicy::default();
        p.backoff_base = f64::NAN;
        assert!(p.validate().is_err());
        p = RecoveryPolicy::default();
        p.backoff_cap = 0.01; // below the base
        assert!(p.validate().is_err());
        p = RecoveryPolicy::default();
        p.crash_timeout = -1.0;
        assert!(p.validate().is_err());
    }

    #[test]
    fn backoff_doubles_then_caps() {
        let p = RecoveryPolicy {
            max_attempts: 10,
            backoff_base: 0.1,
            backoff_cap: 0.5,
            crash_timeout: 0.5,
        };
        assert_eq!(p.backoff(1), 0.1);
        assert_eq!(p.backoff(2), 0.2);
        assert_eq!(p.backoff(3), 0.4);
        assert_eq!(p.backoff(4), 0.5, "capped");
        assert_eq!(p.backoff(40), 0.5, "huge attempt counts stay capped");
    }

    #[test]
    fn retry_budget_is_capped_and_priced() {
        let p = RecoveryPolicy::default();
        assert!(p.should_retry(1));
        assert!(p.should_retry(3));
        assert!(!p.should_retry(4), "max_attempts is a hard cap");
        let first = p.attempt_seconds(1, 2.0);
        assert_eq!(first, 2.0 + p.backoff(1), "failed attempt = wire time + wait");
        let last = p.attempt_seconds(4, 2.0);
        assert_eq!(last, 2.0, "the final attempt never waits");
        assert!(p.attempt_seconds(3, 2.0) > first, "backoff grows per attempt");
    }

    #[test]
    fn crash_detection_is_a_threshold() {
        let p = RecoveryPolicy::default();
        assert!(!p.detects_crash(0.0));
        assert!(!p.detects_crash(0.49));
        assert!(p.detects_crash(0.5));
        assert!(p.detects_crash(10.0));
    }

    #[test]
    fn failover_prefers_surviving_copies() {
        let p = RecoveryPolicy::default();
        assert!(p.prefer_copy_import(1));
        assert!(p.prefer_copy_import(3));
        assert!(!p.prefer_copy_import(0), "no copy anywhere: re-prefill");
    }
}
