//! Kernel-selection policy: TyphoonMLA's fall-back rule (paper §3.1,
//! "Fall-back to Absorb"), generalized into a cost-priced **kernel
//! registry** (DESIGN.md §16).
//!
//! Below the batch threshold B_theta (Eq. 1) there is not enough data
//! reuse for the naive stage to pay off, so a Typhoon deployment
//! executes the absorb-only kernel instead — "ensuring consistently
//! high efficiency across a wide range of batch sizes".
//!
//! The registry turns that binary branch into a table: every kernel is
//! a [`KernelDescriptor`] (name, Table-1 cost function over
//! `(B, L_s, L_n, HardwareSpec, Parallelism)`, applicability
//! predicate), and [`KernelPolicy`] prices the applicable entries per
//! prefix group each iteration.  Entries split into two families —
//! naive-shared readers (typhoon, typhoon-amla, naive) and the absorb
//! formulations (absorb, amla-absorb).  All naive-family entries share
//! the *identical* naive shared stage, so the family decision reduces
//! to the pairwise Eq. 1 crossover against the chosen absorb fallback
//! (`costmodel::parallel::parallel_pair_threshold`), precomputed as an
//! integer threshold; *within* a family the cheapest priced entry wins
//! (strict `<`, first-in-order on ties).
//!
//! **Bit-identity invariant** (pinned by `tests/registry.rs`): the
//! registry restricted to the binary `{requested, absorb-fallback}`
//! population — the default every constructor seeds — reproduces the
//! pre-registry `KernelPolicy` decision for every input.  The floored
//! analytic threshold, not a priced comparison, makes the family call:
//! Eq. 1 floors the exact crossover (61.44 -> 61 on Ascend) while a
//! priced scan would cross at 62, so pricing the family decision would
//! flip the boundary batch.
//!
//! With prefix groups the decision is **per group**: `select` is
//! called with the group's occupancy and the group's shared length —
//! a cold tenant falls back to absorb while a hot tenant runs Typhoon
//! in the same decode iteration.
//!
//! B_theta is **parallelism-aware**: a TP/SP-sharded stack derives the
//! per-rank threshold via `costmodel::parallel::parallel_batch_threshold`
//! (`from_parallelism`), which reproduces the single-device Eq. 1 value
//! bit-identically at `ranks = 1` and collapses in the deep-TP latent
//! replication regime.

use std::sync::Arc;

use crate::config::{HardwareSpec, KernelKind, ModelConfig};
use crate::costmodel::exec_time::component_time;
use crate::costmodel::flops::{AttentionWorkload, CostBreakdown};
use crate::costmodel::parallel::{
    parallel_attention_cost, parallel_batch_threshold, parallel_pair_threshold,
    ParallelismConfig,
};
use crate::costmodel::surface::PriceSurface;

/// Everything the registry knows about one prefix group when pricing
/// its kernel for the next decode iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GroupContext {
    /// The group's occupancy (whole batch for single-prefix configs).
    pub batch: usize,
    /// The group's shared-prefix length, tokens.
    pub shared_len: usize,
    /// Mean non-shared context length across the group's members,
    /// tokens.  The binary (threshold-only) population never reads it;
    /// the N-way pricing uses it to weigh the non-shared stage.
    pub mean_non_shared: usize,
    /// What the operator configured the stack to run.
    pub requested: KernelKind,
}

/// Table-1 cost of one kernel at a workload, per rank under (TP, SP).
pub type KernelCostFn =
    fn(&ModelConfig, &AttentionWorkload, &ParallelismConfig) -> CostBreakdown;

/// Whether a registry entry may serve a group at all.
pub type ApplicableFn = fn(&GroupContext) -> bool;

/// One priced kernel in the registry.
#[derive(Clone, Debug)]
pub struct KernelDescriptor {
    pub kind: KernelKind,
    pub name: &'static str,
    /// Cost function over `(B, L_s, L_n)` x parallelism; the policy
    /// turns it into seconds against its `HardwareSpec`.
    pub cost: KernelCostFn,
    /// Applicability predicate evaluated per group.
    pub applicable: ApplicableFn,
}

fn always(_: &GroupContext) -> bool {
    true
}

fn with_shared_prefix(ctx: &GroupContext) -> bool {
    ctx.shared_len > 0
}

fn cost_fn(kind: KernelKind) -> KernelCostFn {
    match kind {
        KernelKind::Typhoon => |c, w, p| parallel_attention_cost(c, KernelKind::Typhoon, w, p),
        KernelKind::Absorb => |c, w, p| parallel_attention_cost(c, KernelKind::Absorb, w, p),
        KernelKind::Naive => |c, w, p| parallel_attention_cost(c, KernelKind::Naive, w, p),
        KernelKind::AmlaAbsorb => {
            |c, w, p| parallel_attention_cost(c, KernelKind::AmlaAbsorb, w, p)
        }
        KernelKind::TyphoonAmla => {
            |c, w, p| parallel_attention_cost(c, KernelKind::TyphoonAmla, w, p)
        }
    }
}

impl KernelDescriptor {
    /// The standard descriptor for a kernel: its Table-1 parallel cost
    /// model and the given applicability predicate.
    pub fn standard(kind: KernelKind, applicable: ApplicableFn) -> Self {
        KernelDescriptor { kind, name: kind.as_str(), cost: cost_fn(kind), applicable }
    }
}

/// An ordered table of kernel descriptors.  Order is the tie-break:
/// when two entries of a family price identically, the earlier one
/// wins — `full()` lists the legacy kernels first so exact ties keep
/// today's choices.
#[derive(Clone, Debug)]
pub struct KernelRegistry {
    entries: Vec<KernelDescriptor>,
}

impl KernelRegistry {
    /// The binary seed population for an operator-requested kernel:
    /// the kernel itself plus (for the naive-shared readers) its
    /// absorb-family fallback.  This is exactly the pre-registry
    /// policy's option set, and the predicates are `always` so the
    /// decision is purely threshold-driven — the bit-identity mode.
    pub fn binary(requested: KernelKind) -> Self {
        let kinds: &[KernelKind] = match requested {
            KernelKind::Typhoon => &[KernelKind::Typhoon, KernelKind::Absorb],
            KernelKind::TyphoonAmla => &[KernelKind::TyphoonAmla, KernelKind::AmlaAbsorb],
            KernelKind::Absorb => &[KernelKind::Absorb],
            KernelKind::AmlaAbsorb => &[KernelKind::AmlaAbsorb],
            KernelKind::Naive => &[KernelKind::Naive],
        };
        KernelRegistry {
            entries: kinds.iter().map(|&k| KernelDescriptor::standard(k, always)).collect(),
        }
    }

    /// The full N-way population: every kernel the cost model knows.
    /// Naive-shared readers require a shared prefix to exist; the
    /// absorb formulations serve any group.
    pub fn full() -> Self {
        let entries = KernelKind::all()
            .iter()
            .map(|&k| {
                let applicable: ApplicableFn =
                    if k.reads_shared_naive() { with_shared_prefix } else { always };
                KernelDescriptor::standard(k, applicable)
            })
            .collect();
        KernelRegistry { entries }
    }

    pub fn entries(&self) -> &[KernelDescriptor] {
        &self.entries
    }

    pub fn kinds(&self) -> Vec<KernelKind> {
        self.entries.iter().map(|d| d.kind).collect()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// The model/hardware/sharding a policy prices its registry against.
/// Absent (threshold-override construction), families must be
/// singletons or the first entry wins.
#[derive(Clone, Debug)]
struct PricingContext {
    cfg: ModelConfig,
    hw: HardwareSpec,
    par: ParallelismConfig,
    s_q: u64,
}

#[derive(Clone, Debug)]
pub struct KernelPolicy {
    /// The configured kernel (what the operator asked for).
    pub requested: KernelKind,
    /// Fall-back threshold in batch size against the classic absorb
    /// fallback (the legacy Eq. 1 quantity; kept as the public pricing
    /// surface `migration_cooldown_tokens` et al. consume).
    pub b_theta: usize,
    /// A shared prefix must exist and be at least this long for the
    /// naive stage to be worth scheduling at all.
    pub min_shared_len: usize,
    /// The priced option set.
    registry: KernelRegistry,
    /// Per-entry integer fall-back threshold: `Some(B_theta)` for
    /// absorb-family entries (the pairwise Eq. 1 crossover against
    /// *that* fallback), `None` for naive-family entries.
    thetas: Vec<Option<usize>>,
    pricing: Option<PricingContext>,
    /// Fleet-shared price memo (DESIGN.md §17).  When attached, entry
    /// pricing routes through the surface's `(kernel, B, L_s, L_n)`
    /// memo — the compute closure stays this policy's own cost path,
    /// so attached and detached pricing are bit-identical.
    surface: Option<Arc<PriceSurface>>,
}

impl KernelPolicy {
    /// Derive the per-rank B_theta from model + hardware + the stack's
    /// TP/SP sharding via the parallel Eq. 1, over the binary seed
    /// registry for `requested`.  The query length is explicit
    /// (`s_q = 1` for plain decode; speculative/tree decode lowers the
    /// threshold proportionally).
    pub fn from_parallelism(
        requested: KernelKind,
        cfg: &ModelConfig,
        hw: &HardwareSpec,
        s_q: u64,
        par: &ParallelismConfig,
    ) -> Self {
        Self::with_registry(KernelRegistry::binary(requested), requested, cfg, hw, s_q, par)
    }

    /// The N-way policy: price the full registry per prefix group.
    /// `requested` is what the operator configured (it seeds the
    /// `GroupContext`); the registry may still pick any applicable
    /// entry.
    pub fn n_way(
        requested: KernelKind,
        cfg: &ModelConfig,
        hw: &HardwareSpec,
        s_q: u64,
        par: &ParallelismConfig,
    ) -> Self {
        Self::with_registry(KernelRegistry::full(), requested, cfg, hw, s_q, par)
    }

    /// A policy over an explicit registry, with every absorb-family
    /// entry's pairwise threshold derived analytically.
    pub fn with_registry(
        registry: KernelRegistry,
        requested: KernelKind,
        cfg: &ModelConfig,
        hw: &HardwareSpec,
        s_q: u64,
        par: &ParallelismConfig,
    ) -> Self {
        let thetas = registry
            .entries
            .iter()
            .map(|d| {
                d.kind
                    .is_absorb_family()
                    .then(|| parallel_pair_threshold(cfg, hw, s_q, par, d.kind))
            })
            .collect();
        KernelPolicy {
            requested,
            b_theta: parallel_batch_threshold(cfg, hw, s_q, par),
            min_shared_len: 1,
            registry,
            thetas,
            pricing: Some(PricingContext {
                cfg: cfg.clone(),
                hw: hw.clone(),
                par: *par,
                s_q,
            }),
            surface: None,
        }
    }

    /// Derive B_theta from the model + hardware via Eq. 1.
    #[deprecated(
        note = "hard-codes s_q = 1 and ranks = 1; use from_parallelism so \
                sharded stacks get the per-rank threshold"
    )]
    pub fn from_cost_model(
        requested: KernelKind,
        cfg: &ModelConfig,
        hw: &HardwareSpec,
    ) -> Self {
        Self::from_parallelism(requested, cfg, hw, 1, &ParallelismConfig::single())
    }

    /// Threshold-override construction (tests, calibrated deployments):
    /// the binary registry with every absorb entry's threshold pinned
    /// to `b_theta`; no pricing context.
    pub fn with_threshold(requested: KernelKind, b_theta: usize) -> Self {
        let registry = KernelRegistry::binary(requested);
        let thetas = registry
            .entries
            .iter()
            .map(|d| d.kind.is_absorb_family().then_some(b_theta))
            .collect();
        KernelPolicy {
            requested,
            b_theta,
            min_shared_len: 1,
            registry,
            thetas,
            pricing: None,
            surface: None,
        }
    }

    /// Adopt a fleet-shared [`PriceSurface`] for entry pricing.  The
    /// surface must cover this policy's pricing cell exactly
    /// (model/hardware/parallelism/`s_q`); a mismatched surface is
    /// silently refused — the policy keeps pricing directly, which is
    /// always correct, just unmemoized.  The surface memo is keyed by
    /// `KernelKind`, which assumes standard descriptors (the
    /// `cost_fn(kind)` table every repo registry uses); a
    /// `with_registry` population carrying custom cost functions must
    /// not attach a shared surface.
    pub fn attach_surface(&mut self, surface: &Arc<PriceSurface>) {
        let Some(pc) = &self.pricing else { return };
        if surface.covers(&pc.cfg, &pc.hw, &pc.par, pc.s_q) {
            self.surface = Some(Arc::clone(surface));
        }
    }

    pub fn registry(&self) -> &KernelRegistry {
        &self.registry
    }

    /// The pairwise fall-back threshold of an absorb-family entry, or
    /// `None` for naive-family kinds / kinds not in the registry.
    pub fn theta_for(&self, kind: KernelKind) -> Option<usize> {
        self.registry
            .entries
            .iter()
            .position(|d| d.kind == kind)
            .and_then(|i| self.thetas[i])
    }

    /// The per-group decision: `batch` is the *group's* occupancy (the
    /// whole batch for single-prefix configs), `shared_len` the group's
    /// prefix length.  Legacy entry point — prices the group with an
    /// unknown (zero) mean non-shared length, which the binary
    /// population ignores entirely.
    pub fn select(&self, batch: usize, shared_len: usize) -> KernelKind {
        self.select_group(batch, shared_len, 0)
    }

    /// The registry decision with the group's full context.
    pub fn select_group(
        &self,
        batch: usize,
        shared_len: usize,
        mean_non_shared: usize,
    ) -> KernelKind {
        let ctx = GroupContext {
            batch,
            shared_len,
            mean_non_shared,
            requested: self.requested,
        };
        let applicable: Vec<usize> = (0..self.registry.entries.len())
            .filter(|&i| (self.registry.entries[i].applicable)(&ctx))
            .collect();
        let best_naive = self.best_in_family(&applicable, &ctx, true);
        let best_absorb = self.best_in_family(&applicable, &ctx, false);
        match (best_naive, best_absorb) {
            (Some(n), Some(a)) => {
                // The family decision is the analytic pairwise Eq. 1
                // threshold against the absorb entry that would run —
                // floored, so the boundary batch matches the paper's
                // integer B_theta (and the pre-registry policy).
                let theta = self.thetas[a].expect("absorb entries carry a threshold");
                if ctx.batch >= theta && ctx.shared_len >= self.min_shared_len {
                    self.registry.entries[n].kind
                } else {
                    self.registry.entries[a].kind
                }
            }
            (Some(n), None) => self.registry.entries[n].kind,
            (None, Some(a)) => self.registry.entries[a].kind,
            // No applicable entry (a fully predicated-out registry):
            // run what the operator asked for.
            (None, None) => self.requested,
        }
    }

    /// Cheapest applicable entry of one family: priced roofline
    /// seconds at the group's workload, strict `<` so the earliest
    /// entry wins exact ties.  Without a pricing context (threshold
    /// override), the earliest applicable entry wins outright — binary
    /// registries have singleton families, so nothing is lost.
    fn best_in_family(
        &self,
        applicable: &[usize],
        ctx: &GroupContext,
        naive_family: bool,
    ) -> Option<usize> {
        let mut best: Option<(usize, f64)> = None;
        for &i in applicable {
            let d = &self.registry.entries[i];
            if d.kind.reads_shared_naive() != naive_family {
                continue;
            }
            match (&self.pricing, &mut best) {
                (_, None) => best = Some((i, self.price(i, ctx))),
                (None, Some(_)) => {} // first applicable wins unpriced
                (Some(_), Some((_, t))) => {
                    let ti = self.price(i, ctx);
                    if ti < *t {
                        best = Some((i, ti));
                    }
                }
            }
        }
        best.map(|(i, _)| i)
    }

    /// Roofline seconds of entry `i` at the group's workload (0.0
    /// without a pricing context — only reachable for singleton
    /// families where the value is never compared).  With an attached
    /// surface the value is served from the fleet-shared memo; the
    /// compute closure below is the cold path, so both routes produce
    /// identical bits.
    fn price(&self, i: usize, ctx: &GroupContext) -> f64 {
        let Some(pc) = &self.pricing else { return 0.0 };
        let compute = || {
            let wl = AttentionWorkload {
                batch: ctx.batch as u64,
                s_q: pc.s_q,
                l_s: ctx.shared_len as u64,
                l_n: ctx.mean_non_shared as u64,
            };
            let c = (self.registry.entries[i].cost)(&pc.cfg, &wl, &pc.par);
            [c.shared, c.non_shared, c.proj_kvb1, c.proj_kvb2, c.combine]
                .iter()
                .map(|comp| component_time(comp, &pc.hw))
                .sum::<f64>()
        };
        match &self.surface {
            Some(surface) => surface.kernel_seconds(
                self.registry.entries[i].kind,
                ctx.batch as u64,
                ctx.shared_len as u64,
                ctx.mean_non_shared as u64,
                compute,
            ),
            None => compute(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::{ascend_npu, gpu_h800_decode};
    use crate::config::model::deepseek_v3;

    #[test]
    fn typhoon_falls_back_below_threshold() {
        let p = KernelPolicy::with_threshold(KernelKind::Typhoon, 61);
        assert_eq!(p.select(60, 4096), KernelKind::Absorb);
        assert_eq!(p.select(61, 4096), KernelKind::Typhoon);
        assert_eq!(p.select(1024, 4096), KernelKind::Typhoon);
    }

    #[test]
    fn typhoon_falls_back_without_shared_prefix() {
        let p = KernelPolicy::with_threshold(KernelKind::Typhoon, 1);
        assert_eq!(p.select(512, 0), KernelKind::Absorb);
    }

    #[test]
    fn baselines_never_switch() {
        for k in [KernelKind::Absorb, KernelKind::Naive, KernelKind::AmlaAbsorb] {
            let p = KernelPolicy::with_threshold(k, 61);
            for b in [1, 61, 1024] {
                assert_eq!(p.select(b, 4096), k);
            }
        }
    }

    /// The AMLA pair behaves like the classic pair around its own
    /// (higher) threshold: 70 on Ascend vs the classic 61.
    #[test]
    fn typhoon_amla_falls_back_to_amla_absorb() {
        let p = KernelPolicy::from_parallelism(
            KernelKind::TyphoonAmla,
            &deepseek_v3(),
            &ascend_npu(),
            1,
            &ParallelismConfig::single(),
        );
        assert_eq!(p.theta_for(KernelKind::AmlaAbsorb), Some(70));
        assert_eq!(p.select(69, 4096), KernelKind::AmlaAbsorb);
        assert_eq!(p.select(70, 4096), KernelKind::TyphoonAmla);
        assert_eq!(p.select(1024, 0), KernelKind::AmlaAbsorb, "no shared prefix");
    }

    /// The satellite pin: the explicit `single()` derivation reproduces
    /// the paper's B_theta = 61 on Ascend, and the deprecated implicit
    /// constructor delegates to it.
    #[test]
    fn single_parallelism_reproduces_eq1() {
        let p = KernelPolicy::from_parallelism(
            KernelKind::Typhoon,
            &deepseek_v3(),
            &ascend_npu(),
            1,
            &ParallelismConfig::single(),
        );
        assert_eq!(p.b_theta, 61);
        assert_eq!(p.theta_for(KernelKind::Absorb), Some(61));
        assert_eq!(p.theta_for(KernelKind::Typhoon), None, "naive family has no theta");
        #[allow(deprecated)]
        let implicit = KernelPolicy::from_cost_model(
            KernelKind::Typhoon,
            &deepseek_v3(),
            &ascend_npu(),
        );
        assert_eq!(implicit.b_theta, p.b_theta);
        assert_eq!(implicit.min_shared_len, p.min_shared_len);
    }

    /// The per-rank derivation reaches the sharded regimes: realistic
    /// TP/SP reproduce the single-device value, deep TP collapses it.
    #[test]
    fn sharded_derivation_tracks_per_rank_eq1() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        let tp4sp4 = KernelPolicy::from_parallelism(
            KernelKind::Typhoon,
            &cfg,
            &hw,
            1,
            &ParallelismConfig { tp: 4, sp: 4 },
        );
        assert_eq!(tp4sp4.b_theta, 61, "paper deployment keeps Eq. 1");
        let deep = KernelPolicy::from_parallelism(
            KernelKind::Typhoon,
            &cfg,
            &hw,
            1,
            &ParallelismConfig { tp: 128, sp: 1 },
        );
        assert_eq!(deep.b_theta, 1, "latent replication regime");
        assert_eq!(deep.select(1, 4096), KernelKind::Typhoon);
    }

    /// Per-group semantics: one policy instance makes independent
    /// decisions per group occupancy within an iteration.
    #[test]
    fn per_group_decisions_independent() {
        let p = KernelPolicy::with_threshold(KernelKind::Typhoon, 61);
        let picks: Vec<_> = [(100usize, 4096usize), (8, 4096), (61, 0)]
            .iter()
            .map(|&(b, s)| p.select(b, s))
            .collect();
        assert_eq!(
            picks,
            vec![KernelKind::Typhoon, KernelKind::Absorb, KernelKind::Absorb]
        );
    }

    /// Monotonicity: once a naive-family kernel is selected at batch b,
    /// it stays selected for every larger batch (same shared length).
    #[test]
    fn selection_monotone_in_batch() {
        let p = KernelPolicy::with_threshold(KernelKind::Typhoon, 61);
        let mut seen_typhoon = false;
        for b in 0..200 {
            match p.select(b, 1000) {
                KernelKind::Typhoon => seen_typhoon = true,
                KernelKind::Absorb => {
                    assert!(!seen_typhoon, "fallback after typhoon at b={b}")
                }
                k => unreachable!("binary typhoon registry picked {k:?}"),
            }
        }
        assert!(seen_typhoon);
    }

    /// N-way mode on the full registry: the AMLA variants price
    /// strictly cheaper than their classic counterparts on compute-
    /// bound stages, so the registry picks them — amla-absorb below
    /// the family threshold, typhoon-amla above it (nonzero L_n), and
    /// pure naive when there is no non-shared context at all (no
    /// projections to pay for).
    #[test]
    fn n_way_prices_the_full_registry() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        let p = KernelPolicy::n_way(
            KernelKind::Typhoon,
            &cfg,
            &hw,
            1,
            &ParallelismConfig::single(),
        );
        assert_eq!(p.registry().len(), 5);
        // Family threshold is the *winning* absorb entry's: amla's 70.
        assert_eq!(p.theta_for(KernelKind::AmlaAbsorb), Some(70));
        assert_eq!(p.select_group(8, 4096, 512), KernelKind::AmlaAbsorb);
        assert_eq!(p.select_group(1024, 4096, 512), KernelKind::TyphoonAmla);
        assert_eq!(p.select_group(1024, 4096, 0), KernelKind::Naive);
        // No shared prefix: naive readers are inapplicable.
        assert_eq!(p.select_group(1024, 0, 512), KernelKind::AmlaAbsorb);
    }

    /// The family decision tracks the winning absorb entry's threshold:
    /// between 61 (classic) and 70 (amla) the N-way registry still
    /// serves the absorb family, because the cheaper amla fallback
    /// stays competitive longer.
    #[test]
    fn n_way_family_flip_uses_the_winning_fallback_threshold() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        let p = KernelPolicy::n_way(
            KernelKind::Typhoon,
            &cfg,
            &hw,
            1,
            &ParallelismConfig::single(),
        );
        for b in 61..70 {
            assert!(
                p.select_group(b, 4096, 512).is_absorb_family(),
                "b={b} sits between the classic and amla crossovers"
            );
        }
        assert!(p.select_group(70, 4096, 512).reads_shared_naive());
    }

    /// Per-backend thresholds: the decode-calibrated GPU preset's
    /// T/M = 100 puts the classic crossover at 29 and the AMLA one at
    /// 33 — both pinned here so cost-model edits can't silently move
    /// them.
    #[test]
    fn gpu_decode_thresholds_pinned() {
        let cfg = deepseek_v3();
        let hw = gpu_h800_decode();
        let p = KernelPolicy::n_way(
            KernelKind::Typhoon,
            &cfg,
            &hw,
            1,
            &ParallelismConfig::single(),
        );
        assert_eq!(p.b_theta, 29);
        assert_eq!(p.theta_for(KernelKind::Absorb), Some(29));
        assert_eq!(p.theta_for(KernelKind::AmlaAbsorb), Some(33));
    }

    /// An attached fleet surface memoizes entry pricing without moving
    /// a single decision, and repeat selection runs entirely on memo
    /// hits; a surface for the wrong pricing cell is silently refused
    /// (selection stays correct, memo stays cold).
    #[test]
    fn attached_surface_prices_bit_identically() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        let par = ParallelismConfig::single();
        let detached = KernelPolicy::n_way(KernelKind::Typhoon, &cfg, &hw, 1, &par);
        let mut attached = detached.clone();
        let surface = PriceSurface::shared(cfg.clone(), hw.clone(), par);
        attached.attach_surface(&surface);
        let grid = [(8usize, 4096usize, 512usize), (61, 4096, 512), (70, 4096, 512),
            (1024, 4096, 0), (1024, 0, 512)];
        for &(b, ls, ln) in &grid {
            assert_eq!(
                attached.select_group(b, ls, ln),
                detached.select_group(b, ls, ln),
                "b={b} ls={ls} ln={ln}"
            );
        }
        let (_, misses_cold) = surface.stats();
        assert!(misses_cold > 0, "first pass fills the memo");
        for &(b, ls, ln) in &grid {
            attached.select_group(b, ls, ln);
        }
        let (hits, misses_warm) = surface.stats();
        assert_eq!(misses_warm, misses_cold, "second pass is all hits");
        assert!(hits > 0);

        let mut refused = KernelPolicy::n_way(KernelKind::Typhoon, &cfg, &hw, 2, &par);
        let wrong_cell = PriceSurface::shared(cfg.clone(), hw.clone(), par);
        refused.attach_surface(&wrong_cell); // s_q = 2 vs surface's 1
        refused.select_group(128, 4096, 512);
        assert_eq!(wrong_cell.stats(), (0, 0), "mismatched surface never consulted");
    }

    /// Registry shapes: binary populations per requested kernel, and
    /// the full table lists the legacy kernels first (tie-break order).
    #[test]
    fn registry_populations() {
        assert_eq!(
            KernelRegistry::binary(KernelKind::Typhoon).kinds(),
            vec![KernelKind::Typhoon, KernelKind::Absorb]
        );
        assert_eq!(
            KernelRegistry::binary(KernelKind::TyphoonAmla).kinds(),
            vec![KernelKind::TyphoonAmla, KernelKind::AmlaAbsorb]
        );
        assert_eq!(
            KernelRegistry::binary(KernelKind::Absorb).kinds(),
            vec![KernelKind::Absorb]
        );
        assert_eq!(
            KernelRegistry::binary(KernelKind::Naive).kinds(),
            vec![KernelKind::Naive]
        );
        let full = KernelRegistry::full();
        assert!(!full.is_empty());
        assert_eq!(full.kinds()[..3], KernelKind::all()[..3]);
        assert_eq!(full.kinds().len(), KernelKind::all().len());
    }
}
