//! Kernel-selection policy: TyphoonMLA's fall-back rule (paper §3.1,
//! "Fall-back to Absorb").
//!
//! Below the batch threshold B_theta (Eq. 1) there is not enough data
//! reuse for the naive stage to pay off, so a Typhoon deployment
//! executes the absorb-only kernel instead — "ensuring consistently
//! high efficiency across a wide range of batch sizes".
//!
//! With prefix groups the decision is **per group**: the naive stage
//! amortizes over the sequences sharing *each* prefix, so `select` is
//! called with the group's occupancy and the group's shared length —
//! a cold tenant falls back to absorb while a hot tenant runs Typhoon
//! in the same decode iteration.
//!
//! B_theta is **parallelism-aware**: a TP/SP-sharded stack derives the
//! per-rank threshold via `costmodel::parallel::parallel_batch_threshold`
//! (`from_parallelism`), which reproduces the single-device Eq. 1 value
//! bit-identically at `ranks = 1` and collapses in the deep-TP latent
//! replication regime.

use crate::config::{HardwareSpec, KernelKind, ModelConfig};
use crate::costmodel::parallel::{parallel_batch_threshold, ParallelismConfig};

#[derive(Clone, Debug)]
pub struct KernelPolicy {
    /// The configured kernel (what the operator asked for).
    pub requested: KernelKind,
    /// Fall-back threshold in batch size (only used for Typhoon).
    pub b_theta: usize,
    /// A shared prefix must exist and be at least this long for the
    /// naive stage to be worth scheduling at all.
    pub min_shared_len: usize,
}

impl KernelPolicy {
    /// Derive the per-rank B_theta from model + hardware + the stack's
    /// TP/SP sharding via the parallel Eq. 1.  The query length is
    /// explicit (`s_q = 1` for plain decode; speculative/tree decode
    /// lowers the threshold proportionally).
    pub fn from_parallelism(
        requested: KernelKind,
        cfg: &ModelConfig,
        hw: &HardwareSpec,
        s_q: u64,
        par: &ParallelismConfig,
    ) -> Self {
        KernelPolicy {
            requested,
            b_theta: parallel_batch_threshold(cfg, hw, s_q, par),
            min_shared_len: 1,
        }
    }

    /// Derive B_theta from the model + hardware via Eq. 1.
    #[deprecated(
        note = "hard-codes s_q = 1 and ranks = 1; use from_parallelism so \
                sharded stacks get the per-rank threshold"
    )]
    pub fn from_cost_model(
        requested: KernelKind,
        cfg: &ModelConfig,
        hw: &HardwareSpec,
    ) -> Self {
        Self::from_parallelism(requested, cfg, hw, 1, &ParallelismConfig::single())
    }

    pub fn with_threshold(requested: KernelKind, b_theta: usize) -> Self {
        KernelPolicy { requested, b_theta, min_shared_len: 1 }
    }

    /// The per-group decision: `batch` is the *group's* occupancy (the
    /// whole batch for single-prefix configs), `shared_len` the group's
    /// prefix length.
    pub fn select(&self, batch: usize, shared_len: usize) -> KernelKind {
        match self.requested {
            KernelKind::Typhoon
                if batch < self.b_theta || shared_len < self.min_shared_len =>
            {
                KernelKind::Absorb
            }
            k => k,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::ascend_npu;
    use crate::config::model::deepseek_v3;

    #[test]
    fn typhoon_falls_back_below_threshold() {
        let p = KernelPolicy::with_threshold(KernelKind::Typhoon, 61);
        assert_eq!(p.select(60, 4096), KernelKind::Absorb);
        assert_eq!(p.select(61, 4096), KernelKind::Typhoon);
        assert_eq!(p.select(1024, 4096), KernelKind::Typhoon);
    }

    #[test]
    fn typhoon_falls_back_without_shared_prefix() {
        let p = KernelPolicy::with_threshold(KernelKind::Typhoon, 1);
        assert_eq!(p.select(512, 0), KernelKind::Absorb);
    }

    #[test]
    fn baselines_never_switch() {
        for k in [KernelKind::Absorb, KernelKind::Naive] {
            let p = KernelPolicy::with_threshold(k, 61);
            for b in [1, 61, 1024] {
                assert_eq!(p.select(b, 4096), k);
            }
        }
    }

    /// The satellite pin: the explicit `single()` derivation reproduces
    /// the paper's B_theta = 61 on Ascend, and the deprecated implicit
    /// constructor delegates to it.
    #[test]
    fn single_parallelism_reproduces_eq1() {
        let p = KernelPolicy::from_parallelism(
            KernelKind::Typhoon,
            &deepseek_v3(),
            &ascend_npu(),
            1,
            &ParallelismConfig::single(),
        );
        assert_eq!(p.b_theta, 61);
        #[allow(deprecated)]
        let implicit = KernelPolicy::from_cost_model(
            KernelKind::Typhoon,
            &deepseek_v3(),
            &ascend_npu(),
        );
        assert_eq!(implicit.b_theta, p.b_theta);
        assert_eq!(implicit.min_shared_len, p.min_shared_len);
    }

    /// The per-rank derivation reaches the sharded regimes: realistic
    /// TP/SP reproduce the single-device value, deep TP collapses it.
    #[test]
    fn sharded_derivation_tracks_per_rank_eq1() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        let tp4sp4 = KernelPolicy::from_parallelism(
            KernelKind::Typhoon,
            &cfg,
            &hw,
            1,
            &ParallelismConfig { tp: 4, sp: 4 },
        );
        assert_eq!(tp4sp4.b_theta, 61, "paper deployment keeps Eq. 1");
        let deep = KernelPolicy::from_parallelism(
            KernelKind::Typhoon,
            &cfg,
            &hw,
            1,
            &ParallelismConfig { tp: 128, sp: 1 },
        );
        assert_eq!(deep.b_theta, 1, "latent replication regime");
        assert_eq!(deep.select(1, 4096), KernelKind::Typhoon);
    }

    /// Per-group semantics: one policy instance makes independent
    /// decisions per group occupancy within an iteration.
    #[test]
    fn per_group_decisions_independent() {
        let p = KernelPolicy::with_threshold(KernelKind::Typhoon, 61);
        let picks: Vec<_> = [(100usize, 4096usize), (8, 4096), (61, 0)]
            .iter()
            .map(|&(b, s)| p.select(b, s))
            .collect();
        assert_eq!(
            picks,
            vec![KernelKind::Typhoon, KernelKind::Absorb, KernelKind::Absorb]
        );
    }

    /// Monotonicity: once typhoon is selected at batch b, it stays
    /// selected for every larger batch (same shared length).
    #[test]
    fn selection_monotone_in_batch() {
        let p = KernelPolicy::with_threshold(KernelKind::Typhoon, 61);
        let mut seen_typhoon = false;
        for b in 0..200 {
            match p.select(b, 1000) {
                KernelKind::Typhoon => seen_typhoon = true,
                KernelKind::Absorb => {
                    assert!(!seen_typhoon, "fallback after typhoon at b={b}")
                }
                KernelKind::Naive => unreachable!(),
            }
        }
        assert!(seen_typhoon);
    }
}
