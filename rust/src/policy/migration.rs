//! Migrate-vs-spill: when a prefix group's home replica is pressured,
//! move the group's pages to a peer (one interconnect stream, after
//! which the whole overflow lands on a replica that already holds the
//! prefix) or keep spilling single requests around the home (each
//! fresh spill target re-prefills the prefix and serves the group at
//! fragment occupancy).
//!
//! The rule is cost-driven: migrate exactly when the modeled page
//! transfer is cheaper than the modeled re-prefill the spill stream
//! would trigger on its target.  This replaces PR 3's fixed
//! `spill_queue_depth`-only behavior — the *trigger* is owned by
//! `SloAdmission`; this policy owns the *response*.
//!
//! The comparison prices the *deployment-real* costs.  Under the
//! paper's decode-only throughput protocol (`include_prefill = false`)
//! neither side is debited to goodput — prefill never is, and an
//! inbound transfer lands on the destination clock as wall time, not
//! decode time — so in that protocol the rule's goodput effect comes
//! entirely from keeping the re-homed group's overflow concentrated
//! (one typhoon-eligible group instead of scattered absorb-fallback
//! fragments), which the `cluster` artifact asserts directly.

/// What the router should do with a pressured prefix group's overflow.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MigrationDecision {
    /// Route this one request around the home; pages stay put.
    Spill,
    /// Re-home the group's pages to the peer, then route there.
    Migrate,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct MigrationPolicy {
    /// Master switch: disabled reproduces the PR 3 spill-only router
    /// bit-for-bit (the reduction tests pin this).
    pub enabled: bool,
}

impl MigrationPolicy {
    pub fn new(enabled: bool) -> Self {
        MigrationPolicy { enabled }
    }

    /// The cost rule: migrate when streaming the pages beats
    /// recomputing the prefix at the spill target.  Ties spill (the
    /// cheaper-to-undo action).
    pub fn decide(&self, transfer_seconds: f64, reprefill_seconds: f64) -> MigrationDecision {
        if self.enabled && transfer_seconds < reprefill_seconds {
            MigrationDecision::Migrate
        } else {
            MigrationDecision::Spill
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_always_spills() {
        let p = MigrationPolicy::new(false);
        assert_eq!(p.decide(0.0, 1.0), MigrationDecision::Spill);
        assert_eq!(p.decide(1.0, 0.0), MigrationDecision::Spill);
    }

    #[test]
    fn enabled_follows_the_cost_comparison() {
        let p = MigrationPolicy::new(true);
        assert_eq!(p.decide(0.001, 0.1), MigrationDecision::Migrate);
        assert_eq!(p.decide(0.1, 0.001), MigrationDecision::Spill);
        assert_eq!(p.decide(0.5, 0.5), MigrationDecision::Spill, "ties spill");
    }
}
