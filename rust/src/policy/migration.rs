//! Migrate-vs-spill: when a prefix group's home replica is pressured,
//! move the group's pages to a peer (one interconnect stream, after
//! which the whole overflow lands on a replica that already holds the
//! prefix) or keep spilling single requests around the home (each
//! fresh spill target re-prefills the prefix and serves the group at
//! fragment occupancy).
//!
//! The rule is cost-driven: migrate exactly when the modeled page
//! transfer is cheaper than the modeled re-prefill the spill stream
//! would trigger on its target.  This replaces PR 3's fixed
//! `spill_queue_depth`-only behavior — the *trigger* is owned by
//! `SloAdmission`; this policy owns the *response*.
//!
//! **Cool-down (hysteresis).**  Under sustained overload every replica
//! is pressured, so an unconstrained rule re-homes the hot group on
//! every overflowing arrival — bounded ping-pong, but each hop streams
//! the pages again for no lasting concentration win.  The fix is
//! priced on transfer amortization: after a re-home, the group may not
//! re-home again until it has served enough tokens to amortize the
//! transfer it just paid.  The budget is `transfer_seconds` divided by
//! the modeled per-token saving concentration buys — the duplicated
//! per-iteration shared-stage stream a fragmented group pays, which is
//! exactly what the migration avoided (`PolicyEngine::
//! migration_cooldown_tokens` evaluates it at the Eq. 1 threshold
//! occupancy through the same memoized `CostTable` the engines run).
//! A zero-cost re-home (the peer already held the pages) amortizes
//! instantly; a transfer the cost model sees no saving for never does,
//! so such a group re-homes at most once.
//!
//! The comparison prices the *deployment-real* costs.  Under the
//! paper's decode-only throughput protocol (`include_prefill = false`)
//! neither side is debited to goodput — prefill never is, and an
//! inbound transfer lands on the destination clock as wall time, not
//! decode time — so in that protocol the rule's goodput effect comes
//! entirely from keeping the re-homed group's overflow concentrated
//! (one typhoon-eligible group instead of scattered absorb-fallback
//! fragments), which the `cluster` artifact asserts directly.

/// What the router should do with a pressured prefix group's overflow.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MigrationDecision {
    /// Route this one request around the home; pages stay put.
    Spill,
    /// Re-home the group's pages to the peer, then route there.
    Migrate,
}

#[derive(Clone, Copy, Debug)]
pub struct MigrationPolicy {
    /// Master switch: disabled reproduces the PR 3 spill-only router
    /// bit-for-bit (the reduction tests pin this).
    pub enabled: bool,
    /// Per-group re-home cool-down priced on transfer amortization
    /// (see module docs).  On by default — off reproduces the eager
    /// (ping-pong-prone) PR 4 rule.
    pub cooldown: bool,
}

impl Default for MigrationPolicy {
    fn default() -> Self {
        MigrationPolicy { enabled: false, cooldown: true }
    }
}

impl MigrationPolicy {
    pub fn new(enabled: bool) -> Self {
        MigrationPolicy { enabled, ..Default::default() }
    }

    /// The cost rule: migrate when streaming the pages beats
    /// recomputing the prefix at the spill target.  Ties spill (the
    /// cheaper-to-undo action).
    pub fn decide(&self, transfer_seconds: f64, reprefill_seconds: f64) -> MigrationDecision {
        if self.enabled && transfer_seconds < reprefill_seconds {
            MigrationDecision::Migrate
        } else {
            MigrationDecision::Spill
        }
    }

    /// The served-token budget that amortizes a re-home which paid
    /// `transfer_seconds`, given the modeled per-token saving of
    /// staying concentrated.  Saturates: a saving the cost model
    /// cannot see yields an effectively unbounded budget.
    pub fn cooldown_tokens(&self, transfer_seconds: f64, saving_per_token: f64) -> u64 {
        if !self.cooldown || transfer_seconds <= 0.0 {
            return 0;
        }
        if saving_per_token.is_nan() || saving_per_token <= 0.0 {
            return u64::MAX;
        }
        // f64 -> u64 casts saturate, so an astronomical ratio is MAX,
        // not UB.
        (transfer_seconds / saving_per_token).ceil() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_always_spills() {
        let p = MigrationPolicy::new(false);
        assert_eq!(p.decide(0.0, 1.0), MigrationDecision::Spill);
        assert_eq!(p.decide(1.0, 0.0), MigrationDecision::Spill);
    }

    #[test]
    fn enabled_follows_the_cost_comparison() {
        let p = MigrationPolicy::new(true);
        assert_eq!(p.decide(0.001, 0.1), MigrationDecision::Migrate);
        assert_eq!(p.decide(0.1, 0.001), MigrationDecision::Spill);
        assert_eq!(p.decide(0.5, 0.5), MigrationDecision::Spill, "ties spill");
    }

    #[test]
    fn cooldown_amortizes_the_transfer() {
        let p = MigrationPolicy::new(true);
        assert!(p.cooldown, "cool-down defaults on");
        // 6 ms transfer at a 20 us/token saving: 300 tokens.
        assert_eq!(p.cooldown_tokens(6e-3, 2e-5), 300);
        assert_eq!(p.cooldown_tokens(0.0, 2e-5), 0, "free re-homes amortize instantly");
        assert_eq!(p.cooldown_tokens(6e-3, 0.0), u64::MAX, "no saving never amortizes");
        assert_eq!(p.cooldown_tokens(6e-3, -1.0), u64::MAX);
        assert_eq!(p.cooldown_tokens(1e300, 1e-300), u64::MAX, "saturating cast");
        let mut eager = p;
        eager.cooldown = false;
        assert_eq!(eager.cooldown_tokens(6e-3, 2e-5), 0, "PR 4 eager rule");
    }
}
