//! The unified cost-driven decision layer (DESIGN.md §12).
//!
//! Every runtime decision the serving stack makes — which kernel a
//! prefix group runs (Eq. 1 fall-back), whether a pressured group's
//! overflow spills or its pages migrate, and *when* a replica counts
//! as pressured at all — lives here, priced by the same cost model the
//! engines execute:
//!
//! * [`KernelPolicy`] — the per-group naive/absorb fall-back, with a
//!   **parallelism-aware** B_theta derived per rank
//!   (`costmodel::parallel::parallel_batch_threshold`);
//! * [`MigrationPolicy`] — migrate-vs-spill, comparing the modeled
//!   interconnect transfer of a group's pages against the modeled
//!   re-prefill a spill stream triggers;
//! * [`SloAdmission`] — spill/migrate pressure thresholds derived from
//!   a TTFT target and observed arrival/service rates instead of a
//!   fixed queue-depth constant;
//! * [`ScalingPolicy`] — replica autoscaling: spin replicas up/down
//!   against the observed arrival rate and SLO headroom, with every
//!   re-home of a prefix group priced here (bulk page migration over
//!   the interconnect versus a fresh re-prefill);
//! * [`RecoveryPolicy`] — what happens when the fault layer bites:
//!   capped exponential-backoff retry for lost transfers, timeout
//!   crash detection, and failover placement for a dead replica's
//!   prefix groups (surviving copy first, priced re-prefill fallback).
//!
//! [`PolicyEngine`] bundles the five with a fleet-shared
//! [`PriceSurface`] (DESIGN.md §17) and per-quantity memos, so a
//! router probing costs on every arrival pays dense-array lookups, not
//! cost-model evaluations — and a cluster's policy engine prices
//! against the *same* warm surface its replica engines fill.
//! Consistency with the engines is pinned by tests: the analytic
//! per-rank threshold brackets the priced crossover, and the prefill
//! pricing is the exact `SimEngine::prepare_shared` formulation.

pub mod admission;
pub mod kernel;
pub mod migration;
pub mod recovery;
pub mod scaling;

use std::collections::HashMap;
use std::sync::Arc;

use crate::config::{HardwareSpec, KernelKind, ModelConfig};
use crate::costmodel::exec_time::component_time;
use crate::costmodel::parallel::ParallelismConfig;
use crate::costmodel::surface::PriceSurface;
use crate::costmodel::transfer::{prefix_transfer_seconds, shared_prefill_seconds};

pub use admission::SloAdmission;
pub use kernel::{GroupContext, KernelDescriptor, KernelPolicy, KernelRegistry};
pub use migration::{MigrationDecision, MigrationPolicy};
pub use recovery::{RecoveryPolicy, RetryAttempt};
pub use scaling::{ScalingDecision, ScalingPolicy};

/// The decision registry one serving stack (or cluster router) owns.
#[derive(Debug)]
pub struct PolicyEngine {
    hw: HardwareSpec,
    par: ParallelismConfig,
    /// Memoized Table-1 pricing shared by every decision that needs a
    /// shared-stage cost (same exactness discipline as the engines) —
    /// and, when constructed via [`PolicyEngine::with_surface`], shared
    /// with the whole fleet.
    surface: Arc<PriceSurface>,
    pub kernel: KernelPolicy,
    pub migration: MigrationPolicy,
    pub admission: SloAdmission,
    pub scaling: ScalingPolicy,
    pub recovery: RecoveryPolicy,
    /// Memoized modeled prefill seconds per shared length.
    prefill_memo: HashMap<u64, f64>,
    /// Memoized modeled transfer seconds per (tokens, expanded).
    transfer_memo: HashMap<(u64, bool), f64>,
}

impl PolicyEngine {
    /// Build the registry for a stack running `requested` under
    /// (TP, SP) sharding: the kernel threshold is the per-rank Eq. 1;
    /// migration and SLO admission start disabled (the PR 3 behavior)
    /// until configured via the public fields.
    pub fn new(
        model: ModelConfig,
        hw: HardwareSpec,
        requested: KernelKind,
        par: ParallelismConfig,
    ) -> Self {
        let surface = PriceSurface::shared(model, hw.clone(), par);
        Self::with_surface(hw, requested, par, surface)
    }

    /// Build the registry against an existing fleet-shared
    /// [`PriceSurface`] — the cluster router hands the same surface to
    /// its policy engine and every replica stack, so all of them price
    /// against one warm memo.  The surface must cover this engine's
    /// cell (its own model, the given hardware/parallelism, `s_q = 1`);
    /// a mismatch is a debug assertion, and release builds fall back to
    /// a fresh private surface rather than returning wrong prices.
    pub fn with_surface(
        hw: HardwareSpec,
        requested: KernelKind,
        par: ParallelismConfig,
        surface: Arc<PriceSurface>,
    ) -> Self {
        debug_assert!(
            surface.covers(surface.model(), &hw, &par, 1),
            "price surface cell mismatch: surface prices ({}, {:?}), policy wants ({}, {:?})",
            surface.hardware().name,
            surface.parallelism(),
            hw.name,
            par,
        );
        let surface = if surface.covers(surface.model(), &hw, &par, 1) {
            surface
        } else {
            PriceSurface::shared(surface.model().clone(), hw.clone(), par)
        };
        let mut kernel =
            KernelPolicy::from_parallelism(requested, surface.model(), &hw, 1, &par);
        kernel.attach_surface(&surface);
        PolicyEngine {
            surface,
            hw,
            par,
            kernel,
            migration: MigrationPolicy::default(),
            admission: SloAdmission::default(),
            scaling: ScalingPolicy::default(),
            recovery: RecoveryPolicy::default(),
            prefill_memo: HashMap::new(),
            transfer_memo: HashMap::new(),
        }
    }

    pub fn model(&self) -> &ModelConfig {
        self.surface.model()
    }

    /// The fleet-shared pricing cache this engine consults.
    pub fn surface(&self) -> &Arc<PriceSurface> {
        &self.surface
    }

    pub fn parallelism(&self) -> ParallelismConfig {
        self.par
    }

    /// The per-group kernel decision (delegates to the fall-back rule).
    pub fn select(&self, occupancy: usize, shared_len: usize) -> KernelKind {
        self.kernel.select(occupancy, shared_len)
    }

    /// The registry decision with the group's mean non-shared context
    /// threaded through (an N-way registry prices it; the binary seed
    /// population ignores it, so this is `select` bit-identical there).
    pub fn select_group(
        &self,
        occupancy: usize,
        shared_len: usize,
        mean_non_shared: usize,
    ) -> KernelKind {
        self.kernel.select_group(occupancy, shared_len, mean_non_shared)
    }

    /// Modeled per-rank seconds of one group's shared stage at a given
    /// occupancy — the quantity Eq. 1 trades off, priced through the
    /// fleet-shared [`PriceSurface`].  The kernel decision itself uses
    /// the precomputed threshold; this probe is the pricing surface
    /// follow-up policies (replica autoscaling, migration batching —
    /// see ROADMAP) query, and tests pin it against the crossover.
    pub fn shared_stage_seconds(
        &mut self,
        kernel: KernelKind,
        occupancy: u64,
        shared_len: u64,
    ) -> f64 {
        let c = self.surface.cost(kernel, occupancy, shared_len, 0);
        [c.shared, c.proj_kvb1, c.proj_kvb2, c.combine]
            .iter()
            .map(|comp| component_time(comp, &self.hw))
            .sum()
    }

    /// Memoized modeled seconds to stream a prefix group's pages to a
    /// peer replica over the interconnect (rank pairs stream their
    /// shards concurrently, mirroring the `/ ranks` sharding of the
    /// competing re-prefill price).
    pub fn prefix_transfer_seconds(&mut self, tokens: usize, expanded: bool) -> f64 {
        let key = (tokens as u64, expanded);
        if let Some(&s) = self.transfer_memo.get(&key) {
            return s;
        }
        let s =
            prefix_transfer_seconds(self.surface.model(), &self.hw, tokens, expanded, &self.par);
        self.transfer_memo.insert(key, s);
        s
    }

    /// Memoized modeled seconds to rebuild a shared prefix from
    /// scratch on this stack (what a spill stream triggers on a fresh
    /// target).
    pub fn shared_prefill_seconds(&mut self, tokens: usize) -> f64 {
        let key = tokens as u64;
        if let Some(&s) = self.prefill_memo.get(&key) {
            return s;
        }
        let s = shared_prefill_seconds(self.surface.model(), &self.hw, tokens, self.par.ranks());
        self.prefill_memo.insert(key, s);
        s
    }

    /// The migrate-vs-spill call for one pressured prefix group.
    /// `dst_hosts_pages` says whether the candidate peer already holds
    /// the group's pages (from an earlier spill): then both priced
    /// costs are sunk — no transfer crosses the wire and no re-prefill
    /// would run — and re-homing is pure consolidation, so migration
    /// wins outright; the cost comparison only arbitrates fresh
    /// destinations.
    pub fn migrate_or_spill(
        &mut self,
        tokens: usize,
        expanded: bool,
        dst_hosts_pages: bool,
    ) -> MigrationDecision {
        if !self.migration.enabled {
            return MigrationDecision::Spill;
        }
        if self.rehome_by_transfer(tokens, expanded, dst_hosts_pages) {
            MigrationDecision::Migrate
        } else {
            MigrationDecision::Spill
        }
    }

    /// The raw transfer-vs-prefill comparison, without the migration
    /// master switch: true when streaming the group's pages beats
    /// rebuilding them at the destination.  Replica autoscaling prices
    /// every scale-event re-home through this (a spin-up/spin-down
    /// must move or rebuild its groups regardless of whether pressure
    /// migration is enabled).
    pub fn rehome_by_transfer(
        &mut self,
        tokens: usize,
        expanded: bool,
        dst_hosts_pages: bool,
    ) -> bool {
        if dst_hosts_pages {
            return true;
        }
        let transfer = self.prefix_transfer_seconds(tokens, expanded);
        let reprefill = self.shared_prefill_seconds(tokens);
        MigrationPolicy::new(true).decide(transfer, reprefill) == MigrationDecision::Migrate
    }

    /// The served-token budget that amortizes one re-home of a group
    /// with this prefix shape: the modeled transfer seconds divided by
    /// the per-token cost of serving the group *fragmented* (two
    /// shared-stage streams instead of one, evaluated at the Eq. 1
    /// threshold occupancy — the regime the migration defends).  The
    /// group may not re-home again until it has served this many
    /// tokens (`MigrationPolicy::cooldown_tokens`).
    pub fn migration_cooldown_tokens(&mut self, tokens: usize, expanded: bool) -> u64 {
        if !self.migration.cooldown {
            return 0;
        }
        let transfer = self.prefix_transfer_seconds(tokens, expanded);
        // Clamped threshold occupancy: the saving is evaluated where
        // Eq. 1 says concentration starts paying (never at a degenerate
        // or astronomically large batch).
        let b = self.kernel.b_theta.clamp(2, 4096) as u64;
        let kernel = self.select(b as usize, tokens);
        let l = tokens as u64;
        let whole = self.shared_stage_seconds(kernel, b, l);
        let frag = self.shared_stage_seconds(kernel, b / 2, l)
            + self.shared_stage_seconds(kernel, b - b / 2, l);
        let saving_per_token = (frag - whole) / b as f64;
        self.migration.cooldown_tokens(transfer, saving_per_token)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::ascend_npu;
    use crate::config::model::deepseek_v3;
    use crate::costmodel::transfer;

    fn engine() -> PolicyEngine {
        PolicyEngine::new(
            deepseek_v3(),
            ascend_npu(),
            KernelKind::Typhoon,
            ParallelismConfig::single(),
        )
    }

    #[test]
    fn registry_derives_eq1_and_selects_per_group() {
        let p = engine();
        assert_eq!(p.kernel.b_theta, 61);
        assert_eq!(p.select(100, 4096), KernelKind::Typhoon);
        assert_eq!(p.select(8, 4096), KernelKind::Absorb);
    }

    #[test]
    fn migrate_or_spill_disabled_then_cost_driven() {
        let mut p = engine();
        assert_eq!(p.migrate_or_spill(26472, true, false), MigrationDecision::Spill);
        p.migration.enabled = true;
        assert_eq!(
            p.migrate_or_spill(26472, true, false),
            MigrationDecision::Migrate,
            "paper-scale prefix: transfer beats re-prefill"
        );
        // Memoized second call agrees.
        assert_eq!(p.migrate_or_spill(26472, true, false), MigrationDecision::Migrate);
    }

    /// Residency short-circuits the cost comparison: a peer that
    /// already holds the pages makes re-homing free even when a fresh
    /// transfer would lose to the re-prefill (slow interconnect).
    #[test]
    fn resident_destination_migrates_even_on_a_slow_link() {
        let mut hw = ascend_npu();
        hw.interconnect_bw = 1e-3; // fresh transfers always lose
        let mut p = PolicyEngine::new(
            deepseek_v3(),
            hw,
            KernelKind::Typhoon,
            ParallelismConfig::single(),
        );
        p.migration.enabled = true;
        assert_eq!(p.migrate_or_spill(26472, true, false), MigrationDecision::Spill);
        assert_eq!(p.migrate_or_spill(26472, true, true), MigrationDecision::Migrate);
    }

    #[test]
    fn memoized_pricing_matches_direct() {
        let cfg = deepseek_v3();
        let hw = ascend_npu();
        let mut p = engine();
        let a = p.prefix_transfer_seconds(7069, false);
        assert_eq!(
            a.to_bits(),
            transfer::prefix_transfer_seconds(
                &cfg,
                &hw,
                7069,
                false,
                &ParallelismConfig::single()
            )
            .to_bits()
        );
        assert_eq!(a.to_bits(), p.prefix_transfer_seconds(7069, false).to_bits());
        let b = p.shared_prefill_seconds(7069);
        assert_eq!(
            b.to_bits(),
            transfer::shared_prefill_seconds(&cfg, &hw, 7069, 1).to_bits()
        );
        assert_eq!(b.to_bits(), p.shared_prefill_seconds(7069).to_bits());
    }

    /// The shared-stage pricing goes through the memoized table and
    /// reflects the Eq. 1 trade-off: at the threshold occupancy the
    /// typhoon stage stops losing to absorb.
    #[test]
    fn shared_stage_pricing_reflects_the_crossover() {
        let mut p = engine();
        let b = p.kernel.b_theta as u64;
        let t_above = p.shared_stage_seconds(KernelKind::Typhoon, b + 1, 4096);
        let a_above = p.shared_stage_seconds(KernelKind::Absorb, b + 1, 4096);
        assert!(t_above <= a_above, "above B_theta typhoon wins: {t_above} vs {a_above}");
        let t_below = p.shared_stage_seconds(KernelKind::Typhoon, b / 2, 4096);
        let a_below = p.shared_stage_seconds(KernelKind::Absorb, b / 2, 4096);
        assert!(a_below < t_below, "below B_theta absorb wins");
    }

    /// Two policy engines adopting one fleet surface price bit-
    /// identically to a private engine, and the second engine's probes
    /// ride the memo the first one warmed (zero new misses).
    #[test]
    fn with_surface_shares_one_warm_memo() {
        let surface = PriceSurface::shared(
            deepseek_v3(),
            ascend_npu(),
            ParallelismConfig::single(),
        );
        let mut a = PolicyEngine::with_surface(
            ascend_npu(),
            KernelKind::Typhoon,
            ParallelismConfig::single(),
            Arc::clone(&surface),
        );
        let mut b = PolicyEngine::with_surface(
            ascend_npu(),
            KernelKind::Typhoon,
            ParallelismConfig::single(),
            Arc::clone(&surface),
        );
        let x = a.shared_stage_seconds(KernelKind::Typhoon, 100, 4096);
        let (_, misses_warm) = surface.stats();
        let y = b.shared_stage_seconds(KernelKind::Typhoon, 100, 4096);
        let (hits, misses_after) = surface.stats();
        assert_eq!(x.to_bits(), y.to_bits());
        assert_eq!(misses_after, misses_warm, "second engine rides the warm memo");
        assert!(hits > 0);
        let mut fresh = engine();
        assert_eq!(
            fresh.shared_stage_seconds(KernelKind::Typhoon, 100, 4096).to_bits(),
            x.to_bits(),
            "shared and private pricing are bit-identical"
        );
    }

    #[test]
    fn slo_admission_defaults_off() {
        let p = engine();
        assert_eq!(p.admission.spill_depth(100.0, 100.0, 13), 13);
    }

    #[test]
    fn scaling_defaults_off() {
        let p = engine();
        assert!(!p.scaling.enabled);
        assert_eq!(p.scaling.decide(1e9, 1.0, 2), scaling::ScalingDecision::Hold);
    }

    /// The cool-down budget is finite and meaningful for every Table-2
    /// prefix shape on the default hardware: the transfer amortizes in
    /// a bounded number of served tokens, and a longer transfer (same
    /// saving structure) never amortizes faster.
    #[test]
    fn cooldown_budget_finite_for_paper_prefixes() {
        let mut p = engine();
        p.migration.enabled = true;
        for tokens in crate::workload::tenants::TABLE2_LENGTHS {
            let budget = p.migration_cooldown_tokens(tokens, true);
            assert!(budget > 0, "tokens={tokens}: a paid transfer needs amortizing");
            assert!(
                budget < 100_000,
                "tokens={tokens}: budget {budget} should be servable"
            );
        }
        // Eager mode (the PR 4 rule) disables the budget entirely.
        p.migration.cooldown = false;
        assert_eq!(p.migration_cooldown_tokens(26472, true), 0);
    }

    /// `rehome_by_transfer` is `migrate_or_spill` without the master
    /// switch: scaling consults it even when pressure migration is off.
    #[test]
    fn rehome_pricing_ignores_master_switch() {
        let mut p = engine();
        assert!(!p.migration.enabled);
        assert_eq!(p.migrate_or_spill(26472, true, false), MigrationDecision::Spill);
        assert!(p.rehome_by_transfer(26472, true, false), "transfer wins the pricing");
        assert!(p.rehome_by_transfer(1, false, true), "residency is always free");
    }
}
