//! SLO-driven admission: derive the router's pressure threshold from a
//! TTFT target and observed rates instead of a fixed queue-depth
//! constant.
//!
//! A replica with backlog `d` and service rate `mu` (completions per
//! busy second) admits a newly arrived request after roughly `d / mu`
//! seconds of queueing — the dominant TTFT term once the batch is
//! full.  Holding that delay under the TTFT target bounds the backlog
//! at `floor(target * mu)`; arrivals that would push past it are
//! spilled/migrated instead.  Before the replica has any completion
//! history, the observed fleet arrival rate stands in for `mu` (in
//! steady state a keeping-up replica completes as fast as its share
//! arrives).

#[derive(Clone, Copy, Debug, Default)]
pub struct SloAdmission {
    /// TTFT target in seconds; `None` falls back to the caller's fixed
    /// queue-depth threshold (the PR 3 behavior, bit-identical).
    pub ttft_target: Option<f64>,
}

impl SloAdmission {
    pub fn new(ttft_target: Option<f64>) -> Self {
        SloAdmission { ttft_target }
    }

    /// The queue depth at which a replica counts as pressured.
    ///
    /// `service_rate` is the replica's observed completions per busy
    /// second (0 when it has no history yet); `arrival_rate` is the
    /// observed per-replica arrival rate (may be 0/inf early in a run
    /// or under the batch protocol).  Returns `fallback` when no target
    /// is set or neither rate is usable yet; never returns 0 (a zero
    /// threshold would spill every request unconditionally).
    pub fn spill_depth(&self, service_rate: f64, arrival_rate: f64, fallback: usize) -> usize {
        let Some(target) = self.ttft_target else {
            return fallback;
        };
        let mu = if service_rate > 0.0 { service_rate } else { arrival_rate };
        if !mu.is_finite() || mu <= 0.0 {
            return fallback;
        }
        ((target * mu).floor() as usize).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_target_returns_fallback() {
        let a = SloAdmission::new(None);
        assert_eq!(a.spill_depth(100.0, 50.0, 7), 7);
    }

    #[test]
    fn depth_scales_with_target_and_service_rate() {
        let a = SloAdmission::new(Some(0.5));
        // mu = 100 req/s, target 0.5 s -> 50 queued tolerable.
        assert_eq!(a.spill_depth(100.0, 0.0, 7), 50);
        let tight = SloAdmission::new(Some(0.01));
        assert_eq!(tight.spill_depth(100.0, 0.0, 7), 1);
    }

    #[test]
    fn arrival_rate_stands_in_before_history() {
        let a = SloAdmission::new(Some(1.0));
        assert_eq!(a.spill_depth(0.0, 20.0, 7), 20);
        // Neither rate usable yet: fall back.
        assert_eq!(a.spill_depth(0.0, 0.0, 7), 7);
        assert_eq!(a.spill_depth(0.0, f64::INFINITY, 7), 7);
    }

    #[test]
    fn depth_never_zero() {
        let a = SloAdmission::new(Some(1e-9));
        assert_eq!(a.spill_depth(100.0, 0.0, 7), 1);
    }
}
