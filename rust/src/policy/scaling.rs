//! Replica autoscaling: size the fleet to the observed load.
//!
//! TyphoonMLA's fleet-level win is *concentration* — prefix-affinity
//! routing keeps each group's occupancy on the replica holding its
//! pages.  Concentration only pays while the fleet matches the load:
//! an over-provisioned fleet strands groups at fragment occupancy and
//! an under-provisioned one sheds a hot group's overflow as spills
//! (each spill fragments the group and duplicates its shared-stage
//! stream).  This policy closes the loop: the router observes the
//! arrival rate and the per-replica SLO headroom and spins replicas up
//! or down mid-run, re-homing prefix groups over the migration path as
//! the fleet resizes.
//!
//! The decision is a utilization rule over two *observed* rates — no
//! workload-specific constants:
//!
//! * lambda-hat: the windowed fleet arrival rate (requests/second of
//!   wall time over the last `rate_window` arrivals — windowed so a
//!   burst is visible against a calm history);
//! * mu-hat: the summed per-replica service rates (completions per
//!   busy decode second, `Coordinator::service_rate`) of the *active*
//!   replicas — each replica's saturated capacity.
//!
//! Scale **up** when `lambda > headroom * mu_fleet` (the fleet is past
//! its target utilization, queueing delay will blow through the SLO);
//! scale **down** when the fleet one replica smaller would still sit
//! under `down_factor * headroom` utilization (the hysteresis gap
//! keeps up/down from oscillating around one threshold).  Both rates
//! must be observable and finite — the batch protocol (everything at
//! t = 0, lambda infinite) and the cold start (no completions, mu = 0)
//! hold the fleet exactly as configured, which is what the
//! never-triggered bit-identity pin leans on.
//!
//! *Pricing* of a scale event is not here: the cluster prices each
//! group's re-home through `PolicyEngine` (bulk page migration over
//! the interconnect versus a fresh re-prefill at the destination) and
//! executes it over the same `migrate_group` / `import_prefix_group`
//! path pressure migration uses.

use crate::config::ScalingConfig;

/// What the fleet should do right now.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScalingDecision {
    /// The fleet matches the load (or the rates are not observable).
    Hold,
    /// Spin a replica up (utilization past the headroom target).
    Up,
    /// Spin a replica down (one fewer would still have headroom).
    Down,
}

/// The utilization-driven autoscaling rule (see module docs).
#[derive(Clone, Copy, Debug)]
pub struct ScalingPolicy {
    /// Master switch: disabled holds the fleet exactly as configured
    /// (the fixed-fleet reduction tests pin this).
    pub enabled: bool,
    /// Target utilization rho* in (0, 1]: scale up past it.
    pub headroom: f64,
    /// Scale-down hysteresis in (0, 1): the shrunk fleet must sit under
    /// `down_factor * headroom` utilization before a replica retires.
    pub down_factor: f64,
    pub min_replicas: usize,
    pub max_replicas: usize,
    /// Arrivals in the windowed lambda-hat estimate.
    pub rate_window: usize,
    /// Minimum arrivals between scale events (rate limiter, so one
    /// burst triggers one resize, not one per arrival).
    pub cooldown_arrivals: usize,
}

impl Default for ScalingPolicy {
    fn default() -> Self {
        Self::from_config(&ScalingConfig::for_fleet(1))
    }
}

impl ScalingPolicy {
    /// Adopt the validated operator-facing knobs.
    pub fn from_config(cfg: &ScalingConfig) -> Self {
        ScalingPolicy {
            enabled: cfg.enabled,
            headroom: cfg.headroom,
            down_factor: cfg.down_factor,
            min_replicas: cfg.min_replicas,
            max_replicas: cfg.max_replicas,
            rate_window: cfg.rate_window,
            cooldown_arrivals: cfg.cooldown_arrivals,
        }
    }

    /// The sizing rule.  `arrival_rate` is the windowed fleet
    /// lambda-hat (wall requests/second); `fleet_service_rate` the
    /// summed active-replica mu-hat (completions per busy second);
    /// `active` the current active replica count.  Unobservable rates
    /// (cold start, the batch protocol's infinite lambda) hold.
    pub fn decide(
        &self,
        arrival_rate: f64,
        fleet_service_rate: f64,
        active: usize,
    ) -> ScalingDecision {
        if !self.enabled || active == 0 {
            return ScalingDecision::Hold;
        }
        if !arrival_rate.is_finite() || arrival_rate <= 0.0 {
            return ScalingDecision::Hold;
        }
        if !fleet_service_rate.is_finite() || fleet_service_rate <= 0.0 {
            return ScalingDecision::Hold;
        }
        if active < self.max_replicas && arrival_rate > self.headroom * fleet_service_rate {
            return ScalingDecision::Up;
        }
        if active > self.min_replicas {
            // Capacity with one replica retired, assuming the mean
            // per-replica rate (the victim is chosen idle, so this is
            // conservative).
            let shrunk = fleet_service_rate * (active - 1) as f64 / active as f64;
            if arrival_rate < self.headroom * self.down_factor * shrunk {
                return ScalingDecision::Down;
            }
        }
        ScalingDecision::Hold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(min: usize, max: usize) -> ScalingPolicy {
        let mut cfg = ScalingConfig::for_fleet(2);
        cfg.enabled = true;
        cfg.min_replicas = min;
        cfg.max_replicas = max;
        let mut p = ScalingPolicy::from_config(&cfg);
        p.headroom = 0.8;
        p.down_factor = 0.5;
        p
    }

    #[test]
    fn disabled_always_holds() {
        let mut p = policy(1, 8);
        p.enabled = false;
        assert_eq!(p.decide(1e9, 1.0, 2), ScalingDecision::Hold);
        assert_eq!(p.decide(1e-9, 1e9, 2), ScalingDecision::Hold);
    }

    #[test]
    fn overload_scales_up_until_the_cap() {
        let p = policy(1, 4);
        // lambda 100 > 0.8 * mu 100 -> up.
        assert_eq!(p.decide(100.0, 100.0, 2), ScalingDecision::Up);
        assert_eq!(p.decide(100.0, 100.0, 4), ScalingDecision::Hold, "at the cap");
    }

    #[test]
    fn deep_underload_scales_down_until_the_floor() {
        let p = policy(2, 8);
        // Shrunk capacity 100 * 3/4 = 75; threshold 0.8*0.5*75 = 30.
        assert_eq!(p.decide(10.0, 100.0, 4), ScalingDecision::Down);
        assert_eq!(p.decide(10.0, 100.0, 2), ScalingDecision::Hold, "at the floor");
    }

    /// The hysteresis gap: between the up and down thresholds the fleet
    /// holds, so the rule cannot oscillate around one boundary.
    #[test]
    fn mid_band_holds() {
        let p = policy(1, 8);
        for lambda in [31.0, 50.0, 79.0] {
            assert_eq!(p.decide(lambda, 100.0, 2), ScalingDecision::Hold, "{lambda}");
        }
    }

    /// Unobservable rates hold: cold start (mu = 0), the batch
    /// protocol's infinite lambda, and a not-yet-started stream.
    #[test]
    fn unobservable_rates_hold() {
        let p = policy(1, 8);
        assert_eq!(p.decide(f64::INFINITY, 100.0, 2), ScalingDecision::Hold);
        assert_eq!(p.decide(100.0, 0.0, 2), ScalingDecision::Hold);
        assert_eq!(p.decide(0.0, 100.0, 2), ScalingDecision::Hold);
        assert_eq!(p.decide(f64::NAN, 100.0, 2), ScalingDecision::Hold);
    }

    /// Pinched bounds (min == max) hold regardless of load — the
    /// configuration the never-triggered bit-identity test uses.
    #[test]
    fn pinched_bounds_never_scale() {
        let p = policy(2, 2);
        assert_eq!(p.decide(1e9, 1.0, 2), ScalingDecision::Hold);
        assert_eq!(p.decide(1e-9, 1e9, 2), ScalingDecision::Hold);
    }
}
