//! System prompts used in the paper's experiments (Table 2).
//!
//! Substitution: the paper uses leaked production prompts (Johnson,
//! 2025); only the *token count* affects attention throughput, so we
//! model each prompt as a deterministic synthetic token sequence of the
//! paper's exact length.

/// A shared system prompt.
#[derive(Clone, Debug, PartialEq)]
pub struct SystemPrompt {
    pub name: &'static str,
    pub service: &'static str,
    pub tokens: usize,
}

/// Table 2, Prompt A: Claude-4, 26472 tokens.
pub const PROMPT_A: SystemPrompt =
    SystemPrompt { name: "prompt-a", service: "Claude-4", tokens: 26472 };

/// Table 2, Prompt B: OpenAI/o3, 7069 tokens.
pub const PROMPT_B: SystemPrompt =
    SystemPrompt { name: "prompt-b", service: "OpenAI/o3", tokens: 7069 };

/// Table 2, Prompt C: Grok/Personas, 4759 tokens.
pub const PROMPT_C: SystemPrompt =
    SystemPrompt { name: "prompt-c", service: "Grok/Personas", tokens: 4759 };

pub fn all_prompts() -> [SystemPrompt; 3] {
    [PROMPT_A, PROMPT_B, PROMPT_C]
}

pub fn by_name(name: &str) -> Option<SystemPrompt> {
    match name {
        "prompt-a" | "a" => Some(PROMPT_A),
        "prompt-b" | "b" => Some(PROMPT_B),
        "prompt-c" | "c" => Some(PROMPT_C),
        _ => None,
    }
}

impl SystemPrompt {
    /// Deterministic synthetic token ids of the prompt's length
    /// (seeded by name so different prompts never collide in the radix
    /// tree).
    pub fn token_ids(&self, vocab: u32) -> Vec<u32> {
        let mut rng = crate::util::rng::Rng::new(
            self.name.bytes().map(|b| b as u64).sum::<u64>(),
        );
        (0..self.tokens).map(|_| rng.gen_range(0, vocab as u64) as u32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_token_counts() {
        assert_eq!(PROMPT_A.tokens, 26472);
        assert_eq!(PROMPT_B.tokens, 7069);
        assert_eq!(PROMPT_C.tokens, 4759);
    }

    #[test]
    fn token_ids_deterministic_and_distinct() {
        let a1 = PROMPT_A.token_ids(256);
        let a2 = PROMPT_A.token_ids(256);
        assert_eq!(a1, a2);
        assert_eq!(a1.len(), 26472);
        let b = PROMPT_B.token_ids(256);
        assert_ne!(&a1[..100], &b[..100]);
    }
}
